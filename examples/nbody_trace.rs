//! N-Body with trace collection — the Figure 13 analogue on the *real*
//! threaded runtime (the simulated version is `repro trace --exp fig13`).
//!
//! Runs the nested-task N-Body workload on the DDAST and Sync runtimes,
//! dumps the tasks-in-graph / thread-state traces to CSV, and prints
//! summary statistics showing DDAST's faster task submission.
//!
//! Run: `cargo run --release --example nbody_trace`

use std::sync::Arc;

use ddast::coordinator::{RuntimeKind, TaskSystem, TraceKind};
use ddast::workloads::{executor, nbody};

fn run(kind: RuntimeKind) {
    let spec = Arc::new(nbody::generate(nbody::NBodyParams {
        num_particles: 2048,
        timesteps: 2, // like the paper's Fig 13 trace
        bs: 128,
    }));
    let ts = TaskSystem::builder().kind(kind).num_threads(4).tracing(true).build();
    let t0 = std::time::Instant::now();
    let log = executor::run_spec(&ts, &spec, executor::ExecOptions::default());
    let elapsed = t0.elapsed();
    let rt = ts.runtime().clone();
    assert!(log.all_ran());

    let tracer = rt.tracer.as_ref().expect("tracing enabled");
    let events = tracer.merged();
    let task_spans = events
        .iter()
        .filter(|e| matches!(e.kind, TraceKind::TaskStart { .. }))
        .count();
    let mgr_spans = events
        .iter()
        .filter(|e| {
            matches!(
                e.kind,
                TraceKind::State { state: ddast::coordinator::ThreadState::Manager, .. }
            )
        })
        .count();
    let csv = tracer.dump_csv();
    let path = format!("/tmp/nbody_trace_{kind:?}.csv");
    std::fs::write(&path, &csv).expect("write trace");
    // Paraver-compatible export (the paper's §6.2 tooling).
    let prv = tracer.dump_prv(4);
    std::fs::write(format!("/tmp/nbody_trace_{kind:?}.prv"), &prv).expect("write prv");
    println!(
        "{kind:?}: {} tasks in {:.1}ms — {} task spans, {} manager activations, trace -> {path} ({} events)",
        spec.num_tasks(),
        elapsed.as_secs_f64() * 1e3,
        task_spans,
        mgr_spans,
        events.len()
    );
    ts.shutdown();

    // The paper's Fig 13 observation: creators + children all executed, and
    // under DDAST idle threads did manager work.
    assert_eq!(task_spans, spec.num_tasks());
    if kind == RuntimeKind::Ddast {
        assert!(mgr_spans > 0, "idle threads should have become managers");
    }
}

fn main() {
    run(RuntimeKind::Sync);
    run(RuntimeKind::Ddast);
    println!("nbody_trace OK ✔");
}
