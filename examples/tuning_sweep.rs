//! Table 5 / §5 tuning protocol as a runnable example: sweep each DDAST
//! parameter on the simulated machines and print the speedup-over-default
//! tables (quick problem sizes; `repro bench --exp fig5..fig8` runs the
//! full versions).
//!
//! Run: `cargo run --release --example tuning_sweep`

use ddast::bench_harness::figures::{self, FigureOpts, Param};

fn main() {
    let opts = FigureOpts::quick();
    for param in [
        Param::MaxDdastThreads,
        Param::MaxSpins,
        Param::MaxOpsThread,
        Param::MinReadyTasks,
    ] {
        println!("{}", figures::param_sweep(param, opts));
    }
    println!("{}", figures::table5(opts));
    println!("tuning_sweep OK ✔");
}
