//! Quickstart: the OmpSs-style API on the DDAST runtime.
//!
//! Reproduces Listing 1 of the paper — the `propagate`/`correct` pipeline —
//! and prints the execution order, demonstrating that the asynchronous
//! runtime enforces the same dependences the pragma annotations declare.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::{Arc, Mutex};

use ddast::coordinator::{DepMode, RuntimeKind, TaskSystem};

fn main() {
    const N: usize = 6;
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(4)
        .build();

    // Region keys: a[i] -> 0x100+i, b[i] -> 0x200+i (Listing 1's arrays).
    let a = |i: usize| 0x100 + i as u64;
    let b = |i: usize| 0x200 + i as u64;

    let log: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    for i in 1..N {
        // #pragma omp task in(a[i-1]) inout(a[i]) out(b[i])
        let l = Arc::clone(&log);
        ts.spawn(
            &[(a(i - 1), DepMode::In), (a(i), DepMode::Inout), (b(i), DepMode::Out)],
            move || l.lock().unwrap().push(format!("propagate({i})")),
        );
        // #pragma omp task in(b[i-1]) inout(b[i])
        let l = Arc::clone(&log);
        ts.spawn(&[(b(i - 1), DepMode::In), (b(i), DepMode::Inout)], move || {
            l.lock().unwrap().push(format!("correct({i})"))
        });
    }
    // #pragma omp taskwait
    ts.taskwait();

    let order = log.lock().unwrap().clone();
    println!("execution order ({} tasks):", order.len());
    for entry in &order {
        println!("  {entry}");
    }

    // Verify the true dependences of Figure 1: propagate(i) before
    // propagate(i+1), correct(i) before correct(i+1), propagate(i) before
    // correct(i).
    let pos = |name: &str| order.iter().position(|e| e == name).unwrap();
    for i in 1..N {
        if i > 1 {
            assert!(pos(&format!("propagate({})", i - 1)) < pos(&format!("propagate({i})")));
            assert!(pos(&format!("correct({})", i - 1)) < pos(&format!("correct({i})")));
        }
        assert!(pos(&format!("propagate({i})")) < pos(&format!("correct({i})")));
    }
    let rt = ts.runtime().clone();
    println!(
        "all Figure-1 dependences respected ✔ (manager activations: {})",
        rt.stats.mgr_activations.get()
    );
    ts.shutdown();
}
