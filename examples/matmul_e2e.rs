//! END-TO-END driver: blocked matrix multiply through the whole stack.
//!
//! Proves all three layers compose on a real workload:
//!   L1 Pallas `matmul_block` kernel → L2 jax `matmul_step` → AOT HLO text
//!   artifact → PJRT executable → executed from task bodies scheduled by
//!   the L3 DDAST coordinator with real `in/in/inout` block dependences.
//!
//! The result is verified against a sequential Rust reference GEMM, and the
//! run is repeated on the synchronous (Nanos++-like) baseline for the
//! paper's headline comparison. Recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example matmul_e2e`

use std::sync::{Arc, Mutex};
use std::time::Instant;

use ddast::coordinator::{DepMode, RuntimeKind, TaskSystem};
use ddast::runtime::{ArtifactRegistry, PjrtService, PjrtServiceHost};
use ddast::substrate::region::block_addr;
use ddast::substrate::XorShift64;

const MS: usize = 256; // matrix dimension
const BS: usize = 64; // block dimension (matches the `matmul_block` artifact)
const NB: usize = MS / BS;

type Block = Vec<f32>; // BS*BS row-major

fn rand_matrix(rng: &mut XorShift64) -> Vec<Vec<Block>> {
    (0..NB)
        .map(|_| {
            (0..NB)
                .map(|_| (0..BS * BS).map(|_| (rng.next_f64() as f32) - 0.5).collect())
                .collect()
        })
        .collect()
}

/// Sequential reference: dense GEMM over the block representation.
fn reference_product(a: &[Vec<Block>], b: &[Vec<Block>]) -> Vec<Vec<Block>> {
    let mut c: Vec<Vec<Block>> = vec![vec![vec![0.0; BS * BS]; NB]; NB];
    for i in 0..NB {
        for j in 0..NB {
            for k in 0..NB {
                let (ab, bb) = (&a[i][k], &b[k][j]);
                let cb = &mut c[i][j];
                for r in 0..BS {
                    for q in 0..BS {
                        let av = ab[r * BS + q];
                        if av == 0.0 {
                            continue;
                        }
                        for col in 0..BS {
                            cb[r * BS + col] += av * bb[q * BS + col];
                        }
                    }
                }
            }
        }
    }
    c
}

fn run_blocked(
    kind: RuntimeKind,
    threads: usize,
    svc: &PjrtService,
    a: &Arc<Vec<Vec<Block>>>,
    b: &Arc<Vec<Vec<Block>>>,
) -> (Vec<Vec<Block>>, f64) {
    // Shared, lock-per-block output (tasks on the same block are serialized
    // by the inout dependence; the Mutex is for Rust's benefit only).
    let c: Arc<Vec<Vec<Mutex<Block>>>> = Arc::new(
        (0..NB)
            .map(|_| (0..NB).map(|_| Mutex::new(vec![0.0f32; BS * BS])).collect())
            .collect(),
    );
    let ts = TaskSystem::builder().kind(kind).num_threads(threads).build();
    let t0 = Instant::now();
    for i in 0..NB {
        for j in 0..NB {
            for k in 0..NB {
                let (svc, a, b, c) =
                    (svc.clone(), Arc::clone(a), Arc::clone(b), Arc::clone(&c));
                ts.spawn(
                    &[
                        (block_addr(0, i as u64, k as u64), DepMode::In),
                        (block_addr(1, k as u64, j as u64), DepMode::In),
                        (block_addr(2, i as u64, j as u64), DepMode::Inout),
                    ],
                    move || {
                        let mut cb = c[i][j].lock().unwrap();
                        let out = svc
                            .run_f32(
                                "matmul_block",
                                &[
                                    (&a[i][k][..], &[BS, BS][..]),
                                    (&b[k][j][..], &[BS, BS][..]),
                                    (&cb[..], &[BS, BS][..]),
                                ],
                            )
                            .expect("PJRT execute");
                        cb.copy_from_slice(&out);
                    },
                );
            }
        }
    }
    ts.taskwait();
    let elapsed = t0.elapsed().as_secs_f64();
    ts.shutdown();
    let out = c
        .iter()
        .map(|row| row.iter().map(|m| m.lock().unwrap().clone()).collect())
        .collect();
    (out, elapsed)
}

fn max_abs_diff(x: &[Vec<Block>], y: &[Vec<Block>]) -> f32 {
    let mut m = 0.0f32;
    for (rx, ry) in x.iter().zip(y) {
        for (bx, by) in rx.iter().zip(ry) {
            for (&vx, &vy) in bx.iter().zip(by) {
                m = m.max((vx - vy).abs());
            }
        }
    }
    m
}

fn main() {
    println!("matmul_e2e: {MS}x{MS} f32, BS={BS} ({} tasks), full 3-layer stack", NB * NB * NB);
    let host = PjrtServiceHost::start(ArtifactRegistry::default_dir())
        .expect("run `make artifacts` first");
    let svc = host.handle();
    println!("artifacts loaded: {:?}", svc.names().unwrap());

    let mut rng = XorShift64::new(2024);
    let a = Arc::new(rand_matrix(&mut rng));
    let b = Arc::new(rand_matrix(&mut rng));

    println!("computing sequential reference...");
    let t0 = Instant::now();
    let want = reference_product(&a, &b);
    let t_seq = t0.elapsed().as_secs_f64();

    let threads = 4;
    let (got_ddast, t_ddast) = run_blocked(RuntimeKind::Ddast, threads, &svc, &a, &b);
    let diff = max_abs_diff(&got_ddast, &want);
    println!(
        "DDAST   ({threads} threads): {:.3}s  max|Δ| vs reference = {diff:.2e}",
        t_ddast
    );
    assert!(diff < 1e-2, "numeric mismatch through the stack: {diff}");

    let (got_sync, t_sync) = run_blocked(RuntimeKind::Sync, threads, &svc, &a, &b);
    let diff_sync = max_abs_diff(&got_sync, &want);
    println!(
        "Nanos++ ({threads} threads): {:.3}s  max|Δ| vs reference = {diff_sync:.2e}",
        t_sync
    );
    assert!(diff_sync < 1e-2);

    println!(
        "\nsequential reference: {t_seq:.3}s; DDAST/Nanos++ makespan ratio: {:.3}",
        t_sync / t_ddast
    );
    println!("end-to-end OK ✔ (L1 Pallas → L2 JAX → HLO → PJRT → L3 DDAST)");
}
