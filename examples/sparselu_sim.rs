//! Sparse LU on the simulated 48-core ThunderX — Figures 10 & 14 in one
//! runnable example.
//!
//! Generates the paper's Table-4 workload, simulates all three runtime
//! organizations across the thread sweep, prints the speedup table, and
//! renders the in-graph/ready evolution (pyramid vs roof).
//!
//! Run: `cargo run --release --example sparselu_sim`

use ddast::coordinator::{DdastParams, RuntimeKind};
use ddast::sim::engine::{simulate, SimOptions};
use ddast::sim::machine::MachineConfig;
use ddast::sim::report::{ascii_series, speedup_table, Series};
use ddast::workloads::sparselu;

fn main() {
    let machine = MachineConfig::thunderx();
    let spec = sparselu::generate(sparselu::SparseLuParams { ms: 4096, bs: 128 });
    println!(
        "SparseLU {}: {} tasks on simulated {} ({} cores)\n",
        spec.name,
        spec.num_tasks(),
        machine.name,
        machine.cores
    );

    // Scalability (Figure 10c analogue).
    let mut series = Vec::new();
    for (label, kind) in [
        ("Nanos++", RuntimeKind::Sync),
        ("DDAST", RuntimeKind::Ddast),
        ("GOMP", RuntimeKind::GompLike),
    ] {
        let mut points = Vec::new();
        for &t in &machine.thread_sweep() {
            let r = simulate(&spec, &machine, SimOptions::new(kind, t));
            points.push((t, r.speedup));
        }
        series.push(Series { label: label.into(), points });
    }
    println!("{}", speedup_table("Speedup vs sequential (Fig 10 analogue)", &series));

    // Trace shapes (Figure 14 analogue).
    for (label, kind) in [("Nanos++", RuntimeKind::Sync), ("DDAST", RuntimeKind::Ddast)] {
        let r = simulate(
            &spec,
            &machine,
            SimOptions::new(kind, 48)
                .with_params(DdastParams::tuned(48))
                .with_trace(100_000),
        );
        let tr = r.trace.unwrap();
        println!("{}", ascii_series(&format!("tasks in graph — {label}"), &tr.in_graph, 90, 7));
    }
    println!("sparselu_sim OK ✔");
}
