//! PJRT runtime bridge — loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from task bodies. Python never runs on this path.
//!
//! In the offline build environment the external `xla`/`anyhow` crates are
//! unavailable; the bridge compiles against the in-crate no-op stubs in
//! [`shim`] instead, so `cargo build --features pjrt` (and
//! `examples/matmul_e2e.rs`) stay buildable. Execution through the stub
//! returns a clean error; see `shim`'s docs for swapping the real backend
//! back in.

pub mod artifacts;
pub mod exec;
pub mod service;
pub mod shim;

pub use artifacts::ArtifactRegistry;
pub use exec::{ExecHandle, TensorArg};
pub use service::{PjrtService, PjrtServiceHost};
