//! PJRT runtime bridge — loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from task bodies. Python never runs on this path.

pub mod artifacts;
pub mod exec;
pub mod service;

pub use artifacts::ArtifactRegistry;
pub use exec::{ExecHandle, TensorArg};
pub use service::{PjrtService, PjrtServiceHost};
