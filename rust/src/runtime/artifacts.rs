//! Artifact registry: name → compiled PJRT executable.
//!
//! Interchange format is **HLO text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see `python/compile/aot.py` and
//! /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

// Offline shim stand-ins for the real `anyhow`/`xla` crates (see shim.rs).
use crate::runtime::shim::{anyhow, xla, Context, Result};

use crate::runtime::exec::ExecHandle;

/// A PJRT CPU client plus every compiled artifact found in a directory.
///
/// Not `Send` (the `xla` crate wrappers are `Rc`-based): share it across
/// worker threads through [`crate::runtime::PjrtServiceHost`].
pub struct ArtifactRegistry {
    #[allow(dead_code)] // keeps the client (and its devices) alive
    client: xla::PjRtClient,
    executables: HashMap<String, ExecHandle>,
    dir: PathBuf,
}

impl ArtifactRegistry {
    /// Create a CPU client and compile every `*.hlo.txt` under `dir`.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut executables = HashMap::new();
        let entries = std::fs::read_dir(&dir)
            .with_context(|| format!("artifacts dir {} (run `make artifacts`)", dir.display()))?;
        for entry in entries {
            let path = entry?.path();
            let fname = path.file_name().unwrap_or_default().to_string_lossy().to_string();
            if let Some(name) = fname.strip_suffix(".hlo.txt") {
                let exe = Self::compile_file(&client, &path)
                    .with_context(|| format!("compiling {}", path.display()))?;
                executables.insert(name.to_string(), exe);
            }
        }
        if executables.is_empty() {
            return Err(anyhow!(
                "no *.hlo.txt artifacts in {} — run `make artifacts`",
                dir.display()
            ));
        }
        Ok(ArtifactRegistry { client, executables, dir })
    }

    fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<ExecHandle> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse HLO text: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile: {e:?}"))?;
        Ok(ExecHandle::new(exe))
    }

    /// Names of all loaded artifacts, sorted.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn get(&self, name: &str) -> Result<&ExecHandle> {
        self.executables.get(name).ok_or_else(|| {
            anyhow!("artifact '{name}' not found in {} (have: {:?})", self.dir.display(), self.names())
        })
    }

    /// The default artifacts directory relative to the repo root.
    pub fn default_dir() -> PathBuf {
        // Prefer the env override, else ./artifacts next to the binary's CWD.
        std::env::var_os("DDAST_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests only run when artifacts exist (after `make artifacts`);
    /// the python tests + matmul_e2e example cover the full path.
    fn registry() -> Option<ArtifactRegistry> {
        let dir = ArtifactRegistry::default_dir();
        if dir.join("MANIFEST.txt").exists() {
            Some(ArtifactRegistry::load_dir(dir).expect("artifacts load"))
        } else {
            None
        }
    }

    #[test]
    fn loads_all_artifacts_when_built() {
        let Some(reg) = registry() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let names = reg.names();
        assert!(names.contains(&"matmul_block"), "have {names:?}");
        assert!(reg.get("matmul_block").is_ok());
        assert!(reg.get("definitely_not_there").is_err());
    }

    #[test]
    fn missing_dir_is_a_clean_error() {
        let Err(err) = ArtifactRegistry::load_dir("/nonexistent/path") else {
            panic!("expected error for missing dir");
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("artifacts"), "{msg}");
    }
}
