//! Offline stand-ins for the `anyhow` and `xla` crates, so the PJRT
//! bridge compiles (and its plumbing stays testable) with
//! `--features pjrt` in the dependency-free build environment.
//!
//! The real bridge needs two external crates the offline registry cannot
//! provide: `anyhow` (error plumbing) and `xla` (PJRT client bindings).
//! This module supplies API-compatible skeletons for exactly the surface
//! `exec.rs` / `artifacts.rs` / `service.rs` use:
//!
//! * the `anyhow` shim is functional — message errors, `?` conversion from
//!   std errors, `with_context` chaining;
//! * the `xla` shim is a **no-op client**: loading/compiling artifacts
//!   succeeds structurally (file reads are real, so missing-artifact error
//!   paths behave), but every `execute` returns a clean error instead of
//!   computing. `examples/matmul_e2e.rs` therefore *builds* offline and
//!   fails fast at runtime with an actionable message rather than rotting
//!   uncompiled.
//!
//! Swapping in the real backend: add the `xla` + `anyhow` dependencies and
//! replace the `use crate::runtime::shim::...` imports in the three bridge
//! modules with `use anyhow::...` / the bare `xla::` paths. Nothing else
//! in the bridge refers to this module.

use std::fmt;

/// Minimal `anyhow::Error` stand-in: a single formatted message; context
/// prepends, mirroring `anyhow`'s `{:#}` chain rendering closely enough
/// for our error-path tests.
pub struct Error(String);

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }

    fn wrap(self, ctx: impl fmt::Display) -> Self {
        Error(format!("{ctx}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// Like `anyhow`, `Error` deliberately does not implement `std::error::Error`
// itself, which is what makes this blanket `?`-conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// `anyhow::Result` stand-in.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` stand-in (the lazy `with_context` form the bridge
/// uses, plus the eager `context` for completeness).
pub trait Context<T> {
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }

    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }
}

/// `anyhow!` stand-in: formats its arguments into an [`Error`].
macro_rules! anyhow_msg {
    ($($arg:tt)*) => {
        $crate::runtime::shim::Error::msg(format!($($arg)*))
    };
}
pub(crate) use anyhow_msg as anyhow;

/// No-op `xla` crate stand-in (see module docs): structure-only client,
/// compile and literal plumbing; `execute` always errors.
pub mod xla {
    /// Stub error type; `Debug`-printed by the bridge's `map_err` sites,
    /// like the real crate's error enums.
    #[derive(Debug)]
    pub struct XlaError(pub String);

    type XResult<T> = std::result::Result<T, XlaError>;

    fn no_backend<T>(what: &str) -> XResult<T> {
        Err(XlaError(format!(
            "pjrt stub: {what} requires the real xla backend (offline build — \
             see rust/src/runtime/shim.rs)"
        )))
    }

    /// Stub PJRT CPU client.
    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> XResult<PjRtClient> {
            Ok(PjRtClient)
        }

        pub fn compile(&self, _comp: &XlaComputation) -> XResult<PjRtLoadedExecutable> {
            Ok(PjRtLoadedExecutable)
        }
    }

    /// Parsed HLO module. The stub verifies the file is readable (so the
    /// registry's missing-artifact error paths stay real) but keeps no
    /// contents.
    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(path: &str) -> XResult<HloModuleProto> {
            std::fs::read_to_string(path)
                .map(|_| HloModuleProto)
                .map_err(|e| XlaError(format!("read {path}: {e}")))
        }
    }

    /// Computation wrapper.
    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    /// "Compiled" executable; execution needs the real backend.
    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[Literal]) -> XResult<Vec<Vec<PjRtBuffer>>> {
            no_backend("execute")
        }
    }

    /// Device buffer handle.
    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> XResult<Literal> {
            no_backend("to_literal_sync")
        }
    }

    /// Host literal.
    #[derive(Clone)]
    pub struct Literal;

    impl Literal {
        pub fn vec1(_data: &[f32]) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> XResult<Literal> {
            Ok(Literal)
        }

        pub fn to_tuple(&self) -> XResult<Vec<Literal>> {
            no_backend("to_tuple")
        }

        pub fn to_vec<T>(&self) -> XResult<Vec<T>> {
            no_backend("to_vec")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_context_chains() {
        let base: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let err = base.with_context(|| "artifacts dir /x").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("artifacts"), "{msg}");
        assert!(msg.contains("gone"), "{msg}");
    }

    #[test]
    fn anyhow_macro_formats() {
        let e = anyhow!("thing {} broke: {:?}", 7, "why");
        assert!(format!("{e}").contains("thing 7 broke"));
    }

    #[test]
    fn stub_client_compiles_but_never_executes() {
        let client = xla::PjRtClient::cpu().unwrap();
        let exe = client.compile(&xla::XlaComputation).unwrap();
        let err = exe.execute::<xla::Literal>(&[]).unwrap_err();
        assert!(format!("{err:?}").contains("pjrt stub"));
        assert!(xla::HloModuleProto::from_text_file("/definitely/not/there").is_err());
    }
}
