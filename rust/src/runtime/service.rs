//! PJRT execution service.
//!
//! The `xla` crate's wrapper types are `Rc`-based and not `Send`, but task
//! bodies run on any worker thread. The service owns the
//! [`ArtifactRegistry`] on a dedicated thread and serves execution requests
//! over channels — the same "one executor, many requesters" shape a real
//! deployment would use per device. On this 1-core host PJRT CPU compute
//! would serialize anyway; the coordinator's parallelism lives in the task
//! graph.

use std::sync::mpsc;
use std::thread::JoinHandle;

// Offline shim stand-ins for the real `anyhow` crate (see shim.rs).
use crate::runtime::shim::{anyhow, Result};

use crate::runtime::artifacts::ArtifactRegistry;
use crate::runtime::exec::TensorArg;

enum Request {
    Run {
        name: String,
        args: Vec<(Vec<f32>, Vec<usize>)>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Names { reply: mpsc::Sender<Vec<String>> },
    Shutdown,
}

/// Cloneable, `Send` handle to the PJRT service thread.
pub struct PjrtService {
    tx: mpsc::Sender<Request>,
}

impl Clone for PjrtService {
    fn clone(&self) -> Self {
        PjrtService { tx: self.tx.clone() }
    }
}

/// Owns the service thread; dropping it stops the thread.
pub struct PjrtServiceHost {
    tx: mpsc::Sender<Request>,
    thread: Option<JoinHandle<()>>,
}

impl PjrtServiceHost {
    /// Start the service, loading every artifact under `dir`.
    pub fn start(dir: std::path::PathBuf) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let registry = match ArtifactRegistry::load_dir(&dir) {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Run { name, args, reply } => {
                            let result = registry.get(&name).and_then(|exe| {
                                let tensor_args: Vec<TensorArg<'_>> = args
                                    .iter()
                                    .map(|(data, shape)| TensorArg::new(data, shape))
                                    .collect();
                                exe.run_f32_multi(&tensor_args)
                            });
                            let _ = reply.send(result);
                        }
                        Request::Names { reply } => {
                            let _ = reply
                                .send(registry.names().iter().map(|s| s.to_string()).collect());
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(|e| anyhow!("spawn pjrt-service: {e}"))?;
        ready_rx.recv().map_err(|_| anyhow!("pjrt-service died during init"))??;
        Ok(PjrtServiceHost { tx, thread: Some(thread) })
    }

    /// A sendable handle for task bodies.
    pub fn handle(&self) -> PjrtService {
        PjrtService { tx: self.tx.clone() }
    }
}

impl Drop for PjrtServiceHost {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl PjrtService {
    /// Execute artifact `name` with f32 inputs; returns all tuple outputs.
    pub fn run_f32_multi(
        &self,
        name: &str,
        args: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Run {
                name: name.to_string(),
                args: args.iter().map(|(d, s)| (d.to_vec(), s.to_vec())).collect(),
                reply,
            })
            .map_err(|_| anyhow!("pjrt-service stopped"))?;
        rx.recv().map_err(|_| anyhow!("pjrt-service dropped reply"))?
    }

    /// Single-output convenience.
    pub fn run_f32(&self, name: &str, args: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let mut outs = self.run_f32_multi(name, args)?;
        if outs.len() != 1 {
            return Err(anyhow!("expected 1 output, got {}", outs.len()));
        }
        Ok(outs.pop().unwrap())
    }

    pub fn names(&self) -> Result<Vec<String>> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Request::Names { reply }).map_err(|_| anyhow!("pjrt-service stopped"))?;
        rx.recv().map_err(|_| anyhow!("pjrt-service dropped reply"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service() -> Option<PjrtServiceHost> {
        let dir = ArtifactRegistry::default_dir();
        if dir.join("MANIFEST.txt").exists() {
            Some(PjrtServiceHost::start(dir).expect("service start"))
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn matmul_artifact_numerics_via_service() {
        let Some(host) = service() else { return };
        let svc = host.handle();
        // 64x64: C = 0 + A·I = A.
        let mut a = vec![0.0f32; 64 * 64];
        for (i, v) in a.iter_mut().enumerate() {
            *v = (i % 97) as f32 * 0.25 - 10.0;
        }
        let mut eye = vec![0.0f32; 64 * 64];
        for i in 0..64 {
            eye[i * 64 + i] = 1.0;
        }
        let zero = vec![0.0f32; 64 * 64];
        let out = svc
            .run_f32(
                "matmul_block",
                &[(&a, &[64, 64]), (&eye, &[64, 64]), (&zero, &[64, 64])],
            )
            .expect("execute");
        assert_eq!(out.len(), 64 * 64);
        for (x, y) in out.iter().zip(&a) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn service_usable_from_many_threads() {
        let Some(host) = service() else { return };
        let mut handles = Vec::new();
        for t in 0..4 {
            let svc = host.handle();
            handles.push(std::thread::spawn(move || {
                let a = vec![t as f32; 64 * 64];
                let b = vec![1.0f32; 64 * 64];
                let c = vec![0.0f32; 64 * 64];
                let out = svc
                    .run_f32(
                        "matmul_block",
                        &[(&a, &[64, 64]), (&b, &[64, 64]), (&c, &[64, 64])],
                    )
                    .expect("execute");
                // Row sum: each element = 64 * t.
                assert!((out[0] - 64.0 * t as f32).abs() < 1e-3);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn unknown_artifact_is_error() {
        let Some(host) = service() else { return };
        let svc = host.handle();
        assert!(svc.run_f32("nope", &[]).is_err());
        assert!(svc.names().unwrap().contains(&"lu0".to_string()));
    }
}
