//! Typed execution helpers over PJRT loaded executables.
//!
//! The artifacts are lowered with `return_tuple=True`, so every result is a
//! 1-tuple (or n-tuple) of arrays; `run_f32` unwraps the common
//! single-output case. `ExecHandle` is not `Send` (xla wrappers are
//! `Rc`-based); worker threads go through [`crate::runtime::PjrtService`].

// Offline shim stand-ins for the real `anyhow`/`xla` crates (see shim.rs).
use crate::runtime::shim::{anyhow, xla, Result};

/// A float32 input tensor: data + shape.
#[derive(Clone, Debug)]
pub struct TensorArg<'a> {
    pub data: &'a [f32],
    pub shape: Vec<i64>,
}

impl<'a> TensorArg<'a> {
    pub fn new(data: &'a [f32], shape: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data length must match shape"
        );
        TensorArg { data, shape: shape.iter().map(|&d| d as i64).collect() }
    }
}

/// One compiled artifact.
pub struct ExecHandle {
    exe: xla::PjRtLoadedExecutable,
}

impl ExecHandle {
    pub fn new(exe: xla::PjRtLoadedExecutable) -> Self {
        ExecHandle { exe }
    }

    /// Execute with f32 inputs, return the flattened f32 outputs (one Vec
    /// per tuple element).
    pub fn run_f32_multi(&self, args: &[TensorArg<'_>]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(args.len());
        for a in args {
            let lit = xla::Literal::vec1(a.data)
                .reshape(&a.shape)
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // Artifacts are lowered with return_tuple=True.
        let tuple = result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        let mut out = Vec::with_capacity(tuple.len());
        for t in tuple {
            out.push(t.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        Ok(out)
    }

    /// Single-output convenience.
    pub fn run_f32(&self, args: &[TensorArg<'_>]) -> Result<Vec<f32>> {
        let mut outs = self.run_f32_multi(args)?;
        if outs.len() != 1 {
            return Err(anyhow!("expected 1 output, got {}", outs.len()));
        }
        Ok(outs.pop().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_arg_shape_check() {
        let data = vec![1.0f32; 6];
        let t = TensorArg::new(&data, &[2, 3]);
        assert_eq!(t.shape, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "data length")]
    fn tensor_arg_rejects_mismatch() {
        let data = vec![1.0f32; 5];
        let _ = TensorArg::new(&data, &[2, 3]);
    }
}
