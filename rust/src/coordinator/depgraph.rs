//! The task dependence graph (one *domain* per parent task, §2.2.1).
//!
//! Nanos++ keeps a dependence graph per parent task: children can only
//! depend on sibling tasks, and the graph is protected by a spinlock because
//! sibling submissions/finalizations may race. Both runtime organizations
//! use this same code; what differs is *who* calls it (worker threads
//! directly in the Sync baseline, manager threads in DDAST) and therefore
//! how contended the lock is.
//!
//! Semantics per region (last-writer / reader-set tracking):
//! * `in`    — RAW edge from the last unfinished writer;
//! * `out`   — WAR edges from unfinished readers of the current epoch and a
//!             WAW edge from the last unfinished writer;
//! * `inout` — both.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::wd::Wd;
use crate::substrate::{Counter, SpinLock};

/// Per-region bookkeeping: who wrote it last, who has read it since.
#[derive(Default)]
struct RegionEntry {
    last_writer: Option<Arc<Wd>>,
    readers: Vec<Arc<Wd>>,
}

struct DomainInner {
    /// Keyed by region base address (Nanos++ default plugin: exact match).
    entries: HashMap<u64, RegionEntry>,
    /// Range-overlap plugin (Nanos++'s "regions" plugin): entries keyed by
    /// full `(base, len)` regions, conflict = interval overlap. Linear
    /// scan per op — the correctness-oriented plugin, like the original.
    ranged: Vec<(crate::substrate::RegionKey, RegionEntry)>,
    /// Which plugin this domain uses.
    use_ranges: bool,
}

/// A dependence domain: the task graph of one parent task's children.
pub struct DepDomain {
    inner: SpinLock<DomainInner>,
    /// Tasks currently in the graph (submitted, not yet done-handled).
    /// This is the observable plotted in the paper's Figures 12–14.
    tasks_in_graph: Counter,
}

impl Default for DepDomain {
    fn default() -> Self {
        Self::new()
    }
}

impl DepDomain {
    /// Exact-base-match plugin (Nanos++ default; what the benchmarks use).
    pub fn new() -> Self {
        DepDomain {
            inner: SpinLock::new(DomainInner {
                entries: HashMap::new(),
                ranged: Vec::new(),
                use_ranges: false,
            }),
            tasks_in_graph: Counter::new(),
        }
    }

    /// Range-overlap plugin: dependences on `(base, len)` regions conflict
    /// whenever the intervals overlap, not only on exact base match.
    pub fn new_ranged() -> Self {
        DepDomain {
            inner: SpinLock::new(DomainInner {
                entries: HashMap::new(),
                ranged: Vec::new(),
                use_ranges: true,
            }),
            tasks_in_graph: Counter::new(),
        }
    }

    /// Number of tasks currently tracked by this domain.
    #[inline]
    pub fn tasks_in_graph(&self) -> u64 {
        self.tasks_in_graph.get()
    }

    /// Lock statistics of the domain spinlock: (acquisitions, contended,
    /// spin iterations). Fuel for `sim::calibrate`.
    pub fn lock_stats(&self) -> (u64, u64, u64) {
        self.inner.stats()
    }

    /// Insert `task` into the graph, computing its predecessors (task
    /// life-cycle step 2, "Task submission").
    ///
    /// Returns `true` if the task became ready immediately (no pending
    /// predecessors). The caller is responsible for scheduling it then.
    pub fn submit(&self, task: &Arc<Wd>) -> bool {
        {
            let mut inner = self.inner.lock();
            if inner.use_ranges {
                Self::submit_ranged(&mut inner, task);
            } else {
                Self::submit_exact(&mut inner, task);
            }
        }
        self.tasks_in_graph.inc();
        // Release the submission guard; true -> no predecessors remained.
        task.release_pred()
    }

    fn submit_exact(inner: &mut DomainInner, task: &Arc<Wd>) {
        {
            for dep in &task.deps {
                let entry = inner.entries.entry(dep.region.base).or_default();
                let mode = dep.mode;
                if mode.reads() {
                    // RAW on the last unfinished writer.
                    if let Some(w) = &entry.last_writer {
                        if !w.is_finished() && w.id != task.id {
                            w.successors.lock().push(Arc::clone(task));
                            task.add_preds(1);
                        }
                    }
                }
                if mode.writes() {
                    // WAR on every unfinished reader of the current epoch.
                    for r in &entry.readers {
                        if !r.is_finished() && r.id != task.id {
                            r.successors.lock().push(Arc::clone(task));
                            task.add_preds(1);
                        }
                    }
                    // WAW on the last unfinished writer (only needed when
                    // there were no readers — readers already chain after
                    // the writer — but adding it is correct and mirrors
                    // Nanos++' conservative behaviour).
                    if !mode.reads() {
                        if let Some(w) = &entry.last_writer {
                            if !w.is_finished() && w.id != task.id {
                                w.successors.lock().push(Arc::clone(task));
                                task.add_preds(1);
                            }
                        }
                    }
                    // New write epoch: previous readers are superseded.
                    entry.readers.clear();
                    entry.last_writer = Some(Arc::clone(task));
                } else {
                    entry.readers.push(Arc::clone(task));
                }
            }
        }
    }

    /// Range-overlap submission: conservative interval semantics — a task
    /// orders after every unfinished prior accessor whose region overlaps
    /// conflictingly. Self-registration is on the task's exact region; the
    /// scan matches by overlap.
    fn submit_ranged(inner: &mut DomainInner, task: &Arc<Wd>) {
        for dep in &task.deps {
            let mode = dep.mode;
            for (region, entry) in inner.ranged.iter() {
                if !region.overlaps(&dep.region) {
                    continue;
                }
                // RAW/WAW: order after the overlapping writer.
                if let Some(w) = &entry.last_writer {
                    if !w.is_finished() && w.id != task.id {
                        w.successors.lock().push(Arc::clone(task));
                        task.add_preds(1);
                    }
                }
                // WAR: a writer orders after overlapping readers.
                if mode.writes() {
                    for r in &entry.readers {
                        if !r.is_finished() && r.id != task.id {
                            r.successors.lock().push(Arc::clone(task));
                            task.add_preds(1);
                        }
                    }
                }
            }
            // Register on the exact region entry (create on first touch).
            let idx = match inner.ranged.iter().position(|(r, _)| *r == dep.region) {
                Some(i) => i,
                None => {
                    inner.ranged.push((dep.region, RegionEntry::default()));
                    inner.ranged.len() - 1
                }
            };
            let entry = &mut inner.ranged[idx].1;
            if mode.writes() {
                // Readers of *this exact* region are superseded; partially
                // overlapping readers stay (conservative, still correct:
                // they were ordered before this writer above).
                entry.readers.clear();
                entry.last_writer = Some(Arc::clone(task));
            } else {
                entry.readers.push(Arc::clone(task));
            }
        }
    }

    /// Remove a finished task from the graph and collect the successors
    /// that become ready (task life-cycle step 5, "Task finalization").
    ///
    /// Returns the now-ready tasks; the caller schedules them.
    pub fn finish(&self, task: &Arc<Wd>) -> Vec<Arc<Wd>> {
        debug_assert!(task.is_finished(), "finish() before body completed");
        let succs = {
            let mut inner = self.inner.lock();
            // Prune this task from the region entries it touched. The entry
            // itself is kept (empty) for reuse: benchmarks revisit the same
            // block regions constantly, and dropping/reinserting entries
            // was ~10 % of the finish path (EXPERIMENTS.md §Perf iter 1).
            // Memory stays bounded by the number of *distinct* regions.
            if inner.use_ranges {
                for (_, entry) in inner.ranged.iter_mut() {
                    if entry.last_writer.as_ref().is_some_and(|w| w.id == task.id) {
                        entry.last_writer = None;
                    }
                    entry.readers.retain(|r| r.id != task.id);
                }
            } else {
                for dep in &task.deps {
                    if let Some(entry) = inner.entries.get_mut(&dep.region.base) {
                        if entry
                            .last_writer
                            .as_ref()
                            .is_some_and(|w| w.id == task.id)
                        {
                            entry.last_writer = None;
                        }
                        entry.readers.retain(|r| r.id != task.id);
                    }
                }
            }
            // Drain the successor list; nobody can append anymore because
            // `task.is_finished()` is observed under this same lock by
            // submitters.
            std::mem::take(&mut *task.successors.lock())
        };
        self.tasks_in_graph.dec();
        let mut ready = Vec::new();
        for s in succs {
            if s.release_pred() {
                ready.push(s);
            }
        }
        ready
    }

    /// Number of distinct regions ever tracked (test/diagnostic).
    pub fn regions_tracked(&self) -> usize {
        let inner = self.inner.lock();
        inner.entries.len() + inner.ranged.len()
    }

    /// Regions with a live writer or readers (test/diagnostic).
    pub fn live_regions(&self) -> usize {
        let inner = self.inner.lock();
        inner
            .entries
            .values()
            .chain(inner.ranged.iter().map(|(_, e)| e))
            .filter(|e| e.last_writer.is_some() || !e.readers.is_empty())
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dep::{dep_in, dep_inout, dep_out};
    use crate::coordinator::wd::{TaskId, WdState};
    use std::sync::Weak;

    fn mk(id: u64, deps: Vec<crate::coordinator::dep::Dependence>) -> Arc<Wd> {
        Wd::new(TaskId(id), deps, "t", Weak::new(), Box::new(|| {}))
    }

    fn finish_body(t: &Arc<Wd>) {
        t.set_state(WdState::Ready);
        t.set_state(WdState::Running);
        t.set_state(WdState::Finished);
    }

    #[test]
    fn raw_dependence_chain() {
        let d = DepDomain::new();
        let w = mk(1, vec![dep_out(10)]);
        let r = mk(2, vec![dep_in(10)]);
        assert!(d.submit(&w), "writer has no preds");
        assert!(!d.submit(&r), "reader must wait for writer");
        finish_body(&w);
        let ready = d.finish(&w);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].id, TaskId(2));
    }

    #[test]
    fn war_dependence() {
        let d = DepDomain::new();
        let w = mk(1, vec![dep_out(10)]);
        let r = mk(2, vec![dep_in(10)]);
        let w2 = mk(3, vec![dep_out(10)]);
        assert!(d.submit(&w));
        assert!(!d.submit(&r));
        assert!(!d.submit(&w2), "second writer waits for reader (WAR)");
        finish_body(&w);
        let ready = d.finish(&w);
        assert_eq!(ready.len(), 1, "reader released");
        finish_body(&r);
        let ready = d.finish(&r);
        assert_eq!(ready.len(), 1, "second writer released after reader");
        assert_eq!(ready[0].id, TaskId(3));
    }

    #[test]
    fn waw_dependence_without_readers() {
        let d = DepDomain::new();
        let w1 = mk(1, vec![dep_out(10)]);
        let w2 = mk(2, vec![dep_out(10)]);
        assert!(d.submit(&w1));
        assert!(!d.submit(&w2), "WAW ordering enforced");
        finish_body(&w1);
        assert_eq!(d.finish(&w1).len(), 1);
    }

    #[test]
    fn concurrent_readers_dont_order() {
        let d = DepDomain::new();
        let w = mk(1, vec![dep_out(10)]);
        assert!(d.submit(&w));
        finish_body(&w);
        assert!(d.finish(&w).is_empty());
        let r1 = mk(2, vec![dep_in(10)]);
        let r2 = mk(3, vec![dep_in(10)]);
        assert!(d.submit(&r1), "writer already finished");
        assert!(d.submit(&r2), "readers run concurrently");
    }

    #[test]
    fn inout_chains() {
        let d = DepDomain::new();
        let a = mk(1, vec![dep_inout(10)]);
        let b = mk(2, vec![dep_inout(10)]);
        let c = mk(3, vec![dep_inout(10)]);
        assert!(d.submit(&a));
        assert!(!d.submit(&b));
        assert!(!d.submit(&c));
        finish_body(&a);
        let r = d.finish(&a);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, TaskId(2));
        finish_body(&b);
        let r = d.finish(&b);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, TaskId(3));
    }

    #[test]
    fn multi_region_preds_counted_per_region() {
        // Listing 1's propagate/correct pattern: correct(i) needs b[i-1], b[i].
        let d = DepDomain::new();
        let p1 = mk(1, vec![dep_out(100)]); // writes b1
        let p2 = mk(2, vec![dep_out(101)]); // writes b2
        let c = mk(3, vec![dep_in(100), dep_inout(101)]);
        assert!(d.submit(&p1));
        assert!(d.submit(&p2));
        assert!(!d.submit(&c));
        assert_eq!(c.pending_preds(), 2);
        finish_body(&p1);
        assert!(d.finish(&p1).is_empty(), "c still waits on p2");
        finish_body(&p2);
        let r = d.finish(&p2);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, TaskId(3));
    }

    #[test]
    fn graph_prunes_entries() {
        let d = DepDomain::new();
        for i in 0..100u64 {
            let t = mk(i + 1, vec![dep_out(i), dep_in(1000 + i)]);
            d.submit(&t);
            finish_body(&t);
            d.finish(&t);
        }
        assert_eq!(d.live_regions(), 0, "all entries pruned of content");
        assert_eq!(d.tasks_in_graph(), 0);
    }

    #[test]
    fn tasks_in_graph_gauge() {
        let d = DepDomain::new();
        let a = mk(1, vec![dep_out(1)]);
        let b = mk(2, vec![dep_in(1)]);
        d.submit(&a);
        d.submit(&b);
        assert_eq!(d.tasks_in_graph(), 2);
        finish_body(&a);
        d.finish(&a);
        assert_eq!(d.tasks_in_graph(), 1);
    }

    #[test]
    fn ranged_overlap_orders_partial_regions() {
        use crate::coordinator::dep::{DepMode, Dependence};
        use crate::substrate::RegionKey;
        let d = DepDomain::new_ranged();
        let w = mk_r(1, vec![Dependence::new(RegionKey::new(0, 100), DepMode::Out)]);
        let r = mk_r(2, vec![Dependence::new(RegionKey::new(50, 100), DepMode::In)]);
        assert!(d.submit(&w));
        assert!(!d.submit(&r), "partial overlap must order");
        finish_body(&w);
        assert_eq!(d.finish(&w).len(), 1);
    }

    #[test]
    fn ranged_disjoint_do_not_order() {
        use crate::coordinator::dep::{DepMode, Dependence};
        use crate::substrate::RegionKey;
        let d = DepDomain::new_ranged();
        let a = mk_r(1, vec![Dependence::new(RegionKey::new(0, 50), DepMode::Inout)]);
        let b = mk_r(2, vec![Dependence::new(RegionKey::new(50, 50), DepMode::Inout)]);
        assert!(d.submit(&a));
        assert!(d.submit(&b), "disjoint half-open intervals run concurrently");
    }

    #[test]
    fn ranged_war_on_overlap() {
        use crate::coordinator::dep::{DepMode, Dependence};
        use crate::substrate::RegionKey;
        let d = DepDomain::new_ranged();
        let r = mk_r(1, vec![Dependence::new(RegionKey::new(10, 10), DepMode::In)]);
        let w = mk_r(2, vec![Dependence::new(RegionKey::new(0, 15), DepMode::Out)]);
        assert!(d.submit(&r), "reader of untouched region is ready");
        assert!(!d.submit(&w), "writer must wait for overlapping reader");
        finish_body(&r);
        assert_eq!(d.finish(&r).len(), 1);
    }

    fn mk_r(id: u64, deps: Vec<crate::coordinator::dep::Dependence>) -> Arc<Wd> {
        Wd::new(TaskId(id), deps, "t", Weak::new(), Box::new(|| {}))
    }

    #[test]
    fn no_self_dependence() {
        let d = DepDomain::new();
        let t = mk(1, vec![dep_in(5), dep_out(5)]);
        assert!(d.submit(&t), "a task never depends on itself");
    }
}
