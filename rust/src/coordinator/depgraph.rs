//! The task dependence graph (one *domain* per parent task, §2.2.1).
//!
//! Nanos++ keeps a dependence graph per parent task: children can only
//! depend on sibling tasks, and the graph is protected by spinlocks because
//! sibling submissions/finalizations may race. Both runtime organizations
//! use this same code; what differs is *who* calls it (worker threads
//! directly in the Sync baseline, manager threads in DDAST) and therefore
//! how contended the locks are.
//!
//! ## Striping (EXPERIMENTS.md §Lock-free hot paths)
//!
//! The seed guarded the whole domain with a single spinlock, so sibling
//! tasks touching *disjoint* regions still serialized — exactly the
//! artificial contention the paper attributes to centralized runtime
//! structures. The exact-match plugin now stripes the region table over
//! `DEFAULT_STRIPES` lock shards keyed by a region-base hash. An operation
//! acquires the shards of *its own* dependences — in sorted shard order, so
//! multi-shard acquisition is deadlock-free — and holds them together,
//! which preserves the seed's two load-bearing atomicity properties:
//!
//! * a submission is atomic across all its dependences (no ordering cycles
//!   between two in-flight sibling submissions);
//! * `finish` drains a task's successor list while holding every shard a
//!   submitter could be appending from (a submitter appends to a
//!   predecessor found via region R while holding R's shard; R is one of
//!   the predecessor's own dependences, so its shard is in the finishing
//!   task's acquired set).
//!
//! The range-overlap plugin stays single-striped: overlap conflicts cannot
//! be confined to a shard by hashing bases. It is the correctness-oriented
//! plugin, like the original Nanos++ "regions" plugin.
//!
//! Semantics per region (last-writer / reader-set tracking):
//! * `in`    — RAW edge from the last unfinished writer;
//! * `out`   — WAR edges from unfinished readers of the current epoch and a
//!             WAW edge from the last unfinished writer;
//! * `inout` — both.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::replay::EdgeRecorder;
use crate::coordinator::wd::Wd;
use crate::substrate::{CachePadded, Counter, RegionKey, SpinLock, SpinLockGuard};

/// Shard count of the exact-match plugin. Power of two; 8 shards already
/// push the per-shard collision probability for a 4–8-thread submit storm
/// well below the seed's guaranteed 100 %.
const DEFAULT_STRIPES: usize = 8;

/// Hard cap on shards: lets submit/finish keep their guards in a
/// fixed-size stack array (no heap allocation on the graph hot path) and
/// the shard set in one `u64` bitmask.
const MAX_STRIPES: usize = 16;

/// Per-region bookkeeping: who wrote it last, who has read it since.
#[derive(Default)]
struct RegionEntry {
    last_writer: Option<Arc<Wd>>,
    readers: Vec<Arc<Wd>>,
}

#[derive(Default)]
struct Stripe {
    /// Keyed by region base address (Nanos++ default plugin: exact match).
    entries: HashMap<u64, RegionEntry>,
    /// Range-overlap plugin (Nanos++'s "regions" plugin): entries keyed by
    /// full `(base, len)` regions, conflict = interval overlap. Only ever
    /// populated in stripe 0 (ranged domains are single-striped).
    ranged: Vec<(RegionKey, RegionEntry)>,
    /// Exact-region -> `ranged` position, so registration and finalization
    /// are O(1) lookups instead of scans over all regions ever seen.
    ranged_index: HashMap<RegionKey, usize>,
}

/// A dependence domain: the task graph of one parent task's children.
pub struct DepDomain {
    stripes: Box<[CachePadded<SpinLock<Stripe>>]>,
    /// Which plugin this domain uses.
    use_ranges: bool,
    /// Tasks currently in the graph (submitted, not yet done-handled).
    /// This is the observable plotted in the paper's Figures 12–14.
    tasks_in_graph: Counter,
    /// Region entries visited by `finish` (telemetry: the ranged-plugin
    /// finish used to scan *every* region ever seen; the visit count per
    /// finish must now track the task's own dependence count, not the
    /// domain's total region count — guarded by tests and the bench).
    finish_visits: Counter,
    /// Edge-capture hook for the record/replay plane. Only the throwaway
    /// capture domains built by `replay::capture` carry a recorder; it is
    /// fixed at construction, so when recording is off the per-edge cost
    /// is one branch on a plain (non-atomic) `Option` — provably
    /// zero-atomic.
    recorder: Option<Arc<EdgeRecorder>>,
}

impl Default for DepDomain {
    fn default() -> Self {
        Self::new()
    }
}

impl DepDomain {
    /// Exact-base-match plugin (Nanos++ default; what the benchmarks use),
    /// striped over [`DEFAULT_STRIPES`] lock shards.
    pub fn new() -> Self {
        Self::with_stripes(DEFAULT_STRIPES)
    }

    /// Exact-match plugin with an explicit shard count (clamped to
    /// `1..=MAX_STRIPES`, rounded up to a power of two). `with_stripes(1)`
    /// reproduces the seed's single-lock domain — the A/B baseline of
    /// `micro_structures` / BENCH_contention.json.
    pub fn with_stripes(n: usize) -> Self {
        let n = n.clamp(1, MAX_STRIPES).next_power_of_two();
        DepDomain {
            stripes: (0..n).map(|_| CachePadded::new(SpinLock::new(Stripe::default()))).collect(),
            use_ranges: false,
            tasks_in_graph: Counter::new(),
            finish_visits: Counter::new(),
            recorder: None,
        }
    }

    /// Range-overlap plugin: dependences on `(base, len)` regions conflict
    /// whenever the intervals overlap, not only on exact base match.
    /// Single-striped (see module docs).
    pub fn new_ranged() -> Self {
        DepDomain {
            stripes: vec![CachePadded::new(SpinLock::new(Stripe::default()))].into_boxed_slice(),
            use_ranges: true,
            tasks_in_graph: Counter::new(),
            finish_visits: Counter::new(),
            recorder: None,
        }
    }

    /// A capture domain for the record/replay plane: every dependence edge
    /// appended during submission is mirrored into `recorder` (under the
    /// same shard lock that guards the append). Not reachable from any
    /// public constructor — production domains always run with recording
    /// off.
    pub(crate) fn new_recording(recorder: Arc<EdgeRecorder>, ranged: bool) -> Self {
        let mut domain = if ranged { Self::new_ranged() } else { Self::new() };
        domain.recorder = Some(recorder);
        domain
    }

    /// Is the edge-capture hook armed? (False on every public constructor.)
    #[inline]
    pub fn recording(&self) -> bool {
        self.recorder.is_some()
    }

    /// Number of lock shards (diagnostics / A-B bench).
    #[inline]
    pub fn num_stripes(&self) -> usize {
        self.stripes.len()
    }

    /// Number of tasks currently tracked by this domain.
    #[inline]
    pub fn tasks_in_graph(&self) -> u64 {
        self.tasks_in_graph.get()
    }

    /// Region entries visited by `finish` so far (telemetry; see field doc).
    #[inline]
    pub fn finish_visits(&self) -> u64 {
        self.finish_visits.get()
    }

    /// Aggregate lock statistics over all shards: (acquisitions, contended,
    /// spin iterations). Fuel for `sim::calibrate` and the A/B bench.
    pub fn lock_stats(&self) -> (u64, u64, u64) {
        let mut acc = (0, 0, 0);
        for s in self.stripes.iter() {
            let (a, c, i) = s.stats();
            acc.0 += a;
            acc.1 += c;
            acc.2 += i;
        }
        acc
    }

    /// Shard index of a region base: multiplicative hash of the base so
    /// consecutive block addresses spread over shards.
    #[inline]
    fn stripe_of(&self, base: u64) -> usize {
        (base.wrapping_mul(0x9E3779B97F4A7C15) >> 33) as usize & (self.stripes.len() - 1)
    }

    /// Acquire the shards covering `deps` in ascending shard order
    /// (deadlock-free against any concurrent multi-shard acquisition).
    /// Guards land in a fixed stack array indexed by shard id — no heap
    /// allocation on the graph hot path (MAX_STRIPES bounds the array).
    fn lock_shards(
        &self,
        deps: &[crate::coordinator::dep::Dependence],
    ) -> [Option<SpinLockGuard<'_, Stripe>>; MAX_STRIPES] {
        let mut mask = 0u64;
        for d in deps {
            mask |= 1u64 << self.stripe_of(d.region.base);
        }
        self.lock_mask(mask)
    }

    /// Acquire the shards of `mask` in ascending order (see `lock_shards`;
    /// the batch path computes the union mask of several tasks first).
    fn lock_mask(&self, mut mask: u64) -> [Option<SpinLockGuard<'_, Stripe>>; MAX_STRIPES] {
        let mut guards = std::array::from_fn(|_| None);
        while mask != 0 {
            let i = mask.trailing_zeros() as usize;
            guards[i] = Some(self.stripes[i].lock());
            mask &= mask - 1;
        }
        guards
    }

    /// Insert `task` into the graph, computing its predecessors (task
    /// life-cycle step 2, "Task submission").
    ///
    /// Returns `true` if the task became ready immediately (no pending
    /// predecessors). The caller is responsible for scheduling it then.
    pub fn submit(&self, task: &Arc<Wd>) -> bool {
        {
            let rec = self.recorder.as_deref();
            if self.use_ranges {
                let mut stripe = self.stripes[0].lock();
                Self::submit_ranged(&mut stripe, task, rec);
            } else {
                let mut guards = self.lock_shards(&task.deps);
                for dep in &task.deps {
                    let i = self.stripe_of(dep.region.base);
                    Self::submit_exact_dep(
                        guards[i].as_mut().expect("dep's shard locked"),
                        task,
                        dep,
                        rec,
                    );
                }
            }
        }
        self.tasks_in_graph.inc();
        // Release the submission guard; true -> no predecessors remained.
        task.release_pred()
    }

    /// Insert a batch of sibling tasks, acquiring each touched shard **once
    /// per batch** instead of once per task (EXPERIMENTS.md §Batched
    /// request plane — the per-message shard churn was the request plane's
    /// largest remaining per-message cost).
    ///
    /// Correctness relative to per-task [`submit`](DepDomain::submit):
    ///
    /// * **Program order** — tasks are processed in slice order while every
    ///   touched shard is held, so the graph observes exactly the
    ///   serialization the per-message FIFO drain produced.
    /// * **Atomic submission** — the union of the batch's shards is a
    ///   superset of each task's own shards, so each insertion is at least
    ///   as atomic as before (no ordering cycles with concurrent sibling
    ///   submissions).
    /// * **Finish-drain invariant** — appends to a predecessor's successor
    ///   list still happen under the shard of the region the predecessor
    ///   was found through, which a concurrent `finish` of that predecessor
    ///   also holds.
    ///
    /// Submission guards are released *after* the shards are (same as the
    /// per-task path); tasks that became ready immediately are appended to
    /// `ready` in submission order.
    pub fn submit_batch(&self, tasks: &[Arc<Wd>], ready: &mut Vec<Arc<Wd>>) {
        if tasks.is_empty() {
            return;
        }
        {
            let rec = self.recorder.as_deref();
            if self.use_ranges {
                let mut stripe = self.stripes[0].lock();
                for task in tasks {
                    Self::submit_ranged(&mut stripe, task, rec);
                }
            } else {
                let mut mask = 0u64;
                for task in tasks {
                    for d in &task.deps {
                        mask |= 1u64 << self.stripe_of(d.region.base);
                    }
                }
                let mut guards = self.lock_mask(mask);
                for task in tasks {
                    for dep in &task.deps {
                        let i = self.stripe_of(dep.region.base);
                        Self::submit_exact_dep(
                            guards[i].as_mut().expect("dep's shard locked"),
                            task,
                            dep,
                            rec,
                        );
                    }
                }
            }
        }
        self.tasks_in_graph.add(tasks.len() as u64);
        for task in tasks {
            if task.release_pred() {
                ready.push(Arc::clone(task));
            }
        }
    }

    /// Process one dependence against its (locked) shard. `rec` mirrors
    /// every appended edge for the record/replay plane (armed only on
    /// capture domains — `None` elsewhere, one never-taken branch per site).
    fn submit_exact_dep(
        stripe: &mut Stripe,
        task: &Arc<Wd>,
        dep: &crate::coordinator::dep::Dependence,
        rec: Option<&EdgeRecorder>,
    ) {
        let entry = stripe.entries.entry(dep.region.base).or_default();
        let mode = dep.mode;
        if mode.reads() {
            // RAW on the last unfinished writer.
            if let Some(w) = &entry.last_writer {
                if !w.is_finished() && w.id != task.id {
                    w.successors.lock().push(Arc::clone(task));
                    task.add_preds(1);
                    if let Some(rec) = rec {
                        rec.edge(w.id, task.id);
                    }
                }
            }
        }
        if mode.writes() {
            // WAR on every unfinished reader of the current epoch.
            for r in &entry.readers {
                if !r.is_finished() && r.id != task.id {
                    r.successors.lock().push(Arc::clone(task));
                    task.add_preds(1);
                    if let Some(rec) = rec {
                        rec.edge(r.id, task.id);
                    }
                }
            }
            // WAW on the last unfinished writer (only needed when
            // there were no readers — readers already chain after
            // the writer — but adding it is correct and mirrors
            // Nanos++' conservative behaviour).
            if !mode.reads() {
                if let Some(w) = &entry.last_writer {
                    if !w.is_finished() && w.id != task.id {
                        w.successors.lock().push(Arc::clone(task));
                        task.add_preds(1);
                        if let Some(rec) = rec {
                            rec.edge(w.id, task.id);
                        }
                    }
                }
            }
            // New write epoch: previous readers are superseded.
            entry.readers.clear();
            entry.last_writer = Some(Arc::clone(task));
        } else {
            entry.readers.push(Arc::clone(task));
        }
    }

    /// Range-overlap submission: conservative interval semantics — a task
    /// orders after every unfinished prior accessor whose region overlaps
    /// conflictingly. Self-registration is on the task's exact region; the
    /// scan matches by overlap.
    fn submit_ranged(stripe: &mut Stripe, task: &Arc<Wd>, rec: Option<&EdgeRecorder>) {
        for dep in &task.deps {
            let mode = dep.mode;
            for (region, entry) in stripe.ranged.iter() {
                if !region.overlaps(&dep.region) {
                    continue;
                }
                // RAW/WAW: order after the overlapping writer.
                if let Some(w) = &entry.last_writer {
                    if !w.is_finished() && w.id != task.id {
                        w.successors.lock().push(Arc::clone(task));
                        task.add_preds(1);
                        if let Some(rec) = rec {
                            rec.edge(w.id, task.id);
                        }
                    }
                }
                // WAR: a writer orders after overlapping readers.
                if mode.writes() {
                    for r in &entry.readers {
                        if !r.is_finished() && r.id != task.id {
                            r.successors.lock().push(Arc::clone(task));
                            task.add_preds(1);
                            if let Some(rec) = rec {
                                rec.edge(r.id, task.id);
                            }
                        }
                    }
                }
            }
            // Register on the exact region entry (create on first touch);
            // the side index makes this and `finish` O(1) per dependence.
            let idx = match stripe.ranged_index.get(&dep.region) {
                Some(&i) => i,
                None => {
                    stripe.ranged.push((dep.region, RegionEntry::default()));
                    let i = stripe.ranged.len() - 1;
                    stripe.ranged_index.insert(dep.region, i);
                    i
                }
            };
            let entry = &mut stripe.ranged[idx].1;
            if mode.writes() {
                // Readers of *this exact* region are superseded; partially
                // overlapping readers stay (conservative, still correct:
                // they were ordered before this writer above).
                entry.readers.clear();
                entry.last_writer = Some(Arc::clone(task));
            } else {
                entry.readers.push(Arc::clone(task));
            }
        }
    }

    /// Remove a finished task from the graph and collect the successors
    /// that become ready (task life-cycle step 5, "Task finalization").
    ///
    /// Visits only the entries of the task's *own* dependences — O(deps),
    /// not O(all regions ever seen): the task only ever registered on its
    /// exact regions, so nothing else can hold a reference to it. The seed's
    /// ranged path scanned every region, so finish cost grew with
    /// unrelated-region count (guarded by `finish_visits` tests and the
    /// micro_structures bench).
    ///
    /// Returns the now-ready tasks; the caller schedules them.
    ///
    /// **Poison contract**: dead tasks (`Failed`/`Cancelled` — both satisfy
    /// `is_finished`) take this exact path too. The graph itself is
    /// failure-agnostic: it releases the same successor set it would for a
    /// success, and the *caller* (`RuntimeShared::finalize_one`) decides
    /// whether the released tasks become `Ready` or are cancelled in turn.
    /// Keeping poison out of the graph keeps one removal routine for all
    /// outcomes — accounting (`tasks_in_graph`, predecessor counts) cannot
    /// diverge between the success and failure paths.
    pub fn finish(&self, task: &Arc<Wd>) -> Vec<Arc<Wd>> {
        debug_assert!(task.is_finished(), "finish() before body completed");
        let mut visits = 0u64;
        // Prune this task from the region entries it touched. The entry
        // itself is kept (empty) for reuse: benchmarks revisit the same
        // block regions constantly, and dropping/reinserting entries
        // was ~10 % of the finish path (EXPERIMENTS.md §Perf iter 1).
        // Memory stays bounded by the number of *distinct* regions.
        // In both arms the successor list is drained *while the shard
        // guard(s) are still held*: nobody can append anymore because
        // `task.is_finished()` is observed under one of these shards by
        // any would-be submitter (see module docs).
        let succs = if self.use_ranges {
            let mut stripe = self.stripes[0].lock();
            for dep in &task.deps {
                if let Some(&i) = stripe.ranged_index.get(&dep.region) {
                    visits += 1;
                    let entry = &mut stripe.ranged[i].1;
                    if entry.last_writer.as_ref().is_some_and(|w| w.id == task.id) {
                        entry.last_writer = None;
                    }
                    entry.readers.retain(|r| r.id != task.id);
                }
            }
            std::mem::take(&mut *task.successors.lock())
        } else {
            let mut guards = self.lock_shards(&task.deps);
            for dep in &task.deps {
                let i = self.stripe_of(dep.region.base);
                let stripe = guards[i].as_mut().expect("dep's shard locked");
                if let Some(entry) = stripe.entries.get_mut(&dep.region.base) {
                    visits += 1;
                    if entry.last_writer.as_ref().is_some_and(|w| w.id == task.id) {
                        entry.last_writer = None;
                    }
                    entry.readers.retain(|r| r.id != task.id);
                }
            }
            std::mem::take(&mut *task.successors.lock())
        };
        self.finish_visits.add(visits);
        self.tasks_in_graph.dec();
        let mut ready = Vec::new();
        for s in succs {
            if s.release_pred() {
                ready.push(s);
            }
        }
        ready
    }

    /// Number of distinct regions ever tracked (test/diagnostic).
    pub fn regions_tracked(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| {
                let s = s.lock();
                s.entries.len() + s.ranged.len()
            })
            .sum()
    }

    /// Regions with a live writer or readers (test/diagnostic).
    pub fn live_regions(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| {
                let s = s.lock();
                s.entries
                    .values()
                    .chain(s.ranged.iter().map(|(_, e)| e))
                    .filter(|e| e.last_writer.is_some() || !e.readers.is_empty())
                    .count()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dep::{dep_in, dep_inout, dep_out};
    use crate::coordinator::wd::{TaskId, WdState};
    use std::sync::Weak;

    fn mk(id: u64, deps: Vec<crate::coordinator::dep::Dependence>) -> Arc<Wd> {
        Wd::new(TaskId(id), deps, "t", Weak::new(), Box::new(|| {}))
    }

    fn finish_body(t: &Arc<Wd>) {
        t.set_state(WdState::Ready);
        t.set_state(WdState::Running);
        t.set_state(WdState::Finished);
    }

    #[test]
    fn raw_dependence_chain() {
        let d = DepDomain::new();
        let w = mk(1, vec![dep_out(10)]);
        let r = mk(2, vec![dep_in(10)]);
        assert!(d.submit(&w), "writer has no preds");
        assert!(!d.submit(&r), "reader must wait for writer");
        finish_body(&w);
        let ready = d.finish(&w);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].id, TaskId(2));
    }

    #[test]
    fn war_dependence() {
        let d = DepDomain::new();
        let w = mk(1, vec![dep_out(10)]);
        let r = mk(2, vec![dep_in(10)]);
        let w2 = mk(3, vec![dep_out(10)]);
        assert!(d.submit(&w));
        assert!(!d.submit(&r));
        assert!(!d.submit(&w2), "second writer waits for reader (WAR)");
        finish_body(&w);
        let ready = d.finish(&w);
        assert_eq!(ready.len(), 1, "reader released");
        finish_body(&r);
        let ready = d.finish(&r);
        assert_eq!(ready.len(), 1, "second writer released after reader");
        assert_eq!(ready[0].id, TaskId(3));
    }

    #[test]
    fn waw_dependence_without_readers() {
        let d = DepDomain::new();
        let w1 = mk(1, vec![dep_out(10)]);
        let w2 = mk(2, vec![dep_out(10)]);
        assert!(d.submit(&w1));
        assert!(!d.submit(&w2), "WAW ordering enforced");
        finish_body(&w1);
        assert_eq!(d.finish(&w1).len(), 1);
    }

    #[test]
    fn concurrent_readers_dont_order() {
        let d = DepDomain::new();
        let w = mk(1, vec![dep_out(10)]);
        assert!(d.submit(&w));
        finish_body(&w);
        assert!(d.finish(&w).is_empty());
        let r1 = mk(2, vec![dep_in(10)]);
        let r2 = mk(3, vec![dep_in(10)]);
        assert!(d.submit(&r1), "writer already finished");
        assert!(d.submit(&r2), "readers run concurrently");
    }

    #[test]
    fn inout_chains() {
        let d = DepDomain::new();
        let a = mk(1, vec![dep_inout(10)]);
        let b = mk(2, vec![dep_inout(10)]);
        let c = mk(3, vec![dep_inout(10)]);
        assert!(d.submit(&a));
        assert!(!d.submit(&b));
        assert!(!d.submit(&c));
        finish_body(&a);
        let r = d.finish(&a);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, TaskId(2));
        finish_body(&b);
        let r = d.finish(&b);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, TaskId(3));
    }

    #[test]
    fn multi_region_preds_counted_per_region() {
        // Listing 1's propagate/correct pattern: correct(i) needs b[i-1], b[i].
        let d = DepDomain::new();
        let p1 = mk(1, vec![dep_out(100)]); // writes b1
        let p2 = mk(2, vec![dep_out(101)]); // writes b2
        let c = mk(3, vec![dep_in(100), dep_inout(101)]);
        assert!(d.submit(&p1));
        assert!(d.submit(&p2));
        assert!(!d.submit(&c));
        assert_eq!(c.pending_preds(), 2);
        finish_body(&p1);
        assert!(d.finish(&p1).is_empty(), "c still waits on p2");
        finish_body(&p2);
        let r = d.finish(&p2);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].id, TaskId(3));
    }

    #[test]
    fn graph_prunes_entries() {
        let d = DepDomain::new();
        for i in 0..100u64 {
            let t = mk(i + 1, vec![dep_out(i), dep_in(1000 + i)]);
            d.submit(&t);
            finish_body(&t);
            d.finish(&t);
        }
        assert_eq!(d.live_regions(), 0, "all entries pruned of content");
        assert_eq!(d.tasks_in_graph(), 0);
    }

    #[test]
    fn tasks_in_graph_gauge() {
        let d = DepDomain::new();
        let a = mk(1, vec![dep_out(1)]);
        let b = mk(2, vec![dep_in(1)]);
        d.submit(&a);
        d.submit(&b);
        assert_eq!(d.tasks_in_graph(), 2);
        finish_body(&a);
        d.finish(&a);
        assert_eq!(d.tasks_in_graph(), 1);
    }

    #[test]
    fn ranged_overlap_orders_partial_regions() {
        use crate::coordinator::dep::{DepMode, Dependence};
        use crate::substrate::RegionKey;
        let d = DepDomain::new_ranged();
        let w = mk_r(1, vec![Dependence::new(RegionKey::new(0, 100), DepMode::Out)]);
        let r = mk_r(2, vec![Dependence::new(RegionKey::new(50, 100), DepMode::In)]);
        assert!(d.submit(&w));
        assert!(!d.submit(&r), "partial overlap must order");
        finish_body(&w);
        assert_eq!(d.finish(&w).len(), 1);
    }

    #[test]
    fn ranged_disjoint_do_not_order() {
        use crate::coordinator::dep::{DepMode, Dependence};
        use crate::substrate::RegionKey;
        let d = DepDomain::new_ranged();
        let a = mk_r(1, vec![Dependence::new(RegionKey::new(0, 50), DepMode::Inout)]);
        let b = mk_r(2, vec![Dependence::new(RegionKey::new(50, 50), DepMode::Inout)]);
        assert!(d.submit(&a));
        assert!(d.submit(&b), "disjoint half-open intervals run concurrently");
    }

    #[test]
    fn ranged_war_on_overlap() {
        use crate::coordinator::dep::{DepMode, Dependence};
        use crate::substrate::RegionKey;
        let d = DepDomain::new_ranged();
        let r = mk_r(1, vec![Dependence::new(RegionKey::new(10, 10), DepMode::In)]);
        let w = mk_r(2, vec![Dependence::new(RegionKey::new(0, 15), DepMode::Out)]);
        assert!(d.submit(&r), "reader of untouched region is ready");
        assert!(!d.submit(&w), "writer must wait for overlapping reader");
        finish_body(&r);
        assert_eq!(d.finish(&r).len(), 1);
    }

    fn mk_r(id: u64, deps: Vec<crate::coordinator::dep::Dependence>) -> Arc<Wd> {
        Wd::new(TaskId(id), deps, "t", Weak::new(), Box::new(|| {}))
    }

    #[test]
    fn no_self_dependence() {
        let d = DepDomain::new();
        let t = mk(1, vec![dep_in(5), dep_out(5)]);
        assert!(d.submit(&t), "a task never depends on itself");
    }

    // -- striping / finish-cost guards -----------------------------------

    #[test]
    fn striped_semantics_match_single_stripe() {
        // The same RAW/WAR/WAW chain behaves identically at 1 and 8 shards.
        for stripes in [1usize, 8] {
            let d = DepDomain::with_stripes(stripes);
            let w = mk(1, vec![dep_out(10), dep_out(11), dep_out(12)]);
            let r = mk(2, vec![dep_in(10), dep_in(12)]);
            let w2 = mk(3, vec![dep_out(11), dep_out(12)]);
            assert!(d.submit(&w));
            assert!(!d.submit(&r));
            assert!(!d.submit(&w2));
            assert_eq!(r.pending_preds(), 2, "one RAW per region");
            finish_body(&w);
            let ready = d.finish(&w);
            assert_eq!(ready.len(), 1, "reader ready; w2 still blocked by WAR on 12");
            finish_body(&r);
            let ready = d.finish(&r);
            assert_eq!(ready.len(), 1);
            assert_eq!(ready[0].id, TaskId(3));
        }
    }

    #[test]
    fn stripes_spread_regions() {
        let d = DepDomain::new();
        assert!(d.num_stripes() > 1);
        for i in 0..64u64 {
            let t = mk(i + 1, vec![dep_out(i)]);
            d.submit(&t);
        }
        assert_eq!(d.regions_tracked(), 64, "all regions present across shards");
        // The multiplicative hash must not collapse consecutive bases onto
        // one shard (that would re-serialize the benchmarks' block loops).
        let mut used = std::collections::HashSet::new();
        for i in 0..64u64 {
            used.insert(d.stripe_of(i));
        }
        assert!(used.len() >= d.num_stripes() / 2, "hash spreads: {} shards used", used.len());
    }

    #[test]
    fn exact_finish_visits_only_own_deps() {
        let d = DepDomain::new();
        // 500 unrelated live regions.
        let mut unrelated = Vec::new();
        for i in 0..500u64 {
            let t = mk(i + 1, vec![dep_out(10_000 + i)]);
            d.submit(&t);
            unrelated.push(t);
        }
        let t = mk(1000, vec![dep_out(1), dep_in(2)]);
        d.submit(&t);
        finish_body(&t);
        let before = d.finish_visits();
        d.finish(&t);
        assert_eq!(d.finish_visits() - before, 2, "finish is O(own deps)");
    }

    #[test]
    fn ranged_finish_visits_only_own_deps() {
        use crate::coordinator::dep::{DepMode, Dependence};
        use crate::substrate::RegionKey;
        let d = DepDomain::new_ranged();
        // Many unrelated live ranged regions (disjoint intervals).
        let mut unrelated = Vec::new();
        for i in 0..300u64 {
            let t = mk_r(
                i + 1,
                vec![Dependence::new(RegionKey::new(1_000_000 + 10 * i, 5), DepMode::Out)],
            );
            d.submit(&t);
            unrelated.push(t);
        }
        let t = mk_r(999, vec![Dependence::new(RegionKey::new(0, 10), DepMode::Inout)]);
        d.submit(&t);
        finish_body(&t);
        let before = d.finish_visits();
        let ready = d.finish(&t);
        assert!(ready.is_empty());
        assert_eq!(
            d.finish_visits() - before,
            1,
            "ranged finish no longer scans all {} regions",
            d.regions_tracked()
        );
    }

    #[test]
    fn ranged_reader_prune_uses_index() {
        use crate::coordinator::dep::{DepMode, Dependence};
        use crate::substrate::RegionKey;
        // A reader that finishes must disappear from its exact entry so a
        // later writer is not ordered after it (index-lookup prune path).
        let d = DepDomain::new_ranged();
        let r = mk_r(1, vec![Dependence::new(RegionKey::new(0, 10), DepMode::In)]);
        assert!(d.submit(&r));
        finish_body(&r);
        assert!(d.finish(&r).is_empty());
        let w = mk_r(2, vec![Dependence::new(RegionKey::new(0, 10), DepMode::Out)]);
        assert!(d.submit(&w), "finished reader was pruned, writer is free");
    }

    #[test]
    fn lock_stats_aggregate_across_stripes() {
        let d = DepDomain::new();
        for i in 0..32u64 {
            let t = mk(i + 1, vec![dep_out(i)]);
            d.submit(&t);
            finish_body(&t);
            d.finish(&t);
        }
        let (acq, _, _) = d.lock_stats();
        assert!(acq >= 64, "every submit+finish acquired a shard (got {acq})");
    }

    // -- batch insertion --------------------------------------------------

    #[test]
    fn batch_submit_preserves_program_order_within_batch() {
        // Writer then reader on the same region inside ONE batch: the
        // reader must order after the writer exactly as with per-task
        // submission (the batch path processes tasks in slice order).
        let d = DepDomain::new();
        let w = mk(1, vec![dep_out(10)]);
        let r = mk(2, vec![dep_in(10)]);
        let mut ready = Vec::new();
        d.submit_batch(&[Arc::clone(&w), Arc::clone(&r)], &mut ready);
        assert_eq!(ready.len(), 1, "only the writer is ready");
        assert_eq!(ready[0].id, TaskId(1));
        assert_eq!(r.pending_preds(), 1);
        finish_body(&w);
        let released = d.finish(&w);
        assert_eq!(released.len(), 1);
        assert_eq!(released[0].id, TaskId(2));
    }

    #[test]
    fn batch_submit_matches_per_task_semantics() {
        // The same RAW/WAR/WAW chain behaves identically whether submitted
        // per task or per batch, at 1 and 8 stripes.
        for stripes in [1usize, 8] {
            let per = DepDomain::with_stripes(stripes);
            let batched = DepDomain::with_stripes(stripes);
            let mk3 = || {
                vec![
                    mk(1, vec![dep_out(10), dep_out(11), dep_out(12)]),
                    mk(2, vec![dep_in(10), dep_in(12)]),
                    mk(3, vec![dep_out(11), dep_out(12)]),
                ]
            };
            let a = mk3();
            let ready_per: Vec<bool> = a.iter().map(|t| per.submit(t)).collect();
            let b = mk3();
            let mut ready = Vec::new();
            batched.submit_batch(&b, &mut ready);
            let ready_batch: Vec<bool> =
                b.iter().map(|t| ready.iter().any(|r| r.id == t.id)).collect();
            assert_eq!(ready_per, ready_batch, "stripes={stripes}");
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.pending_preds(), y.pending_preds(), "task {:?}", x.id);
            }
            assert_eq!(per.tasks_in_graph(), batched.tasks_in_graph());
        }
    }

    #[test]
    fn batch_submit_acquires_union_once() {
        // 8 tasks over 2 distinct regions: the per-task path pays one shard
        // acquisition per task, the batch path at most one per distinct
        // region — counter-verified, the acceptance metric of
        // `bench_harness::contention::batch_submit_ab`.
        let per = DepDomain::new();
        let batched = DepDomain::new();
        let mk8 = |d0: u64| -> Vec<Arc<Wd>> {
            (0..8u64).map(|i| mk(d0 + i, vec![dep_out(100 + i % 2)])).collect()
        };
        for t in mk8(1) {
            per.submit(&t);
        }
        let (per_acq, _, _) = per.lock_stats();
        assert_eq!(per_acq, 8, "one acquisition per task");
        let mut ready = Vec::new();
        batched.submit_batch(&mk8(11), &mut ready);
        let (batch_acq, _, _) = batched.lock_stats();
        assert!(batch_acq <= 2, "one acquisition per distinct shard, got {batch_acq}");
    }

    #[test]
    fn batch_submit_ranged_plugin() {
        use crate::coordinator::dep::{DepMode, Dependence};
        use crate::substrate::RegionKey;
        let d = DepDomain::new_ranged();
        let w = mk_r(1, vec![Dependence::new(RegionKey::new(0, 100), DepMode::Out)]);
        let r = mk_r(2, vec![Dependence::new(RegionKey::new(50, 100), DepMode::In)]);
        let mut ready = Vec::new();
        d.submit_batch(&[Arc::clone(&w), Arc::clone(&r)], &mut ready);
        assert_eq!(ready.len(), 1, "overlap orders the reader after the writer");
        finish_body(&w);
        assert_eq!(d.finish(&w).len(), 1);
    }

    #[test]
    fn cross_stripe_submit_is_atomic() {
        // Two tasks with two deps each, bases chosen over many values so
        // some pairs land on different shards: the RAW chain must hold for
        // every pair (regression guard for multi-shard acquisition).
        for base in 0..32u64 {
            let d = DepDomain::new();
            let a = mk(1, vec![dep_out(base), dep_out(base + 1)]);
            let b = mk(2, vec![dep_in(base), dep_in(base + 1)]);
            assert!(d.submit(&a));
            assert!(!d.submit(&b));
            assert_eq!(b.pending_preds(), 2, "RAW on both regions");
            finish_body(&a);
            let ready = d.finish(&a);
            assert_eq!(ready.len(), 1);
            assert_eq!(ready[0].id, TaskId(2));
        }
    }
}
