//! Public API: [`TaskSystem`] — the OmpSs-style programming surface.
//!
//! ```no_run
//! use ddast::coordinator::{TaskSystem, RuntimeKind, DepMode};
//!
//! let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(4).build();
//! ts.spawn(&[(0x1, DepMode::Out)], || println!("produce"));
//! ts.spawn(&[(0x1, DepMode::In)], || println!("consume"));
//! ts.taskwait();
//! ```
//!
//! The calling thread plays the role OmpSs gives the "main" thread: it is
//! worker 0 of the pool, and `taskwait` makes it execute tasks / runtime
//! functionalities while it waits (thread-pool model, §2.1).

use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;

use crate::coordinator::ddast::DdastParams;
use crate::coordinator::dep::{DepMode, Dependence};
use crate::coordinator::pool::{
    clear_ctx, current_ctx, install_ctx, DomainErrorCell, RuntimeKind, RuntimeShared, SubmitError,
    TaskErrors,
};
use crate::coordinator::replay::{self, GraphRecording, ReplayOutcome, ReplayRun, ReplayTask};
use crate::coordinator::wd::{TaskBody, Wd, WdState};
use crate::substrate::{FaultPlan, RegionKey, Topology};

/// Builder for [`TaskSystem`].
pub struct TaskSystemBuilder {
    kind: RuntimeKind,
    num_threads: usize,
    params: Option<DdastParams>,
    tracing: bool,
    autotune: bool,
    autotune_interval: std::time::Duration,
    manager_affinity: Option<Vec<usize>>,
    ranged: bool,
    seed: u64,
    fault_plan: Option<Arc<FaultPlan>>,
    record_graphs: bool,
    topology: Option<Topology>,
    ingress_capacity: Option<usize>,
    pathology: bool,
    pathology_config: Option<crate::coordinator::pathology::PathologyConfig>,
}

impl Default for TaskSystemBuilder {
    fn default() -> Self {
        TaskSystemBuilder {
            kind: RuntimeKind::Ddast,
            num_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            params: None,
            tracing: false,
            autotune: false,
            autotune_interval: std::time::Duration::from_millis(2),
            manager_affinity: None,
            ranged: false,
            seed: 0xDDA57,
            fault_plan: None,
            record_graphs: false,
            topology: None,
            ingress_capacity: None,
            pathology: false,
            pathology_config: None,
        }
    }
}

impl TaskSystemBuilder {
    /// Runtime organization (Sync baseline / DDAST / GOMP-like).
    pub fn kind(mut self, kind: RuntimeKind) -> Self {
        self.kind = kind;
        self
    }

    /// Total threads *including* the calling thread.
    pub fn num_threads(mut self, n: usize) -> Self {
        self.num_threads = n.max(1);
        self
    }

    /// Override the DDAST parameters (defaults to `DdastParams::tuned(n)`).
    pub fn params(mut self, p: DdastParams) -> Self {
        self.params = Some(p);
        self
    }

    /// Enable trace collection (Paraver-style figures).
    pub fn tracing(mut self, on: bool) -> Self {
        self.tracing = on;
        self
    }

    /// Seed for stealing/victim RNG (reproducibility).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Enable dynamic DDAST parameter tuning (the paper's §8 future work):
    /// a feedback controller registered in the Functionality Dispatcher
    /// adjusts `MAX_DDAST_THREADS` online.
    pub fn autotune(mut self, on: bool) -> Self {
        self.autotune = on;
        self
    }

    /// Adjustment period of the auto-tuner.
    pub fn autotune_interval(mut self, d: std::time::Duration) -> Self {
        self.autotune_interval = d;
        self
    }

    /// Restrict which workers may become DDAST managers (big.LITTLE
    /// adaptation, paper §8 — e.g. pass the LITTLE-core worker ids).
    pub fn manager_affinity(mut self, workers: Vec<usize>) -> Self {
        self.manager_affinity = Some(workers);
        self
    }

    /// Use the range-overlap dependence plugin: `(base, len)` regions
    /// conflict on interval overlap rather than exact base match
    /// (Nanos++'s richer regions plugin).
    pub fn ranged_deps(mut self, on: bool) -> Self {
        self.ranged = on;
        self
    }

    /// Install a deterministic [`FaultPlan`] (the fault-injection harness —
    /// tests/benches only; see `substrate::fault`). `None` (the default)
    /// keeps every injection site a single branch.
    pub fn fault_plan(mut self, plan: Arc<FaultPlan>) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Enable the record/replay plane:
    /// [`TaskSystem::record_iteration`] then captures a [`GraphRecording`]
    /// of each iteration's resolved dependence graph, which
    /// [`TaskSystem::replay`] re-executes with zero dependence resolution.
    /// Off (the default), both degrade to plain resolved execution and the
    /// edge-capture hook stays a never-taken non-atomic branch.
    pub fn record_graphs(mut self, on: bool) -> Self {
        self.record_graphs = on;
        self
    }

    /// Inject a machine [`Topology`] (sockets × workers-per-socket)
    /// instead of detecting it from the OS. The topology shapes the
    /// two-level signal directory, the locality-biased wake victim
    /// selection, and the socket-ordered steal scan; it is widened
    /// automatically if it cannot cover `num_threads`. Tests and the
    /// simulator's machine models use this to pin a shape; production
    /// callers normally rely on detection (`DDAST_TOPOLOGY=SxW` env
    /// override, then Linux sysfs NUMA nodes, then flat).
    pub fn topology(mut self, topo: Topology) -> Self {
        self.topology = Some(topo);
        self
    }

    /// Capacity of the external-submitter ingress ring (rounded up to a
    /// power of two internally; defaults to
    /// `coordinator::messages::DEFAULT_INGRESS_CAPACITY`). The bound *is*
    /// the admission control: a full ring makes [`TaskSystem::try_submit`]
    /// return [`SubmitError::Busy`] and the blocking submit flavours wait —
    /// backpressure to the producers instead of unbounded queue growth.
    pub fn ingress_capacity(mut self, n: usize) -> Self {
        self.ingress_capacity = Some(n);
        self
    }

    /// Arm the online pathology detector (`coordinator::pathology`):
    /// streaming detection of idle-spin / serialized-drain / creator-
    /// starvation patterns over the trace rings, surfaced as sticky
    /// `RtStats` gauges and consumed by the auto-tuner's `MIN_READY_TASKS`
    /// controller. Implies [`tracing`](TaskSystemBuilder::tracing) — the
    /// rings are the detector's only input. Off (the default), the idle
    /// paths pay one `OnceLock` load and the hot paths pay nothing.
    pub fn pathology(mut self, on: bool) -> Self {
        self.pathology = on;
        if on {
            self.tracing = true;
        }
        self
    }

    /// [`pathology`](TaskSystemBuilder::pathology) with explicit detection
    /// thresholds (tests stage small, exact windows).
    pub fn pathology_config(
        mut self,
        cfg: crate::coordinator::pathology::PathologyConfig,
    ) -> Self {
        self.pathology = true;
        self.tracing = true;
        self.pathology_config = Some(cfg);
        self
    }

    pub fn build(self) -> TaskSystem {
        let params = self.params.unwrap_or_else(|| DdastParams::tuned(self.num_threads));
        let rt = RuntimeShared::new_full(
            self.kind,
            self.num_threads,
            params,
            self.tracing,
            self.seed,
            self.ranged,
            self.fault_plan,
            self.topology,
            self.ingress_capacity
                .unwrap_or(crate::coordinator::messages::DEFAULT_INGRESS_CAPACITY),
        );
        if self.pathology {
            let armed = match self.pathology_config {
                Some(cfg) => rt.arm_pathology_with(cfg),
                None => rt.arm_pathology(),
            };
            debug_assert!(armed, "pathology() implies tracing, so arming cannot fail");
        }
        let mut autotuner = None;
        if self.kind == RuntimeKind::Ddast {
            match self.manager_affinity {
                Some(workers) => rt.register_ddast_with_affinity(workers),
                None => rt.register_ddast(),
            }
            if self.autotune {
                let tuner =
                    crate::coordinator::autotune::AutoTuner::new(Arc::clone(&rt), self.autotune_interval);
                tuner.register();
                autotuner = Some(tuner);
            }
        }
        // The calling thread is worker 0.
        install_ctx(&rt, 0);
        let mut threads = Vec::new();
        for w in 1..self.num_threads {
            let rt = Arc::clone(&rt);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("ddast-worker-{w}"))
                    .spawn(move || rt.worker_loop(w))
                    .expect("spawn worker"),
            );
        }
        if self.kind == RuntimeKind::CentralDast {
            // The centralized design runs its manager on an *additional*
            // thread (the paper's earlier system [7]).
            let rt2 = Arc::clone(&rt);
            let slot = self.num_threads;
            threads.push(
                std::thread::Builder::new()
                    .name("dast-manager".into())
                    .spawn(move || rt2.dast_thread_loop(slot))
                    .expect("spawn dast manager"),
            );
        }
        TaskSystem {
            inner: Arc::new(Inner {
                rt,
                threads: Mutex::new(threads),
                autotuner,
                record_graphs: self.record_graphs,
                replay_cache: Mutex::new(None),
            }),
        }
    }
}

struct Inner {
    rt: Arc<RuntimeShared>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    autotuner: Option<Arc<crate::coordinator::autotune::AutoTuner>>,
    /// Record/replay plane enabled (TaskSystemBuilder::record_graphs).
    record_graphs: bool,
    /// The arena run bound to the recording replayed last — reused while
    /// the caller keeps replaying the same recording, rebuilt (and
    /// re-installed into the runtime's RCU slot) when a different one
    /// arrives.
    replay_cache: Mutex<Option<Arc<ReplayRun>>>,
}

/// Handle to a running task system. Cloneable; capture clones inside task
/// bodies to spawn nested tasks. The pool shuts down when the last clone
/// that called [`TaskSystem::shutdown`] (or `Drop` of the final handle)
/// completes.
#[derive(Clone)]
pub struct TaskSystem {
    inner: Arc<Inner>,
}

impl TaskSystem {
    pub fn builder() -> TaskSystemBuilder {
        TaskSystemBuilder::default()
    }

    /// Convenience: a DDAST system with tuned parameters.
    pub fn new_ddast(num_threads: usize) -> Self {
        Self::builder().kind(RuntimeKind::Ddast).num_threads(num_threads).build()
    }

    /// Convenience: the Nanos++-like synchronous baseline.
    pub fn new_sync(num_threads: usize) -> Self {
        Self::builder().kind(RuntimeKind::Sync).num_threads(num_threads).build()
    }

    #[inline]
    pub fn runtime(&self) -> &Arc<RuntimeShared> {
        &self.inner.rt
    }

    /// The auto-tuner, if enabled through [`TaskSystemBuilder::autotune`].
    pub fn autotuner(&self) -> Option<&Arc<crate::coordinator::autotune::AutoTuner>> {
        self.inner.autotuner.as_ref()
    }

    /// Spawn a task with address-keyed dependences — the ergonomic form
    /// matching `#pragma omp task in(...) out(...)`.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, deps: &[(u64, DepMode)], body: F) {
        let deps = deps
            .iter()
            .map(|&(addr, mode)| Dependence::new(RegionKey::addr(addr), mode))
            .collect();
        self.spawn_full(deps, "task", body);
    }

    /// Spawn with full [`Dependence`] descriptors and a trace label.
    pub fn spawn_full<F: FnOnce() + Send + 'static>(
        &self,
        deps: Vec<Dependence>,
        label: &'static str,
        body: F,
    ) {
        let (rt, worker, parent) = self.ctx();
        rt.spawn_from(worker, &parent, deps, label, Box::new(body));
    }

    /// [`TaskSystem::spawn_full`] returning the task's work descriptor, so
    /// the caller can later block on *this specific task* with
    /// [`TaskSystem::wait_for`] instead of a full `taskwait` barrier.
    pub fn spawn_handle<F: FnOnce() + Send + 'static>(
        &self,
        deps: Vec<Dependence>,
        label: &'static str,
        body: F,
    ) -> Arc<Wd> {
        let (rt, worker, parent) = self.ctx();
        rt.spawn_from(worker, &parent, deps, label, Box::new(body))
    }

    /// Wait until one specific task (a [`TaskSystem::spawn_handle`]
    /// result) has completed and been finalized — the point-to-point
    /// alternative to the `taskwait` barrier. While blocked the calling
    /// thread keeps executing ready tasks; when nothing is actionable it
    /// parks with a **dependence-targeted wake edge** registered on the
    /// predecessor itself, and the predecessor's finalizer wakes exactly
    /// this thread (no directory broadcast).
    pub fn wait_for(&self, task: &Arc<Wd>) {
        let (rt, worker, _parent) = self.ctx();
        rt.taskwait_task(worker, task);
    }

    /// `#pragma omp taskwait`: wait until all children of the *current*
    /// task (the caller's innermost running task, or the implicit root)
    /// have completed and been removed from the runtime structures.
    pub fn taskwait(&self) {
        let (rt, worker, parent) = self.ctx();
        rt.taskwait_on(worker, &parent);
    }

    /// [`TaskSystem::taskwait`], then report whether the run is poisoned:
    /// `Err(TaskErrors)` once any task body panicked (or was cancelled by
    /// poison propagation). Non-breaking companion to the infallible call —
    /// the wait semantics are identical, and the error is *sticky* (the
    /// cumulative counters, not this wait's delta).
    pub fn taskwait_checked(&self) -> Result<(), TaskErrors> {
        self.taskwait();
        match self.inner.rt.task_errors() {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    // ---- serve-scale ingress (external submitters, tenant domains) -------

    /// Submit a task from a thread **outside** the pool, returning its
    /// handle (pair with [`TaskSystem::wait_for`]). Unlike
    /// [`TaskSystem::spawn`] — whose caller is a pool worker that resolves
    /// or enqueues the submission itself — the external lane routes
    /// dependence-carrying tasks through a bounded MPMC ingress ring
    /// drained by the managers, and the submitter never touches
    /// worker-private structures. Blocks (polite backoff) while the ring
    /// is full; the submission is never lost. The task becomes a child of
    /// the implicit root, so a pool-side `taskwait` at root level covers
    /// it.
    pub fn submit_async<F: FnOnce() + Send + 'static>(
        &self,
        deps: &[(u64, DepMode)],
        body: F,
    ) -> Arc<Wd> {
        let rt = &self.inner.rt;
        rt.spawn_external(&rt.root, addr_deps(deps), "ext", Box::new(body))
    }

    /// [`TaskSystem::submit_async`] without the handle — the fire-and-forget
    /// flavour for serve loops that only ever barrier with `taskwait`.
    pub fn submit_silent<F: FnOnce() + Send + 'static>(&self, deps: &[(u64, DepMode)], body: F) {
        let _ = self.submit_async(deps, body);
    }

    /// Non-blocking external submission: [`SubmitError::Busy`] when the
    /// ingress ring is full (admission rolled back completely — the
    /// rejected task leaves no trace in the parent's accounting). The
    /// caller owns the retry/shed decision; `RtStats::ingress_rejected`
    /// counts the backpressure events.
    pub fn try_submit<F: FnOnce() + Send + 'static>(
        &self,
        deps: &[(u64, DepMode)],
        body: F,
    ) -> Result<Arc<Wd>, SubmitError> {
        let rt = &self.inner.rt;
        rt.try_spawn_external(&rt.root, addr_deps(deps), "ext", Box::new(body))
    }

    /// Open an isolated [`GraphDomain`] — one tenant's graph scope on the
    /// shared pool. Each domain has its own root (so `taskwait` scopes to
    /// the domain), its own dependence namespace (two domains using the
    /// same addresses never serialize against each other), and its own
    /// sticky error cell (one tenant's panic poisons *its* graph, not its
    /// neighbours'). Cheap: one detached work descriptor plus a registry
    /// entry.
    pub fn domain(&self) -> GraphDomain {
        let rt = &self.inner.rt;
        // Detached root (no parent): attaching it under `rt.root` would
        // hold the global root's children_live up for the whole life of
        // the handle, wedging root-level taskwait/shutdown. Shutdown still
        // drains domain tasks — they count in `tasks_outstanding`.
        let root = Wd::new(
            rt.fresh_task_id(),
            Vec::new(),
            "domain-root",
            Weak::new(),
            Box::new(|| {}),
        );
        root.set_state(WdState::Running);
        let errors = rt.register_domain(root.id);
        GraphDomain { ts: self.clone(), root, errors }
    }

    // ---- record/replay plane (EXPERIMENTS.md §Graph replay) --------------

    /// Run one iteration's `tasks` to completion through full dependence
    /// resolution, capturing a [`GraphRecording`] of the resolved graph
    /// when [`TaskSystemBuilder::record_graphs`] is on (`None` otherwise —
    /// recording off degrades to plain resolved execution). The capture is
    /// synthetic (a sequential pass over the submission stream against a
    /// throwaway recording domain), so the recorded edge set is the full
    /// program-order one regardless of how the live run interleaves.
    pub fn record_iteration(&self, tasks: Vec<ReplayTask>) -> Option<Arc<GraphRecording>> {
        if !self.inner.record_graphs {
            self.run_tasks_resolved(tasks);
            return None;
        }
        let rec = replay::capture(&tasks, self.inner.rt.ranged_deps);
        self.run_tasks_resolved(tasks);
        self.inner.rt.stats.recordings_captured.inc();
        Some(rec)
    }

    /// Re-execute a recorded iteration with **zero dependence resolution**:
    /// no `DepDomain` shard acquisitions, no Submit/Done messages through
    /// the request plane, no per-iteration descriptor allocation — the
    /// pre-sized arena is recycled and completion counts down the recorded
    /// in-degrees directly. If `tasks`' submission stream hashes
    /// differently from the recording (structure changed), the iteration
    /// transparently falls back to full resolution.
    ///
    /// Must be driven from outside task bodies (it waits on the root, like
    /// the iteration drivers), and by one driver at a time — two concurrent
    /// `replay` calls would both wait on the root and race the arena
    /// install. Bodies may still spawn nested tasks, which resolve
    /// normally, provided they `taskwait` their children before returning.
    pub fn replay(&self, rec: &Arc<GraphRecording>, tasks: Vec<ReplayTask>) -> ReplayOutcome {
        let rt = &self.inner.rt;
        if replay::stream_hash_of(&tasks) != rec.stream_hash() {
            rt.stats.replay_fallbacks.inc();
            self.run_tasks_resolved(tasks);
            return ReplayOutcome::FellBack;
        }
        let run = {
            let mut cache = self
                .inner
                .replay_cache
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            match cache.as_ref() {
                Some(run) if Arc::ptr_eq(&run.rec, rec) => Arc::clone(run),
                _ => {
                    let run = ReplayRun::new(rt, Arc::clone(rec));
                    rt.replay_install(Arc::clone(&run));
                    *cache = Some(Arc::clone(&run));
                    run
                }
            }
        };
        let bodies: Vec<TaskBody> = tasks.into_iter().map(|t| t.body).collect();
        let (rt, worker, parent) = self.ctx();
        assert!(
            Arc::ptr_eq(&parent, &rt.root),
            "replay must be driven from outside task bodies"
        );
        replay::run_iteration(&rt, &run, worker, bodies);
        ReplayOutcome::Replayed
    }

    /// Fallback/off-mode iteration: spawn every task from the root and
    /// wait. (Direct `spawn_from` — the bodies are already boxed.)
    fn run_tasks_resolved(&self, tasks: Vec<ReplayTask>) {
        let (rt, worker, parent) = self.ctx();
        for t in tasks {
            rt.spawn_from(worker, &parent, t.deps, t.label, t.body);
        }
        rt.taskwait_on(worker, &parent);
    }

    /// Resolve the calling thread's context; threads outside the pool act
    /// as worker 0 spawning from the root task.
    fn ctx(&self) -> (Arc<RuntimeShared>, usize, Arc<Wd>) {
        match current_ctx() {
            // The TLS context may belong to a *different* (nested/test)
            // TaskSystem; only trust it if it is ours.
            Some((rt, w, cur)) if Arc::ptr_eq(&rt, &self.inner.rt) => (rt, w, cur),
            _ => (Arc::clone(&self.inner.rt), 0, Arc::clone(&self.inner.rt.root)),
        }
    }

    /// Drain all work and stop the worker threads. Idempotent.
    pub fn shutdown(&self) {
        let rt = &self.inner.rt;
        if !rt.shutdown_requested() {
            // Finish everything in flight first.
            let root = Arc::clone(&rt.root);
            rt.taskwait_on(0, &root);
            rt.request_shutdown();
        }
        // A poisoned `threads` mutex means some thread panicked while
        // holding it — the join handles inside are still valid, and
        // refusing to join them here would leak the pool on the very runs
        // that most need a clean teardown. Take the data and go on.
        let mut threads = self
            .inner
            .threads
            .lock()
            .unwrap_or_else(|poisoned| {
                rt.stats.teardown_degradations.inc();
                poisoned.into_inner()
            });
        for t in threads.drain(..) {
            if t.join().is_err() {
                // A worker died outside the catch_unwind boundary (runtime
                // bug, not a task panic — those are contained). Count it;
                // the remaining joins must still happen.
                rt.stats.teardown_degradations.inc();
            }
        }
    }

    /// [`TaskSystem::shutdown`], then report whether the run was poisoned —
    /// the checked teardown for callers that want failures surfaced instead
    /// of only counted. Same sticky semantics as
    /// [`TaskSystem::taskwait_checked`].
    pub fn shutdown_checked(&self) -> Result<(), TaskErrors> {
        self.shutdown();
        match self.inner.rt.task_errors() {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

/// Address-keyed dependence descriptors — the ergonomic `(addr, mode)`
/// form shared by [`TaskSystem::spawn`] and the ingress surface.
fn addr_deps(deps: &[(u64, DepMode)]) -> Vec<Dependence> {
    deps.iter().map(|&(addr, mode)| Dependence::new(RegionKey::addr(addr), mode)).collect()
}

/// One tenant's isolated graph scope on a shared [`TaskSystem`] — see
/// [`TaskSystem::domain`]. The handle owns the scope: waiting
/// ([`GraphDomain::taskwait`]) covers exactly the tasks submitted through
/// it, and failure state ([`GraphDomain::errors`]) is the domain's own
/// sticky cell — a panic here cancels this domain's dependents and nothing
/// else. Dropping the handle deregisters the domain; tasks still in flight
/// finish under the runtime's orphan-tolerant teardown paths.
///
/// Not `Clone`: the handle is the deregistration point. Share it across
/// submitter threads with an `Arc<GraphDomain>` — every submission method
/// takes `&self` and is thread-safe.
pub struct GraphDomain {
    ts: TaskSystem,
    root: Arc<Wd>,
    errors: Arc<DomainErrorCell>,
}

impl GraphDomain {
    /// The domain's root task — parent of everything submitted through
    /// this handle (e.g. for `RuntimeShared::taskwait_on`-level plumbing).
    pub fn root(&self) -> &Arc<Wd> {
        &self.root
    }

    /// Spawn into the domain from a **pool** thread (the in-pool analogue
    /// of [`TaskSystem::spawn`], scoped to this domain's graph).
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, deps: &[(u64, DepMode)], body: F) {
        let (rt, worker, _parent) = self.ts.ctx();
        rt.spawn_from(worker, &self.root, addr_deps(deps), "domain", Box::new(body));
    }

    /// Submit into the domain from a thread outside the pool — blocking
    /// flavour; semantics of [`TaskSystem::submit_async`] with this
    /// domain's root as parent.
    pub fn submit_async<F: FnOnce() + Send + 'static>(
        &self,
        deps: &[(u64, DepMode)],
        body: F,
    ) -> Arc<Wd> {
        self.ts.inner.rt.spawn_external(&self.root, addr_deps(deps), "ext", Box::new(body))
    }

    /// [`GraphDomain::submit_async`] without the handle.
    pub fn submit_silent<F: FnOnce() + Send + 'static>(&self, deps: &[(u64, DepMode)], body: F) {
        let _ = self.submit_async(deps, body);
    }

    /// Non-blocking external submission into the domain;
    /// [`SubmitError::Busy`] under ring backpressure (fully rolled back).
    pub fn try_submit<F: FnOnce() + Send + 'static>(
        &self,
        deps: &[(u64, DepMode)],
        body: F,
    ) -> Result<Arc<Wd>, SubmitError> {
        self.ts.inner.rt.try_spawn_external(&self.root, addr_deps(deps), "ext", Box::new(body))
    }

    /// Wait for every task submitted through this domain (a `taskwait`
    /// scoped to the domain root). Pool threads execute work while they
    /// wait, exactly like [`TaskSystem::taskwait`].
    pub fn taskwait(&self) {
        let (rt, worker, _parent) = self.ts.ctx();
        rt.taskwait_on(worker, &self.root);
    }

    /// [`GraphDomain::taskwait`], then report **this domain's** poison
    /// state: `Err` iff a task of this domain failed or was cancelled.
    /// Another tenant's failures never surface here.
    pub fn taskwait_checked(&self) -> Result<(), TaskErrors> {
        self.taskwait();
        match self.errors.summary() {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// The domain's sticky failure summary without waiting (`None` while
    /// clean).
    pub fn errors(&self) -> Option<TaskErrors> {
        self.errors.summary()
    }
}

impl Drop for GraphDomain {
    fn drop(&mut self) {
        self.ts.inner.rt.deregister_domain(self.root.id);
    }
}

impl Drop for Inner {
    fn drop(&mut self) {
        // Last handle gone: drain and join. Same graceful teardown as
        // `shutdown`: a poisoned lock or a dead worker must not abort the
        // process via a panic-in-drop — count and keep joining.
        if !self.rt.shutdown_requested() {
            let root = Arc::clone(&self.rt.root);
            self.rt.taskwait_on(0, &root);
            self.rt.request_shutdown();
        }
        let mut threads = self.threads.lock().unwrap_or_else(|poisoned| {
            self.rt.stats.teardown_degradations.inc();
            poisoned.into_inner()
        });
        for t in threads.drain(..) {
            if t.join().is_err() {
                self.rt.stats.teardown_degradations.inc();
            }
        }
        clear_ctx();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn quickstart_compiles_and_runs() {
        let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(2).build();
        let x = Arc::new(AtomicU64::new(0));
        let (x1, x2) = (Arc::clone(&x), Arc::clone(&x));
        ts.spawn(&[(1, DepMode::Out)], move || x1.store(21, Ordering::SeqCst));
        ts.spawn(&[(1, DepMode::Inout)], move || {
            x2.fetch_add(21, Ordering::SeqCst);
        });
        ts.taskwait();
        assert_eq!(x.load(Ordering::SeqCst), 42);
        ts.shutdown();
    }

    #[test]
    fn nested_tasks_and_taskwait() {
        let ts = TaskSystem::new_ddast(2);
        let sum = Arc::new(AtomicU64::new(0));
        let ts2 = ts.clone();
        let s = Arc::clone(&sum);
        ts.spawn(&[], move || {
            // Inside a task: children attach to *this* task.
            for i in 1..=10u64 {
                let s = Arc::clone(&s);
                ts2.spawn(&[], move || {
                    s.fetch_add(i, Ordering::SeqCst);
                });
            }
            ts2.taskwait(); // waits for the 10 children only
            assert_eq!(s.load(Ordering::SeqCst), 55);
        });
        ts.taskwait();
        assert_eq!(sum.load(Ordering::SeqCst), 55);
    }

    #[test]
    fn all_kinds_run_a_chain() {
        for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
            let ts = TaskSystem::builder().kind(kind).num_threads(3).build();
            let v = Arc::new(AtomicU64::new(1));
            for _ in 0..20 {
                let v = Arc::clone(&v);
                ts.spawn(&[(7, DepMode::Inout)], move || {
                    // Dependent chain: each doubles; order violations would
                    // give a different result than 2^20.
                    v.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |x| Some(x * 2)).unwrap();
                });
            }
            ts.taskwait();
            assert_eq!(v.load(Ordering::SeqCst), 1 << 20, "kind={kind:?}");
        }
    }

    #[test]
    fn checked_apis_surface_task_panics() {
        let ts = TaskSystem::new_sync(1);
        ts.spawn(&[], || {});
        assert!(ts.taskwait_checked().is_ok(), "clean run reports Ok");
        ts.spawn(&[], || panic!("kaboom"));
        let err = ts.taskwait_checked().unwrap_err();
        assert_eq!(err.tasks_failed, 1);
        assert!(err.first_panic.as_deref().unwrap().contains("kaboom"));
        // Sticky: the poisoned run stays poisoned through teardown.
        let err = ts.shutdown_checked().unwrap_err();
        assert_eq!(err.tasks_failed, 1);
    }

    #[test]
    fn wait_for_blocks_on_one_task_not_the_barrier() {
        let ts = TaskSystem::new_ddast(2);
        let first = Arc::new(AtomicU64::new(0));
        let f = Arc::clone(&first);
        let handle = ts.spawn_handle(vec![], "first", move || {
            f.store(7, Ordering::SeqCst);
        });
        ts.wait_for(&handle);
        // The specific predecessor is fully finalized once wait_for
        // returns — not merely executed.
        assert_eq!(first.load(Ordering::SeqCst), 7);
        assert!(handle.done_handled());
        ts.shutdown();
    }

    #[test]
    fn injected_topology_shapes_the_directory() {
        let ts = TaskSystem::builder()
            .kind(RuntimeKind::Ddast)
            .num_threads(4)
            .topology(Topology::new(2, 2))
            .build();
        let rt = ts.runtime();
        assert_eq!(rt.topo.sockets(), 2);
        assert_eq!(rt.queues.signals().sockets(), 2);
        let v = Arc::new(AtomicU64::new(0));
        for _ in 0..32 {
            let v = Arc::clone(&v);
            ts.spawn(&[], move || {
                v.fetch_add(1, Ordering::SeqCst);
            });
        }
        ts.taskwait();
        assert_eq!(v.load(Ordering::SeqCst), 32);
        ts.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let ts = TaskSystem::new_sync(2);
        ts.spawn(&[], || {});
        ts.shutdown();
        ts.shutdown();
    }

    #[test]
    fn external_submits_from_outside_the_pool() {
        let ts = TaskSystem::new_ddast(2);
        let hits = Arc::new(AtomicU64::new(0));
        let client = {
            let ts = ts.clone();
            let hits = Arc::clone(&hits);
            std::thread::spawn(move || {
                for i in 0..64u64 {
                    let hits = Arc::clone(&hits);
                    // Mixed dependence keys: chains within a key, parallel
                    // across keys — exercises the ring, not just the
                    // no-deps direct route.
                    ts.submit_silent(&[(i % 5, DepMode::Inout)], move || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    });
                }
            })
        };
        client.join().unwrap();
        ts.taskwait(); // root-level barrier covers external submissions
        assert_eq!(hits.load(Ordering::SeqCst), 64);
        let rt = ts.runtime();
        assert_eq!(
            rt.stats.ingress_admitted.get() + rt.stats.ingress_direct.get(),
            64,
            "every external submission was admitted through a counted route"
        );
        ts.shutdown();
    }

    #[test]
    fn domains_isolate_failures_between_tenants() {
        let ts = TaskSystem::new_ddast(2);
        let a = ts.domain();
        let b = ts.domain();
        // Tenant A: a failing head with a dependent that must be cancelled.
        a.spawn(&[(1, DepMode::Out)], || panic!("tenant A dies"));
        a.spawn(&[(1, DepMode::In)], || {});
        // Tenant B: the same addresses — a *different* dependence
        // namespace, so nothing here serializes against (or is poisoned
        // by) tenant A.
        let ok = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let ok = Arc::clone(&ok);
            b.spawn(&[(1, DepMode::Inout)], move || {
                ok.fetch_add(1, Ordering::SeqCst);
            });
        }
        let err = a.taskwait_checked().unwrap_err();
        assert_eq!(err.tasks_failed, 1);
        assert_eq!(err.tasks_cancelled, 1);
        assert!(err.first_panic.as_deref().unwrap().contains("tenant A dies"));
        b.taskwait_checked().expect("tenant B untouched by A's poison");
        assert_eq!(ok.load(Ordering::SeqCst), 8);
        assert!(b.errors().is_none());
        ts.shutdown();
    }

    #[test]
    fn try_submit_sees_backpressure_at_the_configured_capacity() {
        // One worker — the test thread — which is busy *here*, not
        // draining: the tiny ring fills deterministically.
        let ts = TaskSystem::builder()
            .kind(RuntimeKind::Ddast)
            .num_threads(1)
            .ingress_capacity(2)
            .build();
        let n = Arc::new(AtomicU64::new(0));
        let mut admitted = 0u64;
        let mut busy = 0u64;
        for _ in 0..4 {
            let n = Arc::clone(&n);
            match ts.try_submit(&[(9, DepMode::Inout)], move || {
                n.fetch_add(1, Ordering::SeqCst);
            }) {
                Ok(_) => admitted += 1,
                Err(SubmitError::Busy) => busy += 1,
            }
        }
        assert_eq!(admitted, 2, "ring capacity bounds admission");
        assert_eq!(busy, 2, "overflow rejected, not queued");
        ts.taskwait(); // the waiting worker drains the ring itself
        assert_eq!(n.load(Ordering::SeqCst), 2, "admitted tasks all ran");
        assert_eq!(ts.runtime().stats.ingress_rejected.get(), 2);
        ts.shutdown();
    }
}
