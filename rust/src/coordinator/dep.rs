//! Task data dependences — the `in(...)`, `out(...)`, `inout(...)` clauses
//! of OmpSs/OpenMP (§2.1.1 of the paper).

use crate::substrate::RegionKey;

/// Access mode of a task on a region.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum DepMode {
    /// `in(x)` — the task reads `x`; depends on the last writer (RAW).
    In,
    /// `out(x)` — the task writes `x`; depends on previous readers (WAR)
    /// and the previous writer (WAW).
    Out,
    /// `inout(x)` — reads and writes; union of the above.
    Inout,
}

impl DepMode {
    #[inline]
    pub fn reads(self) -> bool {
        matches!(self, DepMode::In | DepMode::Inout)
    }

    #[inline]
    pub fn writes(self) -> bool {
        matches!(self, DepMode::Out | DepMode::Inout)
    }
}

/// One declared dependence of a task.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Dependence {
    pub region: RegionKey,
    pub mode: DepMode,
}

impl Dependence {
    #[inline]
    pub fn new(region: RegionKey, mode: DepMode) -> Self {
        Dependence { region, mode }
    }

    /// Address-keyed dependence (the form the benchmarks use).
    #[inline]
    pub fn addr(base: u64, mode: DepMode) -> Self {
        Dependence { region: RegionKey::addr(base), mode }
    }

    /// Do two dependences conflict (i.e. order the tasks)? At least one
    /// side must write and the regions must overlap.
    #[inline]
    pub fn conflicts(&self, other: &Dependence) -> bool {
        (self.mode.writes() || other.mode.writes()) && self.region.overlaps(&other.region)
    }
}

/// Convenience constructors mirroring the pragma clauses.
pub fn dep_in(addr: u64) -> Dependence {
    Dependence::addr(addr, DepMode::In)
}
pub fn dep_out(addr: u64) -> Dependence {
    Dependence::addr(addr, DepMode::Out)
}
pub fn dep_inout(addr: u64) -> Dependence {
    Dependence::addr(addr, DepMode::Inout)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes() {
        assert!(DepMode::In.reads() && !DepMode::In.writes());
        assert!(!DepMode::Out.reads() && DepMode::Out.writes());
        assert!(DepMode::Inout.reads() && DepMode::Inout.writes());
    }

    #[test]
    fn conflicts() {
        let r = dep_in(1);
        let r2 = dep_in(1);
        let w = dep_out(1);
        let w2 = dep_out(2);
        assert!(!r.conflicts(&r2), "read-read never conflicts");
        assert!(r.conflicts(&w));
        assert!(w.conflicts(&r));
        assert!(!w.conflicts(&w2), "disjoint regions");
        assert!(dep_inout(1).conflicts(&dep_inout(1)));
    }
}
