//! Work Descriptors — the runtime's task representation (§2.2.1).
//!
//! Each task is one `Wd` flowing through the life-cycle state machine the
//! paper describes: *Created → Submitted → Ready → Running → Finished →
//! DoneHandled → Deletable*. The extra `DoneHandled` state is the paper's
//! §3.1 trick: instead of a third message type for deletion, a state marks
//! when the Done Task Message has been fully processed so the WD can be
//! reclaimed safely.
//!
//! The failure-containment plane adds two terminal-outcome states between
//! `Finished` and `DoneHandled`: a panicking body lands in **`Failed`**
//! (instead of `Finished`), and a task poisoned by a failed predecessor is
//! **`Cancelled`** (instead of `Ready`) — both then run the *normal*
//! finalize path (`DoneHandled → Deletable`), so successor notification,
//! `children_live` accounting and the taskwait wake edge never leak. The
//! numbering keeps every dead task `is_finished()`: submitters must not
//! chain new dependences on a corpse.

use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Weak};

use crate::coordinator::dep::Dependence;
use crate::substrate::SpinLock;

/// Monotonic task identifier (0 is the implicit root task).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct TaskId(pub u64);

/// Task body. `FnOnce` because a task runs exactly once.
pub type TaskBody = Box<dyn FnOnce() + Send + 'static>;

/// Task life-cycle states (paper §2.2.1 steps 1–6, plus the deletion
/// state of §3.1).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u8)]
pub enum WdState {
    /// Step 1: allocated and initialized.
    Created = 0,
    /// Step 2: dependences being/been inserted in the task graph.
    Submitted = 1,
    /// Step 3: dependences satisfied, queued for execution.
    Ready = 2,
    /// Executing on some worker.
    Running = 3,
    /// Step 5: body finished; successors not yet notified.
    Finished = 4,
    /// Body panicked (caught at the execution boundary); successors not yet
    /// notified. Finalizes normally, but poisons its dependents.
    Failed = 5,
    /// Poisoned by a failed/cancelled predecessor: the body never runs, the
    /// task finalizes normally. Placed ≥ `Finished` so submitters treat it
    /// as a completed predecessor.
    Cancelled = 6,
    /// Done Task Message processed: successors notified, removed from graph.
    DoneHandled = 7,
    /// Step 6: no children alive either — safe to reclaim.
    Deletable = 8,
}

impl WdState {
    fn from_u8(v: u8) -> WdState {
        match v {
            0 => WdState::Created,
            1 => WdState::Submitted,
            2 => WdState::Ready,
            3 => WdState::Running,
            4 => WdState::Finished,
            5 => WdState::Failed,
            6 => WdState::Cancelled,
            7 => WdState::DoneHandled,
            8 => WdState::Deletable,
            _ => unreachable!("invalid WdState {v}"),
        }
    }
}

/// A work descriptor. Shared via `Arc`; the dependence graph, ready pools
/// and message queues all hold references during the task's life.
pub struct Wd {
    pub id: TaskId,
    /// Declared dependences (fixed at creation).
    pub deps: Vec<Dependence>,
    /// Label used by tracing/benchmarks (e.g. "lu0", "propagate").
    pub label: &'static str,
    /// The code to run. Taken exactly once by the executing worker.
    body: SpinLock<Option<TaskBody>>,
    state: AtomicU8,
    /// Pending predecessor count **plus one submission guard**. The guard
    /// prevents the task from becoming ready while its own submission is
    /// still inserting dependences.
    preds: AtomicUsize,
    /// Successor tasks discovered by the dependence graph. Mutated only
    /// under the owning domain's lock; drained once at finish.
    pub(crate) successors: SpinLock<Vec<Arc<Wd>>>,
    /// Direct children not yet done-handled (taskwait + deletion safety).
    children_live: AtomicUsize,
    /// Taskwait waiter registration — the **child-completion wake edge**:
    /// `(generation << 32) | (worker + 1)`, 0 = no waiter. A thread
    /// blocked in `taskwait_on` publishes itself here before parking; the
    /// finalizer that drives `children_live` to zero claims the slot and
    /// wakes that worker's parking slot. See [`Wd::register_waiter`] for
    /// the ownership rules.
    waiter: AtomicU64,
    /// Monotonic registration generation (makes each waiter token unique,
    /// so clears/claims can never hit a later registration).
    waiter_gen: AtomicU64,
    /// Parent task. Weak to break the parent→domain→child→parent cycle.
    pub(crate) parent: Weak<Wd>,
    /// Dependence domain for this task's children (lazily created on first
    /// child with dependences). `Arc` so graph operations run without
    /// holding this outer lock.
    pub(crate) child_domain: SpinLock<Option<Arc<crate::coordinator::depgraph::DepDomain>>>,
}

impl Wd {
    pub fn new(
        id: TaskId,
        deps: Vec<Dependence>,
        label: &'static str,
        parent: Weak<Wd>,
        body: TaskBody,
    ) -> Arc<Wd> {
        Arc::new(Wd {
            id,
            deps,
            label,
            body: SpinLock::new(Some(body)),
            state: AtomicU8::new(WdState::Created as u8),
            preds: AtomicUsize::new(1), // the submission guard
            successors: SpinLock::new(Vec::new()),
            children_live: AtomicUsize::new(0),
            waiter: AtomicU64::new(0),
            waiter_gen: AtomicU64::new(0),
            parent,
            child_domain: SpinLock::new(None),
        })
    }

    /// The implicit root task (the "main" task of §2.1: the thread-pool
    /// model gives the whole program an enclosing task).
    pub fn root() -> Arc<Wd> {
        let root = Wd::new(TaskId(0), Vec::new(), "root", Weak::new(), Box::new(|| {}));
        root.set_state(WdState::Running);
        root
    }

    #[inline]
    pub fn state(&self) -> WdState {
        WdState::from_u8(self.state.load(Ordering::Acquire))
    }

    /// Transition with a validity check: the life cycle only moves forward.
    ///
    /// SeqCst: the `DoneHandled` + `children_live == 0` → `Deletable`
    /// decision is taken from two threads reading each other's writes
    /// (store-buffer pattern); Acq/Rel alone would allow both to miss.
    pub fn set_state(&self, next: WdState) {
        let prev = self.state.swap(next as u8, Ordering::SeqCst);
        debug_assert!(
            prev < next as u8 || (prev == next as u8),
            "illegal WD state transition {:?} -> {:?} (task {:?})",
            WdState::from_u8(prev),
            next,
            self.id
        );
    }

    /// Has the Done Task Message for this task been fully processed?
    /// (Used instead of a third message type — paper §3.1.)
    #[inline]
    pub fn done_handled(&self) -> bool {
        self.state.load(Ordering::Acquire) >= WdState::DoneHandled as u8
    }

    /// Has the body finished executing? Checked under the domain lock by
    /// the graph code to decide whether a would-be predecessor still counts.
    /// `Failed` and `Cancelled` tasks count as finished: a dead task can
    /// never run, so chaining a new dependence on it would wait forever.
    #[inline]
    pub fn is_finished(&self) -> bool {
        self.state.load(Ordering::Acquire) >= WdState::Finished as u8
    }

    /// Did this task die (panic or poison) rather than complete? Meaningful
    /// from the moment of death until the finalizer advances the state to
    /// `DoneHandled` — exactly the window in which the finalizer decides
    /// whether the released successors must be poisoned.
    #[inline]
    pub fn is_poisoned(&self) -> bool {
        matches!(self.state(), WdState::Failed | WdState::Cancelled)
    }

    /// Take the body for execution. Panics if taken twice — a task must run
    /// exactly once (invariant #2 of DESIGN.md §6).
    pub fn take_body(&self) -> TaskBody {
        self.body
            .lock()
            .take()
            .unwrap_or_else(|| panic!("task {:?} body taken twice", self.id))
    }

    /// Drop the body without running it — a cancelled task releases its
    /// captures (Arcs, buffers) at cancellation time instead of holding
    /// them until the `Wd` itself is reclaimed. Idempotent.
    pub fn drop_body(&self) {
        drop(self.body.lock().take());
    }

    /// Add `n` pending predecessors. Called under the domain lock during
    /// submission.
    #[inline]
    pub fn add_preds(&self, n: usize) {
        self.preds.fetch_add(n, Ordering::AcqRel);
    }

    /// Drop one pending predecessor (or the submission guard). Returns true
    /// when the task just became ready.
    #[inline]
    pub fn release_pred(&self) -> bool {
        let prev = self.preds.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "pred underflow on task {:?}", self.id);
        prev == 1
    }

    #[inline]
    pub fn pending_preds(&self) -> usize {
        self.preds.load(Ordering::Acquire)
    }

    /// Re-arm a replay-arena descriptor for the next recorded iteration:
    /// install the fresh body and the recorded in-degree, and rewind the
    /// life cycle to `Created`. This is the **only** sanctioned backward
    /// state transition in the runtime — it deliberately bypasses
    /// [`set_state`](Wd::set_state)'s forward-only check, and is sound only
    /// because the caller (`replay::run_iteration`) re-arms every
    /// descriptor *before* seeding any, on a quiesced arena: the previous
    /// iteration's taskwait returned, so every descriptor is `Deletable`
    /// with no waiter, no successor list and no live children. No
    /// submission guard is needed — nothing can release a predecessor
    /// until seeding starts.
    pub(crate) fn recycle_for_replay(&self, body: TaskBody, preds: usize) {
        debug_assert!(
            matches!(self.state(), WdState::Created | WdState::Deletable),
            "recycling a descriptor still in flight: {:?} (task {:?})",
            self.state(),
            self.id
        );
        debug_assert_eq!(self.children_live(), 0, "recycle with live children ({:?})", self.id);
        debug_assert!(!self.waiter_registered(), "recycle with dangling waiter ({:?})", self.id);
        debug_assert!(self.successors.lock().is_empty(), "arena tasks never chain successors");
        *self.body.lock() = Some(body);
        self.preds.store(preds, Ordering::Release);
        self.state.store(WdState::Created as u8, Ordering::SeqCst);
    }

    /// Register a newly created child (for taskwait and deletion safety).
    #[inline]
    pub fn child_created(&self) {
        self.children_live.fetch_add(1, Ordering::AcqRel);
    }

    /// A child reached `DoneHandled`. Returns true if this was the last
    /// live child. SeqCst pairs with [`Wd::set_state`] (see there).
    #[inline]
    pub fn child_done(&self) -> bool {
        let prev = self.children_live.fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev > 0, "children underflow on task {:?}", self.id);
        prev == 1
    }

    #[inline]
    pub fn children_live(&self) -> usize {
        self.children_live.load(Ordering::SeqCst)
    }

    // ---- taskwait waiter slot (targeted wake edges) ----------------------

    /// Register the calling worker as this task's taskwait waiter.
    ///
    /// One slot carries **two kinds of targeted wake edge**: the
    /// child-completion edge (a thread blocked in `taskwait_on` on *this
    /// task's children*, claimed by the finalizer that drives
    /// `children_live` to zero) and the dependence-targeted edge (a thread
    /// blocked in `taskwait_task` on *this task itself*, claimed by this
    /// task's own finalizer right after the `DoneHandled` store). The two
    /// cannot collide in practice — an in-body `taskwait_on` returns
    /// before the body finishes, long before finalize — and a cross-claim
    /// is merely a spurious wake: the claimed waiter re-checks its
    /// condition and re-registers before parking again.
    ///
    /// **Ownership rules** (the wake-edge contract — also in the README
    /// architecture map): only the blocked thread may *publish* (CAS
    /// `0 → packed`, this method); only a finalizer may *claim*
    /// ([`take_waiter`](Wd::take_waiter)'s swap `→ 0`); and the waiter
    /// *clears its own* registration ([`clear_waiter`](Wd::clear_waiter),
    /// CAS `packed → 0`) after every park attempt, so a registration never
    /// outlives the park it guards.
    ///
    /// `SeqCst`: pairs with the finalizer's publish-then-claim — the slot
    /// and the wake condition (`children_live`, or the `DoneHandled`
    /// state for the dependence edge) need a single total order so that
    /// either the waiter's post-announce re-check sees the condition, or
    /// the finalizer's claim sees the registration (the store-buffer
    /// argument in `taskwait_on`/`taskwait_task`).
    ///
    /// Returns the token to pass to `clear_waiter`, or `None` when another
    /// waiter is already registered (two taskwaits on one WD — reachable
    /// only through the root task from outside the pool); the caller must
    /// fall back to polling.
    pub fn register_waiter(&self, worker: usize) -> Option<u64> {
        debug_assert!((worker as u64) < u32::MAX as u64);
        let gen = self.waiter_gen.fetch_add(1, Ordering::Relaxed).wrapping_add(1);
        let packed = (gen << 32) | (worker as u64 + 1);
        self.waiter
            .compare_exchange(0, packed, Ordering::SeqCst, Ordering::SeqCst)
            .ok()
            .map(|_| packed)
    }

    /// Withdraw the registration published with
    /// [`register_waiter`](Wd::register_waiter). Returns `false` when a
    /// finalizer already claimed it (its wake is in flight or delivered —
    /// harmless either way, the waiter is awake to call this).
    pub fn clear_waiter(&self, token: u64) -> bool {
        self.waiter.compare_exchange(token, 0, Ordering::SeqCst, Ordering::SeqCst).is_ok()
    }

    /// Claim the waiter registration, if any — the finalizer side of the
    /// wake edge, called on the decrement that zeroes `children_live`.
    /// Returns the registered worker id to wake. The cheap peek keeps the
    /// hot finalize path (most tasks never have a waiter) to one load.
    pub fn take_waiter(&self) -> Option<usize> {
        if self.waiter.load(Ordering::SeqCst) == 0 {
            return None;
        }
        let v = self.waiter.swap(0, Ordering::SeqCst);
        if v == 0 {
            None
        } else {
            Some((v & u32::MAX as u64) as usize - 1)
        }
    }

    /// Is a taskwait waiter currently registered? (Racy peek for tests —
    /// after `taskwait_on` returns, no registration may dangle.)
    #[inline]
    pub fn waiter_registered(&self) -> bool {
        self.waiter.load(Ordering::Acquire) != 0
    }

    /// Dependence domain for this task's children, created on first use
    /// (exact-match plugin).
    pub fn child_domain(&self) -> Arc<crate::coordinator::depgraph::DepDomain> {
        self.child_domain_with(false)
    }

    /// Like [`Wd::child_domain`], selecting the dependence plugin on first
    /// creation (`ranged = true` → the range-overlap plugin).
    pub fn child_domain_with(&self, ranged: bool) -> Arc<crate::coordinator::depgraph::DepDomain> {
        let mut slot = self.child_domain.lock();
        slot.get_or_insert_with(|| {
            Arc::new(if ranged {
                crate::coordinator::depgraph::DepDomain::new_ranged()
            } else {
                crate::coordinator::depgraph::DepDomain::new()
            })
        })
        .clone()
    }

    /// The children's domain if it was ever created (diagnostics/tracing).
    pub fn child_domain_opt(&self) -> Option<Arc<crate::coordinator::depgraph::DepDomain>> {
        self.child_domain.lock().clone()
    }
}

impl std::fmt::Debug for Wd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Wd")
            .field("id", &self.id)
            .field("label", &self.label)
            .field("state", &self.state())
            .field("preds", &self.pending_preds())
            .field("children_live", &self.children_live())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dep::dep_in;

    fn mk(id: u64) -> Arc<Wd> {
        Wd::new(TaskId(id), vec![dep_in(1)], "t", Weak::new(), Box::new(|| {}))
    }

    #[test]
    fn lifecycle_forward() {
        let wd = mk(1);
        assert_eq!(wd.state(), WdState::Created);
        wd.set_state(WdState::Submitted);
        wd.set_state(WdState::Ready);
        wd.set_state(WdState::Running);
        wd.set_state(WdState::Finished);
        assert!(wd.is_finished());
        assert!(!wd.done_handled());
        wd.set_state(WdState::DoneHandled);
        assert!(wd.done_handled());
        wd.set_state(WdState::Deletable);
    }

    #[test]
    fn failed_and_cancelled_are_finished_and_finalize_forward() {
        // A panicked body: Running → Failed → DoneHandled → Deletable, and
        // a poisoned dependent: Submitted → Cancelled → DoneHandled →
        // Deletable. Both read as finished (submitters must skip corpses)
        // and as poisoned until done-handled.
        let failed = mk(10);
        failed.set_state(WdState::Submitted);
        failed.set_state(WdState::Ready);
        failed.set_state(WdState::Running);
        failed.set_state(WdState::Failed);
        assert!(failed.is_finished());
        assert!(failed.is_poisoned());
        assert!(!failed.done_handled());
        failed.set_state(WdState::DoneHandled);
        assert!(failed.done_handled());
        assert!(!failed.is_poisoned(), "poison window closes at DoneHandled");
        failed.set_state(WdState::Deletable);

        let cancelled = mk(11);
        cancelled.set_state(WdState::Submitted);
        cancelled.set_state(WdState::Cancelled);
        assert!(cancelled.is_finished());
        assert!(cancelled.is_poisoned());
        cancelled.drop_body();
        cancelled.drop_body(); // idempotent
        cancelled.set_state(WdState::DoneHandled);
        cancelled.set_state(WdState::Deletable);
    }

    #[test]
    #[should_panic(expected = "body taken twice")]
    fn body_taken_once() {
        let wd = mk(2);
        let b = wd.take_body();
        b();
        let _ = wd.take_body();
    }

    #[test]
    fn pred_counting_with_guard() {
        let wd = mk(3);
        // Starts with the submission guard.
        assert_eq!(wd.pending_preds(), 1);
        wd.add_preds(2);
        assert!(!wd.release_pred()); // one real pred gone
        assert!(!wd.release_pred()); // second real pred gone
        assert!(wd.release_pred()); // guard released -> ready now
    }

    #[test]
    fn children_accounting() {
        let wd = mk(4);
        wd.child_created();
        wd.child_created();
        assert_eq!(wd.children_live(), 2);
        assert!(!wd.child_done());
        assert!(wd.child_done());
    }

    #[test]
    fn waiter_slot_register_claim_clear() {
        let wd = mk(5);
        assert!(!wd.waiter_registered());
        let t = wd.register_waiter(3).expect("empty slot registers");
        assert!(wd.waiter_registered());
        assert!(wd.register_waiter(4).is_none(), "occupied slot refuses");
        assert_eq!(wd.take_waiter(), Some(3), "finalizer claims the worker id");
        assert!(!wd.waiter_registered());
        assert!(!wd.clear_waiter(t), "claimed registration cannot be cleared");
        assert_eq!(wd.take_waiter(), None, "claim is one-shot");
        // Re-registration gets a fresh generation: the old token is dead.
        let t2 = wd.register_waiter(3).unwrap();
        assert_ne!(t, t2, "generation makes each registration unique");
        assert!(!wd.clear_waiter(t), "stale token cannot clear the new slot");
        assert!(wd.clear_waiter(t2), "own token clears");
        assert!(!wd.waiter_registered());
    }

    #[test]
    fn root_is_running() {
        let r = Wd::root();
        assert_eq!(r.state(), WdState::Running);
        assert_eq!(r.id, TaskId(0));
    }
}
