//! Messages and the per-worker queuing system (paper §3.1).
//!
//! Two message types only:
//! * **Submit Task Message** — a worker created a task and asks the manager
//!   to insert it into the dependence graph;
//! * **Done Task Message** — a worker finished a task's body and asks the
//!   manager to notify/schedule its successors.
//!
//! Task deletion needs no third message: the `DoneHandled` state on the WD
//! carries that synchronization (§3.1, last paragraph).
//!
//! Each worker owns one queue *pair*; only the owning worker pushes, and
//! the Submit queue is FIFO with an exclusive consumer token so the graph
//! sees submissions in program order (§3.1, ordering discussion).
//!
//! Managers drain a claimed worker *per batch* rather than per message:
//! [`WorkerQueues::drain_batch_with`] pops up to the Listing-2 budget into
//! a reusable [`MsgBatch`] in one pass and applies the graph mutations
//! (`RuntimeShared::process_batch`, one shard-acquisition set per batch)
//! **while the Submit consumer token is held**, so pop + insertion stay
//! atomic per worker and concurrent managers cannot reorder one worker's
//! submissions (EXPERIMENTS.md §Batched request plane). The
//! popped-vs-processed distinction of the pending gauge is unchanged —
//! the batch is accounted with one
//! [`messages_processed`](QueueSystem::messages_processed) call after its
//! graph mutations complete.

use std::sync::Arc;

use crate::coordinator::wd::Wd;
use crate::substrate::{IngressRing, ShardedCounter, SignalDirectory, SpscQueue, Topology};

/// Default capacity of the external-submitter ingress ring. Bounded by
/// design: the ring *is* the admission control — when it fills, external
/// submitters get `Busy` back instead of growing an unbounded queue inside
/// the runtime. Overridable via `TaskSystemBuilder::ingress_capacity`.
pub const DEFAULT_INGRESS_CAPACITY: usize = 1024;

/// Extra sharded-counter cells reserved for external-submitter threads.
/// The pending gauge's shard count was sized from the pool thread count
/// alone (`num_workers + 2`), so a burst of external producers aliased the
/// pool's cells and turned the gauge's sharding into contention. External
/// threads never get trace rings or queue pairs (those stay pool-indexed);
/// they only need counter cells, and `ShardedCounter`'s thread-local
/// round-robin cell assignment spreads any number of them over this
/// allowance.
pub const EXTERNAL_SHARD_ALLOWANCE: usize = 8;

/// Request to insert a created task into the dependence graph.
#[derive(Debug)]
pub struct SubmitTaskMsg {
    pub task: Arc<Wd>,
}

/// Notification that a task's body finished.
#[derive(Debug)]
pub struct DoneTaskMsg {
    pub task: Arc<Wd>,
    /// Worker that executed the task (successors are scheduled to its
    /// ready queue for locality).
    pub worker: usize,
}

/// Reusable drain buffer for [`WorkerQueues::drain_batch`]. A manager
/// keeps one per callback activation: messages are popped into it in one
/// pass and the graph mutations are applied per batch
/// (`RuntimeShared::process_batch`), so the steady state allocates
/// nothing — the vectors keep their capacity across drains.
#[derive(Default)]
pub struct MsgBatch {
    /// Submitted tasks, in the owning worker's FIFO program order.
    pub submits: Vec<Arc<Wd>>,
    /// Done notifications (their relative order does not affect graph
    /// correctness; submits are applied first, mirroring Listing 2's
    /// Submit-before-Done priority).
    pub dones: Vec<DoneTaskMsg>,
    /// Scratch for the tasks a batch made ready (`process_batch` fills and
    /// drains it into the ready pools) — part of the batch buffer so the
    /// manager hot path reuses its capacity instead of allocating.
    pub ready: Vec<Arc<Wd>>,
}

impl MsgBatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Messages currently buffered (the `ready` scratch is not a message).
    #[inline]
    pub fn len(&self) -> usize {
        self.submits.len() + self.dones.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.submits.is_empty() && self.dones.is_empty()
    }

    /// Empty the buffers, keeping their capacity.
    pub fn clear(&mut self) {
        self.submits.clear();
        self.dones.clear();
        self.ready.clear();
    }
}

/// The queue pair owned by one worker thread.
pub struct WorkerQueues {
    pub submit: SpscQueue<SubmitTaskMsg>,
    pub done: SpscQueue<DoneTaskMsg>,
}

impl Default for WorkerQueues {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkerQueues {
    pub fn new() -> Self {
        WorkerQueues { submit: SpscQueue::new(), done: SpscQueue::new() }
    }

    /// Total messages currently pending in this pair.
    pub fn pending(&self) -> usize {
        self.submit.len() + self.done.len()
    }

    /// Pop up to `budget` messages from this pair into `batch` in one pass
    /// — Submit Task Messages first (they uncover parallelism; Listing 2's
    /// priority), then Done Task Messages, both FIFO under the same
    /// exclusive consumer tokens as per-message draining — and run `apply`
    /// on the filled batch **while the Submit consumer token is still
    /// held**. `budget` is the Listing-2 `MAX_OPS_THREAD`; managers read
    /// it from `TunableParams::snapshot` per activation, so the
    /// `AutoTuner`'s queue-depth controller adjusts how much one claimed
    /// worker is drained without touching this code. Holding the token across the graph application is what
    /// keeps pop + insertion atomic per worker: without it, a second
    /// manager could drain this worker's *next* submissions and insert
    /// them into the graph before this batch's, breaking program order.
    /// (Done messages carry no such ordering: their tasks already ran, and
    /// concurrent finishes of distinct tasks commute under the shard
    /// locks, exactly as when different workers' done queues are drained
    /// by different managers.)
    ///
    /// A token held by another manager skips that queue (the caller
    /// re-raises the worker if messages remain, exactly as before).
    /// `batch` is cleared first and refilled; `apply` runs only if the
    /// batch is non-empty. Returns the number of messages drained.
    pub fn drain_batch_with<F: FnOnce(&mut MsgBatch)>(
        &self,
        budget: usize,
        batch: &mut MsgBatch,
        apply: F,
    ) -> usize {
        batch.clear();
        // Bound to a named variable so the guard lives across `apply`.
        let _submit_guard = match self.submit.try_acquire() {
            Some(mut g) => {
                while batch.submits.len() < budget {
                    match g.pop() {
                        Some(m) => batch.submits.push(m.task),
                        None => break,
                    }
                }
                Some(g)
            }
            None => None,
        };
        if let Some(mut g) = self.done.try_acquire() {
            while batch.len() < budget {
                match g.pop() {
                    Some(m) => batch.dones.push(m),
                    None => break,
                }
            }
        }
        let n = batch.len();
        if n > 0 {
            apply(batch);
        }
        n
    }

    /// [`drain_batch_with`](WorkerQueues::drain_batch_with) without the
    /// in-token application step — the Submit token is released before the
    /// caller sees the batch, so this is only program-order-safe in
    /// **single-consumer** contexts (tests, diagnostics). Managers that
    /// can run concurrently must use `drain_batch_with`.
    pub fn drain_batch(&self, budget: usize, batch: &mut MsgBatch) -> usize {
        self.drain_batch_with(budget, batch, |_| {})
    }
}

/// All workers' queues, the work-signal directory managers scan instead of
/// sweeping every queue pair, the shared external-submitter ingress ring,
/// and a sharded pending gauge for quiescence.
pub struct QueueSystem {
    pub workers: Vec<WorkerQueues>,
    /// Messages pushed and not yet fully *processed* (not merely popped):
    /// the counter is decremented after the graph mutation completes, so
    /// `pending() == 0` means the runtime structures are up to date.
    /// Sharded: every push/process touches only the calling thread's cell
    /// (the seed's single `Counter` was a global RMW per message); gauges
    /// read the relaxed sweep, `quiescent()` the exact fallback. Counts
    /// ingress-ring entries too (incremented on admission), so every
    /// pending-based decision — parking re-checks, quiescence — covers the
    /// external lane with no extra condition.
    pending: ShardedCounter,
    /// Which workers have unclaimed requests — the DDAST sweep walks this
    /// instead of all queue pairs (O(dirty), not O(workers)).
    signals: SignalDirectory,
    /// Shared bounded ring for submissions from threads *outside* the pool
    /// (the serve lane). Producers compete on a CAS, managers drain it
    /// through the same `MsgBatch` path as the SPSC plane, and the signal
    /// directory's external-producer bit carries its wakeups.
    ingress: IngressRing<Arc<Wd>>,
}

impl QueueSystem {
    pub fn new(num_workers: usize) -> Self {
        Self::with_park_slots(num_workers, num_workers)
    }

    /// Like [`QueueSystem::new`], but with the signal directory sized to
    /// `park_slots` parking contexts — `park_slots >= num_workers`. The
    /// runtime passes one slot per *context*, like the trace rings: the
    /// CentralDast DAS thread parks (timed) on the extra slot beyond the
    /// workers, so `wake_all` (shutdown, watchdog) reaches it. Only the
    /// first `num_workers` slots carry work-signal raises; the extras are
    /// parking-only.
    pub fn with_park_slots(num_workers: usize, park_slots: usize) -> Self {
        Self::with_topology(
            num_workers,
            park_slots,
            Topology::word_grain(park_slots.max(num_workers).max(1)),
        )
    }

    /// Like [`QueueSystem::with_park_slots`], but the signal directory is
    /// laid out along `topo` (two-level: socket summary → per-worker bits),
    /// so manager sweeps and wake scans only touch dirty sockets. The
    /// runtime passes its resolved [`Topology`]; the default above keeps
    /// the flat word-grain layout.
    pub fn with_topology(num_workers: usize, park_slots: usize, topo: Topology) -> Self {
        Self::with_topology_and_ingress(num_workers, park_slots, topo, DEFAULT_INGRESS_CAPACITY)
    }

    /// Like [`QueueSystem::with_topology`], with an explicit ingress-ring
    /// capacity (the admission bound for external submitters — see
    /// [`DEFAULT_INGRESS_CAPACITY`]).
    pub fn with_topology_and_ingress(
        num_workers: usize,
        park_slots: usize,
        topo: Topology,
        ingress_capacity: usize,
    ) -> Self {
        debug_assert!(park_slots >= num_workers);
        QueueSystem {
            workers: (0..num_workers).map(|_| WorkerQueues::new()).collect(),
            // +2 for the CentralDast DAS slot and stray non-pool threads,
            // plus the external-submitter allowance, so an ingress burst
            // never aliases a pool context's counter cell (satellite fix:
            // cells sized from the contexts that actually touch the gauge).
            pending: ShardedCounter::with_shards(
                num_workers + 2 + EXTERNAL_SHARD_ALLOWANCE,
            ),
            signals: SignalDirectory::new_with_topology(
                park_slots.max(num_workers).max(1),
                topo,
            ),
            ingress: IngressRing::new(ingress_capacity),
        }
    }

    #[inline]
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// The work-signal directory (manager-side scans, re-raises).
    #[inline]
    pub fn signals(&self) -> &SignalDirectory {
        &self.signals
    }

    /// Exclusive directory access during construction — the runtime uses
    /// this to install the `IngressRaise` fault-plan gate before the queue
    /// system is shared.
    #[inline]
    pub fn signals_mut(&mut self) -> &mut SignalDirectory {
        &mut self.signals
    }

    /// Push a Submit Task Message from `worker` (its own queue only).
    /// Enqueue first, raise second — the directory's no-lost-wakeup
    /// protocol requires the message to precede its signal.
    pub fn push_submit(&self, worker: usize, task: Arc<Wd>) {
        self.pending.inc();
        self.workers[worker].submit.push(SubmitTaskMsg { task });
        self.signals.raise(worker);
    }

    /// Push a Done Task Message from `worker`.
    pub fn push_done(&self, worker: usize, task: Arc<Wd>) {
        self.pending.inc();
        self.workers[worker].done.push(DoneTaskMsg { task, worker });
        self.signals.raise(worker);
    }

    /// Admit a submission from a thread *outside* the pool: publish into
    /// the bounded ingress ring, count it pending, then raise the
    /// directory's external-producer bit (publish-then-signal, same order
    /// as [`push_submit`](QueueSystem::push_submit) — the raise issues the
    /// producer-side fence of the park protocol, so a parked pool cannot
    /// miss it). `Err` hands the task back when the ring is full:
    /// backpressure, with **no** runtime-visible side effects from this
    /// call (the caller undoes its own accounting).
    pub fn try_push_external(&self, task: Arc<Wd>) -> Result<(), Arc<Wd>> {
        match self.ingress.try_push(task) {
            Ok(()) => {
                self.pending.inc();
                self.signals.raise_external();
                Ok(())
            }
            Err(task) => Err(task),
        }
    }

    /// Pop one admitted external submission (manager-side; consumers
    /// compete on a CAS). The caller settles the pending gauge via
    /// [`messages_processed`](QueueSystem::messages_processed) after the
    /// graph mutation, like any other message.
    pub fn pop_external(&self) -> Option<Arc<Wd>> {
        self.ingress.try_pop()
    }

    /// External submissions admitted and not yet popped (approximate under
    /// concurrency, exact when quiescent).
    #[inline]
    pub fn ingress_pending(&self) -> usize {
        self.ingress.len()
    }

    /// Capacity of the external-submitter ring (the admission bound).
    #[inline]
    pub fn ingress_capacity(&self) -> usize {
        self.ingress.capacity()
    }

    /// (accepted pushes, pops, rejected pushes) on the ingress ring.
    pub fn ingress_stats(&self) -> (u64, u64, u64) {
        self.ingress.stats()
    }

    /// Mark one popped message as fully processed.
    #[inline]
    pub fn message_processed(&self) {
        self.pending.dec();
    }

    /// Per-batch accounting: mark `n` popped messages as fully processed
    /// in one sharded-cell update (the batch path's counterpart of
    /// [`message_processed`](QueueSystem::message_processed)).
    #[inline]
    pub fn messages_processed(&self, n: u64) {
        self.pending.sub(n);
    }

    /// Messages pushed but not yet fully processed (relaxed sweep — gauge
    /// strength, may be transiently off while pushes are in flight).
    #[inline]
    pub fn pending(&self) -> u64 {
        self.pending.get()
    }

    /// Exact pending read for decisions that must not act on a torn sweep
    /// (`quiescent()`).
    #[inline]
    pub fn pending_exact(&self) -> u64 {
        self.pending.exact()
    }

    /// Quiescence cross-check against the directory: no worker may hold a
    /// raised signal *and* queued messages. Stale raises (the producer's
    /// raise landed just after the draining manager's claim) are reclaimed
    /// here — with the claim-then-recheck protocol — so shutdown converges.
    pub fn signals_quiescent(&self) -> bool {
        let mut from = 0;
        while let Some(w) = self.signals.first_raised_from(from) {
            if self.workers[w].pending() > 0 {
                return false;
            }
            self.signals.try_claim(w);
            if self.workers[w].pending() > 0 {
                // A message raced in behind our emptiness check: hand the
                // signal back and report non-quiescent.
                self.signals.raise(w);
                return false;
            }
            from = w + 1;
        }
        // Same claim-then-recheck discipline for the external lane: a
        // stale external bit (ring already drained) is reclaimed; a raced
        // admission hands it back and reports non-quiescent.
        if self.signals.external_raised() {
            if self.ingress.len() > 0 {
                return false;
            }
            self.signals.try_claim_external();
            if self.ingress.len() > 0 {
                self.signals.raise_external();
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wd::TaskId;
    use std::sync::Weak;

    fn mk(id: u64) -> Arc<Wd> {
        Wd::new(TaskId(id), Vec::new(), "t", Weak::new(), Box::new(|| {}))
    }

    #[test]
    fn submit_fifo_per_worker() {
        let qs = QueueSystem::new(2);
        qs.push_submit(0, mk(1));
        qs.push_submit(0, mk(2));
        qs.push_submit(1, mk(3));
        assert_eq!(qs.pending(), 3);
        let mut g = qs.workers[0].submit.try_acquire().unwrap();
        assert_eq!(g.pop().unwrap().task.id, TaskId(1));
        assert_eq!(g.pop().unwrap().task.id, TaskId(2));
        assert!(g.pop().is_none());
    }

    #[test]
    fn pending_tracks_processing_not_popping() {
        let qs = QueueSystem::new(1);
        qs.push_done(0, mk(1));
        let msg = {
            let mut g = qs.workers[0].done.try_acquire().unwrap();
            g.pop().unwrap()
        };
        // Popped but not processed yet.
        assert_eq!(qs.pending(), 1);
        drop(msg);
        qs.message_processed();
        assert_eq!(qs.pending(), 0);
    }

    #[test]
    fn done_records_executing_worker() {
        let qs = QueueSystem::new(3);
        qs.push_done(2, mk(9));
        let mut g = qs.workers[2].done.try_acquire().unwrap();
        let m = g.pop().unwrap();
        assert_eq!(m.worker, 2);
    }

    #[test]
    fn pushes_raise_signals_and_quiescence_cross_checks() {
        let qs = QueueSystem::new(4);
        assert!(qs.signals_quiescent());
        qs.push_submit(2, mk(1));
        assert!(qs.signals().is_raised(2));
        assert!(!qs.signals_quiescent(), "queued message blocks quiescence");
        // Drain + process: the raised flag becomes stale and the
        // cross-check self-heals it.
        {
            let mut g = qs.workers[2].submit.try_acquire().unwrap();
            g.pop().unwrap();
        }
        qs.message_processed();
        assert!(qs.signals_quiescent());
        assert!(!qs.signals().is_raised(2), "stale raise reclaimed");
        assert_eq!(qs.pending_exact(), 0);
    }

    #[test]
    fn queue_pair_pending() {
        let wq = WorkerQueues::new();
        assert_eq!(wq.pending(), 0);
        wq.submit.push(SubmitTaskMsg { task: mk(1) });
        wq.done.push(DoneTaskMsg { task: mk(2), worker: 0 });
        assert_eq!(wq.pending(), 2);
    }

    #[test]
    fn drain_batch_prioritizes_submits_and_respects_budget() {
        let wq = WorkerQueues::new();
        for i in 1..=5u64 {
            wq.submit.push(SubmitTaskMsg { task: mk(i) });
        }
        for i in 10..=12u64 {
            wq.done.push(DoneTaskMsg { task: mk(i), worker: 0 });
        }
        let mut batch = MsgBatch::new();
        // Budget 6: all 5 submits, then 1 done.
        assert_eq!(wq.drain_batch(6, &mut batch), 6);
        let ids: Vec<u64> = batch.submits.iter().map(|t| t.id.0).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5], "submits drained FIFO, first");
        assert_eq!(batch.dones.len(), 1);
        assert_eq!(batch.dones[0].task.id, TaskId(10));
        // The next drain clears the buffer and picks up the leftovers.
        assert_eq!(wq.drain_batch(6, &mut batch), 2);
        assert!(batch.submits.is_empty());
        let dids: Vec<u64> = batch.dones.iter().map(|d| d.task.id.0).collect();
        assert_eq!(dids, vec![11, 12]);
        assert_eq!(wq.drain_batch(6, &mut batch), 0);
        assert!(batch.is_empty());
    }

    #[test]
    fn drain_batch_with_holds_submit_token_during_apply() {
        // The graph application must run under the Submit consumer token:
        // releasing it earlier would let a second manager insert this
        // worker's *later* submissions first (program-order violation).
        let wq = WorkerQueues::new();
        wq.submit.push(SubmitTaskMsg { task: mk(1) });
        let mut batch = MsgBatch::new();
        let n = wq.drain_batch_with(8, &mut batch, |b| {
            assert_eq!(b.submits.len(), 1);
            assert!(
                wq.submit.try_acquire().is_none(),
                "submit token must be held while the batch is applied"
            );
        });
        assert_eq!(n, 1);
        assert!(wq.submit.try_acquire().is_some(), "token released after apply");
    }

    #[test]
    fn drain_batch_skips_held_tokens() {
        let wq = WorkerQueues::new();
        wq.submit.push(SubmitTaskMsg { task: mk(1) });
        wq.done.push(DoneTaskMsg { task: mk(2), worker: 0 });
        let held = wq.submit.try_acquire().unwrap();
        let mut batch = MsgBatch::new();
        // Submit token held elsewhere: only the done side drains; the
        // caller sees pending() > 0 and re-raises, as per-message did.
        assert_eq!(wq.drain_batch(8, &mut batch), 1);
        assert!(batch.submits.is_empty());
        assert_eq!(batch.dones.len(), 1);
        assert_eq!(wq.pending(), 1);
        drop(held);
        assert_eq!(wq.drain_batch(8, &mut batch), 1);
        assert_eq!(batch.submits.len(), 1);
    }

    #[test]
    fn external_push_raises_the_external_bit_and_counts_pending() {
        let qs = QueueSystem::new(2);
        assert!(qs.try_push_external(mk(1)).is_ok());
        assert!(qs.signals().external_raised());
        assert_eq!(qs.pending(), 1);
        assert_eq!(qs.ingress_pending(), 1);
        assert!(!qs.signals_quiescent(), "admitted submission blocks quiescence");
        assert!(qs.signals().try_claim_external());
        let task = qs.pop_external().expect("admitted task pops");
        assert_eq!(task.id, TaskId(1));
        qs.message_processed();
        assert_eq!(qs.pending_exact(), 0);
        assert!(qs.signals_quiescent());
    }

    #[test]
    fn external_backpressure_hands_the_task_back() {
        let qs = QueueSystem::with_topology_and_ingress(
            1,
            1,
            Topology::word_grain(1),
            2,
        );
        assert_eq!(qs.ingress_capacity(), 2);
        assert!(qs.try_push_external(mk(1)).is_ok());
        assert!(qs.try_push_external(mk(2)).is_ok());
        let back = qs.try_push_external(mk(3)).expect_err("ring full");
        assert_eq!(back.id, TaskId(3));
        // Rejection leaves no runtime-visible traces: pending unchanged.
        assert_eq!(qs.pending(), 2);
        let (pushes, _, rejected) = qs.ingress_stats();
        assert_eq!((pushes, rejected), (2, 1));
        // Drain; admission capacity is restored.
        while let Some(_t) = qs.pop_external() {
            qs.message_processed();
        }
        assert!(qs.try_push_external(mk(3)).is_ok());
        assert!(qs.pop_external().is_some());
        qs.message_processed();
        assert!(qs.signals_quiescent() || qs.signals().try_claim_external());
    }

    #[test]
    fn stale_external_bit_is_reclaimed_by_quiescence() {
        let qs = QueueSystem::new(1);
        assert!(qs.try_push_external(mk(7)).is_ok());
        // Drain without claiming the bit: it is now stale.
        qs.pop_external().unwrap();
        qs.message_processed();
        assert!(qs.signals().external_raised());
        assert!(qs.signals_quiescent(), "stale bit must not block quiescence");
        assert!(!qs.signals().external_raised(), "stale bit reclaimed");
    }

    #[test]
    fn batch_accounting_per_batch() {
        let qs = QueueSystem::new(2);
        for i in 0..5u64 {
            qs.push_submit(0, mk(i + 1));
        }
        let mut batch = MsgBatch::new();
        let n = qs.workers[0].drain_batch(8, &mut batch) as u64;
        assert_eq!(n, 5);
        assert_eq!(qs.pending(), 5, "popped but not yet processed");
        qs.messages_processed(n);
        assert_eq!(qs.pending_exact(), 0);
    }
}
