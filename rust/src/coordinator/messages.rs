//! Messages and the per-worker queuing system (paper §3.1).
//!
//! Two message types only:
//! * **Submit Task Message** — a worker created a task and asks the manager
//!   to insert it into the dependence graph;
//! * **Done Task Message** — a worker finished a task's body and asks the
//!   manager to notify/schedule its successors.
//!
//! Task deletion needs no third message: the `DoneHandled` state on the WD
//! carries that synchronization (§3.1, last paragraph).
//!
//! Each worker owns one queue *pair*; only the owning worker pushes, and
//! the Submit queue is FIFO with an exclusive consumer token so the graph
//! sees submissions in program order (§3.1, ordering discussion).

use std::sync::Arc;

use crate::coordinator::wd::Wd;
use crate::substrate::{ShardedCounter, SignalDirectory, SpscQueue};

/// Request to insert a created task into the dependence graph.
#[derive(Debug)]
pub struct SubmitTaskMsg {
    pub task: Arc<Wd>,
}

/// Notification that a task's body finished.
#[derive(Debug)]
pub struct DoneTaskMsg {
    pub task: Arc<Wd>,
    /// Worker that executed the task (successors are scheduled to its
    /// ready queue for locality).
    pub worker: usize,
}

/// The queue pair owned by one worker thread.
pub struct WorkerQueues {
    pub submit: SpscQueue<SubmitTaskMsg>,
    pub done: SpscQueue<DoneTaskMsg>,
}

impl Default for WorkerQueues {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkerQueues {
    pub fn new() -> Self {
        WorkerQueues { submit: SpscQueue::new(), done: SpscQueue::new() }
    }

    /// Total messages currently pending in this pair.
    pub fn pending(&self) -> usize {
        self.submit.len() + self.done.len()
    }
}

/// All workers' queues, the work-signal directory managers scan instead of
/// sweeping every queue pair, and a sharded pending gauge for quiescence.
pub struct QueueSystem {
    pub workers: Vec<WorkerQueues>,
    /// Messages pushed and not yet fully *processed* (not merely popped):
    /// the counter is decremented after the graph mutation completes, so
    /// `pending() == 0` means the runtime structures are up to date.
    /// Sharded: every push/process touches only the calling thread's cell
    /// (the seed's single `Counter` was a global RMW per message); gauges
    /// read the relaxed sweep, `quiescent()` the exact fallback.
    pending: ShardedCounter,
    /// Which workers have unclaimed requests — the DDAST sweep walks this
    /// instead of all queue pairs (O(dirty), not O(workers)).
    signals: SignalDirectory,
}

impl QueueSystem {
    pub fn new(num_workers: usize) -> Self {
        QueueSystem {
            workers: (0..num_workers).map(|_| WorkerQueues::new()).collect(),
            pending: ShardedCounter::new(),
            signals: SignalDirectory::new(num_workers.max(1)),
        }
    }

    #[inline]
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// The work-signal directory (manager-side scans, re-raises).
    #[inline]
    pub fn signals(&self) -> &SignalDirectory {
        &self.signals
    }

    /// Push a Submit Task Message from `worker` (its own queue only).
    /// Enqueue first, raise second — the directory's no-lost-wakeup
    /// protocol requires the message to precede its signal.
    pub fn push_submit(&self, worker: usize, task: Arc<Wd>) {
        self.pending.inc();
        self.workers[worker].submit.push(SubmitTaskMsg { task });
        self.signals.raise(worker);
    }

    /// Push a Done Task Message from `worker`.
    pub fn push_done(&self, worker: usize, task: Arc<Wd>) {
        self.pending.inc();
        self.workers[worker].done.push(DoneTaskMsg { task, worker });
        self.signals.raise(worker);
    }

    /// Mark one popped message as fully processed.
    #[inline]
    pub fn message_processed(&self) {
        self.pending.dec();
    }

    /// Messages pushed but not yet fully processed (relaxed sweep — gauge
    /// strength, may be transiently off while pushes are in flight).
    #[inline]
    pub fn pending(&self) -> u64 {
        self.pending.get()
    }

    /// Exact pending read for decisions that must not act on a torn sweep
    /// (`quiescent()`).
    #[inline]
    pub fn pending_exact(&self) -> u64 {
        self.pending.exact()
    }

    /// Quiescence cross-check against the directory: no worker may hold a
    /// raised signal *and* queued messages. Stale raises (the producer's
    /// raise landed just after the draining manager's claim) are reclaimed
    /// here — with the claim-then-recheck protocol — so shutdown converges.
    pub fn signals_quiescent(&self) -> bool {
        let mut from = 0;
        while let Some(w) = self.signals.first_raised_from(from) {
            if self.workers[w].pending() > 0 {
                return false;
            }
            self.signals.try_claim(w);
            if self.workers[w].pending() > 0 {
                // A message raced in behind our emptiness check: hand the
                // signal back and report non-quiescent.
                self.signals.raise(w);
                return false;
            }
            from = w + 1;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wd::TaskId;
    use std::sync::Weak;

    fn mk(id: u64) -> Arc<Wd> {
        Wd::new(TaskId(id), Vec::new(), "t", Weak::new(), Box::new(|| {}))
    }

    #[test]
    fn submit_fifo_per_worker() {
        let qs = QueueSystem::new(2);
        qs.push_submit(0, mk(1));
        qs.push_submit(0, mk(2));
        qs.push_submit(1, mk(3));
        assert_eq!(qs.pending(), 3);
        let mut g = qs.workers[0].submit.try_acquire().unwrap();
        assert_eq!(g.pop().unwrap().task.id, TaskId(1));
        assert_eq!(g.pop().unwrap().task.id, TaskId(2));
        assert!(g.pop().is_none());
    }

    #[test]
    fn pending_tracks_processing_not_popping() {
        let qs = QueueSystem::new(1);
        qs.push_done(0, mk(1));
        let msg = {
            let mut g = qs.workers[0].done.try_acquire().unwrap();
            g.pop().unwrap()
        };
        // Popped but not processed yet.
        assert_eq!(qs.pending(), 1);
        drop(msg);
        qs.message_processed();
        assert_eq!(qs.pending(), 0);
    }

    #[test]
    fn done_records_executing_worker() {
        let qs = QueueSystem::new(3);
        qs.push_done(2, mk(9));
        let mut g = qs.workers[2].done.try_acquire().unwrap();
        let m = g.pop().unwrap();
        assert_eq!(m.worker, 2);
    }

    #[test]
    fn pushes_raise_signals_and_quiescence_cross_checks() {
        let qs = QueueSystem::new(4);
        assert!(qs.signals_quiescent());
        qs.push_submit(2, mk(1));
        assert!(qs.signals().is_raised(2));
        assert!(!qs.signals_quiescent(), "queued message blocks quiescence");
        // Drain + process: the raised flag becomes stale and the
        // cross-check self-heals it.
        {
            let mut g = qs.workers[2].submit.try_acquire().unwrap();
            g.pop().unwrap();
        }
        qs.message_processed();
        assert!(qs.signals_quiescent());
        assert!(!qs.signals().is_raised(2), "stale raise reclaimed");
        assert_eq!(qs.pending_exact(), 0);
    }

    #[test]
    fn queue_pair_pending() {
        let wq = WorkerQueues::new();
        assert_eq!(wq.pending(), 0);
        wq.submit.push(SubmitTaskMsg { task: mk(1) });
        wq.done.push(DoneTaskMsg { task: mk(2), worker: 0 });
        assert_eq!(wq.pending(), 2);
    }
}
