//! The DDAST manager: parameters (§3.3, Table 5) and the callback
//! (Listing 2) registered in the Functionality Dispatcher.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::coordinator::pool::RuntimeShared;
use crate::substrate::FaultSite;

/// Tuning knobs of the DDAST callback (paper §3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DdastParams {
    /// Maximum number of threads allowed to execute the DDAST callback
    /// concurrently.
    pub max_ddast_threads: usize,
    /// Times a manager iterates over all queues without finding a message
    /// before leaving the callback.
    pub max_spins: u32,
    /// Maximum messages satisfied from the same worker's queues before
    /// moving to the next worker — also the per-batch drain budget of
    /// `drain_batch_with`. Live-tuned against observed queue depth by the
    /// `AutoTuner` (§8), between the Table-5 baseline and
    /// `MAX_OPS_THREAD_CAP`; the callback snapshots it per activation.
    pub max_ops_thread: usize,
    /// Manager threads exit once at least this many ready tasks exist.
    pub min_ready_tasks: u64,
}

impl DdastParams {
    /// Pre-tuning defaults (Table 5 "Initial Value"). `usize::MAX` models
    /// the paper's "∞" for `MAX_DDAST_THREADS`.
    pub fn initial() -> Self {
        DdastParams {
            max_ddast_threads: usize::MAX,
            max_spins: 20,
            max_ops_thread: 6,
            min_ready_tasks: 4,
        }
    }

    /// Post-tuning defaults (Table 5 "Tuned Value"):
    /// `MAX_DDAST_THREADS = ⌈num_threads / 8⌉`, `MAX_SPINS = 1`,
    /// `MAX_OPS_THREAD = 8`, `MIN_READY_TASKS = 4`.
    pub fn tuned(num_threads: usize) -> Self {
        DdastParams {
            max_ddast_threads: num_threads.div_ceil(8).max(1),
            max_spins: 1,
            max_ops_thread: 8,
            min_ready_tasks: 4,
        }
    }
}

impl Default for DdastParams {
    fn default() -> Self {
        // Tuned values for a nominal 8-thread machine; `TaskSystem::builder`
        // replaces this with `tuned(num_threads)`.
        DdastParams::tuned(8)
    }
}

/// The DDAST callback — the paper's Listing 2 with two structural changes:
/// instead of sweeping **all** worker queue pairs per round (lines 5–6
/// iterate every thread), the manager walks the
/// [`SignalDirectory`](crate::substrate::SignalDirectory) and visits only
/// workers that actually enqueued requests since the last visit; and a
/// visited worker is drained **per batch** (lines 8–20's pop loop becomes
/// one [`drain_batch`](crate::coordinator::messages::WorkerQueues::drain_batch)
/// into a reusable buffer, applied by `RuntimeShared::process_batch` with
/// one shard-acquisition set per same-parent run instead of per message).
/// The Listing 2 semantics are preserved:
///
/// * `MAX_DDAST_THREADS` gate on entry (line 1, CAS so the cap is exact);
/// * per-worker `MAX_OPS_THREAD` budget, Submit before Done (lines 8–20,
///   now the batch's drain budget and fill priority) — a worker left with
///   messages (budget exhausted, or its queue token held by another
///   manager) is re-raised so the next round revisits it;
/// * `MIN_READY_TASKS` early exit checked before each worker (line 7) — a
///   claimed-but-unvisited worker keeps its directory mark;
/// * spin budget reset on progress, decrement on an empty round, exit at
///   zero (lines 24–25).
///
/// The scan starts in the manager's own socket (two-level directory,
/// `scan_near`) and its rotor starts successive scans at successive
/// workers within it, so one noisy producer cannot starve the others of
/// manager attention and a manager drains cache-near queues first.
///
/// Returns `true` if at least one message was satisfied (the Functionality
/// Dispatcher uses this for its idle accounting).
pub fn ddast_callback(rt: &Arc<RuntimeShared>, me: usize) -> bool {
    // Snapshot the live parameters: the auto-tuner (§8 future work) may
    // adjust them between callback executions — in particular the
    // per-worker batch budget `max_ops_thread`, which it drives against
    // observed queue depth, so every activation drains with the current
    // budget (guarded by `ddast_callback_honors_live_budget_next_activation`).
    let p = rt.tunables().snapshot();

    // Listing 2 line 1: `if (numThreads >= MAX_DDAST_THREADS) return`.
    // CAS loop so the cap is never overshot (DESIGN.md invariant #4).
    loop {
        let n = rt.mgr_count.load(Ordering::Acquire);
        if n >= p.max_ddast_threads {
            return false;
        }
        if rt
            .mgr_count
            .compare_exchange_weak(n, n + 1, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            rt.stats.mgr_peak.record_max(n as u64 + 1);
            break;
        }
    }
    rt.stats.mgr_activations.inc();
    rt.trace_manager_enter(me);

    let dir = rt.queues.signals();
    let mut spins = p.max_spins;
    let mut total_processed: u64 = 0;
    // Reusable drain buffer: lives for the whole callback activation, so
    // steady-state rounds allocate nothing.
    let mut batch = crate::coordinator::messages::MsgBatch::new();
    // Listing 2 lines 4..25, with the line 5–6 all-workers sweep replaced
    // by a claiming scan over the signal directory.
    loop {
        let mut total_cnt: usize = 0;
        // Locality-biased sweep: start in the manager's own socket (its
        // neighbours' queues share the cache hierarchy), rotor-rotated
        // within the socket so co-located producers still take turns; the
        // scan wraps across every socket, so remote raisers are never
        // starved — topology biases the order, not the coverage.
        let mut scan = dir.scan_near(me);
        loop {
            // Line 7: early exit when enough parallelism is uncovered. The
            // sharded gauge's relaxed sweep is fine here — this is the hot
            // inner check and MIN_READY_TASKS is a heuristic threshold.
            // Checked *before* claiming, so unvisited workers keep their
            // directory marks.
            if rt.ready.ready_count() >= p.min_ready_tasks {
                break;
            }
            let w = match scan.next() {
                Some(w) => w,
                None => break,
            };
            let wq = &rt.queues.workers[w];
            // Fault site `DrainBatch`: defer this worker's drain to a later
            // round. Re-raise first so the deferral cannot strand the
            // messages behind a clean directory — exactly the budget-
            // exhausted hand-back below, minus the drain.
            if wq.pending() > 0 && rt.fault_inject(FaultSite::DrainBatch) {
                dir.raise(w);
                continue;
            }
            // Lines 8–20 batched: up to MAX_OPS_THREAD messages — Submit
            // prioritized, FIFO — in one pass, with the graph application
            // running while the Submit consumer token is still held (pop +
            // insertion stay atomic per worker, so concurrent managers
            // cannot interleave one worker's submissions out of program
            // order — same guarantee the per-message loop had).
            let cnt =
                wq.drain_batch_with(p.max_ops_thread, &mut batch, |b| rt.process_batch(me, b));
            // Budget exhausted — or a queue token was held by another
            // manager — with messages left: hand the worker back to the
            // directory so a later round revisits it.
            if wq.pending() > 0 {
                dir.raise(w);
            }
            total_cnt += cnt;
        }
        // The external lane rides the same round: one bounded drain of the
        // ingress ring per sweep (claim the external bit → pop a chunk →
        // the same batch path, which re-raises the bit when entries
        // remain). Counted as progress, so sustained outside traffic keeps
        // the manager resident instead of spinning down between requests.
        total_cnt += rt.drain_ingress(me, &mut batch, p.max_ops_thread) as usize;
        total_processed += total_cnt as u64;
        // Line 24: reset the spin budget on progress, decrement otherwise.
        spins = if total_cnt == 0 { spins.saturating_sub(1) } else { p.max_spins };
        // Line 25 break conditions. The loop-exit decision uses the
        // exact-read fallback so a torn sweep of the sharded counter
        // cannot make the manager leave early (or linger) spuriously.
        if spins == 0 || rt.ready.ready_count_exact() >= p.min_ready_tasks {
            break;
        }
    }

    rt.stats.mgr_msgs.add(total_processed);
    rt.mgr_count.fetch_sub(1, Ordering::AcqRel);
    rt.trace_manager_exit(me, total_processed > 0);
    if total_processed == 0 {
        // Empty-handed exit — the idle moment the hang watchdog (and the
        // pathology detector's streaming scan) piggybacks on: if work sits
        // outstanding while everyone else is parked past the deadline,
        // re-raise and wake before going idle ourselves.
        rt.watchdog_tick();
        rt.pathology_tick();
    }
    total_processed > 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_values_match_table5() {
        let p = DdastParams::initial();
        assert_eq!(p.max_ddast_threads, usize::MAX);
        assert_eq!(p.max_spins, 20);
        assert_eq!(p.max_ops_thread, 6);
        assert_eq!(p.min_ready_tasks, 4);
    }

    #[test]
    fn tuned_values_match_table5() {
        let p = DdastParams::tuned(64);
        assert_eq!(p.max_ddast_threads, 8, "⌈64/8⌉");
        assert_eq!(p.max_spins, 1);
        assert_eq!(p.max_ops_thread, 8);
        assert_eq!(p.min_ready_tasks, 4);
        // Small machines still get one manager.
        assert_eq!(DdastParams::tuned(1).max_ddast_threads, 1);
        assert_eq!(DdastParams::tuned(4).max_ddast_threads, 1);
        assert_eq!(DdastParams::tuned(9).max_ddast_threads, 2, "ceiling");
        assert_eq!(DdastParams::tuned(48).max_ddast_threads, 6);
    }
}
