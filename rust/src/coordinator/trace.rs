//! Execution tracing — the reproduction's Paraver stand-in (paper §6.2).
//!
//! Collects the observables the paper plots: tasks in the dependence graph
//! (Fig 12a/13b/14a), ready tasks (Fig 12b/14b/15a) and per-thread states
//! (Fig 13a/13c/15b). Per-thread buffers keep recording off the hot path's
//! shared state; `dump_csv` and the ASCII renderers in `bench_harness`
//! consume the merged stream.

use std::sync::Mutex;
use std::time::Instant;

/// What a thread is doing (Fig 13's color legend).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ThreadState {
    /// Sky-blue in the paper's traces.
    Idle,
    /// Running application task code (label tells which task type).
    Task,
    /// Acting as a DDAST manager (runtime code on an idle thread).
    Manager,
}

/// One trace record.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Nanoseconds since trace start.
    pub t_ns: u64,
    pub kind: TraceKind,
}

#[derive(Clone, Debug)]
pub enum TraceKind {
    /// Gauge: number of tasks currently in the dependence graph.
    InGraph(u64),
    /// Gauge: number of ready tasks.
    Ready(u64),
    /// Thread `worker` switched state; label names the task type when
    /// entering `ThreadState::Task`.
    State { worker: usize, state: ThreadState, label: &'static str },
    /// Task lifetime markers (id, label) for span reconstruction.
    TaskStart { worker: usize, id: u64, label: &'static str },
    TaskEnd { worker: usize, id: u64 },
}

/// Trace collector. One instance per runtime; cheap enough to keep on for
/// the trace figures, `None`d out for throughput benches.
pub struct Tracer {
    start: Instant,
    buffers: Vec<Mutex<Vec<TraceEvent>>>,
}

impl Tracer {
    pub fn new(num_threads: usize) -> Self {
        Tracer {
            start: Instant::now(),
            buffers: (0..num_threads.max(1)).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }

    #[inline]
    pub fn record(&self, worker: usize, kind: TraceKind) {
        let ev = TraceEvent { t_ns: self.now_ns(), kind };
        self.buffers[worker % self.buffers.len()].lock().unwrap().push(ev);
    }

    /// Merge all per-thread buffers, sorted by time.
    pub fn merged(&self) -> Vec<TraceEvent> {
        let mut all: Vec<TraceEvent> = Vec::new();
        for b in &self.buffers {
            all.extend(b.lock().unwrap().iter().cloned());
        }
        all.sort_by_key(|e| e.t_ns);
        all
    }

    /// CSV dump: `t_ns,kind,worker,value,label`.
    pub fn dump_csv(&self) -> String {
        let mut out = String::from("t_ns,kind,worker,value,label\n");
        for e in self.merged() {
            match &e.kind {
                TraceKind::InGraph(v) => out.push_str(&format!("{},in_graph,,{},\n", e.t_ns, v)),
                TraceKind::Ready(v) => out.push_str(&format!("{},ready,,{},\n", e.t_ns, v)),
                TraceKind::State { worker, state, label } => out.push_str(&format!(
                    "{},state,{},{},{}\n",
                    e.t_ns,
                    worker,
                    match state {
                        ThreadState::Idle => 0,
                        ThreadState::Task => 1,
                        ThreadState::Manager => 2,
                    },
                    label
                )),
                TraceKind::TaskStart { worker, id, label } => {
                    out.push_str(&format!("{},task_start,{},{},{}\n", e.t_ns, worker, id, label))
                }
                TraceKind::TaskEnd { worker, id } => {
                    out.push_str(&format!("{},task_end,{},{},\n", e.t_ns, worker, id))
                }
            }
        }
        out
    }

    /// Export in Paraver `.prv` format — the tool the paper's §6.2 traces
    /// were rendered with. State records (`1:cpu:appl:task:thread:begin:
    /// end:state`) encode Idle/Task/Manager; event records (`2:...:type:
    /// value`) carry the gauges (type 9001 = tasks in graph, 9002 = ready).
    pub fn dump_prv(&self, num_threads: usize) -> String {
        let events = self.merged();
        let end_time = events.last().map_or(0, |e| e.t_ns);
        let mut out = format!(
            "#Paraver (01/01/2026 at 00:00):{end_time}_ns:1(1):1:1({num_threads}:1)\n"
        );
        // Reconstruct per-thread state intervals.
        let mut cur_state: Vec<(u64, u32)> = vec![(0, 0); num_threads]; // (since, state)
        let state_code = |s: &ThreadState| match s {
            ThreadState::Idle => 0u32,
            ThreadState::Task => 1,
            ThreadState::Manager => 3,
        };
        for e in &events {
            match &e.kind {
                TraceKind::State { worker, state, .. } => {
                    let w = *worker % num_threads;
                    let (since, code) = cur_state[w];
                    if e.t_ns > since {
                        out.push_str(&format!(
                            "1:{cpu}:1:1:{thr}:{since}:{end}:{code}\n",
                            cpu = w + 1,
                            thr = w + 1,
                            end = e.t_ns
                        ));
                    }
                    cur_state[w] = (e.t_ns, state_code(state));
                }
                TraceKind::InGraph(v) => {
                    out.push_str(&format!("2:1:1:1:1:{}:9001:{v}\n", e.t_ns));
                }
                TraceKind::Ready(v) => {
                    out.push_str(&format!("2:1:1:1:1:{}:9002:{v}\n", e.t_ns));
                }
                _ => {}
            }
        }
        for (w, (since, code)) in cur_state.iter().enumerate() {
            if end_time > *since {
                out.push_str(&format!(
                    "1:{cpu}:1:1:{thr}:{since}:{end_time}:{code}\n",
                    cpu = w + 1,
                    thr = w + 1
                ));
            }
        }
        out
    }

    /// Time series of a gauge: (t_ns, value) pairs.
    pub fn gauge_series(&self, in_graph: bool) -> Vec<(u64, u64)> {
        self.merged()
            .into_iter()
            .filter_map(|e| match e.kind {
                TraceKind::InGraph(v) if in_graph => Some((e.t_ns, v)),
                TraceKind::Ready(v) if !in_graph => Some((e.t_ns, v)),
                _ => None,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_merge_in_time_order() {
        let t = Tracer::new(2);
        t.record(0, TraceKind::InGraph(1));
        t.record(1, TraceKind::InGraph(2));
        t.record(0, TraceKind::Ready(1));
        let m = t.merged();
        assert_eq!(m.len(), 3);
        assert!(m.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
    }

    #[test]
    fn csv_has_all_rows() {
        let t = Tracer::new(1);
        t.record(0, TraceKind::TaskStart { worker: 0, id: 7, label: "lu0" });
        t.record(0, TraceKind::TaskEnd { worker: 0, id: 7 });
        t.record(0, TraceKind::State { worker: 0, state: ThreadState::Manager, label: "" });
        let csv = t.dump_csv();
        assert_eq!(csv.lines().count(), 4, "header + 3 events");
        assert!(csv.contains("task_start,0,7,lu0"));
        assert!(csv.contains("state,0,2,"));
    }

    #[test]
    fn prv_export_structure() {
        let t = Tracer::new(2);
        t.record(0, TraceKind::State { worker: 0, state: ThreadState::Task, label: "m" });
        t.record(1, TraceKind::State { worker: 1, state: ThreadState::Manager, label: "" });
        t.record(0, TraceKind::InGraph(3));
        t.record(0, TraceKind::State { worker: 0, state: ThreadState::Idle, label: "" });
        let prv = t.dump_prv(2);
        assert!(prv.starts_with("#Paraver"));
        assert!(prv.contains(":9001:3"), "{prv}");
        // State records exist for both threads.
        assert!(prv.lines().any(|l| l.starts_with("1:1:")));
        assert!(prv.lines().any(|l| l.starts_with("1:2:")));
    }

    #[test]
    fn gauge_series_filters() {
        let t = Tracer::new(1);
        t.record(0, TraceKind::InGraph(5));
        t.record(0, TraceKind::Ready(2));
        t.record(0, TraceKind::InGraph(6));
        assert_eq!(t.gauge_series(true).len(), 2);
        assert_eq!(t.gauge_series(false).len(), 1);
    }
}
