//! Execution tracing — the reproduction's Paraver stand-in (paper §6.2).
//!
//! Collects the observables the paper plots: tasks in the dependence graph
//! (Fig 12a/13b/14a), ready tasks (Fig 12b/14b/15a) and per-thread states
//! (Fig 13a/13c/15b). Per-thread buffers keep recording off the hot path's
//! shared state; `dump_csv` and the ASCII renderers in `bench_harness`
//! consume the merged stream.
//!
//! ## Wait-free rings
//!
//! The seed kept each thread's buffer in a `Mutex<Vec>` — one lock
//! round-trip (and occasionally a reallocation) per event, on the task
//! start/end hot path. Each buffer is now a [`TraceRing`]: an append-only
//! segmented buffer owned by one recording thread. The owner writes the
//! slot and publishes it with a single release store of the ring's length;
//! `merged`/`dump_csv` read the published length with an acquire load and
//! walk the prefix. A full ring **drops** the event and counts it
//! ([`Tracer::dropped`]) instead of blocking or reallocating — tracing must
//! never add a lock or an unbounded stall to the runtime being measured.
//!
//! Rings are sized by the *actual* number of recording contexts (workers
//! plus the CentralDast DAS slot). The seed indexed buffers with
//! `worker % buffers.len()`, which silently merged the DAS thread's stream
//! into worker 0's; `record` now accounts an out-of-range event on a
//! dedicated tracer-level `misrouted` counter (folded into `dropped`)
//! rather than corrupting another thread's stream or mischarging ring 0's
//! own overflow count.
//!
//! ## Incremental readers
//!
//! [`Tracer::cursor`] + [`Tracer::read_new`] give in-process consumers (the
//! pathology detector) a per-ring cursor over the published prefix: each
//! call copies only events appended since the cursor's last visit, so a
//! periodic scan is O(new events), never a re-merge of the whole trace.
//!
//! The seed implementation survives as [`LockedTracer`] for the
//! `trace_append` contention A/B.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::substrate::CachePadded;

/// What a thread is doing (Fig 13's color legend).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ThreadState {
    /// Sky-blue in the paper's traces.
    Idle,
    /// Running application task code (label tells which task type).
    Task,
    /// Acting as a DDAST manager (runtime code on an idle thread).
    Manager,
}

/// One trace record.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Nanoseconds since trace start.
    pub t_ns: u64,
    pub kind: TraceKind,
}

#[derive(Clone, Debug)]
pub enum TraceKind {
    /// Gauge: number of tasks currently in the dependence graph.
    InGraph(u64),
    /// Gauge: number of ready tasks.
    Ready(u64),
    /// Thread `worker` switched state; label names the task type when
    /// entering `ThreadState::Task`.
    State { worker: usize, state: ThreadState, label: &'static str },
    /// Task lifetime markers (id, label) for span reconstruction.
    TaskStart { worker: usize, id: u64, label: &'static str },
    TaskEnd { worker: usize, id: u64 },
    /// A creator pushed a no-deps task onto its *own* ready deque
    /// (`spawn_from`'s fast path — not replay refills, not ingress
    /// drains). Paired with the eventual `TaskStart` by `id`, this is the
    /// raw signal the pathology detector's creator-starvation rule reads:
    /// pushes whose starts land on *another* ring were stolen, and the
    /// push→start gap is the ready-time-in-queue sample.
    ReadyPush { worker: usize, id: u64 },
}

// The rings store events as `MaybeUninit` and free segments without
// running destructors; that is only sound while events own no heap.
const _: () = assert!(!std::mem::needs_drop::<TraceEvent>());

/// Events per ring segment (~160 KiB of events; segments allocate lazily).
const SEG_EVENTS: usize = 4096;

/// Default per-thread ring capacity: 128 segments ≈ 524k events.
const DEFAULT_RING_CAP: usize = SEG_EVENTS * 128;

struct TraceSeg {
    slots: Box<[UnsafeCell<MaybeUninit<TraceEvent>>]>,
}

fn alloc_seg() -> *mut TraceSeg {
    Box::into_raw(Box::new(TraceSeg {
        slots: (0..SEG_EVENTS).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect(),
    }))
}

/// Append-only wait-free trace buffer. **Single writer**: only the thread
/// that owns the slot appends (the same contract as
/// [`SpscQueue::push`](crate::substrate::SpscQueue::push)); any thread may
/// read the published prefix concurrently.
struct TraceRing {
    /// Lazily allocated segments. Stored with release before the length
    /// that publishes their first slot.
    segs: Box<[AtomicPtr<TraceSeg>]>,
    /// Published event count: slots `0..len` are initialized and immutable.
    len: CachePadded<AtomicUsize>,
    /// Single-writer guard. Normally uncontended (only the owning thread
    /// appends); if a second thread ever races in — e.g. an unbound thread
    /// falling back to worker 0's context — its event degrades to a counted
    /// drop instead of an unsynchronized slot write.
    busy: AtomicBool,
    /// Events discarded: ring full, out-of-range slot (release builds), or
    /// a second writer racing the owner.
    dropped: CachePadded<AtomicU64>,
    cap: usize,
}

// SAFETY: the single-writer protocol serializes slot writes; readers only
// touch slots below the release-published `len`. `TraceEvent` is `Send`.
unsafe impl Send for TraceRing {}
unsafe impl Sync for TraceRing {}

impl TraceRing {
    fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        TraceRing {
            segs: (0..cap.div_ceil(SEG_EVENTS))
                .map(|_| AtomicPtr::new(std::ptr::null_mut()))
                .collect(),
            len: CachePadded::new(AtomicUsize::new(0)),
            busy: AtomicBool::new(false),
            dropped: CachePadded::new(AtomicU64::new(0)),
            cap,
        }
    }

    /// Owner append: one uncontended CAS on the guard, the slot write, two
    /// plain stores. Wait-free — the CAS is a single bounded attempt (a
    /// loss means a second writer is misusing the ring; the event is
    /// dropped and counted, never blocked on and never a data race).
    fn push(&self, ev: TraceEvent) {
        if self
            .busy
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            debug_assert!(false, "trace ring has two concurrent writers");
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let n = self.len.load(Ordering::Relaxed);
        if n >= self.cap {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            self.busy.store(false, Ordering::Release);
            return;
        }
        let si = n / SEG_EVENTS;
        let mut seg = self.segs[si].load(Ordering::Relaxed);
        if seg.is_null() {
            seg = alloc_seg();
            // Publication order is carried by the `len` release store
            // below; the pointer store itself needs no ordering, but
            // release keeps it obviously safe for raw-pointer readers.
            self.segs[si].store(seg, Ordering::Release);
        }
        // SAFETY: the `busy` guard serializes writers; slot `n` is
        // unpublished (readers stop at `len`), so this write races with
        // nothing.
        unsafe {
            (*(*seg).slots[n % SEG_EVENTS].get()).write(ev);
        }
        self.len.store(n + 1, Ordering::Release);
        self.busy.store(false, Ordering::Release);
    }

    /// Copy the published prefix into `out` (any thread).
    fn snapshot_into(&self, out: &mut Vec<TraceEvent>) {
        self.snapshot_range(0, out);
    }

    /// Copy published slots `from..len` into `out`, returning the new
    /// published length. Any thread; the acquire load of `len` orders after
    /// the owner's slot writes exactly as in [`snapshot_into`]. Incremental
    /// readers (the pathology detector's ring cursors) call this with their
    /// previous return value so each event is copied once, with no
    /// re-merge of the whole ring.
    fn snapshot_range(&self, from: usize, out: &mut Vec<TraceEvent>) -> usize {
        let n = self.len.load(Ordering::Acquire);
        out.reserve(n.saturating_sub(from));
        let mut i = from.min(n);
        while i < n {
            let si = i / SEG_EVENTS;
            let seg = self.segs[si].load(Ordering::Acquire);
            debug_assert!(!seg.is_null(), "published slot in unallocated segment");
            if seg.is_null() {
                break;
            }
            let upto = ((si + 1) * SEG_EVENTS).min(n);
            while i < upto {
                // SAFETY: `i < len` — the acquire read of `len` orders
                // after the owner's slot write and segment publication.
                out.push(unsafe { (*(*seg).slots[i % SEG_EVENTS].get()).assume_init_ref().clone() });
                i += 1;
            }
        }
        n
    }

    fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

impl Drop for TraceRing {
    fn drop(&mut self) {
        for s in self.segs.iter() {
            let p = s.load(Ordering::Relaxed);
            if !p.is_null() {
                // SAFETY: exclusive access; events need no drop (const
                // assert above), so freeing the segment storage suffices.
                unsafe { drop(Box::from_raw(p)) };
            }
        }
    }
}

/// Incremental read position over a [`Tracer`]'s rings: one published-length
/// watermark per ring. Mint with [`Tracer::cursor`], advance with
/// [`Tracer::read_new`]. Plain data — the tracer's release-published ring
/// lengths carry all the synchronization.
#[derive(Clone, Debug)]
pub struct RingCursor {
    read: Vec<usize>,
}

impl RingCursor {
    /// A cursor over zero rings — reads nothing until replaced by a real
    /// [`Tracer::cursor`] (placeholder for lazily attached consumers).
    pub fn empty() -> Self {
        RingCursor { read: Vec::new() }
    }

    /// Does this cursor track no rings?
    pub fn is_empty(&self) -> bool {
        self.read.is_empty()
    }
}

/// Trace collector. One instance per runtime; cheap enough to keep on for
/// the trace figures, `None`d out for throughput benches. `record` is
/// wait-free (see the module docs); one ring per recording thread.
pub struct Tracer {
    start: Instant,
    rings: Vec<TraceRing>,
    /// Events whose slot was out of range for this tracer's ring count.
    /// A dedicated counter — charging these to `rings[0].dropped` (as the
    /// seed-era code did) panicked on a zero-ring tracer and polluted
    /// ring 0's own overflow accounting otherwise.
    misrouted: AtomicU64,
}

impl Tracer {
    /// A tracer with one ring per recording context and the default
    /// per-ring capacity. `num_threads` must count *every* slot that will
    /// record — workers plus any extra service-thread slots.
    pub fn new(num_threads: usize) -> Self {
        Self::with_capacity(num_threads, DEFAULT_RING_CAP)
    }

    /// [`Tracer::new`] with an explicit per-ring event capacity (tests and
    /// memory-constrained runs; events past capacity are dropped+counted).
    pub fn with_capacity(num_threads: usize, events_per_thread: usize) -> Self {
        Tracer {
            start: Instant::now(),
            rings: (0..num_threads.max(1)).map(|_| TraceRing::new(events_per_thread)).collect(),
            misrouted: AtomicU64::new(0),
        }
    }

    /// Number of per-thread rings (recording slots).
    pub fn num_rings(&self) -> usize {
        self.rings.len()
    }

    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }

    /// Append an event to `worker`'s ring. Must be called by the thread
    /// that owns slot `worker` (single-writer rings). The slot must be in
    /// range — rings are sized by the actual thread count; an out-of-range
    /// slot is counted on the tracer-level `misrouted` counter (folded into
    /// [`dropped`](Tracer::dropped)) instead of silently aliasing another
    /// thread's stream (the seed's `worker % len` merged the DAS manager's
    /// stream into worker 0's) or mischarging ring 0's own overflow
    /// accounting. Counted in every build profile: a misroute is telemetry
    /// about a mis-sized tracer, not a debug-only invariant.
    #[inline]
    pub fn record(&self, worker: usize, kind: TraceKind) {
        let ev = TraceEvent { t_ns: self.now_ns(), kind };
        match self.rings.get(worker) {
            Some(ring) => ring.push(ev),
            None => {
                self.misrouted.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Events discarded across all rings (full ring, a second writer racing
    /// the owner) plus tracer-level misroutes (out-of-range slot).
    pub fn dropped(&self) -> u64 {
        self.rings.iter().map(|r| r.dropped()).sum::<u64>() + self.misrouted()
    }

    /// Events whose slot index had no ring (out-of-range `worker`).
    pub fn misrouted(&self) -> u64 {
        self.misrouted.load(Ordering::Relaxed)
    }

    /// Merge all per-thread buffers, sorted by time.
    pub fn merged(&self) -> Vec<TraceEvent> {
        let mut all: Vec<TraceEvent> = Vec::new();
        for r in &self.rings {
            r.snapshot_into(&mut all);
        }
        all.sort_by_key(|e| e.t_ns);
        all
    }

    /// CSV dump: `t_ns,kind,worker,value,label`.
    pub fn dump_csv(&self) -> String {
        let mut out = String::from("t_ns,kind,worker,value,label\n");
        for e in self.merged() {
            match &e.kind {
                TraceKind::InGraph(v) => out.push_str(&format!("{},in_graph,,{},\n", e.t_ns, v)),
                TraceKind::Ready(v) => out.push_str(&format!("{},ready,,{},\n", e.t_ns, v)),
                TraceKind::State { worker, state, label } => out.push_str(&format!(
                    "{},state,{},{},{}\n",
                    e.t_ns,
                    worker,
                    match state {
                        ThreadState::Idle => 0,
                        ThreadState::Task => 1,
                        ThreadState::Manager => 2,
                    },
                    label
                )),
                TraceKind::TaskStart { worker, id, label } => {
                    out.push_str(&format!("{},task_start,{},{},{}\n", e.t_ns, worker, id, label))
                }
                TraceKind::TaskEnd { worker, id } => {
                    out.push_str(&format!("{},task_end,{},{},\n", e.t_ns, worker, id))
                }
                TraceKind::ReadyPush { worker, id } => {
                    out.push_str(&format!("{},ready_push,{},{},\n", e.t_ns, worker, id))
                }
            }
        }
        out
    }

    /// Export in Paraver `.prv` format — the tool the paper's §6.2 traces
    /// were rendered with. State records (`1:cpu:appl:task:thread:begin:
    /// end:state`) encode Idle/Task/Manager; event records (`2:...:type:
    /// value`) carry the gauges (type 9001 = tasks in graph, 9002 = ready).
    pub fn dump_prv(&self, num_threads: usize) -> String {
        let events = self.merged();
        let end_time = events.last().map_or(0, |e| e.t_ns);
        let mut out = format!(
            "#Paraver (01/01/2026 at 00:00):{end_time}_ns:1(1):1:1({num_threads}:1)\n"
        );
        // Reconstruct per-thread state intervals.
        let mut cur_state: Vec<(u64, u32)> = vec![(0, 0); num_threads]; // (since, state)
        let state_code = |s: &ThreadState| match s {
            ThreadState::Idle => 0u32,
            ThreadState::Task => 1,
            ThreadState::Manager => 3,
        };
        for e in &events {
            match &e.kind {
                TraceKind::State { worker, state, .. } => {
                    let w = *worker % num_threads;
                    let (since, code) = cur_state[w];
                    if e.t_ns > since {
                        out.push_str(&format!(
                            "1:{cpu}:1:1:{thr}:{since}:{end}:{code}\n",
                            cpu = w + 1,
                            thr = w + 1,
                            end = e.t_ns
                        ));
                    }
                    cur_state[w] = (e.t_ns, state_code(state));
                }
                TraceKind::InGraph(v) => {
                    out.push_str(&format!("2:1:1:1:1:{}:9001:{v}\n", e.t_ns));
                }
                TraceKind::Ready(v) => {
                    out.push_str(&format!("2:1:1:1:1:{}:9002:{v}\n", e.t_ns));
                }
                _ => {}
            }
        }
        for (w, (since, code)) in cur_state.iter().enumerate() {
            if end_time > *since {
                out.push_str(&format!(
                    "1:{cpu}:1:1:{thr}:{since}:{end_time}:{code}\n",
                    cpu = w + 1,
                    thr = w + 1
                ));
            }
        }
        out
    }

    /// A fresh incremental cursor positioned at the start of every ring.
    pub fn cursor(&self) -> RingCursor {
        RingCursor { read: vec![0; self.rings.len()] }
    }

    /// Copy events ring `ring` has published since `cur` last visited it
    /// into `out` (appended; `out` is not cleared), advancing the cursor.
    /// Returns the number of events copied. Any thread may call this
    /// concurrently with the owner's appends — it reads only the
    /// release-published prefix. A cursor minted by a *different* tracer's
    /// [`cursor`](Tracer::cursor) (wrong ring count) reads nothing.
    pub fn read_new(&self, cur: &mut RingCursor, ring: usize, out: &mut Vec<TraceEvent>) -> usize {
        let (Some(r), Some(pos)) = (self.rings.get(ring), cur.read.get_mut(ring)) else {
            return 0;
        };
        let before = *pos;
        *pos = r.snapshot_range(before, out);
        *pos - before
    }

    /// Time series of a gauge: (t_ns, value) pairs.
    pub fn gauge_series(&self, in_graph: bool) -> Vec<(u64, u64)> {
        self.merged()
            .into_iter()
            .filter_map(|e| match e.kind {
                TraceKind::InGraph(v) if in_graph => Some((e.t_ns, v)),
                TraceKind::Ready(v) if !in_graph => Some((e.t_ns, v)),
                _ => None,
            })
            .collect()
    }
}

/// The seed's tracer: one `Mutex<Vec>` per thread, a lock round-trip per
/// event, `worker % len` slot aliasing. Retained (not wired into the
/// runtime) as the old side of the `trace_append` contention A/B.
pub struct LockedTracer {
    start: Instant,
    buffers: Vec<Mutex<Vec<TraceEvent>>>,
}

impl LockedTracer {
    pub fn new(num_threads: usize) -> Self {
        LockedTracer {
            start: Instant::now(),
            buffers: (0..num_threads.max(1)).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    #[inline]
    pub fn record(&self, worker: usize, kind: TraceKind) {
        let ev = TraceEvent { t_ns: self.start.elapsed().as_nanos() as u64, kind };
        self.buffers[worker % self.buffers.len()].lock().unwrap().push(ev);
    }

    pub fn merged(&self) -> Vec<TraceEvent> {
        let mut all: Vec<TraceEvent> = Vec::new();
        for b in &self.buffers {
            all.extend(b.lock().unwrap().iter().cloned());
        }
        all.sort_by_key(|e| e.t_ns);
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_merge_in_time_order() {
        let t = Tracer::new(2);
        t.record(0, TraceKind::InGraph(1));
        t.record(1, TraceKind::InGraph(2));
        t.record(0, TraceKind::Ready(1));
        let m = t.merged();
        assert_eq!(m.len(), 3);
        assert!(m.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn csv_has_all_rows() {
        let t = Tracer::new(1);
        t.record(0, TraceKind::TaskStart { worker: 0, id: 7, label: "lu0" });
        t.record(0, TraceKind::TaskEnd { worker: 0, id: 7 });
        t.record(0, TraceKind::State { worker: 0, state: ThreadState::Manager, label: "" });
        let csv = t.dump_csv();
        assert_eq!(csv.lines().count(), 4, "header + 3 events");
        assert!(csv.contains("task_start,0,7,lu0"));
        assert!(csv.contains("state,0,2,"));
    }

    #[test]
    fn prv_export_structure() {
        let t = Tracer::new(2);
        t.record(0, TraceKind::State { worker: 0, state: ThreadState::Task, label: "m" });
        t.record(1, TraceKind::State { worker: 1, state: ThreadState::Manager, label: "" });
        t.record(0, TraceKind::InGraph(3));
        t.record(0, TraceKind::State { worker: 0, state: ThreadState::Idle, label: "" });
        let prv = t.dump_prv(2);
        assert!(prv.starts_with("#Paraver"));
        assert!(prv.contains(":9001:3"), "{prv}");
        // State records exist for both threads.
        assert!(prv.lines().any(|l| l.starts_with("1:1:")));
        assert!(prv.lines().any(|l| l.starts_with("1:2:")));
    }

    #[test]
    fn gauge_series_filters() {
        let t = Tracer::new(1);
        t.record(0, TraceKind::InGraph(5));
        t.record(0, TraceKind::Ready(2));
        t.record(0, TraceKind::InGraph(6));
        assert_eq!(t.gauge_series(true).len(), 2);
        assert_eq!(t.gauge_series(false).len(), 1);
    }

    #[test]
    fn ring_crosses_segments() {
        let t = Tracer::with_capacity(1, SEG_EVENTS * 2 + 10);
        let n = SEG_EVENTS + 17;
        for i in 0..n {
            t.record(0, TraceKind::InGraph(i as u64));
        }
        let m = t.merged();
        assert_eq!(m.len(), n);
        // Append order preserved within a ring (monotonic gauge values).
        let vals: Vec<u64> = m
            .iter()
            .filter_map(|e| match e.kind {
                TraceKind::InGraph(v) => Some(v),
                _ => None,
            })
            .collect();
        assert!(vals.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn overflow_drops_and_counts() {
        let t = Tracer::with_capacity(2, 100);
        for i in 0..150u64 {
            t.record(0, TraceKind::InGraph(i));
        }
        for i in 0..40u64 {
            t.record(1, TraceKind::Ready(i));
        }
        assert_eq!(t.dropped(), 50, "ring 0 dropped the overflow");
        assert_eq!(t.merged().len(), 140);
        assert_eq!(t.gauge_series(true).len(), 100);
        assert_eq!(t.gauge_series(false).len(), 40);
    }

    #[test]
    fn out_of_range_slot_counts_misrouted_not_ring0() {
        // Regression: the out-of-range arm used to charge rings[0].dropped,
        // polluting ring 0's own overflow accounting.
        let t = Tracer::new(1);
        t.record(5, TraceKind::InGraph(1));
        t.record(9, TraceKind::Ready(2));
        assert_eq!(t.misrouted(), 2);
        assert_eq!(t.dropped(), 2, "misroutes fold into dropped()");
        assert_eq!(t.rings[0].dropped(), 0, "ring 0's own counter untouched");
        assert!(t.merged().is_empty());
    }

    #[test]
    fn zero_ring_tracer_counts_misrouted_without_panicking() {
        // Regression: with zero rings, the old arm indexed rings[0] and
        // panicked. Constructors floor at one ring, so build the zero-ring
        // shape directly.
        let t = Tracer { start: Instant::now(), rings: Vec::new(), misrouted: AtomicU64::new(0) };
        t.record(0, TraceKind::InGraph(1));
        assert_eq!(t.misrouted(), 1);
        assert_eq!(t.dropped(), 1);
        assert!(t.merged().is_empty());
    }

    #[test]
    fn cursor_reads_incrementally() {
        let t = Tracer::new(2);
        let mut cur = t.cursor();
        t.record(0, TraceKind::InGraph(1));
        t.record(0, TraceKind::InGraph(2));
        t.record(1, TraceKind::Ready(1));
        let mut out = Vec::new();
        assert_eq!(t.read_new(&mut cur, 0, &mut out), 2);
        assert_eq!(t.read_new(&mut cur, 1, &mut out), 1);
        assert_eq!(out.len(), 3);
        // Nothing new: cursor is caught up.
        assert_eq!(t.read_new(&mut cur, 0, &mut out), 0);
        assert_eq!(t.read_new(&mut cur, 1, &mut out), 0);
        assert_eq!(out.len(), 3);
        // New events appear exactly once, from the watermark on.
        t.record(0, TraceKind::InGraph(3));
        out.clear();
        assert_eq!(t.read_new(&mut cur, 0, &mut out), 1);
        assert!(matches!(out[0].kind, TraceKind::InGraph(3)));
        // Out-of-range ring index reads nothing.
        assert_eq!(t.read_new(&mut cur, 7, &mut out), 0);
    }

    #[test]
    fn cursor_crosses_segment_boundaries() {
        let t = Tracer::with_capacity(1, SEG_EVENTS * 2 + 10);
        let mut cur = t.cursor();
        let mut out = Vec::new();
        // Fill to just short of the boundary, read, then cross it.
        for i in 0..(SEG_EVENTS - 3) {
            t.record(0, TraceKind::InGraph(i as u64));
        }
        assert_eq!(t.read_new(&mut cur, 0, &mut out), SEG_EVENTS - 3);
        for i in 0..20 {
            t.record(0, TraceKind::InGraph((SEG_EVENTS - 3 + i) as u64));
        }
        out.clear();
        assert_eq!(t.read_new(&mut cur, 0, &mut out), 20);
        let vals: Vec<u64> = out
            .iter()
            .filter_map(|e| match e.kind {
                TraceKind::InGraph(v) => Some(v),
                _ => None,
            })
            .collect();
        assert!(vals.windows(2).all(|w| w[0] + 1 == w[1]), "in order across the seam");
        assert_eq!(vals[0], (SEG_EVENTS - 3) as u64);
    }

    #[test]
    fn csv_renders_ready_push() {
        let t = Tracer::new(1);
        t.record(0, TraceKind::ReadyPush { worker: 0, id: 42 });
        assert!(t.dump_csv().contains("ready_push,0,42"));
    }

    #[test]
    fn locked_baseline_matches_merge_behavior() {
        let t = LockedTracer::new(2);
        t.record(0, TraceKind::InGraph(1));
        t.record(1, TraceKind::Ready(2));
        let m = t.merged();
        assert_eq!(m.len(), 2);
        assert!(m.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
    }
}
