//! Dynamic DDAST parameter tuning — the paper's stated future work (§8:
//! "the runtime manager will dynamically tune its parameters to fit the
//! application requirements", citing the feedback-directed approach of
//! [18]).
//!
//! The tuner is itself a Functionality Dispatcher callback (§3.2 envisions
//! exactly this: more runtime services sharing idle threads). Every
//! `interval` of runtime it samples two signals and nudges the *tunable*
//! parameters:
//!
//! * **backlog**: messages pending while ready tasks are scarce → the
//!   managers cannot keep up → raise `MAX_DDAST_THREADS`;
//! * **idle managers**: activations that found little work → shrink
//!   `MAX_DDAST_THREADS` back toward the static tuned value (locality,
//!   §5.1).

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::ddast::DdastParams;
use crate::coordinator::pool::RuntimeShared;
use crate::substrate::Counter;

/// Atomically adjustable DDAST parameters.
#[derive(Debug)]
pub struct TunableParams {
    max_ddast_threads: AtomicUsize,
    max_spins: AtomicU32,
    max_ops_thread: AtomicUsize,
    min_ready_tasks: AtomicU64,
}

impl TunableParams {
    pub fn new(p: DdastParams) -> Self {
        TunableParams {
            max_ddast_threads: AtomicUsize::new(p.max_ddast_threads),
            max_spins: AtomicU32::new(p.max_spins),
            max_ops_thread: AtomicUsize::new(p.max_ops_thread),
            min_ready_tasks: AtomicU64::new(p.min_ready_tasks),
        }
    }

    /// Consistent-enough snapshot for one callback execution.
    pub fn snapshot(&self) -> DdastParams {
        DdastParams {
            max_ddast_threads: self.max_ddast_threads.load(Ordering::Relaxed),
            max_spins: self.max_spins.load(Ordering::Relaxed),
            max_ops_thread: self.max_ops_thread.load(Ordering::Relaxed),
            min_ready_tasks: self.min_ready_tasks.load(Ordering::Relaxed),
        }
    }

    pub fn set_max_ddast_threads(&self, v: usize) {
        self.max_ddast_threads.store(v.max(1), Ordering::Relaxed);
    }

    pub fn set_max_ops_thread(&self, v: usize) {
        self.max_ops_thread.store(v.max(1), Ordering::Relaxed);
    }

    pub fn set_min_ready_tasks(&self, v: u64) {
        self.min_ready_tasks.store(v.max(1), Ordering::Relaxed);
    }
}

/// The feedback controller. Registered with
/// [`AutoTuner::register`]; safe to run from any idle thread.
pub struct AutoTuner {
    rt: Arc<RuntimeShared>,
    /// Static tuned baseline to decay back to.
    baseline: DdastParams,
    /// Adjustment period (wall time).
    interval: std::time::Duration,
    start: Instant,
    /// Last adjustment timestamp (µs since start) — CAS-guarded so only
    /// one idle thread adjusts per period.
    last_adjust_us: AtomicU64,
    // Deltas of the counters at the previous adjustment.
    last_mgr_activations: AtomicU64,
    last_mgr_msgs: AtomicU64,
    /// Number of adjustments performed (diagnostics/tests).
    pub adjustments: Counter,
    pub raises: Counter,
    pub decays: Counter,
}

impl AutoTuner {
    pub fn new(rt: Arc<RuntimeShared>, interval: std::time::Duration) -> Arc<Self> {
        let baseline = DdastParams::tuned(rt.num_threads);
        Arc::new(AutoTuner {
            rt,
            baseline,
            interval,
            start: Instant::now(),
            last_adjust_us: AtomicU64::new(0),
            last_mgr_activations: AtomicU64::new(0),
            last_mgr_msgs: AtomicU64::new(0),
            adjustments: Counter::new(),
            raises: Counter::new(),
            decays: Counter::new(),
        })
    }

    /// Register the tuner in the runtime's Functionality Dispatcher.
    pub fn register(self: &Arc<Self>) {
        let tuner = Arc::clone(self);
        self.rt
            .dispatcher
            .register("autotune", Box::new(move |_worker| tuner.step()));
    }

    /// One controller step. Returns true if parameters were adjusted.
    pub fn step(&self) -> bool {
        let now_us = self.start.elapsed().as_micros() as u64;
        let last = self.last_adjust_us.load(Ordering::Acquire);
        if now_us.saturating_sub(last) < self.interval.as_micros() as u64 {
            return false;
        }
        // One adjuster per period.
        if self
            .last_adjust_us
            .compare_exchange(last, now_us, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return false;
        }
        let tunables = self.rt.tunables();
        let p = tunables.snapshot();
        let backlog = self.rt.queues.pending();
        let ready = self.rt.ready.ready_count();
        let acts = self.rt.stats.mgr_activations.get();
        let msgs = self.rt.stats.mgr_msgs.get();
        let d_acts = acts - self.last_mgr_activations.swap(acts, Ordering::AcqRel);
        let d_msgs = msgs - self.last_mgr_msgs.swap(msgs, Ordering::AcqRel);

        let mut adjusted = false;
        // Signal 1: backlog with starving workers -> more managers.
        if backlog > 4 * self.rt.num_threads as u64 && ready < p.min_ready_tasks {
            let cap = self.rt.num_threads;
            if p.max_ddast_threads < cap {
                tunables.set_max_ddast_threads((p.max_ddast_threads + 1).min(cap));
                self.raises.inc();
                adjusted = true;
            }
        } else if d_acts > 16 && d_msgs / d_acts.max(1) < 2 {
            // Signal 2: managers mostly find nothing -> decay toward the
            // static tuned value (fewer managers = better locality, §5.1).
            if p.max_ddast_threads > self.baseline.max_ddast_threads {
                tunables.set_max_ddast_threads(p.max_ddast_threads - 1);
                self.decays.inc();
                adjusted = true;
            }
        }
        if adjusted {
            self.adjustments.inc();
        }
        adjusted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_roundtrip() {
        let t = TunableParams::new(DdastParams::tuned(64));
        assert_eq!(t.snapshot(), DdastParams::tuned(64));
        t.set_max_ddast_threads(3);
        assert_eq!(t.snapshot().max_ddast_threads, 3);
        t.set_max_ddast_threads(0); // clamped
        assert_eq!(t.snapshot().max_ddast_threads, 1);
        t.set_max_ops_thread(5);
        t.set_min_ready_tasks(9);
        let s = t.snapshot();
        assert_eq!((s.max_ops_thread, s.min_ready_tasks), (5, 9));
    }
}
