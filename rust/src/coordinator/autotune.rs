//! Dynamic DDAST parameter tuning — the paper's stated future work (§8:
//! "the runtime manager will dynamically tune its parameters to fit the
//! application requirements", citing the feedback-directed approach of
//! [18]).
//!
//! The tuner is itself a Functionality Dispatcher callback (§3.2 envisions
//! exactly this: more runtime services sharing idle threads). Every
//! `interval` of runtime it samples two signals and nudges the *tunable*
//! parameters:
//!
//! * **backlog**: messages pending while ready tasks are scarce → the
//!   managers cannot keep up → raise `MAX_DDAST_THREADS`;
//! * **idle managers**: activations that found little work → shrink
//!   `MAX_DDAST_THREADS` back toward the static tuned value (locality,
//!   §5.1);
//! * **queue depth vs batch budget** (`MAX_OPS_THREAD`): a backlog deeper
//!   than one full manager round at the current budget means every claimed
//!   worker leaves messages behind → grow the budget geometrically toward
//!   [`MAX_OPS_THREAD_CAP`], so one shard-acquisition set drains more of
//!   the burst; an idle request plane decays it back toward the tuned
//!   baseline (oversized batches only pay off under backlog, small ones
//!   keep the next burst's first message from waiting behind a deep
//!   drain). The DDAST callback snapshots the live value on entry
//!   (`TunableParams::snapshot`), so every activation drains with the
//!   current budget.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::ddast::DdastParams;
use crate::coordinator::pool::RuntimeShared;
use crate::substrate::Counter;

/// Upper cap the controller may grow `MAX_OPS_THREAD` to: deep enough to
/// amortize one shard-acquisition set over a whole burst, small enough to
/// bound how long a manager stays away from task execution (and how much
/// a reusable `MsgBatch` buffer can grow).
pub const MAX_OPS_THREAD_CAP: usize = 64;

/// Upper cap the starvation controller may grow `MIN_READY_TASKS` to:
/// managers keep uncovering parallelism until this many tasks are ready,
/// which refills a starved creator's neighborhood — but an unbounded value
/// would pin every idle thread in manager mode forever.
pub const MIN_READY_TASKS_CAP: u64 = 64;

/// Atomically adjustable DDAST parameters.
#[derive(Debug)]
pub struct TunableParams {
    max_ddast_threads: AtomicUsize,
    max_spins: AtomicU32,
    max_ops_thread: AtomicUsize,
    min_ready_tasks: AtomicU64,
}

impl TunableParams {
    pub fn new(p: DdastParams) -> Self {
        TunableParams {
            max_ddast_threads: AtomicUsize::new(p.max_ddast_threads),
            max_spins: AtomicU32::new(p.max_spins),
            max_ops_thread: AtomicUsize::new(p.max_ops_thread),
            min_ready_tasks: AtomicU64::new(p.min_ready_tasks),
        }
    }

    /// Consistent-enough snapshot for one callback execution.
    pub fn snapshot(&self) -> DdastParams {
        DdastParams {
            max_ddast_threads: self.max_ddast_threads.load(Ordering::Relaxed),
            max_spins: self.max_spins.load(Ordering::Relaxed),
            max_ops_thread: self.max_ops_thread.load(Ordering::Relaxed),
            min_ready_tasks: self.min_ready_tasks.load(Ordering::Relaxed),
        }
    }

    pub fn set_max_ddast_threads(&self, v: usize) {
        self.max_ddast_threads.store(v.max(1), Ordering::Relaxed);
    }

    pub fn set_max_ops_thread(&self, v: usize) {
        self.max_ops_thread.store(v.max(1), Ordering::Relaxed);
    }

    pub fn set_min_ready_tasks(&self, v: u64) {
        self.min_ready_tasks.store(v.max(1), Ordering::Relaxed);
    }
}

/// The feedback controller. Registered with
/// [`AutoTuner::register`]; safe to run from any idle thread.
pub struct AutoTuner {
    rt: Arc<RuntimeShared>,
    /// Static tuned baseline to decay back to.
    baseline: DdastParams,
    /// Adjustment period (wall time).
    interval: std::time::Duration,
    start: Instant,
    /// Last adjustment timestamp (µs since start) — CAS-guarded so only
    /// one idle thread adjusts per period.
    last_adjust_us: AtomicU64,
    // Deltas of the counters at the previous adjustment.
    last_mgr_activations: AtomicU64,
    last_mgr_msgs: AtomicU64,
    /// `pathology_starvation` gauge at the previous adjustment — the
    /// `MIN_READY_TASKS` controller reacts to its *delta* (the gauge is
    /// sticky; only fresh detections should raise the knob).
    last_starvation: AtomicU64,
    /// Number of adjustments performed (diagnostics/tests).
    pub adjustments: Counter,
    pub raises: Counter,
    pub decays: Counter,
    /// Batch-budget (`MAX_OPS_THREAD`) raises toward [`MAX_OPS_THREAD_CAP`].
    pub budget_raises: Counter,
    /// Batch-budget decays back toward the tuned baseline.
    pub budget_decays: Counter,
    /// `MIN_READY_TASKS` raises toward [`MIN_READY_TASKS_CAP`] (starvation
    /// detected since the last adjustment).
    pub ready_raises: Counter,
    /// `MIN_READY_TASKS` decays back toward the Table-5 baseline (clean
    /// period).
    pub ready_decays: Counter,
}

impl AutoTuner {
    pub fn new(rt: Arc<RuntimeShared>, interval: std::time::Duration) -> Arc<Self> {
        let baseline = DdastParams::tuned(rt.num_threads);
        Arc::new(AutoTuner {
            rt,
            baseline,
            interval,
            start: Instant::now(),
            last_adjust_us: AtomicU64::new(0),
            last_mgr_activations: AtomicU64::new(0),
            last_mgr_msgs: AtomicU64::new(0),
            last_starvation: AtomicU64::new(0),
            adjustments: Counter::new(),
            raises: Counter::new(),
            decays: Counter::new(),
            budget_raises: Counter::new(),
            budget_decays: Counter::new(),
            ready_raises: Counter::new(),
            ready_decays: Counter::new(),
        })
    }

    /// Register the tuner in the runtime's Functionality Dispatcher.
    pub fn register(self: &Arc<Self>) {
        let tuner = Arc::clone(self);
        self.rt
            .dispatcher
            .register("autotune", Box::new(move |_worker| tuner.step()));
    }

    /// One controller step. Returns true if parameters were adjusted.
    pub fn step(&self) -> bool {
        let now_us = self.start.elapsed().as_micros() as u64;
        let last = self.last_adjust_us.load(Ordering::Acquire);
        if now_us.saturating_sub(last) < self.interval.as_micros() as u64 {
            return false;
        }
        // One adjuster per period.
        if self
            .last_adjust_us
            .compare_exchange(last, now_us, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return false;
        }
        let tunables = self.rt.tunables();
        let p = tunables.snapshot();
        let backlog = self.rt.queues.pending();
        let ready = self.rt.ready.ready_count();
        let acts = self.rt.stats.mgr_activations.get();
        let msgs = self.rt.stats.mgr_msgs.get();
        let d_acts = acts - self.last_mgr_activations.swap(acts, Ordering::AcqRel);
        let d_msgs = msgs - self.last_mgr_msgs.swap(msgs, Ordering::AcqRel);

        let mut adjusted = false;
        // Signal 1: backlog with starving workers -> more managers.
        if backlog > 4 * self.rt.num_threads as u64 && ready < p.min_ready_tasks {
            let cap = self.rt.num_threads;
            if p.max_ddast_threads < cap {
                tunables.set_max_ddast_threads((p.max_ddast_threads + 1).min(cap));
                self.raises.inc();
                adjusted = true;
            }
        } else if d_acts > 16 && d_msgs / d_acts.max(1) < 2 {
            // Signal 2: managers mostly find nothing -> decay toward the
            // static tuned value (fewer managers = better locality, §5.1).
            if p.max_ddast_threads > self.baseline.max_ddast_threads {
                tunables.set_max_ddast_threads(p.max_ddast_threads - 1);
                self.decays.inc();
                adjusted = true;
            }
        }
        // Signal 3 (§8 batch budgets, ROADMAP candidate): drive
        // MAX_OPS_THREAD against the observed queue depth. Deeper backlog
        // than one full manager round at the current budget → every
        // claimed worker leaves messages behind and gets re-raised — grow
        // the budget geometrically toward the cap. An idle request plane
        // (no backlog at all) → decay geometrically back to the tuned
        // baseline. The DDAST callback snapshots the live value on entry,
        // so the next activation drains with the adjusted budget.
        if backlog as usize > p.max_ops_thread * self.rt.num_threads {
            if p.max_ops_thread < MAX_OPS_THREAD_CAP {
                tunables.set_max_ops_thread((p.max_ops_thread * 2).min(MAX_OPS_THREAD_CAP));
                self.budget_raises.inc();
                adjusted = true;
            }
        } else if backlog == 0 && p.max_ops_thread > self.baseline.max_ops_thread {
            tunables
                .set_max_ops_thread((p.max_ops_thread / 2).max(self.baseline.max_ops_thread));
            self.budget_decays.inc();
            adjusted = true;
        }
        // Signal 4 (the pathology detector's first consumer — ROADMAP
        // "MIN_READY_TASKS tuned against a starvation gauge"): fresh
        // starvation detections since the last adjustment mean managers
        // exit before the starved creator's neighborhood refills — grow
        // `MIN_READY_TASKS` geometrically toward the cap so they keep
        // uncovering parallelism. A clean period decays it geometrically
        // back to the Table-5 baseline (an inflated exit threshold keeps
        // idle threads in manager mode for no benefit). The gauge is
        // sticky, so the controller diffs it rather than reading it raw.
        let starv = self.rt.stats.pathology_starvation.get();
        let d_starv = starv - self.last_starvation.swap(starv, Ordering::AcqRel);
        if d_starv > 0 {
            if p.min_ready_tasks < MIN_READY_TASKS_CAP {
                tunables.set_min_ready_tasks((p.min_ready_tasks * 2).min(MIN_READY_TASKS_CAP));
                self.ready_raises.inc();
                adjusted = true;
            }
        } else if p.min_ready_tasks > self.baseline.min_ready_tasks {
            tunables
                .set_min_ready_tasks((p.min_ready_tasks / 2).max(self.baseline.min_ready_tasks));
            self.ready_decays.inc();
            adjusted = true;
        }
        if adjusted {
            self.adjustments.inc();
        }
        adjusted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dep::dep_out;
    use crate::coordinator::pool::RuntimeKind;

    /// Push `n` single-dep tasks into the request plane without processing
    /// them (synthetic backlog for the controller).
    fn push_backlog(rt: &Arc<RuntimeShared>, n: u64, base: u64) {
        let root = Arc::clone(&rt.root);
        for i in 0..n {
            rt.spawn_from(0, &root, vec![dep_out(base + i)], "synthetic", Box::new(|| {}));
        }
    }

    #[test]
    fn backlog_grows_budget_to_cap_and_idle_decays_to_baseline() {
        let rt = RuntimeShared::new(RuntimeKind::Ddast, 2, DdastParams::tuned(2), false, 11);
        let tuner = AutoTuner::new(Arc::clone(&rt), std::time::Duration::ZERO);
        // 200 unprocessed messages — far deeper than one manager round at
        // the tuned budget (8 msgs × 2 workers).
        push_backlog(&rt, 200, 1_000);
        assert_eq!(rt.tunables().snapshot().max_ops_thread, 8);
        let mut seen = Vec::new();
        for _ in 0..6 {
            tuner.step();
            seen.push(rt.tunables().snapshot().max_ops_thread);
        }
        assert_eq!(seen, vec![16, 32, 64, 64, 64, 64], "geometric growth, capped");
        assert_eq!(tuner.budget_raises.get(), 3, "no further raises at the cap");
        // Drain the backlog without processing latency: the request plane
        // goes idle and the budget decays back to the tuned baseline.
        let mut n = 0u64;
        {
            let mut g = rt.queues.workers[0].submit.try_acquire().unwrap();
            while g.pop().is_some() {
                n += 1;
            }
        }
        rt.queues.messages_processed(n);
        assert_eq!(rt.queues.pending_exact(), 0);
        let mut seen = Vec::new();
        for _ in 0..5 {
            tuner.step();
            seen.push(rt.tunables().snapshot().max_ops_thread);
        }
        assert_eq!(seen, vec![32, 16, 8, 8, 8], "decay stops at the tuned baseline");
        assert_eq!(tuner.budget_decays.get(), 3);
    }

    /// Regression: the DDAST callback's `drain_batch_with` budget comes
    /// from `TunableParams::snapshot` **per activation** — a mid-run
    /// change must be honored by the next activation's drain.
    #[test]
    fn ddast_callback_honors_live_budget_next_activation() {
        use crate::coordinator::ddast::ddast_callback;
        let params = DdastParams {
            max_ddast_threads: 1,
            max_spins: 1,
            max_ops_thread: 4,
            // One ready task is "enough parallelism": the callback exits
            // after its first claimed-worker batch, so one activation
            // drains exactly one budget's worth.
            min_ready_tasks: 1,
        };
        let rt = RuntimeShared::new(RuntimeKind::Ddast, 1, params, false, 23);
        // 20 independent single-dep tasks: every submit becomes ready.
        push_backlog(&rt, 20, 10_000);
        let drained_by_one_activation = |rt: &Arc<RuntimeShared>| {
            let before = rt.stats.mgr_msgs.get();
            assert!(ddast_callback(rt, 0), "the activation satisfied messages");
            rt.stats.mgr_msgs.get() - before
        };
        assert_eq!(drained_by_one_activation(&rt), 4, "static budget on activation 1");
        // Mid-run change: picked up by the *next* activation's snapshot.
        rt.tunables().set_max_ops_thread(12);
        while rt.ready.get(0).is_some() {} // re-arm the MIN_READY_TASKS exit
        assert_eq!(drained_by_one_activation(&rt), 12, "raised budget applies");
        rt.tunables().set_max_ops_thread(2);
        while rt.ready.get(0).is_some() {}
        assert_eq!(drained_by_one_activation(&rt), 2, "lowered budget applies");
        assert_eq!(rt.queues.pending_exact(), 20 - 4 - 12 - 2);
    }

    /// The pathology plane's feedback edge: fresh `pathology_starvation`
    /// detections grow `MIN_READY_TASKS` geometrically to the cap; clean
    /// adjustment periods decay it back to the Table-5 baseline. The gauge
    /// is sticky, so only *deltas* raise the knob.
    #[test]
    fn starvation_gauge_grows_min_ready_tasks_and_clean_decays() {
        let rt = RuntimeShared::new(RuntimeKind::Ddast, 2, DdastParams::tuned(2), false, 17);
        let tuner = AutoTuner::new(Arc::clone(&rt), std::time::Duration::ZERO);
        assert_eq!(rt.tunables().snapshot().min_ready_tasks, 4, "Table-5 baseline");
        let mut seen = Vec::new();
        for _ in 0..6 {
            rt.stats.pathology_starvation.inc();
            tuner.step();
            seen.push(rt.tunables().snapshot().min_ready_tasks);
        }
        assert_eq!(seen, vec![8, 16, 32, 64, 64, 64], "geometric growth, capped");
        assert_eq!(tuner.ready_raises.get(), 4, "no further raises at the cap");
        // The gauge stays sticky at its high-water mark; no new detections
        // → clean periods → decay to baseline, never below.
        let mut seen = Vec::new();
        for _ in 0..5 {
            tuner.step();
            seen.push(rt.tunables().snapshot().min_ready_tasks);
        }
        assert_eq!(seen, vec![32, 16, 8, 4, 4], "decay stops at the baseline");
        assert_eq!(tuner.ready_decays.get(), 4);
    }

    #[test]
    fn snapshot_roundtrip() {
        let t = TunableParams::new(DdastParams::tuned(64));
        assert_eq!(t.snapshot(), DdastParams::tuned(64));
        t.set_max_ddast_threads(3);
        assert_eq!(t.snapshot().max_ddast_threads, 3);
        t.set_max_ddast_threads(0); // clamped
        assert_eq!(t.snapshot().max_ddast_threads, 1);
        t.set_max_ops_thread(5);
        t.set_min_ready_tasks(9);
        let s = t.snapshot();
        assert_eq!((s.max_ops_thread, s.min_ready_tasks), (5, 9));
    }
}
