//! Ready-task pools: the Distributed Breadth-First (DBF) scheduling policy.
//!
//! §4 of the paper: "The DBF policy uses a queue of ready tasks for each
//! thread with a stealing mechanism". Ready tasks are pushed FIFO to the
//! enqueueing thread's own queue (breadth-first within a thread) and idle
//! threads steal from victims chosen round-robin from a random start.
//!
//! A global gauge of ready tasks is maintained because the DDAST callback's
//! `MIN_READY_TASKS` break condition needs an O(1) read (Listing 2 line 7).

use std::collections::VecDeque;
use std::sync::Arc;

use crate::coordinator::wd::Wd;
use crate::substrate::{Counter, SpinLock, XorShift64};

/// Per-thread ready queues with stealing.
pub struct ReadyPools {
    queues: Vec<SpinLock<VecDeque<Arc<Wd>>>>,
    ready_count: Counter,
    steals: Counter,
    /// Per-thread RNG state for victim selection (index = thread id).
    rngs: Vec<SpinLock<XorShift64>>,
}

impl ReadyPools {
    pub fn new(num_threads: usize, seed: u64) -> Self {
        ReadyPools {
            queues: (0..num_threads).map(|_| SpinLock::new(VecDeque::new())).collect(),
            ready_count: Counter::new(),
            steals: Counter::new(),
            rngs: (0..num_threads)
                .map(|i| SpinLock::new(XorShift64::new(seed ^ (i as u64).wrapping_mul(0xA24BAED4963EE407))))
                .collect(),
        }
    }

    #[inline]
    pub fn num_threads(&self) -> usize {
        self.queues.len()
    }

    /// Global number of ready tasks across all queues.
    #[inline]
    pub fn ready_count(&self) -> u64 {
        self.ready_count.get()
    }

    /// Total successful steals (diagnostics / calibration).
    #[inline]
    pub fn steal_count(&self) -> u64 {
        self.steals.get()
    }

    /// Push a task that just became ready onto `thread`'s queue.
    pub fn push(&self, thread: usize, task: Arc<Wd>) {
        self.queues[thread % self.queues.len()].lock().push_back(task);
        self.ready_count.inc();
    }

    /// Push a batch (used by done-message processing which can release
    /// several successors at once — one lock acquisition).
    pub fn push_batch(&self, thread: usize, tasks: Vec<Arc<Wd>>) {
        if tasks.is_empty() {
            return;
        }
        let n = tasks.len() as u64;
        {
            let mut q = self.queues[thread % self.queues.len()].lock();
            for t in tasks {
                q.push_back(t);
            }
        }
        self.ready_count.add(n);
    }

    /// Get work for `thread`: own queue first (FIFO), then steal.
    pub fn get(&self, thread: usize) -> Option<Arc<Wd>> {
        let me = thread % self.queues.len();
        if let Some(t) = self.queues[me].lock().pop_front() {
            self.ready_count.dec();
            return Some(t);
        }
        self.steal(me)
    }

    /// Try to steal from another thread's queue. Victims are scanned
    /// round-robin from a random start so steals spread out.
    fn steal(&self, me: usize) -> Option<Arc<Wd>> {
        let n = self.queues.len();
        if n <= 1 {
            return None;
        }
        // Fast path: nothing anywhere.
        if self.ready_count.get() == 0 {
            return None;
        }
        let start = self.rngs[me].lock().next_below(n as u64) as usize;
        for k in 0..n {
            let v = (start + k) % n;
            if v == me {
                continue;
            }
            // Steal from the *back* (oldest work stays with the owner's
            // FIFO front; stealing the back grabs the most recently
            // released — deepest — work, the classic DBF choice).
            if let Some(mut q) = self.queues[v].try_lock() {
                if let Some(t) = q.pop_back() {
                    drop(q);
                    self.ready_count.dec();
                    self.steals.inc();
                    return Some(t);
                }
            }
        }
        None
    }

    /// Drain everything (shutdown path / tests).
    pub fn drain_all(&self) -> Vec<Arc<Wd>> {
        let mut out = Vec::new();
        for q in &self.queues {
            let mut q = q.lock();
            while let Some(t) = q.pop_front() {
                self.ready_count.dec();
                out.push(t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wd::TaskId;
    use std::sync::Weak;

    fn mk(id: u64) -> Arc<Wd> {
        Wd::new(TaskId(id), Vec::new(), "t", Weak::new(), Box::new(|| {}))
    }

    #[test]
    fn fifo_within_own_queue() {
        let p = ReadyPools::new(2, 1);
        p.push(0, mk(1));
        p.push(0, mk(2));
        p.push(0, mk(3));
        assert_eq!(p.ready_count(), 3);
        assert_eq!(p.get(0).unwrap().id, TaskId(1));
        assert_eq!(p.get(0).unwrap().id, TaskId(2));
        assert_eq!(p.get(0).unwrap().id, TaskId(3));
        assert_eq!(p.ready_count(), 0);
    }

    #[test]
    fn stealing_when_own_empty() {
        let p = ReadyPools::new(2, 1);
        p.push(0, mk(1));
        let got = p.get(1).expect("thread 1 steals from thread 0");
        assert_eq!(got.id, TaskId(1));
        assert_eq!(p.steal_count(), 1);
    }

    #[test]
    fn steal_takes_back_of_victim() {
        let p = ReadyPools::new(2, 1);
        p.push(0, mk(1));
        p.push(0, mk(2));
        let got = p.get(1).unwrap();
        assert_eq!(got.id, TaskId(2), "steals the newest task");
        let own = p.get(0).unwrap();
        assert_eq!(own.id, TaskId(1), "owner keeps FIFO front");
    }

    #[test]
    fn empty_pools_return_none() {
        let p = ReadyPools::new(4, 1);
        for t in 0..4 {
            assert!(p.get(t).is_none());
        }
    }

    #[test]
    fn batch_push_counts() {
        let p = ReadyPools::new(1, 1);
        p.push_batch(0, vec![mk(1), mk(2), mk(3)]);
        assert_eq!(p.ready_count(), 3);
        p.push_batch(0, vec![]);
        assert_eq!(p.ready_count(), 3);
    }

    #[test]
    fn drain_all_collects_everything() {
        let p = ReadyPools::new(3, 1);
        p.push(0, mk(1));
        p.push(1, mk(2));
        p.push(2, mk(3));
        let drained = p.drain_all();
        assert_eq!(drained.len(), 3);
        assert_eq!(p.ready_count(), 0);
    }

    #[test]
    fn single_thread_pool_never_steals() {
        let p = ReadyPools::new(1, 1);
        p.push(0, mk(1));
        assert!(p.get(0).is_some());
        assert_eq!(p.steal_count(), 0);
    }
}
