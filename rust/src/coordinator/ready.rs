//! Ready-task pools: the Distributed Breadth-First (DBF) scheduling policy.
//!
//! §4 of the paper: "The DBF policy uses a queue of ready tasks for each
//! thread with a stealing mechanism". Ready tasks are pushed FIFO to the
//! enqueueing thread's own queue (breadth-first within a thread) and idle
//! threads steal the most recently released (deepest) task from victims
//! chosen round-robin from a random start.
//!
//! A global gauge of ready tasks is maintained because the DDAST callback's
//! `MIN_READY_TASKS` break condition needs an O(1) read (Listing 2 line 7).
//!
//! ## Lock-free hot paths (EXPERIMENTS.md §Lock-free hot paths)
//!
//! The seed kept each pool in a `SpinLock<VecDeque>` and the gauge in one
//! global atomic: every push/pop/steal was a lock round-trip plus a shared
//! RMW, so at 4+ threads the pools measured our own artificial contention.
//! Now each per-thread pool is a [`WsDeque`]: the owner's FIFO pop is a
//! single CAS on the front, pushes are an uncontended token CAS on the
//! back, thieves take the back under the same token (contending only with
//! that one victim's pushes), and the gauge is a [`ShardedCounter`] of
//! per-thread padded cells. Victim selection keeps its per-slot xorshift
//! state in a padded atomic cell — a relaxed load + store, no RMW.
//!
//! The GOMP-like comparator intentionally keeps the seed's single locked
//! queue (`ReadyPools::new_central`) — it *models* a centralized contended
//! runtime, so de-contending it would destroy the baseline. The seed's
//! locked per-thread implementation survives as [`LockedReadyPools`] for
//! the old-vs-new A/B in `micro_structures`/`BENCH_contention.json`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::wd::Wd;
use crate::substrate::{
    CachePadded, Counter, ShardedCounter, SpinLock, Topology, WsDeque, XorShift64,
};

/// Aggregate contention statistics of a ready-pool implementation, in the
/// `SpinLock::stats` vocabulary plus the lock-free CAS proxy. Fuel for
/// `sim::calibrate` and the A/B bench.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolContention {
    /// Lock/token acquisitions across all queues.
    pub acquisitions: u64,
    /// Acquisitions that had to spin at least once.
    pub contended: u64,
    /// Total spin iterations.
    pub spin_iters: u64,
    /// Front-CAS attempts (lock-free path only; 0 for locked pools).
    pub cas_attempts: u64,
    /// Front-CAS lost races (the lock-free contention proxy).
    pub cas_retries: u64,
}

impl PoolContention {
    /// Contended events under either regime: spins on a lock/token, or lost
    /// CAS races. The A/B acceptance metric compares these.
    pub fn contended_events(&self) -> u64 {
        self.contended + self.cas_retries
    }
}

enum PoolQueues {
    /// One work-stealing deque per thread (Sync / DDAST / CentralDast).
    PerThread(Vec<CachePadded<WsDeque<Arc<Wd>>>>),
    /// The GOMP-like comparator's single central locked queue.
    Central(SpinLock<VecDeque<Arc<Wd>>>),
}

/// Per-thread ready queues with stealing.
pub struct ReadyPools {
    queues: PoolQueues,
    ready_count: ShardedCounter,
    steals: Counter,
    /// Steals whose victim shared the thief's socket (telemetry for the
    /// topology A/B: ≥ 90% of steals should be local when local work
    /// exists).
    local_steals: Counter,
    /// Steals that crossed a socket boundary.
    remote_steals: Counter,
    /// Socket shape steering victim order: same-socket victims are tried
    /// for a full round before any remote deque is touched.
    topo: Topology,
    /// Per-slot xorshift state for victim selection (index = thread id).
    /// Only the slot's bound thread draws from it, so a relaxed
    /// load+store suffices; the atomic keeps the API safe if two threads
    /// ever share a slot (they'd draw correlated victims, nothing worse).
    rngs: Vec<CachePadded<AtomicU64>>,
}

impl ReadyPools {
    pub fn new(num_threads: usize, seed: u64) -> Self {
        Self::new_with_topology(num_threads, seed, Topology::flat(num_threads))
    }

    /// Like [`ReadyPools::new`], but victim selection follows `topo`:
    /// thieves scan their own socket's deques (random start, full round)
    /// before touching a remote socket — each remote socket then gets its
    /// own random-start round, nearest-rotation order. A flat topology
    /// reproduces the old uniform-random behaviour exactly.
    pub fn new_with_topology(num_threads: usize, seed: u64, topo: Topology) -> Self {
        ReadyPools {
            queues: PoolQueues::PerThread(
                (0..num_threads).map(|_| CachePadded::new(WsDeque::new())).collect(),
            ),
            // +2: the CentralDast DAS slot and stray non-pool threads
            // (tests, the main thread before install) also touch the gauge.
            // External submitters get their own shard allowance on top: the
            // serve plane's no-deps fast path bumps this gauge from outside
            // the pool, and must not fold onto a pool thread's shard (same
            // sizing fix as the message plane's pending gauge).
            ready_count: ShardedCounter::with_shards(
                num_threads + 2 + crate::coordinator::messages::EXTERNAL_SHARD_ALLOWANCE,
            ),
            steals: Counter::new(),
            local_steals: Counter::new(),
            remote_steals: Counter::new(),
            topo: topo.cover(num_threads.max(1)),
            rngs: Self::make_rngs(num_threads, seed),
        }
    }

    /// Single central locked queue — the GOMP-like comparator's
    /// organization (all threads contend on one lock; `num_threads() == 1`).
    pub fn new_central(seed: u64) -> Self {
        ReadyPools {
            queues: PoolQueues::Central(SpinLock::new(VecDeque::new())),
            ready_count: ShardedCounter::new(),
            steals: Counter::new(),
            local_steals: Counter::new(),
            remote_steals: Counter::new(),
            topo: Topology::flat(1),
            rngs: Self::make_rngs(1, seed),
        }
    }

    fn make_rngs(n: usize, seed: u64) -> Vec<CachePadded<AtomicU64>> {
        (0..n)
            .map(|i| {
                let s = XorShift64::new(seed ^ (i as u64).wrapping_mul(0xA24BAED4963EE407));
                CachePadded::new(AtomicU64::new(s.state()))
            })
            .collect()
    }

    #[inline]
    pub fn num_threads(&self) -> usize {
        match &self.queues {
            PoolQueues::PerThread(qs) => qs.len(),
            PoolQueues::Central(_) => 1,
        }
    }

    /// Global number of ready tasks across all queues (relaxed gauge read).
    #[inline]
    pub fn ready_count(&self) -> u64 {
        self.ready_count.get()
    }

    /// Exact-read fallback for decisions that must not act on a torn sweep
    /// (quiescence, the DDAST callback's break conditions).
    #[inline]
    pub fn ready_count_exact(&self) -> u64 {
        self.ready_count.exact()
    }

    /// Total successful steals (diagnostics / calibration).
    #[inline]
    pub fn steal_count(&self) -> u64 {
        self.steals.get()
    }

    /// (same-socket steals, cross-socket steals) — the topology A/B's
    /// locality metric. Sums to [`steal_count`](ReadyPools::steal_count).
    #[inline]
    pub fn steal_locality(&self) -> (u64, u64) {
        (self.local_steals.get(), self.remote_steals.get())
    }

    /// Push a task that just became ready onto `thread`'s queue.
    pub fn push(&self, thread: usize, task: Arc<Wd>) {
        match &self.queues {
            PoolQueues::PerThread(qs) => qs[thread % qs.len()].push(task),
            PoolQueues::Central(q) => q.lock().push_back(task),
        }
        self.ready_count.inc();
    }

    /// Push a batch (used by done-message processing which can release
    /// several successors at once). On the deque path each push is an
    /// uncontended token CAS — no global lock to batch under; the gauge is
    /// still bumped once.
    pub fn push_batch(&self, thread: usize, mut tasks: Vec<Arc<Wd>>) {
        self.push_drain(thread, &mut tasks);
    }

    /// Like [`push_batch`](ReadyPools::push_batch), but *drains* a
    /// caller-owned buffer, keeping its capacity — the batch path's
    /// allocation-free variant (the buffer lives in `MsgBatch` and is
    /// reused across drains).
    pub fn push_drain(&self, thread: usize, tasks: &mut Vec<Arc<Wd>>) {
        if tasks.is_empty() {
            return;
        }
        let n = tasks.len() as u64;
        match &self.queues {
            PoolQueues::PerThread(qs) => {
                let q = &qs[thread % qs.len()];
                for t in tasks.drain(..) {
                    q.push(t);
                }
            }
            PoolQueues::Central(q) => {
                let mut q = q.lock();
                for t in tasks.drain(..) {
                    q.push_back(t);
                }
            }
        }
        self.ready_count.add(n);
    }

    /// Get work for `thread`: own queue first (FIFO), then steal.
    pub fn get(&self, thread: usize) -> Option<Arc<Wd>> {
        match &self.queues {
            PoolQueues::PerThread(qs) => {
                let me = thread % qs.len();
                if let Some(t) = qs[me].pop_front() {
                    self.ready_count.dec();
                    return Some(t);
                }
                self.steal(qs, me)
            }
            PoolQueues::Central(q) => {
                let t = q.lock().pop_front();
                if t.is_some() {
                    self.ready_count.dec();
                }
                t
            }
        }
    }

    /// Try to steal from another thread's queue. Victims are scanned in
    /// topology order: one full round over the thief's own socket (random
    /// start, so same-socket steals spread out), then the remote sockets
    /// in nearest-rotation order, each with its own random-start round —
    /// a remote cache line is only touched after the local socket came up
    /// dry. Under a flat topology the local round covers every deque and
    /// this degenerates to the old uniform-random scan.
    fn steal(&self, qs: &[CachePadded<WsDeque<Arc<Wd>>>], me: usize) -> Option<Arc<Wd>> {
        let n = qs.len();
        if n <= 1 {
            return None;
        }
        // Fast path: nothing anywhere.
        if self.ready_count.get() == 0 {
            return None;
        }
        let rng = &self.rngs[me];
        let (state, draw) = XorShift64::step(rng.load(Ordering::Relaxed));
        rng.store(state, Ordering::Relaxed);
        let my_socket = self.topo.socket_of(me);
        let sockets = self.topo.sockets();
        for s in 0..sockets {
            let sock = (my_socket + s) % sockets;
            let range = self.topo.socket_range(sock, n);
            let span = range.len();
            if span == 0 {
                continue;
            }
            // Random start within the socket (one draw steers every
            // round; the per-socket spans make the offsets independent
            // enough, and determinism per draw keeps the sim replayable).
            let start = ((draw as u128 * span as u128) >> 64) as usize;
            for k in 0..span {
                let v = range.start + (start + k) % span;
                if v == me {
                    continue;
                }
                // Steal from the *back* (oldest work stays with the
                // owner's FIFO front; stealing the back grabs the most
                // recently released — deepest — work, the classic DBF
                // choice).
                if let Some(t) = qs[v].steal_back() {
                    self.ready_count.dec();
                    self.steals.inc();
                    if s == 0 {
                        self.local_steals.inc();
                    } else {
                        self.remote_steals.inc();
                    }
                    return Some(t);
                }
            }
        }
        None
    }

    /// Drain everything (shutdown path / tests).
    pub fn drain_all(&self) -> Vec<Arc<Wd>> {
        let mut out = Vec::new();
        match &self.queues {
            PoolQueues::PerThread(qs) => {
                for q in qs {
                    while let Some(t) = q.pop_front() {
                        self.ready_count.dec();
                        out.push(t);
                    }
                }
            }
            PoolQueues::Central(q) => {
                let mut q = q.lock();
                while let Some(t) = q.pop_front() {
                    self.ready_count.dec();
                    out.push(t);
                }
            }
        }
        out
    }

    /// Aggregate contention statistics across all queues.
    pub fn contention_stats(&self) -> PoolContention {
        let mut s = PoolContention::default();
        match &self.queues {
            PoolQueues::PerThread(qs) => {
                for q in qs {
                    let (acq, cont, spins) = q.token_stats();
                    let (attempts, retries) = q.cas_stats();
                    s.acquisitions += acq;
                    s.contended += cont;
                    s.spin_iters += spins;
                    s.cas_attempts += attempts;
                    s.cas_retries += retries;
                }
            }
            PoolQueues::Central(q) => {
                let (acq, cont, spins) = q.stats();
                s.acquisitions = acq;
                s.contended = cont;
                s.spin_iters = spins;
            }
        }
        s
    }
}

// ---------------------------------------------------------------------------
// LockedReadyPools — the seed implementation, kept as the A/B baseline
// ---------------------------------------------------------------------------

/// The seed's locked per-thread pools (one `SpinLock<VecDeque>` per thread,
/// one global gauge atomic, `SpinLock<XorShift64>` victim RNG). Not used by
/// the runtime anymore; `micro_structures` drives it head-to-head against
/// [`ReadyPools`] to *measure* the contention the lock-free rewrite removed
/// rather than assert it (BENCH_contention.json).
pub struct LockedReadyPools {
    queues: Vec<SpinLock<VecDeque<Arc<Wd>>>>,
    ready_count: Counter,
    steals: Counter,
    rngs: Vec<SpinLock<XorShift64>>,
}

impl LockedReadyPools {
    pub fn new(num_threads: usize, seed: u64) -> Self {
        LockedReadyPools {
            queues: (0..num_threads).map(|_| SpinLock::new(VecDeque::new())).collect(),
            ready_count: Counter::new(),
            steals: Counter::new(),
            rngs: (0..num_threads)
                .map(|i| {
                    SpinLock::new(XorShift64::new(
                        seed ^ (i as u64).wrapping_mul(0xA24BAED4963EE407),
                    ))
                })
                .collect(),
        }
    }

    pub fn ready_count(&self) -> u64 {
        self.ready_count.get()
    }

    pub fn push(&self, thread: usize, task: Arc<Wd>) {
        self.queues[thread % self.queues.len()].lock().push_back(task);
        self.ready_count.inc();
    }

    pub fn get(&self, thread: usize) -> Option<Arc<Wd>> {
        let me = thread % self.queues.len();
        if let Some(t) = self.queues[me].lock().pop_front() {
            self.ready_count.dec();
            return Some(t);
        }
        self.steal(me)
    }

    fn steal(&self, me: usize) -> Option<Arc<Wd>> {
        let n = self.queues.len();
        if n <= 1 {
            return None;
        }
        if self.ready_count.get() == 0 {
            return None;
        }
        let start = self.rngs[me].lock().next_below(n as u64) as usize;
        for k in 0..n {
            let v = (start + k) % n;
            if v == me {
                continue;
            }
            if let Some(mut q) = self.queues[v].try_lock() {
                if let Some(t) = q.pop_back() {
                    drop(q);
                    self.ready_count.dec();
                    self.steals.inc();
                    return Some(t);
                }
            }
        }
        None
    }

    /// Aggregate lock statistics (queue locks + RNG locks), A/B-comparable
    /// with [`ReadyPools::contention_stats`].
    pub fn contention_stats(&self) -> PoolContention {
        let mut s = PoolContention::default();
        for q in self.queues.iter().map(SpinLock::stats).chain(self.rngs.iter().map(SpinLock::stats))
        {
            s.acquisitions += q.0;
            s.contended += q.1;
            s.spin_iters += q.2;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wd::TaskId;
    use std::sync::Weak;

    fn mk(id: u64) -> Arc<Wd> {
        Wd::new(TaskId(id), Vec::new(), "t", Weak::new(), Box::new(|| {}))
    }

    #[test]
    fn fifo_within_own_queue() {
        let p = ReadyPools::new(2, 1);
        p.push(0, mk(1));
        p.push(0, mk(2));
        p.push(0, mk(3));
        assert_eq!(p.ready_count(), 3);
        assert_eq!(p.get(0).unwrap().id, TaskId(1));
        assert_eq!(p.get(0).unwrap().id, TaskId(2));
        assert_eq!(p.get(0).unwrap().id, TaskId(3));
        assert_eq!(p.ready_count(), 0);
    }

    #[test]
    fn stealing_when_own_empty() {
        let p = ReadyPools::new(2, 1);
        p.push(0, mk(1));
        let got = p.get(1).expect("thread 1 steals from thread 0");
        assert_eq!(got.id, TaskId(1));
        assert_eq!(p.steal_count(), 1);
    }

    #[test]
    fn steal_takes_back_of_victim() {
        let p = ReadyPools::new(2, 1);
        p.push(0, mk(1));
        p.push(0, mk(2));
        let got = p.get(1).unwrap();
        assert_eq!(got.id, TaskId(2), "steals the newest task");
        let own = p.get(0).unwrap();
        assert_eq!(own.id, TaskId(1), "owner keeps FIFO front");
    }

    #[test]
    fn empty_pools_return_none() {
        let p = ReadyPools::new(4, 1);
        for t in 0..4 {
            assert!(p.get(t).is_none());
        }
    }

    #[test]
    fn batch_push_counts() {
        let p = ReadyPools::new(1, 1);
        p.push_batch(0, vec![mk(1), mk(2), mk(3)]);
        assert_eq!(p.ready_count(), 3);
        p.push_batch(0, vec![]);
        assert_eq!(p.ready_count(), 3);
    }

    #[test]
    fn drain_all_collects_everything() {
        let p = ReadyPools::new(3, 1);
        p.push(0, mk(1));
        p.push(1, mk(2));
        p.push(2, mk(3));
        let drained = p.drain_all();
        assert_eq!(drained.len(), 3);
        assert_eq!(p.ready_count(), 0);
    }

    #[test]
    fn single_thread_pool_never_steals() {
        let p = ReadyPools::new(1, 1);
        p.push(0, mk(1));
        assert!(p.get(0).is_some());
        assert_eq!(p.steal_count(), 0);
    }

    #[test]
    fn central_pool_is_one_fifo_queue() {
        let p = ReadyPools::new_central(1);
        assert_eq!(p.num_threads(), 1);
        p.push(0, mk(1));
        p.push(3, mk(2)); // any thread id folds onto the single queue
        assert_eq!(p.ready_count(), 2);
        assert_eq!(p.get(2).unwrap().id, TaskId(1), "FIFO across all pushers");
        assert_eq!(p.get(0).unwrap().id, TaskId(2));
        assert_eq!(p.steal_count(), 0, "nothing to steal from");
        let stats = p.contention_stats();
        assert!(stats.acquisitions >= 4, "central path goes through the lock");
    }

    #[test]
    fn contention_stats_aggregate_per_thread_queues() {
        let p = ReadyPools::new(2, 1);
        p.push(0, mk(1));
        p.push(1, mk(2));
        let _ = p.get(0);
        let _ = p.get(1);
        let s = p.contention_stats();
        assert_eq!(s.acquisitions, 2, "two back ops (pushes)");
        assert_eq!(s.cas_attempts, 2, "two front pops");
        assert_eq!(s.contended_events(), 0, "single-threaded use never contends");
    }

    /// Satellite stress: 1 owner releasing tasks vs N thieves; every task
    /// runs exactly once and the sharded gauge settles to zero.
    #[test]
    fn stress_owner_vs_stealers_no_loss_no_dup() {
        use std::collections::HashSet;
        use std::sync::atomic::AtomicBool;
        const TASKS: u64 = 10_000;
        const THIEVES: usize = 3;
        let p = Arc::new(ReadyPools::new(THIEVES + 1, 42));
        let done = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for th in 0..THIEVES {
            let p = Arc::clone(&p);
            let done = Arc::clone(&done);
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    // Thief slot th+1: own queue always empty -> steals.
                    match p.get(th + 1) {
                        Some(t) => got.push(t.id.0),
                        None => {
                            if done.load(Ordering::Acquire) && p.ready_count_exact() == 0 {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                got
            }));
        }
        let mut got = Vec::new();
        for i in 0..TASKS {
            p.push(0, mk(i + 1));
            if i % 4 == 0 {
                if let Some(t) = p.get(0) {
                    got.push(t.id.0);
                }
            }
        }
        done.store(true, Ordering::Release);
        for h in handles {
            got.extend(h.join().unwrap());
        }
        got.extend(p.drain_all().into_iter().map(|t| t.id.0));
        assert_eq!(got.len() as u64, TASKS, "no task lost or duplicated");
        let set: HashSet<u64> = got.iter().copied().collect();
        assert_eq!(set.len() as u64, TASKS);
        assert_eq!(p.ready_count_exact(), 0, "sharded gauge settles");
    }

    #[test]
    fn topology_steal_prefers_local_socket() {
        // 2 sockets × 2 threads. Thread 1's steals must drain its local
        // victim (thread 0) before ever touching the remote socket, even
        // though the remote deque holds work the whole time.
        let p = ReadyPools::new_with_topology(4, 7, Topology::new(2, 2));
        for i in 0..20u64 {
            p.push(0, mk(i * 2 + 1)); // local victim for thread 1
            p.push(2, mk(i * 2 + 2)); // remote socket's work
            let got = p.get(1).expect("local steal");
            assert_eq!(got.id.0 % 2, 1, "stole the local task, got {}", got.id.0);
        }
        let (local, remote) = p.steal_locality();
        assert_eq!((local, remote), (20, 0), "all steals resolved same-socket");
        // Local socket dry: remote work is still reachable (no starvation).
        let got = p.get(1).expect("remote fallback");
        assert_eq!(got.id.0 % 2, 0);
        let (_, remote) = p.steal_locality();
        assert_eq!(remote, 1);
        assert_eq!(p.steal_count(), 21);
    }

    #[test]
    fn locked_pools_match_semantics() {
        // The A/B baseline behaves like the seed: FIFO own queue,
        // newest-first steal.
        let p = LockedReadyPools::new(2, 1);
        p.push(0, mk(1));
        p.push(0, mk(2));
        assert_eq!(p.get(1).unwrap().id, TaskId(2));
        assert_eq!(p.get(0).unwrap().id, TaskId(1));
        assert_eq!(p.ready_count(), 0);
        assert!(p.contention_stats().acquisitions > 0);
    }
}
