//! L3 coordinator — the paper's system contribution.
//!
//! An OmpSs/Nanos++-style task runtime with three interchangeable
//! organizations (`RuntimeKind`): the synchronous baseline, the paper's
//! asynchronous DDAST organization, and a GOMP-like comparator. See the
//! crate docs and DESIGN.md for the module map.

pub mod api;
pub mod autotune;
pub mod ddast;
pub mod dep;
pub mod depgraph;
pub mod dispatcher;
pub mod messages;
pub mod pathology;
pub mod pool;
pub mod ready;
pub mod replay;
pub mod trace;
pub mod wd;

pub use api::{GraphDomain, TaskSystem, TaskSystemBuilder};
pub use autotune::{AutoTuner, TunableParams, MAX_OPS_THREAD_CAP, MIN_READY_TASKS_CAP};
pub use ddast::DdastParams;
pub use dep::{dep_in, dep_inout, dep_out, DepMode, Dependence};
pub use depgraph::DepDomain;
pub use dispatcher::{Dispatcher, LockedDispatcher};
pub use messages::{MsgBatch, QueueSystem};
pub use pathology::{PathologyConfig, PathologyDetector};
pub use pool::{RuntimeKind, RuntimeShared, SubmitError, TaskErrors};
pub use ready::{LockedReadyPools, PoolContention, ReadyPools};
pub use replay::{GraphRecording, ReplayOutcome, ReplayTask};
pub use trace::{LockedTracer, RingCursor, ThreadState, TraceEvent, TraceKind, Tracer};
pub use wd::{TaskId, Wd, WdState};
