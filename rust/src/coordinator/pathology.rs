//! Online pathology detection over the trace rings — the ROADMAP item that
//! turns the tracer from an offline CSV artifact into the runtime's live
//! feedback plane.
//!
//! *Detrimental task execution patterns in mainstream OpenMP runtimes*
//! (Tuft et al., PAPERS.md) catalogues the misbehaviors this module flags;
//! our wait-free trace rings already record the raw events, so detection is
//! a **streaming** pass: the detector keeps a [`RingCursor`] per ring and,
//! on the runtime's existing idle moments (the same hook points as the
//! PR-6 hang watchdog — `commit_park` timeouts, `ddast_callback`
//! empty-handed exits, the DAS loop's idle tier), folds only the events
//! published since its last visit into cheap per-ring window statistics.
//! No post-hoc CSV pass, no re-merge, no timers of its own.
//!
//! ## The three patterns
//!
//! * **Idle-spin at sync points** — park/taskwait commits dominate a window
//!   while the request plane still holds pending messages: threads burn
//!   their idle ladder at a sync point instead of becoming managers.
//! * **Serialized drains** — one manager context owns nearly every
//!   drained-manager exit in a window while several others exit
//!   empty-handed: the distributed manager has collapsed to a de-facto
//!   central one.
//! * **Creator starvation** — a spawning worker's ready-deque pushes are
//!   stolen faster than it can pop them: its own `TaskStart`s stay rare
//!   while its pushes' starts land on other rings. The push→start gap is
//!   recorded into a log2 [`Histogram`] (ready-time-in-queue), so the
//!   quantiles are available next to the flag.
//!
//! ## Surfacing and feedback
//!
//! Detections increment **sticky** `RtStats` gauges
//! (`pathology_idle_spin` / `pathology_serialized_drain` /
//! `pathology_starvation`; `pathology_windows` counts evaluated windows) —
//! cumulative like every other failure-plane gauge. The `AutoTuner`
//! consumes the starvation gauge as its fourth signal: deltas grow
//! `MIN_READY_TASKS` (managers keep uncovering parallelism before exiting,
//! so the starved creator's deque refills locally), clean periods decay it
//! back to the Table-5 baseline — snapshot through `TunableParams` exactly
//! like the `MAX_OPS_THREAD` controller.
//!
//! ## Cost discipline
//!
//! With the detector disarmed (the default) the runtime's hot paths gain
//! **zero** atomics: every detector input is either a trace event that is
//! only recorded when the tracer is on, or a counter the runtime already
//! maintained. Armed, the scan itself runs only on idle paths behind a
//! `try_lock` (one scanner at a time, contenders skip), and each event is
//! copied exactly once via the ring cursors. The `pathology_ab` drill in
//! `bench_harness::contention` asserts the disarmed half by counter delta.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::coordinator::pool::RuntimeShared;
use crate::coordinator::trace::{RingCursor, ThreadState, TraceEvent, TraceKind};
use crate::substrate::Histogram;

/// `State` label recorded when a thread commits a park (worker loop or
/// `taskwait_on` — the sync-point idling the idle-spin rule counts).
pub const LABEL_PARK: &str = "park";
/// `State` label of a manager exit that satisfied at least one message.
pub const LABEL_MGR_DRAINED: &str = "mgr_drained";
/// `State` label of a manager exit that found nothing to drain.
pub const LABEL_MGR_EMPTY: &str = "mgr_empty";

/// Detection thresholds. The defaults are deliberately conservative — a
/// healthy workload suite must pin every gauge at zero
/// (`rust/tests/pathology.rs`) — and every rule additionally requires
/// [`streak_windows`](PathologyConfig::streak_windows) *consecutive*
/// pathological windows before the sticky gauge moves, so a single odd
/// scheduling quantum never trips a flag.
#[derive(Clone, Copy, Debug)]
pub struct PathologyConfig {
    /// Events accumulated (across all rings) before a window is evaluated.
    pub window_events: usize,
    /// Consecutive pathological windows required before a gauge increments
    /// (the first `streak_windows - 1` detections arm, the next fires).
    pub streak_windows: u32,
    /// Idle-spin: park events must be at least this share of the window,
    /// expressed as a percentage, while messages are pending.
    pub idle_spin_park_pct: usize,
    /// Serialized drain: minimum drained-manager exits the dominant ring
    /// must own for the window to be judged at all.
    pub drain_min_drained: usize,
    /// Serialized drain: the dominant ring's share of all drained exits,
    /// as a percentage.
    pub drain_dominance_pct: usize,
    /// Serialized drain: how many *other* rings must have exited
    /// empty-handed at least [`drain_min_empty`](Self::drain_min_empty)
    /// times.
    pub drain_empty_rings: usize,
    /// Serialized drain: empty exits per such ring.
    pub drain_min_empty: usize,
    /// Starvation: minimum ready pushes a ring must make in the window.
    pub starvation_min_pushes: usize,
    /// Starvation: percentage of the ring's pushes that were stolen
    /// (started on another ring).
    pub starvation_stolen_pct: usize,
    /// Starvation: the creator's own starts, as a max percentage of its
    /// pushes (it pops far less than it feeds).
    pub starvation_self_start_pct: usize,
}

impl Default for PathologyConfig {
    fn default() -> Self {
        PathologyConfig {
            window_events: 256,
            streak_windows: 2,
            idle_spin_park_pct: 50,
            drain_min_drained: 8,
            drain_dominance_pct: 90,
            drain_empty_rings: 2,
            drain_min_empty: 4,
            starvation_min_pushes: 16,
            starvation_stolen_pct: 50,
            starvation_self_start_pct: 25,
        }
    }
}

impl PathologyConfig {
    /// Default thresholds over a custom window size (tests stage small,
    /// exact windows).
    pub fn with_window(window_events: usize) -> Self {
        PathologyConfig { window_events: window_events.max(1), ..Default::default() }
    }
}

/// Per-ring accumulators of the current window.
#[derive(Clone, Default, Debug)]
struct RingWindow {
    /// Park commits (State/Idle with [`LABEL_PARK`]).
    parks: usize,
    /// Manager exits that drained ≥ 1 message ([`LABEL_MGR_DRAINED`]).
    mgr_drained: usize,
    /// Manager exits that found nothing ([`LABEL_MGR_EMPTY`]).
    mgr_empty: usize,
    /// Own-deque ready pushes ([`TraceKind::ReadyPush`]).
    pushes: usize,
    /// Task starts executed on this ring.
    starts: usize,
    /// Pushes made *by* this ring whose start landed on another ring.
    stolen: usize,
}

/// Cursor + window state, serialized behind the detector's `try_lock`.
struct ScanState {
    cursor: RingCursor,
    /// Scratch buffer reused across scans (no steady-state allocation).
    buf: Vec<TraceEvent>,
    rings: Vec<RingWindow>,
    /// Events folded into the current window so far.
    events_in_window: usize,
    /// Pending push id → (pushing ring, push time): joined against the
    /// matching `TaskStart` for steal attribution and queue-residence time.
    /// Survives window boundaries (a push may start one window later);
    /// pruned wholesale if it ever balloons (tasks that never start).
    push_times: HashMap<u64, (usize, u64)>,
    /// Consecutive pathological windows per rule (idle-spin, serialized
    /// drain, starvation).
    streaks: [u32; 3],
}

/// Bound on the pending-push join map: far above any healthy in-flight
/// ready set; crossing it means pushes whose tasks never start (e.g. a
/// drill staging pushes only) — drop the joins rather than grow forever.
const PUSH_MAP_PRUNE: usize = 8192;

/// The streaming detector. One per runtime, armed explicitly
/// ([`RuntimeShared::arm_pathology`] / the builder's `.pathology(true)`);
/// unarmed runtimes carry only an empty `OnceLock`.
pub struct PathologyDetector {
    cfg: PathologyConfig,
    scan: Mutex<ScanState>,
    /// Ready-time-in-queue of steal-joined pushes (push → start gap, ns):
    /// the starvation rule's raw signal, exported for quantile readouts.
    ready_wait: Histogram,
}

impl PathologyDetector {
    pub(crate) fn new(cfg: PathologyConfig, num_rings: usize) -> Self {
        PathologyDetector {
            cfg,
            scan: Mutex::new(ScanState {
                cursor: RingCursor::empty(),
                buf: Vec::new(),
                rings: vec![RingWindow::default(); num_rings],
                events_in_window: 0,
                push_times: HashMap::new(),
                streaks: [0; 3],
            }),
            ready_wait: Histogram::new(),
        }
    }

    /// The detection thresholds in force.
    pub fn config(&self) -> &PathologyConfig {
        &self.cfg
    }

    /// Ready-time-in-queue histogram (ns) of pushes joined to their starts.
    pub fn ready_wait(&self) -> &Histogram {
        &self.ready_wait
    }

    /// One streaming scan: fold newly published events into the current
    /// window; evaluate the window each time it fills. Returns whether any
    /// pathology gauge moved. Called from the idle paths via
    /// [`RuntimeShared::pathology_tick`]; a contended `try_lock` skips (one
    /// scanner at a time — the loser's events are picked up by the winner
    /// or the next tick).
    pub fn scan(&self, rt: &RuntimeShared) -> bool {
        let Some(tracer) = &rt.tracer else {
            return false;
        };
        let Ok(mut st) = self.scan.try_lock() else {
            return false;
        };
        let st = &mut *st;
        if st.cursor.is_empty() {
            st.cursor = tracer.cursor();
        }
        if st.rings.len() < tracer.num_rings() {
            st.rings.resize(tracer.num_rings(), RingWindow::default());
        }
        let mut fired = false;
        for r in 0..tracer.num_rings() {
            st.buf.clear();
            if tracer.read_new(&mut st.cursor, r, &mut st.buf) == 0 {
                continue;
            }
            for i in 0..st.buf.len() {
                let ev = st.buf[i].clone();
                st.events_in_window += 1;
                match ev.kind {
                    TraceKind::State { state: ThreadState::Idle, label, .. } => {
                        if label == LABEL_PARK {
                            st.rings[r].parks += 1;
                        } else if label == LABEL_MGR_DRAINED {
                            st.rings[r].mgr_drained += 1;
                        } else if label == LABEL_MGR_EMPTY {
                            st.rings[r].mgr_empty += 1;
                        }
                    }
                    TraceKind::ReadyPush { id, .. } => {
                        st.rings[r].pushes += 1;
                        st.push_times.insert(id, (r, ev.t_ns));
                    }
                    TraceKind::TaskStart { id, .. } => {
                        st.rings[r].starts += 1;
                        if let Some((pr, pt)) = st.push_times.remove(&id) {
                            if pr != r {
                                if let Some(w) = st.rings.get_mut(pr) {
                                    w.stolen += 1;
                                }
                            }
                            self.ready_wait.record(ev.t_ns.saturating_sub(pt));
                        }
                    }
                    _ => {}
                }
                if st.events_in_window >= self.cfg.window_events {
                    fired |= self.evaluate(rt, st);
                }
            }
        }
        fired
    }

    /// Judge one full window against the three rules, advance the streaks,
    /// bump the sticky gauges, reset the window accumulators.
    fn evaluate(&self, rt: &RuntimeShared, st: &mut ScanState) -> bool {
        rt.stats.pathology_windows.inc();
        let cfg = &self.cfg;
        let total = st.events_in_window.max(1);
        let pending = rt.queues.pending();

        // (a) idle-spin at sync points: parks dominate while work is queued.
        let parks: usize = st.rings.iter().map(|w| w.parks).sum();
        let idle_spin = pending > 0 && parks * 100 >= total * cfg.idle_spin_park_pct;

        // (b) serialized drains: one ring owns (almost) every productive
        // manager exit while several others leave empty-handed.
        let drained_total: usize = st.rings.iter().map(|w| w.mgr_drained).sum();
        let serialized = pending > 0
            && st.rings.iter().enumerate().any(|(r, w)| {
                w.mgr_drained >= cfg.drain_min_drained
                    && w.mgr_drained * 100 >= drained_total * cfg.drain_dominance_pct
                    && st
                        .rings
                        .iter()
                        .enumerate()
                        .filter(|&(o, ow)| o != r && ow.mgr_empty >= cfg.drain_min_empty)
                        .count()
                        >= cfg.drain_empty_rings
            });

        // (c) creator starvation: a ring feeds the pool (pushes stolen
        // elsewhere) but barely executes its own ready work.
        let starvation = st.rings.iter().any(|w| {
            w.pushes >= cfg.starvation_min_pushes
                && w.stolen * 100 >= w.pushes * cfg.starvation_stolen_pct
                && w.starts * 100 <= w.pushes * cfg.starvation_self_start_pct
        });

        let gauges = [
            &rt.stats.pathology_idle_spin,
            &rt.stats.pathology_serialized_drain,
            &rt.stats.pathology_starvation,
        ];
        let mut fired = false;
        for (i, hit) in [idle_spin, serialized, starvation].into_iter().enumerate() {
            if hit {
                st.streaks[i] += 1;
                if st.streaks[i] >= cfg.streak_windows {
                    gauges[i].inc();
                    fired = true;
                }
            } else {
                st.streaks[i] = 0;
            }
        }

        for w in &mut st.rings {
            *w = RingWindow::default();
        }
        st.events_in_window = 0;
        if st.push_times.len() > PUSH_MAP_PRUNE {
            st.push_times.clear();
        }
        fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = PathologyConfig::default();
        assert!(c.window_events > 0 && c.streak_windows >= 1);
        assert!(c.idle_spin_park_pct <= 100 && c.drain_dominance_pct <= 100);
        let small = PathologyConfig::with_window(0);
        assert_eq!(small.window_events, 1, "window floors at one event");
    }

    #[test]
    fn labels_are_distinct() {
        assert_ne!(LABEL_PARK, LABEL_MGR_DRAINED);
        assert_ne!(LABEL_MGR_DRAINED, LABEL_MGR_EMPTY);
    }
}
