//! The Functionality Dispatcher (paper §3.2).
//!
//! A registry of callback functions inside the runtime core. Worker threads
//! notify the dispatcher when they become idle; the dispatcher runs the
//! registered callbacks on the idle thread, turning it into a temporary
//! service thread (the DDAST manager is callback #0 in this reproduction,
//! but the module is generic — §3.2 envisions offload handling, finished
//! task processing, etc.).

use crate::substrate::{Counter, SpinLock};

/// A registered runtime functionality. Receives the idle worker's id and
/// returns `true` if it performed useful work (used by the idle loop's
/// backoff and by tests).
pub type DispatchCallback = Box<dyn Fn(usize) -> bool + Send + Sync + 'static>;

struct Registered {
    name: &'static str,
    callback: DispatchCallback,
    invocations: Counter,
    useful: Counter,
}

/// The dispatcher. Registration is expected at runtime init but is allowed
/// at any time (the paper allows registration "during the runtime
/// initialization or the application execution").
pub struct Dispatcher {
    // SpinLock<Vec<..>> rather than RwLock: polls vastly outnumber
    // registrations, and the poll path clones nothing — it iterates under a
    // short critical section collecting indices, then invokes outside it.
    callbacks: SpinLock<Vec<std::sync::Arc<Registered>>>,
    polls: Counter,
}

impl Default for Dispatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Dispatcher {
    pub fn new() -> Self {
        Dispatcher { callbacks: SpinLock::new(Vec::new()), polls: Counter::new() }
    }

    /// Register a callback under a diagnostic name. Returns its slot index.
    pub fn register(&self, name: &'static str, callback: DispatchCallback) -> usize {
        let mut cbs = self.callbacks.lock();
        cbs.push(std::sync::Arc::new(Registered {
            name,
            callback,
            invocations: Counter::new(),
            useful: Counter::new(),
        }));
        cbs.len() - 1
    }

    /// A worker became idle: run every registered functionality once.
    /// Returns `true` if any callback did useful work.
    pub fn poll_idle(&self, worker: usize) -> bool {
        self.polls.inc();
        // Snapshot the registration list (Arc clones) so callbacks run
        // outside the lock and may themselves register more callbacks.
        let snapshot: Vec<_> = self.callbacks.lock().iter().cloned().collect();
        let mut any = false;
        for reg in snapshot {
            reg.invocations.inc();
            if (reg.callback)(worker) {
                reg.useful.inc();
                any = true;
            }
        }
        any
    }

    /// Number of registered functionalities.
    pub fn len(&self) -> usize {
        self.callbacks.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total idle notifications received.
    pub fn poll_count(&self) -> u64 {
        self.polls.get()
    }

    /// Per-callback (name, invocations, useful invocations).
    pub fn callback_stats(&self) -> Vec<(&'static str, u64, u64)> {
        self.callbacks
            .lock()
            .iter()
            .map(|r| (r.name, r.invocations.get(), r.useful.get()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn registered_callback_runs_on_poll() {
        let d = Dispatcher::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        d.register("test", Box::new(move |_w| {
            h.fetch_add(1, Ordering::Relaxed);
            true
        }));
        assert!(d.poll_idle(3));
        assert!(d.poll_idle(1));
        assert_eq!(hits.load(Ordering::Relaxed), 2);
        assert_eq!(d.poll_count(), 2);
    }

    #[test]
    fn useful_work_reported() {
        let d = Dispatcher::new();
        d.register("never-useful", Box::new(|_| false));
        assert!(!d.poll_idle(0));
        d.register("useful", Box::new(|_| true));
        assert!(d.poll_idle(0));
        let stats = d.callback_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].0, "never-useful");
        assert_eq!(stats[0].2, 0);
        assert_eq!(stats[1].2, 1);
    }

    #[test]
    fn callback_receives_worker_id() {
        let d = Dispatcher::new();
        let seen = Arc::new(AtomicUsize::new(usize::MAX));
        let s = Arc::clone(&seen);
        d.register("id", Box::new(move |w| {
            s.store(w, Ordering::Relaxed);
            false
        }));
        d.poll_idle(7);
        assert_eq!(seen.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn registration_during_execution() {
        // A callback may register another callback while running.
        let d = Arc::new(Dispatcher::new());
        let d2 = Arc::clone(&d);
        let once = Arc::new(AtomicUsize::new(0));
        let o = Arc::clone(&once);
        d.register("registrar", Box::new(move |_| {
            if o.swap(1, Ordering::Relaxed) == 0 {
                d2.register("child", Box::new(|_| true));
            }
            false
        }));
        d.poll_idle(0);
        assert_eq!(d.len(), 2);
        assert!(d.poll_idle(0), "child callback now does work");
    }

    #[test]
    fn empty_dispatcher() {
        let d = Dispatcher::new();
        assert!(d.is_empty());
        assert!(!d.poll_idle(0));
    }
}
