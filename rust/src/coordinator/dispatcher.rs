//! The Functionality Dispatcher (paper §3.2).
//!
//! A registry of callback functions inside the runtime core. Worker threads
//! notify the dispatcher when they become idle; the dispatcher runs the
//! registered callbacks on the idle thread, turning it into a temporary
//! service thread (the DDAST manager is callback #0 in this reproduction,
//! but the module is generic — §3.2 envisions offload handling, finished
//! task processing, etc.).
//!
//! ## Lock-free poll path
//!
//! `poll_idle` runs on **every** idle iteration of every worker, while
//! registration happens a handful of times per process — the textbook
//! read-mostly workload. The seed guarded the registry with a
//! `SpinLock<Vec>` and cloned the whole list into a fresh `Vec` per poll;
//! the registry now lives in an [`RcuCell`] snapshot, so a poll is one
//! acquire load and an in-place iteration — no lock, no allocation.
//! Registration clones the callback list (cheap `Arc` bumps) and installs
//! the new snapshot with a CAS. The seed implementation survives as
//! [`LockedDispatcher`] for the `bench_harness::contention` A/B.

use std::sync::Arc;

use crate::substrate::{Counter, RcuCell, ShardedCounter, SpinLock};

/// A registered runtime functionality. Receives the idle worker's id and
/// returns `true` if it performed useful work (used by the idle loop's
/// backoff and by tests).
pub type DispatchCallback = Box<dyn Fn(usize) -> bool + Send + Sync + 'static>;

struct Registered {
    name: &'static str,
    callback: DispatchCallback,
    invocations: Counter,
    useful: Counter,
}

/// The dispatcher. Registration is expected at runtime init but is allowed
/// at any time (the paper allows registration "during the runtime
/// initialization or the application execution") — including from inside a
/// running callback: the poll keeps iterating its own snapshot and picks up
/// the newcomer on the next poll.
pub struct Dispatcher {
    callbacks: RcuCell<Vec<Arc<Registered>>>,
    /// Idle notifications; sharded so the poll fast path bumps a private
    /// cell instead of RMW-ing one global line.
    polls: ShardedCounter,
}

impl Default for Dispatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Dispatcher {
    pub fn new() -> Self {
        Dispatcher { callbacks: RcuCell::new(Vec::new()), polls: ShardedCounter::new() }
    }

    /// Register a callback under a diagnostic name. Returns its slot index.
    pub fn register(&self, name: &'static str, callback: DispatchCallback) -> usize {
        let reg = Arc::new(Registered {
            name,
            callback,
            invocations: Counter::new(),
            useful: Counter::new(),
        });
        self.callbacks.update(|cur| {
            let mut next = cur.clone();
            next.push(Arc::clone(&reg));
            let idx = next.len() - 1;
            (next, idx)
        })
    }

    /// A worker became idle: run every registered functionality once.
    /// Lock- and allocation-free: iterates the current RCU snapshot in
    /// place. Returns `true` if any callback did useful work.
    pub fn poll_idle(&self, worker: usize) -> bool {
        self.polls.inc();
        let snapshot = self.callbacks.read();
        let mut any = false;
        for reg in snapshot.iter() {
            reg.invocations.inc();
            if (reg.callback)(worker) {
                reg.useful.inc();
                any = true;
            }
        }
        any
    }

    /// Number of registered functionalities.
    pub fn len(&self) -> usize {
        self.callbacks.read().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total idle notifications received.
    pub fn poll_count(&self) -> u64 {
        self.polls.get()
    }

    /// Per-callback (name, invocations, useful invocations).
    pub fn callback_stats(&self) -> Vec<(&'static str, u64, u64)> {
        self.callbacks
            .read()
            .iter()
            .map(|r| (r.name, r.invocations.get(), r.useful.get()))
            .collect()
    }

    /// (snapshot installs, lost install races, retired snapshots) of the
    /// registry cell — writer-side telemetry for the A/B drill.
    pub fn registry_stats(&self) -> (u64, u64, u64) {
        self.callbacks.stats()
    }
}

/// The seed's locked dispatcher: `SpinLock<Vec>` registry, cloned into a
/// fresh snapshot `Vec` on every poll. Retained (not wired into the
/// runtime) as the old side of the `dispatcher_poll` contention A/B.
pub struct LockedDispatcher {
    callbacks: SpinLock<Vec<Arc<Registered>>>,
    polls: Counter,
}

impl Default for LockedDispatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl LockedDispatcher {
    pub fn new() -> Self {
        LockedDispatcher { callbacks: SpinLock::new(Vec::new()), polls: Counter::new() }
    }

    pub fn register(&self, name: &'static str, callback: DispatchCallback) -> usize {
        let mut cbs = self.callbacks.lock();
        cbs.push(Arc::new(Registered {
            name,
            callback,
            invocations: Counter::new(),
            useful: Counter::new(),
        }));
        cbs.len() - 1
    }

    pub fn poll_idle(&self, worker: usize) -> bool {
        self.polls.inc();
        // The seed's poll: snapshot the registration list (Arc clones +
        // a Vec allocation) under the lock, invoke outside it.
        let snapshot: Vec<_> = self.callbacks.lock().iter().cloned().collect();
        let mut any = false;
        for reg in snapshot {
            reg.invocations.inc();
            if (reg.callback)(worker) {
                reg.useful.inc();
                any = true;
            }
        }
        any
    }

    pub fn poll_count(&self) -> u64 {
        self.polls.get()
    }

    /// Registry-lock statistics: (acquisitions, contended, spin iters).
    pub fn lock_stats(&self) -> (u64, u64, u64) {
        self.callbacks.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn registered_callback_runs_on_poll() {
        let d = Dispatcher::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        d.register("test", Box::new(move |_w| {
            h.fetch_add(1, Ordering::Relaxed);
            true
        }));
        assert!(d.poll_idle(3));
        assert!(d.poll_idle(1));
        assert_eq!(hits.load(Ordering::Relaxed), 2);
        assert_eq!(d.poll_count(), 2);
    }

    #[test]
    fn useful_work_reported() {
        let d = Dispatcher::new();
        d.register("never-useful", Box::new(|_| false));
        assert!(!d.poll_idle(0));
        d.register("useful", Box::new(|_| true));
        assert!(d.poll_idle(0));
        let stats = d.callback_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].0, "never-useful");
        assert_eq!(stats[0].2, 0);
        assert_eq!(stats[1].2, 1);
    }

    #[test]
    fn callback_receives_worker_id() {
        let d = Dispatcher::new();
        let seen = Arc::new(AtomicUsize::new(usize::MAX));
        let s = Arc::clone(&seen);
        d.register("id", Box::new(move |w| {
            s.store(w, Ordering::Relaxed);
            false
        }));
        d.poll_idle(7);
        assert_eq!(seen.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn registration_during_execution() {
        // A callback may register another callback while running — the RCU
        // snapshot the poll iterates is unaffected by the install.
        let d = Arc::new(Dispatcher::new());
        let d2 = Arc::clone(&d);
        let once = Arc::new(AtomicUsize::new(0));
        let o = Arc::clone(&once);
        d.register("registrar", Box::new(move |_| {
            if o.swap(1, Ordering::Relaxed) == 0 {
                d2.register("child", Box::new(|_| true));
            }
            false
        }));
        d.poll_idle(0);
        assert_eq!(d.len(), 2);
        assert!(d.poll_idle(0), "child callback now does work");
        let (installs, _races, retired) = d.registry_stats();
        assert_eq!(installs, 2);
        assert_eq!(retired, 2);
    }

    #[test]
    fn empty_dispatcher() {
        let d = Dispatcher::new();
        assert!(d.is_empty());
        assert!(!d.poll_idle(0));
    }

    #[test]
    fn register_returns_slot_indices() {
        let d = Dispatcher::new();
        assert_eq!(d.register("a", Box::new(|_| false)), 0);
        assert_eq!(d.register("b", Box::new(|_| false)), 1);
        assert_eq!(d.register("c", Box::new(|_| false)), 2);
    }

    #[test]
    fn locked_baseline_matches_behavior() {
        let d = LockedDispatcher::new();
        assert_eq!(d.register("a", Box::new(|_| false)), 0);
        assert_eq!(d.register("b", Box::new(|_| true)), 1);
        assert!(d.poll_idle(0));
        assert_eq!(d.poll_count(), 1);
        let (acq, _, _) = d.lock_stats();
        assert!(acq >= 3, "two registers + one poll snapshot");
    }
}
