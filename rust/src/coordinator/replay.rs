//! Graph memoization + replay (EXPERIMENTS.md §Graph replay).
//!
//! Iterative workloads (Matmul tiles, N-Body steps, SparseLU sweeps)
//! resubmit a *structurally identical* task graph every iteration, and pay
//! full dependence resolution — shard acquisitions, Submit/Done messages,
//! per-iteration graph insertion — for the same answer each time. This
//! module deletes that hot path for the repeat case, following the
//! Taskgraph framework (Yu et al. 2022, PAPERS.md): resolve the graph
//! **once**, freeze the result, and re-execute later iterations through
//! per-task atomic in-degree countdowns with direct ready-deque refills.
//!
//! * **Record** ([`capture`]) replays the submission stream through a
//!   throwaway [`DepDomain`] carrying an [`EdgeRecorder`] — sequentially,
//!   in program order, with nothing executing — and freezes the recorded
//!   edge multiset, per-task successor lists, initial in-degrees and the
//!   ready seed order into an immutable [`GraphRecording`]. Because no
//!   task finishes during capture, the edge set is the *maximal*
//!   (program-order) one: a superset of what any live resolved run could
//!   have enforced, so a replay is never less ordered than resolution.
//! * **Key** ([`stream_hash_of`]) is an FNV-1a hash of the submission
//!   stream — dep addresses + modes + program order. A replay request
//!   whose stream hashes differently transparently falls back to full
//!   resolution ([`ReplayOutcome::FellBack`]).
//! * **Replay** ([`ReplayRun`] + [`run_iteration`]) re-arms a pre-sized
//!   arena of recycled [`Wd`] descriptors (ids reserved once, bodies and
//!   in-degrees re-installed per iteration — zero per-iteration graph
//!   insertion), seeds the recorded ready order straight into the ready
//!   deques, and lets the normal workers run it. Completion bypasses the
//!   request plane entirely: `run_task` recognizes arena descriptors and
//!   finalizes them in place via the recorded successor lists
//!   (`RuntimeShared::replay_finalize`) — no `DepDomain` shard
//!   acquisitions, no Submit/Done messages, for **every** organization.
//!   Parking, taskwait wake edges and failure containment are unchanged:
//!   a panic during replay still poisons its successor cone, through the
//!   recorded edges instead of the graph.
//!
//! Replay iterations must be driven from outside task bodies (the drivers
//! taskwait on the root), and a recording is only valid on the
//! [`TaskSystem`](crate::coordinator::TaskSystem) that will replay it —
//! the capture honours that system's exact/ranged dependence semantics.

use std::sync::{Arc, Weak};

use crate::coordinator::dep::DepMode;
use crate::coordinator::depgraph::DepDomain;
use crate::coordinator::pool::RuntimeShared;
use crate::coordinator::wd::{TaskBody, TaskId, Wd, WdState};
use crate::substrate::SpinLock;

/// One task of a replayable iteration: the declared dependences (the
/// submission stream the recording is keyed on), a static label, and the
/// body for this iteration.
pub struct ReplayTask {
    pub deps: Vec<crate::coordinator::dep::Dependence>,
    pub label: &'static str,
    pub body: TaskBody,
}

impl ReplayTask {
    pub fn new<F: FnOnce() + Send + 'static>(
        deps: Vec<crate::coordinator::dep::Dependence>,
        label: &'static str,
        body: F,
    ) -> ReplayTask {
        ReplayTask { deps, label, body: Box::new(body) }
    }
}

/// How [`TaskSystem::replay`](crate::coordinator::TaskSystem::replay)
/// executed an iteration.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ReplayOutcome {
    /// The submission stream matched the recording: executed through the
    /// arena countdown path, zero dependence resolution.
    Replayed,
    /// The stream hash mismatched: executed through full resolution
    /// (counted in `RtStats::replay_fallbacks`).
    FellBack,
}

/// Mirrors every dependence edge appended during submission. Installed
/// only on the throwaway capture domains built by [`capture`]; production
/// domains carry `None` and pay a single never-taken branch per edge site
/// (no atomics — the "recording off" fast path).
#[derive(Default)]
pub(crate) struct EdgeRecorder {
    edges: SpinLock<Vec<(u64, u64)>>,
}

impl EdgeRecorder {
    pub(crate) fn new() -> EdgeRecorder {
        EdgeRecorder { edges: SpinLock::new(Vec::new()) }
    }

    /// Record one `pred -> succ` edge. Called under the shard lock at the
    /// exact points `DepDomain` pairs a successor-list push with
    /// `add_preds(1)`, so the recorded multiset matches the countdown
    /// total edge for edge (multi-edges included — each one is a real
    /// pending-predecessor increment the replay must count down).
    #[inline]
    pub(crate) fn edge(&self, pred: TaskId, succ: TaskId) {
        self.edges.lock().push((pred.0, succ.0));
    }

    pub(crate) fn snapshot(&self) -> Vec<(u64, u64)> {
        self.edges.lock().clone()
    }
}

/// The frozen result of resolving one iteration's submission stream.
/// Immutable after capture; shared by reference between the driver and
/// the runtime's replay finalizer.
pub struct GraphRecording {
    stream_hash: u64,
    /// Per-task successor indices, multiplicity preserved (one entry per
    /// recorded edge — each is one in-degree count the successor awaits).
    succs: Vec<Vec<u32>>,
    /// Initial pending-predecessor count per task.
    in_degree: Vec<u32>,
    /// Indices of tasks ready at submission time, in submission order.
    ready_seed: Vec<u32>,
    labels: Vec<&'static str>,
}

impl GraphRecording {
    pub fn num_tasks(&self) -> usize {
        self.in_degree.len()
    }

    pub fn stream_hash(&self) -> u64 {
        self.stream_hash
    }

    /// Total recorded edges (multiplicity included).
    pub fn edge_count(&self) -> usize {
        self.succs.iter().map(Vec::len).sum()
    }

    pub fn ready_seed(&self) -> &[u32] {
        &self.ready_seed
    }

    pub fn in_degree(&self, i: usize) -> u32 {
        self.in_degree[i]
    }

    pub(crate) fn succs(&self, i: usize) -> &[u32] {
        &self.succs[i]
    }
}

#[inline]
fn fnv1a(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash of the submission stream: task count, per-task dep count, and per
/// dep the region address, length and mode — all in program order. Any
/// structural change (different regions, modes, counts or order) yields a
/// different key and forces the fallback path.
pub(crate) fn stream_hash_of(tasks: &[ReplayTask]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    h = fnv1a(h, tasks.len() as u64);
    for t in tasks {
        h = fnv1a(h, t.deps.len() as u64);
        for d in &t.deps {
            h = fnv1a(h, d.region.base);
            h = fnv1a(h, d.region.len);
            let mode = match d.mode {
                DepMode::In => 0,
                DepMode::Out => 1,
                DepMode::Inout => 2,
            };
            h = fnv1a(h, mode);
        }
    }
    h
}

/// Resolve `tasks`' dependences once, sequentially, against a throwaway
/// recording domain, and freeze the result. Phantom descriptors (ids =
/// submission indices) stand in for the real tasks, so recorded edges
/// translate directly to arena offsets; nothing executes and the phantoms
/// are dropped with the scratch domain before this returns.
pub(crate) fn capture(tasks: &[ReplayTask], ranged: bool) -> Arc<GraphRecording> {
    let n = tasks.len();
    let recorder = Arc::new(EdgeRecorder::new());
    let domain = DepDomain::new_recording(Arc::clone(&recorder), ranged);
    let phantoms: Vec<Arc<Wd>> = tasks
        .iter()
        .enumerate()
        .map(|(i, t)| Wd::new(TaskId(i as u64), t.deps.clone(), t.label, Weak::new(), Box::new(|| {})))
        .collect();
    let mut ready_seed = Vec::new();
    for (i, p) in phantoms.iter().enumerate() {
        let ready = if p.deps.is_empty() {
            // Mirror spawn_from's no-dep fast path: the task never enters
            // the graph; dropping the submission guard makes it ready.
            p.release_pred()
        } else {
            domain.submit(p)
        };
        if ready {
            ready_seed.push(i as u32);
        }
    }
    let mut succs = vec![Vec::new(); n];
    let mut edges_in = vec![0u32; n];
    for (pred, succ) in recorder.snapshot() {
        succs[pred as usize].push(succ as u32);
        edges_in[succ as usize] += 1;
    }
    // The guard is released, so what remains pending is exactly the real
    // in-degree — and every increment went through a recorded edge site.
    let in_degree: Vec<u32> = phantoms.iter().map(|p| p.pending_preds() as u32).collect();
    debug_assert_eq!(
        in_degree, edges_in,
        "recorded edges must account for every pending predecessor"
    );
    Arc::new(GraphRecording {
        stream_hash: stream_hash_of(tasks),
        succs,
        in_degree,
        ready_seed,
        labels: tasks.iter().map(|t| t.label).collect(),
    })
}

/// A recording bound to a runtime: the pre-sized arena of recyclable
/// descriptors plus the contiguous id block that lets `run_task` recognize
/// arena tasks with one range check. Installed once per recording into
/// `RuntimeShared`'s RCU slot; iterations only re-arm the arena.
pub(crate) struct ReplayRun {
    pub(crate) rec: Arc<GraphRecording>,
    pub(crate) arena: Vec<Arc<Wd>>,
    base_id: u64,
}

impl ReplayRun {
    pub(crate) fn new(rt: &Arc<RuntimeShared>, rec: Arc<GraphRecording>) -> Arc<ReplayRun> {
        let n = rec.num_tasks();
        let base_id = rt.reserve_task_ids(n as u64);
        let arena: Vec<Arc<Wd>> = (0..n)
            .map(|i| {
                Wd::new(
                    TaskId(base_id + i as u64),
                    Vec::new(),
                    rec.labels[i],
                    Arc::downgrade(&rt.root),
                    Box::new(|| {}),
                )
            })
            .collect();
        Arc::new(ReplayRun { rec, arena, base_id })
    }

    /// Does `id` belong to this run's arena? Ids are reserved as one
    /// contiguous block, so membership is a single wrapping range check.
    #[inline]
    pub(crate) fn owns(&self, id: TaskId) -> bool {
        id.0.wrapping_sub(self.base_id) < self.arena.len() as u64
    }

    #[inline]
    pub(crate) fn index_of(&self, id: TaskId) -> usize {
        debug_assert!(self.owns(id));
        (id.0 - self.base_id) as usize
    }
}

/// Execute one recorded iteration: re-arm every arena descriptor with its
/// body and recorded in-degree, account the tasks on the root, seed the
/// recorded ready order into the deques, and taskwait. All in-degrees are
/// installed *before* anything is seeded, so no countdown can release a
/// descriptor still being recycled — the submission guard is unnecessary.
/// Safe to call again immediately on return: the taskwait only returns
/// once every arena descriptor has been finalized to `Deletable`.
pub(crate) fn run_iteration(
    rt: &Arc<RuntimeShared>,
    run: &Arc<ReplayRun>,
    worker: usize,
    bodies: Vec<TaskBody>,
) {
    let n = run.rec.num_tasks();
    assert_eq!(bodies.len(), n, "replay bodies must match the recording's task count");
    if n == 0 {
        return;
    }
    for (i, body) in bodies.into_iter().enumerate() {
        run.arena[i].recycle_for_replay(body, run.rec.in_degree[i] as usize);
        rt.root.child_created();
    }
    rt.stats.tasks_created.add(n as u64);
    rt.stats.tasks_outstanding.add(n as u64);
    rt.stats.replay_hits.inc();
    let mut seeds = Vec::with_capacity(run.rec.ready_seed.len());
    for &i in &run.rec.ready_seed {
        let t = &run.arena[i as usize];
        t.set_state(WdState::Ready);
        seeds.push(Arc::clone(t));
    }
    let released = seeds.len();
    rt.ready.push_batch(worker, seeds);
    rt.wake_for_ready(released);
    let root = Arc::clone(&rt.root);
    rt.taskwait_on(worker, &root);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dep::{dep_in, dep_inout, dep_out, Dependence};

    fn t(deps: Vec<Dependence>) -> ReplayTask {
        ReplayTask::new(deps, "t", || {})
    }

    #[test]
    fn capture_chain_and_independent_topology() {
        // 0 -> 1 -> 2 on one inout region; 3 independent (no deps).
        let tasks = vec![
            t(vec![dep_inout(10)]),
            t(vec![dep_inout(10)]),
            t(vec![dep_inout(10)]),
            t(vec![]),
        ];
        let rec = capture(&tasks, false);
        assert_eq!(rec.num_tasks(), 4);
        assert_eq!(rec.ready_seed(), &[0, 3]);
        assert_eq!((0..4).map(|i| rec.in_degree(i)).collect::<Vec<_>>(), vec![0, 1, 1, 0]);
        assert_eq!(rec.succs(0), &[1]);
        assert_eq!(rec.succs(1), &[2]);
        assert!(rec.succs(2).is_empty() && rec.succs(3).is_empty());
        assert_eq!(rec.edge_count(), 2);
    }

    #[test]
    fn capture_preserves_multi_edges() {
        // 0 writes two regions, 1 reads both: two RAW edges, in-degree 2.
        let tasks = vec![
            t(vec![dep_out(1), dep_out(2)]),
            t(vec![dep_in(1), dep_in(2)]),
        ];
        let rec = capture(&tasks, false);
        assert_eq!(rec.succs(0), &[1, 1], "both edges kept — each is one countdown");
        assert_eq!(rec.in_degree(1), 2);
        assert_eq!(rec.ready_seed(), &[0]);
    }

    #[test]
    fn capture_fan_out_and_war() {
        // writer 0; readers 1,2 (RAW); writer 3 (WAR x2 + WAW).
        let tasks = vec![
            t(vec![dep_out(7)]),
            t(vec![dep_in(7)]),
            t(vec![dep_in(7)]),
            t(vec![dep_out(7)]),
        ];
        let rec = capture(&tasks, false);
        assert_eq!(rec.in_degree(1), 1);
        assert_eq!(rec.in_degree(2), 1);
        assert_eq!(rec.in_degree(3), 3, "WAR on both readers + conservative WAW");
        assert_eq!(rec.ready_seed(), &[0]);
        let mut s0 = rec.succs(0).to_vec();
        s0.sort_unstable();
        assert_eq!(s0, vec![1, 2, 3]);
    }

    #[test]
    fn capture_ranged_overlap() {
        let w = Dependence::new(crate::substrate::RegionKey { base: 0, len: 100 }, DepMode::Out);
        let r = Dependence::new(crate::substrate::RegionKey { base: 50, len: 100 }, DepMode::In);
        let rec = capture(&[t(vec![w]), t(vec![r])], true);
        assert_eq!(rec.in_degree(1), 1, "overlapping ranged RAW edge captured");
        assert_eq!(rec.succs(0), &[1]);
    }

    #[test]
    fn stream_hash_keys_on_structure_only() {
        let a = vec![t(vec![dep_in(1)]), t(vec![dep_out(2)])];
        let b = vec![t(vec![dep_in(1)]), t(vec![dep_out(2)])];
        assert_eq!(stream_hash_of(&a), stream_hash_of(&b), "same stream, same key");
        let addr = vec![t(vec![dep_in(9)]), t(vec![dep_out(2)])];
        let mode = vec![t(vec![dep_out(1)]), t(vec![dep_out(2)])];
        let order = vec![t(vec![dep_out(2)]), t(vec![dep_in(1)])];
        let count = vec![t(vec![dep_in(1)])];
        for other in [&addr, &mode, &order, &count] {
            assert_ne!(stream_hash_of(&a), stream_hash_of(other));
        }
    }
}
