//! The worker thread pool and the runtime core shared state.
//!
//! Implements the paper's task flow (Fig 2 for the Sync baseline, Fig 3 for
//! DDAST): task creation/submission, the idle loop that notifies the
//! Functionality Dispatcher, task execution, finalization and the
//! `DoneHandled`/`Deletable` deletion protocol, plus `taskwait`.
//!
//! ## Failure containment
//!
//! Task bodies execute inside a `catch_unwind` boundary: a panicking body
//! lands its `Wd` in [`WdState::Failed`] and still runs the **full**
//! finalize path, so successor notification, `children_live` accounting and
//! the taskwait wake edge never leak. A failed task *poisons* its
//! dependents — every successor its finish releases is
//! [`WdState::Cancelled`] (body dropped unrun) and finalized in turn, so
//! poison propagates transitively along the dependence edges while the
//! graph drains normally. A hang watchdog ([`RuntimeShared::watchdog_tick`])
//! piggybacks on the idle paths and re-raises/wakes when workers sit parked
//! past a deadline with work outstanding. All of it is observable through
//! `RtStats` and [`RuntimeShared::task_errors`], and injectable
//! deterministically through a [`FaultPlan`].

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::ddast::{ddast_callback, DdastParams};
use crate::coordinator::dep::Dependence;
use crate::coordinator::dispatcher::Dispatcher;
use crate::coordinator::messages::{DoneTaskMsg, MsgBatch, QueueSystem};
use crate::coordinator::pathology::{PathologyConfig, PathologyDetector, LABEL_PARK};
use crate::coordinator::ready::ReadyPools;
use crate::coordinator::replay::ReplayRun;
use crate::coordinator::trace::{ThreadState, TraceKind, Tracer};
use crate::coordinator::wd::{TaskBody, TaskId, Wd, WdState};
use crate::substrate::{Counter, FaultPlan, FaultSite, RcuCell, SpinLock, Topology};

/// Which runtime organization to run (paper §6.1's compared runtimes, plus
/// the authors' earlier centralized design [7] for lineage comparison).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RuntimeKind {
    /// `Nanos++` baseline: worker threads mutate the dependence graph
    /// directly under the domain spinlocks (Fig 2).
    Sync,
    /// The paper's contribution: asynchronous requests to a distributed
    /// manager running on idle threads (Fig 3).
    Ddast,
    /// The authors' previous design (IPDPSW'17 [7]): same message queues,
    /// but one *dedicated* manager thread (the DAS Thread) drains them —
    /// worker threads never become managers. One core is spent on
    /// management permanently; the manager saturates at scale, which is
    /// what motivated DDAST.
    CentralDast,
    /// GOMP-like comparator: direct graph mutation + one centralized ready
    /// queue all threads contend on.
    GompLike,
}

impl RuntimeKind {
    /// Does this organization communicate through the message queues?
    #[inline]
    pub fn asynchronous(self) -> bool {
        matches!(self, RuntimeKind::Ddast | RuntimeKind::CentralDast)
    }
}

/// Aggregate runtime statistics.
#[derive(Default)]
pub struct RtStats {
    pub tasks_created: Counter,
    pub tasks_executed: Counter,
    /// Tasks created but not yet done-handled (quiescence gauge).
    pub tasks_outstanding: Counter,
    pub mgr_activations: Counter,
    pub mgr_msgs: Counter,
    /// Peak number of threads concurrently inside the DDAST callback
    /// (invariant: never exceeds `MAX_DDAST_THREADS` — DESIGN.md #4).
    pub mgr_peak: Counter,
    pub graph_submits: Counter,
    pub graph_finishes: Counter,
    /// Parks committed inside `taskwait_on` (tentpole telemetry: the
    /// taskwait spin ladder never reaches a blind sleep — it parks).
    pub taskwait_parks: Counter,
    /// Child-completion wake edges fired: a finalizer's decrement-to-zero
    /// claimed a parent's waiter registration and woke its worker slot.
    pub taskwait_wake_edges: Counter,
    /// Dependence-targeted wake edges fired: a finalizer claimed a waiter
    /// registered **on the finishing task itself** (`taskwait_task`) and
    /// woke exactly that worker — point-to-point, never a broadcast.
    pub dep_wake_edges: Counter,
    /// Task bodies that panicked (caught at the execution boundary).
    pub tasks_failed: Counter,
    /// Tasks poisoned by a failed/cancelled predecessor: body dropped
    /// unrun, finalized normally.
    pub tasks_cancelled: Counter,
    /// Hang-watchdog self-heals: workers found parked past the progress
    /// deadline with work outstanding, re-raised and woken.
    pub watchdog_recoveries: Counter,
    /// Teardown paths that degraded gracefully instead of asserting (e.g. a
    /// parent `Wd` already reclaimed while a poisoned run shuts down).
    pub teardown_degradations: Counter,
    /// Iterations executed through the replay plane (recorded graph, zero
    /// dependence resolution — EXPERIMENTS.md §Graph replay).
    pub replay_hits: Counter,
    /// Replay requests whose submission-stream hash mismatched the
    /// recording, transparently executed through full resolution instead.
    pub replay_fallbacks: Counter,
    /// Graph recordings captured in record mode.
    pub recordings_captured: Counter,
    /// External submissions admitted through the ingress ring (the
    /// serve-scale lane — EXPERIMENTS.md §Serve-scale ingress).
    pub ingress_admitted: Counter,
    /// External submissions rejected by ring backpressure (`try_submit`
    /// returned `Busy`): the serve plane's admission gauge.
    pub ingress_rejected: Counter,
    /// External submissions that bypassed the ring (no dependences, or a
    /// synchronous organization): admitted directly by the submitting
    /// thread, admission cannot fail.
    pub ingress_direct: Counter,
    /// Trace windows evaluated by the online pathology detector (zero while
    /// the detector is disarmed — the `pathology_ab` drill's proof that the
    /// non-detecting hot path gained nothing).
    pub pathology_windows: Counter,
    /// Sticky: windows where park/taskwait idling dominated while messages
    /// sat pending (idle-spin at a sync point, Tuft et al. pattern (a)).
    pub pathology_idle_spin: Counter,
    /// Sticky: windows where one manager context owned nearly all drained
    /// exits while others left empty-handed (serialized drains).
    pub pathology_serialized_drain: Counter,
    /// Sticky: windows where a creator's ready pushes were stolen faster
    /// than it popped them (creator starvation). The `AutoTuner`'s
    /// `MIN_READY_TASKS` controller consumes this gauge's deltas.
    pub pathology_starvation: Counter,
}

/// Failure summary of a run — the payload of the non-breaking checked APIs
/// (`TaskSystem::taskwait_checked` / `shutdown_checked`). Counters are
/// cumulative for the runtime's lifetime: a run that ever failed stays
/// poisoned (fail-stop reporting), matching the sticky `RtStats` gauges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskErrors {
    /// Task bodies that panicked.
    pub tasks_failed: u64,
    /// Dependents cancelled by poison propagation.
    pub tasks_cancelled: u64,
    /// Message of the first caught panic (task id + label + payload).
    pub first_panic: Option<String>,
}

impl std::fmt::Display for TaskErrors {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} task(s) failed, {} cancelled",
            self.tasks_failed, self.tasks_cancelled
        )?;
        if let Some(msg) = &self.first_panic {
            write!(f, " (first: {msg})")?;
        }
        Ok(())
    }
}

impl std::error::Error for TaskErrors {}

/// Why an external submission was not admitted
/// ([`RuntimeShared::try_spawn_external`] / `TaskSystem::try_submit`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The ingress ring is full: backpressure engaged instead of unbounded
    /// queue growth. Retry later, or use the blocking submit flavour.
    Busy,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "ingress ring full (backpressure engaged)"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Per-domain sticky failure cell (serve plane): every live `GraphDomain`
/// registers one, keyed by its root task id, and the failure paths
/// attribute panics/cancellations to the owning domain by climbing the
/// parent chain. Reading a cell is lock-free counter loads; the registry
/// lock is taken only at domain churn and on the (rare) failure paths.
pub(crate) struct DomainErrorCell {
    failed: Counter,
    cancelled: Counter,
    first_panic: SpinLock<Option<String>>,
}

impl DomainErrorCell {
    fn new() -> DomainErrorCell {
        DomainErrorCell {
            failed: Counter::new(),
            cancelled: Counter::new(),
            first_panic: SpinLock::new(None),
        }
    }

    /// `None` while the domain is clean — the domain-scoped analogue of
    /// [`RuntimeShared::task_errors`], same sticky fail-stop semantics.
    pub(crate) fn summary(&self) -> Option<TaskErrors> {
        let tasks_failed = self.failed.get();
        let tasks_cancelled = self.cancelled.get();
        if tasks_failed == 0 && tasks_cancelled == 0 {
            return None;
        }
        Some(TaskErrors {
            tasks_failed,
            tasks_cancelled,
            first_panic: self.first_panic.lock().clone(),
        })
    }
}

/// Hang-watchdog progress stamp: a coarse "last useful work" timestamp
/// (µs since runtime construction) the idle paths compare against
/// [`WATCHDOG_DEADLINE`]. Turning the no-lost-wakeup invariant from an
/// assumption into a monitored property: if it ever breaks (or a fault
/// plan breaks it on purpose), the next idle pass detects the stall and
/// re-raises/wakes instead of hanging.
struct Watchdog {
    base: Instant,
    last_progress_us: AtomicU64,
}

impl Watchdog {
    fn new() -> Watchdog {
        Watchdog { base: Instant::now(), last_progress_us: AtomicU64::new(0) }
    }

    #[inline]
    fn now_us(&self) -> u64 {
        self.base.elapsed().as_micros() as u64
    }

    /// Stamp "useful work happened now". Relaxed: the stamp is a heuristic
    /// deadline input, not a synchronization edge.
    #[inline]
    fn note_progress(&self) {
        self.last_progress_us.store(self.now_us(), Ordering::Relaxed);
    }

    #[inline]
    fn stale(&self, deadline: Duration) -> bool {
        self.now_us().saturating_sub(self.last_progress_us.load(Ordering::Relaxed))
            >= deadline.as_micros() as u64
    }
}

thread_local! {
    /// (runtime, worker id, current task stack) of the thread.
    static CTX: RefCell<Option<WorkerCtx>> = const { RefCell::new(None) };
}

struct WorkerCtx {
    rt: Arc<RuntimeShared>,
    worker: usize,
    task_stack: Vec<Arc<Wd>>,
}

/// Everything the workers share. Owned by [`crate::coordinator::api::TaskSystem`].
pub struct RuntimeShared {
    pub kind: RuntimeKind,
    /// Parameters at construction (the static defaults).
    pub params: DdastParams,
    /// Live parameters — adjustable at runtime by the auto-tuner (§8
    /// future work); the DDAST callback snapshots these on entry.
    tunables: Arc<crate::coordinator::autotune::TunableParams>,
    pub num_threads: usize,
    /// Resolved socket shape (builder override → `DDAST_TOPOLOGY` env →
    /// OS detection → flat). Steers the signal directory's two-level
    /// layout, steal victim order and wake victim selection.
    pub topo: Topology,
    pub queues: QueueSystem,
    pub ready: ReadyPools,
    pub dispatcher: Dispatcher,
    /// The implicit whole-program task; parent of top-level tasks.
    pub root: Arc<Wd>,
    /// Threads currently inside the DDAST callback (Listing 2's
    /// `numThreads`).
    pub mgr_count: AtomicUsize,
    pub stats: RtStats,
    pub tracer: Option<Tracer>,
    /// Use the range-overlap dependence plugin for new domains
    /// (TaskSystemBuilder::ranged_deps).
    pub ranged_deps: bool,
    /// Deterministic fault-injection plan (tests/benches); `None` in
    /// production — every site check is then a single branch.
    fault_plan: Option<Arc<FaultPlan>>,
    /// Message of the first caught task panic (feeds [`TaskErrors`]).
    first_panic: SpinLock<Option<String>>,
    watchdog: Watchdog,
    /// The online pathology detector, armed explicitly
    /// ([`arm_pathology`](RuntimeShared::arm_pathology) — requires
    /// tracing). Empty on every other runtime: the idle-path tick is then
    /// one `OnceLock` load, and no hot path records anything extra.
    pathology: std::sync::OnceLock<PathologyDetector>,
    shutdown: AtomicBool,
    next_task_id: AtomicU64,
    /// The installed replay run, if any (record/replay plane). RCU snapshot:
    /// `run_task` reads it once per task (one Acquire load) to recognize
    /// arena descriptors, which finalize in place instead of going through
    /// the graph or the request plane. Installed once per recording — not
    /// per iteration — so the cell's retire list stays bounded by the
    /// number of distinct recordings replayed.
    replay: RcuCell<Option<Arc<ReplayRun>>>,
    /// Sticky per-domain failure cells, keyed by each live `GraphDomain`'s
    /// root task id (registered at creation, removed at retirement). A
    /// locked `Vec` suffices: it is touched at domain churn and on failure
    /// paths only, and live-domain counts stay small.
    domain_errors: SpinLock<Vec<(TaskId, Arc<DomainErrorCell>)>>,
}

impl RuntimeShared {
    pub fn new(
        kind: RuntimeKind,
        num_threads: usize,
        params: DdastParams,
        tracing: bool,
        seed: u64,
    ) -> Arc<Self> {
        Self::new_with_plugin(kind, num_threads, params, tracing, seed, false)
    }

    /// Like [`RuntimeShared::new`], selecting the dependence plugin
    /// (`ranged_deps = true` → range-overlap regions).
    pub fn new_with_plugin(
        kind: RuntimeKind,
        num_threads: usize,
        params: DdastParams,
        tracing: bool,
        seed: u64,
        ranged_deps: bool,
    ) -> Arc<Self> {
        Self::new_with_options(kind, num_threads, params, tracing, seed, ranged_deps, None, None)
    }

    /// Full-option constructor: dependence plugin plus an optional
    /// deterministic [`FaultPlan`] (fault-injection harness; `None` outside
    /// tests/benches) plus an optional [`Topology`] override (`None` →
    /// [`Topology::detect`]: `DDAST_TOPOLOGY` env, then OS NUMA nodes,
    /// then flat).
    pub fn new_with_options(
        kind: RuntimeKind,
        num_threads: usize,
        params: DdastParams,
        tracing: bool,
        seed: u64,
        ranged_deps: bool,
        fault_plan: Option<Arc<FaultPlan>>,
        topology: Option<Topology>,
    ) -> Arc<Self> {
        Self::new_full(
            kind,
            num_threads,
            params,
            tracing,
            seed,
            ranged_deps,
            fault_plan,
            topology,
            crate::coordinator::messages::DEFAULT_INGRESS_CAPACITY,
        )
    }

    /// [`RuntimeShared::new_with_options`] plus the ingress-ring capacity
    /// (the external lane's admission bound —
    /// `TaskSystemBuilder::ingress_capacity`).
    #[allow(clippy::too_many_arguments)]
    pub fn new_full(
        kind: RuntimeKind,
        num_threads: usize,
        params: DdastParams,
        tracing: bool,
        seed: u64,
        ranged_deps: bool,
        fault_plan: Option<Arc<FaultPlan>>,
        topology: Option<Topology>,
        ingress_capacity: usize,
    ) -> Arc<Self> {
        assert!(num_threads >= 1, "need at least the main thread");
        let topo = topology.unwrap_or_else(|| Topology::detect(num_threads)).cover(num_threads);
        // GOMP-like: a single central *locked* ready queue all threads hit
        // (the comparator models a centralized contended runtime, so it
        // deliberately skips the per-thread lock-free deques).
        let ready = if kind == RuntimeKind::GompLike {
            ReadyPools::new_central(seed)
        } else {
            ReadyPools::new_with_topology(num_threads, seed, topo)
        };
        // Trace rings are sized by the *actual* number of recording
        // contexts: the centralized design's DAS thread records from an
        // extra slot beyond the workers. (The seed's tracer wrapped that
        // slot onto worker 0's buffer via `worker % buffers.len()`,
        // silently merging two threads' streams.)
        let trace_slots = num_threads + usize::from(kind == RuntimeKind::CentralDast);
        // The signal directory gets one parking slot per *context*, like the
        // trace rings: the centralized design's DAS thread parks (timed) on
        // the extra slot beyond the workers, so shutdown and the watchdog
        // can wake it instead of waiting out a blind sleep.
        let mut queues = QueueSystem::with_topology_and_ingress(
            num_threads,
            trace_slots,
            topo,
            ingress_capacity,
        );
        if let Some(plan) = &fault_plan {
            // The IngressRaise site lives inside the directory itself
            // (`raise_external` is called by outside threads with no
            // runtime context): hand the plan over before sharing.
            queues.signals_mut().install_fault_plan(Arc::clone(plan));
        }
        Arc::new(RuntimeShared {
            kind,
            params,
            tunables: Arc::new(crate::coordinator::autotune::TunableParams::new(params)),
            num_threads,
            topo,
            queues,
            ready,
            dispatcher: Dispatcher::new(),
            root: Wd::root(),
            mgr_count: AtomicUsize::new(0),
            stats: RtStats::default(),
            tracer: if tracing { Some(Tracer::new(trace_slots)) } else { None },
            ranged_deps,
            fault_plan,
            first_panic: SpinLock::new(None),
            watchdog: Watchdog::new(),
            pathology: std::sync::OnceLock::new(),
            shutdown: AtomicBool::new(false),
            next_task_id: AtomicU64::new(1),
            replay: RcuCell::new(None),
            domain_errors: SpinLock::new(Vec::new()),
        })
    }

    /// Register the DDAST callback in the Functionality Dispatcher (§3.2's
    /// sequence diagram step "register callback", done at runtime init).
    pub fn register_ddast(self: &Arc<Self>) {
        let rt = Arc::clone(self);
        self.dispatcher
            .register("ddast", Box::new(move |worker| ddast_callback(&rt, worker)));
    }

    /// Register the DDAST callback restricted to a subset of workers — the
    /// paper's big.LITTLE adaptation (§8: "allowing a subset of the worker
    /// threads to become manager threads", e.g. only the LITTLE cores).
    pub fn register_ddast_with_affinity(self: &Arc<Self>, allowed_workers: Vec<usize>) {
        let rt = Arc::clone(self);
        let mut mask = vec![false; self.num_threads + 1];
        for w in allowed_workers {
            if w < mask.len() {
                mask[w] = true;
            }
        }
        assert!(
            mask.iter().any(|&b| b),
            "manager affinity must allow at least one worker (deadlock otherwise)"
        );
        self.dispatcher.register(
            "ddast(affinity)",
            Box::new(move |worker| {
                if !mask.get(worker).copied().unwrap_or(false) {
                    return false;
                }
                ddast_callback(&rt, worker)
            }),
        );
    }

    /// Live (auto-tunable) DDAST parameters.
    #[inline]
    pub fn tunables(&self) -> &Arc<crate::coordinator::autotune::TunableParams> {
        &self.tunables
    }

    #[inline]
    pub fn fresh_task_id(&self) -> TaskId {
        TaskId(self.next_task_id.fetch_add(1, Ordering::Relaxed))
    }

    /// Reserve `n` consecutive task ids and return the first. The replay
    /// arena claims its block up front so arena membership is a single
    /// range check in `run_task`.
    #[inline]
    pub(crate) fn reserve_task_ids(&self, n: u64) -> u64 {
        self.next_task_id.fetch_add(n, Ordering::Relaxed)
    }

    /// Install `run` as the active replay run (replacing any previous one).
    pub(crate) fn replay_install(&self, run: Arc<ReplayRun>) {
        self.replay.update(|_| (Some(Arc::clone(&run)), ()));
    }

    #[inline]
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        // Wake every parked worker so the exit condition is re-evaluated
        // (wake_all issues the producer-side fence; after this flag is set,
        // workers refuse to park — see `worker_loop` — so nothing can
        // re-park past a missed shutdown).
        self.queues.signals().wake_all();
    }

    /// All work done and all messages processed? Uses the sharded gauges'
    /// exact-read fallbacks — a torn relaxed sweep must not let a worker
    /// exit its loop while a ready task is still queued — and cross-checks
    /// the exact pending gauge against the work-signal directory ("no dirty
    /// workers"), reclaiming stale raises along the way.
    pub fn quiescent(&self) -> bool {
        self.stats.tasks_outstanding.get() == 0
            && self.queues.pending_exact() == 0
            && self.ready.ready_count_exact() == 0
            && self.queues.signals_quiescent()
    }

    // ---- failure containment ---------------------------------------------

    /// The installed fault-injection plan, if any (tests/telemetry).
    pub fn fault_plan(&self) -> Option<&Arc<FaultPlan>> {
        self.fault_plan.as_ref()
    }

    /// Draw a fault decision for `site` — `false` (one branch) when no plan
    /// is installed or the site is disarmed.
    #[inline]
    pub(crate) fn fault_inject(&self, site: FaultSite) -> bool {
        self.fault_plan.as_ref().is_some_and(|p| p.should_inject(site))
    }

    /// Failure summary so far: `None` while the run is clean, the sticky
    /// counters plus the first panic message once anything failed.
    pub fn task_errors(&self) -> Option<TaskErrors> {
        let tasks_failed = self.stats.tasks_failed.get();
        let tasks_cancelled = self.stats.tasks_cancelled.get();
        if tasks_failed == 0 && tasks_cancelled == 0 {
            return None;
        }
        Some(TaskErrors { tasks_failed, tasks_cancelled, first_panic: self.first_panic.lock().clone() })
    }

    /// Register a domain root for per-domain failure attribution
    /// (`GraphDomain` creation). Returns the domain's sticky cell; the
    /// holder reads it directly, no registry lookup on the read side.
    pub(crate) fn register_domain(&self, root_id: TaskId) -> Arc<DomainErrorCell> {
        let cell = Arc::new(DomainErrorCell::new());
        self.domain_errors.lock().push((root_id, Arc::clone(&cell)));
        cell
    }

    /// Retire a domain root from the attribution registry (`GraphDomain`
    /// drop). Holders may keep reading their own cell handle.
    pub(crate) fn deregister_domain(&self, root_id: TaskId) {
        self.domain_errors.lock().retain(|(id, _)| *id != root_id);
    }

    /// The failure cell of the domain owning `task`, if any: climb the
    /// parent chain to the topmost ancestor below the implicit root and
    /// look its id up in the registry. Failure paths only — the happy path
    /// never calls this.
    fn domain_cell_for(&self, task: &Arc<Wd>) -> Option<Arc<DomainErrorCell>> {
        let mut top_id = task.id;
        let mut cur = task.parent.upgrade();
        while let Some(p) = cur {
            if p.id == TaskId(0) {
                break; // the implicit whole-program root owns no cell
            }
            top_id = p.id;
            cur = p.parent.upgrade();
        }
        let reg = self.domain_errors.lock();
        reg.iter().find(|(id, _)| *id == top_id).map(|(_, c)| Arc::clone(c))
    }

    /// Count a poisoned cancellation, attributing it to the owning
    /// domain's sticky cell when the task lives under a registered
    /// `GraphDomain` — containment stays per-tenant (ISSUE 9 layer 1).
    fn note_cancelled(&self, task: &Arc<Wd>) {
        self.stats.tasks_cancelled.inc();
        if let Some(cell) = self.domain_cell_for(task) {
            cell.cancelled.inc();
        }
    }

    /// One hang-watchdog pass, piggybacked on the idle paths (the DDAST
    /// sweep's empty-handed exits, the DAS loop's idle tier, timed-park
    /// timeouts). Detects "work outstanding + workers parked + no progress
    /// for [`WATCHDOG_DEADLINE`]" and self-heals: re-raises every worker
    /// with queued messages, wakes all parked slots, counts the recovery.
    /// Returns whether it healed. Cheap when healthy: two relaxed loads and
    /// a compare.
    pub fn watchdog_tick(&self) -> bool {
        if self.shutdown_requested() || !self.watchdog.stale(WATCHDOG_DEADLINE) {
            return false;
        }
        let signals = self.queues.signals();
        if signals.parked_count() == 0 {
            return false;
        }
        if self.queues.pending() == 0 && self.ready.ready_count() == 0 {
            return false;
        }
        // Self-heal: restore the raise for every worker that still has
        // queued messages (a swallowed raise leaves the directory clean
        // while the queue is not), then wake everything parked — spurious
        // wakes re-park, a stalled wake is delivered late instead of never.
        for w in 0..self.queues.num_workers() {
            if self.queues.workers[w].pending() > 0 {
                signals.raise(w);
            }
        }
        // The external lane heals the same way: entries resident in the
        // ingress ring behind a clean external bit get the bit restored.
        if self.queues.ingress_pending() > 0 {
            signals.raise_external();
        }
        signals.wake_all();
        self.watchdog.note_progress();
        self.stats.watchdog_recoveries.inc();
        true
    }

    // ---- online pathology detection --------------------------------------

    /// Arm the online pathology detector with `cfg`. Requires tracing (the
    /// detector's only input is the trace rings); returns whether it armed.
    /// Idempotent — the first arm wins. Builder surface:
    /// `TaskSystemBuilder::pathology(true)`.
    pub fn arm_pathology_with(&self, cfg: PathologyConfig) -> bool {
        let Some(t) = &self.tracer else {
            return false;
        };
        self.pathology.set(PathologyDetector::new(cfg, t.num_rings())).is_ok()
    }

    /// [`arm_pathology_with`](RuntimeShared::arm_pathology_with) at the
    /// default thresholds.
    pub fn arm_pathology(&self) -> bool {
        self.arm_pathology_with(PathologyConfig::default())
    }

    /// The armed detector, if any (gauge/quantile readouts).
    pub fn pathology(&self) -> Option<&PathologyDetector> {
        self.pathology.get()
    }

    /// One detector scan, piggybacked on the same idle moments as
    /// [`watchdog_tick`](RuntimeShared::watchdog_tick). Disarmed (the
    /// default): a single `OnceLock` load — no atomics added to any path.
    /// Returns whether a pathology gauge moved.
    pub fn pathology_tick(&self) -> bool {
        match self.pathology.get() {
            Some(d) => d.scan(self),
            None => false,
        }
    }

    // ---- tracing helpers -------------------------------------------------

    #[inline]
    pub fn trace_manager_enter(&self, worker: usize) {
        if let Some(t) = &self.tracer {
            t.record(worker, TraceKind::State { worker, state: ThreadState::Manager, label: "" });
        }
    }

    /// Record a manager exit, labeled by whether the activation satisfied
    /// any messages — the raw signal of the pathology detector's
    /// serialized-drain rule (one ring owning the drained exits while
    /// others exit empty).
    #[inline]
    pub fn trace_manager_exit(&self, worker: usize, drained: bool) {
        if let Some(t) = &self.tracer {
            let label = if drained {
                crate::coordinator::pathology::LABEL_MGR_DRAINED
            } else {
                crate::coordinator::pathology::LABEL_MGR_EMPTY
            };
            t.record(worker, TraceKind::State { worker, state: ThreadState::Idle, label });
        }
    }

    /// Record a committed park on `worker`'s own ring (worker loop and
    /// `taskwait_on` both commit through [`commit_park`] — the sync-point
    /// idling the pathology detector's idle-spin rule counts).
    #[inline]
    fn trace_park(&self, worker: usize) {
        if let Some(t) = &self.tracer {
            t.record(worker, TraceKind::State { worker, state: ThreadState::Idle, label: LABEL_PARK });
        }
    }

    #[inline]
    fn trace_gauges(&self, worker: usize) {
        if let Some(t) = &self.tracer {
            let in_graph = self.root.child_domain_opt().map_or(0, |d| d.tasks_in_graph());
            t.record(worker, TraceKind::InGraph(in_graph));
            t.record(worker, TraceKind::Ready(self.ready.ready_count()));
        }
    }

    // ---- task life cycle -------------------------------------------------

    /// Create + submit a task (life-cycle steps 1 and 2). `worker` is the
    /// creating thread; `parent` the creating task.
    pub fn spawn_from(
        self: &Arc<Self>,
        worker: usize,
        parent: &Arc<Wd>,
        deps: Vec<Dependence>,
        label: &'static str,
        body: TaskBody,
    ) -> Arc<Wd> {
        assert!(
            !self.shutdown_requested(),
            "spawn after shutdown was requested"
        );
        let wd = Wd::new(self.fresh_task_id(), deps, label, Arc::downgrade(parent), body);
        parent.child_created();
        self.stats.tasks_created.inc();
        self.stats.tasks_outstanding.inc();

        if wd.deps.is_empty() {
            // Fast path: no dependences -> never enters the graph; ready
            // immediately in every organization.
            wd.set_state(WdState::Submitted);
            let became_ready = wd.release_pred();
            debug_assert!(became_ready);
            wd.set_state(WdState::Ready);
            self.ready.push(worker, Arc::clone(&wd));
            self.wake_for_ready(worker, 1);
            // Creator-starvation signal: the push onto the creator's *own*
            // deque, joined by id against the eventual TaskStart (replay
            // refills and ingress drains record nothing here — their
            // pushes are not a creator feeding itself).
            if let Some(t) = &self.tracer {
                t.record(worker, TraceKind::ReadyPush { worker, id: wd.id.0 });
            }
            self.trace_gauges(worker);
            return wd;
        }

        match self.kind {
            RuntimeKind::Sync | RuntimeKind::GompLike => {
                // Fig 2: the creating thread updates the graph itself,
                // contending on the domain spinlock.
                self.process_submit_direct(worker, Arc::clone(&wd));
            }
            RuntimeKind::Ddast | RuntimeKind::CentralDast => {
                // Fig 3: request the runtime operation instead and return
                // to application code immediately.
                self.queues.push_submit(worker, Arc::clone(&wd));
            }
        }
        self.trace_gauges(worker);
        wd
    }

    /// Wake parked idle workers when ready tasks appear: they observe
    /// message traffic through [`SignalDirectory::raise`]'s wake hook, but
    /// ready-pool pushes have no raise — this is their wake edge. One fence
    /// plus a bitmap load when nobody is parked (the common case).
    ///
    /// `worker` is the thread whose deque just received the tasks: the
    /// wake scan prefers a parked worker on *that deque's socket* (it can
    /// steal the new work without crossing sockets), falling back to the
    /// remaining sockets in rotation.
    ///
    /// Fault site [`FaultSite::WakeEdge`]: an injected fault swallows the
    /// wake (an unbounded delay) — the timed-park recheck cadence and the
    /// hang watchdog must then deliver the work anyway.
    #[inline]
    pub(crate) fn wake_for_ready(&self, worker: usize, n: usize) {
        if self.fault_inject(FaultSite::WakeEdge) {
            return;
        }
        self.queues.signals().wake_parked_near(n, Some(worker));
    }

    // ---- external-submitter lane (serve-scale ingress) -------------------

    /// Create an externally submitted task and route it. `Ok(wd)` — fully
    /// admitted through a direct route: no dependences (ready immediately,
    /// pushed straight to a deque — safe from a foreign thread because the
    /// deque's back side is token-serialized for pushers and thieves
    /// alike), or a synchronous organization (Fig 2: the submitting thread
    /// mutates the graph itself under the domain locks, exactly like a
    /// pool thread would — admission cannot fail). `Err(wd)` — the task
    /// must go through the bounded ingress ring; the caller decides
    /// blocking vs rejecting. The submitter has no deque or trace slot of
    /// its own: ready pushes spread by task id, and **no** tracer call
    /// happens on any external path (trace rings are single-writer).
    fn create_external(
        self: &Arc<Self>,
        parent: &Arc<Wd>,
        deps: Vec<Dependence>,
        label: &'static str,
        body: TaskBody,
    ) -> Result<Arc<Wd>, Arc<Wd>> {
        assert!(
            !self.shutdown_requested(),
            "external submit after shutdown was requested"
        );
        let wd = Wd::new(self.fresh_task_id(), deps, label, Arc::downgrade(parent), body);
        parent.child_created();
        self.stats.tasks_created.inc();
        self.stats.tasks_outstanding.inc();

        if wd.deps.is_empty() {
            wd.set_state(WdState::Submitted);
            let became_ready = wd.release_pred();
            debug_assert!(became_ready);
            wd.set_state(WdState::Ready);
            let slot = (wd.id.0 as usize) % self.num_threads;
            self.ready.push(slot, Arc::clone(&wd));
            self.wake_for_ready(slot, 1);
            self.stats.ingress_direct.inc();
            return Ok(wd);
        }

        match self.kind {
            RuntimeKind::Sync | RuntimeKind::GompLike => {
                let slot = (wd.id.0 as usize) % self.num_threads;
                self.process_submit_direct(slot, Arc::clone(&wd));
                self.stats.ingress_direct.inc();
                Ok(wd)
            }
            RuntimeKind::Ddast | RuntimeKind::CentralDast => Err(wd),
        }
    }

    /// External-submitter lane, blocking flavour: create + submit a task
    /// from a thread *outside* the pool, waiting out ring backpressure
    /// instead of rejecting — the submission is never lost. The polite
    /// idle ladder bounds the retry cost; the pool must be drained
    /// concurrently (worker threads, a DAS thread, or a thread inside
    /// `taskwait`) for the wait to end.
    pub fn spawn_external(
        self: &Arc<Self>,
        parent: &Arc<Wd>,
        deps: Vec<Dependence>,
        label: &'static str,
        body: TaskBody,
    ) -> Arc<Wd> {
        match self.create_external(parent, deps, label, body) {
            Ok(wd) => wd,
            Err(wd) => {
                let mut pending = Arc::clone(&wd);
                let mut idle: u32 = 0;
                loop {
                    match self.queues.try_push_external(pending) {
                        Ok(()) => break,
                        Err(back) => {
                            pending = back;
                            idle = idle.saturating_add(1);
                            idle_backoff(idle);
                        }
                    }
                }
                self.stats.ingress_admitted.inc();
                wd
            }
        }
    }

    /// External-submitter lane, non-blocking flavour:
    /// [`SubmitError::Busy`] when the ingress ring is full. On rejection
    /// every side effect of admission is rolled back — including the
    /// parent's child accounting, settled through the **full**
    /// child-completion protocol (see
    /// [`reject_external`](RuntimeShared::reject_external)).
    pub fn try_spawn_external(
        self: &Arc<Self>,
        parent: &Arc<Wd>,
        deps: Vec<Dependence>,
        label: &'static str,
        body: TaskBody,
    ) -> Result<Arc<Wd>, SubmitError> {
        match self.create_external(parent, deps, label, body) {
            Ok(wd) => Ok(wd),
            Err(wd) => match self.queues.try_push_external(Arc::clone(&wd)) {
                Ok(()) => {
                    self.stats.ingress_admitted.inc();
                    Ok(wd)
                }
                Err(task) => {
                    self.reject_external(&task);
                    Err(SubmitError::Busy)
                }
            },
        }
    }

    /// Roll back a rejected external admission. The creation counters are
    /// undone and the parent's `children_live` is settled through the
    /// **full** child-completion protocol: a bare decrement could strand a
    /// parent mid-`taskwait` that counted the phantom child at its
    /// re-check and parked — the wake edge must fire exactly as if the
    /// child had finished.
    fn reject_external(&self, task: &Arc<Wd>) {
        self.stats.ingress_rejected.inc();
        self.stats.tasks_created.dec();
        self.stats.tasks_outstanding.dec();
        task.drop_body();
        let Some(parent) = task.parent.upgrade() else {
            self.stats.teardown_degradations.inc();
            return;
        };
        if parent.child_done() {
            if let Some(w) = parent.take_waiter() {
                self.stats.taskwait_wake_edges.inc();
                if !self.fault_inject(FaultSite::WakeEdge) {
                    self.queues.signals().wake_worker(w);
                }
            }
            if parent.done_handled() {
                parent.set_state(WdState::Deletable);
            }
        }
    }

    /// Drain up to `budget` externally submitted tasks from the ingress
    /// ring into `batch` and process them through the ordinary batch path
    /// (same-parent grouping, one shard-acquisition set per run). Returns
    /// the number of messages processed. The directory's external bit is
    /// claimed first — concurrent managers don't all pile onto the ring —
    /// and re-raised when entries remain, so the invariant "ring
    /// non-empty ⇒ bit raised or a drain in flight" holds at every exit.
    pub fn drain_ingress(&self, mgr_worker: usize, batch: &mut MsgBatch, budget: usize) -> u64 {
        let signals = self.queues.signals();
        // Plain-load guard before the RMW, same discipline as the DAS
        // thread's per-worker signal sweep.
        if !signals.external_raised() || !signals.try_claim_external() {
            return 0;
        }
        let mut n = 0u64;
        while (n as usize) < budget {
            match self.queues.pop_external() {
                Some(task) => {
                    batch.submits.push(task);
                    n += 1;
                }
                None => break,
            }
        }
        if n > 0 {
            self.process_batch(mgr_worker, batch);
        }
        // Budget exhausted mid-ring, or a producer mid-push (tail claimed,
        // value not yet published): restore the bit so the leftover is
        // somebody's work. The producer's own raise makes this merely
        // redundant in the mid-push case, never required — but redundant
        // raises are cheap and lost ones are deadlocks.
        if self.queues.ingress_pending() > 0 {
            signals.raise_external();
        }
        n
    }

    fn process_submit_direct(&self, worker: usize, task: Arc<Wd>) {
        let Some(parent) = task.parent.upgrade() else {
            // Teardown after failure: the parent WD was already reclaimed,
            // so the submission has no domain to enter. Degrade to a
            // counted cancellation instead of asserting — the poisoned run
            // must still reach quiescence and join.
            self.orphaned_submit(task);
            return;
        };
        let domain = parent.child_domain_with(self.ranged_deps);
        task.set_state(WdState::Submitted);
        self.stats.graph_submits.inc();
        if domain.submit(&task) {
            task.set_state(WdState::Ready);
            self.ready.push(worker, task);
            self.wake_for_ready(worker, 1);
        }
    }

    /// Counted graceful degradation for a submission whose parent WD is
    /// already gone (reachable only during teardown after a failure):
    /// cancel the task and settle the outstanding gauge, with no
    /// `child_done`/domain traffic — there is no parent left to notify.
    fn orphaned_submit(&self, task: Arc<Wd>) {
        self.stats.teardown_degradations.inc();
        task.set_state(WdState::Submitted);
        task.set_state(WdState::Cancelled);
        task.drop_body();
        self.note_cancelled(&task);
        task.set_state(WdState::DoneHandled);
        task.set_state(WdState::Deletable);
        self.stats.tasks_outstanding.dec();
    }

    /// Manager-side handling of a single Submit Task Message — the
    /// retained **per-message baseline**: every runtime route (DDAST
    /// callback, DAS thread) goes through
    /// [`process_batch`](RuntimeShared::process_batch), but this is the
    /// simplest reference implementation of one manager step (kept like
    /// `LockedDispatcher`/`LockedTracer`, and guarded by
    /// `per_message_baseline_path_still_works`). Caller must hold the
    /// worker's Submit consumer token across the call if other managers
    /// may run concurrently (program order).
    pub fn process_submit(&self, mgr_worker: usize, task: Arc<Wd>) {
        self.process_submit_direct(mgr_worker, task);
        self.queues.message_processed();
        self.trace_gauges(mgr_worker);
    }

    /// Manager-side handling of a single Done Task Message (per-message
    /// baseline — see [`process_submit`](RuntimeShared::process_submit)).
    pub fn process_done_msg(&self, mgr_worker: usize, msg: DoneTaskMsg) {
        self.finalize_task(mgr_worker, &msg.task);
        self.queues.message_processed();
        self.trace_gauges(mgr_worker);
    }

    /// Manager-side handling of one drained [`MsgBatch`]: Submit messages
    /// are grouped into runs of same-parent siblings (contiguous runs, so
    /// a worker's FIFO program order is preserved) and inserted with
    /// [`DepDomain::submit_batch`] — one shard-acquisition set per run
    /// instead of per message — then Done messages are finalized. The
    /// pending gauge is settled once per batch, and the trace gauges
    /// sampled once per batch instead of per message.
    pub fn process_batch(&self, mgr_worker: usize, batch: &mut MsgBatch) {
        let n = batch.len() as u64;
        if n == 0 {
            return;
        }
        debug_assert!(batch.ready.is_empty(), "ready scratch drained last batch");
        let mut i = 0;
        while i < batch.submits.len() {
            // Identity probe via Weak::ptr_eq: no refcount traffic on the
            // shared parent line while grouping; one upgrade per run.
            let mut j = i + 1;
            while j < batch.submits.len()
                && batch.submits[j].parent.ptr_eq(&batch.submits[i].parent)
            {
                j += 1;
            }
            let Some(parent) = batch.submits[i].parent.upgrade() else {
                // Teardown after failure: the whole same-parent run is
                // orphaned — degrade each task instead of asserting.
                for task in batch.submits[i..j].iter().cloned() {
                    self.orphaned_submit(task);
                }
                i = j;
                continue;
            };
            let domain = parent.child_domain_with(self.ranged_deps);
            for task in &batch.submits[i..j] {
                task.set_state(WdState::Submitted);
            }
            self.stats.graph_submits.add((j - i) as u64);
            domain.submit_batch(&batch.submits[i..j], &mut batch.ready);
            i = j;
        }
        batch.submits.clear();
        if !batch.ready.is_empty() {
            for t in &batch.ready {
                t.set_state(WdState::Ready);
            }
            let released = batch.ready.len();
            self.ready.push_drain(mgr_worker, &mut batch.ready);
            self.wake_for_ready(mgr_worker, released);
        }
        for msg in batch.dones.drain(..) {
            self.finalize_task(mgr_worker, &msg.task);
        }
        self.queues.messages_processed(n);
        self.watchdog.note_progress();
        self.trace_gauges(mgr_worker);
    }

    /// Life-cycle step 5/6: remove from graph, wake successors, run the
    /// deletion-state protocol. Called by the worker itself (Sync/GOMP) or
    /// by a manager thread (DDAST).
    ///
    /// **Poison propagation**: when `task` died ([`WdState::Failed`] or
    /// [`WdState::Cancelled`]), every successor its finish releases is
    /// cancelled instead of made ready — and, having no body to run, is
    /// finalized immediately on a local worklist (iterative, so a long
    /// poisoned chain cannot overflow the stack). Each cancelled task runs
    /// this same full protocol: graph removal, `DoneHandled`/`Deletable`,
    /// parent accounting, wake edge — accounting never leaks, it only
    /// skips the bodies.
    fn finalize_task(&self, worker: usize, task: &Arc<Wd>) {
        // Lazily filled: the happy path never allocates.
        let mut poisoned: Vec<Arc<Wd>> = Vec::new();
        self.finalize_one(worker, task, &mut poisoned);
        while let Some(dead) = poisoned.pop() {
            self.finalize_one(worker, &dead, &mut poisoned);
        }
    }

    fn finalize_one(&self, worker: usize, task: &Arc<Wd>, poisoned: &mut Vec<Arc<Wd>>) {
        let Some(parent) = task.parent.upgrade() else {
            // Teardown after failure: the parent WD was already reclaimed.
            // Its domain (and with it any successors) is gone too — settle
            // this task's own accounting and degrade gracefully.
            self.stats.teardown_degradations.inc();
            task.set_state(WdState::DoneHandled);
            self.fire_dep_wake(task);
            if task.children_live() == 0 {
                task.set_state(WdState::Deletable);
            }
            self.stats.tasks_outstanding.dec();
            return;
        };
        if !task.deps.is_empty() {
            let domain = parent.child_domain_with(self.ranged_deps);
            self.stats.graph_finishes.inc();
            let ready = domain.finish(task);
            if task.is_poisoned() {
                for t in &ready {
                    t.set_state(WdState::Cancelled);
                    t.drop_body();
                    self.note_cancelled(t);
                }
                poisoned.extend(ready);
            } else {
                for t in &ready {
                    t.set_state(WdState::Ready);
                }
                let released = ready.len();
                self.ready.push_batch(worker, ready);
                if released > 0 {
                    self.wake_for_ready(worker, released);
                }
            }
        }
        // §3.1: deletion synchronization through an extra state rather than
        // a third message type.
        task.set_state(WdState::DoneHandled);
        // Dependence-targeted wake edge: a worker blocked in
        // `taskwait_task` on *this* task is registered in the task's own
        // waiter slot. The (SeqCst) `DoneHandled` store above precedes
        // this claim, pairing with the waiter's register-then-recheck
        // order — same store-buffer argument as the child-completion edge
        // below, with `done_handled()` as the condition.
        self.fire_dep_wake(task);
        if task.children_live() == 0 {
            task.set_state(WdState::Deletable);
        }
        self.stats.tasks_outstanding.dec();
        if parent.child_done() {
            // Child-completion wake edge: the (SeqCst) decrement above
            // precedes this claim, pairing with `taskwait_on`'s
            // register-then-recheck order — a parent committing to park
            // either saw zero children at its re-check, or its
            // registration is visible here and gets a targeted wake.
            if let Some(w) = parent.take_waiter() {
                self.stats.taskwait_wake_edges.inc();
                if !self.fault_inject(FaultSite::WakeEdge) {
                    self.queues.signals().wake_worker(w);
                }
            }
            if parent.done_handled() {
                parent.set_state(WdState::Deletable);
            }
        }
    }

    /// Execute a ready task on `worker` (life-cycle steps 3–5).
    ///
    /// **Panic isolation**: the body runs inside a
    /// `catch_unwind(AssertUnwindSafe(..))` boundary. A panicking body can
    /// no longer unwind through `worker_loop` (killing the worker and
    /// leaking its parked bit and the parent's `children_live`): the task
    /// lands in [`WdState::Failed`], the panic is recorded for
    /// [`RuntimeShared::task_errors`], and the task takes the **same**
    /// finalize route as a successful one — successor poisoning included.
    /// `AssertUnwindSafe` is sound here because the only state crossing the
    /// boundary is the body itself (consumed either way) and shared runtime
    /// structures whose invariants are maintained by their own atomics and
    /// locks, not by the body's completion.
    pub fn run_task(self: &Arc<Self>, worker: usize, task: Arc<Wd>) {
        task.set_state(WdState::Running);
        if let Some(t) = &self.tracer {
            t.record(worker, TraceKind::TaskStart { worker, id: task.id.0, label: task.label });
            t.record(
                worker,
                TraceKind::State { worker, state: ThreadState::Task, label: task.label },
            );
        }
        let body = task.take_body();
        // Make the executing task the current task for nested spawns.
        CTX.with(|c| {
            if let Some(ctx) = c.borrow_mut().as_mut() {
                ctx.task_stack.push(Arc::clone(&task));
            }
        });
        // Fault site `TaskBody`: panic inside the boundary instead of
        // running the body, exercising the Failed path end to end.
        let inject = self.fault_inject(FaultSite::TaskBody);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            if inject {
                panic!("injected fault: task body");
            }
            body();
        }));
        // The pop runs on the unwind path too: a panicking task must not
        // leave itself on the stack as the parent of later spawns.
        CTX.with(|c| {
            if let Some(ctx) = c.borrow_mut().as_mut() {
                let popped = ctx.task_stack.pop();
                debug_assert!(popped.is_some_and(|p| p.id == task.id));
            }
        });
        match outcome {
            Ok(()) => {
                task.set_state(WdState::Finished);
                self.stats.tasks_executed.inc();
            }
            Err(payload) => {
                task.set_state(WdState::Failed);
                self.stats.tasks_failed.inc();
                self.record_panic(&task, payload.as_ref());
            }
        }
        if let Some(t) = &self.tracer {
            t.record(worker, TraceKind::TaskEnd { worker, id: task.id.0 });
            t.record(worker, TraceKind::State { worker, state: ThreadState::Idle, label: "" });
        }
        self.watchdog.note_progress();
        // Replay plane: arena descriptors bypass the graph *and* the
        // request plane for every organization — their successors are
        // recorded, so the countdown finalize runs right here on the
        // executing worker (no Done message, no shard acquisition). Cost
        // when no run is installed: one RCU load and a `None` branch.
        if let Some(run) = self.replay.read() {
            if run.owns(task.id) {
                self.replay_finalize(worker, &task, run);
                self.trace_gauges(worker);
                return;
            }
        }
        match self.kind {
            RuntimeKind::Sync | RuntimeKind::GompLike => self.finalize_task(worker, &task),
            RuntimeKind::Ddast | RuntimeKind::CentralDast => self.queues.push_done(worker, task),
        }
        self.trace_gauges(worker);
    }

    /// Replay-plane finalize: like
    /// [`finalize_task`](RuntimeShared::finalize_task), but successors come
    /// from the recorded graph instead of a `DepDomain::finish`, and the
    /// countdown is each successor's recycled `preds` counter. Poison
    /// propagation walks the same local worklist: a failed replay task
    /// cancels exactly the successor cone the recording captured.
    fn replay_finalize(&self, worker: usize, task: &Arc<Wd>, run: &Arc<ReplayRun>) {
        let mut poisoned: Vec<Arc<Wd>> = Vec::new();
        self.replay_finalize_one(worker, task, run, &mut poisoned);
        while let Some(dead) = poisoned.pop() {
            self.replay_finalize_one(worker, &dead, run, &mut poisoned);
        }
    }

    fn replay_finalize_one(
        &self,
        worker: usize,
        task: &Arc<Wd>,
        run: &Arc<ReplayRun>,
        poisoned: &mut Vec<Arc<Wd>>,
    ) {
        let idx = run.index_of(task.id);
        // Recorded-successor countdown — the replay analogue of
        // `DepDomain::finish`, with zero shard traffic. Multi-edges were
        // recorded once per pending-predecessor increment, so releasing
        // once per recorded edge balances exactly.
        let mut ready: Vec<Arc<Wd>> = Vec::new();
        for &s in run.rec.succs(idx) {
            let succ = &run.arena[s as usize];
            if succ.release_pred() {
                ready.push(Arc::clone(succ));
            }
        }
        if task.is_poisoned() {
            for t in &ready {
                t.set_state(WdState::Cancelled);
                t.drop_body();
                self.note_cancelled(t);
            }
            poisoned.extend(ready);
        } else {
            for t in &ready {
                t.set_state(WdState::Ready);
            }
            let released = ready.len();
            if released > 0 {
                self.ready.push_batch(worker, ready);
                self.wake_for_ready(worker, released);
            }
        }
        // Same deletion-state protocol and parent accounting as
        // `finalize_one`; the parent of every arena task is the root, which
        // outlives the runtime, so the teardown degradation arm is
        // defensive only.
        task.set_state(WdState::DoneHandled);
        self.fire_dep_wake(task);
        if task.children_live() == 0 {
            task.set_state(WdState::Deletable);
        }
        self.stats.tasks_outstanding.dec();
        let Some(parent) = task.parent.upgrade() else {
            self.stats.teardown_degradations.inc();
            return;
        };
        if parent.child_done() {
            if let Some(w) = parent.take_waiter() {
                self.stats.taskwait_wake_edges.inc();
                if !self.fault_inject(FaultSite::WakeEdge) {
                    self.queues.signals().wake_worker(w);
                }
            }
            if parent.done_handled() {
                parent.set_state(WdState::Deletable);
            }
        }
    }

    /// Finalizer side of the **dependence-targeted wake edge**: claim a
    /// waiter registered on the finishing task's own slot
    /// ([`taskwait_task`](RuntimeShared::taskwait_task)) and wake exactly
    /// that worker — point-to-point, never a broadcast scan. Must run
    /// after the task's `DoneHandled` store (the waiter's re-check
    /// condition). Cost on the hot path: one load when no waiter is
    /// registered. Same [`FaultSite::WakeEdge`] guard as every other wake
    /// edge — a swallowed wake is redelivered by the timed-park cadence
    /// and the watchdog.
    #[inline]
    fn fire_dep_wake(&self, task: &Arc<Wd>) {
        if let Some(w) = task.take_waiter() {
            self.stats.dep_wake_edges.inc();
            if !self.fault_inject(FaultSite::WakeEdge) {
                self.queues.signals().wake_worker(w);
            }
        }
    }

    /// Record the first caught task panic for [`TaskErrors::first_panic`],
    /// globally and — when the task lives under a registered `GraphDomain`
    /// — in the owning domain's sticky cell.
    fn record_panic(&self, task: &Arc<Wd>, payload: &(dyn std::any::Any + Send)) {
        let msg = if let Some(s) = payload.downcast_ref::<&str>() {
            s
        } else if let Some(s) = payload.downcast_ref::<String>() {
            s.as_str()
        } else {
            "non-string panic payload"
        };
        let full = format!("task {:?} ({}) panicked: {msg}", task.id, task.label);
        {
            let mut slot = self.first_panic.lock();
            if slot.is_none() {
                *slot = Some(full.clone());
            }
        }
        if let Some(cell) = self.domain_cell_for(task) {
            cell.failed.inc();
            let mut slot = cell.first_panic.lock();
            if slot.is_none() {
                *slot = Some(full);
            }
        }
    }

    /// One scheduling attempt for `worker`: run a ready task, else notify
    /// the Functionality Dispatcher (§3.2: idle threads run registered
    /// functionalities). Returns true if anything useful happened.
    pub fn try_make_progress(self: &Arc<Self>, worker: usize) -> bool {
        if let Some(task) = self.ready.get(worker) {
            self.run_task(worker, task);
            return true;
        }
        self.dispatcher.poll_idle(worker)
    }

    /// Block the current task until all its children are done-handled
    /// (the `taskwait` annotation, §2.1.1). The blocked thread keeps
    /// executing other ready tasks / runtime functionalities meanwhile
    /// (task life-cycle step 4, "Task becomes blocked"); when nothing
    /// actionable is visible it **parks** on its worker slot with a
    /// child-completion wake edge registered on `task`, instead of the
    /// seed's blind spin → yield → sleep ladder (the idle-spinning at
    /// synchronization points that Tuft et al. measure as detrimental,
    /// replaced by the blocking waits of Álvarez et al.).
    ///
    /// Wake-edge protocol (store-buffer-proof, the same fence discipline
    /// as pool parking): the waiter CAS-publishes `(generation, worker)`
    /// into the task's waiter slot ([`Wd::register_waiter`]), announces on
    /// the signal directory (`begin_park`, SeqCst RMW + fence), then
    /// re-checks `children_live`. The finalizer decrements `children_live`
    /// (SeqCst) *before* claiming the slot ([`Wd::take_waiter`]) and
    /// waking the slot's parker (`wake_worker`). In the SeqCst total
    /// order, either the waiter's re-check sees the zero (and cancels), or
    /// the finalizer's claim sees the registration (and wakes) — a last
    /// child finishing exactly as the parent commits to parking always
    /// wakes it.
    ///
    /// While work is visible that this thread may act on next round
    /// (queued requests, ready tasks, a shutdown drain), the park is
    /// *timed* at the old sleep cadence — never the blind sleep — so the
    /// taskwait keeps helping with children instead of oversleeping a
    /// burst; parking never blocks the children's progress either way,
    /// because every ready release wakes as many parked slots as tasks
    /// released.
    pub fn taskwait_on(self: &Arc<Self>, worker: usize, task: &Arc<Wd>) {
        let mut idle: u32 = 0;
        while task.children_live() > 0 {
            if self.try_make_progress(worker) {
                idle = 0;
                continue;
            }
            idle += 1;
            if idle < PARK_AFTER {
                // Below the park threshold this ladder only ever spins or
                // yields (the sleep tier starts at PARK_AFTER).
                idle_backoff(idle);
                continue;
            }
            // Register the wake edge BEFORE announcing the park: the
            // finalizer reads the slot only after its decrement, so this
            // order closes the lost-wakeup window (doc comment above).
            let Some(token) = task.register_waiter(worker) else {
                // Another thread already waits on this task (two taskwaits
                // on one WD — only reachable through the root task from
                // outside the pool). No wake edge is available to us, so
                // this degenerate fallback keeps the seed's polite ladder
                // (it may sleep; spinning on yield would burn a core for
                // the other waiter's whole wait).
                idle_backoff(idle);
                continue;
            };
            let signals = self.queues.signals();
            if !signals.begin_park(worker) {
                // Another thread is mid-park on this worker slot (external
                // threads sharing worker 0's id): never double-park a
                // slot; same degenerate fallback as above.
                task.clear_waiter(token);
                idle_backoff(idle);
                continue;
            }
            if task.children_live() == 0 {
                task.clear_waiter(token);
                signals.cancel_park(worker);
                break;
            }
            self.stats.taskwait_parks.inc();
            idle = self.commit_park(worker);
            task.clear_waiter(token);
        }
    }

    /// Block `worker` until a **specific predecessor task** reaches
    /// `DoneHandled` — the dependence-targeted generalization of
    /// [`taskwait_on`](RuntimeShared::taskwait_on). Where `taskwait_on`
    /// parks on "all my children are finished" with a child-completion
    /// wake edge, this parks on "that one task finished" with the edge
    /// registered in the *predecessor's own* waiter slot; the
    /// predecessor's finalizer ([`fire_dep_wake`](RuntimeShared::fire_dep_wake))
    /// claims the slot and wakes exactly this worker, point-to-point —
    /// no broadcast scan of the directory on the wake path.
    ///
    /// Lost-wakeup proof, same store-buffer discipline as `taskwait_on`
    /// with `done_handled()` as the condition: the waiter registers
    /// (SeqCst CAS), announces the park (`begin_park`, SeqCst RMW +
    /// fence), then re-checks `pred.done_handled()`. The finalizer stores
    /// `DoneHandled` (SeqCst swap) *before* claiming the slot. In the
    /// SeqCst total order either the re-check sees the state (and
    /// cancels), or the claim sees the registration (and wakes).
    ///
    /// Like `taskwait_on`, the loop keeps executing ready work
    /// (`try_make_progress`) while blocked, so waiting on a predecessor
    /// never idles a core that could run its transitive inputs.
    pub fn taskwait_task(self: &Arc<Self>, worker: usize, pred: &Arc<Wd>) {
        let mut idle: u32 = 0;
        while !pred.done_handled() {
            if self.try_make_progress(worker) {
                idle = 0;
                continue;
            }
            idle += 1;
            if idle < PARK_AFTER {
                idle_backoff(idle);
                continue;
            }
            // Register BEFORE announcing the park (mirrors `taskwait_on`):
            // the finalizer claims the slot only after its `DoneHandled`
            // store, so this order closes the lost-wakeup window.
            let Some(token) = pred.register_waiter(worker) else {
                // The slot is taken — either the predecessor's own body is
                // in a `taskwait_on` (child edge) or another thread already
                // waits on it. Degenerate fallback: the seed's polite
                // ladder, identical to `taskwait_on`'s contended arm.
                idle_backoff(idle);
                continue;
            };
            let signals = self.queues.signals();
            if !signals.begin_park(worker) {
                pred.clear_waiter(token);
                idle_backoff(idle);
                continue;
            }
            if pred.done_handled() {
                pred.clear_waiter(token);
                signals.cancel_park(worker);
                break;
            }
            self.stats.taskwait_parks.inc();
            idle = self.commit_park(worker);
            pred.clear_waiter(token);
        }
    }

    /// The dedicated DAS Thread loop of the centralized design
    /// (`RuntimeKind::CentralDast`, the authors' IPDPSW'17 system [7]):
    /// drains every worker's queues continuously and never executes
    /// application tasks. `worker_slot` is an extra context slot beyond
    /// the workers (its ready pushes wrap onto worker queues).
    pub fn dast_thread_loop(self: Arc<Self>, worker_slot: usize) {
        install_ctx(&self, worker_slot);
        let mut idle: u32 = 0;
        let mut batch = MsgBatch::new();
        loop {
            let mut processed: u64 = 0;
            for w in 0..self.queues.num_workers() {
                let wq = &self.queues.workers[w];
                // The DAS thread keeps its historical full sweep (the
                // design being compared against predates the directory) but
                // still consumes raised signals so the directory stays
                // consistent for the quiescence cross-check. Guarded by a
                // plain load: the spin loop must not pay two shared RMWs
                // per worker per sweep when nothing is raised.
                let signals = self.queues.signals();
                if signals.is_raised(w) {
                    signals.try_claim(w);
                }
                // Fault site `DrainBatch`: defer this worker's drain to a
                // later sweep. Re-raise so the deferral cannot strand the
                // messages behind a clean directory.
                if wq.pending() > 0 && self.fault_inject(FaultSite::DrainBatch) {
                    signals.raise(w);
                    continue;
                }
                // Drain-to-empty in bounded chunks through the batch path:
                // the graph pays one shard-acquisition set per chunk, the
                // chunk bound keeps the reusable buffer small, and the
                // application runs under the Submit token (the DAS thread
                // is the sole manager here, but the invariant is kept
                // uniform with the DDAST callback).
                loop {
                    let cnt = wq.drain_batch_with(DAS_BATCH, &mut batch, |b| {
                        self.process_batch(worker_slot, b)
                    });
                    if cnt == 0 {
                        break;
                    }
                    processed += cnt as u64;
                }
            }
            // The external lane: the centralized manager owns the ingress
            // ring drain too (claim bit → pop chunk → batch path; the
            // re-raise inside keeps leftovers visible between chunks).
            loop {
                let cnt = self.drain_ingress(worker_slot, &mut batch, DAS_BATCH);
                if cnt == 0 {
                    break;
                }
                processed += cnt;
            }
            if processed > 0 {
                self.stats.mgr_activations.inc();
                self.stats.mgr_msgs.add(processed);
                idle = 0;
                continue;
            }
            if self.shutdown_requested() && self.quiescent() {
                break;
            }
            idle += 1;
            if idle < PARK_AFTER {
                // Spin/yield tiers only — the sleep tier starts at
                // PARK_AFTER and is replaced by the timed park below.
                idle_backoff(idle);
                continue;
            }
            self.watchdog_tick();
            self.pathology_tick();
            // Timed park on the DAS slot's own directory entry (the extra
            // slot beyond the workers — see the constructor). Formerly the
            // last blind `idle_backoff` sleep in the runtime: shutdown's
            // `wake_all` and the watchdog now cut the wait short instead of
            // waiting out the quantum. The park stays *timed*: message
            // pushes raise the directory, but a raise-wake may land on a
            // parked worker rather than this slot, so an indefinite park
            // could strand the queue — the timeout preserves the old
            // worst-case drain latency (one IDLE_RECHECK quantum) while
            // wakes make the common case prompt.
            let signals = self.queues.signals();
            if !signals.begin_park(worker_slot) {
                idle_backoff(idle);
                continue;
            }
            if self.queues.pending() > 0 || self.shutdown_requested() {
                signals.cancel_park(worker_slot);
                idle = PARK_RETRY_IDLE;
                continue;
            }
            signals.park_timeout(worker_slot, IDLE_RECHECK);
            idle = PARK_RETRY_IDLE;
        }
        clear_ctx();
    }

    /// Commit a park announced with [`SignalDirectory::begin_park`] after
    /// the caller's own re-check passed: **timed** at the old sleep
    /// cadence when work is visible this thread cannot act on (or a
    /// shutdown drain is in flight — `park_wake_condition`), indefinite —
    /// relying on wake edges — otherwise. Shared by `worker_loop` and
    /// `taskwait_on` so the two parking sites cannot drift. Returns the
    /// idle level the caller's backoff ladder resumes at: the retry tier
    /// after a wake (the reason is usually real work — skip the spin
    /// tier), the park threshold after a timeout (straight back to the
    /// announce → re-check → commit cycle after one progress attempt).
    fn commit_park(&self, worker: usize) -> u32 {
        let signals = self.queues.signals();
        self.trace_park(worker);
        // An armed wake-edge fault site may swallow the very wake an
        // indefinite park relies on: under such a plan every park is timed,
        // so injected wake losses stay inside the recovery envelope (the
        // recheck cadence redelivers what the fault withheld).
        let wake_faults_armed =
            self.fault_plan.as_ref().is_some_and(|p| p.armed(FaultSite::WakeEdge));
        let woke = if self.park_wake_condition() || wake_faults_armed {
            signals.park_timeout(worker, IDLE_RECHECK)
        } else {
            signals.park(worker);
            true
        };
        if woke {
            PARK_RETRY_IDLE
        } else {
            // Timed out with work visible this thread could not act on —
            // the cheap moment to ask whether everyone else is stuck too,
            // and the detector's moment to fold the events that piled up.
            self.watchdog_tick();
            self.pathology_tick();
            PARK_AFTER
        }
    }

    /// Re-check a worker's wake condition after
    /// [`SignalDirectory::begin_park`] published its parked bit: anything
    /// that should keep the worker awake — queued requests, ready tasks, a
    /// shutdown in flight. Plain/relaxed reads suffice: `begin_park`'s and
    /// `wake_parked`'s `SeqCst` fences close the store-buffer race, so
    /// either this sees the producer's work or the producer's wake scan
    /// sees the parked bit (substrate::signal module docs §Parking).
    /// (A stale directory raise — flag set, queue already drained — is
    /// deliberately *not* a wake condition: it carries no work, and keeping
    /// the worker awake on it would spin until someone reclaimed the flag.)
    #[inline]
    fn park_wake_condition(&self) -> bool {
        self.shutdown_requested()
            || self.queues.pending() > 0
            || self.ready.ready_count() > 0
    }

    /// The worker thread main loop. Fully idle workers park on the signal
    /// directory instead of sleeping blind (paper's idle threads "do
    /// runtime work instead of burning cycles" — and when there is no
    /// runtime work either, they now cost nothing and wake on the next
    /// enqueue rather than a sleep-quantum later).
    pub fn worker_loop(self: Arc<Self>, worker: usize) {
        install_ctx(&self, worker);
        let mut idle: u32 = 0;
        loop {
            if self.try_make_progress(worker) {
                idle = 0;
                continue;
            }
            if self.shutdown_requested() && self.quiescent() {
                break;
            }
            idle += 1;
            if idle < PARK_AFTER {
                idle_backoff(idle);
                continue;
            }
            // Event-driven parking replaces the blind sleep tier entirely:
            // announce, re-check, commit. Visible work this worker cannot
            // act on (a CentralDast worker cannot drain messages itself; a
            // Ddast worker may be over the MAX_DDAST_THREADS cap) and
            // shutdown drains no longer fall back to the 100 µs blind
            // sleep either — the worker commits to a *timed* park at the
            // same cadence, which a producer's wake (or
            // `request_shutdown`'s wake_all) cuts short. An indefinite
            // park never commits once the shutdown flag is visible (the
            // re-check sees it through `park_wake_condition`), so the exit
            // condition above is always reached; only the DAS thread still
            // sleeps blind (see `idle_backoff`).
            if !self.queues.signals().begin_park(worker) {
                // Slot already announced by another thread (an external
                // thread driving this pool worker's id — unreachable from
                // the pool itself, which owns its slots exclusively): the
                // polite ladder, never a yield-spin for the other
                // occupant's whole wait.
                idle_backoff(idle);
                continue;
            }
            idle = self.commit_park(worker);
        }
        clear_ctx();
    }
}

/// Idle iterations before a worker (or a taskwait) tries to park: past the
/// spin and yield tiers of [`idle_backoff`] — parking replaces the former
/// 100 µs blind sleep tier in the worker loop *and* in `taskwait_on`.
const PARK_AFTER: u32 = 256;

/// Idle level a worker resumes at after a park/cancel: skips the spin tier
/// (the wake reason is usually real work) but re-parks quickly if the work
/// was claimed by another worker.
const PARK_RETRY_IDLE: u32 = 16;

/// Messages per chunk of the DAS thread's drain-to-empty batch loop (the
/// centralized baseline predates the auto-tuned budget, so it keeps a
/// fixed chunk; the DDAST callback's budget is live — see `AutoTuner`).
const DAS_BATCH: usize = 64;

/// Cadence of the *timed* parks that replaced the blind sleep tier: where
/// the runtime once slept 100 µs unconditionally (visible-but-unactionable
/// work, shutdown drains), it now parks wakeably for the same quantum.
const IDLE_RECHECK: std::time::Duration = std::time::Duration::from_micros(100);

/// How long the runtime may go without useful work — while work is
/// outstanding and workers sit parked — before an idle pass declares a
/// stall and self-heals (re-raise + wake_all). 50 timed-park quanta: far
/// above any healthy scheduling gap, far below a test timeout.
const WATCHDOG_DEADLINE: Duration = Duration::from_millis(5);

/// Idle back-off: spin briefly, then yield, then sleep. The sleep tier
/// matters when the host is oversubscribed (more runtime threads than
/// cores — always true on this 1-core box): pure spin/yield starves
/// whoever holds actual work (e.g. the PJRT service thread). **No loop
/// reaches the blind sleep tier on a supported path anymore**: the worker
/// loop, `taskwait_on` *and* the DAS thread call this with
/// `idle < PARK_AFTER` — spin/yield tiers — and replace the sleep with
/// directory parking (timed via [`IDLE_RECHECK`] when work is visible
/// they cannot act on — always for the DAS slot — indefinite plus wake
/// edges otherwise). The one exception is the degenerate contended-slot
/// fallback (an external thread sharing a pool worker's id, where no
/// parker or wake edge is available): that keeps the full ladder rather
/// than yield-spinning a core away.
#[inline]
fn idle_backoff(idle: u32) {
    if idle < 16 {
        std::hint::spin_loop();
    } else if idle < 256 {
        std::thread::yield_now();
    } else {
        // One quantum, shared with the timed parks: the DAS thread's blind
        // sleep and every other thread's wakeable park stay on the same
        // cadence by construction.
        std::thread::sleep(IDLE_RECHECK);
    }
}

/// Bind this thread to `rt` as `worker` (main thread and pool threads).
pub fn install_ctx(rt: &Arc<RuntimeShared>, worker: usize) {
    CTX.with(|c| {
        *c.borrow_mut() = Some(WorkerCtx { rt: Arc::clone(rt), worker, task_stack: Vec::new() })
    });
}

pub fn clear_ctx() {
    CTX.with(|c| *c.borrow_mut() = None);
}

/// (runtime, worker id, current task) of the calling thread, if bound.
pub fn current_ctx() -> Option<(Arc<RuntimeShared>, usize, Arc<Wd>)> {
    CTX.with(|c| {
        c.borrow().as_ref().map(|ctx| {
            let cur = ctx.task_stack.last().cloned().unwrap_or_else(|| Arc::clone(&ctx.rt.root));
            (Arc::clone(&ctx.rt), ctx.worker, cur)
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dep::{dep_in, dep_out};
    use std::sync::atomic::AtomicUsize;

    fn rt(kind: RuntimeKind) -> Arc<RuntimeShared> {
        let rt = RuntimeShared::new(kind, 1, DdastParams::tuned(1), false, 42);
        if kind == RuntimeKind::Ddast {
            rt.register_ddast();
        }
        install_ctx(&rt, 0);
        rt
    }

    fn drain(rt: &Arc<RuntimeShared>) {
        let root = Arc::clone(&rt.root);
        rt.taskwait_on(0, &root);
    }

    #[test]
    fn sync_runs_dependent_tasks_in_order() {
        let rt = rt(RuntimeKind::Sync);
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        let (o1, o2) = (Arc::clone(&order), Arc::clone(&order));
        let root = Arc::clone(&rt.root);
        rt.spawn_from(0, &root, vec![dep_out(1)], "w", Box::new(move || o1.lock().unwrap().push(1)));
        rt.spawn_from(0, &root, vec![dep_in(1)], "r", Box::new(move || o2.lock().unwrap().push(2)));
        drain(&rt);
        assert_eq!(*order.lock().unwrap(), vec![1, 2]);
        assert_eq!(rt.stats.tasks_executed.get(), 2);
        clear_ctx();
    }

    #[test]
    fn ddast_single_thread_self_drains() {
        let rt = rt(RuntimeKind::Ddast);
        let hits = Arc::new(AtomicUsize::new(0));
        let root = Arc::clone(&rt.root);
        for i in 0..100u64 {
            let h = Arc::clone(&hits);
            rt.spawn_from(
                0,
                &root,
                vec![dep_inout_addr(i % 7)],
                "t",
                Box::new(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                }),
            );
        }
        drain(&rt);
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert!(rt.quiescent());
        assert!(rt.stats.mgr_activations.get() > 0, "the idle thread became a manager");
        clear_ctx();
    }

    fn dep_inout_addr(a: u64) -> Dependence {
        crate::coordinator::dep::dep_inout(a)
    }

    #[test]
    fn per_message_baseline_path_still_works() {
        // The per-message manager handlers are the retained reference
        // implementation (the runtime itself routes through
        // process_batch); play one full submit→run→done cycle through
        // them so the baseline cannot silently rot.
        let rt = rt(RuntimeKind::Ddast);
        let root = Arc::clone(&rt.root);
        rt.spawn_from(0, &root, vec![dep_out(5)], "t", Box::new(|| {}));
        assert_eq!(rt.queues.pending(), 1);
        let m = {
            let mut g = rt.queues.workers[0].submit.try_acquire().unwrap();
            g.pop().unwrap()
        };
        rt.process_submit(0, m.task);
        assert_eq!(rt.queues.pending(), 0);
        let task = rt.ready.get(0).expect("submit made the task ready");
        rt.run_task(0, task); // Ddast: enqueues the Done Task Message
        let m = {
            let mut g = rt.queues.workers[0].done.try_acquire().unwrap();
            g.pop().unwrap()
        };
        rt.process_done_msg(0, m);
        assert_eq!(rt.stats.tasks_outstanding.get(), 0);
        assert!(rt.quiescent(), "stale raises self-heal; all gauges settled");
        clear_ctx();
    }

    #[test]
    fn gomp_like_runs_everything() {
        let rt = rt(RuntimeKind::GompLike);
        let hits = Arc::new(AtomicUsize::new(0));
        let root = Arc::clone(&rt.root);
        for _ in 0..50 {
            let h = Arc::clone(&hits);
            rt.spawn_from(0, &root, vec![], "t", Box::new(move || {
                h.fetch_add(1, Ordering::Relaxed);
            }));
        }
        drain(&rt);
        assert_eq!(hits.load(Ordering::Relaxed), 50);
        clear_ctx();
    }

    #[test]
    fn deletion_protocol_reaches_deletable() {
        let rt = rt(RuntimeKind::Sync);
        let root = Arc::clone(&rt.root);
        let wd = rt.spawn_from(0, &root, vec![dep_out(9)], "t", Box::new(|| {}));
        drain(&rt);
        assert_eq!(wd.state(), WdState::Deletable);
        clear_ctx();
    }

    #[test]
    fn finalize_fires_child_completion_wake_edge() {
        let rt = rt(RuntimeKind::Sync);
        let root = Arc::clone(&rt.root);
        let wd = rt.spawn_from(0, &root, vec![], "t", Box::new(|| {}));
        let token = root.register_waiter(0).expect("slot starts empty");
        let task = rt.ready.get(0).expect("no-dep spawn is immediately ready");
        assert!(Arc::ptr_eq(&task, &wd));
        rt.run_task(0, task); // Sync: finalizes inline → last child → wake edge
        assert_eq!(rt.stats.taskwait_wake_edges.get(), 1);
        assert!(!root.waiter_registered(), "the finalizer claimed the registration");
        assert!(!root.clear_waiter(token), "claimed token is dead");
        // The targeted wake deposited a token on worker 0's parking slot:
        // the next park returns immediately instead of blocking.
        let signals = rt.queues.signals();
        assert!(signals.begin_park(0));
        signals.park(0);
        clear_ctx();
    }

    #[test]
    fn finalize_fires_dependence_targeted_wake_edge() {
        // The dep-edge mirror of the child-completion test above: the
        // waiter registers on the *predecessor's own* slot, and the
        // predecessor's finalizer wakes exactly that worker.
        let rt = rt(RuntimeKind::Sync);
        let root = Arc::clone(&rt.root);
        let pred = rt.spawn_from(0, &root, vec![], "pred", Box::new(|| {}));
        let token = pred.register_waiter(0).expect("slot starts empty");
        let task = rt.ready.get(0).expect("no-dep spawn is immediately ready");
        assert!(Arc::ptr_eq(&task, &pred));
        rt.run_task(0, task); // Sync: finalizes inline → DoneHandled → dep wake
        assert!(pred.done_handled());
        assert_eq!(rt.stats.dep_wake_edges.get(), 1);
        assert!(!pred.waiter_registered(), "the finalizer claimed the registration");
        assert!(!pred.clear_waiter(token), "claimed token is dead");
        // Point-to-point: the wake deposited a token on worker 0's slot,
        // no directory broadcast happened on this path.
        let signals = rt.queues.signals();
        assert!(signals.begin_park(0));
        signals.park(0);
        // taskwait_task on an already-finalized predecessor returns
        // without spinning up a park.
        rt.taskwait_task(0, &pred);
        clear_ctx();
    }

    #[test]
    fn taskwait_task_blocks_until_specific_predecessor() {
        let rt = rt(RuntimeKind::Sync);
        let root = Arc::clone(&rt.root);
        let hit = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hit);
        let pred = rt.spawn_from(0, &root, vec![dep_out(3)], "pred", Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        }));
        // Single-threaded Sync runtime: taskwait_task itself must execute
        // the predecessor via try_make_progress before returning.
        rt.taskwait_task(0, &pred);
        assert_eq!(hit.load(Ordering::Relaxed), 1);
        assert!(pred.done_handled());
        drain(&rt);
        clear_ctx();
    }

    #[test]
    fn panicking_task_fails_and_accounting_settles() {
        let rt = rt(RuntimeKind::Sync);
        let root = Arc::clone(&rt.root);
        let wd = rt.spawn_from(0, &root, vec![], "boomer", Box::new(|| panic!("boom")));
        drain(&rt);
        // The panic was contained: the task died Failed, finalized fully,
        // and the taskwait returned.
        assert_eq!(rt.stats.tasks_failed.get(), 1);
        assert_eq!(rt.stats.tasks_executed.get(), 0);
        assert_eq!(rt.stats.tasks_outstanding.get(), 0);
        assert_eq!(wd.state(), WdState::Deletable);
        assert!(rt.quiescent());
        let errs = rt.task_errors().expect("a failed run reports errors");
        assert_eq!(errs.tasks_failed, 1);
        assert_eq!(errs.tasks_cancelled, 0);
        let msg = errs.first_panic.expect("panic message recorded");
        assert!(msg.contains("boom") && msg.contains("boomer"), "{msg}");
        clear_ctx();
    }

    #[test]
    fn failed_task_poisons_dependents_transitively() {
        let rt = rt(RuntimeKind::Sync);
        let root = Arc::clone(&rt.root);
        let ran = Arc::new(AtomicUsize::new(0));
        rt.spawn_from(0, &root, vec![dep_out(1)], "head", Box::new(|| panic!("head died")));
        // A chain behind the head (In 1 → Out 2, then In 2) plus a sibling
        // reader: poison must flow through *released* edges transitively.
        let r1 = Arc::clone(&ran);
        let mid = rt.spawn_from(
            0,
            &root,
            vec![dep_in(1), dep_out(2)],
            "mid",
            Box::new(move || {
                r1.fetch_add(1, Ordering::Relaxed);
            }),
        );
        let r2 = Arc::clone(&ran);
        let tail = rt.spawn_from(0, &root, vec![dep_in(2)], "tail", Box::new(move || {
            r2.fetch_add(1, Ordering::Relaxed);
        }));
        let r3 = Arc::clone(&ran);
        let sib = rt.spawn_from(0, &root, vec![dep_in(1)], "sib", Box::new(move || {
            r3.fetch_add(1, Ordering::Relaxed);
        }));
        drain(&rt);
        assert_eq!(ran.load(Ordering::Relaxed), 0, "no poisoned body ran");
        assert_eq!(rt.stats.tasks_failed.get(), 1);
        assert_eq!(rt.stats.tasks_cancelled.get(), 3);
        for (wd, name) in [(&mid, "mid"), (&tail, "tail"), (&sib, "sib")] {
            assert_eq!(wd.state(), WdState::Deletable, "{name} finalized fully");
        }
        assert_eq!(rt.stats.tasks_outstanding.get(), 0);
        assert!(rt.quiescent(), "poisoned graph drains to quiescence");
        let errs = rt.task_errors().unwrap();
        assert_eq!((errs.tasks_failed, errs.tasks_cancelled), (1, 3));
        clear_ctx();
    }

    #[test]
    fn external_submissions_flow_through_the_ring() {
        let rt = RuntimeShared::new_full(
            RuntimeKind::Ddast,
            1,
            DdastParams::tuned(1),
            false,
            42,
            false,
            None,
            None,
            32,
        );
        rt.register_ddast();
        install_ctx(&rt, 0);
        let root = Arc::clone(&rt.root);
        let hits = Arc::new(AtomicUsize::new(0));
        let ext = {
            let rt2 = Arc::clone(&rt);
            let root2 = Arc::clone(&root);
            let h = Arc::clone(&hits);
            std::thread::spawn(move || {
                for i in 0..16u64 {
                    let h = Arc::clone(&h);
                    rt2.spawn_external(
                        &root2,
                        vec![dep_inout_addr(i % 3)],
                        "ext",
                        Box::new(move || {
                            h.fetch_add(1, Ordering::Relaxed);
                        }),
                    );
                }
            })
        };
        ext.join().unwrap();
        drain(&rt);
        assert_eq!(hits.load(Ordering::Relaxed), 16);
        assert_eq!(rt.stats.ingress_admitted.get(), 16);
        assert_eq!(rt.stats.tasks_executed.get(), 16);
        assert!(rt.quiescent(), "ring drained, external bit reclaimed");
        clear_ctx();
    }

    #[test]
    fn external_no_deps_submission_is_direct() {
        let rt = rt(RuntimeKind::Ddast);
        let root = Arc::clone(&rt.root);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        rt.spawn_external(&root, vec![], "ext", Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        }));
        assert_eq!(rt.stats.ingress_direct.get(), 1);
        assert_eq!(rt.queues.ingress_pending(), 0, "never touched the ring");
        drain(&rt);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        clear_ctx();
    }

    #[test]
    fn external_backpressure_rejects_and_rolls_back() {
        let rt = RuntimeShared::new_full(
            RuntimeKind::Ddast,
            1,
            DdastParams::tuned(1),
            false,
            42,
            false,
            None,
            None,
            2,
        );
        rt.register_ddast();
        install_ctx(&rt, 0);
        let root = Arc::clone(&rt.root);
        for _ in 0..2 {
            rt.try_spawn_external(&root, vec![dep_out(1)], "ext", Box::new(|| {}))
                .expect("ring has room");
        }
        let err = rt
            .try_spawn_external(&root, vec![dep_out(1)], "ext", Box::new(|| {}))
            .expect_err("ring full");
        assert_eq!(err, SubmitError::Busy);
        assert_eq!(rt.stats.ingress_rejected.get(), 1);
        assert_eq!(rt.stats.tasks_created.get(), 2, "rejected creation rolled back");
        assert_eq!(rt.root.children_live(), 2, "phantom child settled");
        drain(&rt); // the taskwait drives the dispatcher, draining the ring
        assert_eq!(rt.stats.tasks_executed.get(), 2);
        assert!(rt.quiescent());
        clear_ctx();
    }

    #[test]
    fn domain_failures_attribute_to_the_registered_cell() {
        let rt = rt(RuntimeKind::Sync);
        // A detached domain root, exactly as GraphDomain builds one.
        let dom_root = Wd::new(
            rt.fresh_task_id(),
            Vec::new(),
            "domain-root",
            std::sync::Weak::new(),
            Box::new(|| {}),
        );
        dom_root.set_state(WdState::Running);
        let cell = rt.register_domain(dom_root.id);
        rt.spawn_from(0, &dom_root, vec![dep_out(1)], "head", Box::new(|| panic!("dom boom")));
        rt.spawn_from(0, &dom_root, vec![dep_in(1)], "succ", Box::new(|| {}));
        // An innocent bystander under the implicit root.
        let root = Arc::clone(&rt.root);
        rt.spawn_from(0, &root, vec![dep_out(7)], "clean", Box::new(|| {}));
        rt.taskwait_on(0, &dom_root);
        drain(&rt);
        let errs = cell.summary().expect("domain cell records the failure");
        assert_eq!((errs.tasks_failed, errs.tasks_cancelled), (1, 1));
        assert!(errs.first_panic.unwrap().contains("dom boom"));
        // Global sticky counters see it too; the bystander ran clean.
        assert_eq!(rt.stats.tasks_executed.get(), 1);
        rt.deregister_domain(dom_root.id);
        clear_ctx();
    }

    #[test]
    fn outstanding_gauge_settles_to_zero() {
        let rt = rt(RuntimeKind::Ddast);
        let root = Arc::clone(&rt.root);
        for i in 0..20u64 {
            rt.spawn_from(0, &root, vec![dep_out(i)], "t", Box::new(|| {}));
        }
        drain(&rt);
        assert_eq!(rt.stats.tasks_outstanding.get(), 0);
        assert_eq!(rt.queues.pending(), 0);
        clear_ctx();
    }
}
