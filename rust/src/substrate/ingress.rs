//! Bounded multi-producer ingress ring for the external-submitter lane.
//!
//! The per-worker SPSC queues ([`crate::substrate::spsc`]) carry the pool's
//! own Submit/Done traffic: exactly one producer (the worker) and one
//! consumer (whichever manager claimed the worker's signal bit). Threads
//! *outside* the pool have no SPSC slot — giving every external client its
//! own registered slot would tie admission capacity to client count, which
//! is exactly what a serve-scale ingress must avoid. Instead, all external
//! producers share one bounded ring, and the managers drain it through the
//! same `MsgBatch` path as the SPSC plane.
//!
//! # Structure
//!
//! A fixed power-of-two array of slots, each carrying a sequence word
//! (Vyukov-style bounded MPMC queue). A producer claims slot `tail & mask`
//! by CAS-advancing `tail` once the slot's sequence says "empty for this
//! lap"; a consumer symmetrically claims `head & mask` once the sequence
//! says "full for this lap". The sequence word is the per-slot handoff:
//! `store(Release)` after writing the value, `load(Acquire)` before reading
//! it, so values are published without any shared lock. Competing producers
//! (or competing manager drains) only ever contend on the CAS — no producer
//! blocks another through a half-finished write.
//!
//! # Backpressure
//!
//! `try_push` never waits: when the ring is full for a whole lap it returns
//! the value to the caller (`Err`), and the `rejected` counter records the
//! admission failure. Bounded capacity is the admission control — under
//! saturation the request plane pushes back on clients instead of growing
//! an unbounded queue in the runtime.
//!
//! # No lost wakeups
//!
//! The ring itself only publishes values; waking a parked pool is the
//! caller's job (push, then raise the signal directory's external-producer
//! bit — see `SignalDirectory::raise_external`, which issues the producer-
//! side fence of the park protocol).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::substrate::deque::CachePadded;
use crate::substrate::stats::Counter;

struct Slot<T> {
    /// Lap marker: `index` when empty and writable by the producer that
    /// claims `tail == index`; `index + 1` when full and readable by the
    /// consumer that claims `head == index`; `index + capacity` after
    /// consumption (empty for the next lap).
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded Vyukov-style MPMC ring. See the module docs.
pub struct IngressRing<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    /// Consumer cursor (managers compete here).
    head: CachePadded<AtomicUsize>,
    /// Producer cursor (external submitters compete here).
    tail: CachePadded<AtomicUsize>,
    /// Accepted pushes.
    pushes: Counter,
    /// Successful pops.
    pops: Counter,
    /// `try_push` rejections (ring full: backpressure engaged).
    rejected: Counter,
}

// SAFETY: values move through slots guarded by the per-slot sequence
// protocol; a slot is only read/written by the thread that won the
// corresponding cursor CAS for that lap.
unsafe impl<T: Send> Send for IngressRing<T> {}
unsafe impl<T: Send> Sync for IngressRing<T> {}

impl<T> IngressRing<T> {
    /// A ring with at least `capacity` slots (rounded up to a power of
    /// two, minimum 2).
    pub fn new(capacity: usize) -> IngressRing<T> {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Box<[Slot<T>]> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        IngressRing {
            slots,
            mask: cap - 1,
            head: CachePadded::new(AtomicUsize::new(0)),
            tail: CachePadded::new(AtomicUsize::new(0)),
            pushes: Counter::new(),
            pops: Counter::new(),
            rejected: Counter::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Non-blocking admission: `Err(value)` hands the value back when the
    /// ring is full (backpressure).
    pub fn try_push(&self, value: T) -> Result<(), T> {
        let mut tail = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[tail & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - tail as isize;
            if dif == 0 {
                // Slot empty for this lap: race other producers for it.
                match self.tail.compare_exchange_weak(
                    tail,
                    tail.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the tail CAS grants exclusive
                        // write access to this slot for this lap.
                        unsafe { (*slot.val.get()).write(value) };
                        slot.seq.store(tail.wrapping_add(1), Ordering::Release);
                        self.pushes.inc();
                        return Ok(());
                    }
                    Err(observed) => tail = observed,
                }
            } else if dif < 0 {
                // A whole lap behind: full. Reject — this is the
                // admission-control edge, not an error.
                self.rejected.inc();
                return Err(value);
            } else {
                // Another producer claimed this tail; reload and retry.
                tail = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Pop one value if available. Managers may compete here; losers retry
    /// on the next slot or observe empty.
    pub fn try_pop(&self) -> Option<T> {
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[head & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - (head.wrapping_add(1)) as isize;
            if dif == 0 {
                // Slot full for this lap: race other consumers for it.
                match self.head.compare_exchange_weak(
                    head,
                    head.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the head CAS grants exclusive
                        // read access to this slot for this lap.
                        let value = unsafe { (*slot.val.get()).assume_init_read() };
                        slot.seq
                            .store(head.wrapping_add(self.mask + 1), Ordering::Release);
                        self.pops.inc();
                        return Some(value);
                    }
                    Err(observed) => head = observed,
                }
            } else if dif < 0 {
                // Not yet produced: empty (or a producer mid-write).
                return None;
            } else {
                head = self.head.load(Ordering::Relaxed);
            }
        }
    }

    /// Entries currently resident (approximate under concurrency, exact
    /// when quiescent).
    pub fn len(&self) -> usize {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Relaxed);
        tail.wrapping_sub(head).min(self.slots.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (accepted pushes, pops, rejected pushes).
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.pushes.get(), self.pops.get(), self.rejected.get())
    }
}

impl<T> Drop for IngressRing<T> {
    fn drop(&mut self) {
        // Drain undelivered values so their destructors run.
        while self.try_pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_capacity() {
        let ring = IngressRing::new(8);
        assert_eq!(ring.capacity(), 8);
        for i in 0..8 {
            assert!(ring.try_push(i).is_ok());
        }
        assert_eq!(ring.len(), 8);
        for i in 0..8 {
            assert_eq!(ring.try_pop(), Some(i));
        }
        assert_eq!(ring.try_pop(), None);
        assert!(ring.is_empty());
    }

    #[test]
    fn full_ring_rejects_and_counts() {
        let ring = IngressRing::new(2);
        assert!(ring.try_push(1).is_ok());
        assert!(ring.try_push(2).is_ok());
        assert_eq!(ring.try_push(3), Err(3));
        let (pushes, pops, rejected) = ring.stats();
        assert_eq!((pushes, pops, rejected), (2, 0, 1));
        assert_eq!(ring.try_pop(), Some(1));
        assert!(ring.try_push(3).is_ok());
        assert_eq!(ring.try_pop(), Some(2));
        assert_eq!(ring.try_pop(), Some(3));
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        let ring: IngressRing<u32> = IngressRing::new(5);
        assert_eq!(ring.capacity(), 8);
        let tiny: IngressRing<u32> = IngressRing::new(0);
        assert_eq!(tiny.capacity(), 2);
    }

    #[test]
    fn wraps_many_laps() {
        let ring = IngressRing::new(4);
        for lap in 0..1000u64 {
            assert!(ring.try_push(lap).is_ok());
            assert_eq!(ring.try_pop(), Some(lap));
        }
        assert!(ring.is_empty());
    }

    #[test]
    fn concurrent_producers_lose_nothing() {
        const PRODUCERS: usize = 4;
        const PER: u64 = 5_000;
        let ring = Arc::new(IngressRing::new(64));
        let mut handles = Vec::new();
        for p in 0..PRODUCERS as u64 {
            let r = Arc::clone(&ring);
            handles.push(std::thread::spawn(move || {
                for i in 0..PER {
                    let mut v = p * PER + i;
                    loop {
                        match r.try_push(v) {
                            Ok(()) => break,
                            Err(back) => {
                                v = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            }));
        }
        let consumer = {
            let r = Arc::clone(&ring);
            std::thread::spawn(move || {
                let mut seen = vec![false; PRODUCERS * PER as usize];
                let mut got = 0usize;
                while got < seen.len() {
                    match r.try_pop() {
                        Some(v) => {
                            assert!(!seen[v as usize], "duplicate delivery of {v}");
                            seen[v as usize] = true;
                            got += 1;
                        }
                        None => std::thread::yield_now(),
                    }
                }
                assert_eq!(r.try_pop(), None);
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        consumer.join().unwrap();
        let (pushes, pops, _) = ring.stats();
        assert_eq!(pushes, PRODUCERS as u64 * PER);
        assert_eq!(pops, PRODUCERS as u64 * PER);
    }

    #[test]
    fn drop_releases_undelivered_values() {
        let payload = Arc::new(());
        {
            let ring = IngressRing::new(4);
            ring.try_push(Arc::clone(&payload)).unwrap();
            ring.try_push(Arc::clone(&payload)).unwrap();
            assert_eq!(Arc::strong_count(&payload), 3);
        }
        assert_eq!(Arc::strong_count(&payload), 1);
    }
}
