//! Deterministic xorshift64* RNG.
//!
//! Used everywhere randomness is needed (victim selection for work stealing,
//! SparseLU sparsity pattern, synthetic DAGs, simulator jitter) so that runs
//! are reproducible bit-for-bit given a seed — criterion benches and the
//! figure regeneration depend on that.

/// xorshift64* — tiny, fast, passes BigCrush on the high bits.
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // 0 is a fixed point of xorshift; displace it.
        XorShift64 { state: seed.max(1).wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    /// One xorshift64* step on a raw state word: `(next_state, output)`.
    /// Lets callers keep the state in an atomic/`Cell` slot (e.g. the ready
    /// pools' per-slot victim RNG) without constructing a struct per draw.
    /// `state` must be nonzero (guaranteed for states produced by
    /// [`XorShift64::new`]/[`XorShift64::state`]: xorshift never reaches 0
    /// from a nonzero state).
    #[inline]
    pub fn step(state: u64) -> (u64, u64) {
        debug_assert_ne!(state, 0, "xorshift64 zero fixed point");
        let mut x = state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        (x, x.wrapping_mul(0x2545F4914F6CDD1D))
    }

    /// The raw state word (seed material for an external `step`-driven slot).
    #[inline]
    pub fn state(&self) -> u64 {
        self.state
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let (state, out) = Self::step(self.state);
        self.state = state;
        out
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift range reduction (Lemire); bias negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform float in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn step_matches_struct_sequence() {
        let mut r = XorShift64::new(42);
        let mut s = XorShift64::new(42).state();
        for _ in 0..100 {
            let (next, out) = XorShift64::step(s);
            s = next;
            assert_eq!(out, r.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = XorShift64::new(7);
        for n in [1u64, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.next_below(n) < n);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval_and_spread() {
        let mut r = XorShift64::new(9);
        let mut lo = 0usize;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            if v < 0.5 {
                lo += 1;
            }
        }
        assert!((4000..6000).contains(&lo), "lo={lo}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift64::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
