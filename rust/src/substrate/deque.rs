//! Lock-free scheduling substrate: a Chase–Lev style work-stealing deque,
//! a cache-line padding wrapper and a sharded (per-thread-cell) counter.
//!
//! §4's DBF policy needs three operations per ready pool: the owner pushes
//! released tasks at the back, the owner pops its own work FIFO from the
//! front, and idle threads steal the *newest* task from the back. The seed
//! implemented all three under one `SpinLock<VecDeque>` per pool plus one
//! global `ready_count` atomic — every scheduling action was a potential
//! contended RMW, so the Sync-vs-DDAST curves partly measured our own lock,
//! not the paper's contention (see EXPERIMENTS.md §Lock-free hot paths).
//!
//! [`WsDeque`] splits the ends:
//!
//! * **front** (`top`): consumed by a single CAS, Chase–Lev's steal
//!   operation. Safe from *any* thread; the owner uses it for its FIFO pop.
//! * **back** (`bottom`): the push/steal-back end. Back movers are
//!   serialized by a one-bit token (an uncontended CAS in the common case);
//!   under the token the classic Chase–Lev `pop_bottom` protocol resolves
//!   the last-element race against concurrent front CASes.
//!
//! The token departs from textbook Chase–Lev (whose bottom end is bound to
//! one owner *thread*) because our runtime has legitimate multi-pusher
//! slots: the CentralDast DAS thread wraps onto worker 0's pool, and
//! DBF thieves take from the back. Serializing only the back keeps the hot
//! owner pop (front CAS) entirely lock-free while making every back op a
//! single uncontended CAS unless a back-steal is racing the owner — exactly
//! the contention the `token_stats()` counters expose. The memory ordering
//! discipline follows Lê, Pop, Cohen & Nardelli, "Correct and Efficient
//! Work-Stealing for Weakly Ordered Memory Models" (PPoPP'13).
//!
//! Counters mirror [`SpinLock::stats`](crate::substrate::SpinLock::stats)
//! so `sim::calibrate` and the A/B bench read old and new structures with
//! the same vocabulary: token (acquisitions, contended, spins) for the back
//! end, CAS (attempts, retries) for the front end.

use std::cell::{Cell, UnsafeCell};
use std::mem::MaybeUninit;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicIsize, AtomicPtr, AtomicU64, AtomicUsize, Ordering};

// ---------------------------------------------------------------------------
// CachePadded
// ---------------------------------------------------------------------------

/// Pads and aligns `T` to 128 bytes so neighbouring values never share a
/// cache line (128 covers the spatial-prefetcher pair on x86 and the 128 B
/// lines on some POWER/Apple cores — the machines in the paper's Table 1).
#[derive(Default, Debug)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    pub const fn new(value: T) -> Self {
        CachePadded { value }
    }

    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> Self {
        CachePadded::new(value)
    }
}

// ---------------------------------------------------------------------------
// ShardedCounter
// ---------------------------------------------------------------------------

/// Floor on the default cell count of a [`ShardedCounter`] (the seed's
/// fixed size). [`ShardedCounter::new`] sizes up from here when the host
/// has more cores; structures that know their real thread count size
/// exactly with [`ShardedCounter::with_shards`].
const MIN_COUNTER_SHARDS: usize = 16;

/// Hard cap on cells: bounds the sweep cost of `get`/`exact` (and the
/// memory: 128 B per padded cell).
const MAX_COUNTER_SHARDS: usize = 256;

static NEXT_SHARD_ID: AtomicUsize = AtomicUsize::new(0);

/// Cached default cell count (0 = not yet computed).
static DEFAULT_SHARDS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static SHARD_ID: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// Process-wide round-robin thread id (NOT masked: each counter masks by
/// its own cell count, so differently-sized counters coexist).
#[inline]
fn shard_id() -> usize {
    SHARD_ID.with(|c| {
        let mut id = c.get();
        if id == usize::MAX {
            id = NEXT_SHARD_ID.fetch_add(1, Ordering::Relaxed);
            c.set(id);
        }
        id
    })
}

/// Default cell count: the host's parallelism rounded up to a power of
/// two, floored at the seed's 16. Computed once (the syscall behind
/// `available_parallelism` is not free) and cached.
fn default_shards() -> usize {
    let cached = DEFAULT_SHARDS.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let n = n.next_power_of_two().clamp(MIN_COUNTER_SHARDS, MAX_COUNTER_SHARDS);
    DEFAULT_SHARDS.store(n, Ordering::Relaxed);
    n
}

/// A gauge counter striped over per-thread cache-padded cells.
///
/// `inc`/`dec`/`add`/`sub` touch only the calling thread's cell — no shared
/// RMW on the hot path, unlike [`Counter`](crate::substrate::Counter) where
/// every scheduling action bounced one global cache line between cores.
/// Cells are signed: a task pushed on thread A and popped on thread B leaves
/// A's cell positive and B's negative; only the *sum* is meaningful.
///
/// The cell count is per instance: the seed's fixed 16 cells silently
/// collided threads 17+ onto shared lines (the round-robin ids wrap at the
/// mask). Owners that know their thread count size exactly with
/// [`ShardedCounter::with_shards`]; [`ShardedCounter::new`] sizes from the
/// host's parallelism, floored at the seed's 16 so nothing shrinks.
///
/// Reads come in two strengths:
/// * [`ShardedCounter::get`] — a relaxed sweep; cheap, monotonic enough for
///   gauges and the `MIN_READY_TASKS` heuristic's inner fast checks;
/// * [`ShardedCounter::exact`] — a fenced double-sweep that only returns
///   when two consecutive sweeps agree, for decisions that must not act on
///   a torn read (`quiescent()`, the DDAST callback's break conditions).
pub struct ShardedCounter {
    cells: Box<[CachePadded<AtomicI64>]>,
}

impl Default for ShardedCounter {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardedCounter {
    /// Default-sized counter (host parallelism, floored at 16 cells) —
    /// for owners that cannot know their thread count up front.
    pub fn new() -> Self {
        Self::with_shards(default_shards())
    }

    /// Counter sized for `threads` concurrent updaters: cells = the next
    /// power of two ≥ `threads`, clamped to `1..=MAX_COUNTER_SHARDS`, so
    /// round-robin thread ids spread without colliding (the regression the
    /// seed's fixed 16 hit beyond 16 threads).
    pub fn with_shards(threads: usize) -> Self {
        let n = threads.max(1).next_power_of_two().min(MAX_COUNTER_SHARDS);
        ShardedCounter { cells: (0..n).map(|_| CachePadded::new(AtomicI64::new(0))).collect() }
    }

    /// Number of cells (power of two).
    #[inline]
    pub fn num_shards(&self) -> usize {
        self.cells.len()
    }

    /// The calling thread's cell.
    #[inline]
    fn cell(&self) -> &AtomicI64 {
        &self.cells[shard_id() & (self.cells.len() - 1)]
    }

    #[inline]
    pub fn inc(&self) {
        self.cell().fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.cell().fetch_add(n as i64, Ordering::Relaxed);
    }

    #[inline]
    pub fn dec(&self) {
        self.cell().fetch_sub(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn sub(&self, n: u64) {
        self.cell().fetch_sub(n as i64, Ordering::Relaxed);
    }

    /// Relaxed sweep over the cells. Transiently off by in-flight updates;
    /// never negative (clamped).
    #[inline]
    pub fn get(&self) -> u64 {
        let sum: i64 = self.cells.iter().map(|c| c.load(Ordering::Relaxed)).sum();
        sum.max(0) as u64
    }

    /// Exact-read fallback: fenced sweeps repeated until two agree (bounded
    /// retries; returns the freshest sweep if the counter won't settle —
    /// callers re-poll in loops, so a transient misread self-corrects).
    pub fn exact(&self) -> u64 {
        let sweep = || -> i64 {
            std::sync::atomic::fence(Ordering::SeqCst);
            self.cells.iter().map(|c| c.load(Ordering::SeqCst)).sum()
        };
        let mut prev = sweep();
        for _ in 0..3 {
            let cur = sweep();
            if cur == prev {
                break;
            }
            prev = cur;
        }
        prev.max(0) as u64
    }

    /// Reset all cells (bench harness between A/B phases).
    pub fn reset(&self) {
        for c in self.cells.iter() {
            c.store(0, Ordering::Relaxed);
        }
    }
}

impl std::fmt::Debug for ShardedCounter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedCounter").field("sum", &self.get()).finish()
    }
}

// ---------------------------------------------------------------------------
// WsDeque
// ---------------------------------------------------------------------------

/// Growable circular buffer of the deque. Slots are `MaybeUninit`: liveness
/// is tracked solely by the `top`/`bottom` indices, and retired generations
/// keep their (bitwise-copied) contents unread-able only through stale
/// thieves whose CAS then fails.
struct Buffer<T> {
    mask: usize,
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
}

impl<T> Buffer<T> {
    fn alloc(cap: usize) -> *mut Buffer<T> {
        debug_assert!(cap.is_power_of_two());
        Box::into_raw(Box::new(Buffer {
            mask: cap - 1,
            slots: (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect(),
        }))
    }

    #[inline]
    fn cap(&self) -> usize {
        self.mask + 1
    }

    /// Bitwise-read index `i`. Caller owns the value only after it wins the
    /// index race (CAS or token); otherwise it must `mem::forget` the copy.
    #[inline]
    unsafe fn read(&self, i: isize) -> T {
        (*self.slots[i as usize & self.mask].get()).assume_init_read()
    }

    #[inline]
    unsafe fn write(&self, i: isize, value: T) {
        (*self.slots[i as usize & self.mask].get()).write(value);
    }
}

/// Result of one [`WsDeque::steal_front`] attempt.
#[derive(Debug, PartialEq, Eq)]
pub enum Steal<T> {
    /// The deque was observed empty.
    Empty,
    /// Lost a CAS race with another consumer; retrying may succeed.
    Retry,
    /// Won an element.
    Success(T),
}

/// Work-stealing deque (see module docs for the design and its relation to
/// Chase–Lev).
pub struct WsDeque<T> {
    /// Front index; grows monotonically, consumed by CAS (`steal_front`).
    top: CachePadded<AtomicIsize>,
    /// Back index; moved only under `token`.
    bottom: CachePadded<AtomicIsize>,
    /// Current buffer generation. Written under `token` (grow), read by
    /// thieves with `Acquire`.
    buffer: AtomicPtr<Buffer<T>>,
    /// One-bit token serializing back-end movers (push / pop_back /
    /// steal_back / grow).
    token: CachePadded<AtomicBool>,
    /// Retired buffer generations, freed on `Drop` (stale thieves may still
    /// hold pointers into them, so they stay mapped for the deque's life;
    /// geometric growth bounds the waste at ~1× the final buffer).
    retired: UnsafeCell<Vec<*mut Buffer<T>>>,
    // -- telemetry (mirrors SpinLock::stats vocabulary) --------------------
    token_acquisitions: AtomicU64,
    token_contended: AtomicU64,
    token_spins: AtomicU64,
    cas_attempts: AtomicU64,
    cas_retries: AtomicU64,
}

// SAFETY: `T: Send` values move between threads through the deque; all
// shared mutable state is behind atomics, the back token, or (for
// `retired`) the token-holder-only invariant.
unsafe impl<T: Send> Send for WsDeque<T> {}
unsafe impl<T: Send> Sync for WsDeque<T> {}

const INITIAL_CAP: usize = 64;

impl<T> Default for WsDeque<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> WsDeque<T> {
    pub fn new() -> Self {
        WsDeque {
            top: CachePadded::new(AtomicIsize::new(0)),
            bottom: CachePadded::new(AtomicIsize::new(0)),
            buffer: AtomicPtr::new(Buffer::alloc(INITIAL_CAP)),
            token: CachePadded::new(AtomicBool::new(false)),
            retired: UnsafeCell::new(Vec::new()),
            token_acquisitions: AtomicU64::new(0),
            token_contended: AtomicU64::new(0),
            token_spins: AtomicU64::new(0),
            cas_attempts: AtomicU64::new(0),
            cas_retries: AtomicU64::new(0),
        }
    }

    /// Elements currently in the deque (racy snapshot; never negative).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    // -- back token --------------------------------------------------------

    #[inline]
    fn acquire_token(&self) {
        let mut spins: u64 = 0;
        while self
            .token
            .compare_exchange_weak(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            spins += 1;
            std::hint::spin_loop();
            if spins % 64 == 0 {
                std::thread::yield_now();
            }
        }
        self.token_acquisitions.fetch_add(1, Ordering::Relaxed);
        if spins > 0 {
            self.token_contended.fetch_add(1, Ordering::Relaxed);
            self.token_spins.fetch_add(spins, Ordering::Relaxed);
        }
    }

    /// One-shot token grab. Mirrors `SpinLock::try_lock`: a successful grab
    /// counts as an acquisition, a failed one counts nothing (the caller
    /// skips ahead instead of spinning).
    #[inline]
    fn try_acquire_token(&self) -> bool {
        let ok = self
            .token
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok();
        if ok {
            self.token_acquisitions.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    #[inline]
    fn release_token(&self) {
        self.token.store(false, Ordering::Release);
    }

    // -- operations --------------------------------------------------------

    /// Push at the back. Constant-time; contends only with a concurrent
    /// back-steal on the same deque.
    pub fn push(&self, value: T) {
        self.acquire_token();
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = self.buffer.load(Ordering::Relaxed);
        // SAFETY: token held — sole back mover; `buf` is the live generation.
        unsafe {
            if (b - t) as usize >= (*buf).cap() {
                buf = self.grow(buf, t, b);
            }
            (*buf).write(b, value);
        }
        self.bottom.store(b + 1, Ordering::Release);
        self.release_token();
    }

    /// Grow to the next power of two, copying live indices `t..b`. Token
    /// must be held. The old generation is retired, not freed: thieves may
    /// hold its pointer; their top CAS validates anything they read from it.
    unsafe fn grow(&self, old: *mut Buffer<T>, t: isize, b: isize) -> *mut Buffer<T> {
        let new = Buffer::alloc((*old).cap() * 2);
        for i in t..b {
            let slot = (*(*old).slots[i as usize & (*old).mask].get()).as_ptr();
            (*new).write(i, std::ptr::read(slot));
        }
        self.buffer.store(new, Ordering::Release);
        // SAFETY: token held — only back movers touch `retired` until Drop.
        (*self.retired.get()).push(old);
        new
    }

    /// Pop the newest element from the back (the DBF thief's choice and a
    /// LIFO/depth-first owner policy). Runs Chase–Lev's `pop_bottom`
    /// protocol under the token, so it is safe from any thread.
    pub fn pop_back(&self) -> Option<T> {
        self.acquire_token();
        let result = self.pop_back_locked();
        self.release_token();
        result
    }

    /// `pop_back` that refuses to wait: if the back token is busy (the
    /// owner is mid-push or another thief is mid-steal), returns `None`
    /// immediately so a DBF thief can move on to the next victim — the
    /// same skip-ahead the seed got from `SpinLock::try_lock`.
    pub fn steal_back(&self) -> Option<T> {
        if !self.try_acquire_token() {
            return None;
        }
        let result = self.pop_back_locked();
        self.release_token();
        result
    }

    /// Chase–Lev `pop_bottom`. The back token must be held.
    fn pop_back_locked(&self) -> Option<T> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = self.buffer.load(Ordering::Relaxed);
        self.bottom.store(b, Ordering::Relaxed);
        // The SeqCst fence orders the `bottom` store before the `top` load
        // against the symmetric pair in `steal_front` (PPoPP'13 Fig. 1).
        std::sync::atomic::fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            // SAFETY: index b is outside every front-thief's range (they
            // only take indices < bottom == b); last-element case re-checked
            // below by CAS.
            let value = unsafe { (*buf).read(b) };
            if t == b {
                // Last element: race the front CAS for it.
                if self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_err()
                {
                    // A front consumer won; our bitwise copy is dead.
                    std::mem::forget(value);
                    self.bottom.store(b + 1, Ordering::Relaxed);
                    None
                } else {
                    self.bottom.store(b + 1, Ordering::Relaxed);
                    Some(value)
                }
            } else {
                Some(value)
            }
        } else {
            // Empty: restore the canonical bottom.
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// One attempt to take the oldest element from the front. Pure CAS —
    /// no token, callable from any thread (the owner's FIFO pop and the
    /// drain path both use it).
    pub fn steal_front(&self) -> Steal<T> {
        let t = self.top.load(Ordering::Acquire);
        std::sync::atomic::fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        self.cas_attempts.fetch_add(1, Ordering::Relaxed);
        let buf = self.buffer.load(Ordering::Acquire);
        // SAFETY: bitwise copy; ownership is established only by the CAS
        // below, otherwise the copy is forgotten. The buffer generation we
        // loaded holds index t's bits for as long as t may still win a CAS
        // (retired generations stay mapped until Drop).
        let value = unsafe { (*buf).read(t) };
        if self
            .top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
            .is_ok()
        {
            Steal::Success(value)
        } else {
            std::mem::forget(value);
            self.cas_retries.fetch_add(1, Ordering::Relaxed);
            Steal::Retry
        }
    }

    /// Take the oldest element, retrying lost races until success or empty.
    /// Each lost CAS means another consumer succeeded — globally lock-free.
    pub fn pop_front(&self) -> Option<T> {
        loop {
            match self.steal_front() {
                Steal::Success(v) => return Some(v),
                Steal::Empty => return None,
                Steal::Retry => std::hint::spin_loop(),
            }
        }
    }

    // -- telemetry ---------------------------------------------------------

    /// Back-token statistics: (acquisitions, contended acquisitions, spin
    /// iterations) — same triple as [`SpinLock::stats`](crate::substrate::SpinLock::stats).
    pub fn token_stats(&self) -> (u64, u64, u64) {
        (
            self.token_acquisitions.load(Ordering::Relaxed),
            self.token_contended.load(Ordering::Relaxed),
            self.token_spins.load(Ordering::Relaxed),
        )
    }

    /// Front-CAS statistics: (attempts, lost races).
    pub fn cas_stats(&self) -> (u64, u64) {
        (self.cas_attempts.load(Ordering::Relaxed), self.cas_retries.load(Ordering::Relaxed))
    }

    pub fn reset_stats(&self) {
        self.token_acquisitions.store(0, Ordering::Relaxed);
        self.token_contended.store(0, Ordering::Relaxed);
        self.token_spins.store(0, Ordering::Relaxed);
        self.cas_attempts.store(0, Ordering::Relaxed);
        self.cas_retries.store(0, Ordering::Relaxed);
    }
}

impl<T> Drop for WsDeque<T> {
    fn drop(&mut self) {
        // Exclusive access (`&mut self`): drop live elements, then free the
        // current and retired generations.
        while self.pop_back().is_some() {}
        unsafe {
            drop(Box::from_raw(self.buffer.load(Ordering::Relaxed)));
            for p in (*self.retired.get()).drain(..) {
                drop(Box::from_raw(p));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn fifo_from_front_lifo_from_back() {
        let d: WsDeque<u64> = WsDeque::new();
        d.push(1);
        d.push(2);
        d.push(3);
        assert_eq!(d.len(), 3);
        assert_eq!(d.pop_front(), Some(1), "front is FIFO");
        assert_eq!(d.pop_back(), Some(3), "back is LIFO");
        assert_eq!(d.pop_front(), Some(2));
        assert_eq!(d.pop_front(), None);
        assert_eq!(d.pop_back(), None);
        assert!(d.is_empty());
    }

    #[test]
    fn grows_past_initial_capacity() {
        let d: WsDeque<usize> = WsDeque::new();
        let n = INITIAL_CAP * 4 + 3;
        for i in 0..n {
            d.push(i);
        }
        assert_eq!(d.len(), n);
        for i in 0..n {
            assert_eq!(d.pop_front(), Some(i), "order survives growth");
        }
        assert!(d.is_empty());
    }

    #[test]
    fn grow_interleaved_with_consumption_keeps_order() {
        let d: WsDeque<usize> = WsDeque::new();
        let mut expect_front = 0usize;
        let mut next = 0usize;
        for round in 0..8 {
            for _ in 0..(INITIAL_CAP / 2 + round) {
                d.push(next);
                next += 1;
            }
            for _ in 0..(INITIAL_CAP / 4) {
                assert_eq!(d.pop_front(), Some(expect_front));
                expect_front += 1;
            }
        }
        while let Some(v) = d.pop_front() {
            assert_eq!(v, expect_front);
            expect_front += 1;
        }
        assert_eq!(expect_front, next);
    }

    #[test]
    fn drop_releases_remaining_elements() {
        let marker = Arc::new(());
        {
            let d: WsDeque<Arc<()>> = WsDeque::new();
            for _ in 0..100 {
                d.push(Arc::clone(&marker));
            }
            // d dropped with 100 live elements.
        }
        assert_eq!(Arc::strong_count(&marker), 1, "no leak, no double-drop");
    }

    /// 1 owner pushes + back-pops, N thieves front-steal: every element is
    /// consumed exactly once (no loss, no duplication).
    #[test]
    fn stress_front_stealers_vs_owner() {
        const PER: u64 = 20_000;
        const THIEVES: usize = 3;
        let d: Arc<WsDeque<u64>> = Arc::new(WsDeque::new());
        let done = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..THIEVES {
            let d = Arc::clone(&d);
            let done = Arc::clone(&done);
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match d.steal_front() {
                        Steal::Success(v) => got.push(v),
                        Steal::Retry => std::hint::spin_loop(),
                        Steal::Empty => {
                            if done.load(Ordering::Acquire) && d.is_empty() {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                got
            }));
        }
        let mut owner_got = Vec::new();
        for i in 0..PER {
            d.push(i);
            if i % 3 == 0 {
                if let Some(v) = d.pop_back() {
                    owner_got.push(v);
                }
            }
        }
        done.store(true, Ordering::Release);
        let mut all: Vec<u64> = owner_got;
        for h in handles {
            all.extend(h.join().unwrap());
        }
        while let Some(v) = d.pop_front() {
            all.push(v);
        }
        assert_eq!(all.len() as u64, PER, "every element consumed exactly once");
        let set: HashSet<u64> = all.iter().copied().collect();
        assert_eq!(set.len() as u64, PER, "no duplicates");
    }

    /// Mixed ends under load: thieves use the token'd back-steal while the
    /// owner front-pops — the ReadyPools configuration.
    #[test]
    fn stress_back_stealers_vs_front_owner() {
        const PER: u64 = 20_000;
        const THIEVES: usize = 2;
        let d: Arc<WsDeque<u64>> = Arc::new(WsDeque::new());
        let done = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..THIEVES {
            let d = Arc::clone(&d);
            let done = Arc::clone(&done);
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match d.steal_back() {
                        Some(v) => got.push(v),
                        None => {
                            if done.load(Ordering::Acquire) && d.is_empty() {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                got
            }));
        }
        let mut owner_got = Vec::new();
        for i in 0..PER {
            d.push(i);
            if i % 2 == 0 {
                if let Some(v) = d.pop_front() {
                    owner_got.push(v);
                }
            }
        }
        done.store(true, Ordering::Release);
        let mut all: Vec<u64> = owner_got;
        for h in handles {
            all.extend(h.join().unwrap());
        }
        while let Some(v) = d.pop_front() {
            all.push(v);
        }
        assert_eq!(all.len() as u64, PER);
        let set: HashSet<u64> = all.iter().copied().collect();
        assert_eq!(set.len() as u64, PER);
    }

    #[test]
    fn telemetry_counts_operations() {
        let d: WsDeque<u32> = WsDeque::new();
        d.push(1);
        d.push(2);
        let _ = d.pop_front();
        let (acq, _, _) = d.token_stats();
        assert_eq!(acq, 2, "two back ops (pushes)");
        let (attempts, retries) = d.cas_stats();
        assert_eq!(attempts, 1);
        assert_eq!(retries, 0, "uncontended front pop never retries");
        d.reset_stats();
        assert_eq!(d.token_stats(), (0, 0, 0));
        assert_eq!(d.cas_stats(), (0, 0));
    }

    #[test]
    fn sharded_counter_settles_across_threads() {
        let c = Arc::new(ShardedCounter::new());
        let mut handles = Vec::new();
        for k in 0..4u64 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    c.inc();
                }
                if k % 2 == 0 {
                    for _ in 0..10_000 {
                        c.dec();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.exact(), 20_000);
        assert_eq!(c.get(), 20_000);
        c.reset();
        assert_eq!(c.exact(), 0);
    }

    #[test]
    fn sharded_counter_sizes_past_sixteen_threads() {
        // The seed's fixed 16 cells collided round-robin ids 17+ onto
        // already-occupied lines. Sizing from the thread count removes the
        // collision: 24 consecutive ids map to 24 distinct cells of a
        // 24-thread counter.
        let c = ShardedCounter::with_shards(24);
        assert_eq!(c.num_shards(), 32, "next power of two");
        let mask = c.num_shards() - 1;
        let distinct: HashSet<usize> = (0..24).map(|id| id & mask).collect();
        assert_eq!(distinct.len(), 24, "no two of 24 consecutive ids share a cell");
        // The seed scheme provably collided: 24 consecutive ids into 16.
        let seed_distinct: HashSet<usize> = (0..24).map(|id| id & 15).collect();
        assert!(seed_distinct.len() < 24);
        // Bounds.
        assert_eq!(ShardedCounter::with_shards(0).num_shards(), 1);
        assert_eq!(ShardedCounter::with_shards(1).num_shards(), 1);
        assert_eq!(ShardedCounter::with_shards(100_000).num_shards(), 256, "hard cap");
        assert!(ShardedCounter::new().num_shards() >= 16, "default never shrinks");
    }

    #[test]
    fn sharded_counter_correct_with_24_threads() {
        // Behavioral regression guard at > 16 threads: the sum stays exact
        // whatever cells the ids land on.
        let c = Arc::new(ShardedCounter::with_shards(24));
        std::thread::scope(|s| {
            for k in 0..24u64 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..2_000 {
                        c.inc();
                    }
                    if k % 3 == 0 {
                        for _ in 0..2_000 {
                            c.dec();
                        }
                    }
                });
            }
        });
        assert_eq!(c.exact(), 16 * 2_000);
    }

    #[test]
    fn sharded_counter_cross_thread_dec_clamps() {
        // Push on one thread, pop on another: individual cells go negative,
        // the sum stays correct and `get` never underflows.
        let c = Arc::new(ShardedCounter::new());
        c.add(5);
        let c2 = Arc::clone(&c);
        std::thread::spawn(move || {
            c2.sub(5);
        })
        .join()
        .unwrap();
        assert_eq!(c.exact(), 0);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn cache_padded_is_big_and_transparent() {
        assert!(std::mem::size_of::<CachePadded<u8>>() >= 128);
        assert_eq!(std::mem::align_of::<CachePadded<u8>>(), 128);
        let p = CachePadded::new(7u32);
        assert_eq!(*p, 7);
        assert_eq!(p.into_inner(), 7);
    }
}
