//! Virtual time for the discrete-event simulator.
//!
//! Times are kept in integer **nanoseconds** to make event ordering exact
//! and runs reproducible (no float accumulation drift across the millions of
//! events in a figure sweep).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulated time (ns since simulation start).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default, Debug)]
pub struct SimTime(pub u64);

/// A span of simulated time (ns).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default, Debug)]
pub struct SimDuration(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    #[inline]
    pub fn as_nanos(self) -> u64 {
        self.0
    }

    #[inline]
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 * 1e-9
    }

    #[inline]
    pub fn saturating_sub(self, other: SimTime) -> SimDuration {
        SimDuration(self.0.saturating_sub(other.0))
    }
}

impl SimDuration {
    pub const ZERO: SimDuration = SimDuration(0);

    #[inline]
    pub fn from_nanos(ns: u64) -> Self {
        SimDuration(ns)
    }

    #[inline]
    pub fn from_micros(us: u64) -> Self {
        SimDuration(us * 1_000)
    }

    #[inline]
    pub fn from_millis(ms: u64) -> Self {
        SimDuration(ms * 1_000_000)
    }

    /// From fractional microseconds (cost models are specified in µs).
    #[inline]
    pub fn from_micros_f64(us: f64) -> Self {
        SimDuration((us * 1_000.0).round().max(0.0) as u64)
    }

    #[inline]
    pub fn as_nanos(self) -> u64 {
        self.0
    }

    #[inline]
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 * 1e-9
    }

    /// Scale by a dimensionless factor (e.g. cache-pollution inflation).
    #[inline]
    pub fn scale(self, f: f64) -> Self {
        SimDuration((self.0 as f64 * f).round().max(0.0) as u64)
    }
}

impl Add<SimDuration> for SimTime {
    type Output = SimTime;
    #[inline]
    fn add(self, d: SimDuration) -> SimTime {
        SimTime(self.0 + d.0)
    }
}

impl AddAssign<SimDuration> for SimTime {
    #[inline]
    fn add_assign(&mut self, d: SimDuration) {
        self.0 += d.0;
    }
}

impl Add for SimDuration {
    type Output = SimDuration;
    #[inline]
    fn add(self, o: SimDuration) -> SimDuration {
        SimDuration(self.0 + o.0)
    }
}

impl AddAssign for SimDuration {
    #[inline]
    fn add_assign(&mut self, o: SimDuration) {
        self.0 += o.0;
    }
}

impl Sub for SimTime {
    type Output = SimDuration;
    #[inline]
    fn sub(self, o: SimTime) -> SimDuration {
        SimDuration(self.0 - o.0)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs_f64())
    }
}

impl fmt::Display for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1_000_000_000 {
            write!(f, "{:.3}s", self.as_secs_f64())
        } else if self.0 >= 1_000_000 {
            write!(f, "{:.3}ms", self.0 as f64 / 1e6)
        } else if self.0 >= 1_000 {
            write!(f, "{:.3}µs", self.0 as f64 / 1e3)
        } else {
            write!(f, "{}ns", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = SimTime::ZERO + SimDuration::from_micros(5);
        assert_eq!(t.as_nanos(), 5_000);
        let t2 = t + SimDuration::from_millis(1);
        assert_eq!((t2 - t).as_nanos(), 1_000_000);
    }

    #[test]
    fn scale_rounds() {
        let d = SimDuration::from_nanos(100);
        assert_eq!(d.scale(1.5).as_nanos(), 150);
        assert_eq!(d.scale(0.0).as_nanos(), 0);
    }

    #[test]
    fn micros_f64() {
        assert_eq!(SimDuration::from_micros_f64(0.5).as_nanos(), 500);
        assert_eq!(SimDuration::from_micros_f64(1.2345).as_nanos(), 1235);
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", SimDuration::from_nanos(5)), "5ns");
        assert_eq!(format!("{}", SimDuration::from_micros(5)), "5.000µs");
        assert_eq!(format!("{}", SimDuration::from_millis(5)), "5.000ms");
        assert_eq!(format!("{}", SimDuration::from_millis(5000)), "5.000s");
    }
}
