//! Deterministic fault injection for the failure-containment plane.
//!
//! A [`FaultPlan`] is a seeded decision stream over **named sites** in the
//! runtime: every place instrumented for injection asks
//! [`FaultPlan::should_inject`] and gets a reproducible yes/no drawn from
//! one shared xorshift64* stream ([`XorShift64::step`] on an atomic state
//! word, so any thread may draw). The sites the runtime instruments:
//!
//! * [`FaultSite::TaskBody`] — the executing worker panics *inside* the
//!   `catch_unwind` boundary instead of running the body, exercising the
//!   Failed → poison → finalize path end to end;
//! * [`FaultSite::WakeEdge`] — a ready-push / wake-edge wake is swallowed
//!   (an unbounded delay), exercising the timed-park recheck cadence and
//!   the hang watchdog's re-raise/wake self-heal;
//! * [`FaultSite::DrainBatch`] — a manager defers a claimed worker's batch
//!   drain to a later activation (the worker is re-raised, not lost),
//!   exercising the no-lost-raise retry paths;
//! * [`FaultSite::IngressRaise`] — an external submitter's
//!   `raise_external` is dropped after its entry was published into the
//!   ingress ring, exercising the watchdog's stranded-ring re-raise (a
//!   blocking `submit_async` must be healed, never hang).
//!
//! Decisions are counted per site (`draws` / `injected`), so stress tests
//! can assert that a scenario actually exercised the fault — a fault plan
//! that never fires proves nothing. With a fixed seed and a
//! single-threaded driver the decision sequence is bit-for-bit
//! reproducible; under a multi-threaded pool the *stream* is still
//! deterministic, only its interleaving across threads varies.
//!
//! The plan is intentionally dumb: it owns no clocks and spawns no
//! threads. Delays are realized by the *caller* (skipping a wake, deferring
//! a drain), so the injected behaviours stay inside the runtime's own
//! recovery envelope instead of racing an external timer.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::substrate::stats::Counter;
use crate::substrate::XorShift64;

/// Named injection sites (indices into the per-site tables).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum FaultSite {
    /// Panic instead of running a task body.
    TaskBody = 0,
    /// Swallow a ready-push / wake-edge wake.
    WakeEdge = 1,
    /// Defer a claimed worker's batch drain (worker re-raised).
    DrainBatch = 2,
    /// Drop an external submitter's ingress raise (ring entry published,
    /// signal withheld — the watchdog must re-raise the stranded ring).
    IngressRaise = 3,
}

/// Number of named sites (table size).
pub const NUM_FAULT_SITES: usize = 4;

/// Rate denominator: rates are expressed out of `1 << 16`. A rate of
/// [`FAULT_ALWAYS`] injects on every draw.
pub const FAULT_ALWAYS: u32 = 1 << 16;

/// A seeded, shareable fault-injection plan. See the module docs.
pub struct FaultPlan {
    /// Shared xorshift64* state; drawn via CAS so any thread can pull from
    /// the one deterministic stream.
    state: AtomicU64,
    /// Per-site injection rate out of [`FAULT_ALWAYS`]. 0 = site disabled
    /// (no draw, no counter traffic — the happy path stays one branch).
    rates: [u32; NUM_FAULT_SITES],
    /// Draws per site (only armed sites count).
    draws: [Counter; NUM_FAULT_SITES],
    /// Injections per site.
    injected: [Counter; NUM_FAULT_SITES],
    /// Remaining injections per site (`u64::MAX` = unbounded). A budget of
    /// `n` makes exactly the first `n` sampled hits inject — the handle
    /// that scopes a fault to "the first task" in containment tests.
    budgets: [AtomicU64; NUM_FAULT_SITES],
}

impl FaultPlan {
    /// A plan with every site disabled. Arm sites with
    /// [`FaultPlan::with_rate`].
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            state: AtomicU64::new(XorShift64::new(seed).state()),
            rates: [0; NUM_FAULT_SITES],
            draws: std::array::from_fn(|_| Counter::new()),
            injected: std::array::from_fn(|_| Counter::new()),
            budgets: std::array::from_fn(|_| AtomicU64::new(u64::MAX)),
        }
    }

    /// Arm `site` at `rate` out of [`FAULT_ALWAYS`] (clamped).
    pub fn with_rate(mut self, site: FaultSite, rate: u32) -> FaultPlan {
        self.rates[site as usize] = rate.min(FAULT_ALWAYS);
        self
    }

    /// Cap `site` at `budget` total injections: sampled hits beyond the
    /// budget are suppressed (the draw still advances the shared stream).
    /// `FAULT_ALWAYS` + budget 1 pins the fault to exactly the first draw
    /// — e.g. "only domain A's head task panics".
    pub fn with_budget(self, site: FaultSite, budget: u64) -> FaultPlan {
        self.budgets[site as usize].store(budget, Ordering::Relaxed);
        self
    }

    /// Is `site` armed at all? One array load — cheap enough for hot paths
    /// that want to skip building injection arguments.
    #[inline]
    pub fn armed(&self, site: FaultSite) -> bool {
        self.rates[site as usize] > 0
    }

    /// Draw the next decision for `site`. Disabled sites return `false`
    /// without touching the stream. The stream is shared across sites: for
    /// a given seed, the whole-plan decision sequence is fixed by the
    /// order in which armed sites are hit.
    pub fn should_inject(&self, site: FaultSite) -> bool {
        let rate = self.rates[site as usize];
        if rate == 0 {
            return false;
        }
        self.draws[site as usize].inc();
        let hit = if rate >= FAULT_ALWAYS {
            true
        } else {
            // One xorshift step, CAS-published so concurrent draws never
            // reuse a state word; the high 16 bits are the uniform sample.
            let mut cur = self.state.load(Ordering::Relaxed);
            loop {
                let (next, out) = XorShift64::step(cur);
                match self.state.compare_exchange_weak(
                    cur,
                    next,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break (out >> 48) < rate as u64,
                    Err(observed) => cur = observed,
                }
            }
        };
        if !hit {
            return false;
        }
        // Budget gate: claim one injection slot atomically; concurrent
        // hits over the last slot race the decrement, so at most `budget`
        // ever pass.
        if self.budgets[site as usize]
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
                if b == u64::MAX {
                    Some(b) // unbounded: never consumed
                } else {
                    b.checked_sub(1)
                }
            })
            .is_err()
        {
            return false;
        }
        self.injected[site as usize].inc();
        true
    }

    /// Draws taken at `site` (armed sites only).
    pub fn draws(&self, site: FaultSite) -> u64 {
        self.draws[site as usize].get()
    }

    /// Injections fired at `site`.
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.injected[site as usize].get()
    }

    /// Total injections across all sites.
    pub fn total_injected(&self) -> u64 {
        self.injected.iter().map(Counter::get).sum()
    }
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let draws: [u64; NUM_FAULT_SITES] = std::array::from_fn(|i| self.draws[i].get());
        let injected: [u64; NUM_FAULT_SITES] = std::array::from_fn(|i| self.injected[i].get());
        f.debug_struct("FaultPlan")
            .field("rates", &self.rates)
            .field("draws", &draws)
            .field("injected", &injected)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sites_never_inject_or_draw() {
        let plan = FaultPlan::new(7);
        for _ in 0..1000 {
            assert!(!plan.should_inject(FaultSite::TaskBody));
            assert!(!plan.should_inject(FaultSite::WakeEdge));
            assert!(!plan.should_inject(FaultSite::IngressRaise));
        }
        assert_eq!(plan.draws(FaultSite::TaskBody), 0);
        assert_eq!(plan.total_injected(), 0);
        assert!(!plan.armed(FaultSite::TaskBody));
    }

    #[test]
    fn always_rate_injects_every_draw() {
        let plan = FaultPlan::new(7).with_rate(FaultSite::TaskBody, FAULT_ALWAYS);
        assert!(plan.armed(FaultSite::TaskBody));
        for _ in 0..100 {
            assert!(plan.should_inject(FaultSite::TaskBody));
        }
        assert_eq!(plan.draws(FaultSite::TaskBody), 100);
        assert_eq!(plan.injected(FaultSite::TaskBody), 100);
    }

    #[test]
    fn same_seed_same_decision_sequence() {
        let a = FaultPlan::new(42).with_rate(FaultSite::DrainBatch, FAULT_ALWAYS / 2);
        let b = FaultPlan::new(42).with_rate(FaultSite::DrainBatch, FAULT_ALWAYS / 2);
        let sa: Vec<bool> = (0..500).map(|_| a.should_inject(FaultSite::DrainBatch)).collect();
        let sb: Vec<bool> = (0..500).map(|_| b.should_inject(FaultSite::DrainBatch)).collect();
        assert_eq!(sa, sb);
        assert!(sa.iter().any(|&x| x), "half rate fired at least once in 500");
        assert!(sa.iter().any(|&x| !x), "half rate skipped at least once in 500");
    }

    #[test]
    fn rate_roughly_respected() {
        let plan = FaultPlan::new(3).with_rate(FaultSite::WakeEdge, FAULT_ALWAYS / 4);
        let hits =
            (0..10_000).filter(|_| plan.should_inject(FaultSite::WakeEdge)).count() as f64;
        let frac = hits / 10_000.0;
        assert!((0.2..0.3).contains(&frac), "frac={frac}");
        assert_eq!(plan.draws(FaultSite::WakeEdge), 10_000);
        assert_eq!(plan.injected(FaultSite::WakeEdge), hits as u64);
    }

    #[test]
    fn budget_caps_total_injections() {
        let plan = FaultPlan::new(5)
            .with_rate(FaultSite::TaskBody, FAULT_ALWAYS)
            .with_budget(FaultSite::TaskBody, 3);
        let hits = (0..100).filter(|_| plan.should_inject(FaultSite::TaskBody)).count();
        assert_eq!(hits, 3, "exactly the first three draws inject");
        assert_eq!(plan.draws(FaultSite::TaskBody), 100, "draws keep counting");
        assert_eq!(plan.injected(FaultSite::TaskBody), 3);
        // Unbudgeted sites stay unbounded.
        let free = FaultPlan::new(5).with_rate(FaultSite::WakeEdge, FAULT_ALWAYS);
        assert!((0..100).all(|_| free.should_inject(FaultSite::WakeEdge)));
    }

    #[test]
    fn concurrent_draws_never_lose_counts() {
        let plan =
            std::sync::Arc::new(FaultPlan::new(9).with_rate(FaultSite::TaskBody, FAULT_ALWAYS / 2));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = std::sync::Arc::clone(&plan);
            handles.push(std::thread::spawn(move || {
                (0..5_000).filter(|_| p.should_inject(FaultSite::TaskBody)).count() as u64
            }));
        }
        let hits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(plan.draws(FaultSite::TaskBody), 20_000);
        assert_eq!(plan.injected(FaultSite::TaskBody), hits);
    }
}
