//! Cheap runtime statistics: relaxed atomic counters and a log2 histogram.
//!
//! The runtime keeps the counters the paper's analysis needed (tasks in
//! graph, ready tasks, messages queued, manager activations...) and the
//! bench harness derives Figure 12/13/14/15-style evolutions from them.

use std::sync::atomic::{AtomicU64, Ordering};

/// Relaxed atomic counter (monotonic or gauge).
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed) + 1
    }

    #[inline]
    pub fn add(&self, n: u64) -> u64 {
        self.0.fetch_add(n, Ordering::Relaxed) + n
    }

    #[inline]
    pub fn dec(&self) -> u64 {
        self.0.fetch_sub(1, Ordering::Relaxed) - 1
    }

    #[inline]
    pub fn sub(&self, n: u64) -> u64 {
        self.0.fetch_sub(n, Ordering::Relaxed) - n
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed)
    }

    /// Monotonic max-tracking (e.g. peak concurrent managers).
    #[inline]
    pub fn record_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
}

/// Lock-free log2-bucketed histogram of u64 samples (e.g. lock spin counts,
/// queue residence times in ns). 64 buckets: bucket b holds samples whose
/// highest set bit is b.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        let b = 63 - (v | 1).leading_zeros() as usize;
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of bucket,
    /// clipped to the observed max). `target` is clamped to at least one
    /// sample so `q → 0.0` lands in the first *occupied* bucket instead of
    /// being satisfied by an empty leading one; the top bucket saturates to
    /// `u64::MAX` rather than wrapping its upper bound back to `1<<63`.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (b, bucket) in self.buckets.iter().enumerate() {
            acc += bucket.load(Ordering::Relaxed);
            if acc >= target {
                let bound = if b + 1 >= 64 {
                    u64::MAX
                } else {
                    1u64 << (b + 1)
                };
                return bound.min(self.max());
            }
        }
        self.max()
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::new();
        assert_eq!(c.inc(), 1);
        assert_eq!(c.add(9), 10);
        assert_eq!(c.dec(), 9);
        assert_eq!(c.sub(4), 5);
        assert_eq!(c.get(), 5);
        c.set(0);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn histogram_mean_and_max() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 4, 100] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 110);
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 22.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let q50 = h.quantile(0.5);
        let q99 = h.quantile(0.99);
        assert!(q50 <= q99);
        assert!(q50 >= 256 && q50 <= 1024, "q50={q50}");
    }

    #[test]
    fn histogram_reset() {
        let h = Histogram::new();
        h.record(7);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
    }

    #[test]
    fn record_zero_goes_to_bucket_zero() {
        let h = Histogram::new();
        h.record(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn quantile_zero_hits_first_occupied_bucket() {
        // Regression: q=0.0 used to make target==0, satisfied by the empty
        // bucket 0 — returning 2 for *any* non-empty histogram.
        let h = Histogram::new();
        h.record(100);
        assert_eq!(h.quantile(0.0), 100);
        assert_eq!(h.quantile(0.5), 100);
    }

    #[test]
    fn quantile_one_is_the_max() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.quantile(1.0), 1000);
        assert_eq!(h.quantile(1.0), h.max());
    }

    #[test]
    fn quantile_single_sample_is_exact() {
        let h = Histogram::new();
        h.record(7);
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 7, "q={q}");
        }
    }

    #[test]
    fn quantile_top_bucket_saturates() {
        // Regression: the bucket upper bound `1 << (b+1).min(63)` capped the
        // top bucket's bound at 1<<63 instead of saturating.
        let h = Histogram::new();
        h.record(u64::MAX);
        assert_eq!(h.quantile(0.5), u64::MAX);
        assert_eq!(h.quantile(1.0), u64::MAX);
    }
}
