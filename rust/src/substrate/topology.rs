//! Machine topology descriptor: sockets × workers-per-socket.
//!
//! The paper's evaluation machines (Table 1 — up to the 64-core KNL, and
//! the two-socket Power8+/Power9 nodes) are exactly where flat shared
//! structures stop scaling: a directory word or a steal victim on the
//! wrong socket costs a cross-socket cache-line bounce per touch. This
//! descriptor is the one place the runtime learns the socket shape; the
//! substrate threads it through the hot paths:
//!
//! * [`SignalDirectory`](crate::substrate::SignalDirectory) lays its
//!   worker-bit words out **per socket** (two-level: socket summary word →
//!   per-worker bits) so sweeps and wake scans only touch dirty sockets;
//! * `ReadyPools::steal` tries same-socket victims for a full round before
//!   touching a remote deque;
//! * ready-push wake sites prefer a parked worker on the socket whose
//!   deque received the tasks.
//!
//! Sources, in priority order: an explicit
//! `TaskSystem::builder().topology(..)` (tests, the `sim/` machine
//! models), the `DDAST_TOPOLOGY=SxW` environment override (CI forces
//! multi-socket shapes on single-socket runners this way), best-effort OS
//! detection (Linux sysfs NUMA nodes), and finally a flat single-socket
//! fallback. The descriptor is plain copyable data — no atomics, no
//! detection on any hot path.

/// Sockets × workers-per-socket. See the module docs for how it is
/// obtained and where it steers the runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    sockets: usize,
    workers_per_socket: usize,
}

impl Topology {
    /// Socket count cap: the directory's socket-summary bitmap is one
    /// `u64` word.
    pub const MAX_SOCKETS: usize = 64;

    /// A shape of `sockets` sockets with `workers_per_socket` workers
    /// each. Both are clamped to at least 1; sockets to at most
    /// [`MAX_SOCKETS`](Topology::MAX_SOCKETS).
    pub fn new(sockets: usize, workers_per_socket: usize) -> Self {
        Topology {
            sockets: sockets.clamp(1, Self::MAX_SOCKETS),
            workers_per_socket: workers_per_socket.max(1),
        }
    }

    /// Single-socket shape covering `workers` — the "no topology" policy
    /// (every victim equidistant, one summary bit over everything).
    pub fn flat(workers: usize) -> Self {
        Topology::new(1, workers.max(1))
    }

    /// Shape whose sockets coincide with the directory's 64-bit words —
    /// reproduces the pre-topology directory layout exactly (64 workers
    /// per summary bit). [`SignalDirectory::new`] uses this, so code that
    /// never mentions topology keeps its old layout and old behaviour.
    ///
    /// [`SignalDirectory::new`]: crate::substrate::SignalDirectory::new
    pub fn word_grain(workers: usize) -> Self {
        Topology::new(workers.max(1).div_ceil(64), 64)
    }

    /// Distribute `workers` over `sockets` as evenly as possible (never
    /// more sockets than workers).
    pub fn with_workers(sockets: usize, workers: usize) -> Self {
        let workers = workers.max(1);
        let sockets = sockets.clamp(1, Self::MAX_SOCKETS).min(workers);
        Topology::new(sockets, workers.div_ceil(sockets))
    }

    /// Detect the shape for `workers` worker slots: the
    /// `DDAST_TOPOLOGY=SxW` environment override first (widened to cover
    /// `workers`), then the OS, then flat.
    pub fn detect(workers: usize) -> Self {
        if let Ok(spec) = std::env::var("DDAST_TOPOLOGY") {
            if let Some(t) = Self::parse(&spec) {
                return t.cover(workers);
            }
        }
        match Self::os_socket_count() {
            Some(nodes) if nodes >= 2 => Topology::with_workers(nodes, workers),
            _ => Topology::flat(workers),
        }
    }

    /// Parse a `SxW` shape spec (e.g. `4x8` = 4 sockets × 8 workers).
    /// Returns `None` on anything malformed — detection then falls
    /// through, it never panics on a bad environment.
    pub fn parse(spec: &str) -> Option<Self> {
        let (s, w) = spec.trim().split_once(['x', 'X'])?;
        let sockets: usize = s.trim().parse().ok()?;
        let per: usize = w.trim().parse().ok()?;
        if sockets == 0 || per == 0 {
            return None;
        }
        Some(Topology::new(sockets, per))
    }

    /// Best-effort NUMA-node count (Linux sysfs). `None` anywhere the
    /// directory is absent or unreadable.
    fn os_socket_count() -> Option<usize> {
        let entries = std::fs::read_dir("/sys/devices/system/node").ok()?;
        let nodes = entries
            .filter_map(|e| e.ok())
            .filter(|e| {
                let name = e.file_name();
                let name = name.to_string_lossy();
                name.strip_prefix("node")
                    .is_some_and(|d| !d.is_empty() && d.bytes().all(|b| b.is_ascii_digit()))
            })
            .count();
        (nodes >= 1).then_some(nodes)
    }

    /// Same socket count, widened (if needed) so `workers` slots all map
    /// to a valid socket. Directories size themselves for *slots* (which
    /// may exceed the worker count — the CentralDast DAS slot), so every
    /// consumer normalizes through this.
    pub fn cover(self, workers: usize) -> Self {
        if workers <= self.capacity() {
            self
        } else {
            Topology::new(self.sockets, workers.div_ceil(self.sockets))
        }
    }

    /// Socket count.
    #[inline]
    pub fn sockets(&self) -> usize {
        self.sockets
    }

    /// Workers per socket.
    #[inline]
    pub fn workers_per_socket(&self) -> usize {
        self.workers_per_socket
    }

    /// Total worker slots the shape covers.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.sockets * self.workers_per_socket
    }

    /// Socket of `worker` (out-of-shape slots clamp to the last socket).
    #[inline]
    pub fn socket_of(&self, worker: usize) -> usize {
        (worker / self.workers_per_socket).min(self.sockets - 1)
    }

    /// Worker-index range of `socket`, clipped to `n` total workers.
    #[inline]
    pub fn socket_range(&self, socket: usize, n: usize) -> std::ops::Range<usize> {
        let lo = (socket * self.workers_per_socket).min(n);
        let hi = if socket + 1 == self.sockets {
            n // last socket absorbs clamped overflow slots
        } else {
            ((socket + 1) * self.workers_per_socket).min(n)
        };
        lo..hi
    }

    /// One socket — locality policies degenerate to the flat behaviour.
    #[inline]
    pub fn is_flat(&self) -> bool {
        self.sockets == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_socket_mapping() {
        let t = Topology::new(4, 8);
        assert_eq!((t.sockets(), t.workers_per_socket(), t.capacity()), (4, 8, 32));
        assert_eq!(t.socket_of(0), 0);
        assert_eq!(t.socket_of(7), 0);
        assert_eq!(t.socket_of(8), 1);
        assert_eq!(t.socket_of(31), 3);
        assert_eq!(t.socket_of(999), 3, "overflow clamps to the last socket");
        assert_eq!(t.socket_range(1, 32), 8..16);
        assert_eq!(t.socket_range(3, 30), 24..30, "last range clipped to n");
        assert!(!t.is_flat());
        assert!(Topology::flat(16).is_flat());
    }

    #[test]
    fn word_grain_matches_the_flat_directory_layout() {
        assert_eq!(Topology::word_grain(8), Topology::new(1, 64));
        assert_eq!(Topology::word_grain(64), Topology::new(1, 64));
        assert_eq!(Topology::word_grain(65), Topology::new(2, 64));
        assert_eq!(Topology::word_grain(130), Topology::new(3, 64));
        assert_eq!(Topology::word_grain(4096), Topology::new(64, 64));
    }

    #[test]
    fn cover_widens_only_when_needed() {
        let t = Topology::new(4, 8);
        assert_eq!(t.cover(32), t);
        assert_eq!(t.cover(3), t);
        let wide = t.cover(33); // oversubscribed: one extra park slot
        assert_eq!((wide.sockets(), wide.workers_per_socket()), (4, 9));
        assert_eq!(wide.socket_of(33), 3);
    }

    #[test]
    fn with_workers_distributes_evenly() {
        let t = Topology::with_workers(2, 7);
        assert_eq!((t.sockets(), t.workers_per_socket()), (2, 4));
        let one = Topology::with_workers(8, 3);
        assert_eq!(one.sockets(), 3, "never more sockets than workers");
    }

    #[test]
    fn parse_accepts_sxw_and_rejects_garbage() {
        assert_eq!(Topology::parse("4x8"), Some(Topology::new(4, 8)));
        assert_eq!(Topology::parse(" 2X16 "), Some(Topology::new(2, 16)));
        assert_eq!(Topology::parse("0x8"), None);
        assert_eq!(Topology::parse("4x"), None);
        assert_eq!(Topology::parse("abc"), None);
        assert_eq!(Topology::parse(""), None);
    }

    #[test]
    fn clamps_to_summary_word() {
        let t = Topology::new(1_000, 1);
        assert_eq!(t.sockets(), Topology::MAX_SOCKETS);
    }
}
