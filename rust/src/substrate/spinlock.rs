//! Spin lock with contention accounting.
//!
//! Nanos++ protects each per-parent dependence graph with a spinlock
//! (§2.2.1: "actions in each graph are protected by spinlocks"). The whole
//! point of the paper is the time threads waste spinning here, so the lock
//! counts acquisitions and contended acquisitions — the bench harness and
//! the simulator calibration read these.

use std::cell::UnsafeCell;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Test-and-test-and-set spin lock with statistics.
pub struct SpinLock<T> {
    locked: AtomicBool,
    /// Total successful acquisitions.
    acquisitions: AtomicU64,
    /// Acquisitions that had to spin at least once.
    contended: AtomicU64,
    /// Total spin iterations across all acquisitions (coarse contention
    /// "time" proxy used by `sim::calibrate`).
    spin_iters: AtomicU64,
    value: UnsafeCell<T>,
}

// SAFETY: standard lock-based interior mutability.
unsafe impl<T: Send> Send for SpinLock<T> {}
unsafe impl<T: Send> Sync for SpinLock<T> {}

impl<T> SpinLock<T> {
    pub const fn new(value: T) -> Self {
        SpinLock {
            locked: AtomicBool::new(false),
            acquisitions: AtomicU64::new(0),
            contended: AtomicU64::new(0),
            spin_iters: AtomicU64::new(0),
            value: UnsafeCell::new(value),
        }
    }

    /// Acquire the lock, spinning until available.
    pub fn lock(&self) -> SpinLockGuard<'_, T> {
        let mut spins: u64 = 0;
        loop {
            // Test-and-test-and-set: spin on a plain load first so the
            // cache line stays shared while the lock is held.
            if !self.locked.load(Ordering::Relaxed)
                && self
                    .locked
                    .compare_exchange_weak(false, true, Ordering::Acquire, Ordering::Relaxed)
                    .is_ok()
            {
                break;
            }
            spins += 1;
            std::hint::spin_loop();
            if spins % 64 == 0 {
                // Be polite on oversubscribed boxes (this machine has a
                // single core; pure spinning would livelock the holder out).
                std::thread::yield_now();
            }
        }
        self.acquisitions.fetch_add(1, Ordering::Relaxed);
        if spins > 0 {
            self.contended.fetch_add(1, Ordering::Relaxed);
            self.spin_iters.fetch_add(spins, Ordering::Relaxed);
        }
        SpinLockGuard { lock: self }
    }

    /// Try to acquire without spinning.
    pub fn try_lock(&self) -> Option<SpinLockGuard<'_, T>> {
        if self
            .locked
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            self.acquisitions.fetch_add(1, Ordering::Relaxed);
            Some(SpinLockGuard { lock: self })
        } else {
            None
        }
    }

    /// (acquisitions, contended acquisitions, total spin iterations).
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.acquisitions.load(Ordering::Relaxed),
            self.contended.load(Ordering::Relaxed),
            self.spin_iters.load(Ordering::Relaxed),
        )
    }

    pub fn reset_stats(&self) {
        self.acquisitions.store(0, Ordering::Relaxed);
        self.contended.store(0, Ordering::Relaxed);
        self.spin_iters.store(0, Ordering::Relaxed);
    }
}

pub struct SpinLockGuard<'a, T> {
    lock: &'a SpinLock<T>,
}

impl<'a, T> Deref for SpinLockGuard<'a, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // SAFETY: guard guarantees exclusive access.
        unsafe { &*self.lock.value.get() }
    }
}

impl<'a, T> DerefMut for SpinLockGuard<'a, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: guard guarantees exclusive access.
        unsafe { &mut *self.lock.value.get() }
    }
}

impl<'a, T> Drop for SpinLockGuard<'a, T> {
    fn drop(&mut self) {
        self.lock.locked.store(false, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn exclusive_increment() {
        let lock = Arc::new(SpinLock::new(0u64));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let lock = Arc::clone(&lock);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    *lock.lock() += 1;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*lock.lock(), 40_000);
        let (acq, _, _) = lock.stats();
        assert_eq!(acq, 40_001);
    }

    #[test]
    fn try_lock_fails_while_held() {
        let lock = SpinLock::new(());
        let g = lock.lock();
        assert!(lock.try_lock().is_none());
        drop(g);
        assert!(lock.try_lock().is_some());
    }

    #[test]
    fn stats_reset() {
        let lock = SpinLock::new(5);
        {
            let _g = lock.lock();
        }
        assert!(lock.stats().0 > 0);
        lock.reset_stats();
        assert_eq!(lock.stats(), (0, 0, 0));
    }
}
