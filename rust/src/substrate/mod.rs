//! Low-level building blocks shared by the real runtime and the simulator.
//!
//! These are the "substrates" the paper's system depends on: the per-worker
//! single-producer queues the messages travel through (§3.1), the spin locks
//! that guard dependence domains in the baseline runtime (§2.2.1), the
//! region keys dependence tracking hashes on, deterministic RNG for
//! reproducible stealing/workload generation, virtual-time newtypes for the
//! discrete-event simulator and cheap atomic statistics.

pub mod deque;
pub mod fault;
pub mod ingress;
pub mod park;
pub mod rcu;
pub mod signal;
pub mod spsc;
pub mod spinlock;
pub mod region;
pub mod rng;
pub mod topology;
pub mod vtime;
pub mod stats;

pub use deque::{CachePadded, ShardedCounter, Steal, WsDeque};
pub use fault::{FaultPlan, FaultSite, FAULT_ALWAYS};
pub use ingress::IngressRing;
pub use park::Parker;
pub use rcu::RcuCell;
pub use region::{RegionKey, RegionSet};
pub use rng::XorShift64;
pub use signal::{ScanClaim, SignalDirectory};
pub use spinlock::{SpinLock, SpinLockGuard};
pub use spsc::{ConsumerGuard, SpscQueue};
pub use stats::{Counter, Histogram};
pub use topology::Topology;
pub use vtime::{SimDuration, SimTime};
