//! Event-driven thread parking: a futex-style token state machine over
//! `std::thread::park`/`unpark`, with no external dependencies.
//!
//! The paper's DDAST thesis is that idle threads should *do runtime work
//! instead of burning cycles* — but a fully idle worker (no ready tasks, no
//! queued requests, dispatcher callbacks all empty-handed) previously had
//! nothing better than the blind spin → yield → sleep ladder of
//! `idle_backoff`, paying up to a full sleep quantum of wake latency on the
//! next enqueue and burning scheduler slots meanwhile (exactly the
//! detrimental idle patterns Tuft et al. measure in mainstream OpenMP
//! runtimes). [`Parker`] is the building block that lets such a worker
//! *park* until a producer's signal arrives, in the spirit of the
//! futex-based sleep paths of Álvarez et al., *Advanced Synchronization
//! Techniques for Task-based Runtime Systems* (arXiv:2105.07902).
//!
//! ## State machine
//!
//! One `AtomicU32` with three states and futex-wake token semantics:
//!
//! ```text
//!            unpark            park (consume)
//!   EMPTY ────────────▶ NOTIFIED ────────────▶ EMPTY
//!     │ park (commit)      ▲
//!     ▼                    │ unpark (+ thread::unpark)
//!   WAITING ───────────────┘
//! ```
//!
//! * [`Parker::unpark`] deposits a single token (saturating — like a futex
//!   wake, multiple wakes before the sleeper arrives coalesce) and calls
//!   `thread::unpark` only when the owner is actually committed (`WAITING`).
//! * [`Parker::park`] consumes a pending token without blocking; otherwise
//!   it publishes `WAITING` and loops on `thread::park` until a token
//!   arrives. Spurious `thread::park` returns (allowed by std) re-park.
//!
//! The one-token memory means a wake that races a *cancelled* park attempt
//! simply makes the owner's next `park` return immediately once — the
//! caller's recheck loop absorbs it. That is the same tolerance the
//! work-signal directory's claim-then-recheck protocol already relies on.
//!
//! `park` must only be called by one thread at a time (the slot owner);
//! `unpark` is safe from anywhere. The no-lost-wakeup pairing with shared
//! state (queues, ready pools) lives one level up, in
//! [`SignalDirectory`](crate::substrate::SignalDirectory)'s
//! `begin_park`/`wake_parked` fence protocol.

use std::sync::atomic::{AtomicU32, Ordering};
use std::thread::Thread;

use crate::substrate::SpinLock;

const EMPTY: u32 = 0;
const WAITING: u32 = 1;
const NOTIFIED: u32 = 2;

/// One parking slot (see module docs for the protocol).
pub struct Parker {
    state: AtomicU32,
    /// Handle of the owner thread, registered on each blocking `park`.
    /// Touched only on the slow paths (commit-to-park, wake-of-waiting);
    /// the spin lock is never held across blocking.
    thread: SpinLock<Option<Thread>>,
}

impl Default for Parker {
    fn default() -> Self {
        Self::new()
    }
}

impl Parker {
    pub fn new() -> Self {
        Parker { state: AtomicU32::new(EMPTY), thread: SpinLock::new(None) }
    }

    /// Is a wake token currently pending? (Racy peek, telemetry/tests.)
    #[inline]
    pub fn token_pending(&self) -> bool {
        self.state.load(Ordering::Acquire) == NOTIFIED
    }

    /// Block the calling thread until a token is available, then consume
    /// it. Returns immediately (consuming the token) if one is already
    /// pending. Only the slot owner may call this.
    pub fn park(&self) {
        // Fast path: a token is already there.
        if self.state.swap(EMPTY, Ordering::Acquire) == NOTIFIED {
            return;
        }
        // Register ourselves so unpark can reach this thread, then commit.
        *self.thread.lock() = Some(std::thread::current());
        if self
            .state
            .compare_exchange(EMPTY, WAITING, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            // A token landed between the swap above and the commit.
            self.state.store(EMPTY, Ordering::Release);
            return;
        }
        loop {
            std::thread::park();
            if self
                .state
                .compare_exchange(NOTIFIED, EMPTY, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
            // Spurious wakeup (still WAITING): park again.
        }
    }

    /// Like [`Parker::park`], but give up after `timeout`. Returns `true`
    /// when a token was consumed (immediately-pending or delivered while
    /// blocked), `false` on timeout. The token state machine is identical;
    /// a timeout withdraws the `WAITING` announcement with one swap — an
    /// unpark that raced the withdrawal either left `NOTIFIED` (consumed
    /// here, return `true`) or already read `WAITING` and issued a stray
    /// `thread::unpark`, which at worst makes a *later* blocking park spin
    /// one spurious loop. Used for the bounded waits that replaced the
    /// runtime's blind 100 µs sleep tier (visible-but-unactionable work,
    /// shutdown drains): same re-check cadence, but a wake edge can cut
    /// the wait short.
    pub fn park_timeout(&self, timeout: std::time::Duration) -> bool {
        if self.state.swap(EMPTY, Ordering::Acquire) == NOTIFIED {
            return true;
        }
        *self.thread.lock() = Some(std::thread::current());
        if self
            .state
            .compare_exchange(EMPTY, WAITING, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            self.state.store(EMPTY, Ordering::Release);
            return true;
        }
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let now = std::time::Instant::now();
            if now >= deadline {
                // Withdraw the announcement, consuming a token that raced
                // in between the last wake check and the deadline.
                return self.state.swap(EMPTY, Ordering::AcqRel) == NOTIFIED;
            }
            std::thread::park_timeout(deadline - now);
            if self
                .state
                .compare_exchange(NOTIFIED, EMPTY, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return true;
            }
            // Spurious wakeup or not-yet-expired timeout: loop decides.
        }
    }

    /// Deposit a wake token; if the owner is committed to parking, wake it.
    /// Multiple unparks before the next park coalesce into one token.
    pub fn unpark(&self) {
        if self.state.swap(NOTIFIED, Ordering::AcqRel) == WAITING {
            // The owner registered its handle before publishing WAITING
            // (see `park`), so the clone below observes it.
            let t = self.thread.lock().clone();
            if let Some(t) = t {
                t.unpark();
            }
        }
        // EMPTY -> token stored for the next park; NOTIFIED -> coalesced.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn pending_token_makes_park_immediate() {
        let p = Parker::new();
        assert!(!p.token_pending());
        p.unpark();
        assert!(p.token_pending());
        p.park(); // must not block
        assert!(!p.token_pending());
    }

    #[test]
    fn unparks_coalesce() {
        let p = Parker::new();
        p.unpark();
        p.unpark();
        p.unpark();
        p.park(); // consumes the single coalesced token
        assert!(!p.token_pending());
    }

    #[test]
    fn unpark_wakes_parked_thread() {
        let p = Arc::new(Parker::new());
        let p2 = Arc::clone(&p);
        let h = std::thread::spawn(move || {
            p2.park();
        });
        // Give the thread a moment to actually commit to parking, then wake.
        std::thread::sleep(std::time::Duration::from_millis(10));
        p.unpark();
        h.join().unwrap();
    }

    #[test]
    fn park_timeout_consumes_pending_token() {
        let p = Parker::new();
        p.unpark();
        assert!(p.park_timeout(std::time::Duration::ZERO), "pending token, no block");
        assert!(!p.token_pending());
    }

    #[test]
    fn park_timeout_expires_without_token() {
        let p = Parker::new();
        let t0 = std::time::Instant::now();
        assert!(!p.park_timeout(std::time::Duration::from_millis(5)));
        assert!(t0.elapsed() >= std::time::Duration::from_millis(5));
        // The WAITING announcement was withdrawn: a later unpark only
        // deposits a token.
        p.unpark();
        assert!(p.token_pending());
    }

    #[test]
    fn park_timeout_woken_early_by_unpark() {
        let p = Arc::new(Parker::new());
        let p2 = Arc::clone(&p);
        let h = std::thread::spawn(move || p2.park_timeout(std::time::Duration::from_secs(60)));
        std::thread::sleep(std::time::Duration::from_millis(10));
        p.unpark();
        assert!(h.join().unwrap(), "the unpark ended the timed park early");
    }

    /// Ping-pong stress: every round's unpark must wake the parked side —
    /// a lost wakeup hangs (and times out) the test.
    #[test]
    fn park_unpark_ping_pong_no_lost_wakeup() {
        const ROUNDS: u64 = 20_000;
        let a = Arc::new(Parker::new());
        let b = Arc::new(Parker::new());
        let turns = Arc::new(AtomicU64::new(0));
        let (a2, b2, t2) = (Arc::clone(&a), Arc::clone(&b), Arc::clone(&turns));
        let h = std::thread::spawn(move || {
            for _ in 0..ROUNDS {
                a2.park();
                t2.fetch_add(1, Ordering::AcqRel);
                b2.unpark();
            }
        });
        for i in 0..ROUNDS {
            a.unpark();
            b.park();
            assert_eq!(turns.load(Ordering::Acquire), i + 1);
        }
        h.join().unwrap();
        assert_eq!(turns.load(Ordering::Acquire), ROUNDS);
    }
}
