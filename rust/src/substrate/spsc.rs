//! Unbounded single-producer queues with *exclusive consumer acquisition*.
//!
//! §3.1 of the paper: every worker thread owns two queues (Submit / Done)
//! where **only the owning worker pushes** and **only one manager thread at
//! a time may pop**. The submit queue must preserve FIFO order (task graph
//! correctness); exclusivity is enforced by a consumer token acquired with
//! [`SpscQueue::try_acquire`], mirroring `worker.queueSubmit.acquire()` in
//! the paper's Listing 2.
//!
//! Implementation: a segmented ring. The producer appends to the tail
//! segment without synchronizing with the consumer except through atomic
//! head/tail indices; segments are fixed-size boxed arrays linked through a
//! tiny mutex that is touched only on segment boundaries (every
//! `SEGMENT_LEN` operations), so the common-path push/pop are a couple of
//! atomic ops.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Segment-lock acquisitions that found the mutex poisoned and recovered
/// (see [`lock_poison_recoveries`]).
static LOCK_POISON_RECOVERIES: AtomicU64 = AtomicU64::new(0);

/// How many times any queue's segment lock was taken back from a poisoned
/// state. The queue's invariants live in the atomic head/tail indices, not
/// in the guarded segment list, so a panic that poisons the mutex (a worker
/// dying mid-push during a failed run's teardown) leaves the data valid —
/// refusing to shut down over it would turn one contained failure into a
/// wedged process. Nonzero values are telemetry for such teardowns.
pub fn lock_poison_recoveries() -> u64 {
    LOCK_POISON_RECOVERIES.load(Ordering::Relaxed)
}

/// Take `m` even if poisoned, counting the recovery (teardown-after-failure
/// graceful degradation — doc on [`lock_poison_recoveries`]).
fn lock_recovering<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| {
        LOCK_POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed);
        poisoned.into_inner()
    })
}

/// Number of slots per segment. 256 slots keeps the segment under 4 KiB for
/// pointer-sized payloads so producer/consumer touch disjoint cache lines
/// most of the time.
pub const SEGMENT_LEN: usize = 256;

struct Segment<T> {
    slots: Box<[Option<T>]>,
}

impl<T> Segment<T> {
    fn new() -> Self {
        let mut v = Vec::with_capacity(SEGMENT_LEN);
        v.resize_with(SEGMENT_LEN, || None);
        Segment { slots: v.into_boxed_slice() }
    }
}

/// Unbounded single-producer / exclusively-acquired-consumer queue.
///
/// The queue is unbounded because a saturated bounded queue would force the
/// producing worker to either block (deadlocking a single-threaded run) or
/// process messages itself (changing the algorithm). The paper's earlier
/// centralized design [7] needed an anti-saturation mechanism; the
/// distributed design sheds load by letting *any* idle worker drain queues,
/// so unboundedness only ever buffers short bursts.
struct Inner<T> {
    segs: VecDeque<Segment<T>>,
    /// Global slot index of `segs[0].slots[0]`. Always a multiple of
    /// `SEGMENT_LEN`; advanced only when the consumer retires a segment.
    base: usize,
}

pub struct SpscQueue<T> {
    inner: Mutex<Inner<T>>,
    /// Total pushed (monotonic). Only the producer writes.
    tail: AtomicUsize,
    /// Total popped (monotonic). Only the current consumer writes.
    head: AtomicUsize,
    /// Consumer token: true while a manager holds the pop side.
    consumer_held: AtomicBool,
    /// Successful consumer-token grabs (telemetry: the request-plane A/B
    /// counts how many queue tokens a manager sweep touches).
    acquires: AtomicUsize,
}

// SAFETY: T must be Send to cross threads; the protocol (single producer,
// single token-holding consumer) serializes slot access: slot i is written
// exactly once by the producer before tail advances past i, and read exactly
// once by the consumer holding the token after observing tail > i.
unsafe impl<T: Send> Send for SpscQueue<T> {}
unsafe impl<T: Send> Sync for SpscQueue<T> {}

impl<T> Default for SpscQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> SpscQueue<T> {
    pub fn new() -> Self {
        let mut segs = VecDeque::new();
        segs.push_back(Segment::new());
        SpscQueue {
            inner: Mutex::new(Inner { segs, base: 0 }),
            tail: AtomicUsize::new(0),
            head: AtomicUsize::new(0),
            consumer_held: AtomicBool::new(false),
            acquires: AtomicUsize::new(0),
        }
    }

    /// Number of messages currently queued.
    #[inline]
    pub fn len(&self) -> usize {
        let t = self.tail.load(Ordering::Acquire);
        let h = self.head.load(Ordering::Acquire);
        t.saturating_sub(h)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Producer-side push. MUST only be called by the owning worker thread.
    pub fn push(&self, value: T) {
        let t = self.tail.load(Ordering::Relaxed);
        let seg_off = t % SEGMENT_LEN;
        {
            let mut inner = lock_recovering(&self.inner);
            // `base` is maintained under this same lock, so the producer's
            // segment arithmetic cannot race with segment retirement.
            let rel = (t - inner.base) / SEGMENT_LEN;
            while inner.segs.len() <= rel {
                inner.segs.push_back(Segment::new());
            }
            let seg = inner.segs.get_mut(rel).unwrap();
            seg.slots[seg_off] = Some(value);
        }
        self.tail.store(t + 1, Ordering::Release);
    }

    /// Try to become the exclusive consumer. Mirrors
    /// `queue.acquire()` in the paper's Listing 2: returns `None` if another
    /// manager thread currently owns the pop side.
    pub fn try_acquire(&self) -> Option<ConsumerGuard<'_, T>> {
        if self
            .consumer_held
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            self.acquires.fetch_add(1, Ordering::Relaxed);
            Some(ConsumerGuard { q: self })
        } else {
            None
        }
    }

    /// Successful [`try_acquire`](SpscQueue::try_acquire) grabs so far. The
    /// DDAST A/B uses this to verify a manager sweep touches only signaled
    /// workers' queues.
    #[inline]
    pub fn acquire_count(&self) -> u64 {
        self.acquires.load(Ordering::Relaxed) as u64
    }

    /// Pop the oldest message. Only callable through a [`ConsumerGuard`].
    fn pop_internal(&self) -> Option<T> {
        let h = self.head.load(Ordering::Relaxed);
        let t = self.tail.load(Ordering::Acquire);
        if h >= t {
            return None;
        }
        let seg_off = h % SEGMENT_LEN;
        let value;
        {
            let mut inner = lock_recovering(&self.inner);
            debug_assert!(h >= inner.base && h < inner.base + SEGMENT_LEN);
            let seg = inner.segs.front_mut().unwrap();
            value = seg.slots[seg_off].take();
            // Crossing a segment boundary: retire the drained front segment.
            if seg_off == SEGMENT_LEN - 1 {
                inner.segs.pop_front();
                inner.base += SEGMENT_LEN;
                if inner.segs.is_empty() {
                    inner.segs.push_back(Segment::new());
                }
            }
        }
        self.head.store(h + 1, Ordering::Release);
        debug_assert!(value.is_some(), "slot {h} empty despite tail {t}");
        value
    }
}

/// Exclusive pop-side token. Dropping it releases the queue for other
/// manager threads.
pub struct ConsumerGuard<'a, T> {
    q: &'a SpscQueue<T>,
}

impl<'a, T> ConsumerGuard<'a, T> {
    /// FIFO pop. Returns `None` when the queue is (momentarily) empty.
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        self.q.pop_internal()
    }

    /// Messages still queued.
    #[inline]
    pub fn len(&self) -> usize {
        self.q.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

impl<'a, T> Drop for ConsumerGuard<'a, T> {
    fn drop(&mut self) {
        self.q.consumer_held.store(false, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop_fifo_single_thread() {
        let q = SpscQueue::new();
        for i in 0..1000 {
            q.push(i);
        }
        assert_eq!(q.len(), 1000);
        let mut g = q.try_acquire().unwrap();
        for i in 0..1000 {
            assert_eq!(g.pop(), Some(i));
        }
        assert_eq!(g.pop(), None);
    }

    #[test]
    fn crosses_many_segments() {
        let q = SpscQueue::new();
        let n = SEGMENT_LEN * 7 + 13;
        for i in 0..n {
            q.push(i);
        }
        let mut g = q.try_acquire().unwrap();
        for i in 0..n {
            assert_eq!(g.pop(), Some(i));
        }
        assert!(g.is_empty());
    }

    #[test]
    fn interleaved_push_pop() {
        let q = SpscQueue::new();
        let mut next_out = 0usize;
        for round in 0..100usize {
            for i in 0..round {
                q.push(round * 1000 + i);
            }
            let mut g = q.try_acquire().unwrap();
            // Drain half.
            for _ in 0..(round / 2) {
                let v = g.pop().unwrap();
                let _ = v;
                next_out += 1;
            }
        }
        let mut g = q.try_acquire().unwrap();
        while g.pop().is_some() {
            next_out += 1;
        }
        let total: usize = (0..100).sum();
        assert_eq!(next_out, total);
    }

    #[test]
    fn consumer_token_is_exclusive() {
        let q: SpscQueue<u32> = SpscQueue::new();
        let g1 = q.try_acquire();
        assert!(g1.is_some());
        assert!(q.try_acquire().is_none());
        drop(g1);
        assert!(q.try_acquire().is_some());
    }

    #[test]
    fn concurrent_producer_consumer() {
        let q = Arc::new(SpscQueue::new());
        let n = 200_000usize;
        let prod = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..n {
                    q.push(i);
                }
            })
        };
        let cons = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut expect = 0usize;
                while expect < n {
                    if let Some(mut g) = q.try_acquire() {
                        while let Some(v) = g.pop() {
                            assert_eq!(v, expect);
                            expect += 1;
                        }
                    }
                    std::hint::spin_loop();
                }
            })
        };
        prod.join().unwrap();
        cons.join().unwrap();
        assert!(q.is_empty());
    }

    #[test]
    fn multi_manager_contention_preserves_fifo_batches() {
        // Several "manager" threads compete for the consumer token; within
        // the token FIFO order must hold, and every message is seen once.
        let q = Arc::new(SpscQueue::new());
        let n = 100_000usize;
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = Arc::clone(&q);
            let seen = Arc::clone(&seen);
            handles.push(std::thread::spawn(move || loop {
                if let Some(mut g) = q.try_acquire() {
                    let mut batch = Vec::new();
                    for _ in 0..64 {
                        match g.pop() {
                            Some(v) => batch.push(v),
                            None => break,
                        }
                    }
                    if !batch.is_empty() {
                        seen.lock().unwrap().extend(batch);
                    }
                }
                let s = seen.lock().unwrap().len();
                if s >= n {
                    break;
                }
                std::hint::spin_loop();
            }));
        }
        for i in 0..n {
            q.push(i);
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut all = seen.lock().unwrap().clone();
        // Token exclusivity + FIFO pop means the concatenation in pop order
        // is exactly 0..n.
        assert_eq!(all.len(), n);
        let sorted_ok = all.windows(2).all(|w| w[0] < w[1]);
        assert!(sorted_ok, "pops were not globally FIFO");
        all.dedup();
        assert_eq!(all.len(), n);
    }
}
