//! Dependency-free RCU-style snapshot cell (the arc-swap idiom, hand-rolled
//! for the offline build).
//!
//! A read-mostly registry — the Functionality Dispatcher's callback list —
//! wants reads that cost one atomic load and writes that may be arbitrarily
//! expensive. [`RcuCell`] stores the current snapshot behind an
//! `AtomicPtr`; readers do a single `Acquire` load and use the snapshot in
//! place (no clone, no refcount bump, no lock), writers clone the snapshot,
//! modify the clone and install it with a CAS.
//!
//! ## Reclamation
//!
//! The classic RCU problem — when may a replaced snapshot be freed? — is
//! resolved the same way [`WsDeque`](crate::substrate::WsDeque) retires its
//! grown buffers: **never before drop**. Replaced snapshots go on a retired
//! list freed when the cell itself is dropped, so a reader's borrowed
//! snapshot stays valid for as long as it can hold it (the borrow is tied
//! to the cell's lifetime). Memory cost is one snapshot per update, which
//! suits registries written a handful of times per process (callback
//! registration happens "during runtime initialization or the application
//! execution" — §3.2 — but is never per-event). Do not use this cell for
//! high-frequency writes.
//!
//! Deferred reclamation also kills ABA on the install CAS: a retired
//! snapshot's address is never handed back to the allocator while the cell
//! lives, so the CAS cannot mistake a recycled pointer for the snapshot it
//! read.

use std::sync::atomic::{AtomicPtr, Ordering};

use crate::substrate::spinlock::SpinLock;
use crate::substrate::stats::Counter;

/// Read-mostly snapshot cell. See the module docs for the cost model.
pub struct RcuCell<T> {
    current: AtomicPtr<T>,
    /// Replaced snapshots, freed on drop (writers only; cold path).
    retired: SpinLock<Vec<*mut T>>,
    updates: Counter,
    update_retries: Counter,
}

// SAFETY: the cell hands out `&T` to any thread (readers) and moves `T`
// values in from writer threads, so both `Send` and `Sync` on `T` are
// required; all shared mutable state is the atomic pointer and the
// spin-locked retired list.
unsafe impl<T: Send + Sync> Send for RcuCell<T> {}
unsafe impl<T: Send + Sync> Sync for RcuCell<T> {}

impl<T> RcuCell<T> {
    pub fn new(value: T) -> Self {
        RcuCell {
            current: AtomicPtr::new(Box::into_raw(Box::new(value))),
            retired: SpinLock::new(Vec::new()),
            updates: Counter::new(),
            update_retries: Counter::new(),
        }
    }

    /// The current snapshot: one `Acquire` load, no lock, no allocation.
    /// The reference stays valid for the borrow of `self` (snapshots are
    /// retired, not freed — module docs), but is a *snapshot*: concurrent
    /// updates will not be visible through it.
    #[inline]
    pub fn read(&self) -> &T {
        // SAFETY: `current` always points at a live allocation; no snapshot
        // is freed before `Drop` takes `&mut self`, which cannot coexist
        // with this `&self` borrow.
        unsafe { &*self.current.load(Ordering::Acquire) }
    }

    /// Clone-and-CAS update. `f` receives the current snapshot and returns
    /// the replacement plus a result passed back to the caller; it may run
    /// several times if concurrent writers race (keep it side-effect-free).
    pub fn update<R, F: FnMut(&T) -> (T, R)>(&self, mut f: F) -> R {
        loop {
            let cur = self.current.load(Ordering::Acquire);
            // SAFETY: live allocation (see `read`).
            let (next, result) = f(unsafe { &*cur });
            let next_ptr = Box::into_raw(Box::new(next));
            match self.current.compare_exchange(
                cur,
                next_ptr,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    // Readers may still hold `cur`: retire it, free on drop.
                    self.retired.lock().push(cur);
                    self.updates.inc();
                    return result;
                }
                Err(_) => {
                    // Lost to a concurrent writer. `next_ptr` was never
                    // published, so it is exclusively ours to free.
                    // SAFETY: just allocated above, unpublished.
                    drop(unsafe { Box::from_raw(next_ptr) });
                    self.update_retries.inc();
                }
            }
        }
    }

    /// (successful updates, lost install races, retired snapshots).
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.updates.get(), self.update_retries.get(), self.retired.lock().len() as u64)
    }
}

impl<T> Drop for RcuCell<T> {
    fn drop(&mut self) {
        // Exclusive access: free the live snapshot and every retired one.
        // SAFETY: all pointers were created by `Box::into_raw` and are
        // distinct (retired list never holds the current pointer).
        unsafe {
            drop(Box::from_raw(self.current.load(Ordering::Relaxed)));
            for p in self.retired.lock().drain(..) {
                drop(Box::from_raw(p));
            }
        }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for RcuCell<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RcuCell").field("current", self.read()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn read_sees_initial_and_updated() {
        let c = RcuCell::new(vec![1, 2]);
        assert_eq!(c.read(), &vec![1, 2]);
        let idx = c.update(|v| {
            let mut v2 = v.clone();
            v2.push(3);
            (v2, v.len())
        });
        assert_eq!(idx, 2, "update returns the closure's result");
        assert_eq!(c.read(), &vec![1, 2, 3]);
        let (updates, retries, retired) = c.stats();
        assert_eq!(updates, 1);
        assert_eq!(retries, 0);
        assert_eq!(retired, 1);
    }

    #[test]
    fn snapshot_survives_concurrent_update() {
        let c = RcuCell::new(String::from("old"));
        let snap = c.read();
        c.update(|_| (String::from("new"), ()));
        // The old snapshot is retired, not freed: still readable.
        assert_eq!(snap, "old");
        assert_eq!(c.read(), "new");
    }

    #[test]
    fn concurrent_updates_all_land() {
        const THREADS: usize = 4;
        const PER: usize = 500;
        let c = Arc::new(RcuCell::new(0u64));
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..PER {
                        c.update(|v| (v + 1, ()));
                    }
                });
            }
        });
        assert_eq!(*c.read(), (THREADS * PER) as u64);
        let (updates, _retries, retired) = c.stats();
        assert_eq!(updates, (THREADS * PER) as u64);
        assert_eq!(retired, updates, "one retired snapshot per update");
    }

    #[test]
    fn drop_frees_all_generations() {
        let marker = Arc::new(());
        {
            let c = RcuCell::new(Arc::clone(&marker));
            for _ in 0..10 {
                c.update(|v| (Arc::clone(v), ()));
            }
            assert_eq!(Arc::strong_count(&marker), 12, "current + 10 retired + local");
        }
        assert_eq!(Arc::strong_count(&marker), 1, "drop freed every snapshot");
    }
}
