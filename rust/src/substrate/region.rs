//! Dependence regions.
//!
//! OmpSs `in(a[i])` / `out(b[i])` clauses name memory *regions*. Nanos++'s
//! default dependence plugin keys them by base address; richer plugins
//! handle overlapping ranges. We model both: a [`RegionKey`] is a
//! `(base, len)` pair; the default hashing mode keys on `base` only (exact
//! match, the common fast path the paper benchmarks), while
//! [`RegionKey::overlaps`] supports the range-overlap plugin used by the
//! property tests to cross-check graph construction.

/// A named memory region a task depends on. `base` is an opaque address-like
/// u64 (workload generators use block coordinates packed into it).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct RegionKey {
    pub base: u64,
    pub len: u64,
}

impl RegionKey {
    #[inline]
    pub fn new(base: u64, len: u64) -> Self {
        RegionKey { base, len }
    }

    /// Address-only key (Nanos++ default plugin behaviour).
    #[inline]
    pub fn addr(base: u64) -> Self {
        RegionKey { base, len: 1 }
    }

    /// Half-open interval overlap test.
    #[inline]
    pub fn overlaps(&self, other: &RegionKey) -> bool {
        self.base < other.base.saturating_add(other.len)
            && other.base < self.base.saturating_add(self.len)
    }

    #[inline]
    pub fn contains(&self, other: &RegionKey) -> bool {
        self.base <= other.base
            && other.base.saturating_add(other.len) <= self.base.saturating_add(self.len)
    }
}

/// Helper to pack (matrix, i, j) block coordinates into region addresses so
/// workload generators produce disjoint keys per logical block.
#[inline]
pub fn block_addr(matrix: u8, i: u64, j: u64) -> u64 {
    ((matrix as u64) << 56) | (i << 28) | j
}

/// A small sorted set of regions, used by tests to reason about task
/// footprints (conflict detection between two tasks' dependence lists).
#[derive(Clone, Debug, Default)]
pub struct RegionSet {
    regions: Vec<RegionKey>,
}

impl RegionSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, r: RegionKey) {
        match self.regions.binary_search(&r) {
            Ok(_) => {}
            Err(pos) => self.regions.insert(pos, r),
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &RegionKey> {
        self.regions.iter()
    }

    pub fn len(&self) -> usize {
        self.regions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.regions.is_empty()
    }

    /// Does any region in `self` overlap any region in `other`?
    pub fn conflicts_with(&self, other: &RegionSet) -> bool {
        // Both sorted by (base, len): sweep in O(n+m) for the common case.
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.regions.len() && j < other.regions.len() {
            let a = &self.regions[i];
            let b = &other.regions[j];
            if a.overlaps(b) {
                return true;
            }
            if a.base.saturating_add(a.len) <= b.base.saturating_add(b.len) {
                i += 1;
            } else {
                j += 1;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_basics() {
        let a = RegionKey::new(0, 10);
        let b = RegionKey::new(9, 1);
        let c = RegionKey::new(10, 5);
        assert!(a.overlaps(&b));
        assert!(!a.overlaps(&c));
        assert!(b.overlaps(&a));
        assert!(a.contains(&b));
        assert!(!b.contains(&a));
    }

    #[test]
    fn addr_keys_are_unit_regions() {
        let a = RegionKey::addr(42);
        assert_eq!(a.len, 1);
        assert!(a.overlaps(&RegionKey::addr(42)));
        assert!(!a.overlaps(&RegionKey::addr(43)));
    }

    #[test]
    fn block_addr_disjoint() {
        // Different matrices / coordinates never collide.
        let mut seen = std::collections::HashSet::new();
        for m in 0..3u8 {
            for i in 0..16 {
                for j in 0..16 {
                    assert!(seen.insert(block_addr(m, i, j)));
                }
            }
        }
    }

    #[test]
    fn region_set_conflicts() {
        let mut s1 = RegionSet::new();
        s1.insert(RegionKey::new(0, 4));
        s1.insert(RegionKey::new(100, 4));
        let mut s2 = RegionSet::new();
        s2.insert(RegionKey::new(50, 10));
        assert!(!s1.conflicts_with(&s2));
        s2.insert(RegionKey::new(102, 1));
        assert!(s1.conflicts_with(&s2));
    }

    #[test]
    fn region_set_dedup() {
        let mut s = RegionSet::new();
        s.insert(RegionKey::addr(7));
        s.insert(RegionKey::addr(7));
        assert_eq!(s.len(), 1);
    }
}
