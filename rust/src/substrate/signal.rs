//! Work-signal directory: a per-worker "dirty" flag directory with a
//! topology-aware two-level summary bitmap, so managers visit only the
//! workers that actually produced requests — and stay on their own socket
//! while doing it.
//!
//! Before this module, the DDAST callback (paper Listing 2) swept *every*
//! worker's queue pair per round — an O(workers) walk plus one queue-token
//! CAS pair per worker even when a single worker was producing. The
//! directory turns that into O(dirty): workers mark themselves dirty with
//! one cheap atomic on their own cache line when they enqueue a request,
//! and managers scan a socket-summary bitmap to find (and claim) only the
//! marked workers. The direction follows Álvarez et al., *Advanced
//! Synchronization Techniques for Task-based Runtime Systems*
//! (arXiv:2105.07902), which removes exactly these residual shared-structure
//! touches from Nanos6's manager paths.
//!
//! ## Structure
//!
//! Three levels, ground truth at the bottom, laid out along a
//! [`Topology`] (sockets × workers-per-socket):
//!
//! 1. **flags** — one cache-padded `AtomicBool` per worker. The worker's
//!    [`raise`](SignalDirectory::raise) is a single `swap` on a line nobody
//!    else writes in steady state (managers touch it only to claim).
//! 2. **words** — `u64` bitmaps laid out **per socket**: socket `s` owns
//!    words `[s·wps, (s+1)·wps)` (`wps` = words per socket), bit = the
//!    worker's index *within its socket*. Written only on a flag
//!    *transition* (clean → dirty), so a worker spamming requests RMWs its
//!    own flag line, not the shared word — and a raise never dirties a
//!    word shared with another socket's workers, so steady-state raise
//!    traffic stays inside the socket's cache domain.
//! 3. **summary** — one `u64`, bit = **socket** with (possibly) dirty
//!    workers. A sweep at 128+ workers loads this one word, then only the
//!    dirty sockets' words — never a clean remote socket's line.
//!
//! [`SignalDirectory::new`] keeps the pre-topology layout exactly (one
//! "socket" per 64-worker word, via [`Topology::word_grain`]);
//! [`SignalDirectory::new_with_topology`] lays the directory out along a
//! real machine shape.
//!
//! ## No-lost-wakeup protocol
//!
//! Producer: enqueue the message, then `raise` (set flag, propagate up on
//! transition). Manager: `claim` (clear word bit, then clear flag), then
//! drain the queue. All flag/word operations are `AcqRel` RMWs, so on each
//! level the two sides are totally ordered by cache coherence:
//!
//! * claim's flag-swap before raise's flag-swap → raise sees `false`,
//!   re-propagates, and the *next* scan observes the worker;
//! * raise's flag-swap before claim's → claim reads the raise's write,
//!   which synchronizes-with it, so the drain that follows the claim sees
//!   the enqueued message.
//!
//! The summary level is maintained conservatively: a scanner that observes
//! an empty word clears the socket's summary bit and *re-checks every word
//! of that socket*, restoring the bit if any is (or was re-)populated. A
//! summary bit may therefore be transiently stale in either direction;
//! scans tolerate false positives (they just load an empty word) and false
//! negatives last at most one in-flight raise (the raiser re-sets the bit
//! before its `raise` returns).
//!
//! ## Fairness
//!
//! [`scan_rotor`](SignalDirectory::scan_rotor) starts each scan at a
//! rotating worker index (shared atomic rotor), so a noisy low-numbered
//! worker cannot starve higher slots of manager attention.
//! [`scan_near`](SignalDirectory::scan_near) rotates the same way but
//! *within the caller's own socket*, so a manager drains local producers
//! before crossing sockets (the scan still wraps the whole directory —
//! locality biases the order, it never strands a remote worker).
//!
//! ## Parking (event-driven idle workers)
//!
//! A fully idle worker — nothing ready, nothing queued, dispatcher
//! callbacks empty-handed — can *park* on the directory instead of
//! sleeping blind: it announces itself in a parked-waiter bitmap
//! ([`begin_park`](SignalDirectory::begin_park), same per-socket word
//! layout as the dirty words), re-checks its wake condition, and blocks on
//! its slot's [`Parker`] ([`park`](SignalDirectory::park)). Producers wake
//! parked waiters through [`wake_parked`](SignalDirectory::wake_parked) —
//! every [`raise`](SignalDirectory::raise) does this automatically, so the
//! next enqueue after a worker parks wakes it.
//!
//! Wake victim selection is **locality-biased and rotor-fair**:
//! [`wake_parked_near`](SignalDirectory::wake_parked_near) scans the
//! preferred worker's socket first (the socket whose deque just received
//! the tasks), falling back to the remaining sockets in rotation — and a
//! per-call wake rotor rotates the start *bit* inside each word, so
//! repeated single-task wakes spread over a socket's parked workers
//! instead of always reviving the lowest-numbered one.
//!
//! The no-lost-wakeup argument is the classic store-buffer (Dekker)
//! pattern, closed with `SeqCst` fences:
//!
//! * waiter: RMW the parked bit, **fence**, load the work state (queues /
//!   ready gauges / shutdown flag) — both inside `begin_park`'s contract;
//! * producer: store the work (enqueue, ready push, shutdown flag),
//!   **fence**, load the parked bitmap — the fence is issued by
//!   `wake_parked` itself, before it reads the bitmap.
//!
//! Sequentially consistent fences on both sides forbid the outcome where
//! each side misses the other's store: either the waiter's re-check sees
//! the new work (and cancels the park), or the producer's wake scan sees
//! the parked bit (and unparks). A wake that races a cancelled park
//! leaves a token in the `Parker`; the next park attempt consumes it and
//! falls through to another re-check — spurious, never lost. The argument
//! is layout-independent: the per-socket words only change *which* lines
//! the scan reads, not the fence pairing, and
//! [`wake_all`](SignalDirectory::wake_all) unconditionally walks **every
//! socket's every word**, so shutdown cannot strand a parked slot behind
//! a locality preference.
//!
//! Two parking refinements serve the runtime's synchronization points:
//! [`park_timeout`](SignalDirectory::park_timeout) bounds the wait where
//! the runtime once slept blind (work visible the caller cannot act on),
//! and [`wake_worker`](SignalDirectory::wake_worker) delivers a *targeted*
//! wake to one slot — the taskwait child-completion wake edge and the
//! dependence-targeted wake edge, where the finalizer knows exactly which
//! worker is parked waiting for it.
//!
//! ## External producers (the ingress lane)
//!
//! Threads *outside* the pool have no worker slot — and must not get one:
//! directory slots are laid out along the machine topology, and widening
//! the layout per external client would change the socket split the tests
//! and the wake paths rely on. Instead the directory carries **one**
//! external-producer bit beside the worker slots
//! ([`raise_external`](SignalDirectory::raise_external) /
//! [`try_claim_external`](SignalDirectory::try_claim_external)): an
//! external submitter publishes its work (a push into the shared ingress
//! ring), then raises the bit — which wakes a parked worker through the
//! same fenced `wake_parked_near` path as a worker raise, so the
//! no-lost-wakeup argument above extends unchanged to the new producer
//! class. Managers treat the bit exactly like a worker's dirty flag:
//! claim, drain the ring, re-raise if the drain left entries behind. The
//! bit is a *separate field*, so scans, sweeps, socket counts and every
//! worker-indexed path are byte-for-byte unaffected when no external
//! producer exists.

use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};

use crate::substrate::deque::{CachePadded, ShardedCounter};
use crate::substrate::park::Parker;
use crate::substrate::stats::Counter;
use crate::substrate::topology::Topology;

const WORD_BITS: usize = 64;

/// Per-worker dirty directory with a topology-aware two-level summary.
/// See the module docs for the protocol.
pub struct SignalDirectory {
    /// Ground truth: worker w is (possibly) dirty while `flags[w]` is set.
    flags: Box<[CachePadded<AtomicBool>]>,
    /// Bitmap hint, laid out per socket (see module docs §Structure),
    /// maintained on transitions only.
    words: Box<[CachePadded<AtomicU64>]>,
    /// Bitmap hint over sockets: bit `s` set while socket `s` has
    /// (possibly) dirty workers (conservative; see module docs).
    summary: CachePadded<AtomicU64>,
    /// Fairness rotor: successive scans start at successive workers.
    rotor: CachePadded<AtomicUsize>,
    /// Wake fairness rotor: successive wake scans rotate the start socket
    /// (when no preference is given) and the start bit within each word.
    wake_rotor: CachePadded<AtomicUsize>,
    /// Sockets in the layout (= summary bits in use).
    sockets: usize,
    /// Worker slots per socket.
    slots_per_socket: usize,
    /// `u64` words per socket (= ceil(slots_per_socket / 64)).
    words_per_socket: usize,
    /// Raises (worker-side; sharded so the hot path stays on private cells).
    raises: ShardedCounter,
    /// Raises that transitioned clean → dirty and touched the shared word.
    promotions: ShardedCounter,
    /// Successful claims (manager-side).
    claims: Counter,
    /// Worker words loaded by claiming scans past the summary gate — the
    /// counter behind the "sweeps visit only dirty sockets" A/B.
    word_visits: Counter,
    /// Parked-waiter bitmap: bit = worker between `begin_park` and its
    /// wake/cancel. Same per-socket word layout as `words`.
    parked: Box<[CachePadded<AtomicU64>]>,
    /// One parking slot per worker (see module docs §Parking).
    parkers: Box<[CachePadded<Parker>]>,
    /// Committed parks (worker actually blocked).
    parks: Counter,
    /// Successful wakes delivered to parked workers.
    park_wakes: Counter,
    /// External-producer dirty bit (module docs §External producers).
    /// Deliberately *not* a worker slot: the slot/word layout — and with
    /// it the socket split — stays identical whether or not external
    /// submitters exist.
    external: CachePadded<AtomicBool>,
    /// External raises (ingress pushes signalled).
    external_raises: Counter,
    /// Fault-injection plan for [`FaultSite::IngressRaise`]
    /// (`raise_external` is called by outside threads with no runtime
    /// context, so the site lives here rather than in the pool). `None` in
    /// production — the site check is then a single branch. Installed once
    /// at construction time ([`install_fault_plan`]
    /// (SignalDirectory::install_fault_plan)), before the directory is
    /// shared.
    fault_plan: Option<std::sync::Arc<crate::substrate::fault::FaultPlan>>,
}

impl SignalDirectory {
    /// A directory for `n` worker slots (1 ..= 4096), laid out at word
    /// grain ([`Topology::word_grain`]) — the flat pre-topology layout:
    /// one summary bit per 64-worker word.
    pub fn new(n: usize) -> Self {
        assert!(n <= WORD_BITS * WORD_BITS, "summary bitmap covers 4096 slots");
        Self::new_with_topology(n, Topology::word_grain(n))
    }

    /// A directory for `n` worker slots laid out along `topo` (widened via
    /// [`Topology::cover`] if the shape is smaller than `n` — directories
    /// are sized by *slots*, which may exceed the worker count).
    pub fn new_with_topology(n: usize, topo: Topology) -> Self {
        assert!(n >= 1, "directory needs at least one worker slot");
        let topo = topo.cover(n);
        let slots_per_socket = topo.workers_per_socket();
        // Trim trailing sockets the slot count never reaches.
        let sockets = n.div_ceil(slots_per_socket).min(topo.sockets());
        assert!(sockets <= WORD_BITS, "socket summary bitmap is one u64");
        let words_per_socket = slots_per_socket.div_ceil(WORD_BITS);
        let nwords = sockets * words_per_socket;
        SignalDirectory {
            flags: (0..n).map(|_| CachePadded::new(AtomicBool::new(false))).collect(),
            words: (0..nwords).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
            summary: CachePadded::new(AtomicU64::new(0)),
            rotor: CachePadded::new(AtomicUsize::new(0)),
            wake_rotor: CachePadded::new(AtomicUsize::new(0)),
            sockets,
            slots_per_socket,
            words_per_socket,
            raises: ShardedCounter::with_shards(n + 2),
            promotions: ShardedCounter::with_shards(n + 2),
            claims: Counter::new(),
            word_visits: Counter::new(),
            parked: (0..nwords).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
            parkers: (0..n).map(|_| CachePadded::new(Parker::new())).collect(),
            parks: Counter::new(),
            park_wakes: Counter::new(),
            external: CachePadded::new(AtomicBool::new(false)),
            external_raises: Counter::new(),
            fault_plan: None,
        }
    }

    /// Install a [`FaultPlan`](crate::substrate::fault::FaultPlan) whose
    /// [`IngressRaise`](crate::substrate::fault::FaultSite::IngressRaise)
    /// site gates [`raise_external`](SignalDirectory::raise_external).
    /// Requires exclusive access — call before the directory is shared
    /// (the runtime constructor does, when a plan is configured).
    pub fn install_fault_plan(
        &mut self,
        plan: std::sync::Arc<crate::substrate::fault::FaultPlan>,
    ) {
        self.fault_plan = Some(plan);
    }

    /// Worker slots covered.
    #[inline]
    pub fn len(&self) -> usize {
        self.flags.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.flags.is_empty()
    }

    /// Sockets in the directory's layout.
    #[inline]
    pub fn sockets(&self) -> usize {
        self.sockets
    }

    /// Socket of `worker` under the directory's layout.
    #[inline]
    pub fn socket_of(&self, worker: usize) -> usize {
        (worker / self.slots_per_socket).min(self.sockets - 1)
    }

    /// Word index holding `worker`'s bit (layout introspection — the
    /// topology A/B counts cross-socket shared words through this).
    #[inline]
    pub fn word_of(&self, worker: usize) -> usize {
        self.locate(worker).0
    }

    /// Worker words in the directory.
    #[inline]
    pub fn word_count(&self) -> usize {
        self.words.len()
    }

    /// Worker words loaded by claiming scans past the summary gate.
    #[inline]
    pub fn word_visits(&self) -> u64 {
        self.word_visits.get()
    }

    /// (word index, bit, socket) of `worker` under the per-socket layout.
    #[inline]
    fn locate(&self, worker: usize) -> (usize, u64, usize) {
        let s = worker / self.slots_per_socket;
        let local = worker - s * self.slots_per_socket;
        let wi = s * self.words_per_socket + local / WORD_BITS;
        (wi, 1u64 << (local % WORD_BITS), s)
    }

    /// Worker index of bit `b` in word `wi` (inverse of `locate`).
    #[inline]
    fn worker_at(&self, wi: usize, b: usize) -> usize {
        let s = wi / self.words_per_socket;
        s * self.slots_per_socket + (wi % self.words_per_socket) * WORD_BITS + b
    }

    /// Mark `worker` dirty. Callable from any thread (re-raising a worker
    /// whose budgeted drain left messages behind is done by managers); the
    /// hot path — the worker signalling its own enqueue — is one `AcqRel`
    /// swap on the worker's private flag line plus a sharded stat bump,
    /// plus the parked-waiter wake check (a fence and a bitmap load when
    /// nobody is parked — see module docs §Parking).
    ///
    /// The wake check runs on *every* raise, not only on clean→dirty
    /// promotions: a stale-dirty flag (raised, queue already drained) must
    /// not swallow the wakeup for a fresh message behind it. The wake
    /// prefers the raiser's own socket — the manager it revives drains the
    /// queue without crossing sockets.
    #[inline]
    pub fn raise(&self, worker: usize) {
        debug_assert!(worker < self.flags.len());
        self.raises.inc();
        if !self.flags[worker].swap(true, Ordering::AcqRel) {
            // Clean → dirty transition: propagate up the hierarchy.
            self.promotions.inc();
            let (wi, bit, s) = self.locate(worker);
            if self.words[wi].fetch_or(bit, Ordering::AcqRel) == 0 {
                self.summary.fetch_or(1u64 << s, Ordering::AcqRel);
            }
        }
        self.wake_parked_near(1, Some(worker));
    }

    /// Is `worker` currently marked dirty? (Racy peek, for telemetry and
    /// quiescence sweeps.)
    #[inline]
    pub fn is_raised(&self, worker: usize) -> bool {
        self.flags[worker].load(Ordering::Acquire)
    }

    /// Claim `worker`'s dirty mark: clears its word bit, then its flag
    /// (top-down, so a concurrent raise re-propagates — module docs).
    /// Returns `true` if the flag was set, i.e. the caller now owes the
    /// worker a queue drain.
    pub fn try_claim(&self, worker: usize) -> bool {
        debug_assert!(worker < self.flags.len());
        let (wi, bit, _) = self.locate(worker);
        self.words[wi].fetch_and(!bit, Ordering::AcqRel);
        if self.flags[worker].swap(false, Ordering::AcqRel) {
            self.claims.inc();
            true
        } else {
            false
        }
    }

    /// One scan over the directory starting at `start`, claiming each dirty
    /// worker as it is yielded. The iterator visits every slot position at
    /// most once (one full rotation), touching only words whose socket the
    /// summary marks dirty.
    pub fn scan_from(&self, start: usize) -> ScanClaim<'_> {
        let n = self.flags.len();
        let start = start % n;
        let (start_word, bit, _) = self.locate(start);
        ScanClaim {
            dir: self,
            start_word,
            start_bit: bit.trailing_zeros() as usize,
            nwords: self.words.len(),
            visit: 0,
            cur_word: 0,
            cur_mask: 0,
        }
    }

    /// [`scan_from`](SignalDirectory::scan_from) at the shared fairness
    /// rotor; each call advances the rotor by one slot.
    pub fn scan_rotor(&self) -> ScanClaim<'_> {
        let start = self.rotor.fetch_add(1, Ordering::Relaxed) % self.flags.len();
        self.scan_from(start)
    }

    /// [`scan_from`](SignalDirectory::scan_from) starting inside
    /// `worker`'s own socket (rotor-rotated within it), so a manager
    /// drains same-socket producers before crossing sockets. The scan
    /// still wraps the whole directory — locality biases the order, it
    /// never strands a remote worker.
    pub fn scan_near(&self, worker: usize) -> ScanClaim<'_> {
        let n = self.flags.len();
        let s = self.socket_of(worker.min(n - 1));
        let base = s * self.slots_per_socket;
        let span = self.slots_per_socket.min(n - base).max(1);
        let off = self.rotor.fetch_add(1, Ordering::Relaxed) % span;
        self.scan_from(base + off)
    }

    /// First raised worker at index ≥ `start` (flag sweep — the exact
    /// ground truth, for quiescence cross-checks; O(n), off the hot path).
    pub fn first_raised_from(&self, start: usize) -> Option<usize> {
        (start..self.flags.len()).find(|&w| self.flags[w].load(Ordering::Acquire))
    }

    /// (raises, clean→dirty promotions, successful claims).
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.raises.get(), self.promotions.get(), self.claims.get())
    }

    // ---- external producers ---------------------------------------------

    /// Mark the external-producer lane dirty. Called by an outside thread
    /// *after* it published work into the ingress ring (publish-then-
    /// signal, exactly like a worker's `raise`). Wakes a parked worker on
    /// every call — a stale-dirty bit must not swallow the wake for fresh
    /// traffic behind it — and `wake_parked_near` issues the producer-side
    /// `SeqCst` fence, so the no-lost-wakeup pairing with `begin_park`
    /// holds for this producer class too. No socket preference: external
    /// traffic has no home socket.
    /// Fault site [`IngressRaise`](crate::substrate::fault::FaultSite::IngressRaise):
    /// an injected fault drops the raise *after* the producer published its
    /// ring entry — the ring is then stranded behind a clean external bit,
    /// and the hang watchdog's `ingress_pending > 0` re-raise must heal it
    /// (a blocking `submit_async` hangs otherwise).
    #[inline]
    pub fn raise_external(&self) {
        if let Some(plan) = &self.fault_plan {
            if plan.should_inject(crate::substrate::fault::FaultSite::IngressRaise) {
                return;
            }
        }
        self.external_raises.inc();
        self.external.swap(true, Ordering::AcqRel);
        self.wake_parked_near(1, None);
    }

    /// Claim the external-producer bit. Returns `true` if it was set — the
    /// caller now owes the ingress ring a drain (and must re-raise if the
    /// drain leaves entries behind, mirroring the budgeted worker drain).
    #[inline]
    pub fn try_claim_external(&self) -> bool {
        self.external.swap(false, Ordering::AcqRel)
    }

    /// Is the external-producer lane currently marked dirty? (Racy peek,
    /// for sweep gating and quiescence checks.)
    #[inline]
    pub fn external_raised(&self) -> bool {
        self.external.load(Ordering::Acquire)
    }

    /// Raises taken on the external-producer lane.
    pub fn external_raises(&self) -> u64 {
        self.external_raises.get()
    }

    // ---- parking ---------------------------------------------------------

    /// Announce that `worker` is about to park: publish its parked bit with
    /// a `SeqCst` RMW, then fence. **Contract:** the caller must re-check
    /// its wake condition (queued messages, ready tasks, shutdown) *after*
    /// this returns, and then either [`park`](SignalDirectory::park) /
    /// [`park_timeout`](SignalDirectory::park_timeout) or
    /// [`cancel_park`](SignalDirectory::cancel_park). The trailing fence
    /// pairs with the one in [`wake_parked`](SignalDirectory::wake_parked)
    /// so plain loads suffice for the re-check (module docs §Parking).
    ///
    /// Returns `true` when this call claimed the announcement (the bit
    /// transitioned 0 → 1). `false` means another thread is already mid-
    /// park on this slot (reachable only when an external thread drives a
    /// pool worker's id, e.g. two handles taskwaiting as worker 0): the
    /// caller must back off instead of double-parking the slot's
    /// [`Parker`], whose blocking side is single-owner.
    #[must_use = "a false return means another thread owns the slot; parking anyway double-parks its Parker"]
    pub fn begin_park(&self, worker: usize) -> bool {
        debug_assert!(worker < self.flags.len());
        let (wi, bit, _) = self.locate(worker);
        let had = self.parked[wi].fetch_or(bit, Ordering::SeqCst) & bit != 0;
        fence(Ordering::SeqCst);
        !had
    }

    /// Abort a park attempt announced with `begin_park` (the re-check found
    /// work). A wake that already claimed the bit left a token in the
    /// slot's `Parker`; the next `park` consumes it and returns immediately
    /// — one spurious loop, never a lost wakeup.
    pub fn cancel_park(&self, worker: usize) {
        let (wi, bit, _) = self.locate(worker);
        self.parked[wi].fetch_and(!bit, Ordering::AcqRel);
    }

    /// Commit the park announced with `begin_park`: block until a producer
    /// wakes this slot (or a pending token is consumed). Clears the parked
    /// bit on return. Only the slot's owner thread may call this.
    pub fn park(&self, worker: usize) {
        self.parks.inc();
        self.parkers[worker].park();
        // A waker normally clears the bit before unparking; clear it
        // ourselves in case the token came from a wake raced by an earlier
        // cancelled attempt.
        self.cancel_park(worker);
    }

    /// Commit the park announced with `begin_park`, but give up after
    /// `timeout` — the bounded variant the runtime uses where it once slept
    /// blind (work is visible that the caller cannot act on, or a shutdown
    /// drain is in progress): same re-check cadence as the old 100 µs
    /// sleep quantum, but a producer's wake edge ends it early. Clears the
    /// parked bit on either outcome. Returns `true` when a wake token was
    /// consumed, `false` on timeout.
    pub fn park_timeout(&self, worker: usize, timeout: std::time::Duration) -> bool {
        self.parks.inc();
        let woke = self.parkers[worker].park_timeout(timeout);
        // On the timeout path no waker claimed the bit: withdraw it (a
        // waker that did claim it left it clear; this is then a no-op).
        self.cancel_park(worker);
        woke
    }

    /// Targeted wake for `worker` — the taskwait **child-completion wake
    /// edge** and the **dependence-targeted wake edge**
    /// (`RuntimeShared::finalize_task` → a waiter parked on a parent's
    /// children or a predecessor's completion). Issues the producer-side
    /// `SeqCst` fence, claims the worker's parked bit if set, and unparks
    /// the slot's [`Parker`] **unconditionally**: an unclaimed wake merely
    /// deposits a token the slot's next park attempt consumes — one
    /// spurious re-check, never a lost wakeup (the waiter it raced is by
    /// then awake and re-checking). Returns whether a committed
    /// announcement was claimed.
    pub fn wake_worker(&self, worker: usize) -> bool {
        if worker >= self.parkers.len() {
            return false;
        }
        fence(Ordering::SeqCst);
        let (wi, bit, _) = self.locate(worker);
        let claimed = self.parked[wi].fetch_and(!bit, Ordering::AcqRel) & bit != 0;
        self.parkers[worker].unpark();
        if claimed {
            self.park_wakes.inc();
        }
        claimed
    }

    /// Wake up to `n` parked workers with no socket preference (the start
    /// socket rotates per call). See
    /// [`wake_parked_near`](SignalDirectory::wake_parked_near).
    pub fn wake_parked(&self, n: usize) -> usize {
        self.wake_parked_near(n, None)
    }

    /// Wake up to `n` parked workers, preferring `prefer`'s socket.
    /// Issues the producer-side `SeqCst` fence (module docs §Parking)
    /// before reading the bitmap, so callers only need to have *already
    /// published* the work being signalled. Called by
    /// [`raise`](SignalDirectory::raise) for message traffic (preferring
    /// the raiser's socket); ready-task producers pass the worker whose
    /// deque received the tasks, shutdown wakes all.
    ///
    /// Victim selection is two-level and rotor-fair: the preferred socket
    /// (or, with no preference, a per-call rotating start socket) is
    /// scanned first, remaining sockets in rotation after it — and inside
    /// each word the start *bit* rotates per call, so repeated wakes
    /// don't always revive a socket's lowest-numbered worker. Returns the
    /// number of workers woken.
    pub fn wake_parked_near(&self, n: usize, prefer: Option<usize>) -> usize {
        if n == 0 {
            return 0;
        }
        fence(Ordering::SeqCst);
        let rot = self.wake_rotor.fetch_add(1, Ordering::Relaxed);
        let start_bit = (rot % WORD_BITS) as u32;
        let start_socket = match prefer {
            Some(w) if w < self.flags.len() => self.socket_of(w),
            _ => rot % self.sockets,
        };
        let mut woken = 0;
        for k in 0..self.sockets {
            if woken >= n {
                break;
            }
            let s = (start_socket + k) % self.sockets;
            for j in 0..self.words_per_socket {
                if woken >= n {
                    break;
                }
                woken += self.wake_in_word(s * self.words_per_socket + j, start_bit, n - woken);
            }
        }
        woken
    }

    /// Claim-and-unpark parked bits of word `wi`, starting at `start_bit`
    /// and proceeding cyclically, up to `budget` wakes.
    fn wake_in_word(&self, wi: usize, start_bit: u32, budget: usize) -> usize {
        let word = &self.parked[wi];
        let mut woken = 0;
        while woken < budget {
            let val = word.load(Ordering::Acquire);
            if val == 0 {
                break;
            }
            let idx = (val.rotate_right(start_bit).trailing_zeros() + start_bit)
                % WORD_BITS as u32;
            let bit = 1u64 << idx;
            // Claim the bit; a racing waker may have beaten us to it (the
            // re-load then sees it cleared and picks another or stops).
            if word.fetch_and(!bit, Ordering::AcqRel) & bit != 0 {
                let w = self.worker_at(wi, idx as usize);
                self.parkers[w].unpark();
                self.park_wakes.inc();
                woken += 1;
            }
        }
        woken
    }

    /// Wake every parked worker (shutdown, quiescence edges). Traverses
    /// **both** directory levels unconditionally — every socket, every
    /// word — so an oversubscribed or locality-laid-out directory can
    /// never strand a parked slot.
    pub fn wake_all(&self) -> usize {
        self.wake_parked(usize::MAX)
    }

    /// Workers currently announced as parked (racy peek, tests/telemetry).
    pub fn parked_count(&self) -> usize {
        self.parked.iter().map(|w| w.load(Ordering::Acquire).count_ones() as usize).sum()
    }

    /// (committed parks, wakes delivered to parked workers).
    pub fn park_stats(&self) -> (u64, u64) {
        (self.parks.get(), self.park_wakes.get())
    }
}

/// Claiming scan over a [`SignalDirectory`] (see
/// [`scan_from`](SignalDirectory::scan_from)). Yields each claimed worker;
/// dirty workers it does *not* reach (caller stopped early) keep their
/// marks for the next scan.
pub struct ScanClaim<'a> {
    dir: &'a SignalDirectory,
    start_word: usize,
    start_bit: usize,
    nwords: usize,
    /// Word visits performed. Visit 0 is the start word masked to bits ≥
    /// `start_bit`; visits 1..nwords walk the remaining words in rotation;
    /// visit nwords revisits the start word's low bits (in-word rotation).
    visit: usize,
    cur_word: usize,
    cur_mask: u64,
}

impl Iterator for ScanClaim<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        loop {
            while self.cur_mask != 0 {
                let b = self.cur_mask.trailing_zeros() as usize;
                self.cur_mask &= self.cur_mask - 1;
                let w = self.dir.worker_at(self.cur_word, b);
                if w < self.dir.len() && self.dir.try_claim(w) {
                    return Some(w);
                }
                // Bit already claimed by a racing manager (or a slot past
                // the directory end in the last partial word): skip.
            }
            if self.visit > self.nwords {
                return None;
            }
            let low_mask = (1u64 << self.start_bit).wrapping_sub(1);
            let (wi, filter) = if self.visit == 0 {
                (self.start_word, !low_mask)
            } else if self.visit == self.nwords {
                (self.start_word, low_mask)
            } else {
                ((self.start_word + self.visit) % self.nwords, u64::MAX)
            };
            self.visit += 1;
            if filter == 0 {
                continue;
            }
            let socket = wi / self.dir.words_per_socket;
            let sbit = 1u64 << socket;
            if self.dir.summary.load(Ordering::Acquire) & sbit == 0 {
                continue;
            }
            self.dir.word_visits.inc();
            let val = self.dir.words[wi].load(Ordering::Acquire);
            if val == 0 {
                // Word drained: drop the socket's summary hint, then
                // re-check *every word of the socket* for a raise that
                // landed in between and restore the hint.
                self.dir.summary.fetch_and(!sbit, Ordering::AcqRel);
                let base = socket * self.dir.words_per_socket;
                let repopulated = (base..base + self.dir.words_per_socket)
                    .any(|k| self.dir.words[k].load(Ordering::Acquire) != 0);
                if repopulated {
                    self.dir.summary.fetch_or(sbit, Ordering::AcqRel);
                }
                continue;
            }
            self.cur_word = wi;
            self.cur_mask = val & filter;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as StdAtomicU64;
    use std::sync::Arc;

    #[test]
    fn raise_then_scan_claims_once() {
        let dir = SignalDirectory::new(8);
        assert_eq!(dir.scan_from(0).next(), None);
        dir.raise(5);
        dir.raise(5); // idempotent while dirty
        let got: Vec<usize> = dir.scan_from(0).collect();
        assert_eq!(got, vec![5]);
        assert_eq!(dir.scan_from(0).next(), None, "claim consumed the mark");
        let (raises, promotions, claims) = dir.stats();
        assert_eq!(raises, 2);
        assert_eq!(promotions, 1, "second raise saw the flag already set");
        assert_eq!(claims, 1);
    }

    #[test]
    fn spans_multiple_words() {
        let dir = SignalDirectory::new(130);
        for w in [0usize, 63, 64, 129] {
            dir.raise(w);
        }
        let mut got: Vec<usize> = dir.scan_from(0).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 63, 64, 129]);
        assert!(dir.first_raised_from(0).is_none());
    }

    #[test]
    fn raise_after_scan_is_seen_by_next_scan() {
        let dir = SignalDirectory::new(70);
        assert_eq!(dir.scan_from(0).next(), None);
        dir.raise(69);
        assert_eq!(dir.scan_from(0).collect::<Vec<_>>(), vec![69]);
        // Re-raise after the claim (the budgeted-drain leftover case).
        dir.raise(69);
        assert_eq!(dir.scan_from(0).collect::<Vec<_>>(), vec![69]);
    }

    #[test]
    fn scan_rotation_orders_from_start() {
        let dir = SignalDirectory::new(8);
        for w in 0..8 {
            dir.raise(w);
        }
        let got: Vec<usize> = dir.scan_from(5).collect();
        assert_eq!(got, vec![5, 6, 7, 0, 1, 2, 3, 4], "in-word rotation");
    }

    #[test]
    fn scan_rotation_orders_across_sockets() {
        // 3 sockets × 4 workers: worker order must survive the per-socket
        // word layout (socket-major words = worker order).
        let dir = SignalDirectory::new_with_topology(12, Topology::new(3, 4));
        assert_eq!(dir.sockets(), 3);
        assert_eq!(dir.word_count(), 3);
        for w in 0..12 {
            dir.raise(w);
        }
        let got: Vec<usize> = dir.scan_from(6).collect();
        assert_eq!(got, vec![6, 7, 8, 9, 10, 11, 0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn scan_near_starts_in_own_socket_and_wraps() {
        let dir = SignalDirectory::new_with_topology(12, Topology::new(3, 4));
        for w in 0..12 {
            dir.raise(w);
        }
        // Worker 5 lives in socket 1 (workers 4..8): the scan must begin
        // there, and still reach every other socket's workers.
        let got: Vec<usize> = dir.scan_near(5).collect();
        assert_eq!(got.len(), 12, "locality bias must not strand anyone");
        assert!(
            (4..8).contains(&got[0]),
            "scan_near(5) started at {} — outside socket 1",
            got[0]
        );
    }

    #[test]
    fn two_level_scan_visits_only_dirty_socket_words() {
        // 4 sockets × 32 workers (the acceptance shape): dirty exactly one
        // socket, and the claiming scan must load exactly that socket's
        // word — not all four.
        let dir = SignalDirectory::new_with_topology(128, Topology::new(4, 32));
        assert_eq!(dir.word_count(), 4);
        for w in 64..96 {
            dir.raise(w); // socket 2 only
        }
        let before = dir.word_visits();
        let got: Vec<usize> = dir.scan_from(0).collect();
        assert_eq!(got.len(), 32);
        let visited = dir.word_visits() - before;
        assert_eq!(visited, 1, "only the dirty socket's word is loaded");
    }

    #[test]
    fn rotor_advances_between_scans() {
        let dir = SignalDirectory::new(4);
        dir.raise(0);
        dir.raise(1);
        let first: Vec<usize> = dir.scan_rotor().collect();
        dir.raise(0);
        dir.raise(1);
        let second: Vec<usize> = dir.scan_rotor().collect();
        // Both scans see both workers; the rotor shifted the start.
        let mut f = first.clone();
        let mut s = second.clone();
        f.sort_unstable();
        s.sort_unstable();
        assert_eq!(f, vec![0, 1]);
        assert_eq!(s, vec![0, 1]);
        assert_ne!(first, second, "fairness rotor rotates the visit order");
    }

    #[test]
    fn concurrent_raise_claim_loses_nothing() {
        const N: usize = 96;
        const PER: u64 = 20_000;
        const PRODUCERS: usize = 3;
        let dir = Arc::new(SignalDirectory::new(N));
        run_raise_claim_stress(dir, N, PER, PRODUCERS);
    }

    /// Satellite port: the same store-buffer-proof stress at 128 workers
    /// laid out across 4 socket boundaries — raises and claims cross the
    /// per-socket words and the socket summary on every path.
    #[test]
    fn concurrent_raise_claim_loses_nothing_two_level_128() {
        const N: usize = 128;
        const PER: u64 = 15_000;
        const PRODUCERS: usize = 4;
        let dir = Arc::new(SignalDirectory::new_with_topology(N, Topology::new(4, 32)));
        assert_eq!(dir.sockets(), 4);
        run_raise_claim_stress(dir, N, PER, PRODUCERS);
    }

    fn run_raise_claim_stress(
        dir: Arc<SignalDirectory>,
        n: usize,
        per: u64,
        producers: usize,
    ) {
        let pending: Arc<Vec<StdAtomicU64>> =
            Arc::new((0..n).map(|_| StdAtomicU64::new(0)).collect());
        let drained = Arc::new(StdAtomicU64::new(0));
        let live = Arc::new(StdAtomicU64::new(producers as u64));
        let total = per * producers as u64;
        std::thread::scope(|s| {
            for p in 0..producers {
                let dir = Arc::clone(&dir);
                let pending = Arc::clone(&pending);
                let live = Arc::clone(&live);
                s.spawn(move || {
                    for i in 0..per {
                        let w = ((i.wrapping_mul(2654435761) >> 3) as usize + p * 31) % n;
                        pending[w].fetch_add(1, Ordering::Release);
                        dir.raise(w);
                    }
                    live.fetch_sub(1, Ordering::AcqRel);
                });
            }
            let dir2 = Arc::clone(&dir);
            let pending2 = Arc::clone(&pending);
            let drained2 = Arc::clone(&drained);
            let live2 = Arc::clone(&live);
            s.spawn(move || {
                let mut empty_after_done = 0u32;
                loop {
                    let mut got = 0u64;
                    for w in dir2.scan_rotor() {
                        got += pending2[w].swap(0, Ordering::AcqRel);
                    }
                    let d = drained2.fetch_add(got, Ordering::AcqRel) + got;
                    if d >= total {
                        break;
                    }
                    if got == 0 {
                        if live2.load(Ordering::Acquire) == 0 {
                            empty_after_done += 1;
                            // Bounded, so a lost wakeup fails fast instead
                            // of hanging the suite.
                            assert!(
                                empty_after_done < 10_000,
                                "directory lost a wakeup: drained {d}/{total}"
                            );
                        }
                        std::thread::yield_now();
                    }
                }
            });
        });
        assert_eq!(drained.load(Ordering::Acquire), total);
        // Any leftover raised flag must be stale (its pending already 0).
        let leftovers: Vec<usize> = dir.scan_from(0).collect();
        for w in leftovers {
            assert_eq!(pending[w].load(Ordering::Acquire), 0, "worker {w} left behind");
        }
        assert!(dir.first_raised_from(0).is_none());
    }

    // ---- parking ---------------------------------------------------------

    #[test]
    fn park_cancel_and_token_roundtrip() {
        let dir = SignalDirectory::new(8);
        assert_eq!(dir.parked_count(), 0);
        assert!(dir.begin_park(3));
        assert_eq!(dir.parked_count(), 1);
        dir.cancel_park(3);
        assert_eq!(dir.parked_count(), 0);
        // A wake that wins the race against the (re-announced) parker
        // deposits a token; park then returns without blocking.
        assert!(dir.begin_park(3));
        assert_eq!(dir.wake_parked(1), 1);
        assert_eq!(dir.parked_count(), 0, "waker claimed the bit");
        assert!(dir.begin_park(3));
        dir.park(3); // consumes the pending token, must not block
        assert_eq!(dir.parked_count(), 0);
        let (parks, wakes) = dir.park_stats();
        assert_eq!(parks, 1);
        assert_eq!(wakes, 1);
    }

    #[test]
    fn wake_parked_bounds_and_wake_all() {
        let dir = SignalDirectory::new(130);
        for w in [1usize, 64, 129] {
            assert!(dir.begin_park(w));
        }
        assert_eq!(dir.parked_count(), 3);
        assert_eq!(dir.wake_parked(2), 2);
        assert_eq!(dir.parked_count(), 1);
        assert_eq!(dir.wake_all(), 1);
        assert_eq!(dir.parked_count(), 0);
        assert_eq!(dir.wake_all(), 0, "nothing left to wake");
    }

    #[test]
    fn wake_parked_prefers_the_given_socket() {
        let dir = SignalDirectory::new_with_topology(32, Topology::new(4, 8));
        // One parked worker per socket.
        for w in [2usize, 10, 18, 26] {
            assert!(dir.begin_park(w));
        }
        // Preferring worker 19's socket (socket 2) must wake its parked
        // neighbour first, regardless of the rotor state.
        for _ in 0..8 {
            assert_eq!(dir.wake_parked_near(1, Some(19)), 1);
            for w in [2usize, 10, 26] {
                assert!(!dir.begin_park(w), "remote-socket slot {w} was woken");
            }
            // The socket-2 slot's bit was the one claimed: re-announce it
            // for the next round (its Parker holds the deposited tokens).
            assert!(dir.begin_park(18));
        }
        dir.wake_all();
    }

    #[test]
    fn wake_rotor_spreads_wakes_within_a_socket() {
        // Satellite: repeated wakes must not always revive the socket's
        // lowest-numbered worker. Park all 8 slots of a one-socket
        // directory, wake one at a time, and record which slot each wake
        // picked (the slot whose re-announce now succeeds).
        let dir = SignalDirectory::new_with_topology(8, Topology::new(1, 8));
        let mut picked = Vec::new();
        for _ in 0..8 {
            for w in 0..8 {
                let _ = dir.begin_park(w); // idempotent for already-parked
            }
            assert_eq!(dir.wake_parked(1), 1);
            let woken = (0..8)
                .find(|&w| {
                    if dir.begin_park(w) {
                        dir.cancel_park(w);
                        true
                    } else {
                        false
                    }
                })
                .expect("exactly one slot was woken");
            picked.push(woken);
        }
        dir.wake_all();
        let distinct: std::collections::HashSet<_> = picked.iter().collect();
        assert!(
            distinct.len() >= 2,
            "wake rotor never rotated: picked {picked:?}"
        );
    }

    /// Satellite regression: an oversubscribed two-level directory with
    /// 128 workers parked across 4 sockets — one `wake_all` sweep (the
    /// `request_shutdown` path) must traverse both levels and free every
    /// slot; a stranded parked worker hangs (and times out) the join.
    #[test]
    fn wake_all_frees_128_parked_workers_across_sockets() {
        const N: usize = 128;
        let dir = Arc::new(SignalDirectory::new_with_topology(N, Topology::new(4, 32)));
        std::thread::scope(|s| {
            for w in 0..N {
                let dir = Arc::clone(&dir);
                s.spawn(move || {
                    assert!(dir.begin_park(w));
                    dir.park(w); // a wake_all that misses this slot hangs here
                });
            }
            let mut woken = 0usize;
            while woken < N {
                woken += dir.wake_all();
                std::thread::yield_now();
            }
        });
        assert_eq!(dir.parked_count(), 0);
        let (parks, wakes) = dir.park_stats();
        assert_eq!(parks, N as u64);
        assert_eq!(wakes, N as u64);
    }

    #[test]
    fn begin_park_claims_the_announcement() {
        let dir = SignalDirectory::new(4);
        assert!(dir.begin_park(2), "first announcement claims the slot");
        assert!(!dir.begin_park(2), "second announcer must back off");
        dir.cancel_park(2);
        assert!(dir.begin_park(2), "cancel releases the claim");
        dir.cancel_park(2);
    }

    #[test]
    fn wake_worker_targets_one_slot() {
        let dir = SignalDirectory::new(70);
        assert!(dir.begin_park(1));
        assert!(dir.begin_park(69));
        assert!(dir.wake_worker(69), "claimed the announced slot");
        assert_eq!(dir.parked_count(), 1, "slot 1 untouched");
        // Unclaimed wake: deposits a token only.
        assert!(!dir.wake_worker(3));
        assert!(dir.begin_park(3));
        dir.park(3); // consumes the deposited token, must not block
        assert!(!dir.wake_worker(usize::MAX), "out-of-range is a no-op");
        let (_, wakes) = dir.park_stats();
        assert_eq!(wakes, 1, "only the claimed wake counted");
        dir.cancel_park(1);
    }

    #[test]
    fn park_timeout_times_out_and_clears_bit() {
        let dir = SignalDirectory::new(2);
        assert!(dir.begin_park(0));
        assert!(!dir.park_timeout(0, std::time::Duration::from_millis(2)));
        assert_eq!(dir.parked_count(), 0, "timeout withdrew the announcement");
        // A pending token ends the timed park immediately.
        dir.wake_worker(0);
        assert!(dir.begin_park(0));
        assert!(dir.park_timeout(0, std::time::Duration::from_secs(60)));
        assert_eq!(dir.parked_count(), 0);
    }

    // ---- external producers ---------------------------------------------

    #[test]
    fn external_bit_raise_claim_roundtrip() {
        let dir = SignalDirectory::new(8);
        assert!(!dir.external_raised());
        assert!(!dir.try_claim_external(), "clean lane claims nothing");
        dir.raise_external();
        dir.raise_external(); // idempotent while dirty
        assert!(dir.external_raised());
        assert_eq!(dir.external_raises(), 2);
        assert!(dir.try_claim_external());
        assert!(!dir.external_raised());
        assert!(!dir.try_claim_external(), "claim consumed the bit");
        // The external lane is not a worker slot: no scan may yield it.
        assert_eq!(dir.scan_from(0).next(), None);
        assert!(dir.first_raised_from(0).is_none());
    }

    #[test]
    fn external_raise_does_not_change_the_layout() {
        // The serve lane must not widen the directory: socket split and
        // word count are those of the worker slots alone.
        let dir = SignalDirectory::new_with_topology(8, Topology::new(4, 2));
        dir.raise_external();
        assert_eq!(dir.sockets(), 4);
        assert_eq!(dir.len(), 8);
        assert_eq!(dir.word_count(), 4);
        assert!(dir.try_claim_external());
    }

    #[test]
    fn external_raise_wakes_a_parked_worker() {
        let dir = SignalDirectory::new(4);
        assert!(dir.begin_park(2));
        dir.raise_external();
        assert_eq!(dir.parked_count(), 0, "external raise claimed the bit");
        assert!(dir.begin_park(2));
        dir.park(2); // consumes the deposited token, must not block
        let (_, wakes) = dir.park_stats();
        assert_eq!(wakes, 1);
        assert!(dir.try_claim_external());
    }

    /// External-producer no-lost-wakeup litmus: the same store-buffer race
    /// as `run_park_race`, but the producer is an outside thread with no
    /// worker slot — publish into a counter (standing in for the ingress
    /// ring), then `raise_external`. A lost wakeup hangs (and times out).
    #[test]
    fn park_concurrent_with_external_raise_always_wakes() {
        run_external_park_race(SignalDirectory::new(4), 0, 10_000);
    }

    /// Satellite port: the same race across a 4×8 two-level layout with
    /// the consumer on the last socket's last slot.
    #[test]
    fn park_concurrent_with_external_raise_always_wakes_two_level_4x8() {
        let dir = SignalDirectory::new_with_topology(32, Topology::new(4, 8));
        assert_eq!(dir.sockets(), 4);
        run_external_park_race(dir, 31, 10_000);
    }

    fn run_external_park_race(dir: SignalDirectory, slot: usize, rounds: u64) {
        let dir = Arc::new(dir);
        let work = Arc::new(StdAtomicU64::new(0));
        let done = Arc::new(StdAtomicU64::new(0));
        let (dir2, work2, done2) = (Arc::clone(&dir), Arc::clone(&work), Arc::clone(&done));
        let consumer = std::thread::spawn(move || {
            let mut got = 0u64;
            while got < rounds {
                if dir2.try_claim_external() {
                    let n = work2.swap(0, Ordering::AcqRel);
                    if n > 0 {
                        got += n;
                        done2.store(got, Ordering::Release);
                        continue;
                    }
                }
                assert!(dir2.begin_park(slot));
                // Plain-load re-check: begin_park's fence pairs with the
                // fence raise_external issues through wake_parked_near.
                if work2.load(Ordering::Relaxed) == 0 {
                    dir2.park(slot);
                } else {
                    dir2.cancel_park(slot);
                }
            }
        });
        for i in 0..rounds {
            work.fetch_add(1, Ordering::AcqRel);
            dir.raise_external(); // publish-then-signal
            while done.load(Ordering::Acquire) < i + 1 {
                std::thread::yield_now();
            }
        }
        consumer.join().unwrap();
        let (parks, wakes) = dir.park_stats();
        assert!(wakes >= parks.saturating_sub(1), "parks {parks} vs wakes {wakes}");
        assert!(dir.external_raises() >= rounds);
    }

    /// A worker that parks concurrently with a raise must wake: the raise
    /// side publishes work then wakes, the park side announces then
    /// re-checks then commits. A lost wakeup hangs (and times out) here.
    #[test]
    fn park_concurrent_with_raise_always_wakes() {
        run_park_race(SignalDirectory::new(4), 0, 10_000);
    }

    /// Satellite port: the same race at 128 workers across 4 sockets, with
    /// the consumer on the *last* socket's last slot — the wake must cross
    /// the two-level layout's socket boundary every round.
    #[test]
    fn park_concurrent_with_raise_always_wakes_two_level_128() {
        let dir = SignalDirectory::new_with_topology(128, Topology::new(4, 32));
        run_park_race(dir, 127, 10_000);
    }

    fn run_park_race(dir: SignalDirectory, slot: usize, rounds: u64) {
        let dir = Arc::new(dir);
        let work = Arc::new(StdAtomicU64::new(0));
        let done = Arc::new(StdAtomicU64::new(0));
        let (dir2, work2, done2) = (Arc::clone(&dir), Arc::clone(&work), Arc::clone(&done));
        let consumer = std::thread::spawn(move || {
            let mut got = 0u64;
            while got < rounds {
                let n = work2.swap(0, Ordering::AcqRel);
                if n > 0 {
                    got += n;
                    dir2.try_claim(slot);
                    done2.store(got, Ordering::Release);
                    continue;
                }
                assert!(dir2.begin_park(slot));
                // Re-check after the announce (plain load: the fences in
                // begin_park / wake_parked close the store-buffer race).
                if work2.load(Ordering::Relaxed) == 0 {
                    dir2.park(slot);
                } else {
                    dir2.cancel_park(slot);
                }
            }
        });
        for i in 0..rounds {
            work.fetch_add(1, Ordering::AcqRel);
            dir.raise(slot); // publish-then-wake
            while done.load(Ordering::Acquire) < i + 1 {
                std::thread::yield_now();
            }
        }
        consumer.join().unwrap();
        let (parks, wakes) = dir.park_stats();
        // Not every round parks (the consumer may see the work before
        // announcing), but any committed park must have been woken.
        assert!(parks <= rounds + 1);
        assert!(wakes >= parks.saturating_sub(1), "parks {parks} vs wakes {wakes}");
    }
}
