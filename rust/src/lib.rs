//! # ddast — Asynchronous Task Runtime with a Distributed Manager
//!
//! Reproduction of *"Asynchronous Runtime with Distributed Manager for
//! Task-based Programming Models"* (J. Bosch, C. Álvarez,
//! D. Jiménez-González, X. Martorell, E. Ayguadé — Parallel Computing, 2020,
//! DOI 10.1016/j.parco.2020.102664).
//!
//! The crate provides:
//!
//! * [`coordinator`] — a real, threaded OmpSs/Nanos++-style task runtime with
//!   three interchangeable organizations:
//!   * **Sync** (`Nanos++` baseline): worker threads mutate the shared task
//!     dependence graph directly under per-domain locks;
//!   * **DDAST** (the paper's contribution): workers enqueue
//!     `SubmitTaskMsg`/`DoneTaskMsg` into per-worker queues and idle workers
//!     become *manager threads* through the Functionality Dispatcher;
//!   * **GOMP-like** comparator: centralized ready queue, fork-join idling.
//! * [`workloads`] — generators for the paper's three benchmarks (blocked
//!   Matmul, N-Body with nested tasks, Sparse LU) parameterized exactly as
//!   the paper's Tables 2–4.
//! * [`sim`] — a discrete-event simulator of many-core machines (KNL,
//!   ThunderX, Power8+, Power9 — Table 1) used to regenerate the paper's
//!   evaluation figures on hardware we do not have (see DESIGN.md §2).
//! * [`runtime`] — the PJRT bridge that loads AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from task bodies;
//!   Python never runs on the execution path.
//! * [`bench_harness`] — drivers that print every table and figure of the
//!   paper's evaluation section.
//!
//! ## Quickstart
//!
//! ```no_run
//! use ddast::coordinator::{TaskSystem, RuntimeKind, DepMode};
//!
//! let ts = TaskSystem::builder()
//!     .kind(RuntimeKind::Ddast)
//!     .num_threads(4)
//!     .build();
//! // b[i] depends on a[i] produced by the first task.
//! ts.spawn(&[(0x10, DepMode::Out)], || { /* produce a */ });
//! ts.spawn(&[(0x10, DepMode::In), (0x20, DepMode::Out)], || { /* a -> b */ });
//! ts.taskwait();
//! ```

pub mod substrate;
pub mod coordinator;
pub mod workloads;
pub mod sim;
/// PJRT bridge. In the offline build environment the external
/// `xla`/`anyhow` crates are unavailable; `--features pjrt` compiles the
/// bridge against the in-crate no-op stubs in `runtime::shim` (execution
/// errors cleanly; loading/compiling is structure-only). Swap the shim
/// imports for the real crates where they are vendored.
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod bench_harness;

pub use coordinator::{TaskSystem, RuntimeKind, DepMode, DdastParams, GraphDomain, SubmitError};
pub use sim::machine::MachineConfig;
pub use substrate::Topology;
