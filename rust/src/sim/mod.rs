//! Discrete-event simulation of the runtime on the paper's many-core
//! machines (the documented hardware substitution — DESIGN.md §2).

pub mod calibrate;
pub mod engine;
pub mod machine;
pub mod report;

pub use engine::{simulate, Engine, SimOptions, SimResult, SimStats, SimTrace};
pub use machine::{CostModel, MachineConfig};
