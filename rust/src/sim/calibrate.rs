//! Cost-model calibration against the *real* runtime structures.
//!
//! The simulator's constants (`CostModel`) should track the implementation,
//! not guesses. This module microbenchmarks the actual structures on the
//! host (WD allocation, graph submit/finish, SPSC push/pop, ready-pool
//! push/pop) and reports measured ns/op next to the model's 2 GHz baseline.
//! `repro bench --exp micro` prints the comparison; EXPERIMENTS.md §Perf
//! records it.

use std::sync::{Arc, Weak};
use std::time::Instant;

use crate::coordinator::dep::{dep_in, dep_out};
use crate::coordinator::depgraph::DepDomain;
use crate::coordinator::messages::SubmitTaskMsg;
use crate::coordinator::ready::ReadyPools;
use crate::coordinator::wd::{TaskId, Wd, WdState};
use crate::substrate::SpscQueue;

/// Measured per-operation costs (ns/op) of the real structures.
#[derive(Clone, Copy, Debug, Default)]
pub struct MeasuredCosts {
    pub wd_create_ns: f64,
    pub graph_submit_ns: f64,
    pub graph_finish_ns: f64,
    pub msg_push_ns: f64,
    pub msg_pop_ns: f64,
    pub ready_push_pop_ns: f64,
}

fn time_per_op<F: FnMut()>(iters: u64, mut f: F) -> f64 {
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    start.elapsed().as_nanos() as f64 / iters as f64
}

/// Run the calibration microbenchmarks (~100 ms total).
pub fn measure() -> MeasuredCosts {
    let iters = 20_000u64;

    // WD creation.
    let mut sink = Vec::with_capacity(iters as usize);
    let wd_create_ns = time_per_op(iters, || {
        sink.push(Wd::new(
            TaskId(1),
            vec![dep_in(1), dep_out(2)],
            "cal",
            Weak::new(),
            Box::new(|| {}),
        ));
    });
    sink.clear();

    // Graph submit + finish on a rolling window (steady-state graph size).
    let domain = DepDomain::new();
    let mut next_id = 1u64;
    let mut window: std::collections::VecDeque<Arc<Wd>> = Default::default();
    let graph_submit_ns = time_per_op(iters, || {
        let wd = Wd::new(
            TaskId(next_id),
            vec![dep_in(next_id % 64), dep_out((next_id + 1) % 64)],
            "cal",
            Weak::new(),
            Box::new(|| {}),
        );
        next_id += 1;
        domain.submit(&wd);
        window.push_back(wd);
    });
    let graph_finish_ns = time_per_op(window.len() as u64, || {
        if let Some(wd) = window.pop_front() {
            wd.set_state(WdState::Ready);
            wd.set_state(WdState::Running);
            wd.set_state(WdState::Finished);
            let _ = domain.finish(&wd);
        }
    });

    // Message queue push/pop.
    let q: SpscQueue<SubmitTaskMsg> = SpscQueue::new();
    let proto: Vec<Arc<Wd>> = (0..iters)
        .map(|i| Wd::new(TaskId(i), vec![], "cal", Weak::new(), Box::new(|| {})))
        .collect();
    let mut i = 0usize;
    let msg_push_ns = time_per_op(iters, || {
        q.push(SubmitTaskMsg { task: Arc::clone(&proto[i]) });
        i += 1;
    });
    let mut guard = q.try_acquire().unwrap();
    let msg_pop_ns = time_per_op(iters, || {
        let _ = guard.pop();
    });
    drop(guard);

    // Ready pool push+pop pair.
    let pools = ReadyPools::new(4, 7);
    let mut i = 0usize;
    let ready_push_pop_ns = time_per_op(iters, || {
        pools.push(0, Arc::clone(&proto[i]));
        let _ = pools.get(0);
        i += 1;
    }) / 2.0;

    MeasuredCosts {
        wd_create_ns,
        graph_submit_ns,
        graph_finish_ns,
        msg_push_ns,
        msg_pop_ns,
        ready_push_pop_ns,
    }
}

/// Pretty comparison of measured vs modelled (2 GHz baseline) costs.
pub fn report() -> String {
    let m = measure();
    let model = crate::sim::machine::CostModel::scaled(1.0);
    let mut out = String::new();
    out.push_str("Calibration: measured real-structure costs vs simulator model (2 GHz baseline)\n");
    out.push_str(&format!("{:<24}{:>14}{:>14}\n", "operation", "measured ns", "model ns"));
    let rows = [
        ("wd_create", m.wd_create_ns, model.t_create_ns as f64),
        ("graph_submit (2 deps)", m.graph_submit_ns, (model.t_submit_per_dep_ns * 2) as f64),
        ("graph_finish (2 deps)", m.graph_finish_ns, (model.t_finish_per_dep_ns * 2) as f64),
        ("msg_push", m.msg_push_ns, model.t_msg_push_ns as f64),
        ("msg_pop", m.msg_pop_ns, model.t_msg_pop_ns as f64),
        ("ready_push_pop", m.ready_push_pop_ns, model.t_sched_ns as f64),
    ];
    for (name, meas, modl) in rows {
        out.push_str(&format!("{name:<24}{meas:>14.1}{modl:>14.1}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurements_are_sane() {
        let m = measure();
        // All positive, all below 100µs/op (they are ns–µs scale ops).
        for v in [
            m.wd_create_ns,
            m.graph_submit_ns,
            m.graph_finish_ns,
            m.msg_push_ns,
            m.msg_pop_ns,
            m.ready_push_pop_ns,
        ] {
            assert!(v > 0.0 && v < 100_000.0, "{v}");
        }
    }

    #[test]
    fn report_prints_all_rows() {
        let r = report();
        assert!(r.contains("wd_create") && r.contains("msg_pop"));
        assert_eq!(r.lines().count(), 8);
    }
}
