//! Discrete-event simulator of the task runtime on many-core machines.
//!
//! This is the documented hardware substitution (DESIGN.md §2): the paper's
//! evaluation needs 40–64-core nodes; this engine replays a
//! [`TaskGraphSpec`] under any of the three runtime organizations on a
//! virtual machine from [`MachineConfig`], charging calibrated costs for
//! every runtime operation and modelling the two effects the paper
//! identifies:
//!
//! * **lock contention** — dependence-graph domains are FIFO queueing
//!   resources: a core that wants the lock while it is held *spins*,
//!   wasting virtual time exactly like the real spinlock wastes cycles;
//! * **cache pollution / locality** — runtime-structure work raises a
//!   core's pollution level, inflating its next task body (§6.1: sync-mode
//!   task bodies ran ~1.5× slower than DDAST's in Matmul-KNL-FG), and
//!   graph ops are discounted for cores that touched the structures
//!   recently (§5.1's manager-locality finding). Structure costs also grow
//!   with the number of tasks in the graph (§6.2).
//!
//! The DDAST decision logic here mirrors Listing 2 one-to-one (enter cap,
//! per-worker submit-queue exclusivity, shared per-worker op budget,
//! MIN_READY_TASKS early exit, MAX_SPINS empty-pass budget).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::coordinator::{DdastParams, RuntimeKind};
use crate::sim::machine::MachineConfig;
use crate::substrate::vtime::SimDuration;
use crate::substrate::XorShift64;
use crate::workloads::spec::{CostClass, TaskGraphSpec};

/// Batch sizes: how many creations/graph-ops one event covers (keeps the
/// event count ~3 per task instead of ~8; timing granularity stays well
/// under a task body).
const CREATE_BATCH: usize = 16;
const CREATOR_BATCH: usize = 32;

/// Simulation options.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    pub variant: RuntimeKind,
    pub threads: usize,
    pub params: DdastParams,
    pub seed: u64,
    pub trace: bool,
    /// Minimum spacing of trace gauge samples (ns of virtual time).
    pub trace_resolution_ns: u64,
}

impl SimOptions {
    pub fn new(variant: RuntimeKind, threads: usize) -> Self {
        SimOptions {
            variant,
            threads,
            params: DdastParams::tuned(threads),
            seed: 0x5EED,
            trace: false,
            trace_resolution_ns: 1_000_000,
        }
    }

    pub fn with_params(mut self, p: DdastParams) -> Self {
        self.params = p;
        self
    }

    pub fn with_trace(mut self, res_ns: u64) -> Self {
        self.trace = true;
        self.trace_resolution_ns = res_ns;
        self
    }
}

/// Aggregate statistics of one simulated run.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub tasks_executed: u64,
    pub lock_wait_ns: u64,
    pub graph_op_ns: u64,
    pub task_exec_ns: u64,
    pub pollution_extra_ns: u64,
    pub mgr_passes: u64,
    pub msgs_processed: u64,
    pub steals: u64,
    pub idle_polls: u64,
    pub max_in_graph: u64,
    pub max_ready: u64,
}

/// Gauge/time-series trace of one simulated run (Figures 12–15).
#[derive(Clone, Debug, Default)]
pub struct SimTrace {
    /// (t_ns, tasks in dependence graph).
    pub in_graph: Vec<(u64, u64)>,
    /// (t_ns, ready tasks).
    pub ready: Vec<(u64, u64)>,
    /// Per-core busy spans (start_ns, end_ns, label); label "mgr" =
    /// manager work.
    pub spans: Vec<Vec<(u64, u64, &'static str)>>,
}

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub makespan: SimDuration,
    /// Speedup over the (runtime-free) sequential execution.
    pub speedup: f64,
    pub stats: SimStats,
    pub trace: Option<SimTrace>,
}

// ---------------------------------------------------------------------------

/// FIFO queueing lock: requesters reserve in arrival order; the time spent
/// waiting is the spinning the paper's contention analysis is about.
#[derive(Clone, Copy, Debug, Default)]
struct SimLock {
    free_at: u64,
}

impl SimLock {
    /// Reserve the lock at `now` for `hold` ns. Returns (completion, waited).
    fn acquire(&mut self, now: u64, hold: u64) -> (u64, u64) {
        let start = self.free_at.max(now);
        let waited = start - now;
        self.free_at = start + hold;
        (self.free_at, waited)
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Msg {
    Submit(usize),
    Done(usize),
}

/// What a core is committed to until its next wake. Invariant: every event
/// handler schedules **exactly one** continuation for the core (a pending +
/// wake), so a core is never double-scheduled.
enum Pending {
    /// Wake and take a fresh decision.
    Decide,
    /// Executing creator `creator` produced children `ids[..next]` so far.
    CreatorStep { creator: usize, ids: Vec<usize>, next: usize },
    /// Task body completes at wake.
    TaskEnd { task: usize, started: u64 },
    /// Sync/GOMP: graph-finish for `task` completes at wake.
    DoneApplied { task: usize },
    /// DDAST manager pass completes at wake; apply `msgs`.
    ManagerPass { msgs: Vec<Msg>, started: u64 },
}

struct Core {
    pending: Pending,
    pollution: f64,
    last_rt_op: u64,
    backoff: u64,
    /// Currently counted in `mgr_count` (inside the DDAST callback).
    is_mgr: bool,
    empty_passes: u32,
    /// GOMP: currently spinning on the central queue.
    idle_polling: bool,
    /// When the current idle stretch began (u64::MAX = not idle).
    idle_since: u64,
}

struct TaskRt {
    submitted: bool,
    done: bool,
    executed: bool,
    preds_left: usize,
    children_left: usize,
    creating_done: bool,
}

pub struct Engine<'a> {
    spec: &'a TaskGraphSpec,
    machine: &'a MachineConfig,
    opt: SimOptions,
    now: u64,
    seq: u64,
    events: BinaryHeap<Reverse<(u64, u64, usize)>>,
    cores: Vec<Core>,
    tasks: Vec<TaskRt>,
    preds: Vec<Vec<usize>>,
    succs: Vec<Vec<usize>>,
    /// scope (creator id or usize::MAX for root) -> domain index.
    domain_of_scope: HashMap<usize, usize>,
    scope_of_task: Vec<usize>,
    domain_locks: Vec<SimLock>,
    domain_in_graph: Vec<u64>,
    in_graph_total: u64,
    ready_queues: Vec<VecDeque<usize>>,
    ready_count: u64,
    // DDAST queue system.
    submit_q: Vec<VecDeque<usize>>,
    done_q: Vec<VecDeque<usize>>,
    submit_locked_until: Vec<u64>,
    msgs_pending: u64,
    mgr_count: usize,
    // GOMP central queue model.
    central_lock: SimLock,
    idle_pollers: usize,
    /// Cores currently idle (hot or futex-parked): a GOMP task insertion
    /// wakes them all — the thundering herd that slows creation exactly
    /// when "tasks are executed faster than created" (§6.1, Fig 11a).
    idle_cores: usize,
    // program counter of the main thread.
    main_pos: usize,
    top_level: Vec<usize>,
    done_count: usize,
    last_done_at: u64,
    rng: XorShift64,
    stats: SimStats,
    trace: Option<SimTrace>,
    last_trace_in_graph: (u64, u64),
    last_trace_ready: (u64, u64),
}

impl<'a> Engine<'a> {
    pub fn new(spec: &'a TaskGraphSpec, machine: &'a MachineConfig, mut opt: SimOptions) -> Self {
        if opt.variant == RuntimeKind::CentralDast {
            // The centralized design [7]: the last core is the dedicated
            // DAS Thread — it drains without Listing 2's caps or breaks.
            assert!(opt.threads >= 2, "CentralDast needs a worker + the DAST core");
            opt.params = DdastParams {
                max_ddast_threads: 1,
                max_spins: 1,
                max_ops_thread: usize::MAX / 2,
                min_ready_tasks: u64::MAX,
            };
        }
        let n = spec.tasks.len();
        let preds = spec.predecessor_edges();
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (s, ps) in preds.iter().enumerate() {
            for &p in ps {
                succs[p].push(s);
            }
        }
        // Scope of each task: root, or its creator.
        let mut scope_of_task = vec![usize::MAX; n];
        for t in &spec.tasks {
            for &c in &t.children {
                scope_of_task[c] = t.id;
            }
        }
        let nready = if opt.variant == RuntimeKind::GompLike { 1 } else { opt.threads };
        let tasks = (0..n)
            .map(|i| TaskRt {
                submitted: false,
                done: false,
                executed: false,
                preds_left: 0,
                children_left: spec.tasks[i].children.len(),
                creating_done: false,
            })
            .collect();
        Engine {
            spec,
            machine,
            opt,
            now: 0,
            seq: 0,
            events: BinaryHeap::new(),
            cores: (0..opt.threads)
                .map(|_| Core {
                    pending: Pending::Decide,
                    pollution: 0.0,
                    last_rt_op: u64::MAX,
                    backoff: machine.costs.t_idle_poll_ns,
                    is_mgr: false,
                    empty_passes: 0,
                    idle_polling: false,
                    idle_since: u64::MAX,
                })
                .collect(),
            tasks,
            preds,
            succs,
            domain_of_scope: HashMap::new(),
            scope_of_task,
            domain_locks: Vec::new(),
            domain_in_graph: Vec::new(),
            in_graph_total: 0,
            ready_queues: (0..nready).map(|_| VecDeque::new()).collect(),
            ready_count: 0,
            submit_q: (0..opt.threads).map(|_| VecDeque::new()).collect(),
            done_q: (0..opt.threads).map(|_| VecDeque::new()).collect(),
            submit_locked_until: vec![0; opt.threads],
            msgs_pending: 0,
            mgr_count: 0,
            central_lock: SimLock::default(),
            idle_pollers: 0,
            idle_cores: 0,
            main_pos: 0,
            top_level: spec.top_level(),
            done_count: 0,
            last_done_at: 0,
            rng: XorShift64::new(opt.seed),
            stats: SimStats::default(),
            trace: if opt.trace {
                Some(SimTrace { spans: vec![Vec::new(); opt.threads], ..Default::default() })
            } else {
                None
            },
            last_trace_in_graph: (u64::MAX, u64::MAX),
            last_trace_ready: (u64::MAX, u64::MAX),
        }
    }

    // ---- small helpers ----------------------------------------------------

    fn wake(&mut self, core: usize, at: u64) {
        self.seq += 1;
        self.events.push(Reverse((at, self.seq, core)));
    }

    fn domain_idx(&mut self, scope: usize) -> usize {
        if let Some(&d) = self.domain_of_scope.get(&scope) {
            return d;
        }
        let d = self.domain_locks.len();
        self.domain_locks.push(SimLock::default());
        self.domain_in_graph.push(0);
        self.domain_of_scope.insert(scope, d);
        d
    }

    /// Effective graph-op cost for `core`: base × structure-growth ×
    /// warmth discount.
    fn graph_cost(&mut self, core: usize, base: u64, domain: usize) -> u64 {
        let c = &self.machine.costs;
        // Structure-size growth saturates: once the working set no longer
        // fits any cache level, an op's miss count stops growing.
        let growth = (1.0
            + c.graph_growth_factor * (1.0 + self.domain_in_graph[domain] as f64 / 256.0).ln())
        .min(2.0);
        let warm = self.cores[core].last_rt_op != u64::MAX
            && self.now.saturating_sub(self.cores[core].last_rt_op) <= c.rt_warm_window_ns;
        let disc = if warm { 1.0 - c.rt_warm_discount } else { 1.0 };
        ((base as f64) * growth * disc).round() as u64
    }

    /// GOMP central-lock inflation from hot idle pollers, mildly capped
    /// (cache-line bouncing saturates).
    fn gomp_infl(&self) -> f64 {
        (1.0 + self.machine.costs.gomp_contention * self.idle_pollers as f64).min(2.0)
    }

    /// GOMP thundering herd: inserting a task wakes every idle worker
    /// (hot spinners re-arm, parked ones futex-wake); the creator pays a
    /// per-idler cost. Machine dependent through `gomp_contention` — on
    /// the KNL mesh this is what collapses creation-bound runs at 32/64
    /// threads while ThunderX barely notices (§6.1, Fig 11a vs 11e).
    fn gomp_wake_herd(&self) -> u64 {
        (self.machine.costs.t_central_ns as f64
            * self.machine.costs.gomp_contention
            * 8.0
            * self.idle_cores as f64) as u64
    }

    fn mark_idle(&mut self, core: usize) {
        if self.cores[core].idle_since == u64::MAX {
            self.cores[core].idle_since = self.now;
            self.idle_cores += 1;
        }
    }

    fn mark_busy(&mut self, core: usize) {
        if self.cores[core].idle_since != u64::MAX {
            self.cores[core].idle_since = u64::MAX;
            self.idle_cores -= 1;
        }
    }

    /// Charge runtime-structure work to a core's cache pollution.
    fn pollute(&mut self, core: usize, dur: u64) {
        let c = &mut self.cores[core];
        c.pollution = (c.pollution + dur as f64 / self.machine.costs.pollution_sat_ns as f64).min(1.0);
        c.last_rt_op = self.now;
    }

    fn exec_rate(&self) -> f64 {
        self.machine.flops_per_thread(self.opt.threads)
    }

    fn body_ns(&self, task: usize, pollution: f64) -> u64 {
        let base = match self.spec.tasks[task].cost {
            CostClass::Flops(f) | CostClass::Creator(f) => (f / self.exec_rate() * 1e9) as u64,
            CostClass::FixedNs(ns) => ns,
        };
        let infl = 1.0 + self.machine.costs.pollution_penalty * pollution;
        ((base as f64) * infl) as u64
    }

    fn record_gauges(&mut self) {
        self.stats.max_in_graph = self.stats.max_in_graph.max(self.in_graph_total);
        self.stats.max_ready = self.stats.max_ready.max(self.ready_count);
        if self.trace.is_none() {
            return;
        }
        let res = self.opt.trace_resolution_ns;
        let (lt, lv) = self.last_trace_in_graph;
        if lv != self.in_graph_total && (lt == u64::MAX || self.now.saturating_sub(lt) >= res) {
            self.trace.as_mut().unwrap().in_graph.push((self.now, self.in_graph_total));
            self.last_trace_in_graph = (self.now, self.in_graph_total);
        }
        let (lt, lv) = self.last_trace_ready;
        if lv != self.ready_count && (lt == u64::MAX || self.now.saturating_sub(lt) >= res) {
            self.trace.as_mut().unwrap().ready.push((self.now, self.ready_count));
            self.last_trace_ready = (self.now, self.ready_count);
        }
    }

    fn push_ready(&mut self, core: usize, task: usize) {
        let q = core % self.ready_queues.len();
        self.ready_queues[q].push_back(task);
        self.ready_count += 1;
    }

    // ---- graph effects (same semantics as coordinator::depgraph) ----------

    /// Apply a submission: count unfinished predecessors; ready if none.
    /// Returns true if the task became ready.
    fn apply_submit(&mut self, core: usize, task: usize) {
        let scope = self.scope_of_task[task];
        let d = self.domain_idx(scope);
        let left = self.preds[task].iter().filter(|&&p| !self.tasks[p].done).count();
        let t = &mut self.tasks[task];
        t.submitted = true;
        t.preds_left = left;
        self.domain_in_graph[d] += 1;
        self.in_graph_total += 1;
        if left == 0 {
            self.push_ready(core, task);
        }
        self.record_gauges();
    }

    /// Apply done-processing: notify successors, remove from graph.
    fn apply_done(&mut self, core: usize, task: usize) {
        let scope = self.scope_of_task[task];
        let d = self.domain_idx(scope);
        debug_assert!(self.tasks[task].executed && !self.tasks[task].done);
        self.tasks[task].done = true;
        self.domain_in_graph[d] = self.domain_in_graph[d].saturating_sub(1);
        self.in_graph_total = self.in_graph_total.saturating_sub(1);
        self.done_count += 1;
        self.last_done_at = self.now;
        let succs = self.succs[task].clone();
        for s in succs {
            if self.tasks[s].submitted && !self.tasks[s].done {
                debug_assert!(self.tasks[s].preds_left > 0);
                self.tasks[s].preds_left -= 1;
                if self.tasks[s].preds_left == 0 && !self.tasks[s].executed {
                    self.push_ready(core, s);
                }
            }
        }
        // Creator bookkeeping: last child done -> creator body can finish.
        if scope != usize::MAX {
            self.tasks[scope].children_left -= 1;
            if self.tasks[scope].children_left == 0 && self.tasks[scope].creating_done {
                // The creator's taskwait returns; its finalization is
                // processed inline on the core that completed the last
                // child (without disturbing that core's own schedule).
                self.finish_task_inline(core, scope);
            }
        }
        self.record_gauges();
    }

    /// Submission action costs for one task, per variant. Returns duration
    /// added to the acting core's busy time; queues side effects.
    fn submit_action(&mut self, core: usize, task: usize, at: u64) -> u64 {
        let c = self.machine.costs;
        let ndeps = self.spec.tasks[task].deps.len().max(1) as u64;
        match self.opt.variant {
            RuntimeKind::Ddast | RuntimeKind::CentralDast => {
                // Fig 3: push a Submit Task Message; the graph is touched
                // later by a manager.
                self.submit_q[core].push_back(task);
                self.msgs_pending += 1;
                c.t_msg_push_ns
            }
            RuntimeKind::Sync => {
                let scope = self.scope_of_task[task];
                let d = self.domain_idx(scope);
                let hold = self.graph_cost(core, c.t_submit_per_dep_ns * ndeps, d);
                let (completion, waited) = self.domain_locks[d].acquire(at, hold);
                self.stats.lock_wait_ns += waited;
                self.stats.graph_op_ns += hold;
                self.pollute(core, hold + waited);
                self.apply_submit(core, task);
                completion - at
            }
            RuntimeKind::GompLike => {
                // Central structures: one global lock; idle pollers inflate
                // the effective hold (§6.1's GOMP contention collapse), but
                // the structures themselves are leaner than Nanos++'s.
                let infl = self.gomp_infl();
                let fp = c.gomp_footprint;
                // Insertion wakes every idle worker: the creator eats the
                // herd cost (see gomp_wake_herd).
                let hold = ((c.t_central_ns as f64
                    + (c.t_submit_per_dep_ns * ndeps) as f64 * fp)
                    * infl) as u64
                    + self.gomp_wake_herd();
                let (completion, waited) = self.central_lock.acquire(at, hold);
                self.stats.lock_wait_ns += waited;
                self.stats.graph_op_ns += hold;
                self.pollute(core, ((hold + waited) as f64 * fp) as u64);
                self.apply_submit(core, task);
                completion - at
            }
        }
    }

    /// Finish-processing costs (graph removal + successor release) for
    /// Sync/GOMP — DDAST managers price this inside their pass.
    fn finish_hold(&mut self, core: usize, task: usize) -> (usize, u64) {
        let c = self.machine.costs;
        let ndeps = self.spec.tasks[task].deps.len().max(1) as u64;
        let nsucc = self.succs[task].len() as u64;
        let scope = self.scope_of_task[task];
        let d = self.domain_idx(scope);
        let base = c.t_finish_per_dep_ns * ndeps + c.t_release_per_succ_ns * nsucc;
        (d, self.graph_cost(core, base, d))
    }

    // ---- task execution ----------------------------------------------------

    /// Start executing `task` on `core` at `at` (after scheduling pickup).
    fn start_task(&mut self, core: usize, task: usize, at: u64) {
        let t = &self.spec.tasks[task];
        if t.children.is_empty() {
            let dur = self.body_ns(task, self.cores[core].pollution);
            let base = self.body_ns(task, 0.0);
            self.stats.pollution_extra_ns += dur - base;
            self.stats.task_exec_ns += dur;
            self.cores[core].pending = Pending::TaskEnd { task, started: at };
            self.wake(core, at + dur);
        } else {
            // Creator: its body is the creation loop (plus its own flops).
            let pre = self.body_ns(task, self.cores[core].pollution);
            let ids = t.children.clone();
            self.cores[core].pending = Pending::CreatorStep { creator: task, ids, next: 0 };
            self.wake(core, at + pre);
        }
    }

    /// Finalize a task *without* occupying the core's pending slot (used
    /// for creator completion, which is detected while the core is in the
    /// middle of another event). The lock time is still reserved — it
    /// serializes against everyone else — but the effects apply at `now`.
    fn finish_task_inline(&mut self, core: usize, task: usize) {
        self.tasks[task].executed = true;
        self.stats.tasks_executed += 1;
        match self.opt.variant {
            RuntimeKind::Ddast | RuntimeKind::CentralDast => {
                self.done_q[core].push_back(task);
                self.msgs_pending += 1;
            }
            RuntimeKind::Sync => {
                let (d, hold) = self.finish_hold(core, task);
                let (_completion, waited) = self.domain_locks[d].acquire(self.now, hold);
                self.stats.lock_wait_ns += waited;
                self.stats.graph_op_ns += hold;
                self.pollute(core, hold + waited);
                self.apply_done(core, task);
            }
            RuntimeKind::GompLike => {
                let (_, hold) = self.finish_hold(core, task);
                let infl = self.gomp_infl();
                let fp = self.machine.costs.gomp_footprint;
                let hold =
                    ((hold as f64 * fp + self.machine.costs.t_central_ns as f64) * infl) as u64;
                let (_completion, waited) = self.central_lock.acquire(self.now, hold);
                self.stats.lock_wait_ns += waited;
                self.stats.graph_op_ns += hold;
                self.pollute(core, ((hold + waited) as f64 * fp) as u64);
                self.apply_done(core, task);
            }
        }
    }

    /// Body of `task` finished at `at` on `core`: run the variant's
    /// finalization path.
    fn end_task(&mut self, core: usize, task: usize, at: u64) {
        self.tasks[task].executed = true;
        self.stats.tasks_executed += 1;
        // Cache refilled with application data.
        self.cores[core].pollution = 0.0;
        match self.opt.variant {
            RuntimeKind::Ddast | RuntimeKind::CentralDast => {
                self.done_q[core].push_back(task);
                self.msgs_pending += 1;
                // Push cost is folded into the next decision latency.
                self.cores[core].pending = Pending::Decide;
                self.wake(core, at + self.machine.costs.t_msg_push_ns);
            }
            RuntimeKind::Sync => {
                let (d, hold) = self.finish_hold(core, task);
                let (completion, waited) = self.domain_locks[d].acquire(at, hold);
                self.stats.lock_wait_ns += waited;
                self.stats.graph_op_ns += hold;
                self.pollute(core, hold + waited);
                self.cores[core].pending = Pending::DoneApplied { task };
                self.wake(core, completion);
            }
            RuntimeKind::GompLike => {
                let (_, hold) = self.finish_hold(core, task);
                let infl = self.gomp_infl();
                let fp = self.machine.costs.gomp_footprint;
                let hold =
                    ((hold as f64 * fp + self.machine.costs.t_central_ns as f64) * infl) as u64;
                let (completion, waited) = self.central_lock.acquire(at, hold);
                self.stats.lock_wait_ns += waited;
                self.stats.graph_op_ns += hold;
                self.pollute(core, ((hold + waited) as f64 * fp) as u64);
                self.cores[core].pending = Pending::DoneApplied { task };
                self.wake(core, completion);
            }
        }
    }

    // ---- DDAST manager (Listing 2) -----------------------------------------

    /// One pass over all worker queues. Pops messages *now* (they are
    /// reserved to this manager), prices them, applies effects at wake.
    /// Returns None if the pass found nothing.
    fn manager_pass(&mut self, core: usize) -> Option<(Vec<Msg>, u64)> {
        let p = self.opt.params;
        let c = self.machine.costs;
        let mut msgs = Vec::new();
        let mut dur = 0u64;
        for w in 0..self.opt.threads {
            // Listing 2 line 7.
            if self.ready_count >= p.min_ready_tasks {
                break;
            }
            let mut cnt = 0usize;
            // Submit queue: exclusive acquire (one manager at a time).
            if self.now >= self.submit_locked_until[w] {
                while cnt < p.max_ops_thread {
                    match self.submit_q[w].pop_front() {
                        Some(task) => {
                            let scope = self.scope_of_task[task];
                            let d = self.domain_idx(scope);
                            let ndeps = self.spec.tasks[task].deps.len().max(1) as u64;
                            let hold = self.graph_cost(core, c.t_submit_per_dep_ns * ndeps, d);
                            let (completion, waited) =
                                self.domain_locks[d].acquire(self.now + dur, hold);
                            self.stats.lock_wait_ns += waited;
                            self.stats.graph_op_ns += hold;
                            dur = completion - self.now + c.t_msg_pop_ns;
                            msgs.push(Msg::Submit(task));
                            cnt += 1;
                        }
                        None => break,
                    }
                }
                if cnt > 0 {
                    self.submit_locked_until[w] = self.now + dur;
                }
            }
            // Done queue shares the per-worker budget (Listing 2 L17-20).
            while cnt < p.max_ops_thread {
                match self.done_q[w].pop_front() {
                    Some(task) => {
                        let (d, hold) = self.finish_hold(core, task);
                        let (completion, waited) =
                            self.domain_locks[d].acquire(self.now + dur, hold);
                        self.stats.lock_wait_ns += waited;
                        self.stats.graph_op_ns += hold;
                        dur = completion - self.now + c.t_msg_pop_ns;
                        msgs.push(Msg::Done(task));
                        cnt += 1;
                    }
                    None => break,
                }
            }
        }
        if msgs.is_empty() {
            None
        } else {
            self.msgs_pending -= msgs.len() as u64;
            self.stats.msgs_processed += msgs.len() as u64;
            self.stats.mgr_passes += 1;
            Some((msgs, dur.max(c.t_msg_pop_ns)))
        }
    }

    /// Try to enter / continue manager mode. Returns true if a pass was
    /// scheduled.
    fn try_manager(&mut self, core: usize) -> bool {
        let p = self.opt.params;
        if !self.cores[core].is_mgr {
            if self.mgr_count >= p.max_ddast_threads || self.msgs_pending == 0 {
                return false;
            }
            // Entering when parallelism is already uncovered is a no-op
            // (Listing 2 would bounce straight out through line 7 + 25).
            if self.ready_count >= p.min_ready_tasks {
                return false;
            }
            self.cores[core].is_mgr = true;
            self.cores[core].empty_passes = 0;
            self.mgr_count += 1;
        }
        match self.manager_pass(core) {
            Some((msgs, dur)) => {
                self.cores[core].empty_passes = 0;
                self.pollute(core, dur);
                self.cores[core].pending = Pending::ManagerPass { msgs, started: self.now };
                self.wake(core, self.now + dur);
                true
            }
            None => {
                self.cores[core].empty_passes += 1;
                if self.cores[core].empty_passes >= self.opt.params.max_spins {
                    // Leave the callback.
                    self.cores[core].is_mgr = false;
                    self.mgr_count -= 1;
                    false
                } else {
                    // Spin once more: re-check shortly.
                    self.cores[core].pending = Pending::Decide;
                    self.wake(core, self.now + self.machine.costs.t_msg_pop_ns);
                    true
                }
            }
        }
    }

    /// Leave the DDAST callback (Listing 2's function return).
    fn exit_manager(&mut self, core: usize) {
        if self.cores[core].is_mgr {
            self.cores[core].is_mgr = false;
            self.mgr_count -= 1;
        }
    }

    fn leave_idle_polling(&mut self, core: usize) {
        if self.cores[core].idle_polling {
            self.cores[core].idle_polling = false;
            self.idle_pollers -= 1;
        }
    }

    // ---- the decision function ---------------------------------------------

    fn decide(&mut self, core: usize) {
        let c = self.machine.costs;
        // Main thread: create all top-level tasks first (the benchmarks'
        // sequential creation loop before the global taskwait).
        if core == 0 && self.main_pos < self.top_level.len() {
            self.leave_idle_polling(core);
            self.mark_busy(core);
            let upto = (self.main_pos + CREATE_BATCH).min(self.top_level.len());
            let ids: Vec<usize> = self.top_level[self.main_pos..upto].to_vec();
            self.main_pos = upto;
            let t_create = if self.opt.variant == RuntimeKind::GompLike {
                c.t_create_gomp_ns
            } else {
                c.t_create_ns
            };
            let mut dur = 0u64;
            for &id in &ids {
                dur += t_create;
                dur += self.submit_action(core, id, self.now + dur);
            }
            // NOTE: submit effects for Sync/GOMP were applied immediately
            // (the lock reservations are time-accurate); for DDAST the
            // messages are already in the queue. The batch just occupies
            // the main thread for `dur`.
            self.cores[core].pending = Pending::Decide;
            self.wake(core, self.now + dur);
            return;
        }

        // Centralized DAST: the last core is the dedicated manager; it
        // never executes application tasks.
        if self.opt.variant == RuntimeKind::CentralDast && core == self.opt.threads - 1 {
            match self.manager_pass(core) {
                Some((msgs, dur)) => {
                    self.pollute(core, dur);
                    self.cores[core].pending = Pending::ManagerPass { msgs, started: self.now };
                    self.wake(core, self.now + dur);
                }
                None => {
                    self.stats.idle_polls += 1;
                    self.cores[core].pending = Pending::Decide;
                    self.wake(core, self.now + c.t_msg_pop_ns.max(100));
                }
            }
            return;
        }

        // Worker decision: ready task first.
        if let Some(task) = self.pop_ready(core) {
            self.exit_manager(core);
            self.leave_idle_polling(core);
            self.cores[core].backoff = c.t_idle_poll_ns;
            self.mark_busy(core);
            let pickup = if self.opt.variant == RuntimeKind::GompLike {
                // Central-queue pop under the inflated global lock.
                let infl = self.gomp_infl();
                let hold = (c.t_central_ns as f64 * infl) as u64;
                let (completion, waited) = self.central_lock.acquire(self.now, hold);
                self.stats.lock_wait_ns += waited;
                completion - self.now
            } else {
                c.t_sched_ns
            };
            self.start_task(core, task, self.now + pickup);
            return;
        }

        // DDAST: idle thread -> Functionality Dispatcher -> manager.
        if self.opt.variant == RuntimeKind::Ddast && self.try_manager(core) {
            return;
        }

        // Nothing to do: back off. GOMP idle threads hammer the central
        // queue while *hot* (their count inflates everyone's critical
        // sections — §6.1's collapse), but like libgomp's spin-then-sleep
        // wait policy they cool down after a while and stop contending.
        // Sync/DDAST idle threads poll locally.
        self.stats.idle_polls += 1;
        let b = self.cores[core].backoff;
        self.mark_idle(core);
        if self.opt.variant == RuntimeKind::GompLike {
            // libgomp's spin-then-sleep wait policy: hot spinning (and
            // therefore contending on the central line) for the spin
            // window, then parked on the futex. N-Body's ~100 µs task
            // gaps keep pollers hot (→ the Fig 11a collapse); SparseLU's
            // millisecond droughts let them cool.
            const GOMP_SPIN_WINDOW_NS: u64 = 500_000;
            if self.now - self.cores[core].idle_since < GOMP_SPIN_WINDOW_NS {
                if !self.cores[core].idle_polling {
                    self.cores[core].idle_polling = true;
                    self.idle_pollers += 1;
                }
            } else {
                self.leave_idle_polling(core);
            }
        } else {
            self.cores[core].backoff = (b * 2).min(c.t_idle_poll_ns * 16);
        }
        self.cores[core].pending = Pending::Decide;
        self.wake(core, self.now + b);
    }

    fn pop_ready(&mut self, core: usize) -> Option<usize> {
        if self.ready_count == 0 {
            return None;
        }
        let nq = self.ready_queues.len();
        let me = core % nq;
        if let Some(t) = self.ready_queues[me].pop_front() {
            self.ready_count -= 1;
            return Some(t);
        }
        // Steal: scan from a random start (DBF policy).
        let start = self.rng.next_below(nq as u64) as usize;
        for k in 0..nq {
            let v = (start + k) % nq;
            if v == me {
                continue;
            }
            if let Some(t) = self.ready_queues[v].pop_back() {
                self.ready_count -= 1;
                self.stats.steals += 1;
                return Some(t);
            }
        }
        None
    }

    fn step(&mut self, core: usize) {
        let pending = std::mem::replace(&mut self.cores[core].pending, Pending::Decide);
        match pending {
            Pending::Decide => self.decide(core),
            Pending::CreatorStep { creator, ids, next } => {
                // Create the next batch of children.
                let c = self.machine.costs;
                let t_create = if self.opt.variant == RuntimeKind::GompLike {
                    c.t_create_gomp_ns
                } else {
                    c.t_create_ns
                };
                let upto = (next + CREATOR_BATCH).min(ids.len());
                let mut dur = 0u64;
                for &id in &ids[next..upto] {
                    dur += t_create;
                    dur += self.submit_action(core, id, self.now + dur);
                }
                if upto < ids.len() {
                    self.cores[core].pending = Pending::CreatorStep { creator, ids, next: upto };
                    self.wake(core, self.now + dur);
                } else {
                    // All children created; the creator taskwaits. The core
                    // is released; the creator's body "ends" when the last
                    // child is done-processed (see apply_done).
                    self.tasks[creator].creating_done = true;
                    if let Some(tr) = &mut self.trace {
                        tr.spans[core].push((self.now, self.now + dur, "creator"));
                    }
                    if self.tasks[creator].children_left == 0 {
                        self.finish_task_inline(core, creator);
                    }
                    self.cores[core].pending = Pending::Decide;
                    self.wake(core, self.now + dur);
                }
            }
            Pending::TaskEnd { task, started } => {
                if let Some(tr) = &mut self.trace {
                    tr.spans[core].push((started, self.now, self.spec.tasks[task].label));
                }
                self.end_task(core, task, self.now);
            }
            Pending::DoneApplied { task } => {
                self.apply_done(core, task);
                self.decide(core);
            }
            Pending::ManagerPass { msgs, started } => {
                for m in msgs {
                    match m {
                        Msg::Submit(t) => self.apply_submit(core, t),
                        Msg::Done(t) => self.apply_done(core, t),
                    }
                }
                if let Some(tr) = &mut self.trace {
                    tr.spans[core].push((started, self.now, "mgr"));
                }
                self.decide(core);
            }
        }
    }

    /// Run to completion. Panics on deadlock (event queue drained early).
    pub fn run(mut self) -> SimResult {
        for core in 0..self.opt.threads {
            self.wake(core, 0);
        }
        let n = self.spec.tasks.len();
        let mut guard: u64 = 0;
        while self.done_count < n {
            let Reverse((t, _, core)) = self.events.pop().unwrap_or_else(|| {
                panic!(
                    "simulator deadlock: {}/{} done, {} msgs pending, ready={}",
                    self.done_count, n, self.msgs_pending, self.ready_count
                )
            });
            self.now = t;
            self.step(core);
            guard += 1;
            debug_assert!(guard < 2_000_000_000, "runaway simulation");
        }
        let makespan = SimDuration::from_nanos(self.last_done_at);
        let seq = self.spec.sequential_seconds(self.machine.flops_per_core);
        let speedup = if makespan.as_nanos() == 0 { 0.0 } else { seq / makespan.as_secs_f64() };
        SimResult { makespan, speedup, stats: self.stats, trace: self.trace }
    }
}

/// Convenience wrapper.
pub fn simulate(spec: &TaskGraphSpec, machine: &MachineConfig, opt: SimOptions) -> SimResult {
    Engine::new(spec, machine, opt).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{matmul, nbody, sparselu, synthetic};

    fn knl() -> MachineConfig {
        MachineConfig::knl()
    }

    #[test]
    fn chain_has_no_parallel_speedup() {
        let spec = synthetic::chain(200, 100_000);
        for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
            let r = simulate(&spec, &knl(), SimOptions::new(kind, 8));
            assert_eq!(r.stats.tasks_executed, 200, "{kind:?}");
            // 200 × 100µs = 20ms of serial work; makespan can't beat it.
            assert!(r.makespan.as_nanos() >= 20_000_000, "{kind:?} {}", r.makespan);
        }
    }

    #[test]
    fn independent_tasks_scale() {
        let spec = synthetic::independent(2_000, 200_000);
        let m = knl();
        let r1 = simulate(&spec, &m, SimOptions::new(RuntimeKind::Ddast, 1));
        let r16 = simulate(&spec, &m, SimOptions::new(RuntimeKind::Ddast, 16));
        let ratio = r1.makespan.as_secs_f64() / r16.makespan.as_secs_f64();
        assert!(ratio > 8.0, "16 threads should be >8x faster: {ratio:.2}");
    }

    #[test]
    fn all_variants_complete_matmul() {
        let spec = matmul::generate(matmul::MatmulParams { ms: 1024, bs: 128 });
        for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
            let r = simulate(&spec, &knl(), SimOptions::new(kind, 16));
            assert_eq!(r.stats.tasks_executed as usize, spec.num_tasks(), "{kind:?}");
            assert!(r.speedup > 1.0, "{kind:?}: {}", r.speedup);
        }
    }

    #[test]
    fn nested_nbody_completes() {
        let spec = nbody::generate(nbody::NBodyParams {
            num_particles: 2048,
            timesteps: 4,
            bs: 128,
        });
        for kind in [RuntimeKind::Sync, RuntimeKind::Ddast] {
            let r = simulate(&spec, &knl(), SimOptions::new(kind, 8));
            assert_eq!(r.stats.tasks_executed as usize, spec.num_tasks(), "{kind:?}");
        }
    }

    #[test]
    fn sparselu_completes_all_variants() {
        let spec = sparselu::generate(sparselu::SparseLuParams { ms: 2048, bs: 128 });
        for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
            let r = simulate(&spec, &knl(), SimOptions::new(kind, 12));
            assert_eq!(r.stats.tasks_executed as usize, spec.num_tasks(), "{kind:?}");
        }
    }

    #[test]
    fn ddast_bounds_in_graph_sync_balloons() {
        // Fig 12's roof-vs-pyramid: DDAST keeps far fewer tasks in the
        // graph than the sync runtime.
        let spec = matmul::generate(matmul::MatmulParams { ms: 2048, bs: 128 });
        let m = knl();
        let sync = simulate(&spec, &m, SimOptions::new(RuntimeKind::Sync, 16));
        let ddast = simulate(&spec, &m, SimOptions::new(RuntimeKind::Ddast, 16));
        assert!(
            ddast.stats.max_in_graph * 4 < sync.stats.max_in_graph,
            "ddast={} sync={}",
            ddast.stats.max_in_graph,
            sync.stats.max_in_graph
        );
    }

    #[test]
    fn mgr_cap_respected_and_used() {
        let spec = matmul::generate(matmul::MatmulParams { ms: 2048, bs: 128 });
        let r = simulate(&spec, &knl(), SimOptions::new(RuntimeKind::Ddast, 16));
        assert!(r.stats.mgr_passes > 0);
        assert_eq!(r.stats.msgs_processed as usize, 2 * spec.num_tasks());
    }

    #[test]
    fn trace_collects_series() {
        let spec = matmul::generate(matmul::MatmulParams { ms: 1024, bs: 128 });
        let r = simulate(
            &spec,
            &knl(),
            SimOptions::new(RuntimeKind::Sync, 8).with_trace(1000),
        );
        let tr = r.trace.unwrap();
        assert!(!tr.in_graph.is_empty());
        assert!(!tr.ready.is_empty());
        assert!(tr.spans.iter().any(|s| !s.is_empty()));
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = sparselu::generate(sparselu::SparseLuParams { ms: 1024, bs: 128 });
        let a = simulate(&spec, &knl(), SimOptions::new(RuntimeKind::Ddast, 8));
        let b = simulate(&spec, &knl(), SimOptions::new(RuntimeKind::Ddast, 8));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.stats.msgs_processed, b.stats.msgs_processed);
    }
}
