//! Result formatting: speedup tables and ASCII series/plots for the
//! regenerated figures.

use crate::sim::engine::SimTrace;

/// One speedup-vs-threads series (a line in Figures 9–11).
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    /// (threads, speedup) points.
    pub points: Vec<(usize, f64)>,
}

/// Format several series as the text table the paper's plots encode.
pub fn speedup_table(title: &str, series: &[Series]) -> String {
    let mut out = format!("{title}\n");
    let threads: Vec<usize> = series
        .first()
        .map(|s| s.points.iter().map(|p| p.0).collect())
        .unwrap_or_default();
    out.push_str(&format!("{:<14}", "threads"));
    for t in &threads {
        out.push_str(&format!("{t:>9}"));
    }
    out.push('\n');
    for s in series {
        out.push_str(&format!("{:<14}", s.label));
        for (_, v) in &s.points {
            out.push_str(&format!("{v:>9.2}"));
        }
        out.push('\n');
    }
    out
}

/// ASCII sparkline plot of a gauge series (Figures 12/14 style).
pub fn ascii_series(label: &str, series: &[(u64, u64)], width: usize, height: usize) -> String {
    if series.is_empty() {
        return format!("{label}: <empty>\n");
    }
    let t0 = series.first().unwrap().0;
    let t1 = series.last().unwrap().0.max(t0 + 1);
    let vmax = series.iter().map(|p| p.1).max().unwrap().max(1);
    // Resample to `width` buckets (max value per bucket).
    let mut buckets = vec![0u64; width];
    for &(t, v) in series {
        let b = (((t - t0) as u128 * (width as u128 - 1)) / (t1 - t0) as u128) as usize;
        buckets[b] = buckets[b].max(v);
    }
    // Carry last value forward through empty buckets for readability.
    for i in 1..width {
        if buckets[i] == 0 {
            buckets[i] = buckets[i - 1];
        }
    }
    let mut rows = vec![String::new(); height];
    for (_, row) in rows.iter_mut().enumerate() {
        row.reserve(width);
    }
    for b in buckets.iter() {
        let level = ((b * height as u64) + vmax - 1) / vmax; // ceil
        for (r, row) in rows.iter_mut().enumerate() {
            let threshold = (height - r) as u64;
            row.push(if level >= threshold { '#' } else { ' ' });
        }
    }
    let mut out = format!("{label} (max={vmax}, duration={:.3}s)\n", (t1 - t0) as f64 * 1e-9);
    for row in rows {
        out.push_str(&row);
        out.push('\n');
    }
    out
}

/// Render per-core busy spans as an ASCII Paraver-like timeline
/// (Figure 13/15 style): one row per core, `#` = task, `m` = manager work,
/// `c` = creator, ` ` = idle.
pub fn ascii_timeline(trace: &SimTrace, width: usize) -> String {
    let t1 = trace
        .spans
        .iter()
        .flat_map(|s| s.iter().map(|&(_, e, _)| e))
        .max()
        .unwrap_or(1)
        .max(1);
    let mut out = String::new();
    for (core, spans) in trace.spans.iter().enumerate() {
        let mut row = vec![' '; width];
        for &(s, e, label) in spans {
            let b0 = (s as u128 * (width as u128 - 1) / t1 as u128) as usize;
            let b1 = (e as u128 * (width as u128 - 1) / t1 as u128) as usize;
            let ch = match label {
                "mgr" => 'm',
                "creator" => 'c',
                _ => '#',
            };
            for slot in row.iter_mut().take(b1 + 1).skip(b0) {
                *slot = ch;
            }
        }
        out.push_str(&format!("{core:>3} |"));
        out.extend(row);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_table_format() {
        let s = vec![
            Series { label: "Nanos++".into(), points: vec![(1, 1.0), (2, 1.9)] },
            Series { label: "DDAST".into(), points: vec![(1, 1.0), (2, 2.0)] },
        ];
        let t = speedup_table("Fig X", &s);
        assert!(t.contains("Fig X"));
        assert!(t.contains("Nanos++"));
        assert!(t.contains("1.90"));
        assert_eq!(t.lines().count(), 4);
    }

    #[test]
    fn ascii_series_renders() {
        let series: Vec<(u64, u64)> = (0..100).map(|i| (i * 1000, i % 17)).collect();
        let p = ascii_series("ready", &series, 40, 8);
        assert_eq!(p.lines().count(), 9);
        assert!(p.contains('#'));
    }

    #[test]
    fn ascii_series_empty() {
        assert!(ascii_series("x", &[], 10, 4).contains("<empty>"));
    }

    #[test]
    fn timeline_renders_labels() {
        let tr = SimTrace {
            in_graph: vec![],
            ready: vec![],
            spans: vec![
                vec![(0, 500, "matmul_block"), (600, 900, "mgr")],
                vec![(100, 800, "creator")],
            ],
        };
        let t = ascii_timeline(&tr, 60);
        assert!(t.contains('#') && t.contains('m') && t.contains('c'));
        assert_eq!(t.lines().count(), 2);
    }
}
