//! Machine models — Table 1 of the paper plus the cost constants the
//! discrete-event simulator charges for runtime operations.
//!
//! The paper's testbeds are gone (KNL/ThunderX/Power nodes); this module is
//! the documented substitution (DESIGN.md §2, §7). Cost constants are
//! derived from three sources, in order of preference:
//!
//! 1. measured microbenchmarks of *our* runtime structures on this box
//!    (`repro bench --exp micro`, see `sim::calibrate`), scaled by clock
//!    frequency;
//! 2. the paper's own observations (e.g. Matmul-KNL-FG task bodies run
//!    ~33 % faster under DDAST — §6.1 — which pins `pollution_penalty`);
//! 3. published per-architecture figures (per-core sustained DGEMM rates
//!    for MKL/ARMPL/ESSL-class libraries).

/// Cost model of runtime operations on one machine (nanoseconds of one
/// thread's time unless noted).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Allocate + initialize a Work Descriptor (task creation, step 1).
    pub t_create_ns: u64,
    /// Same for the GOMP-like runtime ("smaller footprint than Nanos++",
    /// §6.1).
    pub t_create_gomp_ns: u64,
    /// Dependence-graph insert, *per declared dependence* (hold time of the
    /// domain lock).
    pub t_submit_per_dep_ns: u64,
    /// Dependence-graph removal/notification per dependence at finish.
    pub t_finish_per_dep_ns: u64,
    /// Extra finish cost per successor released.
    pub t_release_per_succ_ns: u64,
    /// Push one message into a per-worker SPSC queue (DDAST submit/done).
    pub t_msg_push_ns: u64,
    /// Pop + dispatch one message (manager side, before the graph op).
    pub t_msg_pop_ns: u64,
    /// Ready-pool push/pop (per-thread queues, uncontended).
    pub t_sched_ns: u64,
    /// Successful steal (victim scan + queue op).
    pub t_steal_ns: u64,
    /// GOMP central ready-queue critical section (pop *or* idle poll —
    /// idle threads serialize here; §6.1's GOMP contention collapse).
    pub t_central_ns: u64,
    /// Idle back-off poll interval for Sync/DDAST (local check, no shared
    /// damage).
    pub t_idle_poll_ns: u64,
    /// Runtime-structure ops get slower as the structures grow:
    /// `eff = base × (1 + growth × ln(1 + in_graph/256))` (§6.2: overheads
    /// "related to the number of elements ... in the runtime structures").
    pub graph_growth_factor: f64,
    /// Cache pollution: executing graph ops for `d` ns raises the core's
    /// pollution towards 1 with saturation `d / pollution_sat_ns`.
    pub pollution_sat_ns: u64,
    /// Max multiplicative task-time inflation from a fully polluted cache.
    /// Pinned by the paper's Matmul-KNL-FG measurement (~1.5× sync vs
    /// DDAST task time).
    pub pollution_penalty: f64,
    /// Graph ops are cheaper when the core touched the runtime structures
    /// within this window (manager locality, §5.1's Power8+ finding).
    pub rt_warm_window_ns: u64,
    /// Discount applied to graph ops when warm (0.4 = 40 % cheaper).
    pub rt_warm_discount: f64,
    /// GOMP central-lock inflation per idle polling thread. Machine
    /// dependent: high on the 64-core 1.3 GHz KNL mesh, negligible on the
    /// 48-core ThunderX (the paper observes the GOMP idle-contention
    /// collapse on KNL/Power9 but *not* on ThunderX — §6.1, Fig 11).
    pub gomp_contention: f64,
    /// GOMP's leaner structures: factor on graph-op costs and pollution
    /// ("the GNU runtime has a smaller footprint than Nanos++", §6.1).
    pub gomp_footprint: f64,
}

impl CostModel {
    /// Baseline constants at 2 GHz, scaled by `freq_scale` (< 1 = slower
    /// clock = more ns per op).
    pub fn scaled(freq_scale: f64) -> CostModel {
        let s = |ns: u64| ((ns as f64) / freq_scale).round() as u64;
        CostModel {
            // Nanos++ WD creation is heavyweight (allocation, plugin hooks,
            // argument copies): ~2µs at 2 GHz, vs a few hundred ns for the
            // GOMP-like runtime's leaner descriptors.
            t_create_ns: s(1_800),
            t_create_gomp_ns: s(400),
            t_submit_per_dep_ns: s(350),
            t_finish_per_dep_ns: s(300),
            t_release_per_succ_ns: s(150),
            t_msg_push_ns: s(70),
            t_msg_pop_ns: s(60),
            t_sched_ns: s(110),
            t_steal_ns: s(350),
            t_central_ns: s(140),
            t_idle_poll_ns: s(400),
            graph_growth_factor: 0.30,
            // One full dependence-graph op (hash probes over a graph with
            // thousands of WDs) evicts the task's working set: saturation
            // within ~1µs of structure work. Pinned so that sync-mode
            // Matmul-KNL-FG task bodies inflate ~1.5× (paper §6.1).
            pollution_sat_ns: s(1_000),
            pollution_penalty: 0.65,
            rt_warm_window_ns: s(4_000),
            rt_warm_discount: 0.40,
            gomp_contention: 0.02,
            gomp_footprint: 0.50,
        }
    }
}

/// One evaluation machine (Table 1 row + software-stack-level constants).
#[derive(Clone, Copy, Debug)]
pub struct MachineConfig {
    pub name: &'static str,
    /// Physical cores (sum over sockets).
    pub cores: usize,
    /// Sockets (NUMA domains) the cores spread over — the shape behind
    /// [`MachineConfig::topology`]: KNL and ThunderX are single-socket
    /// nodes, the Power testbeds are 2 × CPU (Table 1 "2 × IBM ...").
    pub sockets: usize,
    /// Hardware threads per core.
    pub threads_per_core: usize,
    pub ghz: f64,
    pub mem_gb: usize,
    /// Per-core sustained block-GEMM rate (flop/s) for the BLAS the paper
    /// links (MKL / ARM PL / ESSL-class).
    pub flops_per_core: f64,
    /// Efficiency of running 2+ threads per core (SMT scaling of the
    /// GEMM-bound task bodies).
    pub smt_efficiency: f64,
    pub costs: CostModel,
}

impl MachineConfig {
    /// Intel Xeon Phi 7230, Quadrant mode, 64 cores @ 1.3 GHz, 96 GB +
    /// 16 GB HBM (Table 1). Hyper-threading disabled in the paper's runs.
    pub fn knl() -> Self {
        MachineConfig {
            name: "knl",
            cores: 64,
            sockets: 1,
            threads_per_core: 4,
            ghz: 1.3,
            mem_gb: 96,
            // MKL DGEMM on KNL: ~2 Tflop/s node sustained ⇒ ~32 Gflop/s/core.
            flops_per_core: 32.0e9,
            smt_efficiency: 0.55,
            costs: CostModel {
                // 64 slow cores on a 2D mesh: idle polling on one line is
                // brutal (the paper's Fig 11a GOMP collapse at 32/64).
                gomp_contention: 0.09,
                ..CostModel::scaled(1.3 / 2.0)
            },
        }
    }

    /// Cavium ThunderX, 48 ARMv8 cores @ 1.8 GHz (Table 1). Weak in-order
    /// cores: low GEMM rate, runtime ops comparatively expensive.
    pub fn thunderx() -> Self {
        MachineConfig {
            name: "thunderx",
            cores: 48,
            sockets: 1,
            threads_per_core: 1,
            ghz: 1.8,
            mem_gb: 64,
            // ARM PL GEMM-class rate on ThunderX ≈ 3.5 Gflop/s/core.
            flops_per_core: 3.5e9,
            smt_efficiency: 1.0,
            costs: CostModel {
                // Weak cores never idle long enough to contend (§6.1:
                // "GOMP does not reach the point where there are several
                // idle worker threads" on ThunderX).
                gomp_contention: 0.004,
                ..CostModel::scaled(1.8 / 2.0 * 0.7) // in-order penalty
            },
        }
    }

    /// 2 × IBM PowerNV 8335-GTB, 10 cores each @ 4 GHz, SMT8 (paper uses
    /// up to 2 threads/core).
    pub fn power8() -> Self {
        MachineConfig {
            name: "power8",
            cores: 20,
            sockets: 2,
            threads_per_core: 8,
            ghz: 4.0,
            mem_gb: 256,
            // ESSL DGEMM ≈ 24 Gflop/s/core at 4 GHz.
            flops_per_core: 24.0e9,
            smt_efficiency: 0.70,
            costs: CostModel::scaled(4.0 / 2.0),
        }
    }

    /// 2 × IBM Power9 8335-GTG, 20 cores each @ 3 GHz, SMT4 (paper uses 1
    /// thread/core).
    pub fn power9() -> Self {
        MachineConfig {
            name: "power9",
            cores: 40,
            sockets: 2,
            threads_per_core: 4,
            ghz: 3.0,
            mem_gb: 512,
            flops_per_core: 22.0e9,
            smt_efficiency: 0.70,
            costs: CostModel {
                gomp_contention: 0.03,
                ..CostModel::scaled(3.0 / 2.0)
            },
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "knl" => Some(Self::knl()),
            "thunderx" => Some(Self::thunderx()),
            "power8" | "power8+" => Some(Self::power8()),
            "power9" => Some(Self::power9()),
            _ => None,
        }
    }

    pub fn all() -> Vec<Self> {
        vec![Self::knl(), Self::thunderx(), Self::power8(), Self::power9()]
    }

    /// Max hardware threads the paper exercises on this machine.
    pub fn max_threads_used(&self) -> usize {
        match self.name {
            "knl" => 64,      // HT disabled
            "thunderx" => 48, // 1 thread/core
            "power8" => 40,   // up to 2 threads/core
            "power9" => 40,   // 1 thread/core
            _ => self.cores,
        }
    }

    /// The machine's shape as a runtime [`Topology`] for `threads` worker
    /// threads — what a validation run on `sim`'s models injects via
    /// `TaskSystem::builder().topology(..)` so the two-level signal
    /// directory and the socket-ordered steal scan see the Table 1 socket
    /// split instead of the host's.
    pub fn topology(&self, threads: usize) -> crate::substrate::Topology {
        crate::substrate::Topology::with_workers(self.sockets, threads.max(1))
    }

    /// Per-thread flop rate when running `n` threads (SMT sharing).
    pub fn flops_per_thread(&self, n: usize) -> f64 {
        if n <= self.cores {
            self.flops_per_core
        } else {
            let per_core_threads = (n as f64 / self.cores as f64).ceil();
            self.flops_per_core * self.smt_efficiency * (2.0_f64.min(per_core_threads) / per_core_threads)
        }
    }

    /// The thread-count sweep used in the scalability figures
    /// (1, 2, 4, ... plus the machine maximum).
    pub fn thread_sweep(&self) -> Vec<usize> {
        let mut v = Vec::new();
        let mut t = 1;
        while t < self.max_threads_used() {
            v.push(t);
            t *= 2;
        }
        v.push(self.max_threads_used());
        v.dedup();
        v
    }
}

/// Print Table 1.
pub fn table1() -> String {
    let mut out = String::new();
    out.push_str("Table 1: Machine resources summary\n");
    out.push_str(&format!(
        "{:<10} {:>10} {:>14} {:>8} {:>8}  {}\n",
        "Machine", "Num.Cores", "Threads/core", "CPU GHz", "Mem GB", "Other"
    ));
    for m in MachineConfig::all() {
        let other = if m.name == "knl" { "16GB HBM" } else { "" };
        out.push_str(&format!(
            "{:<10} {:>10} {:>14} {:>8} {:>8}  {}\n",
            m.name, m.cores, m.threads_per_core, m.ghz, m.mem_gb, other
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_rows_match_paper() {
        let knl = MachineConfig::knl();
        assert_eq!((knl.cores, knl.threads_per_core, knl.mem_gb), (64, 4, 96));
        let tx = MachineConfig::thunderx();
        assert_eq!((tx.cores, tx.threads_per_core, tx.mem_gb), (48, 1, 64));
        let p8 = MachineConfig::power8();
        assert_eq!((p8.cores, p8.threads_per_core, p8.mem_gb), (20, 8, 256));
        let p9 = MachineConfig::power9();
        assert_eq!((p9.cores, p9.threads_per_core, p9.mem_gb), (40, 4, 512));
    }

    #[test]
    fn lookup_and_sweep() {
        assert!(MachineConfig::by_name("knl").is_some());
        assert!(MachineConfig::by_name("nope").is_none());
        let sweep = MachineConfig::knl().thread_sweep();
        assert_eq!(*sweep.last().unwrap(), 64);
        assert_eq!(sweep[0], 1);
        assert!(sweep.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn socket_counts_and_topology_shapes() {
        assert_eq!(MachineConfig::knl().sockets, 1);
        assert_eq!(MachineConfig::thunderx().sockets, 1);
        assert_eq!(MachineConfig::power8().sockets, 2);
        assert_eq!(MachineConfig::power9().sockets, 2);
        // power9 at its paper thread count: 2 sockets × 20 workers.
        let topo = MachineConfig::power9().topology(40);
        assert_eq!((topo.sockets(), topo.workers_per_socket()), (2, 20));
        assert!(topo.capacity() >= 40);
        assert_eq!(topo.socket_of(19), 0);
        assert_eq!(topo.socket_of(20), 1);
        // Single-socket machines stay flat.
        assert!(MachineConfig::knl().topology(64).is_flat());
    }

    #[test]
    fn cost_scaling_by_frequency() {
        let fast = CostModel::scaled(2.0);
        let slow = CostModel::scaled(0.5);
        assert!(slow.t_create_ns > fast.t_create_ns * 3);
    }

    #[test]
    fn smt_rate_degrades() {
        let p8 = MachineConfig::power8();
        assert_eq!(p8.flops_per_thread(20), p8.flops_per_core);
        assert!(p8.flops_per_thread(40) < p8.flops_per_core);
    }

    #[test]
    fn table1_prints() {
        let t = table1();
        assert!(t.contains("knl") && t.contains("16GB HBM"));
        assert_eq!(t.lines().count(), 6);
    }
}
