//! Serve-scale ingress drills: sustained external-submitter load against
//! the [`TaskSystem`] fast lane (EXPERIMENTS.md §Serve-scale ingress).
//!
//! Three drills, every claim counter-verified rather than eyeballed:
//!
//! * [`ingress_ab`] — the multi-tenant A/B. Old side: N client threads
//!   submit externally into the **shared root scope**, so every dependence
//!   resolves in the one root `DepDomain` (the pre-domain layout). New
//!   side: the same clients each own a [`GraphDomain`], so resolution
//!   spreads over per-tenant domains and tenants using the *same
//!   addresses* never serialize against each other. Both sides assert
//!   **zero lost submissions** (executed == submitted, and every
//!   submission went through a counted admission route); the new side
//!   additionally proves shard isolation with a registered bystander
//!   domain whose dependence namespace must stay untouched.
//! * [`ingress_backpressure`] — saturation. One worker, a tiny ring, a
//!   burst of `try_submit`s: admission is bounded exactly at the
//!   configured capacity, the overflow is rejected (`SubmitError::Busy`)
//!   and counted, and every *admitted* task still runs.
//! * [`ingress_soak`] — the sustained-load soak: N clients × M tasks of
//!   blocking submissions, reporting throughput plus p50/p95/p99
//!   submission-to-completion latency (log₂-bucketed histogram, so the
//!   quantiles are bucket upper bounds, not exact order statistics). Also
//!   runs the other two drills and folds their counters into the one
//!   [`IngressReport`] the BENCH JSON carries.

use std::sync::Arc;
use std::time::Instant;

use crate::bench_harness::contention::AbReport;
use crate::coordinator::api::{GraphDomain, TaskSystem};
use crate::coordinator::dep::DepMode;
use crate::coordinator::pool::{RuntimeKind, SubmitError};
use crate::substrate::stats::Histogram;

/// Chains per client: consecutive submissions from one client round-robin
/// over this many dependence keys, so each client's stream is 8-wide
/// parallel with in-key chains — graph traffic, not just the no-deps
/// direct route.
const CHAINS: u64 = 8;

/// The serve-scale ingress report (`BENCH_contention.json` → `"ingress"`).
#[derive(Clone, Copy, Debug, Default)]
pub struct IngressReport {
    pub threads: usize,
    pub clients: usize,
    pub tasks_per_client: u64,
    /// Soak submissions (clients × tasks_per_client), all admitted.
    pub submitted: u64,
    /// Soak completions — asserted equal to `submitted` (zero lost).
    pub completed: u64,
    /// Rejections observed by the saturation drill (backpressure engaged).
    pub busy: u64,
    /// Soak throughput: completions per wall-clock second.
    pub throughput_per_sec: f64,
    /// Submission-to-completion latency quantiles (ns, bucket bounds).
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    /// Shared-root vs per-domain A/B (`acquisitions` = dependence-shard
    /// acquisitions over the drill, `elapsed_ns` = makespan).
    pub ab: AbReport,
}

/// Shared-root vs per-tenant-domain A/B. See the module docs; the old
/// side's per-client key blocks are disjoint (the contrast measures
/// *structural* spread across domains, not artificial semantic conflicts),
/// while the new side's clients reuse one key block — the domain namespace
/// keeps them independent anyway.
pub fn ingress_ab(threads: usize, clients: usize, tasks_per_client: u64) -> AbReport {
    use crate::bench_harness::contention::SideReport;
    let total = clients as u64 * tasks_per_client;

    // Old: every client submits into the shared root scope.
    let old = {
        let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(threads).build();
        let rt = Arc::clone(ts.runtime());
        let t0 = Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let ts = ts.clone();
                std::thread::spawn(move || {
                    for i in 0..tasks_per_client {
                        let key = 0x16000 + ((c as u64) << 8) + i % CHAINS;
                        ts.submit_silent(&[(key, DepMode::Inout)], || {});
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        ts.taskwait();
        let elapsed_ns = t0.elapsed().as_nanos() as u64;
        let acquisitions =
            rt.root.child_domain_opt().expect("root scope was used").lock_stats().0;
        assert_eq!(
            rt.stats.ingress_admitted.get() + rt.stats.ingress_direct.get(),
            total,
            "every shared-scope submission admitted through a counted route"
        );
        assert_eq!(rt.stats.tasks_executed.get(), total, "zero lost external submissions");
        ts.shutdown();
        SideReport { acquisitions, elapsed_ns, ..SideReport::default() }
    };

    // New: one GraphDomain per client, plus an idle bystander tenant.
    let new = {
        let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(threads).build();
        let rt = Arc::clone(ts.runtime());
        let domains: Vec<Arc<GraphDomain>> =
            (0..clients).map(|_| Arc::new(ts.domain())).collect();
        let bystander = ts.domain();
        let t0 = Instant::now();
        let handles: Vec<_> = domains
            .iter()
            .map(|dom| {
                let dom = Arc::clone(dom);
                std::thread::spawn(move || {
                    for i in 0..tasks_per_client {
                        // Same addresses in every tenant: the domain
                        // namespace isolates them.
                        dom.submit_silent(&[(0x16000 + i % CHAINS, DepMode::Inout)], || {});
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for dom in &domains {
            dom.taskwait_checked().expect("clean tenant");
        }
        let elapsed_ns = t0.elapsed().as_nanos() as u64;
        let acquisitions: u64 = domains
            .iter()
            .map(|d| d.root().child_domain_opt().map_or(0, |dd| dd.lock_stats().0))
            .sum();
        assert!(
            bystander.root().child_domain_opt().is_none(),
            "per-domain shard isolation: the idle tenant's namespace stays untouched"
        );
        assert_eq!(
            rt.stats.ingress_admitted.get() + rt.stats.ingress_direct.get(),
            total,
            "every domain submission admitted through a counted route"
        );
        assert_eq!(rt.stats.tasks_executed.get(), total, "zero lost external submissions");
        ts.shutdown();
        SideReport { acquisitions, elapsed_ns, ..SideReport::default() }
    };

    AbReport { old, new }
}

/// Saturation drill: one worker (busy *here*, not draining), a
/// `capacity`-slot ring, a burst of `2 × capacity` non-blocking submits.
/// Returns `(admitted, busy)`; asserts the bound is exact, the rejection
/// counter matches, and every admitted task runs.
pub fn ingress_backpressure(capacity: usize) -> (u64, u64) {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(1)
        .ingress_capacity(capacity)
        .build();
    let (mut admitted, mut busy) = (0u64, 0u64);
    for i in 0..2 * capacity as u64 {
        match ts.try_submit(&[(0xBAC0 + i % 2, DepMode::Inout)], || {}) {
            Ok(_) => admitted += 1,
            Err(SubmitError::Busy) => busy += 1,
        }
    }
    assert_eq!(admitted, capacity as u64, "admission bounded exactly at the ring capacity");
    assert!(busy > 0, "backpressure engaged under saturation");
    let rt = Arc::clone(ts.runtime());
    assert_eq!(rt.stats.ingress_rejected.get(), busy);
    ts.taskwait();
    assert_eq!(rt.stats.tasks_executed.get(), admitted, "every admitted task ran");
    ts.shutdown();
    (admitted, busy)
}

/// The sustained-load soak. `clients` external threads each push
/// `tasks_per_client` blocking submissions as fast as the ring admits
/// them; each task body stamps its submission-to-completion latency into a
/// shared histogram. Runs [`ingress_ab`] and [`ingress_backpressure`] too
/// and returns the combined [`IngressReport`].
pub fn ingress_soak(threads: usize, clients: usize, tasks_per_client: u64) -> IngressReport {
    let total = clients as u64 * tasks_per_client;
    let hist = Arc::new(Histogram::new());

    let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(threads).build();
    let rt = Arc::clone(ts.runtime());
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let ts = ts.clone();
            let hist = Arc::clone(&hist);
            std::thread::spawn(move || {
                for i in 0..tasks_per_client {
                    let key = 0x50000 + ((c as u64) << 8) + i % CHAINS;
                    let hist = Arc::clone(&hist);
                    let submitted_at = Instant::now();
                    ts.submit_silent(&[(key, DepMode::Inout)], move || {
                        hist.record(submitted_at.elapsed().as_nanos() as u64);
                    });
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    ts.taskwait();
    let wall = t0.elapsed();
    let completed = hist.count();
    assert_eq!(completed, total, "soak lost a submission");
    assert_eq!(rt.stats.tasks_executed.get(), total);
    ts.shutdown();

    let (_admitted, busy) = ingress_backpressure(4);
    IngressReport {
        threads,
        clients,
        tasks_per_client,
        submitted: total,
        completed,
        busy,
        throughput_per_sec: completed as f64 / wall.as_secs_f64().max(1e-9),
        p50_ns: hist.quantile(0.50),
        p95_ns: hist.quantile(0.95),
        p99_ns: hist.quantile(0.99),
        ab: ingress_ab(threads, clients, tasks_per_client),
    }
}

/// Human-readable block for the soak report.
pub fn render_ingress(r: &IngressReport) -> String {
    format!(
        "ingress soak — {} clients x {} tasks on {} workers: {:.0} tasks/s sustained, \
         latency p50 {:.1} µs / p95 {:.1} µs / p99 {:.1} µs ({}/{} completed, \
         {} saturation rejections)\n  \
         tenancy A/B: shared-root {} shard acquisitions, {:.2} ms vs per-domain {}, {:.2} ms\n",
        r.clients,
        r.tasks_per_client,
        r.threads,
        r.throughput_per_sec,
        r.p50_ns as f64 / 1e3,
        r.p95_ns as f64 / 1e3,
        r.p99_ns as f64 / 1e3,
        r.completed,
        r.submitted,
        r.busy,
        r.ab.old.acquisitions,
        r.ab.old.elapsed_ns as f64 / 1e6,
        r.ab.new.acquisitions,
        r.ab.new.elapsed_ns as f64 / 1e6
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soak_reports_consistent_counts_and_quantiles() {
        let r = ingress_soak(2, 2, 64);
        assert_eq!(r.submitted, 128);
        assert_eq!(r.completed, 128);
        assert!(r.throughput_per_sec > 0.0);
        assert!(r.p50_ns <= r.p95_ns && r.p95_ns <= r.p99_ns, "quantiles monotone");
        assert!(r.busy > 0, "the saturation drill observed backpressure");
        // The A/B's zero-lost and isolation claims are asserted inside the
        // drill; here we only pin that both sides actually ran.
        assert!(r.ab.old.elapsed_ns > 0 && r.ab.new.elapsed_ns > 0);
        assert!(render_ingress(&r).contains("tasks/s sustained"));
    }

    #[test]
    fn backpressure_bound_is_exact() {
        let (admitted, busy) = ingress_backpressure(2);
        assert_eq!((admitted, busy), (2, 2));
    }
}
