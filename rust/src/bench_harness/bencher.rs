//! Minimal criterion-style bench runner (criterion itself is not available
//! in this offline environment). Provides warmup, repeated timed samples,
//! and mean/σ/min reporting; the `harness = false` bench binaries under
//! `rust/benches/` drive it.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len().max(1) as f64
    }

    pub fn stddev_ns(&self) -> f64 {
        let m = self.mean_ns();
        let var = self.samples_ns.iter().map(|s| (s - m) * (s - m)).sum::<f64>()
            / self.samples_ns.len().max(1) as f64;
        var.sqrt()
    }

    pub fn min_ns(&self) -> f64 {
        self.samples_ns.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn report(&self) -> String {
        // The paper reports best-of-5 (§4 criterion 3) — we print min too.
        format!(
            "{:<50} mean {:>12} σ {:>10} min {:>12}  ({} samples)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.stddev_ns()),
            fmt_ns(self.min_ns()),
            self.samples_ns.len()
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}µs", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Bench runner with a time budget per benchmark.
pub struct Bencher {
    /// Samples per benchmark (paper uses 5 repetitions, best-of).
    pub samples: usize,
    /// Warmup iterations before sampling.
    pub warmup: usize,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { samples: 5, warmup: 1, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new(samples: usize, warmup: usize) -> Self {
        Bencher { samples, warmup, results: Vec::new() }
    }

    /// Time `f` (which performs one complete run) `samples` times.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let m = Measurement { name: name.to_string(), samples_ns };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Time a high-frequency operation: `f(iters)` runs the op `iters`
    /// times; reports per-op cost.
    pub fn bench_throughput<F: FnMut(u64)>(&mut self, name: &str, iters: u64, mut f: F) -> &Measurement {
        f(self.warmup as u64 * 100);
        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f(iters);
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let m = Measurement { name: format!("{name} (per op)"), samples_ns };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Guard against the harness itself taking too long in CI-ish runs.
    pub fn elapsed_budget_exceeded(start: Instant, budget: Duration) -> bool {
        start.elapsed() > budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bencher::new(3, 0);
        b.bench("noop", || {});
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].samples_ns.len(), 3);
        assert!(b.results()[0].min_ns() <= b.results()[0].mean_ns());
    }

    #[test]
    fn throughput_per_op() {
        let mut b = Bencher::new(2, 0);
        let m = b.bench_throughput("add", 10_000, |iters| {
            let mut x = 0u64;
            for i in 0..iters {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.mean_ns() < 1_000.0, "per-op cost should be tiny");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(5.0), "5ns");
        assert_eq!(fmt_ns(5_000.0), "5.000µs");
        assert_eq!(fmt_ns(5e6), "5.000ms");
        assert_eq!(fmt_ns(5e9), "5.000s");
    }
}
