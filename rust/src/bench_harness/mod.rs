//! Benchmark harness: per-figure drivers (`figures`) and the in-tree
//! criterion replacement (`bencher`).

pub mod bencher;
pub mod contention;
pub mod figures;
pub mod ingress;

pub use bencher::{Bencher, Measurement};
pub use contention::{AbReport, ContentionReport, SideReport, SweepReport};
pub use figures::{Bench, FigureOpts};
pub use ingress::IngressReport;
