//! Benchmark harness: per-figure drivers (`figures`) and the in-tree
//! criterion replacement (`bencher`).

pub mod bencher;
pub mod figures;

pub use bencher::{Bencher, Measurement};
pub use figures::{Bench, FigureOpts};
