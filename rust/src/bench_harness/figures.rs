//! Drivers that regenerate every table and figure of the paper's
//! evaluation (§5 tuning, §6 performance comparison). Each returns the
//! report as a `String`; the `repro` CLI and the criterion-style benches
//! print them, and EXPERIMENTS.md records paper-vs-measured.

use crate::coordinator::{DdastParams, RuntimeKind};
use crate::sim::engine::{simulate, SimOptions, SimResult};
use crate::sim::machine::MachineConfig;
use crate::sim::report::{ascii_series, ascii_timeline, speedup_table, Series};
use crate::workloads::{matmul, nbody, sparselu, TaskGraphSpec};

/// Figure options. `quick` shrinks problem sizes so benches/tests finish in
/// seconds; `make figures` uses the paper-size runs.
#[derive(Clone, Copy, Debug)]
pub struct FigureOpts {
    pub quick: bool,
}

impl FigureOpts {
    pub fn quick() -> Self {
        FigureOpts { quick: true }
    }

    pub fn full() -> Self {
        FigureOpts { quick: false }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Bench {
    Matmul,
    SparseLu,
    NBody,
}

impl Bench {
    pub fn name(&self) -> &'static str {
        match self {
            Bench::Matmul => "matmul",
            Bench::SparseLu => "sparselu",
            Bench::NBody => "nbody",
        }
    }
}

/// Build the benchmark spec for (bench, machine, grain), scaled down in
/// quick mode while preserving the dependence-pattern shape.
pub fn spec_for(bench: Bench, machine: &str, coarse: bool, opts: FigureOpts) -> TaskGraphSpec {
    match bench {
        Bench::Matmul => {
            let mut p = matmul::table2_params(machine, coarse);
            if opts.quick {
                p.ms = (p.ms / 4).max(p.bs * 4);
            }
            matmul::generate(p)
        }
        Bench::SparseLu => {
            let mut p = sparselu::table4_params(coarse);
            if opts.quick {
                p.ms = 2048;
            }
            sparselu::generate(p)
        }
        Bench::NBody => {
            let mut p = nbody::table3_params(machine, coarse);
            if opts.quick {
                p.num_particles = 4096;
                p.timesteps = 4;
            }
            nbody::generate(p)
        }
    }
}

fn run(
    spec: &TaskGraphSpec,
    m: &MachineConfig,
    kind: RuntimeKind,
    threads: usize,
    params: DdastParams,
) -> SimResult {
    simulate(spec, m, SimOptions::new(kind, threads).with_params(params))
}

// ---------------------------------------------------------------------------
// Tables 1-4
// ---------------------------------------------------------------------------

pub fn table1() -> String {
    crate::sim::machine::table1()
}

/// Tables 2–4: execution arguments + created task counts (generated, so the
/// counts are *our* generators', checked in tests against the paper's).
pub fn tables234() -> String {
    let mut out = String::new();
    out.push_str("Table 2: Matmul execution arguments\n");
    out.push_str(&format!(
        "{:<10}{:>7}{:>7}{:>9}{:>7}{:>9}\n",
        "Machine", "MS", "BS-CG", "#T-CG", "BS-FG", "#T-FG"
    ));
    for mach in ["knl", "thunderx", "power9"] {
        let cg = matmul::table2_params(mach, true);
        let fg = matmul::table2_params(mach, false);
        out.push_str(&format!(
            "{:<10}{:>7}{:>7}{:>9}{:>7}{:>9}\n",
            mach,
            cg.ms,
            cg.bs,
            cg.num_tasks(),
            fg.bs,
            fg.num_tasks()
        ));
    }
    out.push_str("\nTable 3: N-Body execution arguments\n");
    out.push_str(&format!(
        "{:<10}{:>10}{:>5}{:>7}{:>10}{:>7}{:>10}\n",
        "Machine", "Particles", "TS", "BS-CG", "#T-CG", "BS-FG", "#T-FG"
    ));
    for mach in ["knl", "thunderx", "power9"] {
        let cg = nbody::table3_params(mach, true);
        let fg = nbody::table3_params(mach, false);
        out.push_str(&format!(
            "{:<10}{:>10}{:>5}{:>7}{:>10}{:>7}{:>10}\n",
            mach,
            cg.num_particles,
            cg.timesteps,
            cg.bs,
            cg.num_tasks(),
            fg.bs,
            fg.num_tasks()
        ));
    }
    out.push_str("\nTable 4: Sparse LU execution arguments\n");
    let cg = sparselu::table4_params(true);
    let fg = sparselu::table4_params(false);
    out.push_str(&format!(
        "{:<10}{:>7}{:>7}{:>9}{:>7}{:>9}\n",
        "Machine", "MS", "BS-CG", "#T-CG", "BS-FG", "#T-FG"
    ));
    out.push_str(&format!(
        "{:<10}{:>7}{:>7}{:>9}{:>7}{:>9}\n",
        "all",
        cg.ms,
        cg.bs,
        sparselu::generate(cg).num_tasks(),
        fg.bs,
        sparselu::generate(fg).num_tasks()
    ));
    out
}

// ---------------------------------------------------------------------------
// §5: DDAST tuning (Table 5, Figures 5-8)
// ---------------------------------------------------------------------------

/// Which DDAST parameter a sweep varies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Param {
    MaxDdastThreads,
    MaxSpins,
    MaxOpsThread,
    MinReadyTasks,
}

impl Param {
    pub fn set(&self, mut p: DdastParams, v: u64) -> DdastParams {
        match self {
            Param::MaxDdastThreads => p.max_ddast_threads = v as usize,
            Param::MaxSpins => p.max_spins = v as u32,
            Param::MaxOpsThread => p.max_ops_thread = v as usize,
            Param::MinReadyTasks => p.min_ready_tasks = v,
        }
        p
    }

    pub fn name(&self) -> &'static str {
        match self {
            Param::MaxDdastThreads => "MAX_DDAST_THREADS",
            Param::MaxSpins => "MAX_SPINS",
            Param::MaxOpsThread => "MAX_OPS_THREAD",
            Param::MinReadyTasks => "MIN_READY_TASKS",
        }
    }
}

/// §5 protocol: initial values as defaults, one parameter swept 1..=128
/// doubling, Matmul + SparseLU, the two largest thread configs of
/// KNL / ThunderX / Power8+. Y-axis = speedup over the default value.
pub fn param_sweep(param: Param, opts: FigureOpts) -> String {
    let sweep: Vec<u64> = (0..8).map(|i| 1u64 << i).collect();
    let machines = ["knl", "thunderx", "power8"];
    let mut out = format!("Sweep of {} (speedup over default-value run)\n", param.name());
    for mach in machines {
        let m = MachineConfig::by_name(mach).unwrap();
        let max_t = m.max_threads_used();
        let thread_cfgs = [max_t / 2, max_t];
        for bench in [Bench::Matmul, Bench::SparseLu] {
            // The tuning uses fine-grain tasks (the sensitive regime).
            let spec = spec_for(bench, mach, false, opts);
            let mut series = Vec::new();
            for &threads in &thread_cfgs {
                let base = run(&spec, &m, RuntimeKind::Ddast, threads, DdastParams::initial());
                let mut points = Vec::new();
                for &v in &sweep {
                    let p = param.set(DdastParams::initial(), v);
                    let r = run(&spec, &m, RuntimeKind::Ddast, threads, p);
                    points.push((
                        v as usize,
                        base.makespan.as_secs_f64() / r.makespan.as_secs_f64(),
                    ));
                }
                series.push(Series { label: format!("{threads} threads"), points });
            }
            out.push_str(&speedup_table(
                &format!("\n{} / {} (FG), x = {}", bench.name(), mach, param.name()),
                &series,
            ));
        }
    }
    out
}

pub fn fig5(opts: FigureOpts) -> String {
    param_sweep(Param::MaxDdastThreads, opts)
}
pub fn fig6(opts: FigureOpts) -> String {
    param_sweep(Param::MaxSpins, opts)
}
pub fn fig7(opts: FigureOpts) -> String {
    param_sweep(Param::MaxOpsThread, opts)
}
pub fn fig8(opts: FigureOpts) -> String {
    param_sweep(Param::MinReadyTasks, opts)
}

/// Table 5: the parameter defaults before/after tuning, plus a measured
/// confirmation that the tuned values don't lose to the initial ones.
pub fn table5(opts: FigureOpts) -> String {
    let mut out = String::new();
    out.push_str("Table 5: DDAST parameters values\n");
    out.push_str(&format!("{:<20}{:>15}{:>20}\n", "Parameter", "Initial Value", "Tuned Value"));
    out.push_str(&format!("{:<20}{:>15}{:>20}\n", "MAX_DDAST_THREADS", "inf", "ceil(threads/8)"));
    out.push_str(&format!("{:<20}{:>15}{:>20}\n", "MAX_SPINS", 20, 1));
    out.push_str(&format!("{:<20}{:>15}{:>20}\n", "MAX_OPS_THREAD", 6, 8));
    out.push_str(&format!("{:<20}{:>15}{:>20}\n", "MIN_READY_TASKS", 4, 4));
    out.push_str("\nVerification (§5.5): tuned vs initial makespan ratio (>1 = tuned wins)\n");
    for mach in ["knl", "thunderx", "power8"] {
        let m = MachineConfig::by_name(mach).unwrap();
        let threads = m.max_threads_used();
        for bench in [Bench::Matmul, Bench::SparseLu, Bench::NBody] {
            let spec = spec_for(bench, mach, false, opts);
            let a = run(&spec, &m, RuntimeKind::Ddast, threads, DdastParams::initial());
            let b = run(&spec, &m, RuntimeKind::Ddast, threads, DdastParams::tuned(threads));
            out.push_str(&format!(
                "{:<10}{:<10}{} threads: {:>6.3}\n",
                mach,
                bench.name(),
                threads,
                a.makespan.as_secs_f64() / b.makespan.as_secs_f64()
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// §6.1: scalability (Figures 9-11)
// ---------------------------------------------------------------------------

/// Small grid search for the "DDAST tuned" line (§6.1: best values found
/// during tuning verification per combination).
fn best_params(spec: &TaskGraphSpec, m: &MachineConfig, threads: usize) -> DdastParams {
    let mut best = DdastParams::tuned(threads);
    let mut best_t = run(spec, m, RuntimeKind::Ddast, threads, best).makespan;
    for mdt in [1usize, 2, 4, 8, 16] {
        for ops in [8usize, 32] {
            for min_ready in [4u64, 32] {
                let p = DdastParams {
                    max_ddast_threads: mdt,
                    max_spins: 1,
                    max_ops_thread: ops,
                    min_ready_tasks: min_ready,
                };
                let t = run(spec, m, RuntimeKind::Ddast, threads, p).makespan;
                if t < best_t {
                    best_t = t;
                    best = p;
                }
            }
        }
    }
    best
}

/// One scalability subplot: 4 runtime series over the thread sweep.
pub fn scalability(bench: Bench, machine: &str, coarse: bool, opts: FigureOpts) -> String {
    let m = MachineConfig::by_name(machine).unwrap();
    let spec = spec_for(bench, machine, coarse, opts);
    let sweep = m.thread_sweep();
    let tuned = best_params(&spec, &m, *sweep.last().unwrap());
    let mut series = Vec::new();
    for (label, kind, params_fn) in [
        ("Nanos++", RuntimeKind::Sync, None::<fn(usize) -> DdastParams>),
        ("DDAST", RuntimeKind::Ddast, Some(DdastParams::tuned as fn(usize) -> DdastParams)),
        ("DDAST tuned", RuntimeKind::Ddast, None),
        ("GOMP", RuntimeKind::GompLike, None),
    ] {
        let mut points = Vec::new();
        for &t in &sweep {
            let p = match (label, params_fn) {
                ("DDAST tuned", _) => tuned,
                (_, Some(f)) => f(t),
                _ => DdastParams::tuned(t),
            };
            let r = run(&spec, &m, kind, t, p);
            points.push((t, r.speedup));
        }
        series.push(Series { label: label.to_string(), points });
    }
    let grain = if coarse { "CG" } else { "FG" };
    speedup_table(
        &format!("{} {} ({}), {} tasks — speedup vs sequential", bench.name(), machine, grain, spec.num_tasks()),
        &series,
    )
}

fn scalability_figure(bench: Bench, opts: FigureOpts) -> String {
    let mut out = String::new();
    for machine in ["knl", "thunderx", "power9"] {
        for coarse in [false, true] {
            out.push_str(&scalability(bench, machine, coarse, opts));
            out.push('\n');
        }
    }
    out
}

/// Figure 9: Matmul scalability (a–f).
pub fn fig9(opts: FigureOpts) -> String {
    format!("Figure 9: Matmul scalability\n\n{}", scalability_figure(Bench::Matmul, opts))
}

/// Figure 10: Sparse LU scalability (a–f).
pub fn fig10(opts: FigureOpts) -> String {
    format!("Figure 10: Sparse LU scalability\n\n{}", scalability_figure(Bench::SparseLu, opts))
}

/// Figure 11: N-Body scalability (a–f).
pub fn fig11(opts: FigureOpts) -> String {
    format!("Figure 11: N-Body scalability\n\n{}", scalability_figure(Bench::NBody, opts))
}

// ---------------------------------------------------------------------------
// §6.2: execution analysis traces (Figures 12-15)
// ---------------------------------------------------------------------------

fn traced(
    spec: &TaskGraphSpec,
    m: &MachineConfig,
    kind: RuntimeKind,
    threads: usize,
    res_ns: u64,
) -> SimResult {
    simulate(
        spec,
        m,
        SimOptions::new(kind, threads)
            .with_params(DdastParams::tuned(threads))
            .with_trace(res_ns),
    )
}

/// Figure 12: tasks-in-graph and ready evolution, fine-grain Matmul on KNL
/// with 64 threads — pyramid (Nanos++) vs roof (DDAST).
pub fn fig12(opts: FigureOpts) -> String {
    let m = MachineConfig::knl();
    let spec = spec_for(Bench::Matmul, "knl", false, opts);
    let sync = traced(&spec, &m, RuntimeKind::Sync, 64, 100_000);
    let ddast = traced(&spec, &m, RuntimeKind::Ddast, 64, 100_000);
    let (st, dt) = (sync.trace.unwrap(), ddast.trace.unwrap());
    let mut out = String::from("Figure 12: fine-grain Matmul on KNL, 64 threads\n\n");
    out.push_str(&ascii_series("(a) tasks in graph — Nanos++", &st.in_graph, 100, 8));
    out.push_str(&ascii_series("(a) tasks in graph — DDAST", &dt.in_graph, 100, 8));
    out.push_str(&ascii_series("(b) ready tasks — Nanos++", &st.ready, 100, 8));
    out.push_str(&ascii_series("(b) ready tasks — DDAST", &dt.ready, 100, 8));
    out.push_str(&format!(
        "\nmax in-graph: Nanos++ {} vs DDAST {} ({}x)\n",
        sync.stats.max_in_graph,
        ddast.stats.max_in_graph,
        sync.stats.max_in_graph / ddast.stats.max_in_graph.max(1)
    ));
    out
}

/// Figure 13: coarse-grain N-Body on ThunderX (48 threads, 2 timesteps) —
/// thread-state timelines and in-graph evolution.
pub fn fig13(opts: FigureOpts) -> String {
    let m = MachineConfig::thunderx();
    let mut p = nbody::table3_params("thunderx", true);
    p.timesteps = 2; // as in the paper's trace
    if opts.quick {
        p.num_particles = 4096;
    }
    let spec = nbody::generate(p);
    let sync = traced(&spec, &m, RuntimeKind::Sync, 48, 50_000);
    let ddast = traced(&spec, &m, RuntimeKind::Ddast, 48, 50_000);
    let (st, dt) = (sync.trace.unwrap(), ddast.trace.unwrap());
    let mut out = String::from("Figure 13: coarse-grain N-Body on ThunderX, 48 threads, 2 timesteps\n");
    out.push_str("\n(a) Nanos++ thread states ('#'=task, 'c'=creator, 'm'=manager):\n");
    out.push_str(&ascii_timeline(&st, 100));
    out.push_str("\n(b) tasks in graph:\n");
    out.push_str(&ascii_series("Nanos++", &st.in_graph, 100, 6));
    out.push_str(&ascii_series("DDAST", &dt.in_graph, 100, 6));
    out.push_str("\n(c) DDAST thread states:\n");
    out.push_str(&ascii_timeline(&dt, 100));
    out.push_str(&format!(
        "\nmakespan: Nanos++ {} vs DDAST {}\n",
        sync.makespan, ddast.makespan
    ));
    out
}

/// Figure 14: coarse-grain Sparse LU on ThunderX — in-graph and ready
/// evolution for the full run.
pub fn fig14(opts: FigureOpts) -> String {
    let m = MachineConfig::thunderx();
    let spec = spec_for(Bench::SparseLu, "thunderx", true, opts);
    let sync = traced(&spec, &m, RuntimeKind::Sync, 48, 100_000);
    let ddast = traced(&spec, &m, RuntimeKind::Ddast, 48, 100_000);
    let (st, dt) = (sync.trace.unwrap(), ddast.trace.unwrap());
    let mut out = String::from("Figure 14: coarse-grain Sparse LU on ThunderX, 48 threads\n\n");
    out.push_str(&ascii_series("(a) in graph — Nanos++", &st.in_graph, 100, 8));
    out.push_str(&ascii_series("(a) in graph — DDAST", &dt.in_graph, 100, 8));
    out.push_str(&ascii_series("(b) ready — Nanos++", &st.ready, 100, 8));
    out.push_str(&ascii_series("(b) ready — DDAST", &dt.ready, 100, 8));
    out
}

/// Figure 15: the DDAST idle-valley zoom of Sparse LU — ready tasks drop
/// to ~0, idle threads turn manager, then the critical Done message lands
/// and ready jumps.
pub fn fig15(opts: FigureOpts) -> String {
    let m = MachineConfig::thunderx();
    let spec = spec_for(Bench::SparseLu, "thunderx", true, opts);
    let r = traced(&spec, &m, RuntimeKind::Ddast, 48, 20_000);
    let tr = r.trace.unwrap();
    // Find the longest window where ready stays < 4, past the warmup.
    let mut best: (u64, u64) = (0, 0);
    let mut cur_start: Option<u64> = None;
    for &(t, v) in &tr.ready {
        if v < 4 {
            cur_start.get_or_insert(t);
        } else if let Some(s) = cur_start.take() {
            if t - s > best.1 - best.0 {
                best = (s, t);
            }
        }
    }
    let (w0, w1) = if best.1 > best.0 {
        best
    } else {
        (0, r.makespan.as_nanos())
    };
    // Pad the window for context.
    let pad = (w1 - w0) / 2 + 1;
    let (z0, z1) = (w0.saturating_sub(pad), w1 + pad);
    let zoom: Vec<(u64, u64)> =
        tr.ready.iter().copied().filter(|&(t, _)| t >= z0 && t <= z1).collect();
    let mut out = String::from("Figure 15: Sparse LU (CG, ThunderX, 48 threads, DDAST) idle-valley zoom\n\n");
    out.push_str(&format!(
        "(a) ready tasks around the valley [{:.3}ms, {:.3}ms]:\n",
        z0 as f64 / 1e6,
        z1 as f64 / 1e6
    ));
    out.push_str(&ascii_series("ready (zoom)", &zoom, 100, 10));
    let after_max = tr.ready.iter().filter(|&&(t, _)| t >= w1).map(|&(_, v)| v).take(50).max();
    out.push_str(&format!(
        "\nvalley length: {:.3}ms; ready right after the valley: {:?} (paper: jumps >100)\n",
        (w1 - w0) as f64 / 1e6,
        after_max
    ));
    out.push_str(&format!(
        "manager passes during run: {}, messages processed: {}\n",
        r.stats.mgr_passes, r.stats.msgs_processed
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_print() {
        assert!(table1().contains("knl"));
        let t = tables234();
        assert!(t.contains("Table 2") && t.contains("Table 3") && t.contains("Table 4"));
        assert!(t.contains("262176") || t.contains("262 176") || t.contains("262176"));
    }

    #[test]
    fn quick_specs_shrink() {
        let q = spec_for(Bench::Matmul, "knl", false, FigureOpts::quick());
        let f = spec_for(Bench::Matmul, "knl", false, FigureOpts::full());
        assert!(q.num_tasks() < f.num_tasks());
    }

    #[test]
    fn scalability_one_cell_runs() {
        let s = scalability(Bench::Matmul, "power9", true, FigureOpts::quick());
        assert!(s.contains("Nanos++") && s.contains("DDAST tuned") && s.contains("GOMP"));
    }

    #[test]
    fn param_setter() {
        let p = Param::MaxOpsThread.set(DdastParams::initial(), 42);
        assert_eq!(p.max_ops_thread, 42);
        let p = Param::MinReadyTasks.set(DdastParams::initial(), 9);
        assert_eq!(p.min_ready_tasks, 9);
    }
}
