//! Old-vs-new contention A/B drills for the lock-free hot paths.
//!
//! The seed's shared structures (one `SpinLock<VecDeque>` per ready pool,
//! one spinlock per dependence domain) were replaced by Chase–Lev-style
//! deques and striped domains (EXPERIMENTS.md §Lock-free hot paths). This
//! module runs the *same* multi-threaded workload against the seed-era
//! structures ([`LockedReadyPools`], `DepDomain::with_stripes(1)`) and the
//! new ones ([`ReadyPools`], `DepDomain::new()`), and reports contended
//! acquisitions / CAS retries side by side — so the win is measured, not
//! asserted. `micro_structures` and the `contention_ab` tier-1 test both
//! drive it and serialize the result to `BENCH_contention.json` for the
//! perf trajectory of future PRs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Weak};
use std::time::Instant;

use crate::coordinator::dep::dep_out;
use crate::coordinator::depgraph::DepDomain;
use crate::coordinator::ready::{LockedReadyPools, PoolContention, ReadyPools};
use crate::coordinator::wd::{TaskId, Wd, WdState};

/// One side of an A/B measurement.
#[derive(Clone, Copy, Debug, Default)]
pub struct SideReport {
    /// Lock/token acquisitions.
    pub acquisitions: u64,
    /// Contended acquisitions (had to spin).
    pub contended: u64,
    /// Total spin iterations.
    pub spin_iters: u64,
    /// Lock-free CAS attempts (0 for locked structures).
    pub cas_attempts: u64,
    /// Lost CAS races (the lock-free contention proxy).
    pub cas_retries: u64,
    /// Wall-clock of the drill in nanoseconds.
    pub elapsed_ns: u64,
}

impl SideReport {
    /// Contended events under either regime (spins or lost CAS races) —
    /// the acceptance metric of the A/B.
    pub fn contended_events(&self) -> u64 {
        self.contended + self.cas_retries
    }

    fn from_pool(stats: PoolContention, elapsed_ns: u64) -> Self {
        SideReport {
            acquisitions: stats.acquisitions,
            contended: stats.contended,
            spin_iters: stats.spin_iters,
            cas_attempts: stats.cas_attempts,
            cas_retries: stats.cas_retries,
            elapsed_ns,
        }
    }

    fn from_lock_stats(stats: (u64, u64, u64), elapsed_ns: u64) -> Self {
        SideReport {
            acquisitions: stats.0,
            contended: stats.1,
            spin_iters: stats.2,
            cas_attempts: 0,
            cas_retries: 0,
            elapsed_ns,
        }
    }
}

/// A full A/B: seed structure vs lock-free structure on the same workload.
#[derive(Clone, Copy, Debug, Default)]
pub struct AbReport {
    pub old: SideReport,
    pub new: SideReport,
}

impl AbReport {
    /// `old.contended_events() / new.contended_events()` (∞ → u64::MAX
    /// when the new side never contended).
    pub fn reduction(&self) -> f64 {
        let new = self.new.contended_events();
        if new == 0 {
            f64::INFINITY
        } else {
            self.old.contended_events() as f64 / new as f64
        }
    }
}

/// The complete contention A/B (both hot paths) at one thread count.
#[derive(Clone, Copy, Debug, Default)]
pub struct ContentionReport {
    pub threads: usize,
    pub ops_per_thread: u64,
    pub ready_pools: AbReport,
    pub dep_domain: AbReport,
}

fn mk_task(id: u64) -> Arc<Wd> {
    Wd::new(TaskId(id), Vec::new(), "drill", Weak::new(), Box::new(|| {}))
}

/// Ready-pool drill: the first half of the threads produce into their own
/// pools (interleaving occasional own pops, like workers releasing and
/// running tasks); the second half only consume, which forces them onto the
/// steal path. Runs until every produced task is consumed.
fn drill_ready<P, G>(threads: usize, ops: u64, push: P, get: G)
where
    P: Fn(usize, Arc<Wd>) + Sync,
    G: Fn(usize) -> Option<Arc<Wd>> + Sync,
{
    let producers = (threads / 2).max(1);
    let total = producers as u64 * ops;
    let consumed = AtomicU64::new(0);
    let push = &push;
    let get = &get;
    let consumed = &consumed;
    std::thread::scope(|s| {
        for t in 0..threads {
            s.spawn(move || {
                if t < producers {
                    for i in 0..ops {
                        push(t, mk_task(t as u64 * ops + i + 1));
                        if i % 4 == 0 && get(t).is_some() {
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                // Everyone drains until all tasks are accounted for
                // (producers included, so the drill never hangs if the
                // thieves are descheduled).
                while consumed.load(Ordering::Relaxed) < total {
                    if get(t).is_some() {
                        consumed.fetch_add(1, Ordering::Relaxed);
                    } else {
                        std::thread::yield_now();
                    }
                }
            });
        }
    });
}

/// Run the ready-pool A/B at `threads` threads, `ops` pushes per producer.
pub fn ready_pools_ab(threads: usize, ops: u64) -> AbReport {
    let old = LockedReadyPools::new(threads, 7);
    let t0 = Instant::now();
    drill_ready(threads, ops, |t, wd| old.push(t, wd), |t| old.get(t));
    let old_report =
        SideReport::from_pool(old.contention_stats(), t0.elapsed().as_nanos() as u64);

    let new = ReadyPools::new(threads, 7);
    let t0 = Instant::now();
    drill_ready(threads, ops, |t, wd| new.push(t, wd), |t| new.get(t));
    let new_report =
        SideReport::from_pool(new.contention_stats(), t0.elapsed().as_nanos() as u64);

    AbReport { old: old_report, new: new_report }
}

/// Dependence-domain drill: each thread submits and finishes its own
/// stream of single-dep tasks over a small private region set — fully
/// independent regions, so a striped domain should let the threads run
/// (nearly) without contending, while the single-lock domain serializes
/// every operation.
fn drill_domain(domain: &DepDomain, threads: usize, ops: u64) {
    std::thread::scope(|s| {
        for t in 0..threads {
            s.spawn(move || {
                // 8 private regions per thread, revisited round-robin (the
                // benchmarks' block-reuse pattern).
                let base = 1_000_000u64 * (t as u64 + 1);
                for i in 0..ops {
                    let wd = Wd::new(
                        TaskId(t as u64 * ops + i + 1),
                        vec![dep_out(base + i % 8)],
                        "drill",
                        Weak::new(),
                        Box::new(|| {}),
                    );
                    wd.set_state(WdState::Submitted);
                    domain.submit(&wd);
                    wd.set_state(WdState::Ready);
                    wd.set_state(WdState::Running);
                    wd.set_state(WdState::Finished);
                    let ready = domain.finish(&wd);
                    debug_assert!(ready.is_empty(), "streams are independent");
                }
            });
        }
    });
}

/// Run the dependence-domain A/B: 1 stripe (the seed's single lock) vs the
/// default stripe count.
pub fn dep_domain_ab(threads: usize, ops: u64) -> AbReport {
    let old = DepDomain::with_stripes(1);
    let t0 = Instant::now();
    drill_domain(&old, threads, ops);
    let old_report =
        SideReport::from_lock_stats(old.lock_stats(), t0.elapsed().as_nanos() as u64);

    let new = DepDomain::new();
    let t0 = Instant::now();
    drill_domain(&new, threads, ops);
    let new_report =
        SideReport::from_lock_stats(new.lock_stats(), t0.elapsed().as_nanos() as u64);

    AbReport { old: old_report, new: new_report }
}

/// Run both A/Bs.
pub fn run_ab(threads: usize, ops_per_thread: u64) -> ContentionReport {
    ContentionReport {
        threads,
        ops_per_thread,
        ready_pools: ready_pools_ab(threads, ops_per_thread),
        dep_domain: dep_domain_ab(threads, ops_per_thread),
    }
}

fn side_json(s: &SideReport) -> String {
    format!(
        "{{\"acquisitions\": {}, \"contended\": {}, \"spin_iters\": {}, \
         \"cas_attempts\": {}, \"cas_retries\": {}, \"contended_events\": {}, \
         \"elapsed_ns\": {}}}",
        s.acquisitions,
        s.contended,
        s.spin_iters,
        s.cas_attempts,
        s.cas_retries,
        s.contended_events(),
        s.elapsed_ns
    )
}

fn ab_json(ab: &AbReport) -> String {
    let red = ab.reduction();
    let red = if red.is_finite() { format!("{red:.2}") } else { "null".to_string() };
    format!(
        "{{\"old\": {}, \"new\": {}, \"contended_reduction\": {}}}",
        side_json(&ab.old),
        side_json(&ab.new),
        red
    )
}

/// Serialize the report (hand-rolled: the offline environment has no serde).
/// `contended_reduction` is `null` when the new side recorded zero
/// contended events (an infinite improvement).
pub fn to_json(r: &ContentionReport, generated_by: &str) -> String {
    format!(
        "{{\n  \"generated_by\": \"{}\",\n  \"threads\": {},\n  \"ops_per_thread\": {},\n  \
         \"ready_pools\": {},\n  \"dep_domain\": {}\n}}\n",
        generated_by,
        r.threads,
        r.ops_per_thread,
        ab_json(&r.ready_pools),
        ab_json(&r.dep_domain)
    )
}

/// Human-readable table for the bench output.
pub fn render(r: &ContentionReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Contention A/B — {} threads, {} ops/producer (contended = spins, retries = lost CAS)\n",
        r.threads, r.ops_per_thread
    ));
    out.push_str(&format!(
        "{:<22}{:>14}{:>12}{:>12}{:>12}{:>12}\n",
        "structure", "acquisitions", "contended", "cas-retry", "events", "ms"
    ));
    for (name, s) in [
        ("ready: locked (seed)", &r.ready_pools.old),
        ("ready: ws-deque", &r.ready_pools.new),
        ("domain: 1 stripe", &r.dep_domain.old),
        ("domain: striped", &r.dep_domain.new),
    ] {
        out.push_str(&format!(
            "{:<22}{:>14}{:>12}{:>12}{:>12}{:>12.2}\n",
            name,
            s.acquisitions,
            s.contended,
            s.cas_retries,
            s.contended_events(),
            s.elapsed_ns as f64 / 1e6
        ));
    }
    out.push_str(&format!(
        "reduction in contended events: ready-pools {}, dep-domain {}\n",
        fmt_reduction(r.ready_pools.reduction()),
        fmt_reduction(r.dep_domain.reduction())
    ));
    out
}

fn fmt_reduction(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.1}x")
    } else {
        "inf (new side uncontended)".to_string()
    }
}

/// Default output path: the repository root, next to EXPERIMENTS.md.
pub fn default_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_contention.json")
}

/// Write the report to `path` (best-effort; benches must not fail the run
/// over a read-only checkout).
pub fn write_json(path: &std::path::Path, r: &ContentionReport, generated_by: &str) -> bool {
    std::fs::write(path, to_json(r, generated_by)).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ab_runs_and_counts() {
        let r = run_ab(2, 200);
        assert_eq!(r.threads, 2);
        // Every producer push acquired something on both sides.
        assert!(r.ready_pools.old.acquisitions >= 200);
        assert!(r.ready_pools.new.acquisitions + r.ready_pools.new.cas_attempts >= 200);
        assert!(r.dep_domain.old.acquisitions >= 2 * 200 * 2, "submit+finish per op");
        assert!(r.dep_domain.new.acquisitions >= 2 * 200 * 2);
    }

    #[test]
    fn json_shape() {
        let r = run_ab(1, 50);
        let j = to_json(&r, "unit test");
        for key in [
            "\"generated_by\"",
            "\"threads\"",
            "\"ready_pools\"",
            "\"dep_domain\"",
            "\"contended_reduction\"",
            "\"cas_retries\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert!(render(&r).contains("reduction in contended events"));
    }
}
