//! Old-vs-new contention A/B drills for the lock-free hot paths.
//!
//! The seed's shared structures (one `SpinLock<VecDeque>` per ready pool,
//! one spinlock per dependence domain) were replaced by Chase–Lev-style
//! deques and striped domains (EXPERIMENTS.md §Lock-free hot paths), and
//! the request plane's remaining shared touches — the all-workers queue
//! sweep, the dispatcher's locked registry, the tracer's mutexed buffers —
//! by the signal directory, an RCU snapshot and wait-free rings
//! (EXPERIMENTS.md §Request plane). This module runs the *same* workload
//! against the retained seed-era structures ([`LockedReadyPools`],
//! `DepDomain::with_stripes(1)`, a full queue sweep, [`LockedDispatcher`],
//! [`LockedTracer`]) and the new ones, and reports contended acquisitions /
//! CAS retries / token touches side by side — so the win is measured, not
//! asserted. `micro_structures` and the `contention_ab` tier-1 test both
//! drive it and serialize the result to `BENCH_contention.json` for the
//! perf trajectory of future PRs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use crate::coordinator::dep::dep_out;
use crate::coordinator::depgraph::DepDomain;
use crate::coordinator::dispatcher::{Dispatcher, LockedDispatcher};
use crate::coordinator::messages::QueueSystem;
use crate::coordinator::ready::{LockedReadyPools, PoolContention, ReadyPools};
use crate::coordinator::trace::{LockedTracer, TraceKind, Tracer};
use crate::coordinator::wd::{TaskId, Wd, WdState};
use crate::substrate::{FaultPlan, FaultSite, SignalDirectory, Topology};

/// One side of an A/B measurement.
#[derive(Clone, Copy, Debug, Default)]
pub struct SideReport {
    /// Lock/token acquisitions.
    pub acquisitions: u64,
    /// Contended acquisitions (had to spin).
    pub contended: u64,
    /// Total spin iterations.
    pub spin_iters: u64,
    /// Lock-free CAS attempts (0 for locked structures).
    pub cas_attempts: u64,
    /// Lost CAS races (the lock-free contention proxy).
    pub cas_retries: u64,
    /// Wall-clock of the drill in nanoseconds.
    pub elapsed_ns: u64,
}

impl SideReport {
    /// Contended events under either regime (spins or lost CAS races) —
    /// the acceptance metric of the A/B.
    pub fn contended_events(&self) -> u64 {
        self.contended + self.cas_retries
    }

    fn from_pool(stats: PoolContention, elapsed_ns: u64) -> Self {
        SideReport {
            acquisitions: stats.acquisitions,
            contended: stats.contended,
            spin_iters: stats.spin_iters,
            cas_attempts: stats.cas_attempts,
            cas_retries: stats.cas_retries,
            elapsed_ns,
        }
    }

    fn from_lock_stats(stats: (u64, u64, u64), elapsed_ns: u64) -> Self {
        SideReport {
            acquisitions: stats.0,
            contended: stats.1,
            spin_iters: stats.2,
            cas_attempts: 0,
            cas_retries: 0,
            elapsed_ns,
        }
    }
}

/// A full A/B: seed structure vs lock-free structure on the same workload.
#[derive(Clone, Copy, Debug, Default)]
pub struct AbReport {
    pub old: SideReport,
    pub new: SideReport,
}

impl AbReport {
    /// `old.contended_events() / new.contended_events()` (∞ → u64::MAX
    /// when the new side never contended).
    pub fn reduction(&self) -> f64 {
        let new = self.new.contended_events();
        if new == 0 {
            f64::INFINITY
        } else {
            self.old.contended_events() as f64 / new as f64
        }
    }
}

/// The complete contention A/B (all instrumented hot paths) at one thread
/// count.
#[derive(Clone, Copy, Debug, Default)]
pub struct ContentionReport {
    pub threads: usize,
    pub ops_per_thread: u64,
    pub ready_pools: AbReport,
    pub dep_domain: AbReport,
    /// Locked-registry vs RCU-snapshot dispatcher poll.
    pub dispatcher_poll: AbReport,
    /// Mutexed buffers vs wait-free rings trace append.
    pub trace_append: AbReport,
    /// Per-message vs per-batch graph insertion (shard acquisitions are
    /// the counter-verified metric).
    pub batch_submit: AbReport,
}

/// The sparse-traffic request-plane sweep A/B at one simulated worker
/// count: old full queue sweep vs signal-directory scan. `acquisitions`
/// counts queue-token grabs — the metric that goes from O(workers) to
/// O(dirty) per round.
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepReport {
    pub workers: usize,
    pub rounds: u64,
    pub ab: AbReport,
}

fn mk_task(id: u64) -> Arc<Wd> {
    Wd::new(TaskId(id), Vec::new(), "drill", Weak::new(), Box::new(|| {}))
}

/// Ready-pool drill: the first half of the threads produce into their own
/// pools (interleaving occasional own pops, like workers releasing and
/// running tasks); the second half only consume, which forces them onto the
/// steal path. Runs until every produced task is consumed.
fn drill_ready<P, G>(threads: usize, ops: u64, push: P, get: G)
where
    P: Fn(usize, Arc<Wd>) + Sync,
    G: Fn(usize) -> Option<Arc<Wd>> + Sync,
{
    let producers = (threads / 2).max(1);
    let total = producers as u64 * ops;
    let consumed = AtomicU64::new(0);
    let push = &push;
    let get = &get;
    let consumed = &consumed;
    std::thread::scope(|s| {
        for t in 0..threads {
            s.spawn(move || {
                if t < producers {
                    for i in 0..ops {
                        push(t, mk_task(t as u64 * ops + i + 1));
                        if i % 4 == 0 && get(t).is_some() {
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                // Everyone drains until all tasks are accounted for
                // (producers included, so the drill never hangs if the
                // thieves are descheduled).
                while consumed.load(Ordering::Relaxed) < total {
                    if get(t).is_some() {
                        consumed.fetch_add(1, Ordering::Relaxed);
                    } else {
                        std::thread::yield_now();
                    }
                }
            });
        }
    });
}

/// Run the ready-pool A/B at `threads` threads, `ops` pushes per producer.
pub fn ready_pools_ab(threads: usize, ops: u64) -> AbReport {
    let old = LockedReadyPools::new(threads, 7);
    let t0 = Instant::now();
    drill_ready(threads, ops, |t, wd| old.push(t, wd), |t| old.get(t));
    let old_report =
        SideReport::from_pool(old.contention_stats(), t0.elapsed().as_nanos() as u64);

    let new = ReadyPools::new(threads, 7);
    let t0 = Instant::now();
    drill_ready(threads, ops, |t, wd| new.push(t, wd), |t| new.get(t));
    let new_report =
        SideReport::from_pool(new.contention_stats(), t0.elapsed().as_nanos() as u64);

    AbReport { old: old_report, new: new_report }
}

/// Dependence-domain drill: each thread submits and finishes its own
/// stream of single-dep tasks over a small private region set — fully
/// independent regions, so a striped domain should let the threads run
/// (nearly) without contending, while the single-lock domain serializes
/// every operation.
fn drill_domain(domain: &DepDomain, threads: usize, ops: u64) {
    std::thread::scope(|s| {
        for t in 0..threads {
            s.spawn(move || {
                // 8 private regions per thread, revisited round-robin (the
                // benchmarks' block-reuse pattern).
                let base = 1_000_000u64 * (t as u64 + 1);
                for i in 0..ops {
                    let wd = Wd::new(
                        TaskId(t as u64 * ops + i + 1),
                        vec![dep_out(base + i % 8)],
                        "drill",
                        Weak::new(),
                        Box::new(|| {}),
                    );
                    wd.set_state(WdState::Submitted);
                    domain.submit(&wd);
                    wd.set_state(WdState::Ready);
                    wd.set_state(WdState::Running);
                    wd.set_state(WdState::Finished);
                    let ready = domain.finish(&wd);
                    debug_assert!(ready.is_empty(), "streams are independent");
                }
            });
        }
    });
}

/// Run the dependence-domain A/B: 1 stripe (the seed's single lock) vs the
/// default stripe count.
pub fn dep_domain_ab(threads: usize, ops: u64) -> AbReport {
    let old = DepDomain::with_stripes(1);
    let t0 = Instant::now();
    drill_domain(&old, threads, ops);
    let old_report =
        SideReport::from_lock_stats(old.lock_stats(), t0.elapsed().as_nanos() as u64);

    let new = DepDomain::new();
    let t0 = Instant::now();
    drill_domain(&new, threads, ops);
    let new_report =
        SideReport::from_lock_stats(new.lock_stats(), t0.elapsed().as_nanos() as u64);

    AbReport { old: old_report, new: new_report }
}

/// Dispatcher-poll drill: `threads` threads each poll `ops` times against
/// a registry of three no-op callbacks (the DDAST + autotuner shape). Old:
/// the seed's `SpinLock<Vec>` registry, snapshot-cloned per poll. New: the
/// RCU snapshot, one acquire load per poll.
pub fn dispatcher_poll_ab(threads: usize, ops: u64) -> AbReport {
    fn drill<P: Fn(usize) + Sync>(threads: usize, ops: u64, poll: P) -> u64 {
        let poll = &poll;
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for t in 0..threads {
                s.spawn(move || {
                    for _ in 0..ops {
                        poll(t);
                    }
                });
            }
        });
        t0.elapsed().as_nanos() as u64
    }

    let old = LockedDispatcher::new();
    old.register("a", Box::new(|_| false));
    old.register("b", Box::new(|_| false));
    old.register("c", Box::new(|_| true));
    let elapsed = drill(threads, ops, |t| {
        old.poll_idle(t);
    });
    let old_report = SideReport::from_lock_stats(old.lock_stats(), elapsed);

    let new = Dispatcher::new();
    new.register("a", Box::new(|_| false));
    new.register("b", Box::new(|_| false));
    new.register("c", Box::new(|_| true));
    let elapsed = drill(threads, ops, |t| {
        new.poll_idle(t);
    });
    // The RCU poll path takes no lock and loses no CAS races (reads are
    // plain loads); only the wall clock and the zeroed counters speak.
    let new_report = SideReport { elapsed_ns: elapsed, ..SideReport::default() };

    AbReport { old: old_report, new: new_report }
}

/// Trace-append drill: `threads` threads each record `ops` events into
/// their own slot. Old: the seed's `Mutex<Vec>` per buffer — one lock
/// round-trip per event even uncontended. New: wait-free single-writer
/// rings. `acquisitions` on the old side counts the per-event locks.
pub fn trace_append_ab(threads: usize, ops: u64) -> AbReport {
    let old = LockedTracer::new(threads);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let old = &old;
            s.spawn(move || {
                for i in 0..ops {
                    old.record(t, TraceKind::InGraph(i));
                }
            });
        }
    });
    let old_report = SideReport {
        acquisitions: threads as u64 * ops, // one Mutex lock per record
        elapsed_ns: t0.elapsed().as_nanos() as u64,
        ..SideReport::default()
    };
    assert_eq!(old.merged().len() as u64, threads as u64 * ops);

    let new = Tracer::with_capacity(threads, ops as usize);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let new = &new;
            s.spawn(move || {
                for i in 0..ops {
                    new.record(t, TraceKind::InGraph(i));
                }
            });
        }
    });
    let new_report = SideReport {
        elapsed_ns: t0.elapsed().as_nanos() as u64,
        ..SideReport::default()
    };
    assert_eq!(new.merged().len() as u64, threads as u64 * ops, "no event lost");
    assert_eq!(new.dropped(), 0);

    AbReport { old: old_report, new: new_report }
}

/// Drain budget of the batched-submission drill: the Listing-2 tuned
/// `MAX_OPS_THREAD` (Table 5), i.e. the batch size the DDAST callback
/// actually drains per claimed worker.
pub const SUBMIT_BATCH: usize = 8;

/// Batched-submission drill (EXPERIMENTS.md §Batched request plane): each
/// thread inserts `ops` single-dep tasks over a 4-region private set —
/// the benchmarks' block-reuse pattern. Old side: one `DepDomain::submit`
/// per task, i.e. one shard acquisition per message. New side:
/// `submit_batch` in [`SUBMIT_BATCH`]-task groups — the union of a batch's
/// shards (≤ 4 distinct regions here) is acquired once per batch. The
/// acceptance metric is shard acquisitions per message, which the lock
/// counters verify deterministically (it cannot be faked by timing): the
/// old side pays exactly `threads × ops`, the new side at most half that.
pub fn batch_submit_ab(threads: usize, ops: u64) -> AbReport {
    fn drill(domain: &DepDomain, threads: usize, ops: u64, batched: bool) {
        std::thread::scope(|s| {
            for t in 0..threads {
                s.spawn(move || {
                    // 4 private regions per thread, revisited round-robin.
                    let base = 1_000_000u64 * (t as u64 + 1);
                    let mut ready = Vec::new();
                    let mut batch: Vec<Arc<Wd>> = Vec::with_capacity(SUBMIT_BATCH);
                    let mut keep: Vec<Arc<Wd>> = Vec::with_capacity(ops as usize);
                    for i in 0..ops {
                        let wd = Wd::new(
                            TaskId(t as u64 * ops + i + 1),
                            vec![dep_out(base + i % 4)],
                            "drill",
                            Weak::new(),
                            Box::new(|| {}),
                        );
                        wd.set_state(WdState::Submitted);
                        if batched {
                            batch.push(wd);
                            if batch.len() == SUBMIT_BATCH {
                                domain.submit_batch(&batch, &mut ready);
                                keep.append(&mut batch);
                            }
                        } else {
                            domain.submit(&wd);
                            keep.push(wd);
                        }
                    }
                    if !batch.is_empty() {
                        domain.submit_batch(&batch, &mut ready);
                        keep.append(&mut batch);
                    }
                    // `keep` holds the WAW chains alive until the scope
                    // ends; dropping unwinds the forward Arc links.
                });
            }
        });
    }

    let old = DepDomain::new();
    let t0 = Instant::now();
    drill(&old, threads, ops, false);
    let old_report =
        SideReport::from_lock_stats(old.lock_stats(), t0.elapsed().as_nanos() as u64);

    let new = DepDomain::new();
    let t0 = Instant::now();
    drill(&new, threads, ops, true);
    let new_report =
        SideReport::from_lock_stats(new.lock_stats(), t0.elapsed().as_nanos() as u64);

    AbReport { old: old_report, new: new_report }
}

/// Parked-vs-sleeping idle-wake drill: one consumer waits for work items a
/// producer publishes at round-trip pace. Old side: the consumer idles in
/// the seed's blind 100 µs sleep tier (`idle_backoff`'s deepest rung), so
/// every wake costs up to a sleep quantum. New side: the consumer parks on
/// a [`SignalDirectory`] and the producer's raise wakes it event-driven.
/// `elapsed_ns` is the makespan of `rounds` one-message round trips;
/// `acquisitions` records the rounds completed (identical by construction
/// — the drill is also a no-lost-wakeup check: a lost wake hangs it).
pub fn park_wake_ab(rounds: u64) -> AbReport {
    fn drill(rounds: u64, parked: bool) -> SideReport {
        let dir = SignalDirectory::new(2);
        let work = AtomicU64::new(0);
        let consumed = AtomicU64::new(0);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            let (dir, work, consumed) = (&dir, &work, &consumed);
            s.spawn(move || {
                let mut got = 0u64;
                while got < rounds {
                    let n = work.swap(0, Ordering::AcqRel);
                    if n > 0 {
                        got += n;
                        dir.try_claim(0);
                        consumed.store(got, Ordering::Release);
                        continue;
                    }
                    if parked {
                        // Sole owner of slot 0, so the announce always
                        // claims. Plain re-check: the begin_park /
                        // wake_parked fences close the store-buffer race.
                        assert!(dir.begin_park(0));
                        if work.load(Ordering::Relaxed) == 0 {
                            dir.park(0);
                        } else {
                            dir.cancel_park(0);
                        }
                    } else {
                        std::thread::sleep(Duration::from_micros(100));
                    }
                }
            });
            s.spawn(move || {
                for i in 0..rounds {
                    work.fetch_add(1, Ordering::AcqRel);
                    dir.raise(0); // publish-then-wake
                    while consumed.load(Ordering::Acquire) < i + 1 {
                        std::thread::yield_now();
                    }
                }
            });
        });
        SideReport {
            acquisitions: rounds,
            elapsed_ns: t0.elapsed().as_nanos() as u64,
            ..SideReport::default()
        }
    }

    AbReport { old: drill(rounds, false), new: drill(rounds, true) }
}

/// Taskwait-wake drill: a waiter repeatedly waits for a one-child
/// "taskwait" to complete, round-trip with a finisher thread playing the
/// last child's finalizer. Old side: the seed's blind spin → yield →
/// sleep ladder polling the child count (the pre-parking `taskwait_on`
/// shape — up to a 100 µs sleep quantum of wake latency per round). New
/// side: the waiter registers the **child-completion wake edge** on a
/// real `Wd` (`register_waiter`) and parks on a [`SignalDirectory`]; the
/// finisher's decrement-to-zero claims the registration (`take_waiter`)
/// and wakes the slot (`wake_worker`). `acquisitions` records completed
/// rounds on both sides (completion *is* the no-lost-wakeup check: a
/// swallowed wake hangs the drill); `elapsed_ns` is the makespan.
pub fn taskwait_park_ab(rounds: u64) -> AbReport {
    fn drill(rounds: u64, parked: bool) -> SideReport {
        let dir = SignalDirectory::new(2);
        let parent = mk_task(1);
        let started = AtomicU64::new(0);
        let finished = AtomicU64::new(0);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            let (dir, parent) = (&dir, &parent);
            let (started, finished) = (&started, &finished);
            // Finisher: the last child's finalizer — decrement first,
            // then claim the waiter registration and wake the parent.
            s.spawn(move || {
                for r in 0..rounds {
                    while started.load(Ordering::Acquire) <= r {
                        std::thread::yield_now();
                    }
                    parent.child_done();
                    if let Some(w) = parent.take_waiter() {
                        dir.wake_worker(w);
                    }
                }
            });
            // Waiter (worker slot 0).
            for _ in 0..rounds {
                parent.child_created();
                started.fetch_add(1, Ordering::AcqRel);
                let mut idle: u32 = 0;
                while parent.children_live() > 0 {
                    idle += 1;
                    if idle < 32 {
                        std::hint::spin_loop();
                        continue;
                    }
                    if parked {
                        // register → announce → re-check → commit (sole
                        // owner of slot 0, so the announce always claims).
                        if let Some(token) = parent.register_waiter(0) {
                            if dir.begin_park(0) {
                                if parent.children_live() > 0 {
                                    dir.park(0);
                                } else {
                                    dir.cancel_park(0);
                                }
                            }
                            parent.clear_waiter(token);
                        }
                    } else if idle < 64 {
                        // The seed ladder, compressed so the drill reaches
                        // its sleep tier at the same point the parking
                        // side commits.
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep(Duration::from_micros(100));
                    }
                }
                finished.fetch_add(1, Ordering::AcqRel);
            }
        });
        SideReport {
            acquisitions: finished.load(Ordering::Acquire),
            elapsed_ns: t0.elapsed().as_nanos() as u64,
            ..SideReport::default()
        }
    }

    AbReport { old: drill(rounds, false), new: drill(rounds, true) }
}

/// Adaptive-batch-budget drill (the paper's §8 future work, closed by
/// `AutoTuner`): drain a deep burst of `msgs` Submit messages through
/// budgeted `drain_batch_with` rounds against a real single-worker
/// runtime's request plane. Old side: the fixed Table-5 budget (8) —
/// `msgs / 8` token round-trips. New side: the **real controller**
/// (`AutoTuner::step`) runs before every round and grows the budget
/// geometrically toward `MAX_OPS_THREAD_CAP` while the backlog exceeds
/// one manager round, so the same burst drains in a fraction of the
/// token grabs. `acquisitions` counts Submit+Done consumer-token
/// acquisitions (deterministic — the counter-verified A/B metric); both
/// sides must drain every message.
pub fn budget_adapt_ab(msgs: u64) -> AbReport {
    fn drill(msgs: u64, adaptive: bool) -> SideReport {
        use crate::coordinator::autotune::AutoTuner;
        use crate::coordinator::ddast::DdastParams;
        use crate::coordinator::messages::MsgBatch;
        use crate::coordinator::pool::{RuntimeKind, RuntimeShared};

        let rt = RuntimeShared::new(RuntimeKind::Ddast, 1, DdastParams::tuned(1), false, 17);
        let root = Arc::clone(&rt.root);
        for i in 0..msgs {
            rt.spawn_from(0, &root, vec![dep_out(1_000_000 + i)], "drill", Box::new(|| {}));
        }
        let tuner = AutoTuner::new(Arc::clone(&rt), Duration::ZERO);
        let mut batch = MsgBatch::new();
        let mut drained = 0u64;
        let t0 = Instant::now();
        while drained < msgs {
            if adaptive {
                tuner.step();
            }
            let budget = rt.tunables().snapshot().max_ops_thread;
            let n = rt.queues.workers[0]
                .drain_batch_with(budget, &mut batch, |b| rt.process_batch(0, b));
            drained += n as u64;
        }
        let wq = &rt.queues.workers[0];
        SideReport {
            acquisitions: wq.submit.acquire_count() + wq.done.acquire_count(),
            elapsed_ns: t0.elapsed().as_nanos() as u64,
            ..SideReport::default()
        }
    }

    AbReport { old: drill(msgs, false), new: drill(msgs, true) }
}

/// Failure-containment overhead drill: the same happy-path workload —
/// `tasks` single-dep tasks over 8 reused regions, spawned and drained by
/// one thread on the Sync organization — with and without a [`FaultPlan`]
/// installed. Both sides pay the *structural* containment costs
/// (`catch_unwind`, watchdog progress stamps, poison checks on finalize);
/// the A/B isolates the *armed-harness* increment: plan deref + per-site
/// rate draw on every wake edge, and the timed-park downgrade an armed
/// `WakeEdge` site forces. The armed site runs at rate 1/65536 so the
/// decision stream is actually drawn, while an injection on the
/// single-threaded Sync side is semantically a no-op (nobody is parked) —
/// the workload stays identical by construction. `acquisitions` records
/// tasks executed (completing all of them on both sides is the check);
/// `elapsed_ns` is the makespan.
pub fn fault_overhead_ab(tasks: u64) -> AbReport {
    fn drill(tasks: u64, plan: Option<Arc<FaultPlan>>) -> SideReport {
        use crate::coordinator::ddast::DdastParams;
        use crate::coordinator::pool::{RuntimeKind, RuntimeShared};

        let rt = RuntimeShared::new_with_options(
            RuntimeKind::Sync,
            1,
            DdastParams::tuned(1),
            false,
            23,
            false,
            plan,
            None,
        );
        let root = Arc::clone(&rt.root);
        let t0 = Instant::now();
        for i in 0..tasks {
            rt.spawn_from(0, &root, vec![dep_out(1_000 + i % 8)], "drill", Box::new(|| {}));
        }
        rt.taskwait_on(0, &root);
        SideReport {
            acquisitions: rt.stats.tasks_executed.get(),
            elapsed_ns: t0.elapsed().as_nanos() as u64,
            ..SideReport::default()
        }
    }

    let armed = Arc::new(FaultPlan::new(0xFA11).with_rate(FaultSite::WakeEdge, 1));
    AbReport { old: drill(tasks, None), new: drill(tasks, Some(armed)) }
}

/// Record-once-replay-N drill: the same iterated submission stream — 8
/// independent inout chains of 8 tasks (64 tasks/iteration) on the Ddast
/// organization — run `iters` times fully resolved vs recorded once and
/// replayed `iters` times through the frozen
/// [`GraphRecording`](crate::coordinator::GraphRecording). The counters
/// make the claim exact rather than statistical: the resolved side pays at
/// least one dependence-shard acquisition per submit plus a Submit and a
/// Done message per task per iteration; the replayed side's deltas across
/// the measured loop are asserted to be *zero* shard acquisitions and zero
/// graph submits, with manager-message totals frozen at the single
/// recorded iteration's. `acquisitions` reports the dependence-shard
/// acquisition delta across the measured iterations; `elapsed_ns` the
/// makespan of those iterations.
pub fn replay_ab(threads: usize, iters: u64) -> AbReport {
    use crate::coordinator::api::TaskSystem;
    use crate::coordinator::dep::dep_inout;
    use crate::coordinator::pool::RuntimeKind;
    use crate::coordinator::replay::{ReplayOutcome, ReplayTask};

    const CHAINS: u64 = 8;
    const LEN: u64 = 8;
    const TASKS: u64 = CHAINS * LEN;

    // One iteration's submission stream: round-robin across the chains so
    // consecutive stream positions hit different regions (the resolved
    // side's shard traffic is spread, not pathological).
    fn mk_tasks() -> Vec<ReplayTask> {
        (0..LEN)
            .flat_map(|_| 0..CHAINS)
            .map(|c| ReplayTask::new(vec![dep_inout(7_000_000 + c)], "replay-drill", || {}))
            .collect()
    }

    // Old side: resolve every iteration through the dependence domain.
    let old = {
        let ts = TaskSystem::builder()
            .kind(RuntimeKind::Ddast)
            .num_threads(threads)
            .seed(31)
            .build();
        let rt = Arc::clone(ts.runtime());
        let t0 = Instant::now();
        for _ in 0..iters {
            let rec = ts.record_iteration(mk_tasks());
            assert!(rec.is_none(), "recording must stay off on the resolved side");
        }
        let elapsed_ns = t0.elapsed().as_nanos() as u64;
        let domain =
            rt.root.child_domain_opt().expect("resolved iterations create the root domain");
        let acq = domain.lock_stats().0;
        assert_eq!(rt.stats.graph_submits.get(), TASKS * iters, "every task resolved");
        assert!(acq >= TASKS * iters, "at least one shard acquisition per submit");
        ts.shutdown();
        assert_eq!(rt.stats.mgr_msgs.get(), 2 * TASKS * iters, "Submit + Done per task");
        SideReport { acquisitions: acq, elapsed_ns, ..SideReport::default() }
    };

    // New side: record iteration 0, replay the measured `iters`.
    let new = {
        let ts = TaskSystem::builder()
            .kind(RuntimeKind::Ddast)
            .num_threads(threads)
            .seed(31)
            .record_graphs(true)
            .build();
        let rt = Arc::clone(ts.runtime());
        let rec = ts.record_iteration(mk_tasks()).expect("record_graphs captures iteration 0");
        let domain =
            rt.root.child_domain_opt().expect("the recorded iteration resolves normally");
        let acq0 = domain.lock_stats().0;
        let submits0 = rt.stats.graph_submits.get();
        let t0 = Instant::now();
        for _ in 0..iters {
            assert_eq!(ts.replay(&rec, mk_tasks()), ReplayOutcome::Replayed);
        }
        let elapsed_ns = t0.elapsed().as_nanos() as u64;
        let acq_delta = domain.lock_stats().0 - acq0;
        assert_eq!(acq_delta, 0, "replay must never touch a dependence shard");
        assert_eq!(
            rt.stats.graph_submits.get(),
            submits0,
            "replay must never submit to the graph"
        );
        assert_eq!(rt.stats.replay_hits.get(), iters, "every measured iteration replayed");
        ts.shutdown();
        assert_eq!(rt.stats.tasks_executed.get(), TASKS * (iters + 1), "no task lost");
        assert_eq!(
            rt.stats.mgr_msgs.get(),
            2 * TASKS,
            "only the recorded iteration pays manager messages"
        );
        SideReport { acquisitions: acq_delta, elapsed_ns, ..SideReport::default() }
    };

    AbReport { old, new }
}

/// The staged pathology-detector drill (counter-verified, not timed): one
/// runtime per scenario so the sticky gauges isolate, each scenario's
/// event stream written directly into that runtime's trace rings (the
/// drill thread is the sole writer — exactly the rings' single-writer
/// contract) and folded through the real [`PathologyDetector`] scan.
#[derive(Clone, Copy, Debug, Default)]
pub struct PathologyReport {
    /// Events per evaluated window in the staged scenarios.
    pub window_events: usize,
    /// Windows evaluated across the four armed scenarios.
    pub windows: u64,
    /// `pathology_idle_spin` after the idle-spin scenario — the scenario
    /// asserts inline that *only* this flag moved on its runtime.
    pub idle_spin: u64,
    /// `pathology_serialized_drain` after the serialized-drain scenario.
    pub serialized_drain: u64,
    /// `pathology_starvation` after the starvation scenario.
    pub starvation: u64,
    /// Sum of all three gauges after the healthy scenario (must stay 0).
    pub healthy_flags: u64,
    /// `pathology_windows` after replaying the idle-spin stream against a
    /// *disarmed* runtime (must stay 0 — the zero-added-atomics counter
    /// proof: no scan ran, no window was judged, no gauge moved).
    pub disarmed_windows: u64,
    /// `MIN_READY_TASKS` staircase under the starvation feedback: the
    /// Table-5 baseline, the peak after two starvation deltas, and where
    /// clean controller periods settle it (back at the baseline).
    pub min_ready_baseline: u64,
    pub min_ready_peak: u64,
    pub min_ready_settled: u64,
}

/// Run the staged pathology scenarios against the streaming detector.
/// Every claim in the report is asserted inline (exclusive flags, zero
/// healthy/disarmed flags, the `MIN_READY_TASKS` staircase), so the drill
/// doubles as the acceptance check wherever it runs.
pub fn pathology_ab() -> PathologyReport {
    use crate::coordinator::autotune::AutoTuner;
    use crate::coordinator::ddast::DdastParams;
    use crate::coordinator::pathology::{
        PathologyConfig, LABEL_MGR_DRAINED, LABEL_MGR_EMPTY, LABEL_PARK,
    };
    use crate::coordinator::pool::{RuntimeKind, RuntimeShared};
    use crate::coordinator::trace::ThreadState;

    const WINDOW: usize = 32;
    const RINGS: usize = 4;

    fn armed_rt(seed: u64) -> Arc<RuntimeShared> {
        let rt =
            RuntimeShared::new(RuntimeKind::Ddast, RINGS, DdastParams::tuned(RINGS), true, seed);
        assert!(
            rt.arm_pathology_with(PathologyConfig::with_window(WINDOW)),
            "tracing is on, so arming succeeds"
        );
        rt
    }
    fn flags(rt: &RuntimeShared) -> (u64, u64, u64) {
        (
            rt.stats.pathology_idle_spin.get(),
            rt.stats.pathology_serialized_drain.get(),
            rt.stats.pathology_starvation.get(),
        )
    }

    let mut windows = 0u64;

    // (a) Idle-spin at a sync point: two consecutive windows of park
    // commits while a message sits pending (staged straight into the
    // request plane — no trace noise).
    let idle_spin = {
        let rt = armed_rt(41);
        rt.queues.push_submit(0, mk_task(900_001));
        let tr = rt.tracer.as_ref().expect("tracing on");
        for _ in 0..2 * WINDOW {
            tr.record(
                0,
                TraceKind::State { worker: 0, state: ThreadState::Idle, label: LABEL_PARK },
            );
        }
        assert!(rt.pathology_tick(), "the second staged window completes the streak");
        let f = flags(&rt);
        assert!(f.0 >= 1, "idle-spin must trip its own flag");
        assert_eq!((f.1, f.2), (0, 0), "…and only its own flag");
        windows += rt.stats.pathology_windows.get();
        f.0
    };

    // (b) Serialized drains: ring 0 owns every productive manager exit
    // while rings 1 and 2 leave empty-handed, messages pending throughout.
    // Each pass stages exactly one window and scans it.
    let serialized_drain = {
        let rt = armed_rt(43);
        rt.queues.push_submit(0, mk_task(900_002));
        let tr = rt.tracer.as_ref().expect("tracing on");
        for _ in 0..2 {
            for _ in 0..16 {
                tr.record(
                    0,
                    TraceKind::State {
                        worker: 0,
                        state: ThreadState::Idle,
                        label: LABEL_MGR_DRAINED,
                    },
                );
            }
            for r in [1usize, 2] {
                for _ in 0..8 {
                    tr.record(
                        r,
                        TraceKind::State {
                            worker: r,
                            state: ThreadState::Idle,
                            label: LABEL_MGR_EMPTY,
                        },
                    );
                }
            }
            rt.pathology_tick();
        }
        let f = flags(&rt);
        assert!(f.1 >= 1, "serialized-drain must trip its own flag");
        assert_eq!((f.0, f.2), (0, 0), "…and only its own flag");
        windows += rt.stats.pathology_windows.get();
        f.1
    };

    // (c) Creator starvation, closing the loop through the real
    // controller: ring 0 pushes 16 ready tasks per window, 12 start on
    // ring 1 (stolen), only 3 start at home — then `AutoTuner::step`
    // consumes the gauge deltas and walks `MIN_READY_TASKS` up, and clean
    // periods walk it back down to the Table-5 baseline.
    let (starvation, min_ready_baseline, min_ready_peak, min_ready_settled) = {
        let rt = armed_rt(47);
        let tuner = AutoTuner::new(Arc::clone(&rt), Duration::ZERO);
        let baseline = rt.tunables().snapshot().min_ready_tasks;
        let tr = rt.tracer.as_ref().expect("tracing on");
        let mut id = 1u64;
        let mut stage = |n_windows: usize| {
            for _ in 0..n_windows {
                let base = id;
                for _ in 0..16 {
                    tr.record(0, TraceKind::ReadyPush { worker: 0, id });
                    id += 1;
                }
                for k in 0..12 {
                    tr.record(
                        1,
                        TraceKind::TaskStart { worker: 1, id: base + k, label: "stolen" },
                    );
                }
                for k in 12..15 {
                    tr.record(0, TraceKind::TaskStart { worker: 0, id: base + k, label: "own" });
                }
                tr.record(0, TraceKind::InGraph(0)); // filler: the 32nd event
                rt.pathology_tick();
            }
        };
        stage(2); // streak of two -> gauge moves
        tuner.step();
        let after_first = rt.tunables().snapshot().min_ready_tasks;
        stage(2); // streak continues -> fresh deltas
        tuner.step();
        let peak = rt.tunables().snapshot().min_ready_tasks;
        tuner.step(); // clean period -> decay
        tuner.step(); // clean period -> decay to baseline
        let settled = rt.tunables().snapshot().min_ready_tasks;
        assert!(
            after_first > baseline && peak > after_first,
            "starvation deltas must grow MIN_READY_TASKS: {baseline} -> {after_first} -> {peak}"
        );
        assert_eq!(settled, baseline, "clean periods decay back to the Table-5 baseline");
        assert_eq!(tuner.ready_raises.get(), 2, "one raise per starvation delta");
        assert_eq!(tuner.ready_decays.get(), 2, "one decay per clean period");
        let f = flags(&rt);
        assert!(f.2 >= 1, "starvation must trip its own flag");
        assert_eq!((f.0, f.1), (0, 0), "…and only its own flag");
        let d = rt.pathology().expect("armed");
        assert!(d.ready_wait().count() >= 15, "push->start joins fill the ready-wait histogram");
        windows += rt.stats.pathology_windows.get();
        (f.2, baseline, peak, settled)
    };

    // (d) Healthy stream: every ring pushes a little and starts its own
    // work — judged windows, zero flags (the false-positive guard).
    let healthy_flags = {
        let rt = armed_rt(53);
        let tr = rt.tracer.as_ref().expect("tracing on");
        let mut id = 10_000u64;
        for _ in 0..2 {
            for r in 0..RINGS {
                for _ in 0..4 {
                    tr.record(r, TraceKind::ReadyPush { worker: r, id });
                    tr.record(r, TraceKind::TaskStart { worker: r, id, label: "own" });
                    id += 1;
                }
            }
        }
        rt.pathology_tick();
        assert!(rt.stats.pathology_windows.get() >= 2, "the healthy stream was judged");
        let f = flags(&rt);
        assert_eq!(f, (0, 0, 0), "a healthy stream must not trip any flag");
        windows += rt.stats.pathology_windows.get();
        f.0 + f.1 + f.2
    };

    // (e) Disarmed control: the same idle-spin stream against a runtime
    // that never armed the detector. The tick is a single `OnceLock` load;
    // the counter deltas — zero windows judged, zero gauges moved — are
    // the zero-added-atomics proof on the non-detecting path.
    let disarmed_windows = {
        let rt =
            RuntimeShared::new(RuntimeKind::Ddast, RINGS, DdastParams::tuned(RINGS), true, 59);
        let tr = rt.tracer.as_ref().expect("tracing on");
        for _ in 0..2 * WINDOW {
            tr.record(
                0,
                TraceKind::State { worker: 0, state: ThreadState::Idle, label: LABEL_PARK },
            );
        }
        assert!(!rt.pathology_tick(), "disarmed tick must be a no-op");
        assert_eq!(flags(&rt), (0, 0, 0));
        assert_eq!(rt.stats.pathology_windows.get(), 0, "disarmed: nothing scanned");
        rt.stats.pathology_windows.get()
    };

    PathologyReport {
        window_events: WINDOW,
        windows,
        idle_spin,
        serialized_drain,
        starvation,
        healthy_flags,
        disarmed_windows,
        min_ready_baseline,
        min_ready_peak,
        min_ready_settled,
    }
}

/// The topology A/B at one machine shape (sockets × workers-per-socket):
/// the three tentpole claims of the topology plane, each counter-verified
/// against the *same* structures configured flat (the pre-topology
/// layout).
#[derive(Clone, Copy, Debug, Default)]
pub struct TopologyReport {
    pub sockets: usize,
    pub workers: usize,
    pub rounds: u64,
    /// Directory sweep: `acquisitions` = worker words loaded past the
    /// summary gate per claiming drain (flat vs two-level) — the claim is
    /// that a two-level scan touches only dirty-socket words.
    pub sweep: AbReport,
    /// Steal victim order: `acquisitions` = steals in the all-local
    /// window, `contended` = steals that crossed a socket while same-
    /// socket work existed (uniform-random vs socket-ordered scan).
    pub steal: AbReport,
    /// Wake targeting: `acquisitions` = wake rounds, `contended` = wakes
    /// that landed on a worker other than the registered waiter
    /// (directory broadcast vs dependence-targeted edge).
    pub dep_wake: AbReport,
}

/// Two-level-directory sweep drill: every round raises a fixed burst of
/// workers in the **last socket** and fully drains the directory with a
/// claiming scan. Deterministic and single-threaded, so the word-visit
/// counters are exact: the flat layout pays visits across the whole
/// single-socket word range every drain, the two-level layout only loads
/// the dirty socket's words (± one split-start visit when the rotor lands
/// inside it).
fn topology_sweep_side(
    sockets: usize,
    workers_per_socket: usize,
    rounds: u64,
    two_level: bool,
) -> SideReport {
    let workers = sockets * workers_per_socket;
    let topo =
        if two_level { Topology::new(sockets, workers_per_socket) } else { Topology::flat(workers) };
    let dir = SignalDirectory::new_with_topology(workers, topo);
    let dirty_base = (sockets - 1) * workers_per_socket;
    let burst = 3usize.min(workers_per_socket);
    let mut claimed = 0u64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        for k in 0..burst {
            dir.raise(dirty_base + k);
        }
        claimed += dir.scan_rotor().count() as u64;
    }
    assert_eq!(claimed, rounds * burst as u64, "every raise claimed exactly once");
    SideReport {
        acquisitions: dir.word_visits(),
        elapsed_ns: t0.elapsed().as_nanos() as u64,
        ..SideReport::default()
    }
}

/// Socket-ordered steal drill: worker 0 (socket 0) steals with every other
/// worker's deque pre-filled. The victim is recovered from the stolen
/// task's id, so locality is scored identically on both sides — against
/// the *shape*, regardless of what the pools were configured with. The
/// measured window is the first `(workers_per_socket - 1) × per_victim`
/// steals, during which socket-local work exists by construction.
/// Returns `(window_steals, remote_in_window, total_stolen)`.
fn topology_steal_side(
    sockets: usize,
    workers_per_socket: usize,
    per_victim: u64,
    two_level: bool,
) -> (u64, u64, u64) {
    let workers = sockets * workers_per_socket;
    let shape = Topology::new(sockets, workers_per_socket);
    let topo = if two_level { shape } else { Topology::flat(workers) };
    let pools = ReadyPools::new_with_topology(workers, 11, topo);
    for v in 1..workers {
        for i in 0..per_victim {
            pools.push(v, mk_task(((v as u64) << 32) | (i + 1)));
        }
    }
    let window = (workers_per_socket as u64 - 1) * per_victim;
    let (mut taken, mut remote_in_window) = (0u64, 0u64);
    while let Some(wd) = pools.get(0) {
        let victim = (wd.id.0 >> 32) as usize;
        if taken < window && shape.socket_of(victim) != 0 {
            remote_in_window += 1;
        }
        taken += 1;
    }
    assert_eq!(taken, (workers as u64 - 1) * per_victim, "no task stranded");
    if two_level {
        // Cross-check the pools' own locality counters against the
        // id-derived scoring: a socket-ordered scan crosses sockets only
        // after its local round came up dry.
        let (local, remote) = pools.steal_locality();
        assert_eq!(local + remote, taken, "every steal classified");
        assert_eq!(remote, (workers as u64 - workers_per_socket as u64) * per_victim);
    }
    (window, remote_in_window, taken)
}

/// Dependence-targeted wake drill: one waiter slot (socket 0) and one
/// parked decoy per remote socket. Old side: the pre-topology path — the
/// finisher broadcasts one `wake_parked` into the directory, landing on
/// whichever parked bit the rotating scan meets first. New side: the
/// waiter registers on the predecessor `Wd` and the finisher claims the
/// registration and wakes *that* worker. A round is a mistarget when the
/// wake landed on a decoy while the real waiter stayed parked.
fn topology_dep_wake_side(
    sockets: usize,
    workers_per_socket: usize,
    rounds: u64,
    targeted: bool,
) -> SideReport {
    let workers = sockets * workers_per_socket;
    let dir =
        SignalDirectory::new_with_topology(workers, Topology::new(sockets, workers_per_socket));
    let pred = mk_task(1);
    let target = 2usize.min(workers_per_socket - 1); // socket 0
    let decoys: Vec<usize> = (1..sockets).map(|s| s * workers_per_socket + 1).collect();
    let mut mistargets = 0u64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        for &d in &decoys {
            assert!(dir.begin_park(d));
        }
        assert!(dir.begin_park(target));
        if targeted {
            let token = pred.register_waiter(target).expect("slot starts empty");
            let w = pred.take_waiter().expect("finisher claims the registration");
            assert!(dir.wake_worker(w), "the registered waiter was parked");
            assert!(!pred.clear_waiter(token), "claimed token is dead");
        } else {
            assert_eq!(dir.wake_parked(1), 1, "one parked slot woken");
        }
        // Scoring: if the target's bit is still set, the wake landed on a
        // decoy. `begin_park` doubles as the probe (false = still parked).
        if !dir.begin_park(target) {
            mistargets += 1;
        }
        dir.cancel_park(target);
        for &d in &decoys {
            dir.cancel_park(d);
        }
    }
    SideReport {
        acquisitions: rounds,
        contended: mistargets,
        elapsed_ns: t0.elapsed().as_nanos() as u64,
        ..SideReport::default()
    }
}

/// Run the full topology A/B at one shape. All three drills are
/// deterministic (single-threaded, counter-verified) so the report is a
/// proof artifact, not a timing sample.
pub fn topology_ab(sockets: usize, workers_per_socket: usize, rounds: u64) -> TopologyReport {
    assert!(sockets >= 2, "the A/B needs a remote socket");
    assert!(workers_per_socket >= 2);
    let workers = sockets * workers_per_socket;

    let sweep = AbReport {
        old: topology_sweep_side(sockets, workers_per_socket, rounds, false),
        new: topology_sweep_side(sockets, workers_per_socket, rounds, true),
    };

    let per_victim = 4u64;
    let steal = {
        let mk = |(window, remote, total): (u64, u64, u64), elapsed_ns| SideReport {
            acquisitions: window,
            contended: remote,
            cas_attempts: total,
            elapsed_ns,
            ..SideReport::default()
        };
        let t0 = Instant::now();
        let old = topology_steal_side(sockets, workers_per_socket, per_victim, false);
        let old_ns = t0.elapsed().as_nanos() as u64;
        let t0 = Instant::now();
        let new = topology_steal_side(sockets, workers_per_socket, per_victim, true);
        let new_ns = t0.elapsed().as_nanos() as u64;
        AbReport { old: mk(old, old_ns), new: mk(new, new_ns) }
    };

    let dep_wake = AbReport {
        old: topology_dep_wake_side(sockets, workers_per_socket, rounds, false),
        new: topology_dep_wake_side(sockets, workers_per_socket, rounds, true),
    };

    TopologyReport { sockets, workers, rounds, sweep, steal, dep_wake }
}

/// Drain one worker's queue pair (both sweep variants must do identical
/// per-worker work or the A/B acquisition counts stop being comparable).
fn drain_pair(qs: &QueueSystem, worker: usize) -> u64 {
    let wq = &qs.workers[worker];
    let mut processed = 0u64;
    if let Some(mut g) = wq.submit.try_acquire() {
        while g.pop().is_some() {
            qs.message_processed();
            processed += 1;
        }
    }
    if let Some(mut g) = wq.done.try_acquire() {
        while g.pop().is_some() {
            qs.message_processed();
            processed += 1;
        }
    }
    processed
}

/// One old-style manager round: try-acquire **every** worker's queue pair
/// (the pre-refactor DDAST sweep, Listing 2 lines 5–6 over all threads).
fn sweep_all(qs: &QueueSystem) -> u64 {
    (0..qs.num_workers()).map(|w| drain_pair(qs, w)).sum()
}

/// One directory-driven manager round: claim and drain only raised workers.
fn sweep_signaled(qs: &QueueSystem) -> u64 {
    qs.signals().scan_rotor().map(|w| drain_pair(qs, w)).sum()
}

/// Sparse-traffic sweep drill: `workers` queue-pair slots, but only two
/// slots ever produce (alternating, a burst every fourth round) — the
/// "one worker is producing, the manager still sweeps everyone" pathology.
/// Deterministic single-thread interleaving so the acquisition counts are
/// exact: old side = `2 * workers` token grabs per round regardless of
/// traffic; new side = grabs only on claimed (dirty) workers.
pub fn signal_sweep_ab(workers: usize, rounds: u64) -> AbReport {
    fn run(workers: usize, rounds: u64, new_side: bool) -> (SideReport, u64) {
        let qs = QueueSystem::new(workers);
        let t0 = Instant::now();
        let mut processed = 0u64;
        for r in 0..rounds {
            if r % 4 == 0 {
                let producer = (((r / 4) as usize) % 2).min(workers - 1);
                for b in 0..3u64 {
                    qs.push_submit(producer, mk_task(r * 8 + b + 1));
                }
            }
            processed += if new_side { sweep_signaled(&qs) } else { sweep_all(&qs) };
        }
        let acq: u64 = qs
            .workers
            .iter()
            .map(|wq| wq.submit.acquire_count() + wq.done.acquire_count())
            .sum();
        let report = SideReport {
            acquisitions: acq,
            elapsed_ns: t0.elapsed().as_nanos() as u64,
            ..SideReport::default()
        };
        (report, processed)
    }

    let (old, old_processed) = run(workers, rounds, false);
    let (new, new_processed) = run(workers, rounds, true);
    assert_eq!(old_processed, new_processed, "both sweeps drain the same traffic");
    AbReport { old, new }
}

/// Run the sparse-traffic sweep A/B at one simulated worker count.
pub fn run_sweep(workers: usize, rounds: u64) -> SweepReport {
    SweepReport { workers, rounds, ab: signal_sweep_ab(workers, rounds) }
}

/// Run all per-thread-count A/Bs.
pub fn run_ab(threads: usize, ops_per_thread: u64) -> ContentionReport {
    ContentionReport {
        threads,
        ops_per_thread,
        ready_pools: ready_pools_ab(threads, ops_per_thread),
        dep_domain: dep_domain_ab(threads, ops_per_thread),
        dispatcher_poll: dispatcher_poll_ab(threads, ops_per_thread),
        trace_append: trace_append_ab(threads, ops_per_thread),
        batch_submit: batch_submit_ab(threads, ops_per_thread),
    }
}

fn side_json(s: &SideReport) -> String {
    format!(
        "{{\"acquisitions\": {}, \"contended\": {}, \"spin_iters\": {}, \
         \"cas_attempts\": {}, \"cas_retries\": {}, \"contended_events\": {}, \
         \"elapsed_ns\": {}}}",
        s.acquisitions,
        s.contended,
        s.spin_iters,
        s.cas_attempts,
        s.cas_retries,
        s.contended_events(),
        s.elapsed_ns
    )
}

fn ab_json(ab: &AbReport) -> String {
    let red = ab.reduction();
    let red = if red.is_finite() { format!("{red:.2}") } else { "null".to_string() };
    format!(
        "{{\"old\": {}, \"new\": {}, \"contended_reduction\": {}}}",
        side_json(&ab.old),
        side_json(&ab.new),
        red
    )
}

/// Serialize one report (hand-rolled: the offline environment has no
/// serde). Delegates to the same serializer the suite uses, so the two can
/// never drift. `contended_reduction` is `null` when the new side recorded
/// zero contended events (an infinite improvement).
pub fn to_json(r: &ContentionReport, generated_by: &str) -> String {
    format!(
        "{{\n  \"generated_by\": \"{}\",\n  \"report\": {}\n}}\n",
        generated_by,
        report_json_inline(r)
    )
}

fn report_json_inline(r: &ContentionReport) -> String {
    format!(
        "{{\"threads\": {}, \"ops_per_thread\": {}, \"ready_pools\": {}, \
         \"dep_domain\": {}, \"dispatcher_poll\": {}, \"trace_append\": {}, \
         \"batch_submit\": {}}}",
        r.threads,
        r.ops_per_thread,
        ab_json(&r.ready_pools),
        ab_json(&r.dep_domain),
        ab_json(&r.dispatcher_poll),
        ab_json(&r.trace_append),
        ab_json(&r.batch_submit)
    )
}

fn sweep_json_inline(s: &SweepReport) -> String {
    format!(
        "{{\"workers\": {}, \"rounds\": {}, \"ab\": {}}}",
        s.workers,
        s.rounds,
        ab_json(&s.ab)
    )
}

fn ingress_json_inline(i: &crate::bench_harness::ingress::IngressReport) -> String {
    format!(
        "{{\"threads\": {}, \"clients\": {}, \"tasks_per_client\": {}, \
         \"submitted\": {}, \"completed\": {}, \"busy\": {}, \
         \"throughput_per_sec\": {:.1}, \"p50_ns\": {}, \"p95_ns\": {}, \
         \"p99_ns\": {}, \"ab\": {}}}",
        i.threads,
        i.clients,
        i.tasks_per_client,
        i.submitted,
        i.completed,
        i.busy,
        i.throughput_per_sec,
        i.p50_ns,
        i.p95_ns,
        i.p99_ns,
        ab_json(&i.ab)
    )
}

fn topology_json_inline(t: &TopologyReport) -> String {
    format!(
        "{{\"sockets\": {}, \"workers\": {}, \"rounds\": {}, \"sweep\": {}, \
         \"steal\": {}, \"dep_wake\": {}}}",
        t.sockets,
        t.workers,
        t.rounds,
        ab_json(&t.sweep),
        ab_json(&t.steal),
        ab_json(&t.dep_wake)
    )
}

fn pathology_json_inline(p: &PathologyReport) -> String {
    format!(
        "{{\"window_events\": {}, \"windows\": {}, \"idle_spin\": {}, \
         \"serialized_drain\": {}, \"starvation\": {}, \"healthy_flags\": {}, \
         \"disarmed_windows\": {}, \"min_ready_baseline\": {}, \
         \"min_ready_peak\": {}, \"min_ready_settled\": {}}}",
        p.window_events,
        p.windows,
        p.idle_spin,
        p.serialized_drain,
        p.starvation,
        p.healthy_flags,
        p.disarmed_windows,
        p.min_ready_baseline,
        p.min_ready_peak,
        p.min_ready_settled
    )
}

/// Serialize the full suite: per-thread-count reports (each carrying the
/// `batch_submit` drill), the sparse-traffic sweep series, the
/// park-vs-sleep wake-latency pair, the taskwait-wake pair, the
/// adaptive-batch-budget pair, the failure-containment overhead pair, the
/// record/replay pair, the serve-scale ingress soak, the per-shape
/// topology series and the staged pathology-detector report — the shape
/// `BENCH_contention.json` carries.
#[allow(clippy::too_many_arguments)]
pub fn suite_to_json(
    reports: &[ContentionReport],
    sweeps: &[SweepReport],
    park_wake: &AbReport,
    taskwait_park: &AbReport,
    budget_adapt: &AbReport,
    fault_overhead: &AbReport,
    replay: &AbReport,
    ingress: &crate::bench_harness::ingress::IngressReport,
    topology: &[TopologyReport],
    pathology: &PathologyReport,
    generated_by: &str,
) -> String {
    let reports_json: Vec<String> =
        reports.iter().map(|r| format!("    {}", report_json_inline(r))).collect();
    let sweeps_json: Vec<String> =
        sweeps.iter().map(|s| format!("    {}", sweep_json_inline(s))).collect();
    let topology_json: Vec<String> =
        topology.iter().map(|t| format!("    {}", topology_json_inline(t))).collect();
    format!(
        "{{\n  \"generated_by\": \"{}\",\n  \"reports\": [\n{}\n  ],\n  \
         \"signal_sweep\": [\n{}\n  ],\n  \"park_wake\": {},\n  \
         \"taskwait_park\": {},\n  \"budget_adapt\": {},\n  \
         \"fault_overhead\": {},\n  \"replay\": {},\n  \"ingress\": {},\n  \
         \"topology\": [\n{}\n  ],\n  \"pathology\": {}\n}}\n",
        generated_by,
        reports_json.join(",\n"),
        sweeps_json.join(",\n"),
        ab_json(park_wake),
        ab_json(taskwait_park),
        ab_json(budget_adapt),
        ab_json(fault_overhead),
        ab_json(replay),
        ingress_json_inline(ingress),
        topology_json.join(",\n"),
        pathology_json_inline(pathology)
    )
}

/// Human-readable table for the bench output.
pub fn render(r: &ContentionReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Contention A/B — {} threads, {} ops/producer (contended = spins, retries = lost CAS)\n",
        r.threads, r.ops_per_thread
    ));
    out.push_str(&format!(
        "{:<22}{:>14}{:>12}{:>12}{:>12}{:>12}\n",
        "structure", "acquisitions", "contended", "cas-retry", "events", "ms"
    ));
    for (name, s) in [
        ("ready: locked (seed)", &r.ready_pools.old),
        ("ready: ws-deque", &r.ready_pools.new),
        ("domain: 1 stripe", &r.dep_domain.old),
        ("domain: striped", &r.dep_domain.new),
        ("dispatch: locked", &r.dispatcher_poll.old),
        ("dispatch: rcu", &r.dispatcher_poll.new),
        ("trace: mutexed", &r.trace_append.old),
        ("trace: ring", &r.trace_append.new),
        ("submit: per-message", &r.batch_submit.old),
        ("submit: per-batch", &r.batch_submit.new),
    ] {
        out.push_str(&format!(
            "{:<22}{:>14}{:>12}{:>12}{:>12}{:>12.2}\n",
            name,
            s.acquisitions,
            s.contended,
            s.cas_retries,
            s.contended_events(),
            s.elapsed_ns as f64 / 1e6
        ));
    }
    out.push_str(&format!(
        "reduction in contended events: ready-pools {}, dep-domain {}\n",
        fmt_reduction(r.ready_pools.reduction()),
        fmt_reduction(r.dep_domain.reduction())
    ));
    out.push_str(&format!(
        "shard acquisitions per message: per-message {:.2}, per-batch {:.2} ({:.1}x fewer)\n",
        r.batch_submit.old.acquisitions as f64
            / (r.threads as u64 * r.ops_per_thread).max(1) as f64,
        r.batch_submit.new.acquisitions as f64
            / (r.threads as u64 * r.ops_per_thread).max(1) as f64,
        r.batch_submit.old.acquisitions as f64 / r.batch_submit.new.acquisitions.max(1) as f64
    ));
    out
}

/// Human-readable line for the park-vs-sleep wake drill.
pub fn render_park_wake(ab: &AbReport) -> String {
    let rounds = ab.old.acquisitions.max(1);
    format!(
        "park wake — {} round trips: blind 100µs sleep {:.2} ms ({:.1} µs/wake) vs \
         directory park {:.2} ms ({:.1} µs/wake)\n",
        rounds,
        ab.old.elapsed_ns as f64 / 1e6,
        ab.old.elapsed_ns as f64 / rounds as f64 / 1e3,
        ab.new.elapsed_ns as f64 / 1e6,
        ab.new.elapsed_ns as f64 / rounds as f64 / 1e3
    )
}

/// Human-readable line for the taskwait-wake drill.
pub fn render_taskwait_park(ab: &AbReport) -> String {
    let rounds = ab.old.acquisitions.max(1);
    format!(
        "taskwait wake — {} child-completion round trips: spin/sleep ladder {:.2} ms \
         ({:.1} µs/wake) vs wake-edge park {:.2} ms ({:.1} µs/wake)\n",
        rounds,
        ab.old.elapsed_ns as f64 / 1e6,
        ab.old.elapsed_ns as f64 / rounds as f64 / 1e3,
        ab.new.elapsed_ns as f64 / 1e6,
        ab.new.elapsed_ns as f64 / rounds as f64 / 1e3
    )
}

/// Human-readable line for the adaptive-budget drill.
pub fn render_budget_adapt(ab: &AbReport) -> String {
    format!(
        "budget adapt — burst drain: fixed MAX_OPS_THREAD {} token grabs vs \
         auto-tuned {} ({:.1}x fewer), {:.2} ms vs {:.2} ms\n",
        ab.old.acquisitions,
        ab.new.acquisitions,
        ab.old.acquisitions as f64 / ab.new.acquisitions.max(1) as f64,
        ab.old.elapsed_ns as f64 / 1e6,
        ab.new.elapsed_ns as f64 / 1e6
    )
}

/// Human-readable line for the containment-overhead drill.
pub fn render_fault_overhead(ab: &AbReport) -> String {
    let tasks = ab.old.acquisitions.max(1);
    format!(
        "fault overhead — {} happy-path tasks: no plan {:.2} ms ({:.0} ns/task) vs \
         armed harness {:.2} ms ({:.0} ns/task)\n",
        tasks,
        ab.old.elapsed_ns as f64 / 1e6,
        ab.old.elapsed_ns as f64 / tasks as f64,
        ab.new.elapsed_ns as f64 / 1e6,
        ab.new.elapsed_ns as f64 / tasks as f64
    )
}

/// Human-readable line for the record/replay drill.
pub fn render_replay(ab: &AbReport) -> String {
    format!(
        "graph replay — resolve-every-iteration: {} shard acquisitions, {:.2} ms vs \
         record-once-replay-N: {} acquisitions, {:.2} ms\n",
        ab.old.acquisitions,
        ab.old.elapsed_ns as f64 / 1e6,
        ab.new.acquisitions,
        ab.new.elapsed_ns as f64 / 1e6
    )
}

/// Human-readable block for the staged pathology drill.
pub fn render_pathology(p: &PathologyReport) -> String {
    format!(
        "pathology — staged {}-event windows ({} judged): idle-spin flag {}, \
         serialized-drain flag {}, starvation flag {}; healthy stream flags {}, \
         disarmed windows {}; MIN_READY_TASKS {} -> {} -> {}\n",
        p.window_events,
        p.windows,
        p.idle_spin,
        p.serialized_drain,
        p.starvation,
        p.healthy_flags,
        p.disarmed_windows,
        p.min_ready_baseline,
        p.min_ready_peak,
        p.min_ready_settled
    )
}

fn fmt_reduction(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.1}x")
    } else {
        "inf (new side uncontended)".to_string()
    }
}

/// Human-readable block for one topology A/B shape.
pub fn render_topology(t: &TopologyReport) -> String {
    format!(
        "topology — {}x{} ({} workers), {} rounds:\n  \
         sweep word visits: flat {} vs two-level {} ({:.1}x fewer)\n  \
         cross-socket steals in the all-local window: uniform {}/{} vs \
         socket-ordered {}/{}\n  \
         wake mistargets: broadcast {}/{} vs dependence-targeted {}/{}\n",
        t.sockets,
        t.workers / t.sockets.max(1),
        t.workers,
        t.rounds,
        t.sweep.old.acquisitions,
        t.sweep.new.acquisitions,
        t.sweep.old.acquisitions as f64 / t.sweep.new.acquisitions.max(1) as f64,
        t.steal.old.contended,
        t.steal.old.acquisitions,
        t.steal.new.contended,
        t.steal.new.acquisitions,
        t.dep_wake.old.contended,
        t.dep_wake.old.acquisitions,
        t.dep_wake.new.contended,
        t.dep_wake.new.acquisitions
    )
}

/// Human-readable line for one sweep A/B.
pub fn render_sweep(s: &SweepReport) -> String {
    format!(
        "signal sweep — {:>4} simulated workers, {} rounds: queue-token grabs \
         old {} vs new {} ({:.1}x fewer), {:.2} ms vs {:.2} ms\n",
        s.workers,
        s.rounds,
        s.ab.old.acquisitions,
        s.ab.new.acquisitions,
        s.ab.old.acquisitions as f64 / s.ab.new.acquisitions.max(1) as f64,
        s.ab.old.elapsed_ns as f64 / 1e6,
        s.ab.new.elapsed_ns as f64 / 1e6
    )
}

/// Default output path: the repository root, next to EXPERIMENTS.md.
pub fn default_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_contention.json")
}

/// Write the suite to `path` (best-effort; benches must not fail the run
/// over a read-only checkout).
#[allow(clippy::too_many_arguments)]
pub fn write_suite_json(
    path: &std::path::Path,
    reports: &[ContentionReport],
    sweeps: &[SweepReport],
    park_wake: &AbReport,
    taskwait_park: &AbReport,
    budget_adapt: &AbReport,
    fault_overhead: &AbReport,
    replay: &AbReport,
    ingress: &crate::bench_harness::ingress::IngressReport,
    topology: &[TopologyReport],
    pathology: &PathologyReport,
    generated_by: &str,
) -> bool {
    std::fs::write(
        path,
        suite_to_json(
            reports,
            sweeps,
            park_wake,
            taskwait_park,
            budget_adapt,
            fault_overhead,
            replay,
            ingress,
            topology,
            pathology,
            generated_by,
        ),
    )
    .is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ab_runs_and_counts() {
        let r = run_ab(2, 200);
        assert_eq!(r.threads, 2);
        // Every producer push acquired something on both sides.
        assert!(r.ready_pools.old.acquisitions >= 200);
        assert!(r.ready_pools.new.acquisitions + r.ready_pools.new.cas_attempts >= 200);
        assert!(r.dep_domain.old.acquisitions >= 2 * 200 * 2, "submit+finish per op");
        assert!(r.dep_domain.new.acquisitions >= 2 * 200 * 2);
    }

    #[test]
    fn json_shape() {
        let r = run_ab(1, 50);
        let j = to_json(&r, "unit test");
        for key in [
            "\"generated_by\"",
            "\"threads\"",
            "\"ready_pools\"",
            "\"dep_domain\"",
            "\"dispatcher_poll\"",
            "\"trace_append\"",
            "\"batch_submit\"",
            "\"contended_reduction\"",
            "\"cas_retries\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert!(render(&r).contains("reduction in contended events"));
        assert!(render(&r).contains("shard acquisitions per message"));
    }

    #[test]
    fn suite_json_shape() {
        let reports = [run_ab(1, 20), run_ab(2, 20)];
        let sweeps = [run_sweep(8, 40), run_sweep(32, 40)];
        let pw = park_wake_ab(10);
        let tw = taskwait_park_ab(10);
        let ba = budget_adapt_ab(256);
        let fo = fault_overhead_ab(64);
        let rp = replay_ab(2, 3);
        let ing = crate::bench_harness::ingress::ingress_soak(2, 2, 16);
        let topo = [topology_ab(2, 4, 16)];
        let pa = pathology_ab();
        let j = suite_to_json(
            &reports, &sweeps, &pw, &tw, &ba, &fo, &rp, &ing, &topo, &pa, "unit test",
        );
        for key in [
            "\"reports\"",
            "\"signal_sweep\"",
            "\"park_wake\"",
            "\"taskwait_park\"",
            "\"budget_adapt\"",
            "\"fault_overhead\"",
            "\"replay\"",
            "\"ingress\"",
            "\"throughput_per_sec\"",
            "\"p99_ns\"",
            "\"topology\"",
            "\"sockets\": 2",
            "\"dep_wake\"",
            "\"workers\": 32",
            "\"threads\": 2",
            "\"pathology\"",
            "\"min_ready_peak\"",
            "\"disarmed_windows\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert!(render_sweep(&sweeps[0]).contains("simulated workers"));
        assert!(render_park_wake(&pw).contains("round trips"));
        assert!(render_taskwait_park(&tw).contains("child-completion"));
        assert!(render_budget_adapt(&ba).contains("token grabs"));
        assert!(render_fault_overhead(&fo).contains("happy-path tasks"));
        assert!(render_replay(&rp).contains("record-once-replay-N"));
        assert!(render_topology(&topo[0]).contains("wake mistargets"));
        assert!(render_pathology(&pa).contains("MIN_READY_TASKS"));
    }

    #[test]
    fn pathology_drill_counter_verifies_each_flag() {
        // The drill asserts the hard claims inline (exclusive flags, zero
        // healthy/disarmed detections, the MIN_READY_TASKS staircase);
        // this pins the reported shape so the JSON can't drift from the
        // asserted truths.
        let p = pathology_ab();
        assert!(p.idle_spin >= 1 && p.serialized_drain >= 1 && p.starvation >= 1);
        assert_eq!(p.healthy_flags, 0, "healthy stream stays clean");
        assert_eq!(p.disarmed_windows, 0, "disarmed runtime never scans");
        assert!(p.windows >= 8, "every armed scenario judged its windows");
        assert_eq!(p.min_ready_baseline, 4, "Table-5 baseline");
        assert!(p.min_ready_peak > p.min_ready_baseline, "starvation raised the knob");
        assert_eq!(p.min_ready_settled, p.min_ready_baseline, "clean decay settles");
    }

    #[test]
    fn topology_drills_counter_verify_the_claims() {
        // The ISSUE's acceptance shape: 4 sockets × 8 workers. All three
        // drills are deterministic, so these are equalities and hard
        // bounds, not statistical expectations.
        let t = topology_ab(4, 8, 64);
        assert_eq!((t.sockets, t.workers), (4, 32));
        // Sweep: the two-level scan loads at most the dirty socket's words
        // (one per round here) plus at most one split-start extra; the
        // flat layout pays strictly more.
        assert!(
            t.sweep.new.acquisitions <= 2 * t.rounds,
            "two-level sweep must visit only dirty-socket words: {} visits / {} rounds",
            t.sweep.new.acquisitions,
            t.rounds
        );
        // The flat-vs-two-level word-load contrast only exists once the
        // flat layout spans multiple words (> 64 workers): 4 × 64.
        let big = topology_ab(4, 64, 32);
        assert!(big.sweep.new.acquisitions <= 2 * big.rounds);
        assert!(
            big.sweep.old.acquisitions > big.sweep.new.acquisitions,
            "flat sweep must pay more word loads: old={} new={}",
            big.sweep.old.acquisitions,
            big.sweep.new.acquisitions
        );
        // Steal: while same-socket work exists, ≥90% of socket-ordered
        // steals stay local (here: all of them, the scan is exhaustive
        // before crossing); the uniform scan crosses sockets constantly.
        assert!(
            t.steal.new.contended * 10 <= t.steal.new.acquisitions,
            "socket-ordered steals must be ≥90% local in the window: {}/{} remote",
            t.steal.new.contended,
            t.steal.new.acquisitions
        );
        assert!(t.steal.old.contended > t.steal.new.contended);
        // Dependence-targeted wakes always land on the registered waiter;
        // the broadcast side mistargets whenever its rotating scan starts
        // in a decoy's socket (3 of 4 rounds at this shape).
        assert_eq!(t.dep_wake.new.contended, 0, "zero broadcast wakes on the dep path");
        assert!(
            t.dep_wake.old.contended >= t.rounds / 2,
            "broadcast must mistarget the decoys: {}/{}",
            t.dep_wake.old.contended,
            t.rounds
        );
    }

    #[test]
    fn replay_drill_zero_acquisitions() {
        // The drill body already asserts the acceptance counters inline
        // (zero shard acquisitions, zero graph submits, manager messages
        // frozen at the recorded iteration); this pins the reported deltas.
        let iters = 4u64;
        let ab = replay_ab(2, iters);
        assert_eq!(ab.new.acquisitions, 0, "replayed iterations take no shard locks");
        assert!(
            ab.old.acquisitions >= 64 * iters,
            "resolved side pays >= 1 acquisition per task: {}",
            ab.old.acquisitions
        );
    }

    #[test]
    fn fault_overhead_drill_completes_both_sides() {
        // Completing the workload on both sides is the check: an armed
        // harness must not change happy-path semantics, only (maybe) cost.
        let ab = fault_overhead_ab(500);
        assert_eq!(ab.old.acquisitions, 500);
        assert_eq!(ab.new.acquisitions, 500);
        assert!(ab.old.elapsed_ns > 0 && ab.new.elapsed_ns > 0);
    }

    #[test]
    fn taskwait_park_drill_completes_both_sides() {
        // Completion *is* the no-lost-wakeup property: a child-completion
        // wake swallowed while the waiter commits to parking hangs the
        // drill (and times out the suite).
        let ab = taskwait_park_ab(25);
        assert_eq!(ab.old.acquisitions, 25);
        assert_eq!(ab.new.acquisitions, 25);
        assert!(ab.old.elapsed_ns > 0 && ab.new.elapsed_ns > 0);
    }

    #[test]
    fn budget_adapt_drains_with_fewer_token_grabs() {
        // Deterministic counter check: the fixed-budget side pays exactly
        // one Submit + one Done token acquisition per 8-message round; the
        // controller-driven side grows its budget toward the cap and pays
        // at least 4x fewer grabs on a deep burst.
        let msgs = 2_048u64;
        let ab = budget_adapt_ab(msgs);
        assert_eq!(ab.old.acquisitions, 2 * msgs / 8, "fixed budget = msgs/8 rounds");
        assert!(
            ab.new.acquisitions * 4 <= ab.old.acquisitions,
            "adaptive budget must cut token grabs: old={} new={}",
            ab.old.acquisitions,
            ab.new.acquisitions
        );
    }

    #[test]
    fn batch_submit_halves_shard_acquisitions() {
        // Deterministic counter check (the acceptance metric): one
        // acquisition per message on the old side, at most 4 distinct
        // shards per 8-message batch on the new side.
        let ops = 2_000u64;
        for threads in [1usize, 2] {
            let ab = batch_submit_ab(threads, ops);
            let msgs = threads as u64 * ops;
            assert_eq!(ab.old.acquisitions, msgs, "per-message = 1 shard lock per submit");
            assert!(
                ab.new.acquisitions * 2 <= ab.old.acquisitions,
                "per-batch must at least halve shard acquisitions: old={} new={}",
                ab.old.acquisitions,
                ab.new.acquisitions
            );
        }
    }

    #[test]
    fn park_wake_drill_completes_both_sides() {
        // Completion *is* the no-lost-wakeup property here: a swallowed
        // wake hangs the drill. Latency claims are left to the bench.
        let ab = park_wake_ab(25);
        assert_eq!(ab.old.acquisitions, 25);
        assert_eq!(ab.new.acquisitions, 25);
        assert!(ab.old.elapsed_ns > 0 && ab.new.elapsed_ns > 0);
    }

    #[test]
    fn sparse_sweep_touches_only_dirty_queues() {
        // 64 simulated workers, 2 producers: the directory-driven sweep
        // must grab far fewer queue tokens than the full sweep (which pays
        // 2 * workers per round no matter what).
        let s = run_sweep(64, 200);
        assert_eq!(s.ab.old.acquisitions, 2 * 64 * 200, "old sweep is O(workers)");
        assert!(
            s.ab.new.acquisitions < s.ab.old.acquisitions / 10,
            "directory sweep should be O(dirty): old={} new={}",
            s.ab.old.acquisitions,
            s.ab.new.acquisitions
        );
    }
}
