//! Run a [`TaskGraphSpec`] on the *real* threaded runtime.
//!
//! Bodies are synthesized from the spec's cost class (busy-spin of the
//! scaled duration, or nothing for pure graph-overhead runs); creator tasks
//! spawn their children and `taskwait` exactly like the N-Body benchmark's
//! top-level tasks. An [`ExecutionLog`] with global start/end sequence
//! numbers per task is returned — the serial-equivalence property tests
//! check every dependence edge against it (DESIGN.md invariant #1).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::wd::TaskBody;
use crate::coordinator::{GraphRecording, ReplayTask, TaskSystem};
use crate::workloads::spec::{CostClass, TaskGraphSpec};

/// Per-task observation: global sequence numbers at body start/end.
/// `u64::MAX` = never ran.
#[derive(Debug)]
pub struct ExecutionLog {
    pub start: Vec<AtomicU64>,
    pub end: Vec<AtomicU64>,
    clock: AtomicU64,
}

impl ExecutionLog {
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(ExecutionLog {
            start: (0..n).map(|_| AtomicU64::new(u64::MAX)).collect(),
            end: (0..n).map(|_| AtomicU64::new(u64::MAX)).collect(),
            clock: AtomicU64::new(0),
        })
    }

    #[inline]
    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::SeqCst)
    }

    /// Did every task run exactly once (start and end recorded)?
    pub fn all_ran(&self) -> bool {
        self.start.iter().all(|s| s.load(Ordering::SeqCst) != u64::MAX)
            && self.end.iter().all(|e| e.load(Ordering::SeqCst) != u64::MAX)
    }

    /// Check every (pred, succ) edge: pred must *end* before succ *starts*.
    /// Returns the violating edges.
    pub fn dependence_violations(&self, preds: &[Vec<usize>]) -> Vec<(usize, usize)> {
        let mut bad = Vec::new();
        for (succ, ps) in preds.iter().enumerate() {
            let s_start = self.start[succ].load(Ordering::SeqCst);
            for &p in ps {
                let p_end = self.end[p].load(Ordering::SeqCst);
                if !(p_end < s_start) {
                    bad.push((p, succ));
                }
            }
        }
        bad
    }
}

/// Execution options.
#[derive(Clone, Copy, Debug)]
pub struct ExecOptions {
    /// Nanoseconds of busy-spin per flop (0 = skip compute, pure overhead
    /// measurement). 1 Gflop/s/core ⇒ 1.0; this box ≈ 0.25 for f32 scalar.
    pub ns_per_flop: f64,
    /// Cap on any single task's spin (keeps tests fast).
    pub max_task_ns: u64,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { ns_per_flop: 0.0, max_task_ns: 50_000 }
    }
}

#[inline]
fn spin_for(ns: u64) {
    if ns == 0 {
        return;
    }
    let start = std::time::Instant::now();
    while (start.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

fn task_ns(cost: &CostClass, opt: &ExecOptions) -> u64 {
    let ns = match cost {
        CostClass::Flops(f) | CostClass::Creator(f) => (f * opt.ns_per_flop) as u64,
        CostClass::FixedNs(ns) => *ns,
    };
    ns.min(opt.max_task_ns)
}

/// Synthesize task `id`'s body: log the start tick, busy-spin the cost
/// class, spawn + taskwait children (creator tasks), log the end tick.
/// Shared by the resolved spawner and the replay drivers, so recorded and
/// replayed iterations run bit-identical bodies.
fn make_body(
    ts: &TaskSystem,
    spec: &Arc<TaskGraphSpec>,
    log: &Arc<ExecutionLog>,
    id: usize,
    opt: ExecOptions,
) -> TaskBody {
    let t = &spec.tasks[id];
    let ts2 = ts.clone();
    let spec2 = Arc::clone(spec);
    let log2 = Arc::clone(log);
    let ns = task_ns(&t.cost, &opt);
    let children = t.children.clone();
    Box::new(move || {
        log2.start[id].store(log2.tick(), Ordering::SeqCst);
        spin_for(ns);
        if !children.is_empty() {
            for c in &children {
                spawn_task(&ts2, &spec2, &log2, *c, opt);
            }
            // The creator waits for its children (N-Body's inner taskwait):
            // its own dependences are released only afterwards.
            ts2.taskwait();
        }
        log2.end[id].store(log2.tick(), Ordering::SeqCst);
    })
}

fn spawn_task(ts: &TaskSystem, spec: &Arc<TaskGraphSpec>, log: &Arc<ExecutionLog>, id: usize, opt: ExecOptions) {
    let t = &spec.tasks[id];
    let body = make_body(ts, spec, log, id, opt);
    ts.spawn_full(t.deps.clone(), t.label, body);
}

/// Execute `spec` to completion on `ts`. Returns the execution log.
pub fn run_spec(ts: &TaskSystem, spec: &Arc<TaskGraphSpec>, opt: ExecOptions) -> Arc<ExecutionLog> {
    let log = ExecutionLog::new(spec.tasks.len());
    for id in spec.top_level() {
        spawn_task(ts, spec, &log, id, opt);
    }
    ts.taskwait();
    log
}

/// One iteration of `spec` as a replayable submission stream: the
/// top-level tasks in program order, bodies logging into `log`. Nested
/// (creator-spawned) tasks are not part of the stream — creators spawn
/// them from inside their bodies and taskwait them, on replay exactly as
/// on resolution.
pub fn tasks_for(
    ts: &TaskSystem,
    spec: &Arc<TaskGraphSpec>,
    log: &Arc<ExecutionLog>,
    opt: ExecOptions,
) -> Vec<ReplayTask> {
    spec.top_level()
        .into_iter()
        .map(|id| {
            let t = &spec.tasks[id];
            ReplayTask { deps: t.deps.clone(), label: t.label, body: make_body(ts, spec, log, id, opt) }
        })
        .collect()
}

/// Iterate `spec` `iterations` times through the record/replay plane:
/// iteration 0 runs fully resolved (capturing a [`GraphRecording`] when
/// the builder's `record_graphs` flag is on); later iterations replay the
/// recording with zero dependence resolution. With recording off every
/// iteration simply resolves — same results, no replay. Returns the
/// recording (if captured) and one [`ExecutionLog`] per iteration.
pub fn run_spec_replayed(
    ts: &TaskSystem,
    spec: &Arc<TaskGraphSpec>,
    iterations: usize,
    opt: ExecOptions,
) -> (Option<Arc<GraphRecording>>, Vec<Arc<ExecutionLog>>) {
    let mut recording = None;
    let mut logs = Vec::with_capacity(iterations);
    for _ in 0..iterations {
        let log = ExecutionLog::new(spec.tasks.len());
        let tasks = tasks_for(ts, spec, &log, opt);
        match &recording {
            Some(rec) => {
                // The stream is identical by construction; a fallback here
                // would still run the iteration correctly (resolved), and
                // tests pin it down via RtStats::replay_hits.
                ts.replay(rec, tasks);
            }
            None => recording = ts.record_iteration(tasks),
        }
        logs.push(log);
    }
    (recording, logs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RuntimeKind;
    use crate::workloads::synthetic;

    fn run(kind: RuntimeKind, spec: TaskGraphSpec, threads: usize) {
        let spec = Arc::new(spec);
        let ts = TaskSystem::builder().kind(kind).num_threads(threads).build();
        let log = run_spec(&ts, &spec, ExecOptions::default());
        ts.shutdown();
        assert!(log.all_ran(), "{}: not all tasks ran", spec.name);
        let preds = spec.predecessor_edges();
        let bad = log.dependence_violations(&preds);
        assert!(bad.is_empty(), "{}: violations {bad:?}", spec.name);
    }

    #[test]
    fn chain_respects_order_all_kinds() {
        for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
            run(kind, synthetic::chain(50, 0), 2);
        }
    }

    #[test]
    fn diamonds_all_kinds() {
        for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
            run(kind, synthetic::diamonds(8, 5, 0), 3);
        }
    }

    #[test]
    fn random_dags_ddast() {
        for seed in 1..=5 {
            run(RuntimeKind::Ddast, synthetic::random_dag(200, 13, seed), 4);
        }
    }

    #[test]
    fn nested_creators() {
        for kind in [RuntimeKind::Sync, RuntimeKind::Ddast] {
            run(kind, synthetic::nested(4, 10, 0), 2);
        }
    }

    #[test]
    fn small_matmul_executes_correct_order() {
        let p = crate::workloads::matmul::MatmulParams { ms: 512, bs: 128 };
        run(RuntimeKind::Ddast, crate::workloads::matmul::generate(p), 4);
    }

    #[test]
    fn small_sparselu_executes_correct_order() {
        let p = crate::workloads::sparselu::SparseLuParams { ms: 512, bs: 64 };
        run(RuntimeKind::Ddast, crate::workloads::sparselu::generate(p), 4);
    }

    #[test]
    fn small_nbody_nested_executes() {
        let p = crate::workloads::nbody::NBodyParams {
            num_particles: 512,
            timesteps: 3,
            bs: 128,
        };
        run(RuntimeKind::Ddast, crate::workloads::nbody::generate(p), 4);
        run(RuntimeKind::Sync, crate::workloads::nbody::generate(p), 2);
    }
}
