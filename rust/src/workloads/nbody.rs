//! N-Body workload (paper §4.2.2, Table 3) — the *nested tasks* benchmark.
//!
//! Particles are grouped in blocks of `BS`. Each timestep consists of:
//!
//! * one top-level **creator** task `calc_forces(t)` whose body creates
//!   `nb²` child `force(i, j)` tasks (block i receives force contributions
//!   from block j) and taskwaits on them;
//! * one top-level `update(t)` task integrating the particles.
//!
//! Total: `timesteps × (nb² + 2)` tasks — exactly the Table 3 counts
//! (KNL/ThunderX CG: 16 × (128² + 2) = 262 176; FG: 16 × (256² + 2) =
//! 1 048 608; Power CG: 16 × (64² + 2) = 65 568).
//!
//! The nesting is what makes this benchmark hard for DDAST (§4.2.2): the
//! creator's Submit Task Messages gate all the parallelism of the timestep,
//! and task creation throughput becomes the bottleneck at fine grain
//! (§6.1's Fig 11 discussion).

use crate::coordinator::dep::{DepMode, Dependence};
use crate::substrate::region::block_addr;
use crate::substrate::RegionKey;
use crate::workloads::spec::{CostClass, TaskGraphSpec, TaskSpec};

/// Region-key matrix ids: particle positions (per block) and forces
/// (per block).
const POS: u8 = 4;
const FRC: u8 = 5;

/// Table 3 arguments.
#[derive(Clone, Copy, Debug)]
pub struct NBodyParams {
    pub num_particles: usize,
    pub timesteps: usize,
    pub bs: usize,
}

impl NBodyParams {
    pub fn blocks(&self) -> usize {
        assert!(self.num_particles % self.bs == 0);
        self.num_particles / self.bs
    }

    /// Pairwise force kernel cost for one (i, j) block pair, in
    /// *GEMM-normalized* flops: BS² interactions × ~20 flops each (softened
    /// gravity), scaled ×6 because the scalar/divide-heavy force kernel
    /// sustains ~1/6 of the machines' GEMM rate (the simulator and the
    /// sequential-time denominator both use GEMM-rate normalization, so
    /// speedups are internally consistent).
    pub fn force_task_flops(&self) -> f64 {
        6.0 * 20.0 * (self.bs as f64) * (self.bs as f64)
    }

    /// Integration cost for the whole particle set (same normalization).
    pub fn update_task_flops(&self) -> f64 {
        6.0 * 12.0 * self.num_particles as f64
    }

    pub fn num_tasks(&self) -> usize {
        self.timesteps * (self.blocks() * self.blocks() + 2)
    }
}

pub fn generate(p: NBodyParams) -> TaskGraphSpec {
    let nb = p.blocks();
    let mut tasks: Vec<TaskSpec> = Vec::new();
    let mut total = 0.0f64;
    let pos = |i: usize| RegionKey::addr(block_addr(POS, i as u64, 0));
    let frc = |i: usize| RegionKey::addr(block_addr(FRC, i as u64, 0));

    for _t in 0..p.timesteps {
        // Creator: reads all positions, (re)writes all forces. Its children
        // are the nb² force tasks (filled below).
        let creator_id = tasks.len();
        let mut creator_deps = Vec::with_capacity(2 * nb);
        for b in 0..nb {
            creator_deps.push(Dependence::new(pos(b), DepMode::In));
            creator_deps.push(Dependence::new(frc(b), DepMode::Out));
        }
        tasks.push(TaskSpec {
            id: creator_id,
            label: "calc_forces",
            deps: creator_deps,
            cost: CostClass::Creator(0.0),
            children: Vec::with_capacity(nb * nb),
        });
        // Children: force(i, j) accumulates contributions of block j on
        // block i. Siblings within the creator's domain; the inout on
        // frc(i) chains the j-contributions per target block.
        for i in 0..nb {
            for j in 0..nb {
                let id = tasks.len();
                total += p.force_task_flops();
                tasks.push(TaskSpec {
                    id,
                    label: "force",
                    deps: vec![
                        Dependence::new(pos(i), DepMode::In),
                        Dependence::new(pos(j), DepMode::In),
                        Dependence::new(frc(i), DepMode::Inout),
                    ],
                    cost: CostClass::Flops(p.force_task_flops()),
                    children: vec![],
                });
                tasks[creator_id].children.push(id);
            }
        }
        // Update: integrates positions from forces — one task, as in the
        // BAR benchmark's outer level.
        let id = tasks.len();
        let mut update_deps = Vec::with_capacity(2 * nb);
        for b in 0..nb {
            update_deps.push(Dependence::new(frc(b), DepMode::In));
            update_deps.push(Dependence::new(pos(b), DepMode::Inout));
        }
        total += p.update_task_flops();
        tasks.push(TaskSpec {
            id,
            label: "update",
            deps: update_deps,
            cost: CostClass::Flops(p.update_task_flops()),
            children: vec![],
        });
    }
    TaskGraphSpec {
        name: format!("nbody-n{}-ts{}-bs{}", p.num_particles, p.timesteps, p.bs),
        tasks,
        total_flops: total,
    }
}

/// Paper presets (Table 3).
pub fn table3_params(machine: &str, coarse: bool) -> NBodyParams {
    let bs = match (machine, coarse) {
        ("knl" | "thunderx", true) => 128,
        ("knl" | "thunderx", false) => 64,
        ("power8" | "power9", true) => 256,
        ("power8" | "power9", false) => 128,
        _ => panic!("unknown machine {machine}"),
    };
    NBodyParams { num_particles: 16_384, timesteps: 16, bs }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_counts_match_table3() {
        assert_eq!(table3_params("knl", true).num_tasks(), 262_176);
        assert_eq!(table3_params("knl", false).num_tasks(), 1_048_608);
        assert_eq!(table3_params("power9", true).num_tasks(), 65_568);
        assert_eq!(table3_params("power9", false).num_tasks(), 262_176);
        let s = generate(NBodyParams { num_particles: 1024, timesteps: 2, bs: 256 });
        assert_eq!(s.num_tasks(), 2 * (16 + 2));
    }

    #[test]
    fn spec_validates_and_nests() {
        let s = generate(NBodyParams { num_particles: 512, timesteps: 2, bs: 128 });
        assert!(s.validate().is_ok());
        // Top level: creator + update per timestep.
        assert_eq!(s.top_level().len(), 4);
        let creators: Vec<_> = s.tasks.iter().filter(|t| t.label == "calc_forces").collect();
        assert_eq!(creators.len(), 2);
        assert_eq!(creators[0].children.len(), 16);
    }

    #[test]
    fn timesteps_chain_through_positions() {
        let s = generate(NBodyParams { num_particles: 256, timesteps: 2, bs: 128 });
        let preds = s.predecessor_edges();
        let top = s.top_level();
        // top = [c0, u0, c1, u1]; c1 must depend on u0 (positions).
        let (u0, c1) = (top[1], top[2]);
        assert!(preds[c1].contains(&u0), "creator t+1 waits for update t");
        // update t depends on creator t (forces out).
        assert!(preds[top[1]].contains(&top[0]));
    }

    #[test]
    fn force_tasks_chain_per_target_block() {
        let s = generate(NBodyParams { num_particles: 256, timesteps: 1, bs: 128 });
        let preds = s.predecessor_edges();
        // Children of creator 0: ids 1..=4 (2 blocks -> 4 force tasks).
        // force(0,0)=1, force(0,1)=2 share frc(0): 2 depends on 1.
        assert!(preds[2].contains(&1));
        // force(1,0)=3 targets frc(1): independent of 1 and 2.
        assert!(!preds[3].contains(&1) && !preds[3].contains(&2));
    }
}
