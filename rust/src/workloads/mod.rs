//! Benchmark workload generators (paper §4.2, Tables 2–4) and the executor
//! that runs them on the real runtime. The same specs feed the simulator.

pub mod executor;
pub mod matmul;
pub mod nbody;
pub mod sparselu;
pub mod spec;
pub mod synthetic;

pub use executor::{run_spec, ExecOptions, ExecutionLog};
pub use spec::{CostClass, TaskGraphSpec, TaskSpec};

/// The machines of Table 1 by canonical name.
pub const MACHINES: [&str; 4] = ["knl", "thunderx", "power8", "power9"];
