//! Sparse LU decomposition workload (paper §4.2.3, Table 4).
//!
//! The BSC Application Repository SparseLU (BOTS-derived): a blocked LU
//! factorization over a *sparse* block matrix. Four kernel types per
//! elimination step `kk`:
//!
//! * `lu0(A[kk][kk])`                          — diagonal factorization
//! * `fwd(A[kk][kk], A[kk][jj])`               — row panel update
//! * `bdiv(A[kk][kk], A[ii][kk])`              — column panel update
//! * `bmod(A[ii][kk], A[kk][jj], A[ii][jj])`   — trailing update (allocates
//!   the target block on first touch — "fill-in")
//!
//! The irregular, fill-in-driven graph is the paper's stress case for the
//! DDAST manager: discovering one ready task may require processing many
//! messages from different workers (§6.1, Fig 10 discussion; Fig 15).

use crate::coordinator::dep::{DepMode, Dependence};
use crate::substrate::region::block_addr;
use crate::substrate::RegionKey;
use crate::workloads::spec::{CostClass, TaskGraphSpec, TaskSpec};

const MAT: u8 = 3;

/// Table 4 arguments.
#[derive(Clone, Copy, Debug)]
pub struct SparseLuParams {
    pub ms: usize,
    pub bs: usize,
}

impl SparseLuParams {
    pub fn blocks(&self) -> usize {
        assert!(self.ms % self.bs == 0);
        self.ms / self.bs
    }
}

/// The BOTS `genmat` sparsity pattern: which blocks exist initially.
pub fn initial_block_present(ii: usize, jj: usize) -> bool {
    let mut null_entry = false;
    if ii < jj && ii % 3 != 0 {
        null_entry = true;
    }
    if ii > jj && jj % 3 != 0 {
        null_entry = true;
    }
    if ii % 2 == 1 {
        null_entry = true;
    }
    if jj % 2 == 1 {
        null_entry = true;
    }
    if ii == jj {
        null_entry = false;
    }
    if ii == jj + 1 {
        null_entry = false;
    }
    if ii + 1 == jj {
        null_entry = false;
    }
    !null_entry
}

/// Per-kernel cost estimates for BS×BS blocks, in *GEMM-normalized* flops:
/// small-block (64–128) panel factorizations and triangular solves sustain
/// roughly a quarter of the machines' large-GEMM rate (the simulator's
/// `flops_per_core` and the sequential-time denominator use the same
/// normalization, so speedups stay internally consistent).
const SMALL_BLOCK_DERATE: f64 = 4.0;

fn lu0_flops(bs: f64) -> f64 {
    SMALL_BLOCK_DERATE * 2.0 / 3.0 * bs * bs * bs
}
fn fwd_flops(bs: f64) -> f64 {
    SMALL_BLOCK_DERATE * bs * bs * bs
}
fn bdiv_flops(bs: f64) -> f64 {
    SMALL_BLOCK_DERATE * bs * bs * bs
}
fn bmod_flops(bs: f64) -> f64 {
    SMALL_BLOCK_DERATE * 2.0 * bs * bs * bs
}

/// Generate the task graph, simulating fill-in exactly like the benchmark's
/// sequential elimination does.
pub fn generate(p: SparseLuParams) -> TaskGraphSpec {
    let nb = p.blocks();
    let bs = p.bs as f64;
    let mut present = vec![false; nb * nb];
    for ii in 0..nb {
        for jj in 0..nb {
            present[ii * nb + jj] = initial_block_present(ii, jj);
        }
    }
    let mut tasks: Vec<TaskSpec> = Vec::new();
    let mut total = 0.0f64;
    let addr = |i: usize, j: usize| block_addr(MAT, i as u64, j as u64);
    for kk in 0..nb {
        // lu0 on the diagonal block.
        total += lu0_flops(bs);
        tasks.push(TaskSpec {
            id: tasks.len(),
            label: "lu0",
            deps: vec![Dependence::new(RegionKey::addr(addr(kk, kk)), DepMode::Inout)],
            cost: CostClass::Flops(lu0_flops(bs)),
            children: vec![],
        });
        for jj in (kk + 1)..nb {
            if present[kk * nb + jj] {
                total += fwd_flops(bs);
                tasks.push(TaskSpec {
                    id: tasks.len(),
                    label: "fwd",
                    deps: vec![
                        Dependence::new(RegionKey::addr(addr(kk, kk)), DepMode::In),
                        Dependence::new(RegionKey::addr(addr(kk, jj)), DepMode::Inout),
                    ],
                    cost: CostClass::Flops(fwd_flops(bs)),
                    children: vec![],
                });
            }
        }
        for ii in (kk + 1)..nb {
            if present[ii * nb + kk] {
                total += bdiv_flops(bs);
                tasks.push(TaskSpec {
                    id: tasks.len(),
                    label: "bdiv",
                    deps: vec![
                        Dependence::new(RegionKey::addr(addr(kk, kk)), DepMode::In),
                        Dependence::new(RegionKey::addr(addr(ii, kk)), DepMode::Inout),
                    ],
                    cost: CostClass::Flops(bdiv_flops(bs)),
                    children: vec![],
                });
            }
        }
        for ii in (kk + 1)..nb {
            if !present[ii * nb + kk] {
                continue;
            }
            for jj in (kk + 1)..nb {
                if !present[kk * nb + jj] {
                    continue;
                }
                // Fill-in: the target block springs into existence.
                present[ii * nb + jj] = true;
                total += bmod_flops(bs);
                tasks.push(TaskSpec {
                    id: tasks.len(),
                    label: "bmod",
                    deps: vec![
                        Dependence::new(RegionKey::addr(addr(ii, kk)), DepMode::In),
                        Dependence::new(RegionKey::addr(addr(kk, jj)), DepMode::In),
                        Dependence::new(RegionKey::addr(addr(ii, jj)), DepMode::Inout),
                    ],
                    cost: CostClass::Flops(bmod_flops(bs)),
                    children: vec![],
                });
            }
        }
    }
    TaskGraphSpec { name: format!("sparselu-ms{}-bs{}", p.ms, p.bs), tasks, total_flops: total }
}

/// Paper presets (Table 4): identical for every machine.
pub fn table4_params(coarse: bool) -> SparseLuParams {
    if coarse {
        SparseLuParams { ms: 8192, bs: 128 }
    } else {
        SparseLuParams { ms: 8192, bs: 64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validates() {
        let s = generate(SparseLuParams { ms: 1024, bs: 128 });
        assert!(s.validate().is_ok());
    }

    #[test]
    fn task_counts_scale_like_table4() {
        // Table 4 reports 11 472 (BS=128, nb=64) and 89 504 (BS=64, nb=128).
        // Our generator follows the BOTS genmat pattern; counts must be in
        // the same regime and the FG/CG ratio ≈ 7.8×.
        let cg = generate(table4_params(true)).num_tasks();
        let fg = generate(table4_params(false)).num_tasks();
        assert!(cg > 5_000 && cg < 30_000, "cg={cg}");
        assert!(fg > 40_000 && fg < 250_000, "fg={fg}");
        let ratio = fg as f64 / cg as f64;
        assert!(ratio > 5.0 && ratio < 12.0, "ratio={ratio}");
    }

    #[test]
    fn diagonal_blocks_always_present() {
        for i in 0..64 {
            assert!(initial_block_present(i, i));
        }
    }

    #[test]
    fn first_task_is_lu0_and_irregular_pattern() {
        let s = generate(SparseLuParams { ms: 512, bs: 64 });
        assert_eq!(s.tasks[0].label, "lu0");
        let labels: std::collections::HashSet<_> =
            s.tasks.iter().map(|t| t.label).collect();
        assert!(labels.contains("fwd") && labels.contains("bdiv") && labels.contains("bmod"));
    }

    #[test]
    fn lu0_chain_through_elimination_steps() {
        // bmod(ii=kk+1, jj=kk+1) writes the next diagonal block, so the
        // next lu0 depends on it: the classic LU critical path.
        let s = generate(SparseLuParams { ms: 256, bs: 64 });
        let preds = s.predecessor_edges();
        // Find the second lu0.
        let lu0s: Vec<usize> = s
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.label == "lu0")
            .map(|(i, _)| i)
            .collect();
        assert!(lu0s.len() >= 2);
        assert!(
            !preds[lu0s[1]].is_empty(),
            "second lu0 must depend on the trailing update"
        );
    }
}
