//! Blocked Matrix Multiply workload (paper §4.2.1, Table 2).
//!
//! `C[i][j] += A[i][k] * B[k][j]` over `nb = MS/BS` blocks per dimension:
//! `nb³` tasks in several independent chains — one chain per output block
//! (all tasks with the same `C[i][j]` form an `inout` chain; different
//! output blocks are independent). Matches the paper's task counts:
//! KNL CG (8192/512) → 4 096 tasks, FG (8192/256) → 32 768, ThunderX
//! (4096/128) → 32 768, FG (4096/64) → 262 144.

use crate::coordinator::dep::{DepMode, Dependence};
use crate::substrate::region::block_addr;
use crate::substrate::RegionKey;
use crate::workloads::spec::{CostClass, TaskGraphSpec, TaskSpec};

/// Matrix ids for region keys.
const MAT_A: u8 = 0;
const MAT_B: u8 = 1;
const MAT_C: u8 = 2;

/// Table 2 arguments.
#[derive(Clone, Copy, Debug)]
pub struct MatmulParams {
    /// Matrix dimension (elements).
    pub ms: usize,
    /// Block dimension (elements).
    pub bs: usize,
}

impl MatmulParams {
    pub fn blocks(&self) -> usize {
        assert!(self.ms % self.bs == 0, "MS must be a multiple of BS");
        self.ms / self.bs
    }

    /// Flops of one block GEMM task (C += A·B on BS×BS blocks).
    pub fn flops_per_task(&self) -> f64 {
        2.0 * (self.bs as f64).powi(3)
    }

    pub fn num_tasks(&self) -> usize {
        self.blocks().pow(3)
    }
}

/// Generate the task graph.
pub fn generate(p: MatmulParams) -> TaskGraphSpec {
    let nb = p.blocks();
    let flops = p.flops_per_task();
    let mut tasks = Vec::with_capacity(nb * nb * nb);
    // Loop order (i, j, k): the k-chains per output block are created
    // back-to-back, the regular pattern the paper describes.
    for i in 0..nb as u64 {
        for j in 0..nb as u64 {
            for k in 0..nb as u64 {
                let deps = vec![
                    Dependence::new(RegionKey::addr(block_addr(MAT_A, i, k)), DepMode::In),
                    Dependence::new(RegionKey::addr(block_addr(MAT_B, k, j)), DepMode::In),
                    Dependence::new(RegionKey::addr(block_addr(MAT_C, i, j)), DepMode::Inout),
                ];
                tasks.push(TaskSpec {
                    id: tasks.len(),
                    label: "matmul_block",
                    deps,
                    cost: CostClass::Flops(flops),
                    children: vec![],
                });
            }
        }
    }
    let total = flops * tasks.len() as f64;
    TaskGraphSpec { name: format!("matmul-ms{}-bs{}", p.ms, p.bs), tasks, total_flops: total }
}

/// Paper presets (Table 2). `coarse == true` selects the CG column.
pub fn table2_params(machine: &str, coarse: bool) -> MatmulParams {
    match (machine, coarse) {
        ("knl", true) => MatmulParams { ms: 8192, bs: 512 },
        ("knl", false) => MatmulParams { ms: 8192, bs: 256 },
        ("thunderx", true) => MatmulParams { ms: 4096, bs: 128 },
        ("thunderx", false) => MatmulParams { ms: 4096, bs: 64 },
        // Power8+ and Power9 share a row in Table 2.
        ("power8" | "power9", true) => MatmulParams { ms: 8192, bs: 512 },
        ("power8" | "power9", false) => MatmulParams { ms: 8192, bs: 256 },
        _ => panic!("unknown machine {machine}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_counts_match_table2() {
        assert_eq!(generate(table2_params("knl", true)).num_tasks(), 4_096);
        assert_eq!(generate(table2_params("knl", false)).num_tasks(), 32_768);
        assert_eq!(generate(table2_params("thunderx", true)).num_tasks(), 32_768);
        assert_eq!(table2_params("thunderx", false).num_tasks(), 262_144);
        assert_eq!(generate(table2_params("power9", true)).num_tasks(), 4_096);
    }

    #[test]
    fn spec_validates() {
        let s = generate(MatmulParams { ms: 512, bs: 128 });
        assert!(s.validate().is_ok());
        assert_eq!(s.num_tasks(), 64);
    }

    #[test]
    fn chains_per_output_block() {
        // With nb=2: tasks on C[0][0] are ids 0 and 1 (k=0,1) and must chain.
        let s = generate(MatmulParams { ms: 256, bs: 128 });
        let preds = s.predecessor_edges();
        assert!(preds[0].is_empty());
        assert_eq!(preds[1], vec![0], "k-chain on same output block");
        // First task of the next output block is independent.
        assert!(preds[2].is_empty());
    }

    #[test]
    fn total_flops_matches_dense_gemm() {
        let p = MatmulParams { ms: 1024, bs: 256 };
        let s = generate(p);
        let expect = 2.0 * 1024f64.powi(3);
        assert!((s.total_flops - expect).abs() / expect < 1e-12);
    }
}
