//! Portable task-graph descriptions.
//!
//! A [`TaskGraphSpec`] captures a benchmark run as data: every task with its
//! dependences, cost class and (for N-Body) nesting structure. The same spec
//! drives the *real* runtime (bodies synthesized from the cost, or real PJRT
//! compute) and the *simulator* (costs consumed as virtual time), so the two
//! substrates execute identical graphs — DESIGN.md invariant #6.

use crate::coordinator::dep::Dependence;

/// Cost class of a task — resolved to wall/virtual time by the executor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CostClass {
    /// Leaf compute of `flops` floating point operations (e.g. one block
    /// GEMM). The simulator divides by the machine's per-core flop rate;
    /// the real runtime either spins for a calibrated duration or invokes
    /// the PJRT artifact.
    Flops(f64),
    /// Fixed duration in nanoseconds (creation-dominated workloads).
    FixedNs(u64),
    /// A *creator* task: its body spawns the tasks in `children` of the
    /// owning spec (N-Body's nested top-level tasks). The f64 is the
    /// creator's own compute in flops.
    Creator(f64),
}

/// One task in a spec.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    /// Index into [`TaskGraphSpec::tasks`].
    pub id: usize,
    pub label: &'static str,
    pub deps: Vec<Dependence>,
    pub cost: CostClass,
    /// For `CostClass::Creator`: ids of the child tasks this task spawns
    /// when it runs. Empty otherwise.
    pub children: Vec<usize>,
}

/// A whole benchmark instance.
#[derive(Clone, Debug)]
pub struct TaskGraphSpec {
    pub name: String,
    /// All tasks. Tasks *not* listed in any `children` vector are
    /// *top-level*: created by the main thread in `tasks` order (the
    /// program order the submit queues must preserve).
    pub tasks: Vec<TaskSpec>,
    /// Total useful flops (for speedup-vs-sequential accounting).
    pub total_flops: f64,
}

impl TaskGraphSpec {
    /// Ids of top-level tasks in creation order.
    pub fn top_level(&self) -> Vec<usize> {
        let mut is_child = vec![false; self.tasks.len()];
        for t in &self.tasks {
            for &c in &t.children {
                is_child[c] = true;
            }
        }
        (0..self.tasks.len()).filter(|&i| !is_child[i]).collect()
    }

    /// Validate internal consistency (ids, children, dep sanity).
    pub fn validate(&self) -> Result<(), String> {
        for (i, t) in self.tasks.iter().enumerate() {
            if t.id != i {
                return Err(format!("task {i} has id {}", t.id));
            }
            for &c in &t.children {
                if c >= self.tasks.len() {
                    return Err(format!("task {i} child {c} out of range"));
                }
                if c == i {
                    return Err(format!("task {i} is its own child"));
                }
            }
            if matches!(t.cost, CostClass::Creator(_)) != !t.children.is_empty() {
                return Err(format!(
                    "task {i}: Creator cost class iff non-empty children"
                ));
            }
        }
        Ok(())
    }

    /// Sequential execution time at `flops_per_sec`, in seconds — the
    /// "speedup over the sequential version" denominator of Figures 9–11.
    pub fn sequential_seconds(&self, flops_per_sec: f64) -> f64 {
        let mut fixed_ns = 0u64;
        for t in &self.tasks {
            if let CostClass::FixedNs(ns) = t.cost {
                fixed_ns += ns;
            }
        }
        self.total_flops / flops_per_sec + fixed_ns as f64 * 1e-9
    }

    /// Build the explicit predecessor lists implied by the dependences,
    /// replaying submission in program order (top-level order, with
    /// children inserted where their creator would spawn them). Used by
    /// the simulator and by the serial-equivalence property tests.
    pub fn predecessor_edges(&self) -> Vec<Vec<usize>> {
        use crate::coordinator::depgraph::DepDomain;
        use crate::coordinator::wd::{TaskId, Wd, WdState};
        use std::collections::HashMap;
        use std::sync::{Arc, Weak};

        // Replay the exact graph algorithm with inert bodies, then read the
        // edges back from the successor lists. Nested tasks are submitted
        // into their parent's domain in a correct program order
        // approximation: creator first, then its children immediately
        // (depth-first), which matches how the real run submits when the
        // creator executes before later top-level tasks are created.
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); self.tasks.len()];
        // domain per "parent scope": top-level scope = usize::MAX.
        let mut domains: HashMap<usize, DepDomain> = HashMap::new();
        let mut wds: Vec<Option<Arc<Wd>>> = vec![None; self.tasks.len()];
        let mut order: Vec<(usize, usize)> = Vec::new(); // (scope, task)
        for &t in &self.top_level() {
            order.push((usize::MAX, t));
            // Depth-first insertion of nested children.
            let mut stack = vec![t];
            while let Some(c) = stack.pop() {
                for &ch in &self.tasks[c].children {
                    order.push((c, ch));
                    stack.push(ch);
                }
            }
        }
        for &(scope, tid) in &order {
            let spec = &self.tasks[tid];
            let wd = Wd::new(
                TaskId(tid as u64 + 1),
                spec.deps.clone(),
                spec.label,
                Weak::new(),
                Box::new(|| {}),
            );
            let domain = domains.entry(scope).or_default();
            domain.submit(&wd);
            wds[tid] = Some(wd);
        }
        // Read back edges: successor lists live on the predecessor side.
        for (tid, wd) in wds.iter().enumerate() {
            let wd = wd.as_ref().unwrap();
            for succ in wd.successors.lock().iter() {
                preds[succ.id.0 as usize - 1].push(tid);
            }
        }
        // Leave the replay WDs in a consistent state (they are dropped).
        for wd in wds.into_iter().flatten() {
            wd.set_state(WdState::Ready);
        }
        preds
    }

    /// Count of tasks (paper's "#Tasks" column in Tables 2–4 counts every
    /// created task, including creators).
    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dep::{dep_in, dep_out};

    fn tiny() -> TaskGraphSpec {
        TaskGraphSpec {
            name: "tiny".into(),
            tasks: vec![
                TaskSpec {
                    id: 0,
                    label: "a",
                    deps: vec![dep_out(1)],
                    cost: CostClass::Flops(1.0),
                    children: vec![],
                },
                TaskSpec {
                    id: 1,
                    label: "b",
                    deps: vec![dep_in(1), dep_out(2)],
                    cost: CostClass::Flops(1.0),
                    children: vec![],
                },
                TaskSpec {
                    id: 2,
                    label: "c",
                    deps: vec![dep_in(2)],
                    cost: CostClass::Flops(1.0),
                    children: vec![],
                },
            ],
            total_flops: 3.0,
        }
    }

    #[test]
    fn validate_ok_and_top_level() {
        let s = tiny();
        assert!(s.validate().is_ok());
        assert_eq!(s.top_level(), vec![0, 1, 2]);
        assert_eq!(s.num_tasks(), 3);
    }

    #[test]
    fn predecessor_edges_chain() {
        let s = tiny();
        let p = s.predecessor_edges();
        assert!(p[0].is_empty());
        assert_eq!(p[1], vec![0]);
        assert_eq!(p[2], vec![1]);
    }

    #[test]
    fn validate_rejects_bad_ids() {
        let mut s = tiny();
        s.tasks[1].id = 5;
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_creator_mismatch() {
        let mut s = tiny();
        s.tasks[0].children = vec![1];
        assert!(s.validate().is_err(), "children require Creator class");
        s.tasks[0].cost = CostClass::Creator(0.0);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn sequential_time() {
        let s = tiny();
        assert!((s.sequential_seconds(3.0) - 1.0).abs() < 1e-12);
    }
}
