//! Synthetic task graphs for tests, microbenches and property checks:
//! chains, independent fans, diamonds, and seeded random DAGs.

use crate::coordinator::dep::{DepMode, Dependence};
use crate::substrate::{RegionKey, XorShift64};
use crate::workloads::spec::{CostClass, TaskGraphSpec, TaskSpec};

/// One dependent chain of `n` tasks (worst case for parallelism, best case
/// for graph-op locality).
pub fn chain(n: usize, cost_ns: u64) -> TaskGraphSpec {
    let tasks = (0..n)
        .map(|i| TaskSpec {
            id: i,
            label: "chain",
            deps: vec![Dependence::new(RegionKey::addr(0xC0), DepMode::Inout)],
            cost: CostClass::FixedNs(cost_ns),
            children: vec![],
        })
        .collect();
    TaskGraphSpec { name: format!("chain-{n}"), tasks, total_flops: 0.0 }
}

/// `n` fully independent tasks (best case for parallelism, maximal
/// submit-queue pressure).
pub fn independent(n: usize, cost_ns: u64) -> TaskGraphSpec {
    let tasks = (0..n)
        .map(|i| TaskSpec {
            id: i,
            label: "indep",
            deps: vec![Dependence::new(RegionKey::addr(0x1000 + i as u64), DepMode::Out)],
            cost: CostClass::FixedNs(cost_ns),
            children: vec![],
        })
        .collect();
    TaskGraphSpec { name: format!("indep-{n}"), tasks, total_flops: 0.0 }
}

/// Diamonds: `w` parallel chains between a fork and a join, repeated
/// `reps` times. Exercises fan-out/fan-in edges.
pub fn diamonds(w: usize, reps: usize, cost_ns: u64) -> TaskGraphSpec {
    let mut tasks: Vec<TaskSpec> = Vec::new();
    let join_key = |r: usize| RegionKey::addr(0xD000 + r as u64);
    let mid_key = |r: usize, i: usize| RegionKey::addr(0xE000 + (r * w + i) as u64);
    for r in 0..reps {
        // Fork task writes all mid keys.
        let mut fork_deps: Vec<Dependence> =
            (0..w).map(|i| Dependence::new(mid_key(r, i), DepMode::Out)).collect();
        if r > 0 {
            fork_deps.push(Dependence::new(join_key(r - 1), DepMode::In));
        }
        tasks.push(TaskSpec {
            id: tasks.len(),
            label: "fork",
            deps: fork_deps,
            cost: CostClass::FixedNs(cost_ns),
            children: vec![],
        });
        // Middle tasks.
        for i in 0..w {
            tasks.push(TaskSpec {
                id: tasks.len(),
                label: "mid",
                deps: vec![Dependence::new(mid_key(r, i), DepMode::Inout)],
                cost: CostClass::FixedNs(cost_ns),
                children: vec![],
            });
        }
        // Join task reads all mid keys, writes the join key.
        let mut join_deps: Vec<Dependence> =
            (0..w).map(|i| Dependence::new(mid_key(r, i), DepMode::In)).collect();
        join_deps.push(Dependence::new(join_key(r), DepMode::Out));
        tasks.push(TaskSpec {
            id: tasks.len(),
            label: "join",
            deps: join_deps,
            cost: CostClass::FixedNs(cost_ns),
            children: vec![],
        });
    }
    TaskGraphSpec { name: format!("diamonds-{w}x{reps}"), tasks, total_flops: 0.0 }
}

/// Seeded random DAG over `n` tasks and `regions` region keys. Each task
/// takes 1..=3 dependences with random modes — adversarial input for the
/// serial-equivalence property tests.
pub fn random_dag(n: usize, regions: u64, seed: u64) -> TaskGraphSpec {
    let mut rng = XorShift64::new(seed);
    let tasks = (0..n)
        .map(|i| {
            let ndeps = 1 + rng.next_below(3) as usize;
            let mut deps = Vec::with_capacity(ndeps);
            let mut used = Vec::new();
            for _ in 0..ndeps {
                let r = rng.next_below(regions.max(1));
                if used.contains(&r) {
                    continue;
                }
                used.push(r);
                let mode = match rng.next_below(3) {
                    0 => DepMode::In,
                    1 => DepMode::Out,
                    _ => DepMode::Inout,
                };
                deps.push(Dependence::new(RegionKey::addr(0xF000 + r), mode));
            }
            TaskSpec {
                id: i,
                label: "rand",
                deps,
                cost: CostClass::FixedNs(rng.next_below(2_000)),
                children: vec![],
            }
        })
        .collect();
    TaskGraphSpec { name: format!("random-{n}-s{seed}"), tasks, total_flops: 0.0 }
}

/// Two-level nested graph: `outer` creators each spawning `inner`
/// independent children (N-Body-shaped, for nesting tests).
pub fn nested(outer: usize, inner: usize, cost_ns: u64) -> TaskGraphSpec {
    let mut tasks: Vec<TaskSpec> = Vec::new();
    for o in 0..outer {
        let creator_id = tasks.len();
        tasks.push(TaskSpec {
            id: creator_id,
            label: "creator",
            deps: vec![Dependence::new(RegionKey::addr(0xAB00 + o as u64), DepMode::Out)],
            cost: CostClass::Creator(0.0),
            children: Vec::with_capacity(inner),
        });
        for i in 0..inner {
            let id = tasks.len();
            tasks.push(TaskSpec {
                id,
                label: "leaf",
                deps: vec![Dependence::new(
                    RegionKey::addr(0xBC00 + (o * inner + i) as u64),
                    DepMode::Out,
                )],
                cost: CostClass::FixedNs(cost_ns),
                children: vec![],
            });
            tasks[creator_id].children.push(id);
        }
    }
    TaskGraphSpec { name: format!("nested-{outer}x{inner}"), tasks, total_flops: 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_is_a_chain() {
        let s = chain(10, 100);
        assert!(s.validate().is_ok());
        let p = s.predecessor_edges();
        for i in 1..10 {
            assert_eq!(p[i], vec![i - 1]);
        }
    }

    #[test]
    fn independent_has_no_edges() {
        let s = independent(50, 100);
        assert!(s.validate().is_ok());
        assert!(s.predecessor_edges().iter().all(|p| p.is_empty()));
    }

    #[test]
    fn diamond_fan_out_in() {
        let s = diamonds(4, 2, 100);
        assert!(s.validate().is_ok());
        let p = s.predecessor_edges();
        // join of rep 0 is task 5; it depends on the 4 mids.
        assert_eq!(p[5].len(), 4);
        // fork of rep 1 (task 6) depends on join of rep 0.
        assert_eq!(p[6], vec![5]);
    }

    #[test]
    fn random_dag_is_deterministic() {
        let a = random_dag(100, 10, 7);
        let b = random_dag(100, 10, 7);
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.deps, y.deps);
        }
        assert!(a.validate().is_ok());
    }

    #[test]
    fn nested_structure() {
        let s = nested(3, 5, 10);
        assert!(s.validate().is_ok());
        assert_eq!(s.top_level().len(), 3);
        assert_eq!(s.num_tasks(), 3 * 6);
    }
}
