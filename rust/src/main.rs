//! `repro` — CLI for the DDAST reproduction.
//!
//! ```text
//! repro bench --exp <table5|fig5..fig11|micro|tables> [--quick]
//! repro trace --exp <fig12..fig15> [--quick]
//! repro sim   --bench <matmul|sparselu|nbody> --machine <knl|thunderx|power8|power9>
//!             --runtime <sync|ddast|gomp> --threads N [--coarse] [--quick]
//! repro real  --workload <chain|indep|diamonds|matmul|sparselu|nbody>
//!             --runtime <sync|ddast|gomp> --threads N [--tasks N]
//! repro list-machines
//! ```
//!
//! (Argument parsing is hand-rolled: the offline environment has no clap.)

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use ddast::bench_harness::figures::{self, Bench, FigureOpts};
use ddast::coordinator::{DdastParams, RuntimeKind, TaskSystem};
use ddast::sim::engine::{simulate, SimOptions};
use ddast::sim::machine::MachineConfig;
use ddast::workloads::{executor, matmul, nbody, sparselu, synthetic};

fn usage() -> ! {
    eprintln!(
        "usage:\n  repro bench --exp <tables|table5|fig5|fig6|fig7|fig8|fig9|fig10|fig11|micro> [--quick]\n  repro trace --exp <fig12|fig13|fig14|fig15> [--quick]\n  repro sim --bench <matmul|sparselu|nbody> --machine <knl|thunderx|power8|power9> --runtime <sync|ddast|gomp> --threads N [--coarse] [--quick] [--max-ddast N] [--max-ops N] [--min-ready N] [--max-spins N]\n  repro real --workload <chain|indep|diamonds|nested|matmul|sparselu|nbody> --runtime <sync|ddast|gomp> --threads N [--tasks N]\n  repro list-machines"
    );
    std::process::exit(2);
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            m.insert(key.to_string(), val);
        } else {
            eprintln!("unexpected argument: {a}");
            usage();
        }
        i += 1;
    }
    m
}

fn runtime_kind(s: &str) -> RuntimeKind {
    match s {
        "sync" | "nanos" => RuntimeKind::Sync,
        "ddast" => RuntimeKind::Ddast,
        "dast" | "central" => RuntimeKind::CentralDast,
        "gomp" => RuntimeKind::GompLike,
        _ => {
            eprintln!("unknown runtime {s}");
            usage()
        }
    }
}

fn cmd_bench(flags: &HashMap<String, String>) {
    let opts = if flags.contains_key("quick") { FigureOpts::quick() } else { FigureOpts::full() };
    let exp = flags.get("exp").map(String::as_str).unwrap_or("tables");
    let out = match exp {
        "tables" => format!("{}\n{}", figures::table1(), figures::tables234()),
        "table5" => figures::table5(opts),
        "fig5" => figures::fig5(opts),
        "fig6" => figures::fig6(opts),
        "fig7" => figures::fig7(opts),
        "fig8" => figures::fig8(opts),
        "fig9" => figures::fig9(opts),
        "fig10" => figures::fig10(opts),
        "fig11" => figures::fig11(opts),
        "micro" => ddast::sim::calibrate::report(),
        other => {
            eprintln!("unknown experiment {other}");
            usage()
        }
    };
    println!("{out}");
}

fn cmd_trace(flags: &HashMap<String, String>) {
    let opts = if flags.contains_key("quick") { FigureOpts::quick() } else { FigureOpts::full() };
    let exp = flags.get("exp").map(String::as_str).unwrap_or_else(|| usage());
    let out = match exp {
        "fig12" => figures::fig12(opts),
        "fig13" => figures::fig13(opts),
        "fig14" => figures::fig14(opts),
        "fig15" => figures::fig15(opts),
        other => {
            eprintln!("unknown trace experiment {other}");
            usage()
        }
    };
    println!("{out}");
}

fn cmd_sim(flags: &HashMap<String, String>) {
    let bench = match flags.get("bench").map(String::as_str).unwrap_or("matmul") {
        "matmul" => Bench::Matmul,
        "sparselu" => Bench::SparseLu,
        "nbody" => Bench::NBody,
        other => {
            eprintln!("unknown bench {other}");
            usage()
        }
    };
    let machine = flags.get("machine").map(String::as_str).unwrap_or("knl");
    let m = MachineConfig::by_name(machine).unwrap_or_else(|| {
        eprintln!("unknown machine {machine}");
        usage()
    });
    let kind = runtime_kind(flags.get("runtime").map(String::as_str).unwrap_or("ddast"));
    let threads: usize =
        flags.get("threads").and_then(|s| s.parse().ok()).unwrap_or(m.max_threads_used());
    let coarse = flags.contains_key("coarse");
    let opts =
        if flags.contains_key("quick") { FigureOpts::quick() } else { FigureOpts::full() };
    let spec = figures::spec_for(bench, machine, coarse, opts);
    let mut params = DdastParams::tuned(threads);
    if let Some(v) = flags.get("max-ddast").and_then(|s| s.parse().ok()) {
        params.max_ddast_threads = v;
    }
    if let Some(v) = flags.get("max-ops").and_then(|s| s.parse().ok()) {
        params.max_ops_thread = v;
    }
    if let Some(v) = flags.get("min-ready").and_then(|s| s.parse().ok()) {
        params.min_ready_tasks = v;
    }
    if let Some(v) = flags.get("max-spins").and_then(|s| s.parse().ok()) {
        params.max_spins = v;
    }
    let r = simulate(&spec, &m, SimOptions::new(kind, threads).with_params(params));
    println!(
        "sim {} on {} ({:?}, {} threads): makespan {}  speedup {:.2}",
        spec.name, machine, kind, threads, r.makespan, r.speedup
    );
    println!(
        "  tasks {}  msgs {}  mgr passes {}  steals {}  lock wait {:.3}ms  max in-graph {}  max ready {}",
        r.stats.tasks_executed,
        r.stats.msgs_processed,
        r.stats.mgr_passes,
        r.stats.steals,
        r.stats.lock_wait_ns as f64 / 1e6,
        r.stats.max_in_graph,
        r.stats.max_ready
    );
}

fn cmd_real(flags: &HashMap<String, String>) {
    let kind = runtime_kind(flags.get("runtime").map(String::as_str).unwrap_or("ddast"));
    let threads: usize = flags.get("threads").and_then(|s| s.parse().ok()).unwrap_or(4);
    let n: usize = flags.get("tasks").and_then(|s| s.parse().ok()).unwrap_or(10_000);
    let wl = flags.get("workload").map(String::as_str).unwrap_or("indep");
    let spec = match wl {
        "chain" => synthetic::chain(n, 0),
        "indep" => synthetic::independent(n, 0),
        "diamonds" => synthetic::diamonds(8, n / 10 + 1, 0),
        "nested" => synthetic::nested(n / 100 + 1, 100, 0),
        "matmul" => matmul::generate(matmul::MatmulParams { ms: 1024, bs: 128 }),
        "sparselu" => sparselu::generate(sparselu::SparseLuParams { ms: 1024, bs: 64 }),
        "nbody" => nbody::generate(nbody::NBodyParams {
            num_particles: 2048,
            timesteps: 4,
            bs: 128,
        }),
        other => {
            eprintln!("unknown workload {other}");
            usage()
        }
    };
    let spec = Arc::new(spec);
    let ts = TaskSystem::builder().kind(kind).num_threads(threads).build();
    let t0 = std::time::Instant::now();
    let log = executor::run_spec(&ts, &spec, executor::ExecOptions::default());
    let elapsed = t0.elapsed();
    let rt = ts.runtime().clone();
    ts.shutdown();
    assert!(log.all_ran(), "not all tasks ran");
    let viol = log.dependence_violations(&spec.predecessor_edges());
    println!(
        "real {} ({:?}, {} threads): {} tasks in {:.3}ms ({:.0} tasks/s), violations={}, steals={}, mgr activations={}",
        spec.name,
        kind,
        threads,
        spec.num_tasks(),
        elapsed.as_secs_f64() * 1e3,
        spec.num_tasks() as f64 / elapsed.as_secs_f64(),
        viol.len(),
        rt.ready.steal_count(),
        rt.stats.mgr_activations.get(),
    );
    if !viol.is_empty() {
        std::process::exit(1);
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else { usage() };
    let flags = parse_flags(rest);
    match cmd.as_str() {
        "bench" => cmd_bench(&flags),
        "trace" => cmd_trace(&flags),
        "sim" => cmd_sim(&flags),
        "real" => cmd_real(&flags),
        "list-machines" => {
            println!("{}", figures::table1());
            for m in MachineConfig::all() {
                println!(
                    "{}: sweep {:?}, {:.1} Gflop/s/core",
                    m.name,
                    m.thread_sweep(),
                    m.flops_per_core / 1e9
                );
            }
        }
        _ => usage(),
    }
    ExitCode::SUCCESS
}
