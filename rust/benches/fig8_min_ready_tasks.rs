//! Figure 8: speedup over the default value when sweeping
//! MinReadyTasks (paper §5). Quick problem sizes; `repro bench
//! --exp fig8` runs the full-size version.
use ddast::bench_harness::figures::{param_sweep, FigureOpts, Param};

fn main() {
    println!("{}", param_sweep(Param::MinReadyTasks, FigureOpts::quick()));
}
