//! Figure 7: speedup over the default value when sweeping
//! MaxOpsThread (paper §5). Quick problem sizes; `repro bench
//! --exp fig7` runs the full-size version.
use ddast::bench_harness::figures::{param_sweep, FigureOpts, Param};

fn main() {
    println!("{}", param_sweep(Param::MaxOpsThread, FigureOpts::quick()));
}
