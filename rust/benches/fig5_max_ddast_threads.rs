//! Figure 5: speedup over the default value when sweeping
//! MaxDdastThreads (paper §5). Quick problem sizes; `repro bench
//! --exp fig5` runs the full-size version.
use ddast::bench_harness::figures::{param_sweep, FigureOpts, Param};

fn main() {
    println!("{}", param_sweep(Param::MaxDdastThreads, FigureOpts::quick()));
}
