//! Table 5: DDAST parameter defaults before/after tuning + verification
//! that tuned beats initial on every benchmark/machine (paper §5.5).
use ddast::bench_harness::figures::{table5, FigureOpts};

fn main() {
    println!("{}", table5(FigureOpts::quick()));
}
