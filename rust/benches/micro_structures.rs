//! Microbenchmarks of the real runtime structures (calibration source for
//! the simulator's CostModel — DESIGN.md §7, EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench micro_structures`

use ddast::bench_harness::Bencher;
use ddast::coordinator::{RuntimeKind, TaskSystem};
use ddast::sim::calibrate;
use ddast::workloads::{executor, synthetic};
use std::sync::Arc;

fn main() {
    println!("== micro_structures: real-structure op costs ==\n");
    println!("{}", calibrate::report());

    let mut b = Bencher::new(5, 1);
    // End-to-end task throughput per organization (pure overhead: zero-cost
    // bodies). This is the producer-side submit-path + drain cost.
    for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
        let spec = Arc::new(synthetic::independent(20_000, 0));
        b.bench(&format!("20k independent tasks, {kind:?}, 4 threads"), || {
            let ts = TaskSystem::builder().kind(kind).num_threads(4).build();
            executor::run_spec(&ts, &spec, executor::ExecOptions::default());
            ts.shutdown();
        });
    }
    for kind in [RuntimeKind::Sync, RuntimeKind::Ddast] {
        let spec = Arc::new(synthetic::chain(20_000, 0));
        b.bench(&format!("20k chained tasks, {kind:?}, 2 threads"), || {
            let ts = TaskSystem::builder().kind(kind).num_threads(2).build();
            executor::run_spec(&ts, &spec, executor::ExecOptions::default());
            ts.shutdown();
        });
    }
}
