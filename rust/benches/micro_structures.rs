//! Microbenchmarks of the real runtime structures (calibration source for
//! the simulator's CostModel — DESIGN.md §7, EXPERIMENTS.md §Perf), plus
//! the old-vs-new contention A/B of the lock-free hot paths
//! (EXPERIMENTS.md §Lock-free hot paths).
//!
//! Run: `cargo bench --bench micro_structures`
//!
//! Writes `BENCH_contention.json` at the repository root so future PRs have
//! a machine-readable perf trajectory to compare against.

use ddast::bench_harness::{contention, Bencher};
use ddast::coordinator::{RuntimeKind, TaskSystem};
use ddast::sim::calibrate;
use ddast::workloads::{executor, synthetic};
use std::sync::Arc;

fn main() {
    println!("== micro_structures: real-structure op costs ==\n");
    println!("{}", calibrate::report());

    // Old-vs-new contention A/B: the seed's locked structures (ready
    // pools, dependence domain, dispatcher registry, trace buffers) vs the
    // lock-free replacements, on identical multi-threaded drills — plus
    // the request-plane sparse-traffic sweep at three simulated worker
    // counts.
    println!("== contention A/B: seed locked structures vs lock-free ==\n");
    let mut reports = Vec::new();
    for threads in [2usize, 4, 8] {
        let report = contention::run_ab(threads, 50_000);
        println!("{}", contention::render(&report));
        reports.push(report);
    }
    println!("== request-plane sweep A/B: full sweep vs signal directory ==\n");
    let mut sweeps = Vec::new();
    for workers in [8usize, 32, 128] {
        let sweep = contention::run_sweep(workers, 20_000);
        print!("{}", contention::render_sweep(&sweep));
        sweeps.push(sweep);
    }
    println!("\n== idle-wake A/B: blind 100µs sleep vs directory parking ==\n");
    let park_wake = contention::park_wake_ab(2_000);
    print!("{}", contention::render_park_wake(&park_wake));
    println!("\n== taskwait-wake A/B: spin/sleep ladder vs child-completion wake edge ==\n");
    let taskwait_park = contention::taskwait_park_ab(2_000);
    print!("{}", contention::render_taskwait_park(&taskwait_park));
    println!("\n== batch-budget A/B: fixed MAX_OPS_THREAD vs auto-tuned ==\n");
    let budget_adapt = contention::budget_adapt_ab(16_384);
    print!("{}", contention::render_budget_adapt(&budget_adapt));
    println!("\n== containment A/B: no fault plan vs armed harness ==\n");
    let fault_overhead = contention::fault_overhead_ab(50_000);
    print!("{}", contention::render_fault_overhead(&fault_overhead));
    println!("\n== graph replay A/B: resolve every iteration vs record-once-replay-N ==\n");
    let mut replay = None;
    for threads in [2usize, 4, 8] {
        let ab = contention::replay_ab(threads, 200);
        print!("  {threads} threads: {}", contention::render_replay(&ab));
        if threads == 4 {
            replay = Some(ab); // representative mid-width pair for the JSON
        }
    }
    let replay = replay.expect("thread sweep includes 4");
    println!("\n== serve-scale ingress: external-submitter soak + tenancy A/B ==\n");
    let ingress = ddast::bench_harness::ingress::ingress_soak(4, 4, 10_000);
    print!("{}", ddast::bench_harness::ingress::render_ingress(&ingress));
    println!("\n== topology A/B: flat vs two-level directory, uniform vs socket-ordered steal, broadcast vs dependence-targeted wake ==\n");
    let mut topology = Vec::new();
    for (sockets, wps) in [(2usize, 16usize), (4, 8), (4, 32)] {
        let t = contention::topology_ab(sockets, wps, 2_000);
        print!("{}", contention::render_topology(&t));
        topology.push(t);
    }
    println!("\n== pathology detector: staged windows, exclusive flags, MIN_READY_TASKS feedback ==\n");
    let pathology = contention::pathology_ab();
    print!("{}", contention::render_pathology(&pathology));
    println!();
    let path = contention::default_json_path();
    if contention::write_suite_json(
        &path,
        &reports,
        &sweeps,
        &park_wake,
        &taskwait_park,
        &budget_adapt,
        &fault_overhead,
        &replay,
        &ingress,
        &topology,
        &pathology,
        "cargo bench --bench micro_structures",
    ) {
        println!("wrote {}\n", path.display());
    }

    let mut b = Bencher::new(5, 1);
    // End-to-end task throughput per organization (pure overhead: zero-cost
    // bodies). This is the producer-side submit-path + drain cost.
    for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
        let spec = Arc::new(synthetic::independent(20_000, 0));
        b.bench(&format!("20k independent tasks, {kind:?}, 4 threads"), || {
            let ts = TaskSystem::builder().kind(kind).num_threads(4).build();
            executor::run_spec(&ts, &spec, executor::ExecOptions::default());
            ts.shutdown();
        });
    }
    for kind in [RuntimeKind::Sync, RuntimeKind::Ddast] {
        let spec = Arc::new(synthetic::chain(20_000, 0));
        b.bench(&format!("20k chained tasks, {kind:?}, 2 threads"), || {
            let ts = TaskSystem::builder().kind(kind).num_threads(2).build();
            executor::run_spec(&ts, &spec, executor::ExecOptions::default());
            ts.shutdown();
        });
    }

    // Satellite guard: dependence-domain finish cost must not grow with the
    // number of unrelated regions (the ranged plugin used to scan them all).
    finish_cost_guard();
}

/// Prints ranged-plugin finish visit counts at growing unrelated-region
/// counts; the per-finish visit count must stay equal to the task's own
/// dependence count (here: 1) rather than tracking the region total.
fn finish_cost_guard() {
    use ddast::coordinator::{DepDomain, TaskId, Wd, WdState};
    use ddast::substrate::RegionKey;
    use ddast::DepMode;
    use std::sync::Weak;

    println!("\n== finish-cost guard: visits per finish vs unrelated regions ==");
    println!("{:<22}{:>16}", "unrelated regions", "visits/finish (seed: = regions)");
    for unrelated in [10u64, 100, 1_000, 10_000] {
        let d = DepDomain::new_ranged();
        let mut keep = Vec::new();
        for i in 0..unrelated {
            let t = Wd::new(
                TaskId(i + 1),
                vec![ddast::coordinator::Dependence::new(
                    RegionKey::new(1_000_000 + 16 * i, 8),
                    DepMode::Out,
                )],
                "bg",
                Weak::new(),
                Box::new(|| {}),
            );
            d.submit(&t);
            keep.push(t);
        }
        const PROBES: u64 = 64;
        let before = d.finish_visits();
        for p in 0..PROBES {
            let t = Wd::new(
                TaskId(100_000 + p),
                vec![ddast::coordinator::Dependence::new(RegionKey::new(0, 8), DepMode::Inout)],
                "probe",
                Weak::new(),
                Box::new(|| {}),
            );
            d.submit(&t);
            t.set_state(WdState::Ready);
            t.set_state(WdState::Running);
            t.set_state(WdState::Finished);
            d.finish(&t);
        }
        let per_finish = (d.finish_visits() - before) as f64 / PROBES as f64;
        println!("{unrelated:<22}{per_finish:>16.1}");
        assert!(per_finish <= 1.5, "finish visits grew with unrelated regions");
    }
}
