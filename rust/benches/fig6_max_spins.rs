//! Figure 6: speedup over the default value when sweeping
//! MaxSpins (paper §5). Quick problem sizes; `repro bench
//! --exp fig6` runs the full-size version.
use ddast::bench_harness::figures::{param_sweep, FigureOpts, Param};

fn main() {
    println!("{}", param_sweep(Param::MaxSpins, FigureOpts::quick()));
}
