//! Figures 12–15: execution-analysis traces (paper §6.2) — in-graph /
//! ready evolutions and thread-state timelines. Quick sizes; `repro trace
//! --exp fig12..fig15` runs full sizes.
use ddast::bench_harness::figures::{fig12, fig13, fig14, fig15, FigureOpts};

fn main() {
    let o = FigureOpts::quick();
    println!("{}", fig12(o));
    println!("{}", fig13(o));
    println!("{}", fig14(o));
    println!("{}", fig15(o));
}
