//! Figure 9: Matmul scalability — Nanos++ / DDAST / DDAST-tuned / GOMP
//! over the thread sweep on simulated KNL, ThunderX and Power9 (paper
//! §6.1). Quick sizes; `repro bench --exp fig9` runs full sizes.
use ddast::bench_harness::figures::{scalability, Bench, FigureOpts};

fn main() {
    println!("Figure 9 (Matmul scalability, quick sizes)\n");
    for machine in ["knl", "thunderx", "power9"] {
        for coarse in [false, true] {
            println!("{}", scalability(Bench::Matmul, machine, coarse, FigureOpts::quick()));
        }
    }
}
