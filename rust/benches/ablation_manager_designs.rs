//! Ablation: manager organization — none (Sync) vs dedicated thread
//! (CentralDast, the authors' IPDPSW'17 design [7]) vs distributed idle
//! threads (DDAST, this paper). The design choice DESIGN.md §4 calls out.
//!
//! Run: `cargo bench --bench ablation_manager_designs`

use ddast::coordinator::RuntimeKind;
use ddast::sim::engine::{simulate, SimOptions};
use ddast::sim::machine::MachineConfig;
use ddast::sim::report::{speedup_table, Series};
use ddast::workloads::matmul;

fn main() {
    let m = MachineConfig::knl();
    let spec = matmul::generate(matmul::MatmulParams { ms: 4096, bs: 256 });
    let mut series = Vec::new();
    for (label, kind) in [
        ("no manager (Sync)", RuntimeKind::Sync),
        ("dedicated (DAST[7])", RuntimeKind::CentralDast),
        ("distributed (DDAST)", RuntimeKind::Ddast),
    ] {
        let mut points = Vec::new();
        for &t in &[2usize, 4, 8, 16, 32, 64] {
            let r = simulate(&spec, &m, SimOptions::new(kind, t));
            points.push((t, r.speedup));
        }
        series.push(Series { label: label.into(), points });
    }
    println!(
        "{}",
        speedup_table("Ablation: manager organization (Matmul FG, simulated KNL)", &series)
    );
    // Also report the structural difference: graph occupancy.
    for (label, kind) in
        [("DAST[7]", RuntimeKind::CentralDast), ("DDAST", RuntimeKind::Ddast)]
    {
        let r = simulate(&spec, &m, SimOptions::new(kind, 64));
        println!("{label}: max in-graph {} (roof vs pyramid)", r.stats.max_in_graph);
    }
}
