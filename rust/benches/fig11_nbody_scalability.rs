//! Figure 11: NBody scalability — Nanos++ / DDAST / DDAST-tuned / GOMP
//! over the thread sweep on simulated KNL, ThunderX and Power9 (paper
//! §6.1). Quick sizes; `repro bench --exp fig11` runs full sizes.
use ddast::bench_harness::figures::{scalability, Bench, FigureOpts};

fn main() {
    println!("Figure 11 (NBody scalability, quick sizes)\n");
    for machine in ["knl", "thunderx", "power9"] {
        for coarse in [false, true] {
            println!("{}", scalability(Bench::NBody, machine, coarse, FigureOpts::quick()));
        }
    }
}
