//! Invariant guards for the batched request plane and parking-aware idle
//! workers (EXPERIMENTS.md §Batched request plane):
//!
//! * submit FIFO **program order** survives batch draining, including
//!   interleaved Submit/Done traffic and budget-bounded partial drains;
//! * parking has **no lost wakeups**, from the `Parker`/`SignalDirectory`
//!   unit level (covered in-module) up through `QueueSystem` and a real
//!   multi-threaded `TaskSystem` run.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Weak};

use ddast::coordinator::messages::{MsgBatch, QueueSystem};
use ddast::coordinator::wd::{TaskId, Wd};
use ddast::coordinator::{DepMode, RuntimeKind, TaskSystem};

fn mk(id: u64) -> Arc<Wd> {
    Wd::new(TaskId(id), Vec::new(), "t", Weak::new(), Box::new(|| {}))
}

/// Budget-bounded batch drains must hand out a worker's submits in exactly
/// the order the worker pushed them, with interleaved done traffic neither
/// reordering nor displacing them.
#[test]
fn submit_fifo_program_order_survives_batch_drain() {
    let qs = QueueSystem::new(2);
    let mut pushed = Vec::new();
    // Interleave: submit, submit, done, submit... from worker 1.
    for i in 0..100u64 {
        qs.push_submit(1, mk(i + 1));
        pushed.push(i + 1);
        if i % 3 == 0 {
            qs.push_done(1, mk(10_000 + i));
        }
    }
    let mut seen = Vec::new();
    let mut dones = 0usize;
    let mut batch = MsgBatch::new();
    // Small budget forces many partial drains (the Listing-2 shape).
    loop {
        let n = qs.workers[1].drain_batch(8, &mut batch);
        if n == 0 {
            break;
        }
        seen.extend(batch.submits.iter().map(|t| t.id.0));
        dones += batch.dones.len();
        qs.messages_processed(n as u64);
    }
    assert_eq!(seen, pushed, "batch drains preserved FIFO program order");
    assert_eq!(dones, 34);
    assert_eq!(qs.pending_exact(), 0);
    assert!(qs.signals_quiescent());
}

/// Dependent tasks split across *different* batches must still execute in
/// program order: a chain of doubling tasks gives 2^N only if every
/// predecessor ran first. Run on every organization (Ddast routes through
/// the batched DDAST callback, CentralDast through the batched DAS loop).
#[test]
fn dependent_chain_correct_through_batched_managers() {
    for kind in [RuntimeKind::Ddast, RuntimeKind::CentralDast, RuntimeKind::Sync] {
        let ts = TaskSystem::builder().kind(kind).num_threads(3).build();
        let v = Arc::new(AtomicU64::new(1));
        for _ in 0..18 {
            let v = Arc::clone(&v);
            ts.spawn(&[(42, DepMode::Inout)], move || {
                v.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |x| Some(x * 2)).unwrap();
            });
        }
        ts.taskwait();
        assert_eq!(v.load(Ordering::SeqCst), 1 << 18, "kind={kind:?}");
        ts.shutdown();
    }
}

/// No-lost-wakeup end-to-end through the queue system: producers push real
/// messages (enqueue-then-raise), the consumer parks on the directory when
/// it sees nothing. Every message must be drained; a lost wakeup leaves the
/// consumer parked with traffic pending and hangs (times out) the test —
/// except it cannot: the re-check after `begin_park` sees `pending() > 0`
/// for any message whose raise-wake it lost, by the fence protocol.
#[test]
fn parking_no_lost_wakeup_via_queues() {
    const WORKERS: usize = 8;
    const PER: u64 = 3_000;
    let qs = Arc::new(QueueSystem::new(WORKERS));
    let total = WORKERS as u64 * PER;
    std::thread::scope(|s| {
        for w in 0..WORKERS {
            let qs = Arc::clone(&qs);
            s.spawn(move || {
                for i in 0..PER {
                    qs.push_submit(w, mk(w as u64 * PER + i + 1));
                }
            });
        }
        let qs2 = Arc::clone(&qs);
        s.spawn(move || {
            let mut drained = 0u64;
            let mut batch = MsgBatch::new();
            while drained < total {
                let mut got = 0u64;
                for w in qs2.signals().scan_rotor() {
                    loop {
                        let n = qs2.workers[w].drain_batch(64, &mut batch);
                        if n == 0 {
                            break;
                        }
                        qs2.messages_processed(n as u64);
                        got += n as u64;
                    }
                }
                drained += got;
                if got == 0 && drained < total {
                    // Nothing visible: park until the next enqueue's raise
                    // (sole owner of slot 0, so the announce always claims).
                    let dir = qs2.signals();
                    assert!(dir.begin_park(0));
                    if qs2.pending() == 0 {
                        dir.park(0);
                    } else {
                        dir.cancel_park(0);
                    }
                }
            }
        });
    });
    assert_eq!(qs.pending_exact(), 0);
    assert!(qs.signals_quiescent());
    let (parks, wakes) = qs.signals().park_stats();
    assert!(wakes >= parks, "every committed park was woken (parks={parks} wakes={wakes})");
}

/// The 128-worker, cross-socket port of `parking_no_lost_wakeup_via_queues`
/// (satellite of the topology plane): the queue system is laid out on a
/// 4 × 32 [`Topology`], so producers, their directory words and the
/// consumer's parked bit live in *different sockets* of the two-level
/// directory — the raise-side wake must traverse the socket summary to
/// find the parked slot, and the store-buffer fence protocol must hold
/// across the per-socket word split. One real thread per worker slot (128
/// producers), consumer parked on slot 0 in socket 0, traffic raised from
/// every socket. A lost wakeup hangs (times out) the test.
#[test]
fn parking_no_lost_wakeup_via_queues_128_workers_cross_socket() {
    use ddast::substrate::Topology;

    const WORKERS: usize = 128;
    const PER: u64 = 50;
    let qs = Arc::new(QueueSystem::with_topology(WORKERS, WORKERS, Topology::new(4, 32)));
    assert_eq!(qs.signals().sockets(), 4, "the directory took the injected shape");
    let total = WORKERS as u64 * PER;
    std::thread::scope(|s| {
        for w in 0..WORKERS {
            let qs = Arc::clone(&qs);
            s.spawn(move || {
                for i in 0..PER {
                    qs.push_submit(w, mk(w as u64 * PER + i + 1));
                }
            });
        }
        let qs2 = Arc::clone(&qs);
        s.spawn(move || {
            let mut drained = 0u64;
            let mut batch = MsgBatch::new();
            while drained < total {
                let mut got = 0u64;
                for w in qs2.signals().scan_rotor() {
                    loop {
                        let n = qs2.workers[w].drain_batch(64, &mut batch);
                        if n == 0 {
                            break;
                        }
                        qs2.messages_processed(n as u64);
                        got += n as u64;
                    }
                }
                drained += got;
                if got == 0 && drained < total {
                    let dir = qs2.signals();
                    assert!(dir.begin_park(0));
                    if qs2.pending() == 0 {
                        dir.park(0);
                    } else {
                        dir.cancel_park(0);
                    }
                }
            }
        });
    });
    assert_eq!(qs.pending_exact(), 0);
    assert!(qs.signals_quiescent());
    let (parks, wakes) = qs.signals().park_stats();
    assert!(wakes >= parks, "every committed park was woken (parks={parks} wakes={wakes})");
}

/// End-to-end: a DDAST pool whose workers actually park between bursts
/// still drains every burst, stays quiescent, and records park activity.
/// Bursts repeat until parking is observed (idle gaps on a loaded CI box
/// may need a few), bounded so a broken wake path fails instead of hanging.
#[test]
fn ddast_workers_park_between_bursts_and_still_drain() {
    let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(4).build();
    let hits = Arc::new(AtomicU64::new(0));
    let mut spawned = 0u64;
    let mut gaps = 0;
    while gaps < 200 {
        // Idle gap long enough for workers to walk the spin/yield ladder
        // and park (PARK_AFTER = 256 idle iterations).
        std::thread::sleep(std::time::Duration::from_millis(5));
        let parked_seen = ts.runtime().queues.signals().park_stats().0 > 0;
        // Burst: dependences force manager work, not just ready pushes.
        for i in 0..64u64 {
            let h = Arc::clone(&hits);
            ts.spawn(&[(i % 8, DepMode::Inout)], move || {
                h.fetch_add(1, Ordering::Relaxed);
            });
            spawned += 1;
        }
        ts.taskwait();
        assert_eq!(hits.load(Ordering::Relaxed), spawned, "burst fully drained");
        if parked_seen {
            break;
        }
        gaps += 1;
    }
    let (parks, wakes) = ts.runtime().queues.signals().park_stats();
    assert!(parks > 0, "idle workers parked between bursts (after {gaps} gaps)");
    assert!(wakes > 0, "parked workers were woken by the bursts");
    assert!(ts.runtime().quiescent());
    ts.shutdown();
    assert!(ts.runtime().quiescent(), "shutdown drained and woke everyone");
}

/// Shutdown must terminate a pool whose workers are parked (request_shutdown
/// wakes all; nobody re-parks past the flag). A deadlock here hangs the test.
#[test]
fn shutdown_wakes_parked_workers() {
    for _ in 0..20 {
        let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(3).build();
        // A little work, then an idle window in which workers may park.
        for _ in 0..8 {
            ts.spawn(&[], || {});
        }
        ts.taskwait();
        std::thread::sleep(std::time::Duration::from_millis(2));
        ts.shutdown(); // must join all workers, parked or not
    }
}
