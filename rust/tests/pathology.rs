//! False-positive guard for the online pathology detector
//! (EXPERIMENTS.md §Online pathology detection): genuinely healthy
//! workloads — parallel dependence chains, nested fan-out/join waves,
//! record-once/replay-N iterations — run with the detector **armed at the
//! default thresholds**, and every pathology gauge must finish at zero.
//! The staged true-positive scenarios (each drill tripping exactly its own
//! flag, the `MIN_READY_TASKS` staircase, the disarmed zero-cost proof)
//! live in `bench_harness::contention::pathology_ab` and run from the
//! `lockfree_stress` suite; this file pins the other half of the
//! contract: conservative defaults, no cry-wolf flags on real workloads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use ddast::coordinator::{
    dep_inout, DepMode, PathologyConfig, ReplayOutcome, ReplayTask, RuntimeKind, TaskSystem,
};

/// Assert that no pathology flag is raised on `ts`'s runtime. Judged
/// windows are fine — scanning healthy traffic is the detector's job —
/// but the sticky gauges must never move.
fn assert_clean(ts: &TaskSystem, what: &str) {
    let rt = ts.runtime();
    assert_eq!(rt.stats.pathology_idle_spin.get(), 0, "{what}: idle-spin flagged");
    assert_eq!(
        rt.stats.pathology_serialized_drain.get(),
        0,
        "{what}: serialized-drain flagged"
    );
    assert_eq!(rt.stats.pathology_starvation.get(), 0, "{what}: starvation flagged");
}

/// Eight independent inout chains at 4 threads: enough parallelism that
/// nobody legitimately starves or idles, with the detector scanning on
/// every idle moment throughout (taskwait parks, manager exits, DAS idle
/// tiers all tick it).
#[test]
fn healthy_chains_keep_every_gauge_at_zero() {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(4)
        .pathology(true)
        .build();
    let rt = ts.runtime().clone();
    assert!(rt.pathology().is_some(), ".pathology(true) arms the detector");
    assert!(rt.tracer.is_some(), "pathology implies tracing");
    let hits = Arc::new(AtomicU64::new(0));
    const CHAINS: u64 = 8;
    const LEN: u64 = 250;
    for _ in 0..LEN {
        for c in 0..CHAINS {
            let h = Arc::clone(&hits);
            ts.spawn(&[(9_000 + c, DepMode::Inout)], move || {
                h.fetch_add(1, Ordering::Relaxed);
            });
        }
    }
    ts.taskwait();
    assert_eq!(hits.load(Ordering::Relaxed), CHAINS * LEN);
    assert_clean(&ts, "chains");
    ts.shutdown();
    assert_clean(&ts, "chains after shutdown");
}

/// Fan-out/join waves where every worker is both a creator and a
/// consumer: four parents per wave each spawn eight no-dep children and
/// taskwait on them (the inner wait is what makes the parents creators
/// *and* joiners), across repeated waves. Creators consuming their own
/// pushes is the healthy shape the starvation rule must not confuse with
/// a starved spawner.
#[test]
fn healthy_fanout_waves_keep_every_gauge_at_zero() {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(4)
        .pathology(true)
        .build();
    let hits = Arc::new(AtomicU64::new(0));
    const WAVES: u64 = 25;
    const PARENTS: u64 = 4;
    const KIDS: u64 = 8;
    for _ in 0..WAVES {
        for _ in 0..PARENTS {
            let ts2 = ts.clone();
            let h = Arc::clone(&hits);
            ts.spawn(&[], move || {
                for _ in 0..KIDS {
                    let h = Arc::clone(&h);
                    ts2.spawn(&[], move || {
                        h.fetch_add(1, Ordering::Relaxed);
                    });
                }
                ts2.taskwait(); // join: the creator drains its own fan-out
            });
        }
        ts.taskwait();
    }
    assert_eq!(hits.load(Ordering::Relaxed), WAVES * PARENTS * KIDS);
    assert_clean(&ts, "fan-out waves");
    ts.shutdown();
    assert_clean(&ts, "fan-out waves after shutdown");
}

/// Record-once/replay-N with the detector armed: replay refills bypass
/// both the dependence graph and the creator-push fast path, so the
/// detector sees start/end traffic without matching pushes — which must
/// read as healthy, not as anything stolen.
#[test]
fn healthy_replay_iterations_keep_every_gauge_at_zero() {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(4)
        .record_graphs(true)
        .pathology(true)
        .build();
    let mk = || -> Vec<ReplayTask> {
        (0..8u64)
            .flat_map(|_| 0..8u64)
            .map(|c| ReplayTask::new(vec![dep_inout(5_000 + c)], "replay-guard", || {}))
            .collect()
    };
    let rec = ts.record_iteration(mk()).expect("record_graphs captures iteration 0");
    for _ in 0..10 {
        assert_eq!(ts.replay(&rec, mk()), ReplayOutcome::Replayed);
    }
    assert_clean(&ts, "replay");
    ts.shutdown();
    assert_clean(&ts, "replay after shutdown");
}

/// The builder's config override flows through to the armed detector, and
/// an explicitly configured detector still implies tracing.
#[test]
fn builder_config_reaches_the_detector() {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(2)
        .pathology_config(PathologyConfig::with_window(64))
        .build();
    let rt = ts.runtime().clone();
    let d = rt.pathology().expect("pathology_config arms the detector");
    assert_eq!(d.config().window_events, 64);
    assert!(d.config().streak_windows >= 1);
    assert!(rt.tracer.is_some(), "pathology_config implies tracing");
    ts.spawn(&[], || {});
    ts.taskwait();
    assert_clean(&ts, "configured");
    ts.shutdown();
}

/// Default builds stay disarmed: no detector, no judged windows, every
/// gauge untouched — the zero-cost default the tentpole promises.
#[test]
fn default_build_is_disarmed_and_windowless() {
    let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(2).build();
    let rt = ts.runtime().clone();
    assert!(rt.pathology().is_none(), "detector is opt-in");
    assert!(!rt.pathology_tick(), "disarmed tick is a no-op");
    let hits = Arc::new(AtomicU64::new(0));
    for i in 0..200u64 {
        let h = Arc::clone(&hits);
        ts.spawn(&[(i % 4, DepMode::Inout)], move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
    }
    ts.taskwait();
    assert_eq!(hits.load(Ordering::Relaxed), 200);
    assert_eq!(rt.stats.pathology_windows.get(), 0, "no window ever judged");
    assert_clean(&ts, "disarmed");
    ts.shutdown();
}
