//! Deterministic fault-injection suite for the failure-containment plane
//! (EXPERIMENTS.md §Failure containment):
//!
//! * **TaskBody** faults — injected panics land tasks in `Failed`, poison
//!   their dependents into `Cancelled`, and must never hang `taskwait`;
//!   the accounting identity `executed + failed + cancelled == spawned`
//!   holds on every exit path.
//! * **WakeEdge** faults — swallowed wakes are an unbounded *delay*, not a
//!   loss: an armed wake-edge site forces every park to be timed, so the
//!   recheck cadence (plus the hang watchdog) redelivers what the fault
//!   withheld.
//! * **DrainBatch** faults — a manager that defers a worker's drain must
//!   re-raise the worker, so the deferred batch is picked up by a later
//!   sweep instead of rotting in a clean-directory queue.
//! * **Shutdown under fire** — shutdown requested while waiters are parked
//!   and panics are being injected must still join every thread and settle
//!   all gauges, repeated across rounds to sweep the race window.
//!
//! Scenarios run across the `Ddast`, `CentralDast` and `GompLike`
//! organizations; the plans are seeded, so each round's decision *stream*
//! is reproducible (which worker observes a given decision still depends
//! on scheduling, which is exactly the surface being stressed).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use ddast::coordinator::{dep_out, DdastParams, DepMode, RuntimeKind, RuntimeShared, TaskSystem};
use ddast::substrate::{FaultPlan, FaultSite, FAULT_ALWAYS};

const KINDS: [RuntimeKind; 3] =
    [RuntimeKind::Ddast, RuntimeKind::CentralDast, RuntimeKind::GompLike];

/// A quarter of all task bodies panic (seeded stream), over eight inout
/// chains: taskwait must still return, the failure must surface through
/// `taskwait_checked`, and every spawned task must end in exactly one of
/// executed / failed / cancelled.
#[test]
fn injected_panics_never_hang_taskwait() {
    const TASKS: u64 = 300;
    for kind in KINDS {
        let plan =
            Arc::new(FaultPlan::new(0xDEAD_0001).with_rate(FaultSite::TaskBody, FAULT_ALWAYS / 4));
        let ts = TaskSystem::builder()
            .kind(kind)
            .num_threads(4)
            .fault_plan(Arc::clone(&plan))
            .build();
        let rt = ts.runtime().clone();
        for i in 0..TASKS {
            // Eight independent chains: a failure mid-chain poisons the
            // chain's tail, so cancellations are observed alongside panics.
            ts.spawn(&[(i % 8, DepMode::Inout)], || {});
        }
        let errs = ts
            .taskwait_checked()
            .expect_err("a quarter of 300 bodies panicked; the run cannot be clean");
        let executed = rt.stats.tasks_executed.get();
        let failed = rt.stats.tasks_failed.get();
        let cancelled = rt.stats.tasks_cancelled.get();
        assert!(failed > 0, "kind={kind:?}: no injected panic landed");
        assert!(cancelled > 0, "kind={kind:?}: no poisoned dependent observed");
        assert_eq!(executed + failed + cancelled, TASKS, "kind={kind:?}: task leaked");
        assert_eq!(failed, plan.injected(FaultSite::TaskBody), "kind={kind:?}");
        assert_eq!(
            plan.draws(FaultSite::TaskBody),
            executed + failed,
            "kind={kind:?}: cancelled bodies must never draw (they are dropped unrun)"
        );
        assert_eq!((errs.tasks_failed, errs.tasks_cancelled), (failed, cancelled));
        let msg = errs.first_panic.expect("first panic recorded");
        assert!(msg.contains("injected fault"), "kind={kind:?}: {msg}");
        assert!(rt.quiescent(), "kind={kind:?}");
        assert!(!rt.root.waiter_registered(), "kind={kind:?}: dangling registration");
        // The error summary is sticky: shutdown reports the same failures.
        let at_shutdown = ts.shutdown_checked().expect_err("sticky errors survive shutdown");
        assert_eq!(at_shutdown.tasks_failed, failed, "kind={kind:?}");
        assert!(rt.quiescent(), "kind={kind:?} after shutdown");
    }
}

/// Single-worker poison determinism: the head of a dependence fan always
/// panics (rate `FAULT_ALWAYS`), so exactly one task fails and exactly its
/// three released readers are cancelled — same counts on every run, every
/// organization.
#[test]
fn poison_cancels_dependents_deterministically() {
    for kind in KINDS {
        let plan =
            Arc::new(FaultPlan::new(0xDEAD_0002).with_rate(FaultSite::TaskBody, FAULT_ALWAYS));
        let ts = TaskSystem::builder()
            .kind(kind)
            .num_threads(1)
            .fault_plan(Arc::clone(&plan))
            .build();
        let rt = ts.runtime().clone();
        ts.spawn(&[(42, DepMode::Out)], || {});
        for _ in 0..3 {
            ts.spawn(&[(42, DepMode::In)], || {});
        }
        let errs = ts.taskwait_checked().expect_err("the head always panics");
        assert_eq!(errs.tasks_failed, 1, "kind={kind:?}");
        assert_eq!(errs.tasks_cancelled, 3, "kind={kind:?}");
        assert_eq!(rt.stats.tasks_executed.get(), 0, "kind={kind:?}: no body may run");
        assert_eq!(plan.draws(FaultSite::TaskBody), 1, "kind={kind:?}: only the head draws");
        assert!(rt.quiescent(), "kind={kind:?}");
        ts.shutdown();
        assert!(rt.quiescent(), "kind={kind:?} after shutdown");
    }
}

/// A plan with no armed site must be indistinguishable from no plan: no
/// draws, no injections, a clean checked result — the overhead A/B in
/// `bench_harness::contention` leans on exactly this inertness.
#[test]
fn disarmed_plan_is_inert() {
    let plan = Arc::new(FaultPlan::new(0xDEAD_0003));
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(2)
        .fault_plan(Arc::clone(&plan))
        .build();
    let hits = Arc::new(AtomicU64::new(0));
    for i in 0..100u64 {
        let h = Arc::clone(&hits);
        ts.spawn(&[(i % 4, DepMode::Inout)], move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
    }
    ts.taskwait_checked().expect("a disarmed plan never fails a run");
    assert_eq!(hits.load(Ordering::Relaxed), 100);
    assert_eq!(plan.total_injected(), 0);
    for site in [
        FaultSite::TaskBody,
        FaultSite::WakeEdge,
        FaultSite::DrainBatch,
        FaultSite::IngressRaise,
    ] {
        assert_eq!(plan.draws(site), 0, "disarmed site {site:?} must not even draw");
    }
    ts.shutdown_checked().expect("still clean at shutdown");
}

/// ROADMAP failure-plane item: a dropped external raise must be healed by
/// the watchdog's stranded-ring re-raise, never hang a blocking
/// `submit_async`. The budgeted plan (`FAULT_ALWAYS` × budget 1) drops
/// exactly the raise of the one external submission: its entry sits
/// published in the ingress ring behind a clean external bit, managers
/// see nothing to drain (`drain_ingress` is bit-gated), and the pool
/// parks. The watchdog's `ingress_pending > 0` arm must then restore the
/// bit — the exhausted budget lets the healing raise through — and the
/// pool-side `taskwait` completes.
#[test]
fn dropped_ingress_raise_is_healed_by_the_watchdog() {
    let plan = Arc::new(
        FaultPlan::new(0xDEAD_0007)
            .with_rate(FaultSite::IngressRaise, FAULT_ALWAYS)
            .with_budget(FaultSite::IngressRaise, 1),
    );
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(2)
        .fault_plan(Arc::clone(&plan))
        .build();
    let rt = ts.runtime().clone();
    let hits = Arc::new(AtomicU64::new(0));
    let (h, ts2) = (Arc::clone(&hits), ts.clone());
    // A dependence-carrying task from a thread outside the pool is forced
    // through the ingress ring — the route whose raise the plan drops.
    let submitter = std::thread::spawn(move || {
        ts2.submit_silent(&[(7, DepMode::Out)], move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
    });
    submitter.join().expect("publish-then-signal: the submitter itself never blocks here");
    assert_eq!(
        plan.injected(FaultSite::IngressRaise),
        1,
        "the submission's raise was dropped (the scenario actually fired)"
    );
    // The pool must self-heal within the watchdog envelope: taskwait would
    // hang forever if the ring entry stayed stranded.
    ts.taskwait();
    assert_eq!(hits.load(Ordering::Relaxed), 1, "the stranded task ran");
    assert!(
        rt.stats.watchdog_recoveries.get() >= 1,
        "the heal went through the watchdog's re-raise, not luck"
    );
    assert!(rt.quiescent());
    ts.shutdown();
    assert!(rt.quiescent(), "clean after shutdown");
}

/// Every ready-task wake edge is swallowed (`FAULT_ALWAYS`): the runtime
/// must degrade to bounded-latency delivery (armed wake-edge plans force
/// timed parks), never to a hang — all bodies run, the run stays clean.
#[test]
fn swallowed_wake_edges_cannot_hang_the_runtime() {
    for kind in KINDS {
        let plan =
            Arc::new(FaultPlan::new(0xDEAD_0004).with_rate(FaultSite::WakeEdge, FAULT_ALWAYS));
        let ts = TaskSystem::builder()
            .kind(kind)
            .num_threads(3)
            .fault_plan(Arc::clone(&plan))
            .build();
        let rt = ts.runtime().clone();
        let hits = Arc::new(AtomicU64::new(0));
        for i in 0..40u64 {
            let h = Arc::clone(&hits);
            // Sleepy bodies outlive the spin budgets, so idle workers park
            // and depend on wakes the plan is swallowing.
            ts.spawn(&[(i % 4, DepMode::Inout)], move || {
                std::thread::sleep(Duration::from_micros(200));
                h.fetch_add(1, Ordering::Relaxed);
            });
        }
        ts.taskwait_checked().expect("wake faults delay work; they must not fail it");
        assert_eq!(hits.load(Ordering::Relaxed), 40, "kind={kind:?}");
        assert!(
            plan.injected(FaultSite::WakeEdge) > 0,
            "kind={kind:?}: the armed site never fired — nothing was stressed"
        );
        assert!(rt.quiescent(), "kind={kind:?}");
        ts.shutdown();
        assert!(rt.quiescent(), "kind={kind:?} after shutdown");
    }
}

/// Stage the exact pathology the watchdog exists for — queued work, a
/// swallowed raise (directory clean), a parked worker, stale progress —
/// and verify one tick detects it, restores the raise, and stamps progress
/// so it does not double-fire. The healed work then drains normally.
#[test]
fn watchdog_detects_and_heals_a_stalled_runtime() {
    let rt = RuntimeShared::new(RuntimeKind::Ddast, 2, DdastParams::tuned(2), false, 42);
    rt.register_ddast();
    let root = Arc::clone(&rt.root);
    // A queued Submit nobody is draining (no pool threads exist here; the
    // test thread drives everything by hand).
    rt.spawn_from(0, &root, vec![dep_out(7)], "stalled", Box::new(|| {}));
    let signals = rt.queues.signals();
    // Swallow the raise: the directory reads clean while the queue is not.
    assert!(signals.try_claim(0), "spawn raised worker 0");
    assert!(!signals.is_raised(0));
    // Announce a parked worker on slot 1 (announce-only: the slot's owner
    // thread never existed, so nothing blocks).
    assert!(signals.begin_park(1));
    assert!(!rt.watchdog_tick(), "progress is not stale yet — a fresh runtime never trips");
    std::thread::sleep(Duration::from_millis(8)); // > WATCHDOG_DEADLINE (5ms)
    assert!(rt.watchdog_tick(), "stale + parked + pending work is a stall");
    assert_eq!(rt.stats.watchdog_recoveries.get(), 1);
    assert!(signals.is_raised(0), "the heal restored the swallowed raise");
    assert_eq!(signals.parked_count(), 0, "the heal woke the parked slot");
    assert!(!rt.watchdog_tick(), "healing stamps progress; no double-fire");
    assert_eq!(rt.stats.watchdog_recoveries.get(), 1);
    // The re-raised work is reachable again: a normal drain finishes it.
    rt.taskwait_on(0, &root);
    assert_eq!(rt.stats.tasks_executed.get(), 1);
    assert!(rt.quiescent());
}

/// Managers that defer a drain (`DrainBatch` at 50%) must leave the worker
/// re-raised, so deferred batches complete on a later sweep: every body
/// still runs, and the site's injection counter proves deferrals happened.
/// (GompLike has no manager plane, so the site never draws there.)
#[test]
fn deferred_drains_still_complete() {
    for kind in [RuntimeKind::Ddast, RuntimeKind::CentralDast] {
        let plan = Arc::new(
            FaultPlan::new(0xDEAD_0005).with_rate(FaultSite::DrainBatch, FAULT_ALWAYS / 2),
        );
        let ts = TaskSystem::builder()
            .kind(kind)
            .num_threads(2)
            .fault_plan(Arc::clone(&plan))
            .build();
        let rt = ts.runtime().clone();
        let hits = Arc::new(AtomicU64::new(0));
        let mut expected = 0u64;
        let mut rounds = 0u32;
        loop {
            rounds += 1;
            for i in 0..50u64 {
                let h = Arc::clone(&hits);
                ts.spawn(&[(i % 4, DepMode::Inout)], move || {
                    h.fetch_add(1, Ordering::Relaxed);
                });
            }
            expected += 50;
            ts.taskwait();
            assert_eq!(hits.load(Ordering::Relaxed), expected, "kind={kind:?}");
            assert!(rt.quiescent(), "kind={kind:?}");
            if plan.injected(FaultSite::DrainBatch) > 0 || rounds >= 50 {
                break;
            }
        }
        assert!(
            plan.injected(FaultSite::DrainBatch) > 0,
            "kind={kind:?}: no drain was ever deferred within {rounds} rounds"
        );
        ts.shutdown();
        assert!(rt.quiescent(), "kind={kind:?} after shutdown");
    }
}

/// Per-domain failure containment: a budgeted plan (`FAULT_ALWAYS` ×
/// budget 1) pins the injection to the first body that runs — domain A's
/// fan head — so A fails deterministically (1 failed, 1 cancelled
/// dependent) while domain B, submitted once the budget is spent, runs
/// clean. The isolation claim is the contrast at the end: the *global*
/// error summary is poisoned (it aggregates every tenant), but B's
/// domain-scoped summary stays `Ok` — one tenant's panic never leaks into
/// another tenant's checked wait.
#[test]
fn domain_poison_is_contained_to_its_domain() {
    for kind in KINDS {
        let plan = Arc::new(
            FaultPlan::new(0xDEAD_0006)
                .with_rate(FaultSite::TaskBody, FAULT_ALWAYS)
                .with_budget(FaultSite::TaskBody, 1),
        );
        let ts = TaskSystem::builder()
            .kind(kind)
            .num_threads(2)
            .fault_plan(Arc::clone(&plan))
            .build();
        let rt = ts.runtime().clone();
        let a = ts.domain();
        let b = ts.domain();
        // Domain A: the head is the only ready body in the system, so it
        // takes the single budgeted injection; its dependent is poisoned.
        a.spawn(&[(42, DepMode::Out)], || {});
        a.spawn(&[(42, DepMode::In)], || {});
        let errs = a.taskwait_checked().expect_err("A's head always panics");
        assert_eq!(errs.tasks_failed, 1, "kind={kind:?}");
        assert_eq!(errs.tasks_cancelled, 1, "kind={kind:?}");
        assert!(errs.first_panic.expect("A's panic recorded").contains("injected fault"));
        assert_eq!(plan.injected(FaultSite::TaskBody), 1, "kind={kind:?}: budget spent");
        // Domain B: same dependence address, its own namespace — and the
        // exhausted budget keeps the armed site from firing again.
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..3 {
            let h = Arc::clone(&hits);
            b.spawn(&[(42, DepMode::Inout)], move || {
                h.fetch_add(1, Ordering::Relaxed);
            });
        }
        b.taskwait_checked().expect("B untouched by A's poison");
        assert_eq!(hits.load(Ordering::Relaxed), 3, "kind={kind:?}");
        // The contrast that *is* the containment: globally the run is
        // poisoned, per-domain only A is.
        assert!(rt.task_errors().is_some(), "kind={kind:?}: global summary aggregates A");
        assert!(a.errors().is_some(), "kind={kind:?}: A's cell is sticky");
        assert!(b.errors().is_none(), "kind={kind:?}: B's cell stays clean");
        assert!(rt.quiescent(), "kind={kind:?}");
        ts.shutdown();
        assert!(rt.quiescent(), "kind={kind:?} after shutdown");
    }
}

/// Shutdown racing a parked taskwait *while panics are being injected*:
/// ten rounds per organization sweep the shutdown request across the
/// park/finalize window. Every round must join the killer thread, drain
/// through `shutdown`, and settle the accounting identity — injected
/// failures change which bucket a task lands in, never whether it lands.
#[test]
fn shutdown_while_parked_under_injected_panics() {
    const TASKS: u64 = 60;
    for kind in KINDS {
        for round in 0..10u64 {
            let plan = Arc::new(
                FaultPlan::new(0x0BAD_5EED ^ round)
                    .with_rate(FaultSite::TaskBody, FAULT_ALWAYS / 3)
                    .with_rate(FaultSite::WakeEdge, FAULT_ALWAYS / 6)
                    .with_rate(FaultSite::DrainBatch, FAULT_ALWAYS / 6),
            );
            let ts = TaskSystem::builder().kind(kind).num_threads(3).fault_plan(plan).build();
            let rt = ts.runtime().clone();
            for i in 0..TASKS {
                ts.spawn(&[(i % 6, DepMode::Inout)], || {
                    std::thread::sleep(Duration::from_micros(100));
                });
            }
            // All spawns are in before the race starts (spawning into a
            // runtime that is shutting down is a caller error by contract).
            let rt2 = rt.clone();
            let killer = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(1 + round % 3));
                rt2.request_shutdown();
            });
            ts.taskwait();
            killer.join().expect("the shutdown requester must never die");
            ts.shutdown();
            let executed = rt.stats.tasks_executed.get();
            let failed = rt.stats.tasks_failed.get();
            let cancelled = rt.stats.tasks_cancelled.get();
            assert_eq!(
                executed + failed + cancelled,
                TASKS,
                "kind={kind:?} round={round}: task leaked through the shutdown race"
            );
            assert!(rt.quiescent(), "kind={kind:?} round={round}");
            assert!(
                !rt.root.waiter_registered(),
                "kind={kind:?} round={round}: dangling taskwait registration"
            );
        }
    }
}
