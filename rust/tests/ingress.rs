//! Serve-scale ingress guards (EXPERIMENTS.md §Serve-scale ingress):
//!
//! * the **external producer class** inherits the no-lost-wakeup proof —
//!   a consumer parked on the signal directory is always woken by traffic
//!   that arrives *only* from threads outside the pool, at the
//!   `QueueSystem` level (flat and on a 4×8 two-level directory) and
//!   through a real parked `TaskSystem`;
//! * blocking submission under sustained ring saturation never loses a
//!   task (the backpressure wait ends, everything runs);
//! * tenant domains are isolated end-to-end: same dependence addresses,
//!   disjoint graphs, an idle bystander's namespace stays untouched.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Weak};

use ddast::coordinator::messages::{MsgBatch, QueueSystem};
use ddast::coordinator::wd::{TaskId, Wd};
use ddast::coordinator::{DepMode, GraphDomain, RuntimeKind, TaskSystem};
use ddast::substrate::Topology;

fn mk(id: u64) -> Arc<Wd> {
    Wd::new(TaskId(id), Vec::new(), "ext", Weak::new(), Box::new(|| {}))
}

/// Drive the external-producer park litmus against `qs`: `producers`
/// outside threads push only through the ingress ring (no worker queue is
/// ever touched), the consumer drains ring + queues and parks on slot 0
/// when it sees nothing. A wakeup lost between `begin_park`'s announce and
/// a producer's `raise_external` leaves the consumer parked with traffic
/// pending and hangs (times out) the test — except it cannot: the
/// post-announce re-check reads the `pending` gauge, which the external
/// push incremented *before* raising.
fn run_external_park_litmus(qs: Arc<QueueSystem>, producers: usize, per: u64) {
    let total = producers as u64 * per;
    std::thread::scope(|s| {
        for p in 0..producers {
            let qs = Arc::clone(&qs);
            s.spawn(move || {
                for i in 0..per {
                    let mut task = mk(p as u64 * per + i + 1);
                    // Blocking-producer shape: retry the same task until
                    // the ring takes it (the consumer drains concurrently).
                    loop {
                        match qs.try_push_external(task) {
                            Ok(()) => break,
                            Err(back) => {
                                task = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            });
        }
        let qs2 = Arc::clone(&qs);
        s.spawn(move || {
            let mut batch = MsgBatch::new();
            let mut drained = 0u64;
            while drained < total {
                let mut got = 0u64;
                // External lane first (the only live producer class here),
                // then the ordinary per-worker sweep so the litmus keeps
                // the manager's real drain order.
                while let Some(_task) = qs2.pop_external() {
                    qs2.message_processed();
                    got += 1;
                }
                for w in qs2.signals().scan_rotor() {
                    loop {
                        let n = qs2.workers[w].drain_batch(64, &mut batch);
                        if n == 0 {
                            break;
                        }
                        qs2.messages_processed(n as u64);
                        got += n as u64;
                    }
                }
                drained += got;
                if got == 0 && drained < total {
                    let dir = qs2.signals();
                    assert!(dir.begin_park(0));
                    if qs2.pending() == 0 {
                        dir.park(0);
                    } else {
                        dir.cancel_park(0);
                    }
                }
            }
        });
    });
    assert_eq!(qs.ingress_pending(), 0, "ring fully drained");
    assert_eq!(qs.pending_exact(), 0);
    assert!(qs.signals_quiescent(), "external bit settled with the ring empty");
    let (pushes, pops, _rejected) = qs.ingress_stats();
    assert_eq!(pushes, total, "zero lost external submissions");
    assert_eq!(pops, total);
    assert!(qs.signals().external_raises() > 0, "the producers actually used the external bit");
}

/// Flat directory: all workers parked (here: the one consumer), traffic
/// only from outside threads.
#[test]
fn external_producers_never_lose_the_parked_consumer() {
    // Tiny ring so producers hit backpressure and the raise/park protocol
    // is exercised at the full/empty boundaries, not just in mid-flow.
    let qs = Arc::new(QueueSystem::with_topology_and_ingress(4, 4, Topology::new(1, 4), 16));
    run_external_park_litmus(qs, 6, 2_000);
}

/// The 4 × 8 two-level variant (DDAST_TOPOLOGY shape): the consumer's
/// parked bit lives in socket 0 while external raises arrive from threads
/// bound to no socket at all — the external wake must still find the
/// parked slot through the socket summary.
#[test]
fn external_producers_never_lose_the_parked_consumer_4x8() {
    let qs = Arc::new(QueueSystem::with_topology_and_ingress(32, 32, Topology::new(4, 8), 64));
    assert_eq!(qs.signals().sockets(), 4, "the directory took the injected shape");
    run_external_park_litmus(qs, 8, 1_000);
}

/// End-to-end: a DDAST pool whose workers have *parked* (observed via
/// park_stats) is woken by purely external traffic — no pool thread ever
/// submits — and drains every burst. Bounded retry instead of a sleep
/// race: bursts repeat until a burst started with parking observed.
#[test]
fn external_only_traffic_wakes_a_parked_pool() {
    let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(4).build();
    let hits = Arc::new(AtomicU64::new(0));
    let mut submitted = 0u64;
    let mut gaps = 0;
    while gaps < 200 {
        std::thread::sleep(std::time::Duration::from_millis(5));
        let parked_seen = ts.runtime().queues.signals().park_stats().0 > 0;
        let client = {
            let ts = ts.clone();
            let hits = Arc::clone(&hits);
            std::thread::spawn(move || {
                for i in 0..64u64 {
                    let hits = Arc::clone(&hits);
                    ts.submit_silent(&[(i % 8, DepMode::Inout)], move || {
                        hits.fetch_add(1, Ordering::Relaxed);
                    });
                }
            })
        };
        client.join().unwrap();
        submitted += 64;
        ts.taskwait();
        assert_eq!(hits.load(Ordering::Relaxed), submitted, "burst fully drained");
        if parked_seen {
            break;
        }
        gaps += 1;
    }
    let (parks, wakes) = ts.runtime().queues.signals().park_stats();
    assert!(parks > 0, "workers parked between external bursts (after {gaps} gaps)");
    assert!(wakes > 0, "external traffic woke parked workers");
    assert!(ts.runtime().quiescent());
    ts.shutdown();
}

/// Blocking submission under sustained saturation: a two-slot ring, one
/// worker draining, 400 chained submissions from one client. The blocking
/// lane waits out every full-ring episode; losing (or duplicating) a
/// single task breaks the chain count.
#[test]
fn blocking_submits_survive_sustained_saturation() {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(2)
        .ingress_capacity(2)
        .build();
    let v = Arc::new(AtomicU64::new(0));
    let client = {
        let ts = ts.clone();
        let v = Arc::clone(&v);
        std::thread::spawn(move || {
            for _ in 0..400u64 {
                let v = Arc::clone(&v);
                ts.submit_silent(&[(0xC0DE, DepMode::Inout)], move || {
                    v.fetch_add(1, Ordering::SeqCst);
                });
            }
        })
    };
    client.join().unwrap();
    ts.taskwait();
    assert_eq!(v.load(Ordering::SeqCst), 400);
    let rt = ts.runtime();
    assert_eq!(rt.stats.ingress_admitted.get(), 400, "every submission rode the ring");
    assert_eq!(rt.stats.tasks_executed.get(), 400);
    assert!(rt.quiescent());
    ts.shutdown();
}

/// Multi-tenant isolation end-to-end: three client threads, each with its
/// own domain, all using the *same* dependence addresses; plus an idle
/// bystander tenant. Everything completes, per-domain waits scope to the
/// domain, and the bystander's dependence namespace is never touched.
#[test]
fn tenant_domains_isolate_graphs_end_to_end() {
    const CLIENTS: usize = 3;
    const PER: u64 = 500;
    let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(4).build();
    let domains: Vec<Arc<GraphDomain>> = (0..CLIENTS).map(|_| Arc::new(ts.domain())).collect();
    let bystander = ts.domain();
    let hits = Arc::new(AtomicU64::new(0));
    let clients: Vec<_> = domains
        .iter()
        .map(|dom| {
            let dom = Arc::clone(dom);
            let hits = Arc::clone(&hits);
            std::thread::spawn(move || {
                for i in 0..PER {
                    let hits = Arc::clone(&hits);
                    dom.submit_silent(&[(i % 4, DepMode::Inout)], move || {
                        hits.fetch_add(1, Ordering::Relaxed);
                    });
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }
    for dom in &domains {
        dom.taskwait_checked().expect("clean tenant");
    }
    assert_eq!(hits.load(Ordering::Relaxed), CLIENTS as u64 * PER);
    assert!(
        bystander.root().child_domain_opt().is_none(),
        "idle tenant's dependence namespace untouched"
    );
    assert!(ts.runtime().quiescent());
    ts.shutdown();
}
