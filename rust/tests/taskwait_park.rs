//! Stress suite for parking `taskwait_on` and the child-completion wake
//! edge (EXPERIMENTS.md §Taskwait parking):
//!
//! * deep-nested taskwait trees — a parent parks while its grandchildren
//!   are still running, at both nesting levels;
//! * no-lost-wakeup when the last child finishes exactly as the parent
//!   commits to parking (repeat-loop race amplification, counter-verified
//!   through `RtStats::taskwait_parks` / `taskwait_wake_edges`);
//! * shutdown requested while a parent is parked in `taskwait_on` must
//!   not deadlock.
//!
//! All scenarios run across the `Ddast`, `CentralDast` and `GompLike`
//! organizations: Ddast finalizes through the batched callback on idle
//! workers, CentralDast through the dedicated DAS thread (the parked
//! parent cannot help drain, so the wake edge is load-bearing), and
//! GompLike finalizes inline on the executing worker — there the parked
//! parent's *only* wake source is the edge itself.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use ddast::coordinator::{DepMode, RuntimeKind, TaskSystem};

const KINDS: [RuntimeKind; 3] =
    [RuntimeKind::Ddast, RuntimeKind::CentralDast, RuntimeKind::GompLike];

/// Deep-nested taskwait trees: two child tasks each spawn four sleeping
/// grandchildren and taskwait on them (inner level), while the main thread
/// taskwaits on the children (outer level). The grandchildren's sleeps
/// outlive both waiters' spin budgets, so the parents park; rounds repeat
/// (bounded) until a committed taskwait park is observed.
#[test]
fn deep_nested_taskwait_trees_parent_parks_while_grandchildren_run() {
    for kind in KINDS {
        let ts = TaskSystem::builder().kind(kind).num_threads(4).build();
        let rt = ts.runtime().clone();
        let hits = Arc::new(AtomicU64::new(0));
        let mut expected = 0u64;
        let mut rounds = 0u32;
        loop {
            rounds += 1;
            for c in 0..2u64 {
                let ts2 = ts.clone();
                let h = Arc::clone(&hits);
                ts.spawn(&[], move || {
                    for g in 0..4u64 {
                        let h = Arc::clone(&h);
                        // Distinct inout regions per sibling set: the
                        // grandchildren are independent, so the inner
                        // waiter has nothing to execute and must park.
                        ts2.spawn(&[(c * 4 + g, DepMode::Inout)], move || {
                            std::thread::sleep(Duration::from_micros(300));
                            h.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                    ts2.taskwait(); // inner: waits for the grandchildren
                });
                expected += 4;
            }
            ts.taskwait(); // outer: parks while grandchildren still run
            assert_eq!(hits.load(Ordering::Relaxed), expected, "kind={kind:?}");
            assert!(rt.quiescent(), "kind={kind:?}");
            assert!(!rt.root.waiter_registered(), "dangling outer registration");
            if rt.stats.taskwait_parks.get() > 0 || rounds >= 200 {
                break;
            }
        }
        assert!(
            rt.stats.taskwait_parks.get() > 0,
            "kind={kind:?}: no taskwait ever parked within {rounds} rounds"
        );
        ts.shutdown();
        assert!(rt.quiescent(), "kind={kind:?} after shutdown");
    }
}

/// Race amplification for the wake edge: one child per round, with its
/// runtime varied so its completion sweeps across the parent's spin
/// budget and park commit. A lost wakeup (last child finishing exactly as
/// the parent commits, without the edge firing) parks the parent forever
/// and times the test out. Counter-verified: the rounds keep repeating
/// (bounded) until committed parks *and* fired wake edges are both
/// observed, so the parks were real and the edge actually delivered.
#[test]
fn last_child_finish_racing_park_commit_always_wakes_counter_verified() {
    for kind in KINDS {
        let ts = TaskSystem::builder().kind(kind).num_threads(3).build();
        let rt = ts.runtime().clone();
        let hits = Arc::new(AtomicU64::new(0));
        let min_rounds: u64 = if cfg!(debug_assertions) { 300 } else { 1_500 };
        let max_rounds: u64 = min_rounds * 4;
        let mut r = 0u64;
        loop {
            r += 1;
            let h = Arc::clone(&hits);
            // Every 4th round the child sleeps past the parent's whole
            // spin/yield budget (a certain park); the others spin a
            // round-varying amount to sweep the finish across the park
            // commit itself.
            let sleepy = r % 4 == 0;
            let spin = (r % 11) * 41;
            ts.spawn(&[(r % 5, DepMode::Inout)], move || {
                if sleepy {
                    std::thread::sleep(Duration::from_micros(200));
                }
                for _ in 0..spin {
                    std::hint::spin_loop();
                }
                h.fetch_add(1, Ordering::Relaxed);
            });
            ts.taskwait(); // a swallowed wake edge hangs here
            assert!(!rt.root.waiter_registered(), "round {r}: dangling waiter");
            let parks = rt.stats.taskwait_parks.get();
            let edges = rt.stats.taskwait_wake_edges.get();
            if (parks > 0 && edges > 0 && r >= min_rounds) || r >= max_rounds {
                break;
            }
        }
        assert_eq!(hits.load(Ordering::Relaxed), r, "kind={kind:?}: every round drained");
        let parks = rt.stats.taskwait_parks.get();
        let edges = rt.stats.taskwait_wake_edges.get();
        assert!(parks > 0, "kind={kind:?}: no committed taskwait park in {r} rounds");
        assert!(edges > 0, "kind={kind:?}: the wake edge never fired in {r} rounds");
        assert!(rt.quiescent(), "kind={kind:?}");
        eprintln!("kind={kind:?}: rounds={r} taskwait parks={parks} wake edges={edges}");
        ts.shutdown();
    }
}

/// Shutdown requested while a parent is (possibly) parked in
/// `taskwait_on`: the wake_all re-checks the flag, the taskwait switches
/// to bounded timed parks, the still-running child completes and its wake
/// edge releases the parent — and the pool joins. A deadlock anywhere in
/// that chain hangs (and times out) the test.
#[test]
fn shutdown_requested_while_parent_parked_in_taskwait_does_not_deadlock() {
    for kind in KINDS {
        for round in 0u64..10 {
            let ts = TaskSystem::builder().kind(kind).num_threads(3).build();
            let rt = ts.runtime().clone();
            let done = Arc::new(AtomicU64::new(0));
            let d = Arc::clone(&done);
            ts.spawn(&[], move || {
                std::thread::sleep(Duration::from_millis(4));
                d.fetch_add(1, Ordering::Release);
            });
            let rt2 = rt.clone();
            let killer = std::thread::spawn(move || {
                // Land the request inside the parent's wait window, at a
                // varying point of its spin → park progression.
                std::thread::sleep(Duration::from_millis(1 + round % 3));
                rt2.request_shutdown();
            });
            ts.taskwait(); // parent may be parked when the request lands
            assert_eq!(done.load(Ordering::Acquire), 1, "kind={kind:?}");
            killer.join().unwrap();
            ts.shutdown(); // must join every (possibly parked) worker
            assert!(rt.quiescent(), "kind={kind:?}");
        }
    }
}
