//! Multi-thread stress and A/B guards for the lock-free hot paths
//! (Chase–Lev ready deques, striped dependence domains, sharded counters).
//!
//! The `contention_ab_*` test also regenerates `BENCH_contention.json` at
//! the repository root on every tier-1 run, so the perf trajectory stays
//! fresh without a separate bench invocation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use ddast::bench_harness::contention;
use ddast::coordinator::{DdastParams, DepMode, RuntimeKind, TaskSystem};

/// Satellite: 4-thread DDAST end-to-end — quiescence and the manager cap
/// must hold under the sharded ready-count and the new deques.
#[test]
fn ddast_4_threads_quiescent_and_mgr_capped() {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(4)
        .params(DdastParams { max_ddast_threads: 2, max_spins: 2, max_ops_thread: 8, min_ready_tasks: 4 })
        .build();
    let hits = Arc::new(AtomicU64::new(0));
    // A mix of 16 inout chains (dependence pressure, spread across domain
    // stripes) and independent tasks (ready-pool pressure).
    for i in 0..4_000u64 {
        let h = Arc::clone(&hits);
        ts.spawn(&[(i % 16, DepMode::Inout)], move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
        if i % 16 == 0 {
            let h = Arc::clone(&hits);
            ts.spawn(&[], move || {
                h.fetch_add(1, Ordering::Relaxed);
            });
        }
    }
    ts.taskwait();
    let rt = ts.runtime().clone();
    assert_eq!(hits.load(Ordering::Relaxed), 4_000 + 250);
    assert!(rt.quiescent(), "exact sharded-counter read settles to zero");
    let peak = rt.stats.mgr_peak.get();
    assert!(peak <= 2, "mgr_peak {peak} exceeded MAX_DDAST_THREADS=2");
    ts.shutdown();
    assert!(rt.quiescent());
}

/// All organizations drain a steal-heavy workload: one producer thread
/// spawns everything, so the other workers live on the steal path.
#[test]
fn steal_heavy_workload_all_kinds() {
    for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
        let ts = TaskSystem::builder().kind(kind).num_threads(4).build();
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..5_000u64 {
            let h = Arc::clone(&hits);
            ts.spawn(&[], move || {
                h.fetch_add(1, Ordering::Relaxed);
            });
        }
        ts.taskwait();
        assert_eq!(hits.load(Ordering::Relaxed), 5_000, "{kind:?}");
        let rt = ts.runtime().clone();
        assert!(rt.quiescent(), "{kind:?}");
        ts.shutdown();
    }
}

/// The contention A/B suite runs under tier-1 and records its numbers at
/// three thread counts plus three simulated-worker sweep sizes. The hard
/// ≥2x acceptance ratio is checked by the bench on a real multicore box;
/// here (possibly a 1-core CI container) we assert the structural
/// invariants that cannot be timing-dependent, and refresh the JSON.
#[test]
fn contention_ab_smoke_and_json() {
    let thread_counts = [2usize, 4, 8];
    let ops: u64 = 2_000;
    let reports: Vec<_> =
        thread_counts.iter().map(|&t| contention::run_ab(t, ops)).collect();

    for report in &reports {
        let threads = report.threads as u64;
        // Both sides completed identical work: every produced task was
        // consumed exactly once, and every domain op acquired some
        // lock/shard.
        assert!(report.ready_pools.old.acquisitions > 0);
        assert!(
            report.ready_pools.new.cas_attempts > 0,
            "new pools pop through the front CAS, not a lock"
        );
        // submit+finish per op, on both sides.
        assert!(report.dep_domain.old.acquisitions >= 2 * threads * ops);
        assert!(report.dep_domain.new.acquisitions >= 2 * threads * ops);

        // The striped domain's drill touches disjoint regions per thread:
        // it must not contend more than the single lock (the `.max(100)`
        // absorbs scheduler noise on near-serialized 1-core runners; a
        // broken striping scheme would show thousands of contended events
        // here).
        assert!(
            report.dep_domain.new.contended_events()
                <= report.dep_domain.old.contended_events().max(100),
            "striping must not add contention: old={} new={}",
            report.dep_domain.old.contended_events(),
            report.dep_domain.new.contended_events()
        );

        // The locked dispatcher pays one registry-lock acquisition per
        // poll; the RCU poll path pays none (its SideReport only carries
        // wall clock).
        assert!(report.dispatcher_poll.old.acquisitions >= threads * ops);
        assert_eq!(report.dispatcher_poll.new.acquisitions, 0);
        assert_eq!(report.dispatcher_poll.new.contended_events(), 0);
        // Same shape for the tracer: one mutex per recorded event vs none.
        assert!(report.trace_append.old.acquisitions >= threads * ops);
        assert_eq!(report.trace_append.new.acquisitions, 0);

        // Batched graph insertion (acceptance criterion, counter-verified):
        // per-message pays exactly one shard acquisition per submit; the
        // per-batch path acquires the batch's shard union once, at most
        // half as many acquisitions on this drill's 4-region workload.
        assert_eq!(
            report.batch_submit.old.acquisitions,
            threads * ops,
            "per-message baseline is one shard acquisition per message"
        );
        assert!(
            report.batch_submit.new.acquisitions * 2 <= report.batch_submit.old.acquisitions,
            "batch path must show fewer shard acquisitions per message: old={} new={}",
            report.batch_submit.old.acquisitions,
            report.batch_submit.new.acquisitions
        );
    }

    // Sparse-traffic request-plane sweep at 8/32/128 simulated workers:
    // the old sweep's token grabs scale with the worker count, the
    // directory scan's with the (fixed) traffic.
    let sweeps: Vec<_> = [8usize, 32, 128]
        .iter()
        .map(|&w| contention::run_sweep(w, 2_000))
        .collect();
    for s in &sweeps {
        assert_eq!(
            s.ab.old.acquisitions,
            2 * s.workers as u64 * s.rounds,
            "old sweep is O(workers) per round"
        );
        assert!(
            s.ab.new.acquisitions < s.ab.old.acquisitions / 4,
            "directory sweep must be O(dirty): workers={} old={} new={}",
            s.workers,
            s.ab.old.acquisitions,
            s.ab.new.acquisitions
        );
    }
    assert!(
        sweeps[2].ab.new.acquisitions <= sweeps[0].ab.new.acquisitions,
        "new-side grabs track traffic, not worker count"
    );

    // Park-vs-sleep wake drill: completion is the no-lost-wakeup property
    // (a swallowed wake hangs it); latency claims stay in the bench.
    let park_wake = contention::park_wake_ab(50);
    assert_eq!(park_wake.new.acquisitions, 50);

    // Taskwait wake drill: every round's child-completion wake edge must
    // reach the (possibly parked) waiter — completion is the check.
    let taskwait_park = contention::taskwait_park_ab(50);
    assert_eq!(taskwait_park.new.acquisitions, 50);

    // Adaptive batch budget: the fixed side pays exactly one Submit + one
    // Done token grab per 8-message round; the controller-grown budget
    // must cut that by at least 4x on a deep burst (counter-verified,
    // cannot pass by timing luck).
    let budget_adapt = contention::budget_adapt_ab(2_048);
    assert_eq!(budget_adapt.old.acquisitions, 2 * 2_048 / 8);
    assert!(
        budget_adapt.new.acquisitions * 4 <= budget_adapt.old.acquisitions,
        "adaptive budget must cut token grabs: old={} new={}",
        budget_adapt.old.acquisitions,
        budget_adapt.new.acquisitions
    );

    // Containment overhead: an armed (zero-impact) fault harness must not
    // change happy-path semantics — both sides complete every task.
    let fault_overhead = contention::fault_overhead_ab(2_000);
    assert_eq!(fault_overhead.old.acquisitions, 2_000);
    assert_eq!(fault_overhead.new.acquisitions, 2_000);

    // Record/replay: replayed iterations must take zero dependence-shard
    // acquisitions while the resolved baseline pays >= 1 per task per
    // iteration (the drill also asserts zero graph submits and frozen
    // manager-message totals internally, at every thread count).
    let replay_iters = 6u64;
    let mut replay = contention::replay_ab(2, replay_iters);
    for threads in [4usize, 8] {
        let ab = contention::replay_ab(threads, replay_iters);
        assert_eq!(
            ab.new.acquisitions, 0,
            "replay must stay shard-free at {threads} threads"
        );
        assert!(
            ab.old.acquisitions >= 64 * replay_iters,
            "resolved side pays per-task shard locks at {threads} threads"
        );
        if threads == 4 {
            replay = ab; // representative mid-width pair for the JSON
        }
    }
    assert_eq!(replay.new.acquisitions, 0);

    // Serve-scale ingress: the soak's zero-lost / isolation / backpressure
    // claims are asserted inside the drill; the suite pins the reported
    // shape and that the quantiles are populated.
    let ingress = ddast::bench_harness::ingress::ingress_soak(2, 3, 500);
    assert_eq!(ingress.completed, ingress.submitted);
    assert!(ingress.busy > 0, "saturation drill observed backpressure");
    assert!(ingress.p50_ns <= ingress.p99_ns);

    // Topology A/B at a 2-socket and the acceptance 4-socket/32-worker
    // shape (plus a >64-worker shape inside the drill's own unit test for
    // the multi-word sweep contrast). All three claims are structural:
    // sweeps load only dirty-socket words, socket-ordered steals stay
    // local while local work exists, dependence-targeted wakes never
    // broadcast.
    let topology: Vec<_> =
        [(2usize, 16usize), (4, 8)].iter().map(|&(s, w)| contention::topology_ab(s, w, 64)).collect();
    for t in &topology {
        assert!(
            t.sweep.new.acquisitions <= 2 * t.rounds,
            "{}x{}: two-level sweep visits only dirty-socket words: {} / {} rounds",
            t.sockets,
            t.workers,
            t.sweep.new.acquisitions,
            t.rounds
        );
        assert!(
            t.steal.new.contended * 10 <= t.steal.new.acquisitions,
            "{}x{}: ≥90% same-socket steals in the all-local window: {}/{} remote",
            t.sockets,
            t.workers,
            t.steal.new.contended,
            t.steal.new.acquisitions
        );
        assert_eq!(
            t.dep_wake.new.contended, 0,
            "{}x{}: dependence-targeted wakes must land on the registered worker",
            t.sockets, t.workers
        );
        assert!(t.dep_wake.old.contended > 0, "broadcast control side must mistarget");
    }

    // Staged pathology detector: the drill asserts the exclusive-flag,
    // healthy-zero, disarmed-zero and MIN_READY_TASKS-staircase claims
    // inline; the suite pins the reported invariants.
    let pathology = contention::pathology_ab();
    assert!(pathology.idle_spin >= 1 && pathology.serialized_drain >= 1);
    assert!(pathology.starvation >= 1);
    assert_eq!(pathology.healthy_flags, 0, "healthy stream must stay clean");
    assert_eq!(pathology.disarmed_windows, 0, "disarmed runtime must never scan");
    assert!(pathology.min_ready_peak > pathology.min_ready_baseline);
    assert_eq!(pathology.min_ready_settled, pathology.min_ready_baseline);

    let json = contention::suite_to_json(
        &reports,
        &sweeps,
        &park_wake,
        &taskwait_park,
        &budget_adapt,
        &fault_overhead,
        &replay,
        &ingress,
        &topology,
        &pathology,
        "cargo test contention_ab_smoke_and_json",
    );
    assert!(json.contains("\"contended_reduction\""));
    assert!(json.contains("\"signal_sweep\""));
    assert!(json.contains("\"batch_submit\""));
    assert!(json.contains("\"park_wake\""));
    assert!(json.contains("\"taskwait_park\""));
    assert!(json.contains("\"budget_adapt\""));
    assert!(json.contains("\"fault_overhead\""));
    assert!(json.contains("\"replay\""));
    assert!(json.contains("\"ingress\""));
    assert!(json.contains("\"throughput_per_sec\""));
    assert!(json.contains("\"topology\""));
    assert!(json.contains("\"dep_wake\""));
    assert!(json.contains("\"pathology\""));
    assert!(json.contains("\"min_ready_peak\""));
    let path = contention::default_json_path();
    if contention::write_suite_json(
        &path,
        &reports,
        &sweeps,
        &park_wake,
        &taskwait_park,
        &budget_adapt,
        &fault_overhead,
        &replay,
        &ingress,
        &topology,
        &pathology,
        "cargo test contention_ab_smoke_and_json",
    ) {
        eprintln!("refreshed {}", path.display());
    }
    for report in &reports {
        eprintln!("{}", contention::render(report));
    }
    for s in &sweeps {
        eprintln!("{}", contention::render_sweep(s));
    }
    eprintln!("{}", contention::render_park_wake(&park_wake));
    eprintln!("{}", contention::render_taskwait_park(&taskwait_park));
    eprintln!("{}", contention::render_budget_adapt(&budget_adapt));
    eprintln!("{}", contention::render_fault_overhead(&fault_overhead));
    eprintln!("{}", contention::render_replay(&replay));
    eprintln!("{}", ddast::bench_harness::ingress::render_ingress(&ingress));
    for t in &topology {
        eprintln!("{}", contention::render_topology(t));
    }
    eprintln!("{}", contention::render_pathology(&pathology));
}

/// Acceptance guard for the request-plane refactor: during a sparse-traffic
/// run (all messages from one worker), the DDAST callback must visit only
/// signaled workers — zero queue-token acquisitions for the idle ones.
#[test]
fn ddast_callback_skips_idle_workers() {
    use ddast::coordinator::ddast::ddast_callback;
    use ddast::coordinator::dep::dep_out;
    use ddast::coordinator::pool::RuntimeShared;
    use ddast::coordinator::wd::Wd;

    let params = DdastParams {
        max_ddast_threads: 1,
        max_spins: 1,
        max_ops_thread: 64,
        // Never early-exit, so the whole backlog drains in one callback.
        min_ready_tasks: u64::MAX,
    };
    let rt = RuntimeShared::new(RuntimeKind::Ddast, 8, params, false, 7);
    // Sparse traffic: worker 3 is the only producer.
    for i in 0..10u64 {
        let wd = Wd::new(
            rt.fresh_task_id(),
            vec![dep_out(100 + i)],
            "sparse",
            Arc::downgrade(&rt.root),
            Box::new(|| {}),
        );
        rt.root.child_created();
        rt.stats.tasks_outstanding.inc();
        rt.queues.push_submit(3, wd);
    }
    assert!(ddast_callback(&rt, 0), "the manager satisfied messages");
    assert_eq!(rt.queues.pending(), 0, "backlog fully drained");

    for w in [0usize, 1, 2, 4, 5, 6, 7] {
        assert_eq!(
            rt.queues.workers[w].submit.acquire_count(),
            0,
            "idle worker {w}'s submit queue token was acquired"
        );
        assert_eq!(
            rt.queues.workers[w].done.acquire_count(),
            0,
            "idle worker {w}'s done queue token was acquired"
        );
    }
    assert!(rt.queues.workers[3].submit.acquire_count() >= 1, "the producer was visited");
    assert!(rt.queues.signals_quiescent());
}

/// Satellite: dispatcher register-while-polling — pollers iterate RCU
/// snapshots while a registrar concurrently installs new callbacks; every
/// registration must land and no poll may crash or miss the final state.
#[test]
fn dispatcher_register_while_polling_stress() {
    use ddast::coordinator::Dispatcher;

    const CALLBACKS: usize = 64;
    const POLLERS: usize = 3;
    let d = Arc::new(Dispatcher::new());
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let hits = Arc::new(AtomicU64::new(0));
    std::thread::scope(|s| {
        for t in 0..POLLERS {
            let d = Arc::clone(&d);
            let stop = Arc::clone(&stop);
            s.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    d.poll_idle(t);
                }
            });
        }
        for i in 0..CALLBACKS {
            let h = Arc::clone(&hits);
            d.register(
                "stress",
                Box::new(move |_| {
                    h.fetch_add(1, Ordering::Relaxed);
                    true
                }),
            );
            if i % 8 == 0 {
                std::thread::yield_now();
            }
        }
        stop.store(true, Ordering::Release);
    });
    assert_eq!(d.len(), CALLBACKS, "every concurrent registration landed");
    assert!(d.poll_idle(0));
    assert!(hits.load(Ordering::Relaxed) >= CALLBACKS as u64, "final poll ran all callbacks");
    let (installs, _races, retired) = d.registry_stats();
    assert_eq!(installs, CALLBACKS as u64);
    assert_eq!(retired, CALLBACKS as u64, "one retired snapshot per install");
}

/// Satellite: signal-directory no-lost-wakeup through the *runtime's* queue
/// system — producers push real messages and raise; a consumer scans,
/// claims and drains. A signal set after a scan must be observed by a
/// subsequent scan, so the drain always completes.
#[test]
fn signal_directory_no_lost_wakeup_via_queues() {
    use ddast::coordinator::messages::QueueSystem;
    use ddast::coordinator::wd::{TaskId, Wd};
    use std::sync::Weak;

    const WORKERS: usize = 16;
    const PER: u64 = 5_000;
    let qs = Arc::new(QueueSystem::new(WORKERS));
    let drained = Arc::new(AtomicU64::new(0));
    let live = Arc::new(AtomicU64::new(WORKERS as u64));
    let total = WORKERS as u64 * PER;
    std::thread::scope(|s| {
        // One producer per worker slot (the SpscQueue ownership contract).
        for w in 0..WORKERS {
            let qs = Arc::clone(&qs);
            let live = Arc::clone(&live);
            s.spawn(move || {
                for i in 0..PER {
                    let wd = Wd::new(
                        TaskId(w as u64 * PER + i + 1),
                        Vec::new(),
                        "msg",
                        Weak::new(),
                        Box::new(|| {}),
                    );
                    qs.push_submit(w, wd);
                }
                live.fetch_sub(1, Ordering::AcqRel);
            });
        }
        let qs2 = Arc::clone(&qs);
        let drained2 = Arc::clone(&drained);
        let live2 = Arc::clone(&live);
        s.spawn(move || {
            let mut empty_after_done = 0u32;
            loop {
                let mut got = 0u64;
                for w in qs2.signals().scan_rotor() {
                    if let Some(mut g) = qs2.workers[w].submit.try_acquire() {
                        while g.pop().is_some() {
                            qs2.message_processed();
                            got += 1;
                        }
                    }
                }
                let d = drained2.fetch_add(got, Ordering::AcqRel) + got;
                if d >= total {
                    break;
                }
                if got == 0 {
                    if live2.load(Ordering::Acquire) == 0 {
                        empty_after_done += 1;
                        assert!(
                            empty_after_done < 10_000,
                            "lost wakeup: drained {d} of {total}"
                        );
                    }
                    std::thread::yield_now();
                }
            }
        });
    });
    assert_eq!(drained.load(Ordering::Acquire), total);
    assert_eq!(qs.pending_exact(), 0);
    assert!(qs.signals_quiescent(), "only stale raises may remain, and they self-heal");
}

/// Satellite: trace-ring overflow and drain round-trip — a full ring drops
/// (and counts) instead of blocking, published events all survive a
/// concurrent drain.
#[test]
fn trace_ring_overflow_and_drain_roundtrip() {
    use ddast::coordinator::{TraceKind, Tracer};

    let t = Arc::new(Tracer::with_capacity(3, 1_000));
    std::thread::scope(|s| {
        for w in 0..3usize {
            let t = Arc::clone(&t);
            s.spawn(move || {
                // Worker 0 overflows by 500; the others stay within bounds.
                let n = if w == 0 { 1_500u64 } else { 800 };
                for i in 0..n {
                    t.record(w, TraceKind::InGraph(i));
                }
            });
        }
        // Concurrent reader: merged snapshots must only ever grow and
        // never expose unpublished slots.
        let t2 = Arc::clone(&t);
        s.spawn(move || {
            let mut last = 0usize;
            for _ in 0..50 {
                let m = t2.merged().len();
                assert!(m >= last, "published prefix shrank");
                last = m;
                std::thread::yield_now();
            }
        });
    });
    assert_eq!(t.dropped(), 500);
    assert_eq!(t.merged().len(), 1_000 + 800 + 800);
    assert_eq!(t.dump_csv().lines().count(), 1 + 2_600);
}

/// Sharded ready gauge: hammer push/get from many threads through the
/// public runtime API and verify the exact read settles (regression guard
/// for torn relaxed sweeps feeding `quiescent`).
#[test]
fn sharded_gauge_settles_under_churn() {
    for _ in 0..20 {
        let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(3).build();
        for i in 0..300u64 {
            ts.spawn(&[(i % 5, DepMode::Inout)], || {});
        }
        ts.taskwait();
        let rt = ts.runtime().clone();
        assert!(rt.quiescent());
        assert_eq!(rt.ready.ready_count_exact(), 0);
        ts.shutdown();
    }
}
