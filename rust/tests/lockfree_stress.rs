//! Multi-thread stress and A/B guards for the lock-free hot paths
//! (Chase–Lev ready deques, striped dependence domains, sharded counters).
//!
//! The `contention_ab_*` test also regenerates `BENCH_contention.json` at
//! the repository root on every tier-1 run, so the perf trajectory stays
//! fresh without a separate bench invocation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use ddast::bench_harness::contention;
use ddast::coordinator::{DdastParams, DepMode, RuntimeKind, TaskSystem};

/// Satellite: 4-thread DDAST end-to-end — quiescence and the manager cap
/// must hold under the sharded ready-count and the new deques.
#[test]
fn ddast_4_threads_quiescent_and_mgr_capped() {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(4)
        .params(DdastParams { max_ddast_threads: 2, max_spins: 2, max_ops_thread: 8, min_ready_tasks: 4 })
        .build();
    let hits = Arc::new(AtomicU64::new(0));
    // A mix of 16 inout chains (dependence pressure, spread across domain
    // stripes) and independent tasks (ready-pool pressure).
    for i in 0..4_000u64 {
        let h = Arc::clone(&hits);
        ts.spawn(&[(i % 16, DepMode::Inout)], move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
        if i % 16 == 0 {
            let h = Arc::clone(&hits);
            ts.spawn(&[], move || {
                h.fetch_add(1, Ordering::Relaxed);
            });
        }
    }
    ts.taskwait();
    let rt = ts.runtime().clone();
    assert_eq!(hits.load(Ordering::Relaxed), 4_000 + 250);
    assert!(rt.quiescent(), "exact sharded-counter read settles to zero");
    let peak = rt.stats.mgr_peak.get();
    assert!(peak <= 2, "mgr_peak {peak} exceeded MAX_DDAST_THREADS=2");
    ts.shutdown();
    assert!(rt.quiescent());
}

/// All organizations drain a steal-heavy workload: one producer thread
/// spawns everything, so the other workers live on the steal path.
#[test]
fn steal_heavy_workload_all_kinds() {
    for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
        let ts = TaskSystem::builder().kind(kind).num_threads(4).build();
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..5_000u64 {
            let h = Arc::clone(&hits);
            ts.spawn(&[], move || {
                h.fetch_add(1, Ordering::Relaxed);
            });
        }
        ts.taskwait();
        assert_eq!(hits.load(Ordering::Relaxed), 5_000, "{kind:?}");
        let rt = ts.runtime().clone();
        assert!(rt.quiescent(), "{kind:?}");
        ts.shutdown();
    }
}

/// The contention A/B runs under tier-1 and records its numbers. The hard
/// ≥2x acceptance ratio is checked by the bench on a real multicore box;
/// here (possibly a 1-core CI container) we assert the structural
/// invariants that cannot be timing-dependent, and refresh the JSON.
#[test]
fn contention_ab_smoke_and_json() {
    let report = contention::run_ab(4, 5_000);

    // Both sides completed identical work: every produced task was consumed
    // exactly once, and every domain op acquired some lock/shard.
    assert!(report.ready_pools.old.acquisitions > 0);
    assert!(
        report.ready_pools.new.cas_attempts > 0,
        "new pools pop through the front CAS, not a lock"
    );
    // submit+finish per op, 4 threads x 5k ops, on both sides.
    assert!(report.dep_domain.old.acquisitions >= 2 * 4 * 5_000);
    assert!(report.dep_domain.new.acquisitions >= 2 * 4 * 5_000);

    // The striped domain's drill touches disjoint regions per thread: it
    // must not contend more than the single lock (the `.max(100)` absorbs
    // scheduler noise on near-serialized 1-core runners; a broken striping
    // scheme would show thousands of contended events here).
    assert!(
        report.dep_domain.new.contended_events()
            <= report.dep_domain.old.contended_events().max(100),
        "striping must not add contention: old={} new={}",
        report.dep_domain.old.contended_events(),
        report.dep_domain.new.contended_events()
    );

    let json = contention::to_json(&report, "cargo test contention_ab_smoke_and_json");
    assert!(json.contains("\"contended_reduction\""));
    let path = contention::default_json_path();
    if contention::write_json(&path, &report, "cargo test contention_ab_smoke_and_json") {
        eprintln!("refreshed {}", path.display());
    }
    eprintln!("{}", contention::render(&report));
}

/// Sharded ready gauge: hammer push/get from many threads through the
/// public runtime API and verify the exact read settles (regression guard
/// for torn relaxed sweeps feeding `quiescent`).
#[test]
fn sharded_gauge_settles_under_churn() {
    for _ in 0..20 {
        let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(3).build();
        for i in 0..300u64 {
            ts.spawn(&[(i % 5, DepMode::Inout)], || {});
        }
        ts.taskwait();
        let rt = ts.runtime().clone();
        assert!(rt.quiescent());
        assert_eq!(rt.ready.ready_count_exact(), 0);
        ts.shutdown();
    }
}
