//! Integration suite for the record/replay plane (EXPERIMENTS.md §Graph
//! replay): serial equivalence of replayed iterations, transparent
//! fallback on stream-hash mismatch, poison propagation along *recorded*
//! successor edges, and replay interleaving with the parking taskwait —
//! across the runtime organizations.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use ddast::coordinator::{dep_inout, ReplayOutcome, ReplayTask, RuntimeKind, TaskSystem};
use ddast::substrate::{FaultPlan, FaultSite, FAULT_ALWAYS};
use ddast::workloads::executor::{self, ExecOptions};
use ddast::workloads::synthetic;

/// Serial equivalence is the acceptance property: every replayed iteration
/// must respect every dependence edge of the spec, on every organization.
/// Iteration 0 records, iterations 1..=4 replay (counter-pinned — a silent
/// fallback would pass the edge checks but fail `replay_hits`).
#[test]
fn replayed_iterations_respect_every_edge() {
    for kind in [
        RuntimeKind::Ddast,
        RuntimeKind::CentralDast,
        RuntimeKind::GompLike,
        RuntimeKind::Sync,
    ] {
        let spec = Arc::new(synthetic::random_dag(60, 9, 3));
        let ts = TaskSystem::builder()
            .kind(kind)
            .num_threads(3)
            .record_graphs(true)
            .build();
        let (rec, logs) = executor::run_spec_replayed(&ts, &spec, 5, ExecOptions::default());
        let rt = Arc::clone(ts.runtime());
        ts.shutdown();
        assert!(rec.is_some(), "{kind:?}: iteration 0 must capture a recording");
        assert_eq!(rt.stats.recordings_captured.get(), 1, "{kind:?}");
        assert_eq!(rt.stats.replay_hits.get(), 4, "{kind:?}: iterations 1..=4 replay");
        assert_eq!(rt.stats.replay_fallbacks.get(), 0, "{kind:?}");
        let preds = spec.predecessor_edges();
        for (i, log) in logs.iter().enumerate() {
            assert!(log.all_ran(), "{kind:?}: iteration {i} lost a task");
            let bad = log.dependence_violations(&preds);
            assert!(bad.is_empty(), "{kind:?}: iteration {i} violations {bad:?}");
        }
    }
}

/// With the builder flag off, `run_spec_replayed` must degrade to plain
/// resolution: no recording, no replay counters, same results.
#[test]
fn recording_off_resolves_transparently() {
    let spec = Arc::new(synthetic::diamonds(6, 4, 0));
    let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(2).build();
    let (rec, logs) = executor::run_spec_replayed(&ts, &spec, 3, ExecOptions::default());
    let rt = Arc::clone(ts.runtime());
    ts.shutdown();
    assert!(rec.is_none(), "record_graphs off must never capture");
    assert_eq!(rt.stats.recordings_captured.get(), 0);
    assert_eq!(rt.stats.replay_hits.get(), 0);
    assert_eq!(rt.stats.replay_fallbacks.get(), 0);
    let preds = spec.predecessor_edges();
    for (i, log) in logs.iter().enumerate() {
        assert!(log.all_ran(), "iteration {i} lost a task");
        assert!(log.dependence_violations(&preds).is_empty(), "iteration {i}");
    }
}

/// A submission stream whose dependence structure differs from the
/// recording must fall back to full resolution — and still run every
/// body. The matching stream afterwards must replay.
#[test]
fn stream_hash_mismatch_falls_back_to_resolution() {
    let hits = Arc::new(AtomicU64::new(0));
    let mk = |n: u64, hits: &Arc<AtomicU64>| -> Vec<ReplayTask> {
        (0..4u64)
            .map(|i| {
                let h = Arc::clone(hits);
                ReplayTask::new(vec![dep_inout(900 + i % n)], "hash-drill", move || {
                    h.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect()
    };
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(2)
        .record_graphs(true)
        .build();
    let rec = ts.record_iteration(mk(2, &hits)).expect("iteration 0 captures");
    // Four distinct regions instead of two chained pairs: different
    // structure, different stream hash, resolved fallback.
    assert_eq!(ts.replay(&rec, mk(4, &hits)), ReplayOutcome::FellBack);
    // The original stream shape replays.
    assert_eq!(ts.replay(&rec, mk(2, &hits)), ReplayOutcome::Replayed);
    let rt = Arc::clone(ts.runtime());
    ts.shutdown();
    assert_eq!(rt.stats.replay_fallbacks.get(), 1);
    assert_eq!(rt.stats.replay_hits.get(), 1);
    assert_eq!(hits.load(Ordering::SeqCst), 12, "all three iterations ran every body");
}

/// A task failed during replay must poison its *recorded* successor cone
/// exactly like a resolved run poisons dependents: with TaskBody injection
/// always on, each iteration fails the chain head and the independent
/// task (the only bodies that run) and cancels the five chain successors.
/// Broken propagation on the replay side would instead run — and fail —
/// all fourteen tasks (failed=14, cancelled=5).
#[test]
fn replay_failure_poisons_recorded_cone() {
    let plan = Arc::new(FaultPlan::new(0xBAD).with_rate(FaultSite::TaskBody, FAULT_ALWAYS));
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(2)
        .record_graphs(true)
        .fault_plan(plan)
        .build();
    let mk = || -> Vec<ReplayTask> {
        let mut v: Vec<ReplayTask> =
            (0..6).map(|_| ReplayTask::new(vec![dep_inout(77)], "chain", || {})).collect();
        v.push(ReplayTask::new(vec![dep_inout(99)], "independent", || {}));
        v
    };
    let rec = ts.record_iteration(mk()).expect("iteration 0 captures");
    assert_eq!(ts.replay(&rec, mk()), ReplayOutcome::Replayed);
    let rt = Arc::clone(ts.runtime());
    ts.shutdown();
    assert_eq!(rt.stats.tasks_failed.get(), 4, "chain head + independent, both iterations");
    assert_eq!(rt.stats.tasks_cancelled.get(), 10, "five-task cone, both iterations");
    assert_eq!(rt.stats.tasks_executed.get(), 0, "no body completes under FAULT_ALWAYS");
}

/// Replay must compose with the parking taskwait: two parallel spinners
/// feeding a joined finale leave the replay driver idle whenever a pool
/// worker runs the tail, so across enough rounds the driver parks and a
/// recorded-successor finalize delivers the child-completion wake edge.
/// Counter deltas are taken after the recorded iteration so the parks are
/// attributable to *replayed* iterations.
#[test]
fn replay_interleaves_with_parked_taskwait() {
    fn spin_us(us: u64) {
        let t0 = std::time::Instant::now();
        while t0.elapsed().as_micros() < u128::from(us) {
            std::hint::spin_loop();
        }
    }
    for kind in [RuntimeKind::Ddast, RuntimeKind::CentralDast, RuntimeKind::GompLike] {
        let ts = TaskSystem::builder()
            .kind(kind)
            .num_threads(3)
            .record_graphs(true)
            .build();
        let mk = || -> Vec<ReplayTask> {
            vec![
                ReplayTask::new(vec![dep_inout(501)], "spin-a", || spin_us(300)),
                ReplayTask::new(vec![dep_inout(502)], "spin-b", || spin_us(300)),
                ReplayTask::new(
                    vec![dep_inout(501), dep_inout(502)],
                    "finale",
                    || spin_us(50),
                ),
            ]
        };
        let rec = ts.record_iteration(mk()).expect("iteration 0 captures");
        let rt = Arc::clone(ts.runtime());
        let parks0 = rt.stats.taskwait_parks.get();
        let wakes0 = rt.stats.taskwait_wake_edges.get();
        let mut rounds = 0u64;
        while rounds < 200 {
            assert_eq!(ts.replay(&rec, mk()), ReplayOutcome::Replayed, "{kind:?}");
            rounds += 1;
            if rt.stats.taskwait_parks.get() > parks0
                && rt.stats.taskwait_wake_edges.get() > wakes0
            {
                break;
            }
        }
        ts.shutdown();
        assert!(
            rt.stats.taskwait_parks.get() > parks0,
            "{kind:?}: the replay driver never parked in {rounds} rounds"
        );
        assert!(
            rt.stats.taskwait_wake_edges.get() > wakes0,
            "{kind:?}: no wake edge reached the parked driver"
        );
        assert_eq!(
            rt.stats.tasks_executed.get(),
            3 * (rounds + 1),
            "{kind:?}: every iteration ran all three tasks"
        );
    }
}
