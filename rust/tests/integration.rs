//! Integration tests: the whole runtime stack across organizations,
//! thread counts and workloads (DESIGN.md §6 invariants #1–#5).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use ddast::coordinator::{DdastParams, DepMode, RuntimeKind, TaskSystem, WdState};
use ddast::workloads::{executor, matmul, nbody, sparselu, synthetic};

const ALL_KINDS: [RuntimeKind; 3] =
    [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike];

fn check_spec(
    kind: RuntimeKind,
    threads: usize,
    spec: ddast::workloads::TaskGraphSpec,
) -> Arc<ddast::coordinator::RuntimeShared> {
    let spec = Arc::new(spec);
    let ts = TaskSystem::builder().kind(kind).num_threads(threads).build();
    let log = executor::run_spec(&ts, &spec, executor::ExecOptions::default());
    let rt = ts.runtime().clone();
    ts.shutdown();
    assert!(log.all_ran(), "{}/{kind:?}: not all tasks ran", spec.name);
    let violations = log.dependence_violations(&spec.predecessor_edges());
    assert!(violations.is_empty(), "{}/{kind:?}: {violations:?}", spec.name);
    assert!(rt.quiescent(), "{}/{kind:?}: runtime not quiescent", spec.name);
    assert_eq!(rt.stats.tasks_created.get(), spec.num_tasks() as u64);
    assert_eq!(rt.stats.tasks_executed.get(), spec.num_tasks() as u64);
    rt
}

#[test]
fn matmul_all_kinds_and_thread_counts() {
    for kind in ALL_KINDS {
        for threads in [1, 2, 4] {
            check_spec(kind, threads, matmul::generate(matmul::MatmulParams { ms: 512, bs: 64 }));
        }
    }
}

#[test]
fn sparselu_all_kinds() {
    for kind in ALL_KINDS {
        check_spec(kind, 4, sparselu::generate(sparselu::SparseLuParams { ms: 512, bs: 64 }));
    }
}

#[test]
fn nbody_nested_all_kinds() {
    let p = nbody::NBodyParams { num_particles: 1024, timesteps: 3, bs: 128 };
    for kind in ALL_KINDS {
        check_spec(kind, 3, nbody::generate(p));
    }
}

#[test]
fn ddast_uses_managers_sync_does_not() {
    let rt = check_spec(RuntimeKind::Ddast, 4, synthetic::diamonds(8, 50, 0));
    assert!(rt.stats.mgr_activations.get() > 0);
    assert_eq!(rt.queues.pending(), 0);
    let rt = check_spec(RuntimeKind::Sync, 4, synthetic::diamonds(8, 50, 0));
    assert_eq!(rt.stats.mgr_activations.get(), 0, "sync never dispatches managers");
}

#[test]
fn max_ddast_threads_cap_is_respected() {
    for cap in [1usize, 2] {
        let spec = Arc::new(synthetic::independent(5_000, 0));
        let ts = TaskSystem::builder()
            .kind(RuntimeKind::Ddast)
            .num_threads(4)
            .params(DdastParams {
                max_ddast_threads: cap,
                max_spins: 2,
                max_ops_thread: 4,
                min_ready_tasks: 2,
            })
            .build();
        executor::run_spec(&ts, &spec, executor::ExecOptions::default());
        let rt = ts.runtime().clone();
        ts.shutdown();
        let peak = rt.stats.mgr_peak.get();
        assert!(peak <= cap as u64, "peak {peak} exceeded cap {cap}");
        assert!(peak >= 1, "managers must have run");
    }
}

#[test]
fn taskwait_waits_for_exactly_current_children() {
    let ts = TaskSystem::new_ddast(3);
    let counter = Arc::new(AtomicU64::new(0));
    let ts2 = ts.clone();
    let c2 = Arc::clone(&counter);
    ts.spawn(&[], move || {
        for _ in 0..50 {
            let c = Arc::clone(&c2);
            ts2.spawn(&[], move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        ts2.taskwait();
        // All 50 children of *this* task done; outer tasks may still run.
        assert_eq!(c2.load(Ordering::SeqCst) % 1000, 50);
        c2.fetch_add(1000, Ordering::SeqCst);
    });
    ts.taskwait();
    assert_eq!(counter.load(Ordering::SeqCst), 1050);
    ts.shutdown();
}

#[test]
fn dependent_chain_result_equals_sequential() {
    // A computation whose result is order-sensitive: x = (((1*2)+3)*2)+3...
    for kind in ALL_KINDS {
        let ts = TaskSystem::builder().kind(kind).num_threads(4).build();
        let x = Arc::new(AtomicU64::new(1));
        for step in 0..40 {
            let x = Arc::clone(&x);
            ts.spawn(&[(0xAA, DepMode::Inout)], move || {
                let _ = x.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                    Some(if step % 2 == 0 { v * 2 } else { v + 3 })
                });
            });
        }
        ts.taskwait();
        // Sequential reference.
        let mut want = 1u64;
        for step in 0..40 {
            want = if step % 2 == 0 { want * 2 } else { want + 3 };
        }
        assert_eq!(x.load(Ordering::SeqCst), want, "{kind:?}");
        ts.shutdown();
    }
}

#[test]
fn deletion_protocol_terminal_states() {
    let ts = TaskSystem::new_ddast(2);
    let spec = Arc::new(synthetic::nested(3, 8, 0));
    executor::run_spec(&ts, &spec, executor::ExecOptions::default());
    let rt = ts.runtime().clone();
    ts.shutdown();
    assert_eq!(rt.stats.tasks_outstanding.get(), 0);
    // Root never finishes (it is the program), but it must have no live
    // children and an empty graph.
    assert_eq!(rt.root.children_live(), 0);
    assert_eq!(rt.root.child_domain_opt().map_or(0, |d| d.tasks_in_graph()), 0);
}

#[test]
fn readers_run_concurrently_after_writer() {
    let ts = TaskSystem::new_ddast(4);
    let writer_done = Arc::new(AtomicU64::new(0));
    let w = Arc::clone(&writer_done);
    ts.spawn(&[(0xBB, DepMode::Out)], move || {
        w.store(1, Ordering::SeqCst);
    });
    let reads_ok = Arc::new(AtomicU64::new(0));
    for _ in 0..20 {
        let w = Arc::clone(&writer_done);
        let r = Arc::clone(&reads_ok);
        ts.spawn(&[(0xBB, DepMode::In)], move || {
            assert_eq!(w.load(Ordering::SeqCst), 1, "reader ran before writer");
            r.fetch_add(1, Ordering::SeqCst);
        });
    }
    ts.taskwait();
    assert_eq!(reads_ok.load(Ordering::SeqCst), 20);
    ts.shutdown();
}

#[test]
fn initial_vs_tuned_params_both_complete() {
    for params in [DdastParams::initial(), DdastParams::tuned(4)] {
        let spec = Arc::new(synthetic::random_dag(2_000, 17, 99));
        let ts = TaskSystem::builder()
            .kind(RuntimeKind::Ddast)
            .num_threads(4)
            .params(params)
            .build();
        let log = executor::run_spec(&ts, &spec, executor::ExecOptions::default());
        ts.shutdown();
        assert!(log.all_ran());
        assert!(log.dependence_violations(&spec.predecessor_edges()).is_empty());
    }
}

#[test]
fn tracing_records_consistent_task_spans() {
    let spec = Arc::new(synthetic::independent(200, 1_000));
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(2)
        .tracing(true)
        .build();
    executor::run_spec(&ts, &spec, executor::ExecOptions::default());
    let rt = ts.runtime().clone();
    ts.shutdown();
    let events = rt.tracer.as_ref().unwrap().merged();
    let starts = events
        .iter()
        .filter(|e| matches!(e.kind, ddast::coordinator::TraceKind::TaskStart { .. }))
        .count();
    let ends = events
        .iter()
        .filter(|e| matches!(e.kind, ddast::coordinator::TraceKind::TaskEnd { .. }))
        .count();
    assert_eq!(starts, 200);
    assert_eq!(ends, 200);
}

#[test]
fn wd_states_progress_to_deletable() {
    // Directly observe a WD through the life cycle (paper §2.2.1 + §3.1).
    let ts = TaskSystem::new_sync(1);
    let rt = ts.runtime().clone();
    let root = Arc::clone(&rt.root);
    let wd = rt.spawn_from(0, &root, vec![ddast::coordinator::dep_out(0xCC)], "t", Box::new(|| {}));
    ts.taskwait();
    assert_eq!(wd.state(), WdState::Deletable);
    ts.shutdown();
}

#[test]
fn central_dast_variant_runs_workloads() {
    // The authors' earlier centralized design [7]: dedicated manager thread.
    let rt = check_spec(
        RuntimeKind::CentralDast,
        3,
        matmul::generate(matmul::MatmulParams { ms: 512, bs: 64 }),
    );
    assert!(rt.stats.mgr_activations.get() > 0, "the DAS thread must have drained");
    let rt = check_spec(RuntimeKind::CentralDast, 2, synthetic::nested(4, 10, 0));
    assert_eq!(rt.queues.pending(), 0);
}

#[test]
fn autotuner_raises_managers_under_backlog() {
    // Force a pathological configuration (1 manager, deep backlog) and let
    // the §8 auto-tuner fix it.
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(4)
        .params(DdastParams {
            max_ddast_threads: 1,
            max_spins: 1,
            max_ops_thread: 2,
            min_ready_tasks: 1,
        })
        .autotune(true)
        .autotune_interval(std::time::Duration::from_micros(200))
        .build();
    let spec = Arc::new(synthetic::independent(50_000, 0));
    let log = executor::run_spec(&ts, &spec, executor::ExecOptions::default());
    let tuner = ts.autotuner().expect("enabled").clone();
    let rt = ts.runtime().clone();
    ts.shutdown();
    assert!(log.all_ran());
    assert!(
        tuner.raises.get() > 0,
        "backlog of 50k messages should trigger at least one raise"
    );
    assert!(rt.tunables().snapshot().max_ddast_threads > 1);
}

#[test]
fn manager_affinity_restricts_which_workers_manage() {
    // big.LITTLE adaptation (§8): only worker 1 may become a manager.
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(3)
        .manager_affinity(vec![1])
        .tracing(true)
        .build();
    let spec = Arc::new(synthetic::independent(2_000, 0));
    let log = executor::run_spec(&ts, &spec, executor::ExecOptions::default());
    let rt = ts.runtime().clone();
    ts.shutdown();
    assert!(log.all_ran());
    assert!(rt.stats.mgr_activations.get() > 0);
    // Trace must show manager states only on worker 1.
    let managers: std::collections::HashSet<usize> = rt
        .tracer
        .as_ref()
        .unwrap()
        .merged()
        .iter()
        .filter_map(|e| match e.kind {
            ddast::coordinator::TraceKind::State {
                worker,
                state: ddast::coordinator::ThreadState::Manager,
                ..
            } => Some(worker),
            _ => None,
        })
        .collect();
    assert!(!managers.is_empty());
    assert!(managers.iter().all(|&w| w == 1), "managers on {managers:?}");
}

#[test]
fn ranged_plugin_orders_overlapping_regions() {
    use ddast::coordinator::Dependence;
    use ddast::substrate::RegionKey;
    // Writer on [0, 100), reader on [50, 150): exact-match would MISS this
    // conflict; the ranged plugin must order them.
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(3)
        .ranged_deps(true)
        .build();
    let flag = Arc::new(AtomicU64::new(0));
    let f = Arc::clone(&flag);
    ts.spawn_full(
        vec![Dependence::new(RegionKey::new(0, 100), DepMode::Out)],
        "writer",
        move || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            f.store(1, Ordering::SeqCst);
        },
    );
    let f = Arc::clone(&flag);
    let seen = Arc::new(AtomicU64::new(0));
    let s = Arc::clone(&seen);
    ts.spawn_full(
        vec![Dependence::new(RegionKey::new(50, 100), DepMode::In)],
        "reader",
        move || s.store(f.load(Ordering::SeqCst), Ordering::SeqCst),
    );
    ts.taskwait();
    assert_eq!(seen.load(Ordering::SeqCst), 1, "overlap ordering violated");
    ts.shutdown();
}

#[test]
fn ranged_plugin_allows_disjoint_parallelism() {
    use ddast::coordinator::Dependence;
    use ddast::substrate::RegionKey;
    let ts = TaskSystem::builder().kind(RuntimeKind::Sync).num_threads(2).ranged_deps(true).build();
    let count = Arc::new(AtomicU64::new(0));
    for i in 0..50u64 {
        let c = Arc::clone(&count);
        ts.spawn_full(
            vec![Dependence::new(RegionKey::new(i * 100, 100), DepMode::Inout)],
            "disjoint",
            move || {
                c.fetch_add(1, Ordering::SeqCst);
            },
        );
    }
    ts.taskwait();
    assert_eq!(count.load(Ordering::SeqCst), 50);
    ts.shutdown();
}

#[test]
fn ranged_plugin_agrees_with_exact_on_addr_keys() {
    // On address-only keys the two plugins must produce identical orders.
    for ranged in [false, true] {
        let spec = Arc::new(synthetic::random_dag(500, 11, 4242));
        let ts = TaskSystem::builder()
            .kind(RuntimeKind::Ddast)
            .num_threads(3)
            .ranged_deps(ranged)
            .build();
        let log = executor::run_spec(&ts, &spec, executor::ExecOptions::default());
        ts.shutdown();
        assert!(log.all_ran(), "ranged={ranged}");
        assert!(
            log.dependence_violations(&spec.predecessor_edges()).is_empty(),
            "ranged={ranged}"
        );
    }
}
