//! Property-based tests (hand-rolled harness — proptest is unavailable in
//! this offline environment; `Cases` drives seeded random instances with
//! failure-seed reporting).
//!
//! Properties (DESIGN.md §6):
//!  1. serial equivalence — every execution respects an *independently
//!     computed* dependence oracle;
//!  2. exactly-once execution, quiescent shutdown;
//!  3. sim/real agreement on completion counts;
//!  4. SPSC queues are FIFO under contention;
//!  5. the dependence graph matches a naive O(n²) conflict oracle.

use std::sync::Arc;

use ddast::coordinator::{DepMode, Dependence, RuntimeKind, TaskSystem};
use ddast::sim::engine::{simulate, SimOptions};
use ddast::sim::machine::MachineConfig;
use ddast::substrate::XorShift64;
use ddast::workloads::spec::{TaskGraphSpec, TaskSpec};
use ddast::workloads::{executor, synthetic};

/// Tiny property-test driver: runs `f` over `n` seeded cases, reporting the
/// failing seed.
fn cases(n: u64, f: impl Fn(u64)) {
    for seed in 1..=n {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if let Err(e) = result {
            panic!("property failed for seed {seed}: {e:?}");
        }
    }
}

/// Independent O(n²) dependence oracle: task j depends on i < j iff their
/// dependence lists conflict *and* no later writer of the conflicting
/// region supersedes i... conservatively, we check ORDER not edges: for
/// every conflicting pair (i, j), i must complete before j starts.
fn conflicting_pairs(spec: &TaskGraphSpec) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for j in 0..spec.tasks.len() {
        for i in 0..j {
            // Only same-scope tasks are ordered by the graph.
            let same_scope = {
                let scope = |t: &TaskSpec| {
                    spec.tasks
                        .iter()
                        .position(|p| p.children.contains(&t.id))
                        .unwrap_or(usize::MAX)
                };
                scope(&spec.tasks[i]) == scope(&spec.tasks[j])
            };
            if !same_scope {
                continue;
            }
            let conflict = spec.tasks[i]
                .deps
                .iter()
                .any(|a| spec.tasks[j].deps.iter().any(|b| a.conflicts(b)));
            if conflict {
                out.push((i, j));
            }
        }
    }
    out
}

fn random_spec(seed: u64, n: usize, regions: u64) -> TaskGraphSpec {
    synthetic::random_dag(n, regions, seed)
}

#[test]
fn prop_serial_equivalence_vs_conflict_oracle() {
    cases(12, |seed| {
        let spec = Arc::new(random_spec(seed, 120, 7));
        let pairs = conflicting_pairs(&spec);
        for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
            let ts = TaskSystem::builder()
                .kind(kind)
                .num_threads(1 + (seed as usize % 4))
                .seed(seed)
                .build();
            let log = executor::run_spec(&ts, &spec, executor::ExecOptions::default());
            ts.shutdown();
            assert!(log.all_ran());
            for &(i, j) in &pairs {
                let i_end = log.end[i].load(std::sync::atomic::Ordering::SeqCst);
                let j_start = log.start[j].load(std::sync::atomic::Ordering::SeqCst);
                assert!(
                    i_end < j_start,
                    "{kind:?}: conflicting pair ({i},{j}) overlapped (seed {seed})"
                );
            }
        }
    });
}

#[test]
fn prop_exactly_once_and_quiescent() {
    cases(10, |seed| {
        let mut rng = XorShift64::new(seed);
        let n = 50 + rng.next_below(400) as usize;
        let spec = Arc::new(random_spec(seed.wrapping_mul(31), n, 1 + rng.next_below(20)));
        let kind = match seed % 3 {
            0 => RuntimeKind::Sync,
            1 => RuntimeKind::Ddast,
            _ => RuntimeKind::GompLike,
        };
        let ts = TaskSystem::builder().kind(kind).num_threads(3).seed(seed).build();
        let log = executor::run_spec(&ts, &spec, executor::ExecOptions::default());
        let rt = ts.runtime().clone();
        ts.shutdown();
        assert!(log.all_ran(), "seed {seed}");
        assert_eq!(rt.stats.tasks_executed.get(), n as u64, "seed {seed}");
        assert!(rt.quiescent(), "seed {seed}");
        assert_eq!(rt.queues.pending(), 0, "seed {seed}");
    });
}

#[test]
fn prop_sim_and_real_execute_same_task_count() {
    cases(8, |seed| {
        let spec = random_spec(seed, 150, 9);
        let m = MachineConfig::power9();
        for kind in [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::GompLike] {
            let mut opt = SimOptions::new(kind, 8);
            opt.seed = seed;
            let r = simulate(&spec, &m, opt);
            assert_eq!(r.stats.tasks_executed as usize, spec.num_tasks(), "seed {seed} {kind:?}");
        }
        // Real runtime on the same spec.
        let spec = Arc::new(spec);
        let ts = TaskSystem::builder().kind(RuntimeKind::Ddast).num_threads(2).build();
        let log = executor::run_spec(&ts, &spec, executor::ExecOptions::default());
        ts.shutdown();
        assert!(log.all_ran());
    });
}

#[test]
fn prop_spsc_fifo_under_contention() {
    use ddast::substrate::SpscQueue;
    cases(6, |seed| {
        use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
        let q = Arc::new(SpscQueue::new());
        let n = 30_000usize;
        let stop = Arc::new(AtomicBool::new(false));
        let popped = Arc::new(AtomicUsize::new(0));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                let stop = Arc::clone(&stop);
                let popped = Arc::clone(&popped);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while !stop.load(Ordering::Acquire) {
                        if let Some(mut g) = q.try_acquire() {
                            let mut batch = 0;
                            while let Some(v) = g.pop() {
                                got.push(v);
                                popped.fetch_add(1, Ordering::AcqRel);
                                batch += 1;
                                if batch == 64 {
                                    break; // release the token mid-stream
                                }
                            }
                        }
                        std::thread::yield_now();
                    }
                    got
                })
            })
            .collect();
        let mut rng = XorShift64::new(seed);
        for i in 0..n {
            q.push(i);
            if rng.next_below(100) == 0 {
                std::thread::yield_now();
            }
        }
        while popped.load(Ordering::Acquire) < n {
            std::thread::yield_now();
        }
        stop.store(true, Ordering::Release);
        let mut all: Vec<usize> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        assert_eq!(all.len(), n, "seed {seed}: lost or duplicated messages");
        // Each consumer's local order must be increasing and globally the
        // multiset is exactly 0..n.
        let mut sorted = all.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "seed {seed}");
    });
}

#[test]
fn prop_depgraph_matches_naive_oracle_edges() {
    // The graph's computed predecessor count for each task must equal the
    // naive oracle: |{latest conflicting accessors not yet finished}| — we
    // check the weaker but exact invariant that a task becomes ready iff
    // all earlier conflicting tasks finished.
    cases(10, |seed| {
        use ddast::coordinator::{DepDomain, TaskId, Wd, WdState};
        use std::sync::Weak;
        let mut rng = XorShift64::new(seed);
        let n = 60;
        let mut deps_of: Vec<Vec<Dependence>> = Vec::new();
        for _ in 0..n {
            let ndeps = 1 + rng.next_below(3);
            let deps = (0..ndeps)
                .map(|_| {
                    let r = rng.next_below(6);
                    let mode = match rng.next_below(3) {
                        0 => DepMode::In,
                        1 => DepMode::Out,
                        _ => DepMode::Inout,
                    };
                    Dependence::addr(0x9000 + r, mode)
                })
                .collect();
            deps_of.push(deps);
        }
        let domain = DepDomain::new();
        let wds: Vec<Arc<Wd>> = deps_of
            .iter()
            .enumerate()
            .map(|(i, d)| {
                Wd::new(TaskId(i as u64 + 1), d.clone(), "p", Weak::new(), Box::new(|| {}))
            })
            .collect();
        let mut ready: Vec<bool> = Vec::new();
        for wd in &wds {
            ready.push(domain.submit(wd));
        }
        // Retire in submission order; at each step the set of ready tasks
        // must equal the oracle's.
        let mut finished = vec![false; n];
        for i in 0..n {
            // Oracle: i is ready iff every earlier conflicting j finished.
            let oracle_ready = |i: usize, finished: &[bool]| {
                (0..i).all(|j| {
                    finished[j]
                        || !deps_of[i]
                            .iter()
                            .any(|a| deps_of[j].iter().any(|b| a.conflicts(b)))
                })
            };
            assert_eq!(
                ready[i],
                oracle_ready(i, &finished),
                "seed {seed}: task {i} readiness mismatch"
            );
            assert!(ready[i], "by induction, retiring in order keeps head ready");
            wds[i].set_state(WdState::Ready);
            wds[i].set_state(WdState::Running);
            wds[i].set_state(WdState::Finished);
            for released in domain.finish(&wds[i]) {
                ready[released.id.0 as usize - 1] = true;
            }
            finished[i] = true;
        }
        assert_eq!(domain.tasks_in_graph(), 0, "seed {seed}");
    });
}

#[test]
fn prop_sim_deterministic_and_monotone_in_threads() {
    cases(5, |seed| {
        let spec = synthetic::independent(3_000, 100_000);
        let m = MachineConfig::knl();
        let mut opt1 = SimOptions::new(RuntimeKind::Ddast, 4);
        opt1.seed = seed;
        let a = simulate(&spec, &m, opt1);
        let b = simulate(&spec, &m, opt1);
        assert_eq!(a.makespan, b.makespan, "seed {seed}: sim not deterministic");
        let mut opt2 = SimOptions::new(RuntimeKind::Ddast, 32);
        opt2.seed = seed;
        let c = simulate(&spec, &m, opt2);
        assert!(
            c.makespan < a.makespan,
            "seed {seed}: more threads should shrink an independent-task makespan"
        );
    });
}
