//! Integration guards for the topology plane (EXPERIMENTS.md §Topology):
//!
//! * an injected [`Topology`] shapes a *real* pool end-to-end — the runtime
//!   and its two-level signal directory both take the socket layout — and
//!   dependence workloads stay correct on every organization under it;
//! * `request_shutdown` traverses both directory levels: a pool whose 128
//!   workers are parked across four sockets joins cleanly (a wake that only
//!   scanned socket 0 would hang this test);
//! * `wait_for` on a cross-socket predecessor completes via the
//!   dependence-targeted wake edge (`dep_wake_edges` fires when the waiter
//!   actually parked on the edge rather than running the task inline).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use ddast::coordinator::{DepMode, RuntimeKind, TaskSystem};
use ddast::substrate::Topology;

/// A forced 4 × 2 topology on an 8-thread pool must reach both the runtime
/// descriptor and the signal directory, and dependence chains must still
/// execute in program order on every organization under the split layout.
#[test]
fn injected_topology_shapes_every_organization() {
    for kind in
        [RuntimeKind::Sync, RuntimeKind::Ddast, RuntimeKind::CentralDast, RuntimeKind::GompLike]
    {
        let ts = TaskSystem::builder()
            .kind(kind)
            .num_threads(8)
            .topology(Topology::new(4, 2))
            .build();
        let rt = ts.runtime();
        assert_eq!(rt.topo.sockets(), 4, "kind={kind:?}: runtime took the injected shape");
        assert_eq!(
            rt.queues.signals().sockets(),
            4,
            "kind={kind:?}: directory split into the injected sockets"
        );

        // Doubling chain: 2^16 only if every predecessor ran first.
        let v = Arc::new(AtomicU64::new(1));
        for _ in 0..16 {
            let v = Arc::clone(&v);
            ts.spawn(&[(7, DepMode::Inout)], move || {
                v.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |x| Some(x * 2)).unwrap();
            });
        }
        // Plus independent fan-out so ready pushes exercise the
        // locality-biased wake path on more than one socket.
        let hits = Arc::new(AtomicU64::new(0));
        for i in 0..64u64 {
            let h = Arc::clone(&hits);
            ts.spawn(&[(100 + i, DepMode::Out)], move || {
                h.fetch_add(1, Ordering::Relaxed);
            });
        }
        ts.taskwait();
        assert_eq!(v.load(Ordering::SeqCst), 1 << 16, "kind={kind:?}: chain order held");
        assert_eq!(hits.load(Ordering::Relaxed), 64, "kind={kind:?}: fan-out drained");
        assert!(rt.quiescent(), "kind={kind:?}");
        ts.shutdown();
    }
}

/// Shutdown must join a pool whose 128 workers are parked across the four
/// sockets of a 4 × 32 directory. `request_shutdown` broadcasts through
/// `wake_all`, which has to walk *both* directory levels — every socket's
/// summary bit and every word under it; missing a remote socket leaves its
/// workers parked forever and hangs (times out) this test.
#[test]
fn shutdown_joins_128_parked_workers_across_sockets() {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(128)
        .topology(Topology::new(4, 32))
        .build();
    let rt = ts.runtime();
    assert_eq!(rt.queues.signals().sockets(), 4);

    // A little work so the pool is warm, then an idle window in which the
    // workers walk the spin/yield ladder and park. Wait (bounded) until a
    // healthy majority of them actually committed a park so the shutdown
    // broadcast genuinely has cross-socket parked bits to clear.
    let hits = Arc::new(AtomicU64::new(0));
    for i in 0..256u64 {
        let h = Arc::clone(&hits);
        ts.spawn(&[(i % 16, DepMode::Inout)], move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
    }
    ts.taskwait();
    assert_eq!(hits.load(Ordering::Relaxed), 256);
    let mut tries = 0;
    while rt.queues.signals().parked_count() < 96 && tries < 500 {
        std::thread::sleep(Duration::from_millis(2));
        tries += 1;
    }
    assert!(
        rt.queues.signals().parked_count() >= 96,
        "most of the 128 workers parked during the idle window"
    );
    ts.shutdown(); // must wake all four sockets and join all 128 threads
}

/// End-to-end dependence-targeted wake: worker 0 blocks in `wait_for` on a
/// predecessor that another worker is executing. When the waiter really
/// parks (rather than stealing the predecessor and running it inline), the
/// predecessor's finalizer must fire the point-to-point wake edge — counted
/// by `dep_wake_edges`. Which thread gets the task is a scheduling race, so
/// rounds repeat until an edge fires, bounded so a broken wake path fails
/// fast instead of hanging.
#[test]
fn wait_for_fires_dependence_targeted_wake_edge_end_to_end() {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(2)
        .topology(Topology::new(2, 1)) // waiter and executor on different sockets
        .build();
    let rt = Arc::clone(ts.runtime());
    assert_eq!(rt.queues.signals().sockets(), 2);

    let mut fired = false;
    for _ in 0..40 {
        // Spawn the predecessor from *inside* another task so it lands on
        // the executing worker's deque, not the main thread's — otherwise
        // `wait_for` would always pop it locally and never park.
        let (tx, rx) = mpsc::channel();
        let ts2 = ts.clone();
        ts.spawn(&[], move || {
            let pred = ts2.spawn_handle(vec![], "slow-pred", || {
                std::thread::sleep(Duration::from_millis(15));
            });
            tx.send(pred).unwrap();
        });
        let pred = rx.recv().unwrap();
        ts.wait_for(&pred);
        assert!(pred.done_handled(), "wait_for returned only after finalization");
        if rt.stats.dep_wake_edges.get() > 0 {
            fired = true;
            break;
        }
    }
    assert!(fired, "at least one round parked on the edge and was woken point-to-point");
    ts.taskwait();
    assert!(rt.quiescent());
    ts.shutdown();
}

/// `DDAST_TOPOLOGY`-style env injection is covered at the unit level in
/// `substrate/topology.rs`; here we pin the builder override *beating* any
/// ambient detection, since CI exports the variable while running this
/// binary: an explicit `.topology(..)` must win.
#[test]
fn explicit_topology_overrides_detection() {
    let ts = TaskSystem::builder()
        .kind(RuntimeKind::Ddast)
        .num_threads(6)
        .topology(Topology::new(3, 2))
        .build();
    assert_eq!(ts.runtime().topo.sockets(), 3);
    assert_eq!(ts.runtime().topo.workers_per_socket(), 2);
    let hits = Arc::new(AtomicU64::new(0));
    for _ in 0..32 {
        let h = Arc::clone(&hits);
        ts.spawn(&[], move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
    }
    ts.taskwait();
    assert_eq!(hits.load(Ordering::Relaxed), 32);
    ts.shutdown();
}
