//! Executable versions of the paper's qualitative claims (EXPERIMENTS.md):
//! each test pins one evaluation-section shape on reduced-size workloads so
//! regressions in the runtime or the cost model are caught by `cargo test`.

use ddast::coordinator::{DdastParams, RuntimeKind};
use ddast::sim::engine::{simulate, SimOptions};
use ddast::sim::machine::MachineConfig;
use ddast::workloads::{matmul, nbody, sparselu};

fn speedup(
    spec: &ddast::workloads::TaskGraphSpec,
    m: &MachineConfig,
    kind: RuntimeKind,
    threads: usize,
) -> f64 {
    simulate(spec, m, SimOptions::new(kind, threads)).speedup
}

/// Fig 9a/9b: DDAST outperforms the Nanos++ baseline on fine-grain Matmul
/// at the full KNL thread count (paper: ~40 %; we accept ≥ 15 % at reduced
/// problem size).
#[test]
fn fig9_ddast_beats_nanos_on_knl_matmul_fg() {
    let m = MachineConfig::knl();
    let spec = matmul::generate(matmul::MatmulParams { ms: 4096, bs: 256 });
    let sync = speedup(&spec, &m, RuntimeKind::Sync, 64);
    let ddast = speedup(&spec, &m, RuntimeKind::Ddast, 64);
    assert!(
        ddast > sync * 1.15,
        "DDAST {ddast:.2} should beat Nanos++ {sync:.2} by >15%"
    );
}

/// Fig 9d–f: coarse grain at low thread counts — all runtimes similar
/// (within 25 %).
#[test]
fn fig9_cg_low_threads_similar() {
    let m = MachineConfig::power9();
    let spec = matmul::generate(matmul::MatmulParams { ms: 4096, bs: 512 });
    let s = speedup(&spec, &m, RuntimeKind::Sync, 8);
    let d = speedup(&spec, &m, RuntimeKind::Ddast, 8);
    let g = speedup(&spec, &m, RuntimeKind::GompLike, 8);
    for (name, v) in [("ddast", d), ("gomp", g)] {
        let ratio = v / s;
        assert!((0.75..1.6).contains(&ratio), "{name} ratio {ratio:.2} vs sync");
    }
}

/// Fig 10: SparseLU — DDAST achieves performance similar to (or better
/// than) Nanos++ despite the irregular graph.
#[test]
fn fig10_sparselu_ddast_not_worse() {
    let m = MachineConfig::thunderx();
    let spec = sparselu::generate(sparselu::SparseLuParams { ms: 4096, bs: 128 });
    let sync = speedup(&spec, &m, RuntimeKind::Sync, 48);
    let ddast = speedup(&spec, &m, RuntimeKind::Ddast, 48);
    assert!(ddast > sync * 0.9, "DDAST {ddast:.2} vs Nanos++ {sync:.2}");
}

/// Fig 11a: N-Body FG on KNL — Nanos++ performance stands still between
/// 16 and 64 threads while DDAST maintains or increases it.
#[test]
fn fig11_nbody_fg_knl_standstill_vs_ddast() {
    let m = MachineConfig::knl();
    let spec = nbody::generate(nbody::NBodyParams {
        num_particles: 16_384,
        timesteps: 4, // reduced from 16: same per-timestep structure
        bs: 64,
    });
    let sync16 = speedup(&spec, &m, RuntimeKind::Sync, 16);
    let sync64 = speedup(&spec, &m, RuntimeKind::Sync, 64);
    let ddast64 = speedup(&spec, &m, RuntimeKind::Ddast, 64);
    assert!(
        sync64 < sync16 * 1.35,
        "Nanos++ should roughly flatline: {sync16:.2} -> {sync64:.2}"
    );
    assert!(ddast64 > sync64 * 1.2, "DDAST {ddast64:.2} vs Nanos++ {sync64:.2}");
}

/// Fig 11a: GOMP wins at small thread counts on KNL, then collapses from
/// idle-worker contention at 64 threads.
#[test]
fn fig11_gomp_collapse_on_knl() {
    let m = MachineConfig::knl();
    let spec = nbody::generate(nbody::NBodyParams {
        num_particles: 16_384,
        timesteps: 4,
        bs: 64,
    });
    let gomp16 = speedup(&spec, &m, RuntimeKind::GompLike, 16);
    let gomp64 = speedup(&spec, &m, RuntimeKind::GompLike, 64);
    let ddast64 = speedup(&spec, &m, RuntimeKind::Ddast, 64);
    assert!(gomp64 < gomp16, "GOMP must collapse: 16t {gomp16:.2} -> 64t {gomp64:.2}");
    // Paper: DDAST overtakes collapsed GOMP at 64t. Our model gets the
    // collapse but leaves GOMP marginally ahead (documented deviation in
    // EXPERIMENTS.md); assert DDAST is at least competitive (>= 90 %).
    assert!(
        ddast64 > gomp64 * 0.9,
        "DDAST {ddast64:.2} must be competitive with collapsed GOMP {gomp64:.2}"
    );
}

/// Fig 11e: on ThunderX, GOMP never hits the idle-contention point and
/// performs better than both Nanos++-based runtimes.
#[test]
fn fig11_gomp_wins_on_thunderx() {
    let m = MachineConfig::thunderx();
    let spec = nbody::generate(nbody::NBodyParams {
        num_particles: 16_384,
        timesteps: 4,
        bs: 64,
    });
    let sync = speedup(&spec, &m, RuntimeKind::Sync, 48);
    let ddast = speedup(&spec, &m, RuntimeKind::Ddast, 48);
    let gomp = speedup(&spec, &m, RuntimeKind::GompLike, 48);
    assert!(gomp > ddast && ddast > sync, "gomp {gomp:.2} > ddast {ddast:.2} > sync {sync:.2}");
}

/// Fig 12: the in-graph evolution is a pyramid for Nanos++ and a roof for
/// DDAST (an order of magnitude fewer tasks in the runtime structures).
#[test]
fn fig12_pyramid_vs_roof() {
    // Full paper size: the pyramid needs the real task count to tower.
    let m = MachineConfig::knl();
    let spec = matmul::generate(matmul::MatmulParams { ms: 8192, bs: 256 });
    let sync = simulate(&spec, &m, SimOptions::new(RuntimeKind::Sync, 64));
    let ddast = simulate(&spec, &m, SimOptions::new(RuntimeKind::Ddast, 64));
    assert!(
        sync.stats.max_in_graph > 8 * ddast.stats.max_in_graph,
        "pyramid {} vs roof {}",
        sync.stats.max_in_graph,
        ddast.stats.max_in_graph
    );
    assert!(sync.stats.max_ready > 4 * ddast.stats.max_ready);
}

/// Fig 5 (fine-grain subplots): a single manager thread cannot keep up
/// with the incoming messages and the effect vanishes above 2–4 managers.
/// The effect lives where message demand ≈ one manager's capacity — the
/// paper saw it on its FG runs; in our cost model that is ThunderX FG
/// Matmul (150 µs tasks × 48 threads).
#[test]
fn fig5_one_manager_bottleneck() {
    let m = MachineConfig::thunderx();
    let spec = matmul::generate(matmul::MatmulParams { ms: 4096, bs: 64 });
    let with = |mdt: usize| {
        let p = DdastParams { max_ddast_threads: mdt, ..DdastParams::initial() };
        simulate(&spec, &m, SimOptions::new(RuntimeKind::Ddast, 48).with_params(p))
            .makespan
            .as_secs_f64()
    };
    let one = with(1);
    let two = with(2);
    let four = with(4);
    assert!(one > two * 1.2, "1 manager {one:.3}s should lose badly to 2 {two:.3}s");
    assert!(
        (two / four) > 0.9 && (two / four) < 1.1,
        "2 vs 4 managers should be flat: {two:.3} vs {four:.3}"
    );
}

/// Fig 6: MAX_SPINS does not matter (±5 % here; paper ±0.5 % on real HW).
#[test]
fn fig6_max_spins_no_effect() {
    let m = MachineConfig::thunderx();
    let spec = sparselu::generate(sparselu::SparseLuParams { ms: 2048, bs: 128 });
    let with = |spins: u32| {
        let p = DdastParams { max_spins: spins, ..DdastParams::initial() };
        simulate(&spec, &m, SimOptions::new(RuntimeKind::Ddast, 48).with_params(p))
            .makespan
            .as_secs_f64()
    };
    let base = with(20);
    for spins in [1, 4, 64, 128] {
        let r = with(spins) / base;
        assert!((0.95..1.05).contains(&r), "MAX_SPINS={spins}: ratio {r:.3}");
    }
}

/// §6.1: the paper's measured ~1.5× task-body inflation under the sync
/// runtime (cache pollution) is what the cost model encodes.
#[test]
fn sync_task_bodies_inflated_by_pollution() {
    let m = MachineConfig::knl();
    let spec = matmul::generate(matmul::MatmulParams { ms: 2048, bs: 256 });
    let sync = simulate(&spec, &m, SimOptions::new(RuntimeKind::Sync, 32));
    let ddast = simulate(&spec, &m, SimOptions::new(RuntimeKind::Ddast, 32));
    let sync_per_task = sync.stats.task_exec_ns as f64 / sync.stats.tasks_executed as f64;
    let ddast_per_task = ddast.stats.task_exec_ns as f64 / ddast.stats.tasks_executed as f64;
    let ratio = sync_per_task / ddast_per_task;
    assert!(
        (1.25..1.75).contains(&ratio),
        "task-time ratio {ratio:.2} (paper measured ~1.5)"
    );
}
