"""AOT artifact consistency: the built artifacts (if present) match the
MODELS registry and are plain-HLO (CPU-executable)."""

import os

import pytest

from compile.model import MODELS

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "..", "artifacts")


def built():
    return os.path.exists(os.path.join(ART, "MANIFEST.txt"))


@pytest.mark.skipif(not built(), reason="artifacts not built (run `make artifacts`)")
def test_manifest_covers_all_models():
    with open(os.path.join(ART, "MANIFEST.txt")) as f:
        names = {line.split("\t")[0] for line in f if line.strip()}
    missing = set(MODELS) - names
    # Allow the manifest to be older than a freshly added model; it must
    # never list unknown models.
    assert names <= set(MODELS), names - set(MODELS)
    if missing:
        pytest.skip(f"artifacts older than MODELS ({missing}); run `make artifacts`")


@pytest.mark.skipif(not built(), reason="artifacts not built")
def test_artifacts_are_plain_hlo():
    for fname in os.listdir(ART):
        if not fname.endswith(".hlo.txt"):
            continue
        with open(os.path.join(ART, fname)) as f:
            text = f.read()
        assert text.startswith("HloModule"), fname
        assert "tpu_custom_call" not in text, f"{fname} is not CPU-executable"
