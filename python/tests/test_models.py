"""L2 model shape/lowering checks + AOT pipeline sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import to_hlo_text


def test_every_model_lowers_to_hlo_text():
    for name, (fn, specs) in model.MODELS.items():
        text = to_hlo_text(fn, specs)
        assert "HloModule" in text, name
        # No Mosaic custom-calls: interpret-mode pallas lowers to plain HLO.
        assert "tpu_custom_call" not in text, f"{name} not CPU-executable"


def test_model_output_shapes():
    for name, (fn, specs) in model.MODELS.items():
        args = [jnp.zeros(s.shape, s.dtype) + 0.5 for s in specs]
        if name in ("lu0", "fwd", "bdiv"):
            # Need a non-singular diagonal for the solves.
            args[0] = args[0] + jnp.eye(args[0].shape[0], dtype=args[0].dtype) * 8
        out = fn(*args)
        assert isinstance(out, tuple), name
        for o in out:
            assert np.all(np.isfinite(np.asarray(o))), name


def test_matmul_step_numeric():
    rng = np.random.default_rng(5)
    a, b, c = (jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32)) for _ in range(3))
    (out,) = model.matmul_step(a, b, c)
    np.testing.assert_allclose(out, c + a @ b, rtol=5e-4, atol=5e-4)


def test_hlo_text_is_deterministic():
    fn, specs = model.MODELS["matmul_block"]
    assert to_hlo_text(fn, specs) == to_hlo_text(fn, specs)


def test_manifest_matches_models(tmp_path):
    import subprocess, sys, os
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--only", "lu0", "fwd"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = (tmp_path / "MANIFEST.txt").read_text().strip().splitlines()
    assert len(manifest) == 2
    assert (tmp_path / "lu0.hlo.txt").exists()
    assert (tmp_path / "fwd.hlo.txt").exists()
