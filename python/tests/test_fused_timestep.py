"""L2 fused N-Body timestep vs an independent reference composition."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def reference_timestep(pos, vel, mass, dt):
    nb = pos.shape[0]
    acc = np.zeros_like(pos)
    for i in range(nb):
        for j in range(nb):
            acc[i] += np.asarray(ref.nbody_forces(pos[i], pos[j], mass[j]))
    new_pos = np.zeros_like(pos)
    new_vel = np.zeros_like(vel)
    for i in range(nb):
        p, v = ref.nbody_update(pos[i], vel[i], jnp.asarray(acc[i]), dt)
        new_pos[i], new_vel[i] = np.asarray(p), np.asarray(v)
    return new_pos, new_vel


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_fused_timestep_matches_reference(seed):
    rng = np.random.default_rng(seed)
    nb, bs = model.NB_FUSED, model.BS_FUSED
    pos = jnp.asarray(rng.standard_normal((nb, bs, 3)).astype(np.float32))
    vel = jnp.asarray(rng.standard_normal((nb, bs, 3)).astype(np.float32))
    mass = jnp.asarray(rng.random((nb, bs)).astype(np.float32))
    dt = jnp.asarray([0.01], jnp.float32)
    got_p, got_v = model.nbody_timestep(pos, vel, mass, dt)
    want_p, want_v = reference_timestep(np.asarray(pos), np.asarray(vel), mass, 0.01)
    np.testing.assert_allclose(got_p, want_p, rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(got_v, want_v, rtol=3e-3, atol=3e-3)


def test_momentum_drift_is_bounded():
    # Equal masses, symmetric forces: total momentum change ≈ 0.
    rng = np.random.default_rng(7)
    nb, bs = model.NB_FUSED, model.BS_FUSED
    pos = jnp.asarray(rng.standard_normal((nb, bs, 3)).astype(np.float32))
    vel = jnp.zeros((nb, bs, 3), jnp.float32)
    mass = jnp.ones((nb, bs), jnp.float32)
    dt = jnp.asarray([0.01], jnp.float32)
    _, new_vel = model.nbody_timestep(pos, vel, mass, dt)
    total_p = np.asarray(new_vel).sum(axis=(0, 1))
    assert np.all(np.abs(total_p) < 1e-1), total_p
