"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes, seeds and dtypes (the CORE correctness signal of
the compile path — kernels run interpret=True so these tests exercise the
exact computation the AOT artifacts contain).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

SIZES = [8, 16, 32, 64, 128]
TILED_SIZES = [64, 128, 256]


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


def diag_dominant(rng, n, dtype=np.float32):
    a = rng.standard_normal((n, n)).astype(dtype)
    return jnp.asarray(a + n * np.eye(n, dtype=dtype))


# --- Matmul -------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    bs=st.sampled_from(TILED_SIZES),
    tile=st.sampled_from([32, 64, 128]),
)
def test_matmul_block_matches_ref(seed, bs, tile):
    if bs % tile != 0:
        tile = bs
    rng = np.random.default_rng(seed)
    a, b, c = (rand(rng, bs, bs) for _ in range(3))
    got = kernels.matmul_block(a, b, c, tile=tile)
    want = ref.matmul_block(a, b, c)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_matmul_block_float64():
    rng = np.random.default_rng(7)
    a, b, c = (rand(rng, 64, 64, dtype=np.float64) for _ in range(3))
    got = kernels.matmul_block(a, b, c)
    np.testing.assert_allclose(got, ref.matmul_block(a, b, c), rtol=1e-12)


def test_matmul_zero_c_is_pure_product():
    rng = np.random.default_rng(3)
    a, b = rand(rng, 64, 64), rand(rng, 64, 64)
    c = jnp.zeros((64, 64), jnp.float32)
    np.testing.assert_allclose(
        kernels.matmul_block(a, b, c), a @ b, rtol=5e-4, atol=5e-4
    )


# --- N-Body -------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), bs=st.sampled_from(SIZES))
def test_nbody_forces_matches_ref(seed, bs):
    rng = np.random.default_rng(seed)
    pos_i, pos_j = rand(rng, bs, 3), rand(rng, bs, 3)
    mass = jnp.asarray(rng.random(bs).astype(np.float32))
    got = kernels.nbody_forces(pos_i, pos_j, mass)
    want = ref.nbody_forces(pos_i, pos_j, mass)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_nbody_forces_antisymmetry_two_particles():
    # Equal masses: F(i<-j) = -F(j<-i).
    pos_a = jnp.asarray([[0.0, 0.0, 0.0]] * 8, jnp.float32)
    pos_b = jnp.asarray([[1.0, 0.0, 0.0]] * 8, jnp.float32)
    m = jnp.ones(8, jnp.float32)
    f_ab = kernels.nbody_forces(pos_a, pos_b, m)
    f_ba = kernels.nbody_forces(pos_b, pos_a, m)
    np.testing.assert_allclose(f_ab, -f_ba, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), bs=st.sampled_from(SIZES))
def test_nbody_update_matches_ref(seed, bs):
    rng = np.random.default_rng(seed)
    pos, vel, acc = (rand(rng, bs, 3) for _ in range(3))
    gp, gv = kernels.nbody_update(pos, vel, acc, 0.05)
    wp, wv = ref.nbody_update(pos, vel, acc, 0.05)
    np.testing.assert_allclose(gp, wp, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gv, wv, rtol=1e-5, atol=1e-6)


# --- SparseLU -----------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), bs=st.sampled_from(SIZES))
def test_lu0_matches_ref(seed, bs):
    rng = np.random.default_rng(seed)
    a = diag_dominant(rng, bs)
    np.testing.assert_allclose(kernels.lu0(a), ref.lu0(a), rtol=2e-3, atol=2e-3)


def test_lu0_reconstructs_matrix():
    # L @ U must reproduce A (no pivoting, diagonally dominant).
    rng = np.random.default_rng(11)
    a = diag_dominant(rng, 32)
    lu = np.asarray(kernels.lu0(a))
    l = np.tril(lu, -1) + np.eye(32)
    u = np.triu(lu)
    np.testing.assert_allclose(l @ u, np.asarray(a), rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), bs=st.sampled_from(SIZES))
def test_fwd_matches_ref(seed, bs):
    rng = np.random.default_rng(seed)
    diag = ref.lu0(diag_dominant(rng, bs))
    a = rand(rng, bs, bs)
    np.testing.assert_allclose(
        kernels.fwd(diag, a), ref.fwd(diag, a), rtol=2e-3, atol=2e-3
    )


def test_fwd_solves_lower_system():
    rng = np.random.default_rng(13)
    diag = ref.lu0(diag_dominant(rng, 16))
    a = rand(rng, 16, 16)
    x = np.asarray(kernels.fwd(diag, a))
    l = np.tril(np.asarray(diag), -1) + np.eye(16)
    np.testing.assert_allclose(l @ x, np.asarray(a), rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), bs=st.sampled_from(SIZES))
def test_bdiv_matches_ref(seed, bs):
    rng = np.random.default_rng(seed)
    diag = ref.lu0(diag_dominant(rng, bs))
    a = rand(rng, bs, bs)
    np.testing.assert_allclose(
        kernels.bdiv(diag, a), ref.bdiv(diag, a), rtol=2e-3, atol=2e-3
    )


def test_bdiv_solves_upper_system():
    rng = np.random.default_rng(17)
    diag = ref.lu0(diag_dominant(rng, 16))
    a = rand(rng, 16, 16)
    x = np.asarray(kernels.bdiv(diag, a))
    u = np.triu(np.asarray(diag))
    np.testing.assert_allclose(x @ u, np.asarray(a), rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    bs=st.sampled_from(TILED_SIZES),
    tile=st.sampled_from([32, 64, 128]),
)
def test_bmod_matches_ref(seed, bs, tile):
    if bs % tile != 0:
        tile = bs
    rng = np.random.default_rng(seed)
    row, col, inner = (rand(rng, bs, bs) for _ in range(3))
    np.testing.assert_allclose(
        kernels.bmod(row, col, inner, tile=tile),
        ref.bmod(row, col, inner),
        rtol=5e-4,
        atol=5e-4,
    )


def test_blocked_sparselu_matches_dense_lu():
    """The full blocked elimination (the task decomposition the runtime
    executes) equals the unblocked LU of the assembled dense matrix."""
    rng = np.random.default_rng(23)
    nb, bs = 4, 16
    n = nb * bs
    dense = np.asarray(diag_dominant(rng, n))
    blocks = {
        (i, j): jnp.asarray(dense[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs])
        for i in range(nb)
        for j in range(nb)
    }
    out = ref.sparselu_blocked(blocks, nb)
    got = np.zeros_like(dense)
    for (i, j), blk in out.items():
        got[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs] = np.asarray(blk)
    want = np.asarray(ref.lu0(jnp.asarray(dense)))
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)
